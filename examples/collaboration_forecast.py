"""Value-of-collaboration forecasting (the paper's Fig. 6 + Section 6
data-market story): given pilot measurements, fit the Theorem-2 constants
and PREDICT how many owners at which privacy budget make collaboration
beat training alone — without anyone revealing their data. Every pilot
measurement is one `Federation` session on the convex fast path.

    PYTHONPATH=src python examples/collaboration_forecast.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core.cop import budget_sum, fit_constants, min_owners_for_benefit
from repro.data import owner_shards
from repro.federation import (Federation, FederationConfig, federate_problem,
                              relative_fitness)

N_PILOT, N_I, T = 5, 10_000, 1000


def measure(N, eps, seed=3, runs=8):
    shards = owner_shards("lending", [N_I] * N, seed=seed)
    prob, owners = federate_problem(shards, eps, reg=1e-5, theta_max=2.0)
    fed = Federation(owners, FederationConfig(horizon=T, rho=1.0, sigma=2e-5))
    tr = fed.run(jax.random.PRNGKey(0), prob, n_runs=runs)
    return prob, shards, float(jnp.mean(tr.psi[:, -1]))


def main():
    # 1) pilot: a small consortium measures CoP at a few budgets
    pilot = {}
    for eps in (2.0, 5.0, 10.0):
        _, _, cop = measure(N_PILOT, eps)
        pilot[eps] = cop
        print(f"pilot N={N_PILOT}, eps={eps:4.1f}: CoP = {cop:.4f}")
    ss = np.array([budget_sum([e] * N_PILOT) for e in pilot])
    c1, c2 = fit_constants(np.array([N_PILOT * N_I] * len(pilot)), ss,
                           np.array(list(pilot.values())))
    print(f"fitted constants: c1bar={c1:.3g} c2bar={c2:.3g}\n")

    # 2) the isolated baseline an owner would otherwise use
    prob, shards, _ = measure(N_PILOT, 10.0)
    X0, y0 = shards[0]
    th = np.linalg.solve(X0.T @ X0 / N_I + 1e-5 * np.eye(10),
                         X0.T @ y0 / N_I)
    psi_iso = float(relative_fitness(prob, jnp.asarray(np.clip(th, -2, 2))))
    print(f"isolated owner-0 model: psi = {psi_iso:.4f}")

    # 3) forecast: how many owners needed at each budget?
    print("\nforecast (eq. 11): min owners for collaboration to win")
    for eps in (0.5, 1.0, 2.5, 5.0, 10.0):
        n_min = min_owners_for_benefit(psi_iso, N_I, eps, c1, c2)
        print(f"  eps={eps:5.1f}: N >= {n_min}")

    # 4) verify one forecast point empirically
    eps = 2.5
    n_min = min_owners_for_benefit(psi_iso, N_I, eps, c1, c2)
    if 0 < n_min <= 64:
        _, _, cop = measure(n_min, eps)
        print(f"\nverify: N={n_min}, eps={eps} -> measured CoP {cop:.4f} "
              f"vs isolated {psi_iso:.4f} "
              f"({'WINS' if cop < psi_iso else 'loses'})")


if __name__ == "__main__":
    main()
