"""Serving example: batched greedy decoding from the Zamba2 hybrid
(Mamba2 recurrent state + shared-attention ring cache) — the runtime the
decode_32k / long_500k dry-runs lower at pod scale.

    PYTHONPATH=src python examples/serve_hybrid.py
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models import build_model


def main():
    cfg = get_config("zamba2-2.7b").reduced()
    window = 16                                # SWA on the shared attn block
    model = build_model(cfg, remat=False)
    k_init, k_prompt = jax.random.split(jax.random.PRNGKey(0))
    params = model.init(k_init, jnp.float32)

    B, prompt_len, gen = 4, 8, 48
    total = prompt_len + gen
    cache = model.init_cache(B, total, window=window, dtype=jnp.float32)
    prompt = jax.random.randint(k_prompt, (B, prompt_len), 0, cfg.vocab)

    step = jax.jit(lambda p, c, t, pos: model.decode_step(
        p, c, t, pos, window=window))

    toks = prompt[:, :1]
    out = [toks]
    t0 = time.time()
    for t in range(total - 1):
        logits, cache = step(params, cache, toks, jnp.int32(t))
        toks = (prompt[:, t + 1:t + 2] if t + 1 < prompt_len
                else jnp.argmax(logits[:, -1:], -1).astype(jnp.int32))
        out.append(toks)
    dt = time.time() - t0
    seqs = np.asarray(jnp.concatenate(out, axis=1))
    print(f"zamba2 hybrid decode: {B} seqs x {total} tokens "
          f"in {dt:.2f}s ({B*total/dt:.0f} tok/s on CPU)")
    print(f"SSM state: {cfg.n_layers} layers x "
          f"(H={cfg.ssm.expand*cfg.d_model//cfg.ssm.head_dim}, "
          f"N={cfg.ssm.d_state}, P={cfg.ssm.head_dim}) fp32; "
          f"shared-attn ring cache: {cache['shared'][0].k.shape} (W={window})")
    print("sample:", seqs[0][:24], "...")


if __name__ == "__main__":
    main()
