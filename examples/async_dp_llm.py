"""End-to-end deep-model driver: asynchronously DP-train a ~120M-param LM
across 4 private data owners for a few hundred steps on CPU — the same
bank-sharded code path the pod-scale dry-run lowers at 512 devices, driven
through the unified `repro.federation.Federation` session.

    PYTHONPATH=src python examples/async_dp_llm.py [--steps 300] [--tiny]
    PYTHONPATH=src python examples/async_dp_llm.py --arch xlstm-125m

Default model is a 12-layer dense 124M GQA transformer (XLA-CPU compiles it
in seconds; the assigned-pool archs are available via --arch but e.g.
xlstm-125m's sLSTM vjp takes very long to compile on this 1-core CPU).

Each step: uniform owner draw (Poisson clocks), Theorem-1 Laplace noise on
the clipped owner gradient, the paper's inertia update (eqs. 5-7), owner
bank write-back. Privacy accounting lives INSIDE the session's mechanism —
budget-exhausted owners are refused by `fed.step` itself.

By default the loop drives the FUSED multi-round path: chunks of
`--rounds-per-dispatch` rounds run as one `fed.run_rounds` dispatch with
the privacy ledger resident on-device, and `fed.reconcile` folds the
device counters back into the host accountant. `--rounds-per-dispatch 1`
falls back to the host-authorized per-round `fed.step` loop.
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.configs.base import ModelConfig
from repro.data import OwnerDataPipeline, synthetic_owner_shards
from repro.federation import (DataOwner, Federation, FederationConfig,
                              PrivatizerConfig)
from repro.models import build_model

DENSE_124M = ModelConfig(
    name="dense-124m", family="dense", n_layers=12, d_model=768,
    n_heads=12, n_kv_heads=4, d_ff=2048, vocab=50304,
    source="gpt2-small-like demo config")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--arch", default="dense-124m")
    ap.add_argument("--tiny", action="store_true",
                    help="reduced config (CI-speed)")
    ap.add_argument("--owners", type=int, default=4)
    ap.add_argument("--eps", type=float, default=2.0)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=0.05,
                    help="target effective owner-update rate; converted to "
                         "the paper's lr_scale by FederationConfig."
                         "from_target_lr (recorded deviation — the paper's "
                         "exact rho/T^2 rate is ~0 for deep nets)")
    ap.add_argument("--rounds-per-dispatch", type=int, default=25,
                    help="rounds fused into one run_rounds dispatch "
                         "(1 = legacy per-round step loop)")
    args = ap.parse_args()

    cfg = DENSE_124M if args.arch == "dense-124m" else get_config(args.arch)
    if args.tiny:
        cfg = cfg.reduced()
    model = build_model(cfg, remat=False)
    key, init_key = jax.random.split(jax.random.PRNGKey(0))
    params = model.init(init_key, jnp.float32)
    n_params = sum(int(np.prod(leaf.shape))
                   for leaf in jax.tree_util.tree_leaves(params))
    print(f"model: {cfg.name} ({n_params/1e6:.1f}M params, "
          f"{cfg.n_layers} layers)")

    N = args.owners
    shards = synthetic_owner_shards(N, 2048, args.seq, cfg.vocab, seed=0)
    pipe = OwnerDataPipeline(shards, args.batch, seed=0)
    horizon = max(args.steps, 100)
    fcfg = FederationConfig.from_target_lr(
        args.lr, n_owners=N, horizon=horizon, sigma=1e-2, theta_max=100.0)
    owners = [DataOwner(n=sz, epsilon=args.eps, xi=1.0)
              for sz in pipe.owner_sizes]
    fed = Federation(owners, fcfg)

    def loss_fn(p, b):
        return model.loss(p, b)[0]
    fed.make_step(loss_fn,
                  privatizer=PrivatizerConfig(xi=1.0,
                                              granularity="microbatch",
                                              n_microbatches=2),
                  donate=True)
    state = fed.init_state(params)

    losses = []
    t0 = time.time()
    R = max(1, args.rounds_per_dispatch)
    if R == 1:
        it = iter(pipe)
        for k in range(1, args.steps + 1):
            owner, batch = next(it)
            batch = {k2: jnp.asarray(v) for k2, v in batch.items()}
            key, sub = jax.random.split(key)
            state, m = fed.step(state, batch, owner, sub)
            if m["refused"]:
                continue
            if k % 25 == 0 or k == 1:
                loss = float(loss_fn(state.theta_L, batch))
                losses.append(loss)
                print(f"step {k:4d} owner={owner} central-loss={loss:.4f} "
                      f"clip={float(m['clip_frac']):.2f} "
                      f"[{(time.time()-t0)/k:.2f}s/step]")
    else:
        done = 0
        while done < args.steps:
            k = min(R, args.steps - done)
            owner_seq = pipe.schedule(k)
            batches = {k2: jnp.asarray(v)
                       for k2, v in pipe.batches_for(owner_seq).items()}
            key, sub = jax.random.split(key)
            state, ms = fed.run_rounds(
                state, batches, jnp.asarray(owner_seq, jnp.int32), key=sub)
            done += k
            granted = int((~np.asarray(ms["refused"])).sum())
            last = {k2: v[-1] for k2, v in batches.items()}
            loss = float(loss_fn(state.theta_L, last))
            losses.append(loss)
            print(f"step {done:4d} ({k} rounds/dispatch, {granted} granted) "
                  f"central-loss={loss:.4f} "
                  f"clip={float(np.asarray(ms['clip_frac']).mean()):.2f} "
                  f"[{(time.time()-t0)/done:.3f}s/step]")
        fed.reconcile(state)     # fold the device ledger into the host one
    print("\nprivacy ledger:")
    for i, s in fed.ledger().items():
        print(f"  owner {i}: eps={s['epsilon']} responses={s['responses']} "
              f"spent={s['spent']:.3f} refused={s['refused']}")
    if len(losses) >= 2:
        print(f"\nloss {losses[0]:.3f} -> {losses[-1]:.3f} "
              f"({'improved' if losses[-1] < losses[0] else 'flat'})")


if __name__ == "__main__":
    main()
