"""Quickstart: the federation API on synthetic lending data (Fig. 2).

    PYTHONPATH=src python examples/quickstart.py

Three banks, 10k records each, three privacy budgets. One `Federation`
session per budget runs the convex lax.scan fast path; then the Theorem-2
forecast — everything the paper's Section 5.1 does, at laptop scale.
"""
import jax
import numpy as np

from repro.core import bound_asymptotic, fit_constants
from repro.core.cop import budget_sum
from repro.data import owner_shards
from repro.federation import (Federation, FederationConfig, federate_problem,
                              with_budgets)


def main():
    N, n_i, T = 3, 10_000, 1000
    shards = owner_shards("lending", [n_i] * N, seed=0, heterogeneity=0.0)
    prob, owners = federate_problem(shards, 1.0, reg=1e-5, theta_max=2.0)
    print(f"{N} owners x {n_i} records; Xi = "
          f"{max(o.xi for o in owners):.1f}; theta* within "
          f"[{float(prob.theta_star.min()):.2f}, "
          f"{float(prob.theta_star.max()):.2f}]")

    cfg = FederationConfig(horizon=T, rho=1.0, sigma=2 * prob.reg)
    obs = {}
    for eps in (3.0, 7.0, 10.0):
        fed = Federation(with_budgets(owners, eps), cfg)
        tr = fed.run(jax.random.PRNGKey(0), prob, n_runs=30)
        psi = np.asarray(tr.psi)
        med = np.median(psi, axis=0)
        obs[eps] = float(np.mean(psi[:, -1]))
        print(f"eps={eps:5.1f}:  psi median k=10 {med[9]:8.4f}  "
              f"k=500 {med[499]:8.5f}  k=1000 {med[-1]:8.5f}  "
              f"(25-75%: {np.percentile(psi[:, -1], 25):.5f}"
              f"-{np.percentile(psi[:, -1], 75):.5f})")

    # Theorem-2 forecast (eq. 11): fit the two constants, predict
    ss = np.array([budget_sum([e] * N) for e in obs])
    c1, c2 = fit_constants(np.array([N * n_i] * len(obs)), ss,
                           np.array(list(obs.values())))
    print(f"\nfitted eq.(11) constants: c1bar={c1:.3g}, c2bar={c2:.3g}")
    for eps in obs:
        b = bound_asymptotic(N * n_i, [eps] * N, c1, c2)
        print(f"  eps={eps:5.1f}: observed CoP {obs[eps]:.5f}  "
              f"fitted bound {b:.5f}")


if __name__ == "__main__":
    main()
