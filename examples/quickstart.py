"""Quickstart: Algorithm 1 on synthetic lending data (the paper's Fig. 2).

    PYTHONPATH=src python examples/quickstart.py

Three banks, 100k records each, three privacy budgets. Prints the relative
fitness trajectory and the Theorem-2 forecast — everything the paper's
Section 5.1 does, at laptop scale.
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (Algo1Config, bound_asymptotic, fit_constants,
                        make_problem, run_many)
from repro.core.cop import budget_sum
from repro.data import owner_shards


def main():
    N, n_i, T = 3, 10_000, 1000
    shards = owner_shards("lending", [n_i] * N, seed=0, heterogeneity=0.0)
    prob, owners = make_problem(shards, reg=1e-5, theta_max=2.0)
    print(f"{N} owners x {n_i} records; Xi = "
          f"{max(o.xi for o in owners):.1f}; theta* within "
          f"[{float(prob.theta_star.min()):.2f}, "
          f"{float(prob.theta_star.max()):.2f}]")

    obs = {}
    for eps in (3.0, 7.0, 10.0):
        cfg = Algo1Config(horizon=T, rho=1.0, sigma=2 * prob.reg,
                          epsilons=[eps] * N)
        tr = run_many(jax.random.PRNGKey(0), prob, owners, cfg, 30)
        psi = np.asarray(tr.psi)
        med = np.median(psi, axis=0)
        obs[eps] = float(np.mean(psi[:, -1]))
        print(f"eps={eps:5.1f}:  psi median k=10 {med[9]:8.4f}  "
              f"k=500 {med[499]:8.5f}  k=1000 {med[-1]:8.5f}  "
              f"(25-75%: {np.percentile(psi[:, -1], 25):.5f}"
              f"-{np.percentile(psi[:, -1], 75):.5f})")

    # Theorem-2 forecast (eq. 11): fit the two constants, predict
    ss = np.array([budget_sum([e] * N) for e in obs])
    c1, c2 = fit_constants(np.array([N * n_i] * len(obs)), ss,
                           np.array(list(obs.values())))
    print(f"\nfitted eq.(11) constants: c1bar={c1:.3g}, c2bar={c2:.3g}")
    for eps in obs:
        b = bound_asymptotic(N * n_i, [eps] * N, c1, c2)
        print(f"  eps={eps:5.1f}: observed CoP {obs[eps]:.5f}  "
              f"fitted bound {b:.5f}")


if __name__ == "__main__":
    main()
