"""Launch-layer integration: step builders lower + compile on a debug mesh
and the roofline pipeline runs end-to-end (the 512-device campaign itself
runs via `python -m repro.launch.dryrun`; artifacts in results/dryrun)."""
import jax
import pytest

from repro.analysis.hlo_cost import analyze
from repro.analysis.roofline import model_flops, roofline_terms
from repro.configs import ShapeConfig, get_config
from repro.launch.mesh import make_debug_mesh, make_production_mesh
from repro.launch.steps import build_step

SHAPES = [ShapeConfig("t", 64, 4, "train"),
          ShapeConfig("p", 128, 2, "prefill"),
          ShapeConfig("d", 128, 4, "decode")]


@pytest.mark.parametrize("arch", ["yi-6b", "zamba2-2.7b", "qwen3-moe-30b-a3b"])
@pytest.mark.parametrize("shape", SHAPES, ids=lambda s: s.kind)
def test_step_lowers_and_walks(arch, shape):
    cfg = get_config(arch).reduced()
    mesh = make_debug_mesh(1, 1)
    bundle = build_step(cfg, shape, mesh, n_microbatches=2)
    jitted = jax.jit(bundle.step, in_shardings=bundle.in_shardings,
                     donate_argnums=bundle.donate_argnums)
    with mesh:
        compiled = jitted.lower(*bundle.args).compile()
    walked = analyze(compiled.as_text())
    assert walked["flops"] > 0
    terms = roofline_terms(walked["flops"], walked["traffic_bytes"],
                           walked["collective_bytes_total"])
    assert terms["dominant"] in ("compute", "memory", "collective")
    tokens = shape.global_batch * (shape.seq_len
                                   if shape.kind != "decode" else 1)
    assert model_flops(cfg.active_param_count(), tokens,
                       "train" if shape.kind == "train" else "infer") > 0


def test_production_mesh_requires_devices():
    with pytest.raises(RuntimeError):
        make_production_mesh(multi_pod=True)   # 1 CPU device < 512


def test_perf_knobs_lower():
    """§Perf configuration surface stays lowerable."""
    cfg = get_config("yi-6b").reduced()
    mesh = make_debug_mesh(1, 1)
    bundle = build_step(cfg, SHAPES[0], mesh, n_microbatches=2,
                        model_kw={"remat_groups": 2, "kv_chunk": 64})
    jitted = jax.jit(bundle.step, in_shardings=bundle.in_shardings,
                     donate_argnums=bundle.donate_argnums)
    with mesh:
        jitted.lower(*bundle.args).compile()
