"""Substrate: data pipeline, optimizers, checkpointing, sharding rules,
HLO cost walker, clocks."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import latest_step, load_checkpoint, save_checkpoint
from repro.configs import all_configs, get_config
from repro.core.clocks import owner_counts, poisson_schedule, uniform_schedule
from repro.data import (OwnerDataPipeline, health, lending, owner_shards,
                        synthetic_owner_shards)
from repro.optim import adamw, apply_updates, constant, cosine_decay, sgd


# ------------------------------ data --------------------------------------
def test_generators_shapes_and_bounds():
    for gen in (lending, health):
        X, y = gen(1000, seed=1)
        assert X.shape == (1000, 10) and y.shape == (1000,)
        assert np.isfinite(X).all() and np.isfinite(y).all()
        assert np.abs(X).max() <= 3.0 + 1e-9


def test_owner_shards_partition():
    sizes = [100, 250, 50]
    shards = owner_shards("lending", sizes, seed=0)
    assert [s[0].shape[0] for s in shards] == sizes
    # heterogeneity=0 gives exchangeable owners; >0 shifts the local y|x
    homog = owner_shards("lending", [200, 200], seed=0, heterogeneity=0.0)
    het = owner_shards("lending", [200, 200], seed=0, heterogeneity=1.0)
    # same X marginals per seed, different targets under heterogeneity
    np.testing.assert_allclose(homog[0][0], het[0][0])
    assert not np.allclose(homog[0][1], het[0][1])


def test_owner_pipeline_cycles():
    shards = synthetic_owner_shards(3, 8, 16, 100, seed=0)
    pipe = OwnerDataPipeline(shards, batch=4, seed=0)
    it = iter(pipe)
    owners = [next(it)[0] for _ in range(50)]
    assert set(owners) == {0, 1, 2}
    b = shards[0].next_batch(4)
    assert b["tokens"].shape == (4, 16)
    np.testing.assert_array_equal(b["labels"],
                                  np.roll(b["tokens"], -1, axis=1))


# ------------------------------ clocks -------------------------------------
def test_poisson_schedule_statistics(rng_key):
    sched = poisson_schedule(rng_key, n_owners=5, horizon=5000)
    assert float(sched.times[0]) >= 0
    assert bool(jnp.all(jnp.diff(sched.times) >= 0))      # ordered
    counts = np.asarray(owner_counts(sched.owners, 5))
    assert counts.min() > 5000 / 5 * 0.8                   # near-uniform
    # superposed rate = N -> mean gap 1/N
    gaps = np.diff(np.asarray(sched.times))
    assert np.mean(gaps) == pytest.approx(1 / 5, rel=0.1)


def test_uniform_schedule_matches_poisson_marginals(rng_key):
    s = uniform_schedule(rng_key, 4, 8000)
    counts = np.bincount(np.asarray(s), minlength=4)
    assert counts.min() > 8000 / 4 * 0.85


# ------------------------------ optim --------------------------------------
@pytest.mark.parametrize("opt", ["sgd", "momentum", "adamw"])
def test_optimizers_minimize_quadratic(opt, rng_key):
    target = jnp.asarray([1.0, -2.0, 0.5])
    params = {"w": jnp.zeros(3)}
    def loss(p):
        return jnp.sum((p["w"] - target) ** 2)
    if opt == "sgd":
        init, update = sgd(constant(0.1))
    elif opt == "momentum":
        init, update = sgd(constant(0.05), momentum=0.9)
    else:
        init, update = adamw(constant(0.1))
    state = init(params)
    for _ in range(200):
        g = jax.grad(loss)(params)
        upd, state = update(g, state, params)
        params = apply_updates(params, upd)
    assert float(loss(params)) < 1e-3


def test_cosine_schedule_endpoints():
    f = cosine_decay(1.0, total=100, warmup=10)
    assert float(f(jnp.int32(0))) == pytest.approx(0.0)
    assert float(f(jnp.int32(10))) == pytest.approx(1.0)
    assert float(f(jnp.int32(100))) == pytest.approx(0.0, abs=1e-6)


# ------------------------------ checkpoint ---------------------------------
def test_checkpoint_roundtrip(tmp_path, rng_key):
    from repro.core.async_trainer import AsyncDPConfig, init_state
    from repro.core.dp_sgd import PrivatizerConfig
    params = {"w": jax.random.normal(rng_key, (4, 4)),
              "blocks": [{"a": jnp.ones((2,))}, {"a": jnp.zeros((2,))}]}
    acfg = AsyncDPConfig(n_owners=2, horizon=10, epsilons=(1., 1.),
                         owner_sizes=(10, 10),
                         privatizer=PrivatizerConfig(xi=1.0))
    state = init_state(params, acfg)
    d = str(tmp_path / "ckpt")
    save_checkpoint(d, 7, state)
    assert latest_step(d) == 7
    like = init_state(jax.tree_util.tree_map(jnp.zeros_like, params), acfg)
    restored = load_checkpoint(d, 7, like)
    for a, b in zip(jax.tree_util.tree_leaves(restored),
                    jax.tree_util.tree_leaves(state)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b))


# ------------------------------ sharding rules -----------------------------
def _abstract_mesh(shape, names):
    # AbstractMesh takes ((name, size), ...) pairs, not separate tuples.
    from jax.sharding import AbstractMesh
    return AbstractMesh(tuple(zip(names, shape)))


@pytest.mark.parametrize("arch", sorted(all_configs()))
def test_param_specs_divisibility(arch, rng_key):
    """Every sharded axis must divide its dim on the production mesh."""
    from repro.models import build_model
    from repro.sharding import param_specs

    cfg = get_config(arch)
    model = build_model(cfg)
    sds = jax.eval_shape(lambda k: model.init(k, jnp.bfloat16),
                         jax.random.PRNGKey(0))
    mesh = _abstract_mesh((16, 16), ("data", "model"))
    specs = param_specs(sds, cfg, mesh)

    def check(path, leaf, spec):
        assert len(spec) <= len(leaf.shape), (path, spec, leaf.shape)
        for dim, ax in zip(leaf.shape, tuple(spec) + (None,) * 8):
            if ax is None:
                continue
            size = int(np.prod([mesh.shape[a] for a in
                                (ax if isinstance(ax, tuple) else (ax,))]))
            assert dim % size == 0, (path, spec, leaf.shape)

    jax.tree_util.tree_map_with_path(
        lambda p, leaf, s: check(p, leaf, s), sds, specs)


def test_hlo_cost_walker_known_workload():
    from repro.analysis.hlo_cost import analyze
    L, B, D, F = 4, 8, 32, 64

    def f(w1, w2, x):
        def body(h, ws):
            a, b = ws
            return jnp.tanh(h @ a) @ b, None
        h, _ = jax.lax.scan(body, x, (w1, w2))
        return jnp.sum(h)

    w1 = jnp.ones((L, D, F), jnp.float32)
    w2 = jnp.ones((L, F, D), jnp.float32)
    x = jnp.ones((B, D), jnp.float32)
    comp = jax.jit(f).lower(w1, w2, x).compile()
    res = analyze(comp.as_text())
    fwd = L * 2 * (2 * B * D * F)
    assert res["flops"] == pytest.approx(fwd, rel=0.05)
    assert res["traffic_bytes"] > 0
