"""The unified `repro.federation` API: equivalence with the legacy paths
(bit-for-bit under fixed PRNG keys), shim imports, mechanisms, schedules,
and budget exhaustion at the session layer."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data import owner_shards
from repro.federation import (AvailabilityTraceSchedule, DataOwner,
                              Federation, FederationConfig, PaperMechanism,
                              PoissonSchedule, PrivatizerConfig,
                              StrictMechanism, UniformSchedule,
                              federate_problem, with_budgets)

T, SIGMA = 200, 2e-5


@pytest.fixture(scope="module")
def convex():
    shards = owner_shards("lending", [2_000] * 3, seed=0)
    prob, owners = federate_problem(shards, 2.0, reg=1e-5, theta_max=2.0)
    return prob, owners


@pytest.fixture(scope="module")
def toy_deep():
    key = jax.random.PRNGKey(0)
    params = {"w": jax.random.normal(key, (3,)), "b": jnp.zeros(())}
    batch = {"x": jax.random.normal(jax.random.PRNGKey(1), (4, 3)),
             "y": jax.random.normal(jax.random.PRNGKey(2), (4,))}
    def loss_fn(p, b):
        return jnp.mean((b["x"] @ p["w"] + p["b"] - b["y"]) ** 2)
    priv = PrivatizerConfig(xi=1.0, granularity="example")
    return params, batch, loss_fn, priv


def _trees_equal(a, b):
    return all(np.array_equal(np.asarray(x), np.asarray(y)) for x, y in
               zip(jax.tree_util.tree_leaves(a), jax.tree_util.tree_leaves(b)))


# ------------------------- equivalence: convex -----------------------------
def test_convex_run_matches_run_algorithm1_exactly(convex):
    from repro.core import Algo1Config, run_algorithm1
    prob, owners = convex
    key = jax.random.PRNGKey(7)
    old = run_algorithm1(key, prob, [o.gram for o in owners],
                         Algo1Config(horizon=T, rho=1.0, sigma=SIGMA,
                                     epsilons=[o.epsilon for o in owners]))
    fed = Federation(owners, FederationConfig(horizon=T, rho=1.0,
                                              sigma=SIGMA))
    new = fed.run(key, prob)
    for a, b in zip(old, new):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_convex_run_many_matches_exactly(convex):
    from repro.core import Algo1Config, run_many
    prob, owners = convex
    key = jax.random.PRNGKey(3)
    old = run_many(key, prob, [o.gram for o in owners],
                   Algo1Config(horizon=T, rho=1.0, sigma=SIGMA,
                               epsilons=[o.epsilon for o in owners]), 6)
    new = Federation(owners, FederationConfig(horizon=T, rho=1.0,
                                              sigma=SIGMA)).run(
        key, prob, n_runs=6)
    np.testing.assert_array_equal(np.asarray(old.psi), np.asarray(new.psi))
    np.testing.assert_array_equal(np.asarray(old.theta_L),
                                  np.asarray(new.theta_L))


def test_convex_noiseless_flag(convex):
    prob, owners = convex
    cfg = FederationConfig(horizon=T, rho=1.0, sigma=SIGMA, noiseless=True)
    fed = Federation(owners, cfg)
    assert float(jnp.max(fed.mechanism.scales(p=10))) == 0.0
    tr = fed.run(jax.random.PRNGKey(0), prob)
    assert float(tr.psi[-1]) < float(tr.psi[9])      # converges noiselessly


# ------------------------- equivalence: deep -------------------------------
def test_deep_step_matches_make_train_step_exactly(toy_deep):
    from repro.core.async_trainer import (AsyncDPConfig, init_state,
                                          make_train_step)
    params, batch, loss_fn, priv = toy_deep
    acfg = AsyncDPConfig(n_owners=3, horizon=50, rho=1.0, sigma=1e-2,
                         epsilons=(1.0,) * 3, owner_sizes=(100,) * 3, xi=1.0,
                         theta_max=10.0, privatizer=priv, lr_scale=5.0)
    key = jax.random.PRNGKey(9)
    old_step = jax.jit(make_train_step(loss_fn, acfg))
    s1, m1 = old_step(init_state(params, acfg), batch, jnp.int32(1), key)

    owners = [DataOwner(n=100, epsilon=1.0, xi=1.0) for _ in range(3)]
    fed = Federation(owners, FederationConfig(horizon=50, rho=1.0,
                                              sigma=1e-2, theta_max=10.0,
                                              lr_scale=5.0))
    fed.make_step(loss_fn, privatizer=priv)
    f1, m2 = fed.step(fed.init_state(params), batch, 1, key)
    assert _trees_equal(s1, f1)
    assert float(m1["grad_noise_scale"]) == float(m2["grad_noise_scale"])
    assert m2["refused"] is False


# ------------------------- shims ------------------------------------------
def test_legacy_names_still_import():
    from repro.core import Algo1Config, run_many            # noqa: F401
    from repro.core.async_trainer import make_train_step    # noqa: F401
    from repro.core.algorithm1 import Algo1Trace, run_algorithm1  # noqa
    from repro.core.dp_sgd import clip_tree, private_grad   # noqa: F401
    from repro.core.privacy import PrivacyAccountant        # noqa: F401
    from repro.core.clocks import poisson_schedule          # noqa: F401
    from repro.core.linear import make_problem              # noqa: F401
    import repro.core.algorithm1 as old
    import repro.federation.convex as new
    assert old.run_algorithm1 is new.run_algorithm1         # thin, not a fork


# ------------------------- budget exhaustion -------------------------------
def test_exhausted_owner_refused_and_bank_untouched(toy_deep):
    params, batch, loss_fn, priv = toy_deep
    owners = [DataOwner(n=100, epsilon=1.0, xi=1.0) for _ in range(2)]
    fed = Federation(owners, FederationConfig(horizon=2, sigma=1e-2,
                                              theta_max=10.0))
    fed.make_step(loss_fn, privatizer=priv)
    state = fed.init_state(params)
    key = jax.random.PRNGKey(4)
    for _ in range(2):                                  # spend owner 0's cap
        state, m = fed.step(state, batch, 0, key)
        assert m["refused"] is False
    before = state
    state, m = fed.step(state, batch, 0, key)
    assert m["refused"] is True
    assert _trees_equal(before, state)                  # bank + central frozen
    led = fed.ledger()
    assert led[0]["exhausted"] and led[0]["refused"] == 1
    assert led[0]["responses"] == 2
    assert led[1]["responses"] == 0 and led[1]["refused"] == 0
    # an unexhausted owner still gets through
    state, m = fed.step(state, batch, 1, key)
    assert m["refused"] is False


def test_session_is_one_shot(convex):
    # a second ledgered run would emit responses the cumulative ledger
    # refuses — the session refuses reuse instead of drifting
    prob, owners = convex
    fed = Federation(owners, FederationConfig(horizon=20, sigma=SIGMA))
    fed.run(jax.random.PRNGKey(0), prob)
    with pytest.raises(RuntimeError, match="already ran"):
        fed.run(jax.random.PRNGKey(1), prob)
    # statistical replicas stay available on a fresh session
    fed2 = Federation(owners, FederationConfig(horizon=20, sigma=SIGMA))
    fed2.run(jax.random.PRNGKey(0), prob, n_runs=2)
    fed2.run(jax.random.PRNGKey(1), prob, n_runs=2)     # replicas reusable


def test_convex_capped_mechanism_enforces_cap(convex):
    prob, owners = convex
    fed = Federation(owners, FederationConfig(horizon=T, rho=1.0,
                                              sigma=SIGMA),
                     mechanism="per_owner_rounds", cap_slack=0.5)
    cap = fed.mechanism.cap
    assert cap is not None and cap < T // len(owners)
    tr = fed.run(jax.random.PRNGKey(0), prob)
    led = fed.ledger()
    counts = np.bincount(np.asarray(tr.owners_seq), minlength=len(owners))
    for i, c in enumerate(counts):
        assert led[i]["responses"] == min(int(c), cap)
        assert led[i]["refused"] == max(0, int(c) - cap)


# ------------------------- mechanisms & config -----------------------------
def test_cap_slack_rejected_on_uncapped_mechanisms(convex):
    _, owners = convex
    cfg = FederationConfig(horizon=T, sigma=SIGMA)
    with pytest.raises(ValueError, match="per_owner_rounds"):
        Federation(owners, cfg, cap_slack=0.5)   # paper mechanism: no cap


def test_strict_mechanism_sqrt_p_slack(convex):
    _, owners = convex
    cfg = FederationConfig(horizon=T, sigma=SIGMA)
    paper = PaperMechanism(owners, cfg).scales()
    strict = StrictMechanism(owners, cfg).scales(p=16)
    np.testing.assert_allclose(np.asarray(strict),
                               4.0 * np.asarray(paper), rtol=1e-6)
    with pytest.raises(ValueError):
        StrictMechanism(owners, cfg).scales()           # p is required


def test_deep_scales_use_enforced_clip_norm_not_owner_xi(toy_deep):
    # An owner whose gradients are clipped to a LARGER norm than its
    # nominal Xi_i must get noise calibrated to the enforced norm —
    # otherwise its real epsilon exceeds the ledgered one.
    params, batch, loss_fn, priv = toy_deep
    owners = [DataOwner(n=100, epsilon=1.0, xi=1.0),
              DataOwner(n=100, epsilon=1.0, xi=10.0)]
    fed = Federation(owners, FederationConfig(horizon=50, sigma=1e-2,
                                              theta_max=10.0))
    fed.make_step(loss_fn, privatizer=PrivatizerConfig(
        xi=10.0, granularity="example"))   # clips at 10.0, above owner 0's Xi
    state, m = fed.step(fed.init_state(params), batch, 0, jax.random.PRNGKey(0))
    assert float(m["grad_noise_scale"]) == pytest.approx(
        2 * 10.0 * 50 / (100 * 1.0))       # Theorem 1 at the CLIP norm
    np.testing.assert_allclose(
        np.asarray(fed.mechanism.scales(clip_norm=10.0)),
        np.asarray(PaperMechanism(
            [dataclasses.replace(o, xi=10.0) for o in owners],
            fed.config).scales()))


def test_sync_rejects_capped_composition(convex):
    prob, owners = convex
    fed = Federation(owners, FederationConfig(horizon=100, sigma=SIGMA),
                     mechanism="per_owner_rounds", strategy="sync")
    with pytest.raises(ValueError, match="asynchronous composition"):
        fed.run_sync(jax.random.PRNGKey(0), prob, lr=0.4)


def test_availability_trace_gap_falls_back_to_everyone(rng_key):
    # nobody is awake in phase [0.4, 1.0): draw falls back to everyone,
    # and available(..., fallback=True) reports the mask actually sampled
    sched = AvailabilityTraceSchedule(
        windows=((0.0, 0.4), (0.1, 0.4)), period=3.0)
    drawn = sched.draw_with_times(rng_key, 2, 2000)
    owners = np.asarray(drawn.owners)
    raw = np.asarray(sched.available(drawn.times))
    eff = np.asarray(sched.available(drawn.times, fallback=True))
    assert not raw.any(axis=1).all()                    # the trace has gaps
    assert eff[np.arange(len(owners)), owners].all()    # draw matches mask
    assert eff[~raw.any(axis=1)].all()                  # gaps -> everyone


def test_from_target_lr_roundtrip():
    cfg = FederationConfig.from_target_lr(0.05, n_owners=4, horizon=300,
                                          sigma=1e-2)
    assert cfg.effective_lr(4) == pytest.approx(0.05)
    # matches the legacy inline conversion from async_dp_llm.py
    assert cfg.lr_scale == pytest.approx(0.05 * 300 ** 2 * 1e-2 / 4)


def test_with_budgets_and_broadcast(convex):
    _, owners = convex
    re = with_budgets(owners, 7.0)
    assert all(o.epsilon == 7.0 for o in re)
    assert [o.n for o in re] == [o.n for o in owners]
    with pytest.raises(ValueError):
        with_budgets(owners, [1.0, 2.0])                # wrong length


# ------------------------- schedules ---------------------------------------
def test_schedules_are_interchangeable(convex, rng_key):
    prob, owners = convex
    cfg = FederationConfig(horizon=T, rho=1.0, sigma=SIGMA)
    for sched in (UniformSchedule(), PoissonSchedule(),
                  AvailabilityTraceSchedule(
                      windows=((0.0, 0.5), (0.25, 0.75), (0.5, 1.0)))):
        tr = Federation(owners, cfg, schedule=sched).run(rng_key, prob)
        assert tr.owners_seq.shape == (T,)
        assert 0 <= int(tr.owners_seq.min()) <= int(tr.owners_seq.max()) < 3
        assert np.isfinite(np.asarray(tr.psi)).all()


def test_availability_trace_respects_windows(rng_key):
    sched = AvailabilityTraceSchedule(
        windows=((0.0, 0.5), (0.5, 1.0)), period=10.0)
    drawn = sched.draw_with_times(rng_key, 2, 4000)
    avail = np.asarray(sched.available(drawn.times))
    owners = np.asarray(drawn.owners)
    assert avail[np.arange(len(owners)), owners].all()  # only awake owners
    assert set(np.unique(owners)) == {0, 1}             # both get daylight


def test_availability_trace_wraparound_window(rng_key):
    # owner 0's "business hours" straddle the period boundary
    sched = AvailabilityTraceSchedule(
        windows=((0.75, 0.25), (0.25, 0.75)), period=5.0)
    drawn = sched.draw_with_times(rng_key, 2, 2000)
    avail = np.asarray(sched.available(drawn.times))
    owners = np.asarray(drawn.owners)
    assert avail[np.arange(len(owners)), owners].all()


# ------------------------- sync strategy -----------------------------------
def test_sync_strategy_same_surface(convex):
    prob, owners = convex
    fed = Federation(owners, FederationConfig(horizon=100, sigma=SIGMA),
                     strategy="sync")
    tr = fed.run_sync(jax.random.PRNGKey(0), prob, lr=0.4)
    assert np.isfinite(np.asarray(tr.psi)).all()
    assert fed.ledger()[0]["responses"] == 100           # every round answers
    with pytest.raises(ValueError):
        fed.run(jax.random.PRNGKey(0), prob)             # wrong strategy

    vm = Federation(owners, FederationConfig(horizon=100, sigma=SIGMA),
                    strategy="sync").run_sync(jax.random.PRNGKey(0), prob,
                                              lr=0.4, n_runs=3)
    assert vm.psi.shape == (3, 100)


def test_sync_deep_weights_drop_exhausted_owner(toy_deep):
    params, batch, loss_fn, priv = toy_deep
    owners = [DataOwner(n=100, epsilon=1.0, xi=1.0) for _ in range(2)]
    fed = Federation(owners, FederationConfig(horizon=1, sigma=1e-2,
                                              theta_max=10.0),
                     strategy="sync")
    fed.make_step(loss_fn, privatizer=priv, lr=1e-3)
    batches = jax.tree_util.tree_map(lambda a: jnp.stack([a] * 2), batch)
    key = jax.random.PRNGKey(0)
    p1 = fed.sync_round(params, batches, key)            # both live
    assert all(np.isfinite(np.asarray(leaf)).all()
               for leaf in jax.tree_util.tree_leaves(p1))
    p2 = fed.sync_round(params, batches, key)            # both now exhausted
    assert _trees_equal(p2, params)                      # no-op round
    led = fed.ledger()
    assert all(led[i]["refused"] == 1 for i in range(2))
    with pytest.raises(ValueError, match="async path"):
        fed.step(None, batch, 0, key)                    # wrong strategy
