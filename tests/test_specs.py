"""input_specs contract: every dry-run input is a ShapeDtypeStruct with the
assigned shapes, including the modality-stub carve-outs."""
import jax.numpy as jnp
import pytest

from repro.configs import INPUT_SHAPES, get_config
from repro.launch import specs as S
from repro.models import build_model


def test_vlm_patch_stub_carveout():
    cfg = get_config("internvl2-2b")
    shape = INPUT_SHAPES["train_4k"]
    b = S.train_batch_specs(cfg, shape)
    # tokens shrink by n_patches; patch embeddings provided pre-computed
    assert b["tokens"].shape == (256, 4096 - 256)
    assert b["patches"].shape == (256, 256, 2048)
    assert b["patches"].dtype == jnp.bfloat16


def test_audio_frame_stub_carveout():
    cfg = get_config("whisper-medium")
    shape = INPUT_SHAPES["train_4k"]
    b = S.train_batch_specs(cfg, shape)
    assert b["frames"].shape == (256, 1500, 1024)
    assert b["tokens"].shape == (256, 4096)


def test_microbatch_major_layout():
    cfg = get_config("yi-6b")
    shape = INPUT_SHAPES["train_4k"]
    b = S.train_batch_specs(cfg, shape, microbatches=8)
    assert b["tokens"].shape == (8, 32, 4096)


@pytest.mark.parametrize("arch,shape,window", [
    ("mixtral-8x22b", "long_500k", 4096),      # native SWA
    ("yi-6b", "long_500k", 8192),              # documented override
    ("yi-6b", "prefill_32k", None),            # full attention elsewhere
    ("xlstm-125m", "long_500k", None),         # recurrent: no window needed
])
def test_effective_window_policy(arch, shape, window):
    cfg = get_config(arch)
    assert S.effective_window(cfg, INPUT_SHAPES[shape]) == window


@pytest.mark.parametrize("shape_name", sorted(INPUT_SHAPES))
def test_decode_cache_capacity(shape_name):
    """Windowed archs get ring caches of window size, not seq_len."""
    shape = INPUT_SHAPES[shape_name]
    if shape.kind != "decode":
        pytest.skip("decode shapes only")
    cfg = get_config("mixtral-8x22b")
    model = build_model(cfg)
    cache = S.cache_specs_struct(model, shape)
    cap = cache["kv"].k.shape[2]
    assert cap == min(shape.seq_len, cfg.sliding_window)
