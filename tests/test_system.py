"""End-to-end behaviour tests: the drivers and the value-of-collaboration
claim (the paper's headline experiment) at test scale."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (Algo1Config, make_problem, relative_fitness,
                        run_many)
from repro.data import owner_shards


def test_train_driver_end_to_end(tmp_path):
    from repro.launch.train import main
    state = main(["--arch", "xlstm-125m", "--steps", "3", "--batch", "4",
                  "--seq", "32", "--records", "64",
                  "--ckpt-dir", str(tmp_path / "ck")])
    assert int(state.step) == 3
    assert (tmp_path / "ck" / "step_00000003" / "arrays.npz").exists()


def test_serve_driver_end_to_end():
    from repro.launch.serve import main
    seqs = main(["--arch", "yi-6b", "--batch", "2", "--prompt-len", "4",
                 "--gen", "6"])
    assert seqs.shape == (2, 10)


def test_value_of_collaboration():
    """The paper's Fig. 6 logic at test scale: with enough owners and a
    reasonable budget, private collaboration beats training alone without
    privacy on one shard."""
    n_i, N, eps = 20_000, 8, 10.0
    shards = owner_shards("lending", [n_i] * N, seed=3)
    prob, owners = make_problem(shards, reg=1e-5, theta_max=2.0)

    # isolated non-private model of owner 0 (exact ridge on its shard)
    X0, y0 = shards[0]
    G0 = X0.T @ X0 / n_i
    h0 = X0.T @ y0 / n_i
    theta_iso = np.linalg.solve(G0 + 1e-5 * np.eye(10), h0)
    psi_iso = float(relative_fitness(prob, jnp.asarray(theta_iso)))

    cfg = Algo1Config(horizon=600, rho=1.0, sigma=2e-5, epsilons=[eps] * N)
    tr = run_many(jax.random.PRNGKey(0), prob, owners, cfg, 6)
    psi_collab = float(jnp.mean(tr.psi[:, -1]))
    assert psi_collab < psi_iso, (psi_collab, psi_iso)
