import os

# Smoke tests and benches must see the single real CPU device — the 512-way
# override belongs ONLY to launch/dryrun.py (see system DESIGN.md).
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import jax  # noqa: E402
import numpy as np  # noqa: E402
import pytest  # noqa: E402

jax.config.update("jax_enable_x64", False)


@pytest.fixture(scope="session")
def rng_key():
    return jax.random.PRNGKey(0)


@pytest.fixture(scope="session")
def np_rng():
    return np.random.default_rng(0)
