"""Mesh-sharded federation engine + owner-parallel grouped rounds.

Contracts under test (ISSUE 4):

  * Sharding rules: `flat_shardings` puts bank rows on the data axes and P
    on 'model' (folding the data axes into P when N does not divide),
    degrading to replication when nothing divides.
  * 1x1-mesh parity: the sharded engine reproduces the unsharded flat
    path BIT-FOR-BIT (params, bank, ledger, metrics) under the same keys.
  * Multi-device (the CI job forces 8 host devices via XLA_FLAGS): same
    refusal pattern and reconciled ledger EXACTLY; numerics to float
    tolerance (GSPMD reduction order); state stays sharded after
    run_rounds (no gather to one device).
  * Owner-parallel mode: conflict-free partition invariants; ledger spend
    exactly equal to the sequential scan; max_group=1 falls back to the
    sequential scan bit-for-bit; bounded theta_L divergence otherwise.
  * `Federation.reconcile` on sharded states: bit-exact fold, drift and
    superseded-snapshot errors still raised.

On a single-device host every mesh in here is 1x1 — the sharded code
paths still execute (constraints, device_put layouts), the specs just
degrade to replication. The CI `sharded-smoke` job runs this file under
XLA_FLAGS=--xla_force_host_platform_device_count=8 so the real
multi-device branches are exercised on every PR without TPU hardware.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.federation import (DataOwner, Federation, FederationConfig,
                              LedgerDriftError, ParamFlat, PrivatizerConfig,
                              pack_groups, partition_conflict_free)
from repro.launch.mesh import make_host_mesh
from repro.sharding.rules import flat_axes, flat_bank_spec, flat_shardings

N_OWNERS, K = 8, 24
MULTI_DEVICE = len(jax.devices()) > 1


@pytest.fixture(scope="module", autouse=True)
def _partitionable_rng():
    # Multi-device RNG contract: partitionable threefry makes every draw
    # invariant under sharding (the legacy lowering re-associates the
    # counters when GSPMD partitions it, changing the drawn values).
    # Module-scoped save/restore: the rest of the suite keeps the
    # default stream.
    old = jax.config.jax_threefry_partitionable
    jax.config.update("jax_threefry_partitionable", True)
    yield
    jax.config.update("jax_threefry_partitionable", old)


@pytest.fixture(scope="module")
def toy():
    key = jax.random.PRNGKey(0)
    # P = 6*4 + 4 = 28: NOT divisible by 8 — on the 8-device mesh the
    # theta spec degrades (model=2 divides, data folding doesn't), which
    # is exactly the degrade path the rules promise.
    params = {"w": jax.random.normal(key, (6, 4)), "b": jnp.zeros((4,))}
    batches = {"x": jax.random.normal(jax.random.PRNGKey(1), (K, 4, 6)),
               "y": jax.random.normal(jax.random.PRNGKey(2), (K, 4, 4))}
    def loss_fn(p, b):
        return jnp.mean((b["x"] @ p["w"] + p["b"] - b["y"]) ** 2)
    priv = PrivatizerConfig(xi=1.0, granularity="example")
    return params, batches, loss_fn, priv


def _make_fed(loss_fn, priv, horizon=3, mesh=None, **kw):
    owners = [DataOwner(n=100, epsilon=1.0, xi=1.0)
              for _ in range(N_OWNERS)]
    fed = Federation(owners, FederationConfig(horizon=horizon, sigma=1e-2,
                                              theta_max=10.0, lr_scale=5.0))
    fed.make_step(loss_fn, privatizer=priv, pack_params=True, mesh=mesh,
                  **kw)
    return fed


# ------------------------------ rules ---------------------------------------
def test_flat_axes_prefers_owner_rows_on_data():
    from jax.sharding import AbstractMesh
    mesh = AbstractMesh((("data", 4), ("model", 2)))
    n_ax, p_ax = flat_axes(mesh, n_owners=8, p=64)
    assert n_ax == ("data",) and p_ax == ("model",)
    assert flat_bank_spec(mesh, 8, 64) == jax.sharding.PartitionSpec(
        ("data",), ("model",))


def test_flat_axes_folds_data_into_p_when_owners_dont_divide():
    from jax.sharding import AbstractMesh
    mesh = AbstractMesh((("data", 4), ("model", 2)))
    n_ax, p_ax = flat_axes(mesh, n_owners=3, p=64)   # 3 % 4 != 0
    assert n_ax is None and p_ax == ("model", "data")
    # and degrades to replication when nothing divides
    n_ax, p_ax = flat_axes(mesh, n_owners=3, p=7)
    assert n_ax is None and p_ax is None


def test_flat_axes_multi_pod_data_axes():
    from jax.sharding import AbstractMesh
    mesh = AbstractMesh((("pod", 2), ("data", 2), ("model", 2)))
    n_ax, p_ax = flat_axes(mesh, n_owners=8, p=64)
    assert n_ax == ("pod", "data") and p_ax == ("model",)


def test_partition_conflict_free_invariants():
    seq = [0, 1, 2, 0, 1, 0, 0, 3]
    groups = partition_conflict_free(seq)
    assert groups == [(0, 3), (3, 2), (5, 1), (6, 2)]
    # every group: consecutive, distinct owners; concatenation == seq
    flat = []
    for start, length in groups:
        chunk = seq[start:start + length]
        assert len(set(chunk)) == len(chunk)
        flat.extend(chunk)
    assert flat == seq
    assert partition_conflict_free(seq, max_group=1) == [
        (i, 1) for i in range(len(seq))]
    assert partition_conflict_free([]) == []
    with pytest.raises(ValueError, match="max_group"):
        partition_conflict_free(seq, max_group=0)


def test_pack_groups_layout():
    idx, valid = pack_groups([(0, 3), (3, 1), (4, 2)])
    np.testing.assert_array_equal(idx, [[0, 1, 2], [3, 0, 0], [4, 5, 0]])
    np.testing.assert_array_equal(valid, [[True, True, True],
                                          [True, False, False],
                                          [True, True, False]])
    idx0, valid0 = pack_groups([])
    assert idx0.shape == (0, 1) and valid0.shape == (0, 1)


# ----------------------- sharded-state parity -------------------------------
def _run_pair(toy, mesh, bank_dtype=None):
    params, batches, loss_fn, priv = toy
    seq = jax.random.randint(jax.random.PRNGKey(3), (K,), 0, N_OWNERS)
    root = jax.random.PRNGKey(4)
    fed_u = _make_fed(loss_fn, priv, bank_dtype=bank_dtype)
    fed_s = _make_fed(loss_fn, priv, mesh=mesh, bank_dtype=bank_dtype)
    s_u, m_u = fed_u.run_rounds(fed_u.init_state(params), batches, seq,
                                key=root)
    s_s, m_s = fed_s.run_rounds(fed_s.init_state(params), batches, seq,
                                key=root)
    return fed_u, fed_s, s_u, s_s, m_u, m_s


def test_one_by_one_mesh_is_bit_exact(toy):
    # The sharded engine on a trivial mesh IS the PR 3 flat path: same
    # trace modulo no-op constraints, bit-for-bit outputs.
    from repro.launch.mesh import make_debug_mesh
    fed_u, fed_s, s_u, s_s, m_u, m_s = _run_pair(toy, make_debug_mesh(1, 1))
    np.testing.assert_array_equal(np.asarray(s_u.theta_L.buf),
                                  np.asarray(s_s.theta_L.buf))
    np.testing.assert_array_equal(np.asarray(s_u.bank), np.asarray(s_s.bank))
    for name in m_u:
        np.testing.assert_array_equal(np.asarray(m_u[name]),
                                      np.asarray(m_s[name]))
    assert fed_s.reconcile(s_s) == fed_u.reconcile(s_u)


def test_host_mesh_parity_and_residency(toy):
    # Whatever this host offers (1 device locally, 8 in the CI smoke job):
    # exact refusals + ledger, float-tolerance numerics, and the state
    # keeps its mesh layout after the scan — run_rounds never gathered the
    # bank to one device.
    mesh = make_host_mesh(model=2 if len(jax.devices()) % 2 == 0 else 1)
    fed_u, fed_s, s_u, s_s, m_u, m_s = _run_pair(toy, mesh)
    np.testing.assert_array_equal(np.asarray(m_u["refused"]),
                                  np.asarray(m_s["refused"]))
    np.testing.assert_allclose(np.asarray(s_u.theta_L.buf),
                               np.asarray(s_s.theta_L.buf),
                               rtol=2e-5, atol=2e-6)
    assert fed_s.reconcile(s_s) == fed_u.reconcile(s_u)
    assert set(s_s.bank.sharding.mesh.axis_names) == {"data", "model"}
    if MULTI_DEVICE:
        assert len(s_s.bank.sharding.device_set) == len(jax.devices())
        assert not s_s.bank.is_fully_replicated


@pytest.mark.skipif(not MULTI_DEVICE, reason="needs the forced 8-device "
                    "host (CI sharded-smoke job)")
def test_bank_rows_actually_shard_across_devices(toy):
    params, _, loss_fn, priv = toy
    mesh = make_host_mesh(model=2)
    fed = _make_fed(loss_fn, priv, mesh=mesh)
    state = fed.init_state(params)
    spec = state.bank.sharding.spec
    assert spec[0] == ("data",)          # owner rows over the data axis
    shard_rows = {s.data.shape[0] for s in state.bank.addressable_shards}
    assert shard_rows == {N_OWNERS // mesh.shape["data"]}
    # theta replicates over data, shards P over model when divisible
    p = state.theta_L.size
    assert state.theta_L.buf.sharding.spec == (
        flat_shardings(mesh, N_OWNERS, p).theta.spec)


def test_bf16_bank_works_sharded(toy):
    mesh = make_host_mesh(model=2 if len(jax.devices()) % 2 == 0 else 1)
    fed_u, fed_s, s_u, s_s, m_u, m_s = _run_pair(toy, mesh,
                                                 bank_dtype=jnp.bfloat16)
    assert s_s.bank.dtype == jnp.bfloat16
    np.testing.assert_array_equal(np.asarray(m_u["refused"]),
                                  np.asarray(m_s["refused"]))
    assert fed_s.reconcile(s_s) == fed_u.reconcile(s_u)


def test_bf16_bank_under_grouped_owner_parallel(toy):
    # the bf16 bank previously only ran through the sequential scan in
    # this suite; the grouped driver must keep the quantized-row
    # semantics: exact refusal pattern and ledger spend vs the bf16
    # sequential scan, rows written back in bf16, bounded theta deviation
    params, batches, loss_fn, priv = toy
    seq = jax.random.randint(jax.random.PRNGKey(3), (K,), 0, N_OWNERS)
    root = jax.random.PRNGKey(4)
    fed_s = _make_fed(loss_fn, priv, bank_dtype=jnp.bfloat16)
    fed_g = _make_fed(loss_fn, priv, bank_dtype=jnp.bfloat16)
    s_s, m_s = fed_s.run_rounds(fed_s.init_state(params), batches, seq,
                                key=root)
    s_g, m_g = fed_g.run_rounds(fed_g.init_state(params), batches, seq,
                                key=root, owner_parallel=True)
    assert s_g.bank.dtype == jnp.bfloat16
    assert int(np.asarray(m_s["refused"]).sum()) > 0
    np.testing.assert_array_equal(np.asarray(m_s["refused"]),
                                  np.asarray(m_g["refused"]))
    np.testing.assert_array_equal(np.asarray(s_s.ledger.spent),
                                  np.asarray(s_g.ledger.spent))
    assert fed_g.reconcile(s_g) == fed_s.reconcile(s_s)
    g = np.asarray(s_g.theta_L.buf)
    assert np.isfinite(g).all()
    assert np.max(np.abs(np.asarray(s_s.theta_L.buf) - g)) < 2.0
    # and the grouped driver composes with a mesh on the bf16 bank
    mesh = make_host_mesh(model=2 if len(jax.devices()) % 2 == 0 else 1)
    fed_m = _make_fed(loss_fn, priv, mesh=mesh, bank_dtype=jnp.bfloat16)
    s_m, m_m = fed_m.run_rounds(fed_m.init_state(params), batches, seq,
                                key=root, owner_parallel=True)
    np.testing.assert_array_equal(np.asarray(m_s["refused"]),
                                  np.asarray(m_m["refused"]))
    assert fed_m.reconcile(s_m) == fed_g.ledger()


@pytest.mark.parametrize("fmt", ["int8", "fp8"])
def test_quant_bank_works_sharded(toy, fmt):
    # the QuantBank bundle (codes/scales/residual) under flat_shardings:
    # codes rows over the data axes, scales rows likewise, residual laid
    # out exactly like theta; refusals and reconciled ledger exact vs the
    # unsharded quantized engine
    from repro.federation import QuantBank
    mesh = make_host_mesh(model=2 if len(jax.devices()) % 2 == 0 else 1)
    fed_u, fed_s, s_u, s_s, m_u, m_s = _run_pair(toy, mesh, bank_dtype=fmt)
    assert isinstance(s_s.bank, QuantBank)
    np.testing.assert_array_equal(np.asarray(m_u["refused"]),
                                  np.asarray(m_s["refused"]))
    assert fed_s.reconcile(s_s) == fed_u.reconcile(s_u)
    assert np.isfinite(np.asarray(s_s.theta_L.buf)).all()
    if MULTI_DEVICE:
        assert s_s.bank.codes.sharding.spec[0] in ("data", ("data",))
        assert s_s.bank.scales.sharding.spec[0] in ("data", ("data",))
        # the residual lives exactly where theta lives (they add)
        assert (s_s.bank.residual.sharding.spec
                == s_s.theta_L.buf.sharding.spec)


# ------------------- reconcile on sharded states ----------------------------
def test_sharded_reconcile_folds_bit_exactly_and_detects_drift(toy):
    params, batches, loss_fn, priv = toy
    mesh = make_host_mesh()
    fed = _make_fed(loss_fn, priv, horizon=2, mesh=mesh)
    state = fed.init_state(params)
    b0 = jax.tree_util.tree_map(lambda a: a[0], batches)
    key = jax.random.PRNGKey(0)
    for _ in range(2):                     # spend owner 0's cap host-side
        state, m = fed.step(state, b0, 0, key)
        assert not m["refused"]
    # device counters fold back bit-exactly through the sharded state
    led = fed.reconcile(state)
    assert led[0]["responses"] == 2 and led[0]["exhausted"]
    # ...and a STALE device ledger (snapshot predates host-side spend) is
    # still refused loudly, sharded or not
    fed2 = _make_fed(loss_fn, priv, horizon=2, mesh=mesh)
    st2 = fed2.init_state(params)
    for _ in range(2):
        st2, _ = fed2.step(st2, b0, 0, key)
    seq = jnp.zeros(2, jnp.int32)
    st2, ms = fed2.run_rounds(
        st2, jax.tree_util.tree_map(lambda a: a[:2], batches), seq,
        key=jax.random.PRNGKey(1))
    assert not np.asarray(ms["refused"]).any()    # stale ledger grants
    with pytest.raises(LedgerDriftError, match="stale"):
        fed2.reconcile(st2)


def test_sharded_superseded_snapshot_cannot_reconcile(toy):
    params, batches, loss_fn, priv = toy
    mesh = make_host_mesh()
    fed = _make_fed(loss_fn, priv, mesh=mesh)        # horizon (cap) = 3
    def sub(n):
        return jax.tree_util.tree_map(lambda a: a[:n], batches)
    state_a = fed.init_state(params)
    state_a, _ = fed.run_rounds(state_a, sub(8), jnp.zeros(8, jnp.int32),
                                key=jax.random.PRNGKey(1))
    state_b = fed.init_state(params)                 # supersedes state_a
    state_b, _ = fed.run_rounds(state_b, sub(4), jnp.zeros(4, jnp.int32),
                                key=jax.random.PRNGKey(2))
    led = fed.reconcile(state_b)
    assert led[0]["responses"] == 3 and led[0]["refused"] == 1
    with pytest.raises(LedgerDriftError, match="superseded"):
        fed.reconcile(state_a)


# ------------------------ owner-parallel mode -------------------------------
def test_owner_parallel_ledger_spend_matches_sequential(toy):
    # the acceptance bar: grouped execution never changes WHO answered and
    # WHO was refused — the privacy spend is the sequential scan's, exactly
    params, batches, loss_fn, priv = toy
    seq = jax.random.randint(jax.random.PRNGKey(3), (K,), 0, N_OWNERS)
    root = jax.random.PRNGKey(4)
    fed_s = _make_fed(loss_fn, priv)
    fed_g = _make_fed(loss_fn, priv)
    s_s, m_s = fed_s.run_rounds(fed_s.init_state(params), batches, seq,
                                key=root)
    s_g, m_g = fed_g.run_rounds(fed_g.init_state(params), batches, seq,
                                key=root, owner_parallel=True)
    assert int(np.asarray(m_s["refused"]).sum()) > 0       # exhaustion bites
    np.testing.assert_array_equal(np.asarray(m_s["refused"]),
                                  np.asarray(m_g["refused"]))
    np.testing.assert_array_equal(np.asarray(m_s["owner"]),
                                  np.asarray(m_g["owner"]))
    np.testing.assert_array_equal(np.asarray(s_s.ledger.spent),
                                  np.asarray(s_g.ledger.spent))
    np.testing.assert_array_equal(np.asarray(s_s.ledger.refused),
                                  np.asarray(s_g.ledger.refused))
    assert int(s_s.step) == int(s_g.step)
    assert fed_g.reconcile(s_g) == fed_s.reconcile(s_s)
    # bounded deviation, not garbage: both stay in Theta and close-ish
    g = np.asarray(s_g.theta_L.buf)
    assert np.isfinite(g).all() and np.abs(g).max() <= 10.0
    assert np.max(np.abs(np.asarray(s_s.theta_L.buf) - g)) < 2.0


def test_owner_parallel_max_group_one_is_bit_exact(toy):
    # size-1 groups == the sequential scan (run_rounds literally routes to
    # it), so the owner-parallel surface degrades to exact semantics
    params, batches, loss_fn, priv = toy
    seq = jax.random.randint(jax.random.PRNGKey(3), (K,), 0, N_OWNERS)
    root = jax.random.PRNGKey(4)
    fed_s = _make_fed(loss_fn, priv)
    fed_g = _make_fed(loss_fn, priv)
    s_s, m_s = fed_s.run_rounds(fed_s.init_state(params), batches, seq,
                                key=root)
    s_g, m_g = fed_g.run_rounds(fed_g.init_state(params), batches, seq,
                                key=root, owner_parallel=True, max_group=1)
    np.testing.assert_array_equal(np.asarray(s_s.theta_L.buf),
                                  np.asarray(s_g.theta_L.buf))
    np.testing.assert_array_equal(np.asarray(s_s.bank), np.asarray(s_g.bank))
    for name in m_s:
        np.testing.assert_array_equal(np.asarray(m_s[name]),
                                      np.asarray(m_g[name]))


def test_owner_parallel_metrics_come_back_in_round_order(toy):
    params, batches, loss_fn, priv = toy
    # a schedule with a long conflict-free prefix and repeats after
    seq = jnp.asarray(list(range(N_OWNERS)) * (K // N_OWNERS), jnp.int32)
    fed = _make_fed(loss_fn, priv, horizon=K)
    s, m = fed.run_rounds(fed.init_state(params), batches, seq,
                          key=jax.random.PRNGKey(4), owner_parallel=True)
    np.testing.assert_array_equal(np.asarray(m["owner"]), np.asarray(seq))
    assert m["clip_frac"].shape == (K,)
    assert not np.asarray(m["refused"]).any()


def test_owner_parallel_on_tree_state(toy):
    # the grouped driver is representation-generic: pytree states vmap
    # through the same body
    params, batches, loss_fn, priv = toy
    seq = jax.random.randint(jax.random.PRNGKey(3), (K,), 0, N_OWNERS)
    root = jax.random.PRNGKey(4)
    owners = [DataOwner(n=100, epsilon=1.0, xi=1.0)] * N_OWNERS
    fed = Federation(owners, FederationConfig(horizon=3, sigma=1e-2,
                                              theta_max=10.0, lr_scale=5.0))
    fed.make_step(loss_fn, privatizer=priv)          # tree representation
    s, m = fed.run_rounds(fed.init_state(params), batches, seq, key=root,
                          owner_parallel=True)
    assert not isinstance(s.theta_L, ParamFlat)
    fed_ref = _make_fed(loss_fn, priv)
    s_ref, m_ref = fed_ref.run_rounds(fed_ref.init_state(params), batches,
                                      seq, key=root, owner_parallel=True)
    np.testing.assert_array_equal(np.asarray(m["refused"]),
                                  np.asarray(m_ref["refused"]))
    assert fed.reconcile(s) == fed_ref.reconcile(s_ref)


def test_owner_parallel_with_fused_kernel_and_mesh(toy):
    # the production stack end to end: dp_round kernel path + bf16 bank +
    # host mesh + grouped schedule
    params, batches, loss_fn, _ = toy
    priv = PrivatizerConfig(xi=1e-3, granularity="microbatch",
                            n_microbatches=2, fused_kernel=True,
                            kernel_block_rows=8)
    mesh = make_host_mesh()
    fed = _make_fed(loss_fn, priv, horizon=2, mesh=mesh,
                    bank_dtype=jnp.bfloat16)
    seq = jnp.asarray(np.arange(K) % 4, jnp.int32)      # owners 0-3, 6 each
    s, ms = fed.run_rounds(fed.init_state(params), batches, seq,
                           key=jax.random.PRNGKey(6), owner_parallel=True)
    assert np.isfinite(np.asarray(s.theta_L.buf)).all()
    granted = ~np.asarray(ms["refused"])
    assert granted.sum() == 8                           # 2 per owner cap
    led = fed.reconcile(s)
    assert all(led[i]["responses"] == 2 and led[i]["refused"] == 4
               for i in range(4))


def test_owner_parallel_repeat_dispatches_reuse_compile_cache(toy):
    # schedule-drawn partitions differ per dispatch; the session pads
    # (n_groups, G_max) to stable buckets so a serving loop doesn't
    # recompile the K-round scan every call
    params, batches, loss_fn, priv = toy
    fed = _make_fed(loss_fn, priv, horizon=K)
    state = fed.init_state(params)
    for seed in range(4):
        seq = jax.random.randint(jax.random.PRNGKey(seed), (K,), 0,
                                 N_OWNERS)
        state, m = fed.run_rounds(state, batches, seq,
                                  key=jax.random.PRNGKey(10 + seed),
                                  owner_parallel=True, max_group=4)
        assert m["refused"].shape == (K,)
    # compiles are bounded by the power-of-two group buckets straddled
    # (here 2: n_groups lands on both sides of a boundary across seeds),
    # NOT one per dispatch
    assert fed._group_fn._cache_size() <= 2


def test_mesh_requires_flat_engine(toy):
    params, _, loss_fn, priv = toy
    owners = [DataOwner(n=100, epsilon=1.0, xi=1.0)] * N_OWNERS
    fed = Federation(owners, FederationConfig(horizon=3, sigma=1e-2))
    with pytest.raises(ValueError, match="flat-engine option"):
        fed.make_step(loss_fn, privatizer=priv, mesh=make_host_mesh())
    fed.make_step(loss_fn, privatizer=priv)
    with pytest.raises(ValueError, match="flat-engine option"):
        fed.init_state(params, mesh=make_host_mesh())
