"""Staleness-aware async runtime (PR 10): deadlines, retries, decay.

The contracts under test:
  * a zero-latency, zero-timeout, decay=1 staleness policy reproduces the
    fault-armed engine bit-for-bit (every codec) — the runtime arms
    without changing a single bit until latency actually bites;
  * under a fixed key, step loop == fused scan == grouped driver produce
    bit-identical params/bank/ledger/staleness counters with latency,
    deadlines, retries and decay all armed;
  * epsilon lands at response time: answered-late (TIMEOUT) spends,
    never-answered (DROP) and masked retries do not;
  * timeouts schedule exponential backoff with a per-owner retry budget
    and do NOT tick the fault-quarantine window;
  * the ledger's timed_out/retried columns fold through reconcile
    exactly (idempotent; tampering raises LedgerDriftError);
  * the paged engine (n_hot >= N) reproduces the flat engine under the
    full runtime;
  * merge_timeout_codes / as_tick_times enforce their contracts.
"""
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.federation import (DROP, OK, TIMEOUT, DataOwner, FaultPlan,
                              FaultPolicy, Federation, FederationConfig,
                              LatencyPlan, PoissonSchedule, StalenessPolicy,
                              as_tick_times, merge_timeout_codes)
from repro.federation.dp_sgd import PrivatizerConfig
from repro.federation.mechanisms import LedgerDriftError

N_OWNERS, K = 3, 12
CODECS = [None, jnp.bfloat16, "int8", "fp8"]


@pytest.fixture(scope="module")
def toy():
    def loss_fn(params, batch):
        pred = batch["x"] @ params["w"] + params["b"]
        return jnp.mean((pred - batch["y"]) ** 2)

    params = {"w": jnp.zeros((6,), jnp.float32),
              "b": jnp.zeros((), jnp.float32)}
    kb = jax.random.PRNGKey(7)
    batches = {"x": jax.random.normal(kb, (K, 4, 6)),
               "y": jnp.ones((K, 4))}
    return loss_fn, params, batches


def _make_fed(loss_fn, *, fault_policy=None, staleness=None, pack=False,
              bank_dtype=None, mechanism="paper", tree_depth=None,
              horizon=16):
    owners = [DataOwner(n=200, epsilon=2.0, xi=1.0)] * N_OWNERS
    cfg = FederationConfig(horizon=horizon, sigma=1e-2, theta_max=10.0,
                           lr_scale=5.0)
    fed = Federation(owners, cfg, mechanism=mechanism,
                     tree_depth=tree_depth, fault_policy=fault_policy,
                     staleness=staleness)
    fed.make_step(loss_fn, privatizer=PrivatizerConfig(
        xi=1.0, granularity="example"), pack_params=pack,
        bank_dtype=bank_dtype)
    return fed


def _round_robin():
    return jnp.asarray(np.arange(K) % N_OWNERS, jnp.int32)


def _leaves_equal(a, b):
    la = jax.tree_util.tree_leaves(a)
    lb = jax.tree_util.tree_leaves(b)
    assert len(la) == len(lb)
    return all(bool((np.asarray(x) == np.asarray(y)).all())
               for x, y in zip(la, lb))


PLAN = FaultPlan(drop=0.2, stale=0.1, nonfinite=0.2, corrupt=0.2)
POLICY = FaultPolicy(max_faults=2, window=8)
# deadline bites owner 1 (base 2.0 > 1.0), retries arm backoff, decay<1
# exercises the lambda**age inertia path on every driver
RUNTIME = StalenessPolicy(deadline=1.0, max_retries=2, backoff_cap=3,
                          decay=0.9)
LAT = LatencyPlan(base=(0.2, 2.0, 0.2), jitter=0.5)


# ------------------------ identity-runtime parity ---------------------------

@pytest.mark.parametrize("bank_dtype", CODECS)
def test_identity_runtime_matches_fault_armed_engine(toy, bank_dtype):
    # deadline=inf, no retries, decay=1: the armed runtime must trace a
    # program bit-identical to the plain fault-armed engine
    loss_fn, params, batches = toy
    key = jax.random.PRNGKey(3)
    seq = _round_robin()
    pack = bank_dtype is not None

    fed_off = _make_fed(loss_fn, fault_policy=POLICY, pack=pack,
                        bank_dtype=bank_dtype)
    s_off = fed_off.init_state(params)
    s_off, m_off = fed_off.run_rounds(s_off, batches, seq, key, faults=PLAN)

    fed_on = _make_fed(loss_fn, fault_policy=POLICY,
                       staleness=StalenessPolicy(), pack=pack,
                       bank_dtype=bank_dtype)
    s_on = fed_on.init_state(params)
    s_on, m_on = fed_on.run_rounds(s_on, batches, seq, key, faults=PLAN,
                                   latency=LatencyPlan())

    assert _leaves_equal(s_off.theta_L, s_on.theta_L)
    assert _leaves_equal(s_off.bank, s_on.bank)
    assert int(s_off.step) == int(s_on.step)
    assert not bool(np.asarray(m_on["timed_out"]).any())
    assert not bool(np.asarray(m_on["retried"]).any())
    # runtime counters advanced but never bit: clock == K, no grants
    # missed (every applied round stamped), no cooldowns scheduled
    assert int(s_on.stale.clock) == K
    assert not np.asarray(s_on.stale.cooldown).any()
    assert fed_off.reconcile(s_off) == fed_on.reconcile(s_on)


# ------------------ three-driver equivalence with runtime -------------------

@pytest.mark.parametrize("bank_dtype", CODECS)
def test_drivers_bit_identical_under_runtime(toy, bank_dtype):
    loss_fn, params, batches = toy
    key = jax.random.PRNGKey(5)
    seq = _round_robin()
    pack = bank_dtype is not None

    # fused scan
    fed_f = _make_fed(loss_fn, fault_policy=POLICY, staleness=RUNTIME,
                      pack=pack, bank_dtype=bank_dtype)
    s_f = fed_f.init_state(params)
    s_f, m_f = fed_f.run_rounds(s_f, batches, seq, key, faults=PLAN,
                                latency=LAT)
    led_f = fed_f.reconcile(s_f)
    assert int(np.asarray(m_f["timed_out"]).sum()) > 0
    assert int(np.asarray(m_f["retried"]).sum()) > 0

    # per-round step loop under the same merged codes + keys (the host
    # computes lateness exactly as run_rounds does: same key, same salt)
    codes = merge_timeout_codes(PLAN.draw(key, K), LAT.draw(key, seq),
                                RUNTIME.deadline)
    keys = jax.random.split(key, K)
    fed_l = _make_fed(loss_fn, fault_policy=POLICY, staleness=RUNTIME,
                      pack=pack, bank_dtype=bank_dtype)
    s_l = fed_l.init_state(params)
    for k in range(K):
        b = jax.tree_util.tree_map(lambda a: a[k], batches)
        s_l, _ = fed_l.step(s_l, b, int(seq[k]), keys[k],
                            fault_code=int(codes[k]))

    # grouped driver (round-robin -> real multi-member groups)
    fed_g = _make_fed(loss_fn, fault_policy=POLICY, staleness=RUNTIME,
                      pack=pack, bank_dtype=bank_dtype)
    s_g = fed_g.init_state(params)
    s_g, m_g = fed_g.run_rounds(s_g, batches, seq, key, faults=PLAN,
                                latency=LAT, owner_parallel=True,
                                max_group=N_OWNERS)

    for other in (s_l, s_g):
        assert _leaves_equal(s_f.theta_L, other.theta_L)
        assert _leaves_equal(s_f.bank, other.bank)
        assert _leaves_equal(s_f.faults, other.faults)
        assert _leaves_equal(s_f.stale, other.stale)
        assert int(s_f.step) == int(other.step)
    assert led_f == fed_l.ledger()
    assert led_f == fed_g.reconcile(s_g)
    for name in ("timed_out", "retried", "faulted", "dropped",
                 "quarantined", "refused"):
        assert bool((np.asarray(m_f[name]) == np.asarray(m_g[name])).all())


def test_drivers_bit_identical_under_runtime_tree_mechanism(toy):
    loss_fn, params, batches = toy
    key = jax.random.PRNGKey(9)
    seq = _round_robin()

    fed_f = _make_fed(loss_fn, fault_policy=POLICY, staleness=RUNTIME,
                      mechanism="tree", tree_depth=4)
    s_f = fed_f.init_state(params)
    s_f, _ = fed_f.run_rounds(s_f, batches, seq, key, faults=PLAN,
                              latency=LAT)

    codes = merge_timeout_codes(PLAN.draw(key, K), LAT.draw(key, seq),
                                RUNTIME.deadline)
    keys = jax.random.split(key, K)
    fed_l = _make_fed(loss_fn, fault_policy=POLICY, staleness=RUNTIME,
                      mechanism="tree", tree_depth=4)
    s_l = fed_l.init_state(params)
    for k in range(K):
        b = jax.tree_util.tree_map(lambda a: a[k], batches)
        s_l, _ = fed_l.step(s_l, b, int(seq[k]), keys[k],
                            fault_code=int(codes[k]))

    assert _leaves_equal(s_f.theta_L, s_l.theta_L)
    assert _leaves_equal(s_f.tree.nodes, s_l.tree.nodes)
    assert bool((np.asarray(s_f.tree.counts)
                 == np.asarray(s_l.tree.counts)).all())
    assert _leaves_equal(s_f.stale, s_l.stale)
    assert fed_f.reconcile(s_f) == fed_l.ledger()


# ----------------------- epsilon at response time ---------------------------

def _row0(state):
    bank = state.bank
    return np.asarray(bank.codes[0] if hasattr(bank, "codes") else bank[0])


def test_epsilon_spent_iff_response_produced(toy):
    loss_fn, params, batches = toy
    spol = StalenessPolicy(deadline=1.0, max_retries=2)
    fed = _make_fed(loss_fn, staleness=spol, pack=True, bank_dtype="int8")
    s = fed.init_state(params)
    key = jax.random.PRNGKey(13)
    b0 = jax.tree_util.tree_map(lambda a: a[0], batches)
    row0 = _row0(s)

    # answered late: epsilon SPENT, update masked, cooldown scheduled
    s, m = fed.step(s, b0, 0, key, fault_code=TIMEOUT)
    assert m["timed_out"] and not m["retried"]
    led = fed.ledger()
    assert led[0]["responses"] == 1 and led[0]["timed_out"] == 1
    assert bool((_row0(s) == row0).all())
    assert int(s.stale.cooldown[0]) == 1

    # in backoff: masked re-dispatch, NO epsilon, cooldown burns
    s, m = fed.step(s, b0, 0, jax.random.PRNGKey(14), fault_code=OK)
    assert m["retried"] and not m["timed_out"]
    led = fed.ledger()
    assert led[0]["responses"] == 1 and led[0]["retried"] == 1
    assert bool((_row0(s) == row0).all())
    assert int(s.stale.cooldown[0]) == 0

    # never answered: DROP spends nothing
    s, m = fed.step(s, b0, 0, jax.random.PRNGKey(15), fault_code=DROP)
    assert m["dropped"]
    assert fed.ledger()[0]["responses"] == 1
    assert bool((_row0(s) == row0).all())

    # answered on time: spends and applies (grant resets the age)
    s, m = fed.step(s, b0, 0, jax.random.PRNGKey(16), fault_code=OK)
    assert not (m["timed_out"] or m["retried"] or m["dropped"])
    led = fed.ledger()
    assert led[0]["responses"] == 2
    assert not bool((_row0(s) == row0).all())
    assert int(s.stale.last_grant[0]) == int(s.stale.clock) - 1


def test_retry_backoff_schedule_and_budget(toy):
    loss_fn, params, batches = toy
    spol = StalenessPolicy(deadline=1.0, max_retries=2, backoff_cap=3)
    fed = _make_fed(loss_fn, staleness=spol, horizon=64)
    s = fed.init_state(params)
    b0 = jax.tree_util.tree_map(lambda a: a[0], batches)

    def run(code):
        nonlocal s
        s, m = fed.step(s, b0, 0, jax.random.PRNGKey(int(s.stale.clock)),
                        fault_code=code)
        return m

    # 1st timeout: cooldown 2**0 = 1, one retry spent
    assert run(TIMEOUT)["timed_out"]
    assert (int(s.stale.cooldown[0]), int(s.stale.backoff[0]),
            int(s.stale.retry_left[0])) == (1, 1, 1)
    assert run(OK)["retried"]                 # burns the cooldown round
    # 2nd timeout: cooldown 2**1 = 2, budget exhausted
    assert run(TIMEOUT)["timed_out"]
    assert (int(s.stale.cooldown[0]), int(s.stale.retry_left[0])) == (2, 0)
    assert run(OK)["retried"] and run(OK)["retried"]
    # 3rd timeout: no budget left -> NO new cooldown (keeps being served)
    assert run(TIMEOUT)["timed_out"]
    assert int(s.stale.cooldown[0]) == 0
    # a granted round resets the exponent and refills the budget
    m = run(OK)
    assert not (m["timed_out"] or m["retried"])
    assert (int(s.stale.backoff[0]), int(s.stale.retry_left[0])) == (0, 2)


def test_timeouts_do_not_quarantine(toy):
    # slowness has its own escalation path (backoff); only payload
    # faults tick the quarantine window
    loss_fn, params, batches = toy
    spol = StalenessPolicy(deadline=1.0, max_retries=0)
    fed = _make_fed(loss_fn, fault_policy=POLICY, staleness=spol,
                    horizon=64)
    s = fed.init_state(params)
    b0 = jax.tree_util.tree_map(lambda a: a[0], batches)
    for r in range(6):          # far past POLICY.max_faults=2
        s, m = fed.step(s, b0, 0, jax.random.PRNGKey(r),
                        fault_code=TIMEOUT)
        assert m["timed_out"] and not m["quarantined"]
    assert not bool(s.faults.quarantined[0])
    assert fed.ledger()[0]["quarantined"] == 0
    assert fed.ledger()[0]["timed_out"] == 6


def test_lateness_dominates_payload_guards(toy):
    # a late corrupt payload is discarded unread: timed_out, not faulted
    # — and therefore never ticks the quarantine window either
    loss_fn, params, batches = toy
    from repro.federation import CORRUPT_PAYLOAD  # noqa: F401
    spol = StalenessPolicy(deadline=1.0, max_retries=0)
    fed_f = _make_fed(loss_fn, fault_policy=POLICY, staleness=spol)
    s = fed_f.init_state(params)
    key = jax.random.PRNGKey(21)
    # corrupt every round, owner 1 also always late
    codes = jnp.full((K,), 4, jnp.int8)        # CORRUPT_PAYLOAD
    lat = LatencyPlan(base=(0.0, 9.0, 0.0))
    seq = _round_robin()
    s, m = fed_f.run_rounds(s, batches, seq, key, faults=codes, latency=lat)
    led = fed_f.reconcile(s)
    assert led[1]["timed_out"] == 4 and led[1]["faulted"] == 0
    assert led[0]["faulted"] > 0               # on-time corruption faults
    assert not bool(s.faults.quarantined[1])


# ------------------------- ledger folding ----------------------------------

def test_reconcile_folds_runtime_columns_exactly(toy):
    loss_fn, params, batches = toy
    fed = _make_fed(loss_fn, fault_policy=POLICY, staleness=RUNTIME,
                    pack=True, bank_dtype="fp8")
    s = fed.init_state(params)
    s, m = fed.run_rounds(s, batches, _round_robin(),
                          jax.random.PRNGKey(5), faults=PLAN, latency=LAT)
    led = fed.reconcile(s)
    timed = np.zeros(N_OWNERS, int)
    retried = np.zeros(N_OWNERS, int)
    np.add.at(timed, np.asarray(m["owner"]), np.asarray(m["timed_out"]))
    np.add.at(retried, np.asarray(m["owner"]), np.asarray(m["retried"]))
    for i in range(N_OWNERS):
        assert led[i]["timed_out"] == int(timed[i])
        assert led[i]["retried"] == int(retried[i])
    # idempotent: a second fold of the same device ledger is a no-op
    assert fed.reconcile(s) == led
    # a runtime column moving backwards against the fold baseline is
    # drift, loudly (forward deltas are legitimate new rounds)
    assert timed.sum() > 0
    j = int(np.argmax(timed))
    bad = s._replace(ledger=s.ledger.replace(
        timed_out=s.ledger.timed_out.at[j].add(-1)))
    with pytest.raises(LedgerDriftError):
        fed.reconcile(bad)
    # validate-then-apply: the failed fold left the accountant untouched
    assert fed.ledger() == led


# --------------------------- decayed inertia --------------------------------

def test_decay_changes_trajectory_only_when_ages_positive(toy):
    loss_fn, params, batches = toy
    key = jax.random.PRNGKey(17)
    seq = _round_robin()

    def run(decay):
        fed = _make_fed(loss_fn, staleness=StalenessPolicy(decay=decay),
                        pack=True)
        s = fed.init_state(params)
        s, _ = fed.run_rounds(s, batches, seq, key)
        return np.asarray(s.theta_L.buf)

    # round-robin with no faults: every owner's age is still positive at
    # dispatch (rounds since ITS last grant), so decay<1 must move theta
    assert not np.array_equal(run(1.0), run(0.5))
    # decay on a fresh federation's very first rounds equals... nothing
    # else: two different decays also differ
    assert not np.array_equal(run(0.5), run(0.9))


def test_decayed_run_keeps_masked_rows_untouched(toy):
    # decay rescales the inertia TARGET, never the stored owner copy: a
    # timed-out round under decay leaves the bank row bit-identical
    loss_fn, params, batches = toy
    spol = StalenessPolicy(deadline=1.0, max_retries=0, decay=0.8)
    fed = _make_fed(loss_fn, staleness=spol, pack=True, bank_dtype="int8")
    s = fed.init_state(params)
    b0 = jax.tree_util.tree_map(lambda a: a[0], batches)
    row0 = np.asarray(s.bank.codes[0] if hasattr(s.bank, "codes")
                      else s.bank[0])
    s, m = fed.step(s, b0, 0, jax.random.PRNGKey(3), fault_code=TIMEOUT)
    assert m["timed_out"]
    row1 = np.asarray(s.bank.codes[0] if hasattr(s.bank, "codes")
                      else s.bank[0])
    assert bool((row0 == row1).all())


# ------------------------------ paged path ---------------------------------

@pytest.mark.parametrize("bank_dtype", [None, "int8"])
def test_paged_engine_matches_flat_under_runtime(toy, bank_dtype):
    loss_fn, params, batches = toy
    key = jax.random.PRNGKey(5)
    seq = _round_robin()

    fed_a = _make_fed(loss_fn, fault_policy=POLICY, staleness=RUNTIME,
                      pack=True, bank_dtype=bank_dtype)
    s_a = fed_a.init_state(params)
    s_a, _ = fed_a.run_rounds(s_a, batches, seq, key, faults=PLAN,
                              latency=LAT)

    fed_b = _make_fed(loss_fn, fault_policy=POLICY, staleness=RUNTIME,
                      pack=True, bank_dtype=bank_dtype)
    s_b = fed_b.init_paged_state(params, n_hot=N_OWNERS,
                                 bank_dtype=bank_dtype)
    s_b, _ = fed_b.run_rounds(s_b, batches, seq, key, faults=PLAN,
                              latency=LAT)

    assert _leaves_equal(s_a.theta_L, s_b.theta_L)
    assert _leaves_equal(s_a.stale, s_b.stale)
    assert fed_a.reconcile(s_a) == fed_b.reconcile(s_b)
    # every row resident (n_hot == N): hot tier rows == flat bank rows
    hot = s_b.bank.hot
    flat_bank = s_a.bank
    if hasattr(hot, "codes"):
        order = np.argsort(np.asarray(s_b.bank.hot_ids))
        assert bool((np.asarray(hot.codes)[order]
                     == np.asarray(flat_bank.codes)).all())
    else:
        order = np.argsort(np.asarray(s_b.bank.hot_ids))
        assert bool((np.asarray(hot)[order] == np.asarray(flat_bank)).all())


# --------------------------- unit contracts --------------------------------

def test_merge_timeout_codes_contract():
    codes = jnp.asarray([OK, DROP, OK, 4], jnp.int8)
    lat = jnp.asarray([0.5, 9.0, 2.0, 2.0], jnp.float32)
    out = np.asarray(merge_timeout_codes(codes, lat, 1.0))
    # on-time OK stays; DROP never upgrades (no answer to be late); late
    # OK and late CORRUPT both become TIMEOUT
    assert list(out) == [OK, DROP, TIMEOUT, TIMEOUT]
    # per-tick times tighten the deadline to the next arrival gap
    times = jnp.asarray([0.0, 0.1, 0.2, 10.0], jnp.float32)
    out = np.asarray(merge_timeout_codes(
        jnp.zeros((4,), jnp.int8), jnp.full((4,), 0.5, jnp.float32),
        math.inf, times=times))
    # gaps: 0.1, 0.1, 9.8, inf -> first two rounds time out at 0.5
    assert list(out) == [TIMEOUT, TIMEOUT, OK, OK]
    with pytest.raises(ValueError, match="latencies"):
        merge_timeout_codes(codes, jnp.zeros((2,)), 1.0)
    with pytest.raises(ValueError, match="tick times"):
        merge_timeout_codes(codes, lat, 1.0, times=jnp.zeros((2,)))


def test_as_tick_times_contract():
    ok = as_tick_times([0.0, 1.0, 1.0, 2.5], k=4)
    assert ok.dtype == jnp.float32 and ok.shape == (4,)
    with pytest.raises(ValueError, match="1-D"):
        as_tick_times(np.zeros((2, 2)))
    with pytest.raises(ValueError, match="4 tick times"):
        as_tick_times([0.0, 1.0, 2.0, 3.0], k=3)
    with pytest.raises(ValueError, match="finite"):
        as_tick_times([0.0, np.nan])
    with pytest.raises(ValueError, match="non-decreasing"):
        as_tick_times([1.0, 0.5])


def test_latency_plan_validation():
    with pytest.raises(ValueError, match=">= 0"):
        LatencyPlan(base=-1.0)
    with pytest.raises(ValueError, match="jitter"):
        LatencyPlan(jitter=-0.5)
    # zero-jitter draws consume no randomness: same result per owner seq
    seq = jnp.asarray([0, 1, 0], jnp.int32)
    lat = LatencyPlan(base=(1.0, 2.0)).draw(jax.random.PRNGKey(0), seq)
    assert list(np.asarray(lat)) == [1.0, 2.0, 1.0]


def test_staleness_policy_validation():
    with pytest.raises(ValueError, match="deadline"):
        StalenessPolicy(deadline=0.0)
    with pytest.raises(ValueError, match="max_retries"):
        StalenessPolicy(max_retries=-1)
    with pytest.raises(ValueError, match="backoff_cap"):
        StalenessPolicy(backoff_cap=31)
    with pytest.raises(ValueError, match="decay"):
        StalenessPolicy(decay=0.0)


# ---------------------------- arming contract ------------------------------

def test_staleness_auto_arms_never_quarantine_fault_layer(toy):
    loss_fn, params, batches = toy
    fed = _make_fed(loss_fn, staleness=StalenessPolicy())
    assert fed.fault_policy is not None
    s = fed.init_state(params)
    assert s.faults is not None and s.stale is not None
    # and an explicit fault policy is kept as given
    fed2 = _make_fed(loss_fn, fault_policy=POLICY, staleness=RUNTIME)
    assert fed2.fault_policy is POLICY


def test_latency_requires_staleness_armed(toy):
    loss_fn, params, batches = toy
    fed = _make_fed(loss_fn, fault_policy=POLICY)
    s = fed.init_state(params)
    with pytest.raises(ValueError, match="staleness-armed"):
        fed.run_rounds(s, batches, _round_robin(), jax.random.PRNGKey(0),
                       latency=LatencyPlan(base=1.0))


def test_config_staleness_without_fault_layer_raises(toy):
    from repro.federation.deep import init_state
    loss_fn, params, _ = toy
    fed = _make_fed(loss_fn, fault_policy=POLICY, staleness=RUNTIME)
    cfg = fed.as_async_config()
    with pytest.raises(ValueError, match="fault"):
        init_state(params, cfg.replace(fault_policy=None)
                   if hasattr(cfg, "replace")
                   else cfg.__class__(**{**cfg.__dict__,
                                         "fault_policy": None}))


def test_mismatched_times_length_raises(toy):
    loss_fn, params, batches = toy
    fed = _make_fed(loss_fn, fault_policy=POLICY, staleness=RUNTIME)
    s = fed.init_state(params)
    with pytest.raises(ValueError, match="tick times"):
        fed.run_rounds(s, batches, _round_robin(), jax.random.PRNGKey(0),
                       latency=LatencyPlan(base=1.0),
                       times=np.linspace(0.0, 1.0, K - 1))


# ----------------- schedule times feed the deadline model -------------------

def test_schedule_drawn_times_tighten_deadlines(toy):
    # a Poisson schedule exposes arrival instants; with latency armed and
    # no owner_seq, run_rounds draws them alongside the owner sequence
    # and rounds time out against the next-arrival gap
    loss_fn, params, batches = toy
    spol = StalenessPolicy(deadline=math.inf, max_retries=0)
    fed = _make_fed(loss_fn, staleness=spol, horizon=64)
    fed.schedule = PoissonSchedule(rate=1.0)
    s = fed.init_state(params)
    # base latency 0.7 vs unit-rate arrivals: some gaps are shorter, so
    # SOME rounds time out even under an infinite policy deadline
    s, m = fed.run_rounds(s, batches, None, jax.random.PRNGKey(23),
                          latency=LatencyPlan(base=0.7))
    led = fed.reconcile(s)
    total_timed = sum(v["timed_out"] for v in led.values())
    assert 0 < total_timed < K
