"""Quantized resident owner bank (ISSUE 5): the bank_codec kernel family
(int8 / fp8 + stochastic rounding + error feedback), the QuantBank state
container, and the round engine running on it.

Contracts under test:

  * codec: kernel blocks match the jnp oracle bit-for-bit given the same
    bits; stochastic rounding is unbiased; the returned error IS
    x - decode(encode(x)); values already on the grid round-trip exactly.
  * QuantBank: ~4x resident-byte cut vs the f32 bank at 32 owners;
    decode stays within one quantization step of the f32 copies.
  * engine: a REFUSED round is a bit-exact no-op on codes, scales AND
    residual for every quantized codec; step-loop vs fused-driver
    trajectories agree to float tolerance (the f32 bit-parity contract
    explicitly does NOT extend to quantized banks — same standing as
    bf16); grouped owner-parallel execution spends the ledger exactly
    like the sequential scan.
  * error feedback: the int8+EF trajectory stays within a small fraction
    of the f32 trajectory's displacement — quantization error must stay
    well under the DP-noise floor that the Theorem 2 cost-of-privacy
    forecast (tests/test_theorem2_scaling.py) is fitted to, so storage
    precision cannot perturb the paper's headline scaling.
  * `unroll=` on the fused scan changes wall-clock only: any unroll
    factor reproduces unroll=1 bit-for-bit.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.federation import (BankCodec, DataOwner, Federation,
                              FederationConfig, PrivatizerConfig, QuantBank,
                              as_bank_codec, auto_max_group)
from repro.kernels.bank_codec.kernel import (LANES, absmax_2d, decode_2d,
                                             encode_2d)
from repro.kernels.bank_codec.ops import decode_row, encode_row
from repro.kernels.bank_codec.ref import (DECODERS, ENCODERS, QMAX,
                                          det_bits, row_scales_ref,
                                          u01_from_bits)

N_OWNERS, K = 8, 24
FMTS = ("int8", "fp8")


# ------------------------------ codec units --------------------------------
@pytest.mark.parametrize("fmt", FMTS)
def test_encode_decode_blocks_match_ref(fmt, rng_key):
    x = jax.random.normal(rng_key, (64, LANES), jnp.float32) * 2.5
    bits = jax.random.bits(rng_key, x.shape, jnp.uint32)
    scale = row_scales_ref(x.reshape(1, -1), QMAX[fmt])
    codes_k, err_k = encode_2d(x, bits, scale.reshape(1, 1), fmt,
                               block_rows=32, interpret=True)
    codes_r, err_r = ENCODERS[fmt](x, bits, scale)
    np.testing.assert_array_equal(
        np.asarray(codes_k, np.float32), np.asarray(codes_r, np.float32))
    # 1-ulp slack: the jitted kernel may contract x - q*scale into an FMA
    np.testing.assert_allclose(np.asarray(err_k), np.asarray(err_r),
                               rtol=0, atol=1e-6)
    out_k = decode_2d(codes_k, scale.reshape(1, 1), fmt, block_rows=32,
                      interpret=True)
    np.testing.assert_allclose(
        np.asarray(out_k), np.asarray(DECODERS[fmt](codes_r, scale)),
        rtol=0, atol=1e-6)
    am = absmax_2d(x, block_rows=32, interpret=True)
    assert float(am) == float(jnp.max(jnp.abs(x)))


@pytest.mark.parametrize("fmt", FMTS)
@pytest.mark.parametrize("interp", ["oracle", True])
def test_row_roundtrip_error_bound_and_ef_identity(fmt, interp, rng_key):
    x = jax.random.normal(rng_key, (1000,)) * 3.0
    codes, scales, err = encode_row(x, rng_key, fmt, interpret=interp)
    xh = decode_row(codes, scales, fmt, interpret=interp)
    # the EF residual IS the decode error, exactly as computed in f32
    np.testing.assert_allclose(np.asarray(x - xh), np.asarray(err),
                               rtol=0, atol=1e-6)
    amax = float(jnp.max(jnp.abs(x)))
    # int8: one linear step; fp8: one ulp at the top binade (2^-3 rel)
    bound = (amax / 127.0 if fmt == "int8" else amax / 4.0)
    assert float(jnp.max(jnp.abs(err))) <= bound


@pytest.mark.parametrize("fmt", FMTS)
def test_grid_values_roundtrip_exactly(fmt, rng_key):
    # a value already on the quantization grid picks its own code under
    # BOTH stochastic and deterministic rounding — this is what makes a
    # refused row's gather -> (no re-encode) semantics consistent with
    # "the stored copy is exact"
    x = jax.random.normal(rng_key, (512,)) * 1.7
    codes, scales, _ = encode_row(x, rng_key, fmt, deterministic=True,
                                  interpret="oracle")
    on_grid = decode_row(codes, scales, fmt, interpret="oracle")
    for det, key in ((True, None), (False, jax.random.PRNGKey(5))):
        codes2, scales2, err2 = encode_row(on_grid, key, fmt,
                                           deterministic=det,
                                           interpret="oracle")
        np.testing.assert_array_equal(
            np.asarray(codes2, np.float32), np.asarray(codes, np.float32))
        assert float(jnp.max(jnp.abs(err2))) == 0.0


def test_stochastic_rounding_is_unbiased():
    # a constant row between grid points: the SR mean over many elements
    # must land on the value, not on either neighbour
    x = jnp.full((1 << 14,), 0.3) * 100.0
    codes, scales, _ = encode_row(x, jax.random.PRNGKey(7), "int8",
                                  interpret="oracle")
    mean = float(jnp.mean(decode_row(codes, scales, "int8",
                                     interpret="oracle")))
    assert abs(mean - 30.0) < 0.05
    u = u01_from_bits(det_bits((4,)))
    np.testing.assert_array_equal(np.asarray(u), 0.5)


def test_per_block_scales_tighten_mixed_magnitude_rows(rng_key):
    # a row mixing magnitudes (layer-like): per-block scales cut the
    # error on the small-magnitude half by the magnitude ratio
    small = jax.random.normal(rng_key, (512,)) * 0.01
    big = jax.random.normal(jax.random.PRNGKey(1), (512,)) * 10.0
    x = jnp.concatenate([small, big])
    _, _, err_row = encode_row(x, rng_key, "int8", interpret="oracle")
    _, scales_b, err_blk = encode_row(x, rng_key, "int8", block_elems=512,
                                      interpret="oracle")
    assert scales_b.shape == (2,)
    assert (float(jnp.max(jnp.abs(err_blk[:512])))
            < 0.1 * float(jnp.max(jnp.abs(err_row[:512]))))
    with pytest.raises(NotImplementedError, match="oracle backend only"):
        encode_row(x, rng_key, "int8", block_elems=512, interpret=True)


def test_bank_codec_validation():
    assert as_bank_codec("int8") == BankCodec("int8")
    assert as_bank_codec(BankCodec("fp8", block_elems=64)).block_elems == 64
    assert as_bank_codec(None) is None
    assert as_bank_codec("bfloat16") is None          # dense storage path
    with pytest.raises(ValueError, match="unknown bank"):
        as_bank_codec("int4")
    with pytest.raises(ValueError, match="unknown bank codec"):
        BankCodec("int16")


# --------------------------- engine integration ----------------------------
@pytest.fixture(scope="module")
def toy():
    key = jax.random.PRNGKey(0)
    params = {"w": jax.random.normal(key, (6, 3)), "b": jnp.zeros((3,))}
    batches = {"x": jax.random.normal(jax.random.PRNGKey(1), (K, 4, 6)),
               "y": jax.random.normal(jax.random.PRNGKey(2), (K, 4, 3))}
    def loss_fn(p, b):
        return jnp.mean((b["x"] @ p["w"] + p["b"] - b["y"]) ** 2)
    priv = PrivatizerConfig(xi=1.0, granularity="example")
    return params, batches, loss_fn, priv


def _make_fed(loss_fn, priv, horizon=3, **kw):
    owners = [DataOwner(n=100, epsilon=1.0, xi=1.0)
              for _ in range(N_OWNERS)]
    fed = Federation(owners, FederationConfig(horizon=horizon, sigma=1e-2,
                                              theta_max=10.0, lr_scale=5.0))
    fed.make_step(loss_fn, privatizer=priv, pack_params=True, **kw)
    return fed


@pytest.mark.parametrize("fmt", FMTS)
def test_quant_bank_state_and_byte_cut(toy, fmt):
    params, _, loss_fn, priv = toy
    fed = _make_fed(loss_fn, priv, bank_dtype=fmt)
    state = fed.init_state(params)
    bank = state.bank
    assert isinstance(bank, QuantBank)
    p = state.theta_L.size
    assert bank.codes.shape == (N_OWNERS, p)
    assert bank.codes.dtype == bank.codec.code_dtype
    assert bank.scales.shape == (N_OWNERS, 1)
    assert bank.residual.shape == (p,)
    f32_bank = _make_fed(loss_fn, priv).init_state(params).bank
    # codes at 1 byte/elem + f32 scales/residual: N*P + 4*N + 4*P resident
    # bytes vs 4*N*P — the ratio approaches 4x as N and P grow (3.56x at
    # the 32-owner MLP-scale bench config, 2.4x at this tiny toy)
    assert f32_bank.nbytes == 4 * N_OWNERS * p
    assert bank.nbytes == N_OWNERS * p + 4 * N_OWNERS + 4 * p
    assert f32_bank.nbytes / bank.nbytes == pytest.approx(
        4 * N_OWNERS * p / (N_OWNERS * p + 4 * N_OWNERS + 4 * p))
    # every initial row decodes to within half a rounding step: a linear
    # one for int8, a relative one (half an e4m3 ulp, |x|/16) for fp8
    dec = np.asarray(bank.decode_rows())
    ref = np.asarray(state.theta_L.buf)
    step = np.asarray(bank.scales).max()
    bound = (0.5 * step if fmt == "int8"
             else np.abs(ref).max() / 16.0)
    assert np.abs(dec - ref[None]).max() <= bound + 1e-7


@pytest.mark.parametrize("fmt", FMTS)
def test_refusal_rows_roundtrip_exactly_through_codec(toy, fmt):
    # owner 0 exhausts after 2 grants; the refused tail must leave codes,
    # scales AND the EF residual bit-identical, and every other owner's
    # row untouched from init
    params, batches, loss_fn, priv = toy
    fed = _make_fed(loss_fn, priv, horizon=2, bank_dtype=fmt)
    state = fed.init_state(params)
    init_codes = np.asarray(state.bank.codes, np.float32)
    def sub(a, b):
        return jax.tree_util.tree_map(lambda x: x[a:b], batches)
    state, m = fed.run_rounds(state, sub(0, 2), jnp.zeros(2, jnp.int32),
                              key=jax.random.PRNGKey(9))
    assert not np.asarray(m["refused"]).any()
    snap = (np.asarray(state.bank.codes, np.float32),
            np.asarray(state.bank.scales),
            np.asarray(state.bank.residual))
    assert np.abs(snap[2]).max() > 0            # EF residual is live
    state, m = fed.run_rounds(state, sub(2, 6), jnp.zeros(4, jnp.int32),
                              key=jax.random.PRNGKey(10))
    assert np.asarray(m["refused"]).all()
    np.testing.assert_array_equal(
        snap[0], np.asarray(state.bank.codes, np.float32))
    np.testing.assert_array_equal(snap[1], np.asarray(state.bank.scales))
    np.testing.assert_array_equal(snap[2], np.asarray(state.bank.residual))
    # owners 1.. were never scheduled: rows still the init encode
    np.testing.assert_array_equal(
        init_codes[1:], np.asarray(state.bank.codes, np.float32)[1:])
    led = fed.reconcile(state)
    assert led[0]["responses"] == 2 and led[0]["refused"] == 4


def test_step_loop_matches_fused_driver_to_tolerance(toy):
    # the f32 bit-parity contract does NOT extend to quantized banks
    # (XLA fuses the decode multiply differently in and out of the scan,
    # same standing as bf16); the refusal pattern and ledger stay exact,
    # trajectories agree to float tolerance
    params, batches, loss_fn, priv = toy
    seq = jax.random.randint(jax.random.PRNGKey(3), (K,), 0, N_OWNERS)
    root = jax.random.PRNGKey(4)
    keys = jax.random.split(root, K)
    fed_a = _make_fed(loss_fn, priv, bank_dtype="int8")
    s_a = fed_a.init_state(params)
    refused_a = []
    for k in range(K):
        b = jax.tree_util.tree_map(lambda a: a[k], batches)
        s_a, m = fed_a.step(s_a, b, int(seq[k]), keys[k])
        refused_a.append(bool(m["refused"]))
    fed_b = _make_fed(loss_fn, priv, bank_dtype="int8")
    s_b, m_b = fed_b.run_rounds(fed_b.init_state(params), batches, seq,
                                key=root)
    assert sum(refused_a) > 0
    np.testing.assert_array_equal(np.asarray(refused_a),
                                  np.asarray(m_b["refused"]))
    np.testing.assert_allclose(np.asarray(s_a.theta_L.buf),
                               np.asarray(s_b.theta_L.buf),
                               rtol=1e-5, atol=2e-6)
    step = float(np.asarray(s_a.bank.scales).max())
    assert (np.abs(np.asarray(s_a.bank.decode_rows())
                   - np.asarray(s_b.bank.decode_rows())).max()
            <= step + 1e-6)
    assert fed_b.reconcile(s_b) == fed_a.reconcile(s_a)


@pytest.mark.parametrize("fmt", FMTS)
def test_grouped_owner_parallel_on_quant_bank(toy, fmt):
    params, batches, loss_fn, priv = toy
    seq = jax.random.randint(jax.random.PRNGKey(3), (K,), 0, N_OWNERS)
    root = jax.random.PRNGKey(4)
    fed_s = _make_fed(loss_fn, priv, bank_dtype=fmt)
    fed_g = _make_fed(loss_fn, priv, bank_dtype=fmt)
    s_s, m_s = fed_s.run_rounds(fed_s.init_state(params), batches, seq,
                                key=root)
    s_g, m_g = fed_g.run_rounds(fed_g.init_state(params), batches, seq,
                                key=root, owner_parallel=True)
    np.testing.assert_array_equal(np.asarray(m_s["refused"]),
                                  np.asarray(m_g["refused"]))
    np.testing.assert_array_equal(np.asarray(m_s["owner"]),
                                  np.asarray(m_g["owner"]))
    assert fed_g.reconcile(s_g) == fed_s.reconcile(s_s)
    g = np.asarray(s_g.theta_L.buf)
    assert np.isfinite(g).all() and np.abs(g).max() <= 10.0
    assert np.max(np.abs(np.asarray(s_s.theta_L.buf) - g)) < 2.0


def test_fused_scan_unroll_is_bit_exact(toy):
    # unroll trades loop-carry copies for code size; values are identical
    # at ANY factor, on the f32 path (where the bit contract holds) and
    # the quantized path alike
    params, batches, loss_fn, priv = toy
    seq = jax.random.randint(jax.random.PRNGKey(3), (K,), 0, N_OWNERS)
    root = jax.random.PRNGKey(4)
    for bd in (None, "int8"):
        fed_1 = _make_fed(loss_fn, priv, bank_dtype=bd)
        fed_4 = _make_fed(loss_fn, priv, bank_dtype=bd, unroll=4)
        s_1, m_1 = fed_1.run_rounds(fed_1.init_state(params), batches, seq,
                                    key=root)
        s_4, m_4 = fed_4.run_rounds(fed_4.init_state(params), batches, seq,
                                    key=root)
        np.testing.assert_array_equal(np.asarray(s_1.theta_L.buf),
                                      np.asarray(s_4.theta_L.buf))
        if bd is None:
            np.testing.assert_array_equal(np.asarray(s_1.bank),
                                          np.asarray(s_4.bank))
        else:
            np.testing.assert_array_equal(np.asarray(s_1.bank.codes),
                                          np.asarray(s_4.bank.codes))
            np.testing.assert_array_equal(np.asarray(s_1.bank.residual),
                                          np.asarray(s_4.bank.residual))
        for name in m_1:
            np.testing.assert_array_equal(np.asarray(m_1[name]),
                                          np.asarray(m_4[name]))


def test_quant_state_donation_aliasing(toy):
    params, batches, loss_fn, priv = toy
    fed = _make_fed(loss_fn, priv, horizon=K, bank_dtype="int8",
                    donate=True)
    state = fed.init_state(params)
    sub = jax.tree_util.tree_map(lambda a: a[:4], batches)
    new_state, _ = fed.run_rounds(state, sub, jnp.zeros(4, jnp.int32),
                                  key=jax.random.PRNGKey(1))
    assert state.bank.codes.is_deleted()
    assert state.bank.residual.is_deleted()
    assert state.theta_L.buf.is_deleted()
    assert not new_state.bank.codes.is_deleted()
    assert np.isfinite(np.asarray(new_state.theta_L.buf)).all()


def test_fused_kernel_dp_round_on_quant_bank(toy):
    # production stack: dp_round Pallas pass + int8 bank in one scan body
    params, batches, loss_fn, _ = toy
    priv = PrivatizerConfig(xi=1e-3, granularity="microbatch",
                            n_microbatches=2, fused_kernel=True,
                            kernel_block_rows=8)
    fed = _make_fed(loss_fn, priv, horizon=2, bank_dtype="int8")
    seq = jnp.asarray(np.arange(K) % 4, jnp.int32)
    state, ms = fed.run_rounds(fed.init_state(params), batches, seq,
                               key=jax.random.PRNGKey(6))
    assert np.isfinite(np.asarray(state.theta_L.buf)).all()
    granted = ~np.asarray(ms["refused"])
    assert granted.sum() == 8
    led = fed.reconcile(state)
    assert all(led[i]["responses"] == 2 and led[i]["refused"] == 4
               for i in range(4))


# --------------------- Theorem-2 trajectory tolerance ----------------------
def test_error_feedback_bank_stays_within_theorem2_tolerance(toy):
    # Theorem 2's cost-of-privacy forecast is a function of the DP noise
    # alone, so quantized storage may not add error of that order. The
    # distance between two f32 runs differing ONLY in their noise key IS
    # the DP-noise floor; the int8/fp8 runs share the f32 root run's
    # Laplace draws exactly (the codec RNG stream is salted away from the
    # privacy stream), so their deviation is pure quantization error —
    # with stochastic rounding + error feedback it must stay well under
    # one noise redraw AND a small fraction of the learning signal.
    params, batches, loss_fn, priv = toy
    seq = jax.random.randint(jax.random.PRNGKey(3), (K,), 0, N_OWNERS)
    root = jax.random.PRNGKey(4)
    runs = {}
    for name, bd, key in (("f32", None, root),
                          ("f32_alt", None, jax.random.fold_in(root, 1)),
                          ("int8", "int8", root), ("fp8", "fp8", root)):
        fed = _make_fed(loss_fn, priv, horizon=K, bank_dtype=bd)
        s, m = fed.run_rounds(fed.init_state(params), batches, seq,
                              key=key)
        assert not np.asarray(m["refused"]).any()
        runs[name] = np.asarray(s.theta_L.buf)
    theta0 = np.asarray(_make_fed(loss_fn, priv).init_state(
        params).theta_L.buf)
    displacement = np.linalg.norm(runs["f32"] - theta0)
    noise_floor = np.linalg.norm(runs["f32_alt"] - runs["f32"])
    assert displacement > 0 and noise_floor > 0
    for name, tol in (("int8", 0.05), ("fp8", 0.15)):
        dev = np.linalg.norm(runs[name] - runs["f32"])
        assert dev < tol * displacement, (name, dev, displacement)
        assert dev < 0.5 * noise_floor, (name, dev, noise_floor)


def test_auto_max_group_tracks_schedule_statistics():
    # single-owner schedule: grouping cannot win -> sequential
    assert auto_max_group(np.zeros(32, np.int64)) == 1
    # all-distinct schedule: big groups amortize the per-step bank copy
    assert auto_max_group(np.arange(32)) >= 8
    # the chosen cap never exceeds the longest conflict-free run
    seq = np.asarray([0, 1, 2, 0, 1, 2, 0, 1, 2])
    assert auto_max_group(seq) <= 3
    assert auto_max_group(np.zeros(0, np.int64)) == 1
