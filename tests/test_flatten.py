"""Flat-buffer round engine: ParamFlat pack/unpack exactness, flat-vs-tree
bit parity for both deep drivers, and donation aliasing on the flat state.

The contract under test (ISSUE 3): `init_state_flat` / `pack_params=True`
states run the paper's inertia round on ONE contiguous (P,) f32 buffer with
an (N, P) owner bank, and with `fused_kernel=False` reproduce the pytree
path BIT-FOR-BIT under identical per-round keys — params, bank, ledger,
and granted-round metrics.
"""
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, list_archs
from repro.federation import (DataOwner, Federation, FederationConfig,
                              ParamFlat, PrivatizerConfig, flatten_spec,
                              pack_params)
from repro.models import build_model

N_OWNERS, K = 8, 24


def _leaves(t):
    return jax.tree_util.tree_leaves(t)


def _assert_tree_equal(a, b):
    assert (jax.tree_util.tree_structure(a)
            == jax.tree_util.tree_structure(b))
    for x, y in zip(_leaves(a), _leaves(b)):
        assert x.shape == y.shape and x.dtype == y.dtype
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


# ---------------- pack/unpack round trip across model pytrees --------------
@pytest.mark.parametrize("arch", list_archs())
def test_roundtrip_every_model_architecture(arch, rng_key):
    cfg = get_config(arch).reduced()
    model = build_model(cfg, remat=False, moe_mode="onehot",
                        moe_group_tokens=16)
    params = model.init(rng_key, jnp.float32)
    flat = pack_params(params)
    assert flat.buf.dtype == jnp.float32
    assert flat.buf.shape == (flat.size,)
    assert flat.size == sum(int(np.prod(leaf.shape)) if leaf.shape else 1
                            for leaf in _leaves(params))
    _assert_tree_equal(flat.unpack(), params)


class _Block(NamedTuple):
    w: jax.Array
    gate: Optional[jax.Array]          # None leaf in the treedef
    b: jax.Array


def test_roundtrip_mixed_dtypes_and_none_leaves(rng_key):
    ks = jax.random.split(rng_key, 4)
    tree = {
        "blk": _Block(w=jax.random.normal(ks[0], (5, 7), jnp.bfloat16),
                      gate=None,
                      b=jax.random.normal(ks[1], (7,), jnp.float16)),
        "scale": jnp.float32(3.25),                      # scalar leaf
        "deep": [jax.random.normal(ks[2], (2, 3, 4)),
                 {"t": jax.random.normal(ks[3], (1,), jnp.bfloat16)}],
    }
    flat = pack_params(tree)
    assert flat.buf.dtype == jnp.float32
    out = flat.unpack()
    _assert_tree_equal(out, tree)       # f16/bf16 embed exactly in f32
    assert out["blk"].gate is None


def test_pack_rejects_lossy_dtypes():
    with pytest.raises(TypeError, match="cannot pack"):
        flatten_spec({"ids": jnp.zeros((3,), jnp.int32)})
    with pytest.raises(ValueError, match="no array leaves"):
        flatten_spec({"empty": None})


def test_spec_validates_structure_and_shapes(rng_key):
    tree = {"w": jax.random.normal(rng_key, (4, 2)), "b": jnp.zeros((2,))}
    spec = flatten_spec(tree)
    with pytest.raises(ValueError, match="shape mismatch"):
        spec.pack({"w": jnp.zeros((2, 4)), "b": jnp.zeros((2,))})
    with pytest.raises(TypeError, match="dtype mismatch"):
        spec.pack({"w": tree["w"].astype(jnp.bfloat16),
                   "b": tree["b"]})
    with pytest.raises(ValueError, match="structure mismatch"):
        spec.pack({"w": tree["w"]})
    with pytest.raises(ValueError, match="buffer shape"):
        spec.unpack(jnp.zeros((spec.size + 1,)))


def test_param_flat_is_a_pytree_with_static_spec(rng_key):
    flat = pack_params({"w": jax.random.normal(rng_key, (3, 3))})
    doubled = jax.jit(lambda f: jax.tree_util.tree_map(lambda b: 2 * b, f))(
        flat)
    assert isinstance(doubled, ParamFlat)
    assert doubled.spec == flat.spec
    np.testing.assert_array_equal(np.asarray(doubled.buf),
                                  2 * np.asarray(flat.buf))


# ---------------------- flat-vs-tree bit parity ----------------------------
@pytest.fixture(scope="module")
def toy():
    key = jax.random.PRNGKey(0)
    params = {"w": jax.random.normal(key, (6, 3)), "b": jnp.zeros((3,))}
    batches = {"x": jax.random.normal(jax.random.PRNGKey(1), (K, 4, 6)),
               "y": jax.random.normal(jax.random.PRNGKey(2), (K, 4, 3))}
    def loss_fn(p, b):
        return jnp.mean((b["x"] @ p["w"] + p["b"] - b["y"]) ** 2)
    priv = PrivatizerConfig(xi=1.0, granularity="example")
    return params, batches, loss_fn, priv


def _make_fed(loss_fn, priv, pack, horizon=3, donate=False, bank_dtype=None,
              **kw):
    owners = [DataOwner(n=100, epsilon=1.0, xi=1.0)
              for _ in range(N_OWNERS)]
    fed = Federation(owners, FederationConfig(horizon=horizon, sigma=1e-2,
                                              theta_max=10.0, lr_scale=5.0),
                     **kw)
    fed.make_step(loss_fn, privatizer=priv, pack_params=pack, donate=donate,
                  bank_dtype=bank_dtype)
    return fed


def _assert_states_match(s_tree, s_flat):
    spec = s_flat.theta_L.spec
    np.testing.assert_array_equal(
        np.asarray(spec.pack(s_tree.theta_L)), np.asarray(s_flat.theta_L.buf))
    for i in range(N_OWNERS):
        row = jax.tree_util.tree_map(lambda leaf: leaf[i], s_tree.bank)
        np.testing.assert_array_equal(np.asarray(spec.pack(row)),
                                      np.asarray(s_flat.bank[i]))
    for f in ("spent", "cap", "refused"):
        np.testing.assert_array_equal(np.asarray(getattr(s_tree.ledger, f)),
                                      np.asarray(getattr(s_flat.ledger, f)))


def test_step_loop_bit_parity_with_exhaustion(toy):
    # horizon=3 over 8 owners with K=24 draws: refusals interleave with
    # granted rounds, so parity covers the masking path too.
    params, batches, loss_fn, priv = toy
    seq = jax.random.randint(jax.random.PRNGKey(3), (K,), 0, N_OWNERS)
    keys = jax.random.split(jax.random.PRNGKey(4), K)

    fed_t = _make_fed(loss_fn, priv, pack=False)
    fed_f = _make_fed(loss_fn, priv, pack=True)
    s_t, s_f = fed_t.init_state(params), fed_f.init_state(params)
    assert isinstance(s_f.theta_L, ParamFlat)
    assert s_f.bank.shape == (N_OWNERS, s_f.theta_L.size)
    for k in range(K):
        b = jax.tree_util.tree_map(lambda a: a[k], batches)
        s_t, m_t = fed_t.step(s_t, b, int(seq[k]), keys[k])
        s_f, m_f = fed_f.step(s_f, b, int(seq[k]), keys[k])
        assert m_t["refused"] == m_f["refused"]
        if not m_t["refused"]:
            assert float(m_t["clip_frac"]) == float(m_f["clip_frac"])
            assert float(m_t["max_grad_norm"]) == float(m_f["max_grad_norm"])
    _assert_states_match(s_t, s_f)
    _assert_tree_equal(fed_f.params_of(s_f), s_t.theta_L)
    assert fed_f.ledger() == fed_t.ledger()


def test_run_rounds_bit_parity(toy):
    params, batches, loss_fn, priv = toy
    seq = jax.random.randint(jax.random.PRNGKey(3), (K,), 0, N_OWNERS)
    root = jax.random.PRNGKey(4)

    fed_t = _make_fed(loss_fn, priv, pack=False)
    fed_f = _make_fed(loss_fn, priv, pack=True)
    s_t, m_t = fed_t.run_rounds(fed_t.init_state(params), batches, seq,
                                key=root)
    s_f, m_f = fed_f.run_rounds(fed_f.init_state(params), batches, seq,
                                key=root)
    assert int(np.asarray(m_t["refused"]).sum()) > 0
    for name in m_t:
        np.testing.assert_array_equal(np.asarray(m_t[name]),
                                      np.asarray(m_f[name]))
    _assert_states_match(s_t, s_f)
    assert fed_f.reconcile(s_f) == fed_t.reconcile(s_t)


def test_flat_step_loop_matches_flat_fused_driver(toy):
    # the PR 2 contract, restated on the flat engine: one scan dispatch ==
    # the per-round loop bit-for-bit under the same per-round keys
    params, batches, loss_fn, priv = toy
    seq = jax.random.randint(jax.random.PRNGKey(3), (K,), 0, N_OWNERS)
    root = jax.random.PRNGKey(4)
    keys = jax.random.split(root, K)

    fed_a = _make_fed(loss_fn, priv, pack=True)
    s_a = fed_a.init_state(params)
    for k in range(K):
        b = jax.tree_util.tree_map(lambda a: a[k], batches)
        s_a, _ = fed_a.step(s_a, b, int(seq[k]), keys[k])

    fed_b = _make_fed(loss_fn, priv, pack=True)
    s_b, _ = fed_b.run_rounds(fed_b.init_state(params), batches, seq,
                              key=root)
    np.testing.assert_array_equal(np.asarray(s_a.theta_L.buf),
                                  np.asarray(s_b.theta_L.buf))
    np.testing.assert_array_equal(np.asarray(s_a.bank), np.asarray(s_b.bank))


def test_fused_kernel_flat_round_in_scan_body(toy):
    # dp_round Pallas path (interpret on CPU) inside the fused driver:
    # finite updates, real refusal masking, binding clip.
    params, batches, loss_fn, _ = toy
    priv = PrivatizerConfig(xi=1e-3, granularity="microbatch",
                            n_microbatches=2, fused_kernel=True,
                            kernel_block_rows=8)
    fed = _make_fed(loss_fn, priv, pack=True, horizon=2)
    state = fed.init_state(params)
    seq = jnp.asarray(np.arange(K) % 4, jnp.int32)      # owners 0-3, 6 each
    state, ms = fed.run_rounds(state, batches, seq, key=jax.random.PRNGKey(6))
    assert np.isfinite(np.asarray(state.theta_L.buf)).all()
    granted = ~np.asarray(ms["refused"])
    assert granted.sum() == 8                           # 2 per owner cap
    assert np.asarray(ms["clip_frac"])[granted].min() == 1.0
    led = fed.reconcile(state)
    assert all(led[i]["responses"] == 2 and led[i]["refused"] == 4
               for i in range(4))


def test_flat_state_donation_aliasing(toy):
    # donate=True must actually release the flat buffers: the K+1'th step
    # reuses the K'th state's memory instead of doubling the footprint.
    params, batches, loss_fn, priv = toy
    fed = _make_fed(loss_fn, priv, pack=True, horizon=K, donate=True)
    state = fed.init_state(params)
    b0 = jax.tree_util.tree_map(lambda a: a[0], batches)
    new_state, _ = fed.step(state, b0, 0, jax.random.PRNGKey(0))
    assert state.theta_L.buf.is_deleted()
    assert state.bank.is_deleted()
    assert not new_state.theta_L.buf.is_deleted()
    # the donated state keeps working across the fused driver too
    sub = jax.tree_util.tree_map(lambda a: a[:4], batches)
    final, _ = fed.run_rounds(new_state, sub, jnp.zeros(4, jnp.int32),
                              key=jax.random.PRNGKey(1))
    assert new_state.theta_L.buf.is_deleted()
    assert np.isfinite(np.asarray(final.theta_L.buf)).all()


def test_bf16_bank_halves_storage_and_roundtrips_refusals(toy):
    # bank_dtype=bf16: half the resident bank bytes; a REFUSED round's
    # row survives the f32 gather -> bf16 scatter round trip bit-exactly,
    # and granted rounds keep training (finite, quantized copies).
    params, batches, loss_fn, priv = toy
    fed32 = _make_fed(loss_fn, priv, pack=True, horizon=2)
    fed16 = _make_fed(loss_fn, priv, pack=True, horizon=2,
                      bank_dtype=jnp.bfloat16)
    s32, s16 = fed32.init_state(params), fed16.init_state(params)
    assert s16.bank.dtype == jnp.bfloat16
    assert s16.bank.nbytes * 2 == s32.bank.nbytes
    bank0 = np.asarray(s16.bank)

    seq = jnp.asarray([0] * 6, jnp.int32)       # owner 0: 2 granted, 4 refused
    sub = jax.tree_util.tree_map(lambda a: a[:6], batches)
    s16, ms = fed16.run_rounds(s16, sub, seq, key=jax.random.PRNGKey(1))
    assert np.asarray(ms["refused"]).sum() == 4
    np.testing.assert_array_equal(np.asarray(s16.bank)[1:], bank0[1:])
    assert np.isfinite(np.asarray(s16.theta_L.buf)).all()
    assert fed16.reconcile(s16)[0] == {"epsilon": 1.0, "responses": 2,
                                       "spent": 1.0, "exhausted": True,
                                       "refused": 4, "dropped": 0,
                                       "faulted": 0, "quarantined": 0,
                                       "timed_out": 0, "retried": 0}


def test_bank_dtype_requires_flat_engine(toy):
    params, _, loss_fn, priv = toy
    fed = _make_fed(loss_fn, priv, pack=False)
    with pytest.raises(ValueError, match="flat-engine option"):
        fed.init_state(params, bank_dtype=jnp.bfloat16)


def test_init_state_pack_params_override(toy):
    params, _, loss_fn, priv = toy
    fed = _make_fed(loss_fn, priv, pack=False)
    flat_state = fed.init_state(params, pack_params=True)
    assert isinstance(flat_state.theta_L, ParamFlat)
    tree_state = fed.init_state(params)
    assert not isinstance(tree_state.theta_L, ParamFlat)


def test_flat_spec_equality_is_structural(toy):
    # jit caching keys on the spec: same structure -> equal (and hashable),
    # different structure -> unequal
    params, _, _, _ = toy
    spec = flatten_spec(params)
    assert spec == flatten_spec(
        jax.tree_util.tree_map(jnp.zeros_like, params))
    assert hash(spec) == hash(flatten_spec(params))
    assert spec != flatten_spec({"w": params["w"]})
