"""Per-owner heterogeneous privacy budgets: Theorem 2's bound depends on
the budgets only through S = sum_i 1/eps_i^2 — two budget profiles with
equal S should land at statistically comparable CoP, and the noisier owner
dominates S as eps_i^-2."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import Algo1Config, make_problem, run_many
from repro.core.cop import budget_sum
from repro.data import owner_shards

REG, SIGMA, T = 1e-5, 2e-5, 400


@pytest.fixture(scope="module")
def problem():
    shards = owner_shards("lending", [20_000] * 4, seed=5,
                          heterogeneity=0.0)
    return make_problem(shards, reg=REG, theta_max=2.0)


def _psi(problem, epsilons, runs=10, seed=0):
    prob, owners = problem
    cfg = Algo1Config(horizon=T, rho=1.0, sigma=SIGMA, epsilons=epsilons)
    tr = run_many(jax.random.PRNGKey(seed), prob, owners, cfg, runs)
    return float(jnp.mean(tr.psi[:, -1]))


def test_equal_budget_sum_comparable_cop(problem):
    # uniform: 4 owners at eps=2          -> S = 4/4        = 1.0
    # skewed:  [sqrt(2), sqrt(2), 2, 2]^-2 -> 0.5+0.5+0.25+0.25 = 1.5... pick
    # profiles with EXACTLY equal S instead:
    uniform = [2.0] * 4                       # S = 1.0
    skewed = [np.sqrt(2.0), np.sqrt(2.0), 1e6, 1e6]   # S = 0.5+0.5 = 1.0
    assert budget_sum(uniform) == pytest.approx(budget_sum(skewed), rel=1e-6)
    a = _psi(problem, uniform)
    b = _psi(problem, skewed)
    # same S -> same predicted CoP; allow 2.5x statistical slack
    assert a / b < 2.5 and b / a < 2.5, (a, b)


def test_one_paranoid_owner_dominates(problem):
    # a single tight-budget owner dominates S and hence the CoP
    relaxed = [10.0] * 4
    one_tight = [10.0, 10.0, 10.0, 0.5]
    assert budget_sum(one_tight) > 100 * budget_sum(relaxed)
    a = _psi(problem, relaxed)
    b = _psi(problem, one_tight)
    assert b > 2.0 * a, (a, b)
