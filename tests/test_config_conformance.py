"""Assignment conformance: every architecture config must carry the EXACT
published dimensions from the assignment table (guards against silent config
drift) and every reduced variant must obey the smoke-test contract."""
import pytest

from repro.configs import INPUT_SHAPES, get_config, list_archs

# (layers, d_model, heads, kv, d_ff, vocab) from the assignment
ASSIGNED = {
    "zamba2-2.7b": ("hybrid", 54, 2560, 32, 32, 10240, 32000),
    "mixtral-8x22b": ("moe", 56, 6144, 48, 8, 16384, 32768),
    "internvl2-2b": ("vlm", 24, 2048, 16, 8, 8192, 92553),
    "qwen1.5-110b": ("dense", 80, 8192, 64, 8, 49152, 152064),
    "yi-6b": ("dense", 32, 4096, 32, 4, 11008, 64000),
    "whisper-medium": ("audio", 24, 1024, 16, 16, 4096, 51865),
    "xlstm-125m": ("ssm", 12, 768, 4, 4, 0, 50304),
    "granite-20b": ("dense", 52, 6144, 48, 1, 24576, 49152),
    "qwen3-moe-30b-a3b": ("moe", 48, 2048, 32, 4, 768, 151936),
    "command-r-35b": ("dense", 40, 8192, 64, 8, 22528, 256000),
}


def test_all_assigned_archs_present():
    assert sorted(list_archs()) == sorted(ASSIGNED)


@pytest.mark.parametrize("arch", sorted(ASSIGNED))
def test_exact_assigned_dims(arch):
    fam, L, d, H, Kv, ff, V = ASSIGNED[arch]
    cfg = get_config(arch)
    assert cfg.family == fam
    assert cfg.n_layers == L and cfg.d_model == d
    assert cfg.n_heads == H and cfg.n_kv_heads == Kv
    assert cfg.d_ff == ff and cfg.vocab == V
    assert cfg.source, "every config must cite its source"


def test_special_features():
    assert get_config("zamba2-2.7b").ssm.d_state == 64
    assert get_config("mixtral-8x22b").moe.n_experts == 8
    assert get_config("mixtral-8x22b").moe.top_k == 2
    assert get_config("mixtral-8x22b").sliding_window == 4096
    assert get_config("qwen3-moe-30b-a3b").moe.n_experts == 128
    assert get_config("qwen3-moe-30b-a3b").moe.top_k == 8
    assert get_config("qwen1.5-110b").qkv_bias is True
    assert get_config("command-r-35b").qkv_bias is False
    assert get_config("whisper-medium").enc_layers == 24
    assert get_config("whisper-medium").enc_seq == 1500
    assert get_config("internvl2-2b").n_patches == 256
    assert get_config("granite-20b").n_kv_heads == 1       # MQA


def test_assigned_input_shapes():
    s = INPUT_SHAPES
    assert (s["train_4k"].seq_len, s["train_4k"].global_batch) == (4096, 256)
    assert (s["prefill_32k"].seq_len, s["prefill_32k"].global_batch) == (32768, 32)
    assert (s["decode_32k"].seq_len, s["decode_32k"].global_batch) == (32768, 128)
    assert (s["long_500k"].seq_len, s["long_500k"].global_batch) == (524288, 1)
    assert s["decode_32k"].kind == "decode" and s["long_500k"].kind == "decode"


@pytest.mark.parametrize("arch", sorted(ASSIGNED))
def test_reduced_contract(arch):
    r = get_config(arch).reduced()
    assert r.n_layers <= 2
    assert r.d_model <= 512
    if r.moe:
        assert r.moe.n_experts <= 4
    assert r.family == get_config(arch).family


@pytest.mark.parametrize("arch", sorted(ASSIGNED))
def test_param_count_sanity(arch):
    """Analytic param counts land near the models' nominal sizes."""
    nominal = {"zamba2-2.7b": 2.7e9, "mixtral-8x22b": 141e9,
               "internvl2-2b": 2.0e9, "qwen1.5-110b": 111e9,
               "yi-6b": 6e9, "whisper-medium": 0.77e9,
               "xlstm-125m": 0.125e9, "granite-20b": 20e9,
               "qwen3-moe-30b-a3b": 30.5e9, "command-r-35b": 35e9}[arch]
    got = get_config(arch).param_count()
    assert 0.35 * nominal < got < 1.6 * nominal, (got, nominal)
