"""The repro.core legacy import surface: still works, but says so loudly.

PR 1 left thin shims in repro.core so downstream code kept importing; this
pins the deprecation contract added on top of them — every shim module
emits a DeprecationWarning naming the replacement, while re-exporting
objects IDENTICAL to the repro.federation canon (not copies), so behavior
cannot drift before the surface is removed in a later PR.
"""
import importlib
import sys

import pytest

SHIMS = ["repro.core.privacy", "repro.core.async_trainer",
         "repro.core.linear", "repro.core.clocks", "repro.core.dp_sgd",
         "repro.core.algorithm1"]


@pytest.mark.parametrize("module", SHIMS)
def test_core_shim_import_emits_deprecation_warning(module):
    sys.modules.pop(module, None)
    with pytest.warns(DeprecationWarning,
                      match="deprecated shim.*repro.federation"):
        importlib.import_module(module)


def test_core_package_import_is_silent_but_moved_names_warn():
    # the package surface is lazy (PEP 562): importing repro.core — or
    # using its never-moved cop names — must NOT warn; touching a MOVED
    # name imports its shim and does
    import warnings
    for mod in ["repro.core"] + SHIMS:
        sys.modules.pop(mod, None)
    with warnings.catch_warnings():
        warnings.simplefilter("error", DeprecationWarning)
        core = importlib.import_module("repro.core")
        assert core.budget_sum([1.0]) == 1.0          # cop: no warning
    with pytest.warns(DeprecationWarning,
                      match="repro.core.privacy is a deprecated shim"):
        core.PrivacyAccountant                         # noqa: B018
    # and dir() still advertises the whole legacy surface
    assert {"PrivacyAccountant", "run_algorithm1", "bound_asymptotic",
            "make_train_step"} <= set(dir(core))


def test_core_package_unknown_attribute_raises():
    import repro.core as core
    with pytest.raises(AttributeError, match="no attribute 'nope'"):
        core.nope


def test_core_submodules_reachable_as_attributes():
    # the eager surface bound submodules as a side effect
    # (`repro.core.clocks.uniform_schedule` without importing the
    # submodule); the lazy surface must keep that pattern working
    for mod in SHIMS:
        sys.modules.pop(mod, None)
    sys.modules.pop("repro.core", None)
    import repro.core as core
    with pytest.warns(DeprecationWarning):
        clocks = core.clocks
    import repro.federation as fed
    assert clocks.uniform_schedule is fed.uniform_schedule


def test_shim_objects_are_the_federation_objects():
    # identity, not equality: the shim must re-export, never reimplement
    import repro.core as core
    import repro.federation as fed
    assert core.PrivacyAccountant is fed.PrivacyAccountant
    assert core.AsyncDPConfig is fed.AsyncDPConfig
    assert core.make_train_step is fed.make_train_step
    assert core.PrivatizerConfig is fed.PrivatizerConfig
    assert core.LinearProblem is fed.LinearProblem
    assert core.run_algorithm1 is fed.run_algorithm1
    assert core.uniform_schedule is fed.uniform_schedule
