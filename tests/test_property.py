"""Property-based tests (hypothesis) for the system's invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis.roofline import roofline_terms
from repro.core.cop import bound_asymptotic, budget_sum
from repro.core.dp_sgd import clip_tree
from repro.core.linear import make_problem, relative_fitness
from repro.core.privacy import capped_rounds, laplace_scale_theorem1
from repro.data import owner_shards

pytest.importorskip("hypothesis", reason="pip install -r requirements-dev.txt")
from hypothesis import given, settings, strategies as st  # noqa: E402

SET = dict(max_examples=25, deadline=None, derandomize=True)


@given(st.lists(st.floats(0.05, 100.0), min_size=1, max_size=8),
       st.integers(1_000, 10_000_000))
@settings(**SET)
def test_cop_bound_monotone_in_n(epsilons, n):
    b1 = bound_asymptotic(n, epsilons, 1.0, 1.0)
    b2 = bound_asymptotic(2 * n, epsilons, 1.0, 1.0)
    assert b2 < b1
    assert b1 >= 0.0


@given(st.floats(0.05, 50.0), st.floats(1.1, 4.0),
       st.integers(100, 100_000), st.integers(1, 10_000))
@settings(**SET)
def test_theorem1_scale_scaling_laws(eps, mult, horizon, n):
    b = laplace_scale_theorem1(1.0, horizon, n, eps)
    assert laplace_scale_theorem1(1.0, horizon, n, eps * mult) < b
    assert laplace_scale_theorem1(mult, horizon, n, eps) > b
    # exact inverse proportionality
    np.testing.assert_allclose(
        laplace_scale_theorem1(1.0, horizon, n, eps * mult) * mult, b,
        rtol=1e-9)


@given(st.integers(1, 100_000), st.integers(1, 512))
@settings(**SET)
def test_capped_rounds_bounds(T, N):
    c = capped_rounds(T, N)
    assert 1 <= c
    assert c >= T / N            # never less than the expected load


@given(st.lists(st.floats(-10.0, 10.0), min_size=1, max_size=64),
       st.floats(0.01, 10.0))
@settings(**SET)
def test_clip_tree_invariant(values, xi):
    tree = {"x": jnp.asarray(values, jnp.float32)}
    clipped, _ = clip_tree(tree, xi)
    norm = float(jnp.linalg.norm(clipped["x"]))
    assert norm <= xi * (1 + 1e-4)
    # direction preserved
    orig = jnp.asarray(values, jnp.float32)
    if float(jnp.linalg.norm(orig)) > 1e-6:
        cos = float(jnp.dot(clipped["x"], orig)
                    / (jnp.linalg.norm(clipped["x"]) * jnp.linalg.norm(orig)
                       + 1e-12))
        assert cos > 0.999


@given(st.integers(0, 2 ** 31 - 1))
@settings(max_examples=10, deadline=None, derandomize=True)
def test_relative_fitness_nonnegative(seed):
    shards = owner_shards("lending", [500, 500], seed=seed % 97)
    prob, _ = make_problem(shards, reg=1e-5, theta_max=3.0)
    key = jax.random.PRNGKey(seed)
    theta = jax.random.uniform(key, prob.theta_star.shape, minval=-3.0,
                               maxval=3.0)
    assert float(relative_fitness(prob, theta)) >= -1e-6


@given(st.floats(1e6, 1e18), st.floats(1e6, 1e15), st.floats(0, 1e15))
@settings(**SET)
def test_roofline_terms_consistency(flops, byts, coll):
    t = roofline_terms(flops, byts, coll)
    assert t["step_lower_bound_s"] == max(t["compute_s"], t["memory_s"],
                                          t["collective_s"])
    assert t["dominant"] in ("compute", "memory", "collective")
    assert t[f"{t['dominant']}_s"] == t["step_lower_bound_s"]


@given(st.lists(st.floats(0.05, 100.0), min_size=1, max_size=16))
@settings(**SET)
def test_budget_sum_positive_and_additive(epsilons):
    s = budget_sum(epsilons)
    assert s > 0
    np.testing.assert_allclose(budget_sum(epsilons + epsilons), 2 * s,
                               rtol=1e-9)


# ------- ParamFlat pack/unpack under arbitrary pytrees + bank shardings -----
# The flat engine's foundation: packing ANY packable pytree (nested
# containers, f32/bf16/f16 leaves, scalars) into the (P,) buffer is a
# bit-exact round trip, values are invariant under every bank sharding the
# rules can produce on this host's mesh, and the bf16 bank path quantizes
# rows exactly once (row == buf.astype(bf16), bitwise).

_PACK_DTYPES = ("float32", "bfloat16", "float16")

_leaf_desc = st.tuples(
    st.lists(st.integers(1, 4), min_size=0, max_size=3).map(tuple),
    st.sampled_from(_PACK_DTYPES)).map(lambda sd: ("leaf", sd))

_tree_desc = st.recursive(
    _leaf_desc,
    lambda kids: st.one_of(
        st.lists(kids, min_size=1, max_size=3).map(lambda xs: ("list", xs)),
        st.dictionaries(st.sampled_from("abcdef"), kids, min_size=1,
                        max_size=3).map(lambda d: ("dict", d))),
    max_leaves=6)


def _build_tree(desc, key_iter):
    kind, payload = desc
    if kind == "leaf":
        shape, dt = payload
        return jax.random.normal(next(key_iter), shape,
                                 jnp.float32).astype(dt)
    if kind == "list":
        return [_build_tree(c, key_iter) for c in payload]
    return {k: _build_tree(v, key_iter) for k, v in
            sorted(payload.items())}


@given(_tree_desc, st.integers(0, 2 ** 31 - 1), st.booleans(),
       st.integers(0, 7))
@settings(max_examples=25, deadline=None, derandomize=True)
def test_param_flat_roundtrip_under_bank_shardings(desc, seed, bf16_bank,
                                                   mesh_pick):
    from repro.federation.flatten import init_flat_bank, pack_params
    from repro.launch.mesh import make_host_mesh
    from repro.sharding.rules import flat_shardings

    keys = iter(jax.random.split(jax.random.PRNGKey(seed), 256))
    tree = _build_tree(desc, keys)

    flat = pack_params(tree)
    assert flat.buf.dtype == jnp.float32 and flat.buf.shape == (flat.size,)
    out = flat.unpack()
    for a, b in zip(jax.tree_util.tree_leaves(tree),
                    jax.tree_util.tree_leaves(out)):
        assert a.shape == b.shape and a.dtype == b.dtype
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    # arbitrary mesh split of whatever devices this host has (the CI
    # sharded-smoke job forces 8; locally this degrades to 1x1)
    n_dev = len(jax.devices())
    divisors = [d for d in range(1, n_dev + 1) if n_dev % d == 0]
    mesh = make_host_mesh(model=divisors[mesh_pick % len(divisors)])
    n_owners = 2 + seed % 3
    sh = flat_shardings(mesh, n_owners, flat.size)

    # sharded pack: same bits, laid out on the mesh
    sharded = flat.spec.pack(tree, sharding=sh.theta)
    np.testing.assert_array_equal(np.asarray(sharded), np.asarray(flat.buf))
    np.testing.assert_array_equal(
        np.asarray(flat.spec.pack(out)), np.asarray(flat.buf))

    # bank rows: one exact quantization of the central buffer, under the
    # bank sharding, f32 and bf16 storage alike
    dtype = jnp.bfloat16 if bf16_bank else None
    bank = init_flat_bank(flat, n_owners, dtype, sharding=sh.bank)
    assert bank.shape == (n_owners, flat.size)
    target = np.asarray(flat.buf.astype(bank.dtype))
    for i in range(n_owners):
        np.testing.assert_array_equal(np.asarray(bank[i]), target)
    if not bf16_bank:
        # f32 bank: a gathered row unpacks back to the exact pytree
        row = flat.spec.unpack(bank[0])
        for a, b in zip(jax.tree_util.tree_leaves(tree),
                        jax.tree_util.tree_leaves(row)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# --------- schedule partitioning + paged-bank trace streaming (PR 9) --------
_seq = st.lists(st.integers(0, 9), min_size=1, max_size=64)


@given(_seq, st.one_of(st.none(), st.integers(1, 8)))
@settings(**SET)
def test_partition_never_repeats_an_owner_within_a_group(seq, max_group):
    from repro.federation.schedules import partition_conflict_free
    groups = partition_conflict_free(np.asarray(seq, np.int32), max_group)
    for start, length in groups:
        members = seq[start:start + length]
        assert len(members) == len(set(members))
        if max_group is not None:
            assert length <= max_group


@given(_seq, st.one_of(st.none(), st.integers(1, 8)))
@settings(**SET)
def test_pack_groups_preserves_round_order(seq, max_group):
    # the grouped driver's (n_groups, G_max) index matrix, masked by
    # valid and flattened group-major, must be exactly 0..K-1 — groups
    # are consecutive rounds in order, so run_rounds can un-permute
    # group-major metrics back to round order by flattening
    from repro.federation.schedules import (pack_groups,
                                            partition_conflict_free)
    groups = partition_conflict_free(np.asarray(seq, np.int32), max_group)
    idx, valid = pack_groups(groups)
    flat_rounds = idx.reshape(-1)[np.flatnonzero(valid.reshape(-1))]
    np.testing.assert_array_equal(flat_rounds, np.arange(len(seq)))


@given(st.lists(st.integers(0, 99), min_size=1, max_size=40),
       st.integers(1, 16),
       st.lists(st.integers(1, 13), min_size=1, max_size=8))
@settings(**SET)
def test_trace_ring_replays_exact_tiling(trace, chunk, draws):
    # chunked device streaming must reproduce np.resize tiling of the
    # host trace bit-for-bit, across refills, wrap-around, and draws
    # larger than the chunk (which degrade to a direct upload)
    from repro.federation.schedules import TraceRing
    ring = TraceRing(np.asarray(trace, np.int32), chunk=chunk)
    total = sum(draws)
    expect = np.resize(np.asarray(trace, np.int32), total)
    got, cursor = [], 0
    for k in draws:
        # window() peeks without advancing: must agree with next()
        w = np.asarray(ring.window(k))
        out = np.asarray(ring.next(k))
        np.testing.assert_array_equal(w, out)
        np.testing.assert_array_equal(out, expect[cursor:cursor + k])
        cursor += k
        got.append(out)
    np.testing.assert_array_equal(np.concatenate(got), expect)
    assert ring.resident_bytes <= max(chunk, max(draws)) * 4


# ------------------- staleness runtime invariants (PR 10) -------------------
# Engine-level properties run on small cached federations (fixed K so the
# fused scan compiles once per configuration, not once per example).

_RT_K = 8
_RT_PARAMS = {"w": jnp.zeros((6,), jnp.float32)}
_RT_BATCHES = {
    "x": jax.random.normal(jax.random.PRNGKey(7), (_RT_K, 4, 6)),
    "y": jnp.ones((_RT_K, 4))}
_RT_FEDS = {}


def _rt_loss(params, batch):
    return jnp.mean((batch["x"] @ params["w"] - batch["y"]) ** 2)


def _runtime_fed(max_retries):
    from repro.federation import (DataOwner, Federation, FederationConfig,
                                  StalenessPolicy)
    from repro.federation.dp_sgd import PrivatizerConfig
    tag = ("rt", max_retries)
    if tag not in _RT_FEDS:
        owners = [DataOwner(n=200, epsilon=2.0, xi=1.0)] * 2
        cfg = FederationConfig(horizon=4096, sigma=1e-2, theta_max=10.0,
                               lr_scale=5.0)
        fed = Federation(owners, cfg, staleness=StalenessPolicy(
            deadline=1.0, max_retries=max_retries))
        fed.make_step(_rt_loss, privatizer=PrivatizerConfig(
            xi=1.0, granularity="example"))
        _RT_FEDS[tag] = fed
    return _RT_FEDS[tag]


def _rt_run(codes, seq, max_retries):
    fed = _runtime_fed(max_retries)
    s0 = fed.init_state(_RT_PARAMS)
    s, m = fed.run_rounds(s0, _RT_BATCHES, jnp.asarray(seq, jnp.int32),
                          jax.random.PRNGKey(0),
                          faults=jnp.asarray(codes, jnp.int8))
    return s0, s, {k: np.asarray(v) for k, v in m.items()}


@given(st.lists(st.integers(0, 5), min_size=_RT_K, max_size=_RT_K),
       st.lists(st.integers(0, 1), min_size=_RT_K, max_size=_RT_K),
       st.sampled_from([0, 2]))
@settings(max_examples=15, deadline=None, derandomize=True)
def test_epsilon_charged_iff_response_produced(codes, seq, max_retries):
    # a round spends epsilon exactly when the owner produced a response:
    # answered rounds (on-time, guard-rejected, OR late) spend; dropped,
    # refused and backoff-masked retry rounds never touch the ledger
    s0, s, m = _rt_run(codes, seq, max_retries)
    answered = ~(m["refused"] | m["dropped"] | m["quarantined"]
                 | m["retried"])
    d_spent = (np.asarray(s.ledger.spent)
               - np.asarray(s0.ledger.spent))
    d_timed = (np.asarray(s.ledger.timed_out)
               - np.asarray(s0.ledger.timed_out))
    d_retry = (np.asarray(s.ledger.retried)
               - np.asarray(s0.ledger.retried))
    for i in range(2):
        mine = m["owner"] == i
        assert d_spent[i] == int(answered[mine].sum())
        assert d_timed[i] == int(m["timed_out"][mine].sum())
        assert d_retry[i] == int(m["retried"][mine].sum())
    # timeouts are answered (late) rounds; retries never answer
    assert not (m["timed_out"] & ~answered).any()
    assert not (m["retried"] & answered).any()
    # and exactly one outcome per round
    one = (m["refused"].astype(int) + m["dropped"] + m["quarantined"]
           + m["retried"] + m["timed_out"] + m["faulted"])
    assert (one <= 1).all()


@given(st.lists(st.integers(0, 5), min_size=_RT_K, max_size=_RT_K),
       st.lists(st.integers(0, 1), min_size=_RT_K, max_size=_RT_K),
       st.sampled_from([0, 2]))
@settings(max_examples=15, deadline=None, derandomize=True)
def test_age_counters_monotone_and_reset_only_on_grants(codes, seq,
                                                        max_retries):
    s0, s, m = _rt_run(codes, seq, max_retries)
    clock0 = int(s0.stale.clock)
    lg0 = np.asarray(s0.stale.last_grant)
    # the logical clock ticks once per round, whatever the outcome
    assert int(s.stale.clock) == clock0 + _RT_K
    # last_grant moves only when a round actually applied, to the
    # position of the owner's LAST applied round
    applied = ~(m["refused"] | m["dropped"] | m["quarantined"]
                | m["retried"] | m["timed_out"] | m["faulted"])
    owner = m["owner"]
    for i in range(2):
        ks = np.flatnonzero(applied & (owner == i))
        expect = clock0 + int(ks[-1]) if ks.size else int(lg0[i])
        assert int(s.stale.last_grant[i]) == expect
    assert (np.asarray(s.stale.last_grant) >= lg0).all()
    assert (np.asarray(s.stale.cooldown) >= 0).all()
    assert (np.asarray(s.stale.retry_left) >= 0).all()


def _zero_runtime_pair(codec):
    from repro.federation import (DataOwner, FaultPolicy, Federation,
                                  FederationConfig, StalenessPolicy)
    from repro.federation.dp_sgd import PrivatizerConfig
    tag = ("zr", codec)
    if tag not in _RT_FEDS:
        dt = {"bf16": jnp.bfloat16}.get(codec, codec)
        pair = []
        for spol in (None, StalenessPolicy()):
            owners = [DataOwner(n=200, epsilon=2.0, xi=1.0)] * 2
            cfg = FederationConfig(horizon=4096, sigma=1e-2,
                                   theta_max=10.0, lr_scale=5.0)
            fed = Federation(owners, cfg,
                             fault_policy=FaultPolicy(max_faults=3,
                                                      window=8),
                             staleness=spol)
            fed.make_step(_rt_loss, privatizer=PrivatizerConfig(
                xi=1.0, granularity="example"),
                pack_params=codec is not None, bank_dtype=dt)
            pair.append(fed)
        _RT_FEDS[tag] = tuple(pair)
    return _RT_FEDS[tag]


@given(st.sampled_from([None, "bf16", "int8", "fp8"]),
       st.integers(0, 2**16),
       st.lists(st.integers(0, 1), min_size=_RT_K, max_size=_RT_K))
@settings(max_examples=12, deadline=None, derandomize=True)
def test_zero_runtime_policy_is_bit_identical(codec, key_seed, seq):
    # the identity policy (deadline=inf, no retries, decay=1, zero
    # latency) must reproduce the plain fault-armed engine bit-for-bit
    # on every storage codec, for ANY fault plan and dispatch order
    from repro.federation import FaultPlan, LatencyPlan
    fed_off, fed_on = _zero_runtime_pair(codec)
    plan = FaultPlan(drop=0.25, stale=0.15, nonfinite=0.15, corrupt=0.15)
    key = jax.random.PRNGKey(key_seed)
    seq = jnp.asarray(seq, jnp.int32)

    s_off = fed_off.init_state(_RT_PARAMS)
    s_off, _ = fed_off.run_rounds(s_off, _RT_BATCHES, seq, key,
                                  faults=plan)
    s_on = fed_on.init_state(_RT_PARAMS)
    s_on, _ = fed_on.run_rounds(s_on, _RT_BATCHES, seq, key, faults=plan,
                                latency=LatencyPlan())

    for a, b in zip(jax.tree_util.tree_leaves((s_off.theta_L, s_off.bank,
                                               s_off.faults)),
                    jax.tree_util.tree_leaves((s_on.theta_L, s_on.bank,
                                               s_on.faults))):
        assert bool((np.asarray(a) == np.asarray(b)).all())
    for col in ("spent", "refused", "dropped", "faulted", "quarantined"):
        assert bool((np.asarray(getattr(s_off.ledger, col))
                     == np.asarray(getattr(s_on.ledger, col))).all())
    assert not np.asarray(s_on.ledger.timed_out).any()
    assert not np.asarray(s_on.ledger.retried).any()
