"""Property-based tests (hypothesis) for the system's invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis.roofline import roofline_terms
from repro.core.cop import bound_asymptotic, budget_sum
from repro.core.dp_sgd import clip_tree
from repro.core.linear import make_problem, relative_fitness
from repro.core.privacy import capped_rounds, laplace_scale_theorem1
from repro.data import owner_shards

pytest.importorskip("hypothesis", reason="pip install -r requirements-dev.txt")
from hypothesis import given, settings, strategies as st  # noqa: E402

SET = dict(max_examples=25, deadline=None, derandomize=True)


@given(st.lists(st.floats(0.05, 100.0), min_size=1, max_size=8),
       st.integers(1_000, 10_000_000))
@settings(**SET)
def test_cop_bound_monotone_in_n(epsilons, n):
    b1 = bound_asymptotic(n, epsilons, 1.0, 1.0)
    b2 = bound_asymptotic(2 * n, epsilons, 1.0, 1.0)
    assert b2 < b1
    assert b1 >= 0.0


@given(st.floats(0.05, 50.0), st.floats(1.1, 4.0),
       st.integers(100, 100_000), st.integers(1, 10_000))
@settings(**SET)
def test_theorem1_scale_scaling_laws(eps, mult, horizon, n):
    b = laplace_scale_theorem1(1.0, horizon, n, eps)
    assert laplace_scale_theorem1(1.0, horizon, n, eps * mult) < b
    assert laplace_scale_theorem1(mult, horizon, n, eps) > b
    # exact inverse proportionality
    np.testing.assert_allclose(
        laplace_scale_theorem1(1.0, horizon, n, eps * mult) * mult, b,
        rtol=1e-9)


@given(st.integers(1, 100_000), st.integers(1, 512))
@settings(**SET)
def test_capped_rounds_bounds(T, N):
    c = capped_rounds(T, N)
    assert 1 <= c
    assert c >= T / N            # never less than the expected load


@given(st.lists(st.floats(-10.0, 10.0), min_size=1, max_size=64),
       st.floats(0.01, 10.0))
@settings(**SET)
def test_clip_tree_invariant(values, xi):
    tree = {"x": jnp.asarray(values, jnp.float32)}
    clipped, _ = clip_tree(tree, xi)
    norm = float(jnp.linalg.norm(clipped["x"]))
    assert norm <= xi * (1 + 1e-4)
    # direction preserved
    orig = jnp.asarray(values, jnp.float32)
    if float(jnp.linalg.norm(orig)) > 1e-6:
        cos = float(jnp.dot(clipped["x"], orig)
                    / (jnp.linalg.norm(clipped["x"]) * jnp.linalg.norm(orig)
                       + 1e-12))
        assert cos > 0.999


@given(st.integers(0, 2 ** 31 - 1))
@settings(max_examples=10, deadline=None, derandomize=True)
def test_relative_fitness_nonnegative(seed):
    shards = owner_shards("lending", [500, 500], seed=seed % 97)
    prob, _ = make_problem(shards, reg=1e-5, theta_max=3.0)
    key = jax.random.PRNGKey(seed)
    theta = jax.random.uniform(key, prob.theta_star.shape, minval=-3.0,
                               maxval=3.0)
    assert float(relative_fitness(prob, theta)) >= -1e-6


@given(st.floats(1e6, 1e18), st.floats(1e6, 1e15), st.floats(0, 1e15))
@settings(**SET)
def test_roofline_terms_consistency(flops, byts, coll):
    t = roofline_terms(flops, byts, coll)
    assert t["step_lower_bound_s"] == max(t["compute_s"], t["memory_s"],
                                          t["collective_s"])
    assert t["dominant"] in ("compute", "memory", "collective")
    assert t[f"{t['dominant']}_s"] == t["step_lower_bound_s"]


@given(st.lists(st.floats(0.05, 100.0), min_size=1, max_size=16))
@settings(**SET)
def test_budget_sum_positive_and_additive(epsilons):
    s = budget_sum(epsilons)
    assert s > 0
    np.testing.assert_allclose(budget_sum(epsilons + epsilons), 2 * s,
                               rtol=1e-9)


# ------- ParamFlat pack/unpack under arbitrary pytrees + bank shardings -----
# The flat engine's foundation: packing ANY packable pytree (nested
# containers, f32/bf16/f16 leaves, scalars) into the (P,) buffer is a
# bit-exact round trip, values are invariant under every bank sharding the
# rules can produce on this host's mesh, and the bf16 bank path quantizes
# rows exactly once (row == buf.astype(bf16), bitwise).

_PACK_DTYPES = ("float32", "bfloat16", "float16")

_leaf_desc = st.tuples(
    st.lists(st.integers(1, 4), min_size=0, max_size=3).map(tuple),
    st.sampled_from(_PACK_DTYPES)).map(lambda sd: ("leaf", sd))

_tree_desc = st.recursive(
    _leaf_desc,
    lambda kids: st.one_of(
        st.lists(kids, min_size=1, max_size=3).map(lambda xs: ("list", xs)),
        st.dictionaries(st.sampled_from("abcdef"), kids, min_size=1,
                        max_size=3).map(lambda d: ("dict", d))),
    max_leaves=6)


def _build_tree(desc, key_iter):
    kind, payload = desc
    if kind == "leaf":
        shape, dt = payload
        return jax.random.normal(next(key_iter), shape,
                                 jnp.float32).astype(dt)
    if kind == "list":
        return [_build_tree(c, key_iter) for c in payload]
    return {k: _build_tree(v, key_iter) for k, v in
            sorted(payload.items())}


@given(_tree_desc, st.integers(0, 2 ** 31 - 1), st.booleans(),
       st.integers(0, 7))
@settings(max_examples=25, deadline=None, derandomize=True)
def test_param_flat_roundtrip_under_bank_shardings(desc, seed, bf16_bank,
                                                   mesh_pick):
    from repro.federation.flatten import init_flat_bank, pack_params
    from repro.launch.mesh import make_host_mesh
    from repro.sharding.rules import flat_shardings

    keys = iter(jax.random.split(jax.random.PRNGKey(seed), 256))
    tree = _build_tree(desc, keys)

    flat = pack_params(tree)
    assert flat.buf.dtype == jnp.float32 and flat.buf.shape == (flat.size,)
    out = flat.unpack()
    for a, b in zip(jax.tree_util.tree_leaves(tree),
                    jax.tree_util.tree_leaves(out)):
        assert a.shape == b.shape and a.dtype == b.dtype
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    # arbitrary mesh split of whatever devices this host has (the CI
    # sharded-smoke job forces 8; locally this degrades to 1x1)
    n_dev = len(jax.devices())
    divisors = [d for d in range(1, n_dev + 1) if n_dev % d == 0]
    mesh = make_host_mesh(model=divisors[mesh_pick % len(divisors)])
    n_owners = 2 + seed % 3
    sh = flat_shardings(mesh, n_owners, flat.size)

    # sharded pack: same bits, laid out on the mesh
    sharded = flat.spec.pack(tree, sharding=sh.theta)
    np.testing.assert_array_equal(np.asarray(sharded), np.asarray(flat.buf))
    np.testing.assert_array_equal(
        np.asarray(flat.spec.pack(out)), np.asarray(flat.buf))

    # bank rows: one exact quantization of the central buffer, under the
    # bank sharding, f32 and bf16 storage alike
    dtype = jnp.bfloat16 if bf16_bank else None
    bank = init_flat_bank(flat, n_owners, dtype, sharding=sh.bank)
    assert bank.shape == (n_owners, flat.size)
    target = np.asarray(flat.buf.astype(bank.dtype))
    for i in range(n_owners):
        np.testing.assert_array_equal(np.asarray(bank[i]), target)
    if not bf16_bank:
        # f32 bank: a gathered row unpacks back to the exact pytree
        row = flat.spec.unpack(bank[0])
        for a, b in zip(jax.tree_util.tree_leaves(tree),
                        jax.tree_util.tree_leaves(row)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# --------- schedule partitioning + paged-bank trace streaming (PR 9) --------
_seq = st.lists(st.integers(0, 9), min_size=1, max_size=64)


@given(_seq, st.one_of(st.none(), st.integers(1, 8)))
@settings(**SET)
def test_partition_never_repeats_an_owner_within_a_group(seq, max_group):
    from repro.federation.schedules import partition_conflict_free
    groups = partition_conflict_free(np.asarray(seq, np.int32), max_group)
    for start, length in groups:
        members = seq[start:start + length]
        assert len(members) == len(set(members))
        if max_group is not None:
            assert length <= max_group


@given(_seq, st.one_of(st.none(), st.integers(1, 8)))
@settings(**SET)
def test_pack_groups_preserves_round_order(seq, max_group):
    # the grouped driver's (n_groups, G_max) index matrix, masked by
    # valid and flattened group-major, must be exactly 0..K-1 — groups
    # are consecutive rounds in order, so run_rounds can un-permute
    # group-major metrics back to round order by flattening
    from repro.federation.schedules import (pack_groups,
                                            partition_conflict_free)
    groups = partition_conflict_free(np.asarray(seq, np.int32), max_group)
    idx, valid = pack_groups(groups)
    flat_rounds = idx.reshape(-1)[np.flatnonzero(valid.reshape(-1))]
    np.testing.assert_array_equal(flat_rounds, np.arange(len(seq)))


@given(st.lists(st.integers(0, 99), min_size=1, max_size=40),
       st.integers(1, 16),
       st.lists(st.integers(1, 13), min_size=1, max_size=8))
@settings(**SET)
def test_trace_ring_replays_exact_tiling(trace, chunk, draws):
    # chunked device streaming must reproduce np.resize tiling of the
    # host trace bit-for-bit, across refills, wrap-around, and draws
    # larger than the chunk (which degrade to a direct upload)
    from repro.federation.schedules import TraceRing
    ring = TraceRing(np.asarray(trace, np.int32), chunk=chunk)
    total = sum(draws)
    expect = np.resize(np.asarray(trace, np.int32), total)
    got, cursor = [], 0
    for k in draws:
        # window() peeks without advancing: must agree with next()
        w = np.asarray(ring.window(k))
        out = np.asarray(ring.next(k))
        np.testing.assert_array_equal(w, out)
        np.testing.assert_array_equal(out, expect[cursor:cursor + k])
        cursor += k
        got.append(out)
    np.testing.assert_array_equal(np.concatenate(got), expect)
    assert ring.resident_bytes <= max(chunk, max(draws)) * 4
