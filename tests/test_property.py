"""Property-based tests (hypothesis) for the system's invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="pip install -r requirements-dev.txt")
from hypothesis import given, settings, strategies as st

from repro.analysis.roofline import roofline_terms
from repro.core.cop import bound_asymptotic, budget_sum
from repro.core.dp_sgd import clip_tree
from repro.core.linear import make_problem, relative_fitness
from repro.core.privacy import capped_rounds, laplace_scale_theorem1
from repro.data import owner_shards

SET = dict(max_examples=25, deadline=None, derandomize=True)


@given(st.lists(st.floats(0.05, 100.0), min_size=1, max_size=8),
       st.integers(1_000, 10_000_000))
@settings(**SET)
def test_cop_bound_monotone_in_n(epsilons, n):
    b1 = bound_asymptotic(n, epsilons, 1.0, 1.0)
    b2 = bound_asymptotic(2 * n, epsilons, 1.0, 1.0)
    assert b2 < b1
    assert b1 >= 0.0


@given(st.floats(0.05, 50.0), st.floats(1.1, 4.0),
       st.integers(100, 100_000), st.integers(1, 10_000))
@settings(**SET)
def test_theorem1_scale_scaling_laws(eps, mult, horizon, n):
    b = laplace_scale_theorem1(1.0, horizon, n, eps)
    assert laplace_scale_theorem1(1.0, horizon, n, eps * mult) < b
    assert laplace_scale_theorem1(mult, horizon, n, eps) > b
    # exact inverse proportionality
    np.testing.assert_allclose(
        laplace_scale_theorem1(1.0, horizon, n, eps * mult) * mult, b,
        rtol=1e-9)


@given(st.integers(1, 100_000), st.integers(1, 512))
@settings(**SET)
def test_capped_rounds_bounds(T, N):
    c = capped_rounds(T, N)
    assert 1 <= c
    assert c >= T / N            # never less than the expected load


@given(st.lists(st.floats(-10.0, 10.0), min_size=1, max_size=64),
       st.floats(0.01, 10.0))
@settings(**SET)
def test_clip_tree_invariant(values, xi):
    tree = {"x": jnp.asarray(values, jnp.float32)}
    clipped, _ = clip_tree(tree, xi)
    norm = float(jnp.linalg.norm(clipped["x"]))
    assert norm <= xi * (1 + 1e-4)
    # direction preserved
    orig = jnp.asarray(values, jnp.float32)
    if float(jnp.linalg.norm(orig)) > 1e-6:
        cos = float(jnp.dot(clipped["x"], orig)
                    / (jnp.linalg.norm(clipped["x"]) * jnp.linalg.norm(orig)
                       + 1e-12))
        assert cos > 0.999


@given(st.integers(0, 2 ** 31 - 1))
@settings(max_examples=10, deadline=None, derandomize=True)
def test_relative_fitness_nonnegative(seed):
    shards = owner_shards("lending", [500, 500], seed=seed % 97)
    prob, _ = make_problem(shards, reg=1e-5, theta_max=3.0)
    key = jax.random.PRNGKey(seed)
    theta = jax.random.uniform(key, prob.theta_star.shape, minval=-3.0,
                               maxval=3.0)
    assert float(relative_fitness(prob, theta)) >= -1e-6


@given(st.floats(1e6, 1e18), st.floats(1e6, 1e15), st.floats(0, 1e15))
@settings(**SET)
def test_roofline_terms_consistency(flops, byts, coll):
    t = roofline_terms(flops, byts, coll)
    assert t["step_lower_bound_s"] == max(t["compute_s"], t["memory_s"],
                                          t["collective_s"])
    assert t["dominant"] in ("compute", "memory", "collective")
    assert t[f"{t['dominant']}_s"] == t["step_lower_bound_s"]


@given(st.lists(st.floats(0.05, 100.0), min_size=1, max_size=16))
@settings(**SET)
def test_budget_sum_positive_and_additive(epsilons):
    s = budget_sum(epsilons)
    assert s > 0
    np.testing.assert_allclose(budget_sum(epsilons + epsilons), 2 * s,
                               rtol=1e-9)
