"""Algorithm 1 (paper-faithful) behaviour on the paper's convex problem."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (Algo1Config, fitness, make_problem, relative_fitness,
                        run_algorithm1, run_many)
from repro.data import owner_shards

REG, SIGMA = 1e-5, 2e-5


@pytest.fixture(scope="module")
def problem():
    shards = owner_shards("lending", [30_000] * 3, seed=0)
    return make_problem(shards, reg=REG, theta_max=2.0)


def _final_psi(problem, eps, T=400, rho=1.0, runs=8, seed=0):
    prob, owners = problem
    cfg = Algo1Config(horizon=T, rho=rho, sigma=SIGMA,
                      epsilons=[eps] * len(owners))
    tr = run_many(jax.random.PRNGKey(seed), prob, owners, cfg, runs)
    return float(jnp.mean(tr.psi[:, -1]))


def test_noiseless_convergence(problem):
    prob, owners = problem
    cfg = Algo1Config(horizon=400, rho=1.0, sigma=SIGMA,
                      epsilons=[1.0] * 3, noiseless=True)
    tr = run_algorithm1(jax.random.PRNGKey(0), prob, owners, cfg)
    psi = np.asarray(tr.psi)
    assert psi[-1] < 0.05                      # converges near theta*
    assert psi[-1] < psi[9] / 5                # and actually decreased


def test_psi_nonnegative_and_projected(problem):
    prob, owners = problem
    cfg = Algo1Config(horizon=100, rho=1.0, sigma=SIGMA, epsilons=[0.5] * 3)
    tr = run_algorithm1(jax.random.PRNGKey(1), prob, owners, cfg)
    assert float(jnp.min(tr.psi)) >= 0.0       # psi >= 0 by definition
    assert float(jnp.max(jnp.abs(tr.theta_L))) <= prob.theta_max + 1e-6
    assert float(jnp.max(jnp.abs(tr.theta_bank))) <= prob.theta_max + 1e-6


def test_more_privacy_budget_helps(problem):
    lo = _final_psi(problem, eps=1.0)
    hi = _final_psi(problem, eps=100.0)
    assert hi < lo                              # eps up -> cost of privacy down


def test_owner_selection_uniform(problem):
    prob, owners = problem
    cfg = Algo1Config(horizon=3000, rho=1.0, sigma=SIGMA, epsilons=[1.0] * 3)
    tr = run_algorithm1(jax.random.PRNGKey(2), prob, owners, cfg)
    counts = np.bincount(np.asarray(tr.owners_seq), minlength=3)
    assert counts.min() > 3000 / 3 * 0.8        # roughly uniform


def test_beyond_paper_composition_reduces_noise(problem):
    paper = _final_psi(problem, eps=2.0)
    prob, owners = problem
    cfg = Algo1Config(horizon=400, rho=1.0, sigma=SIGMA, epsilons=[2.0] * 3,
                      composition="per_owner_rounds")
    tr = run_many(jax.random.PRNGKey(0), prob, owners, cfg, 8)
    capped = float(jnp.mean(tr.psi[:, -1]))
    assert capped < paper                       # same eps, less noise


def test_fitness_minimum_at_theta_star(problem):
    prob, _ = problem
    key = jax.random.PRNGKey(3)
    for _ in range(5):
        key, k = jax.random.split(key)
        theta = prob.theta_star + 0.1 * jax.random.normal(k, prob.theta_star.shape)
        assert float(fitness(prob, theta)) >= float(prob.f_star) - 1e-9
        assert float(relative_fitness(prob, theta)) >= -1e-9
