"""Per-assigned-architecture smoke tests (reduced configs, CPU):
instantiate the SAME family at 2 layers / d_model<=256 / <=4 experts,
run one forward/loss + one gradient step + one decode step, assert output
shapes and finiteness."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_config, list_archs
from repro.models import build_model

ARCHS = list_archs()


def _batch(cfg, key, B=2, S=24):
    toks = jax.random.randint(key, (B, S), 0, cfg.vocab)
    batch = {"tokens": toks, "labels": toks}
    if cfg.family == "vlm":
        batch["patches"] = jax.random.normal(key, (B, cfg.n_patches,
                                                   cfg.d_model))
    if cfg.family == "audio":
        batch["frames"] = jax.random.normal(key, (B, cfg.enc_seq,
                                                  cfg.d_model))
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_forward_and_grad(arch, rng_key):
    cfg = get_config(arch).reduced()
    assert cfg.n_layers <= 2 and cfg.d_model <= 512
    if cfg.moe:
        assert cfg.moe.n_experts <= 4
    model = build_model(cfg, remat=False, moe_mode="onehot",
                        moe_group_tokens=16)
    params = model.init(rng_key, jnp.float32)
    batch = _batch(cfg, rng_key)

    x, aux = model.forward(params, batch)
    B, S = batch["tokens"].shape
    assert x.shape == (B, S, cfg.d_model)
    assert bool(jnp.all(jnp.isfinite(x)))

    loss, metrics = model.loss(params, batch)
    assert jnp.isfinite(loss) and float(loss) > 0

    grads = jax.grad(lambda p: model.loss(p, batch)[0])(params)
    gnorm = jnp.sqrt(sum(jnp.sum(leaf.astype(jnp.float32) ** 2)
                         for leaf in jax.tree_util.tree_leaves(grads)))
    assert bool(jnp.isfinite(gnorm)) and float(gnorm) > 0


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_decode(arch, rng_key):
    cfg = get_config(arch).reduced()
    model = build_model(cfg, remat=False, moe_mode="onehot",
                        moe_group_tokens=2)
    params = model.init(rng_key, jnp.float32)
    B = 2
    cache = model.init_cache(B, 16, dtype=jnp.float32)
    if cfg.family == "audio":
        frames = jax.random.normal(rng_key, (B, cfg.enc_seq, cfg.d_model))
        cache = model.prime_cross_cache(params, cache, frames)
    toks = jnp.zeros((B, 1), jnp.int32)
    for t in range(3):
        logits, cache = model.decode_step(params, cache, toks, jnp.int32(t))
        assert logits.shape == (B, 1, cfg.vocab)
        assert bool(jnp.all(jnp.isfinite(logits)))
        toks = jnp.argmax(logits, axis=-1).astype(jnp.int32)


@pytest.mark.parametrize("arch", ["yi-6b", "zamba2-2.7b", "xlstm-125m",
                                  "mixtral-8x22b", "whisper-medium",
                                  "internvl2-2b"])
def test_decode_matches_forward(arch, rng_key):
    """Incremental decode must reproduce teacher-forced logits."""
    cfg = get_config(arch).reduced()
    model = build_model(cfg, remat=False, moe_mode="ragged")
    params = model.init(rng_key, jnp.float32)
    B, S = 2, 10
    toks = jax.random.randint(rng_key, (B, S), 0, cfg.vocab)
    batch = {"tokens": toks, "labels": toks}
    if cfg.family == "vlm":
        batch["patches"] = jax.random.normal(rng_key, (B, cfg.n_patches,
                                                       cfg.d_model))
    if cfg.family == "audio":
        batch["frames"] = jax.random.normal(rng_key, (B, cfg.enc_seq,
                                                      cfg.d_model))
    x, _ = model.forward(params, batch)
    full = jnp.einsum("bsd,dv->bsv", x, model._unembed(params))
    cache = model.init_cache(B, S, dtype=jnp.float32)
    if cfg.family == "audio":
        cache = model.prime_cross_cache(params, cache, batch["frames"])
    if cfg.family == "vlm":
        pytest.skip("vlm decode starts after patch prefix; covered above")
    err = 0.0
    for t in range(S):
        lg, cache = model.decode_step(params, cache, toks[:, t:t + 1],
                                      jnp.int32(t))
        err = max(err, float(jnp.max(jnp.abs(lg[:, 0] - full[:, t]))))
    assert err < 5e-3, err


def test_sliding_window_ring_cache(rng_key):
    """Windowed decode (ring cache) == full decode restricted to window."""
    cfg = get_config("mixtral-8x22b").reduced()
    model = build_model(cfg, remat=False, moe_mode="ragged")
    params = model.init(rng_key, jnp.float32)
    B, S, W = 1, 12, 4
    toks = jax.random.randint(rng_key, (B, S), 0, cfg.vocab)
    x, _ = model.forward(params, {"tokens": toks, "labels": toks}, window=W)
    full = jnp.einsum("bsd,dv->bsv", x, model._unembed(params))
    cache = model.init_cache(B, S, window=W, dtype=jnp.float32)
    assert cache["kv"].k.shape[2] == W          # ring capacity == window
    err = 0.0
    for t in range(S):
        lg, cache = model.decode_step(params, cache, toks[:, t:t + 1],
                                      jnp.int32(t), window=W)
        err = max(err, float(jnp.max(jnp.abs(lg[:, 0] - full[:, t]))))
    assert err < 5e-3, err
