"""Device-resident privacy ledger + fused multi-round driver.

The contract under test: `Federation.run_rounds` (one lax.scan dispatch,
authorization via in-graph DeviceLedger masking) reproduces the
host-authorized per-round `step()` loop BIT-FOR-BIT under the same
per-round PRNG keys — params, bank, granted-round metrics, refusal
pattern, and the reconciled host ledger.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.federation import (DataOwner, DeviceLedger, Federation,
                              FederationConfig, LedgerDriftError,
                              PrivatizerConfig, as_owner_seq,
                              make_device_ledger)

N_OWNERS, K = 32, 160


def _leaves_equal(a, b):
    return all(np.array_equal(np.asarray(x), np.asarray(y)) for x, y in
               zip(jax.tree_util.tree_leaves(a), jax.tree_util.tree_leaves(b)))


@pytest.fixture(scope="module")
def toy():
    key = jax.random.PRNGKey(0)
    params = {"w": jax.random.normal(key, (6,)), "b": jnp.zeros(())}
    batches = {"x": jax.random.normal(jax.random.PRNGKey(1), (K, 4, 6)),
               "y": jax.random.normal(jax.random.PRNGKey(2), (K, 4))}
    def loss_fn(p, b):
        return jnp.mean((b["x"] @ p["w"] + p["b"] - b["y"]) ** 2)
    priv = PrivatizerConfig(xi=1.0, granularity="example")
    return params, batches, loss_fn, priv


def _make_fed(loss_fn, priv, horizon=3, **kw):
    owners = [DataOwner(n=100, epsilon=1.0, xi=1.0)
              for _ in range(N_OWNERS)]
    fed = Federation(owners, FederationConfig(horizon=horizon, sigma=1e-2,
                                              theta_max=10.0, lr_scale=5.0),
                     **kw)
    fed.make_step(loss_fn, privatizer=priv)
    return fed


# --------------- refusal semantics at scale (32 owners) --------------------
def test_run_rounds_matches_step_loop_bit_exact_with_exhaustion(toy):
    # horizon=3 and K=160 uniform draws over 32 owners: most owners blow
    # through their cap MID-schedule, so granted and refused rounds
    # interleave heavily — exactly the regime where device and host
    # accounting could drift.
    params, batches, loss_fn, priv = toy
    owner_seq = jax.random.randint(jax.random.PRNGKey(3), (K,), 0, N_OWNERS)
    root = jax.random.PRNGKey(4)
    keys = jax.random.split(root, K)

    fed_loop = _make_fed(loss_fn, priv)
    s_loop = fed_loop.init_state(params)
    refused_loop, metrics_loop = [], []
    for k in range(K):
        b = jax.tree_util.tree_map(lambda a: a[k], batches)
        s_loop, m = fed_loop.step(s_loop, b, int(owner_seq[k]), keys[k])
        refused_loop.append(m["refused"])
        if not m["refused"]:
            metrics_loop.append((k, float(m["clip_frac"]),
                                 float(m["grad_noise_scale"])))

    fed_fused = _make_fed(loss_fn, priv)
    s_fused = fed_fused.init_state(params)
    s_fused, ms = fed_fused.run_rounds(s_fused, batches, owner_seq, key=root)

    refused_fused = np.asarray(ms["refused"])
    assert refused_loop == [bool(r) for r in refused_fused]
    assert sum(refused_loop) > 20                  # exhaustion really bites
    assert not all(refused_loop[-N_OWNERS:])       # ...but not a dead tail

    # model state: bit-for-bit
    assert _leaves_equal(s_loop.theta_L, s_fused.theta_L)
    assert _leaves_equal(s_loop.bank, s_fused.bank)
    assert int(s_loop.step) == int(s_fused.step) == K - sum(refused_loop)

    # granted-round metrics: bit-for-bit
    for k, cf, gs in metrics_loop:
        assert float(ms["clip_frac"][k]) == cf
        assert float(ms["grad_noise_scale"][k]) == gs

    # reconciled ledger == the host-authorized loop's ledger, exactly
    assert fed_fused.reconcile(s_fused) == fed_loop.ledger()

    # and the device ledger agrees with both
    spent = np.asarray(s_fused.ledger.spent)
    refused_dev = np.asarray(s_fused.ledger.refused)
    counts = np.bincount(np.asarray(owner_seq), minlength=N_OWNERS)
    np.testing.assert_array_equal(spent, np.minimum(counts, 3))
    np.testing.assert_array_equal(refused_dev, np.maximum(counts - 3, 0))


def test_chunked_run_rounds_reconcile_is_idempotent(toy):
    # reconcile after every chunk must fold only the delta — same final
    # ledger as one reconcile at the end of an equivalent single schedule.
    params, batches, loss_fn, priv = toy
    owner_seq = jax.random.randint(jax.random.PRNGKey(3), (K,), 0, N_OWNERS)
    keys = jax.random.split(jax.random.PRNGKey(4), K)

    fed = _make_fed(loss_fn, priv)
    state = fed.init_state(params)
    half = K // 2
    for sl in (slice(0, half), slice(half, K)):
        state, _ = fed.run_rounds(
            state, jax.tree_util.tree_map(lambda a: a[sl], batches),
            owner_seq[sl], key=jax.random.PRNGKey(10))
        led = fed.reconcile(state)
    led_again = fed.reconcile(state)               # no new rounds: no-op
    assert led == led_again
    total = sum(led[i]["responses"] + led[i]["refused"]
                for i in range(N_OWNERS))
    assert total == K
    del keys


def test_reconcile_detects_stale_ledger_drift(toy):
    # Host-authorized rounds taken AFTER the device snapshot make the
    # device cap check permissive; reconcile must refuse to absorb the
    # overspend instead of silently double-booking epsilon.
    params, batches, loss_fn, priv = toy
    fed = _make_fed(loss_fn, priv, horizon=2)
    state = fed.init_state(params)
    b0 = jax.tree_util.tree_map(lambda a: a[0], batches)
    key = jax.random.PRNGKey(0)
    for _ in range(2):                     # spend owner 0's cap host-side
        state, m = fed.step(state, b0, 0, key)
        assert not m["refused"]
    # stale device ledger still thinks owner 0 has budget -> grants 2 more
    seq = jnp.zeros(2, jnp.int32)
    state, ms = fed.run_rounds(
        state, jax.tree_util.tree_map(lambda a: a[:2], batches), seq,
        key=jax.random.PRNGKey(1))
    assert not np.asarray(ms["refused"]).any()
    before = fed.ledger()
    with pytest.raises(LedgerDriftError, match="stale"):
        fed.reconcile(state)
    assert fed.ledger() == before      # validate-then-apply: no partial fold


def test_superseded_state_cannot_reconcile(toy):
    # Two live device states from one session would fold divergent counter
    # chains against a single baseline (silently under-counting emitted
    # noise); only the LATEST snapshot's chain may reconcile.
    params, batches, loss_fn, priv = toy
    fed = _make_fed(loss_fn, priv)                 # horizon (cap) = 3
    def sub(n):
        return jax.tree_util.tree_map(lambda a: a[:n], batches)
    state_a = fed.init_state(params)
    state_a, _ = fed.run_rounds(state_a, sub(8), jnp.zeros(8, jnp.int32),
                                key=jax.random.PRNGKey(1))
    state_b = fed.init_state(params)               # supersedes state_a
    # the fresh snapshot re-seeds from host totals (nothing folded yet: 0)
    np.testing.assert_array_equal(np.asarray(state_b.ledger.spent),
                                  np.zeros(N_OWNERS, np.int32))
    state_b, _ = fed.run_rounds(state_b, sub(4), jnp.zeros(4, jnp.int32),
                                key=jax.random.PRNGKey(2))
    led = fed.reconcile(state_b)
    assert led[0]["responses"] == 3 and led[0]["refused"] == 1
    before = fed.ledger()
    with pytest.raises(LedgerDriftError, match="superseded"):
        fed.reconcile(state_a)                     # divergent chain: loud
    assert fed.ledger() == before


def test_re_snapshot_seeds_counters_from_host_totals(toy):
    # a fresh snapshot after reconciled work starts from the host's
    # cumulative counters, so its own chain folds exact deltas
    params, batches, loss_fn, priv = toy
    fed = _make_fed(loss_fn, priv)                 # horizon (cap) = 3
    def sub(n):
        return jax.tree_util.tree_map(lambda a: a[:n], batches)
    state = fed.init_state(params)
    state, _ = fed.run_rounds(state, sub(8), jnp.zeros(8, jnp.int32),
                              key=jax.random.PRNGKey(1))
    led = fed.reconcile(state)
    assert led[0]["responses"] == 3 and led[0]["refused"] == 5
    fresh = fed.init_state(params)
    np.testing.assert_array_equal(np.asarray(fresh.ledger.spent)[:1], [3])
    np.testing.assert_array_equal(np.asarray(fresh.ledger.refused)[:1], [5])
    fresh, _ = fed.run_rounds(fresh, sub(4), jnp.zeros(4, jnp.int32),
                              key=jax.random.PRNGKey(2))
    led = fed.reconcile(fresh)
    assert led[0]["refused"] == 9                  # 5 + 4, exactly once
    assert led[0]["responses"] == 3


def test_device_ledger_seeded_from_host_accountant(toy):
    # refusals decided on-device must match what the host would decide,
    # including budget already spent before the state was built
    params, batches, loss_fn, priv = toy
    fed = _make_fed(loss_fn, priv, horizon=2)
    for _ in range(2):
        assert fed.mechanism.authorize(0)          # pre-spend owner 0
    state = fed.init_state(params)
    np.testing.assert_array_equal(
        np.asarray(state.ledger.spent),
        [2] + [0] * (N_OWNERS - 1))
    seq = jnp.asarray([0, 1], jnp.int32)
    state, ms = fed.run_rounds(
        state, jax.tree_util.tree_map(lambda a: a[:2], batches), seq,
        key=jax.random.PRNGKey(1))
    np.testing.assert_array_equal(np.asarray(ms["refused"]), [True, False])
    led = fed.reconcile(state)
    assert led[0]["responses"] == 2 and led[0]["refused"] == 1
    assert led[1]["responses"] == 1


def test_capped_mechanism_caps_reach_the_device(toy):
    params, _, loss_fn, priv = toy
    fed = _make_fed(loss_fn, priv, horizon=64, mechanism="per_owner_rounds",
                    cap_slack=0.5)
    state = fed.init_state(params)
    cap = fed.mechanism.cap
    assert cap is not None and cap == int(state.ledger.cap[0])
    np.testing.assert_array_equal(np.asarray(state.ledger.cap),
                                  [cap] * N_OWNERS)


def test_run_rounds_draws_from_pluggable_schedule(toy):
    params, batches, loss_fn, priv = toy
    fed = _make_fed(loss_fn, priv, horizon=64)
    state = fed.init_state(params)
    state, ms = fed.run_rounds(state, batches, key=jax.random.PRNGKey(5))
    drawn = np.asarray(ms["owner"])
    assert drawn.shape == (K,)
    assert 0 <= drawn.min() and drawn.max() < N_OWNERS
    assert len(np.unique(drawn)) > N_OWNERS // 2   # schedule actually mixes


# --------------------------- plumbing units --------------------------------
def test_device_ledger_construction_and_remaining():
    led = make_device_ledger([3, 5], spent=[1, 5])
    assert isinstance(led, DeviceLedger)
    np.testing.assert_array_equal(np.asarray(led.remaining()), [2, 0])
    assert bool(led.authorized(jnp.int32(0)))
    assert not bool(led.authorized(jnp.int32(1)))


def test_as_owner_seq_validates():
    out = as_owner_seq([0, 1, 2], 3)
    assert out.dtype == jnp.int32
    with pytest.raises(ValueError, match="out of range"):
        as_owner_seq([0, 3], 3)
    with pytest.raises(ValueError, match="1-D"):
        as_owner_seq(np.zeros((2, 2), np.int32), 3)


def test_legacy_three_field_state_still_constructs(toy):
    # downstream code that built AsyncDPState positionally keeps working;
    # run_rounds demands the ledger explicitly.
    from repro.federation import AsyncDPState, make_fused_rounds
    params, batches, loss_fn, priv = toy
    st = AsyncDPState(params, params, jnp.zeros((), jnp.int32))
    assert st.ledger is None
    fed = _make_fed(loss_fn, priv)
    run = make_fused_rounds(loss_fn, fed.as_async_config(priv))
    with pytest.raises(ValueError, match="device ledger"):
        run(st, batches, jnp.zeros(K, jnp.int32),
            jax.random.split(jax.random.PRNGKey(0), K))


# --------------------------- fused kernel path -----------------------------
def test_fused_kernel_privatizer_in_scan_body(toy):
    # clip+noise through the Pallas kernels (interpret mode on CPU) inside
    # the fused scan: finite updates, real refusal masking, and the clip
    # actually binds (scaled-up loss -> clip_frac == 1).
    params, batches, loss_fn, priv = toy
    priv = PrivatizerConfig(xi=1e-3, granularity="microbatch",
                            n_microbatches=2, fused_kernel=True,
                            kernel_block_rows=8)
    fed = _make_fed(loss_fn, priv, horizon=2)
    state = fed.init_state(params)
    small = jax.tree_util.tree_map(lambda a: a[:24], batches)
    seq = jnp.asarray(np.arange(24) % 4, jnp.int32)    # owners 0-3, 6 each
    state, ms = fed.run_rounds(state, small, seq, key=jax.random.PRNGKey(6))
    assert all(np.isfinite(np.asarray(leaf)).all()
               for leaf in jax.tree_util.tree_leaves(state.theta_L))
    granted = ~np.asarray(ms["refused"])
    assert granted.sum() == 8                           # 2 per owner cap
    assert np.asarray(ms["clip_frac"])[granted].min() == 1.0
    led = fed.reconcile(state)
    assert all(led[i]["responses"] == 2 and led[i]["refused"] == 4
               for i in range(4))


def test_fused_kernel_matches_jnp_clip_semantics(toy):
    # With noise off, the kernel backend must agree with the jnp backend
    # to float tolerance (same clip math, different reduction path).
    from repro.federation import private_grad
    params, batches, loss_fn, _ = toy
    b = jax.tree_util.tree_map(lambda a: a[0], batches)
    key = jax.random.PRNGKey(0)
    kw = dict(xi=1e-3, granularity="microbatch", n_microbatches=2)
    g_jnp, m_jnp = private_grad(loss_fn, params, b, key,
                                cfg=PrivatizerConfig(**kw), noise_scale=0.0)
    g_k, m_k = private_grad(loss_fn, params, b, key,
                            cfg=PrivatizerConfig(fused_kernel=True,
                                                 kernel_block_rows=8, **kw),
                            noise_scale=0.0)
    for a, c in zip(jax.tree_util.tree_leaves(g_jnp),
                    jax.tree_util.tree_leaves(g_k)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(c), rtol=1e-5,
                                   atol=1e-8)
    assert float(m_jnp["clip_frac"]) == float(m_k["clip_frac"])
