"""DP-FTRL tree-aggregated correlated noise (mechanism + kernels + drivers).

Contracts under test:
  * depth-0 tree == paper mechanism BIT-FOR-BIT under fixed keys, on the
    pytree path, the flat reference path, and the flat fused path.
  * the tree_noise kernel family (repro.kernels.tree_noise) matches its
    jnp oracle on the same Laplace bits, and the online binary counter
    satisfies the popcount/telescoping invariants (cumulative noise over
    t leaves == sum of popcount(t) active nodes — the O(log K) bound).
  * drivers: host step loop == fused scan bit-for-bit; grouped rounds
    advance IDENTICAL tree state; refusals (mid-schedule exhaustion)
    leave nodes AND counters bit-exactly untouched for every bank codec.
  * accounting: cap = min(T, 2^d - 1), per-node scale d * b(R),
    summary's tree-completion view, reconcile bit-exact.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.federation import (AsyncDPConfig, DataOwner, Federation,
                              FederationConfig, PrivatizerConfig,
                              TreeMechanism, TreeNoise, init_state_flat,
                              init_tree_noise, make_mechanism,
                              make_sync_dp_step, make_train_step)
from repro.federation.flatten import flatten_spec
from repro.kernels.tree_noise.ops import tree_delta_row
from repro.kernels.tree_noise.ref import tree_delta_ref, tree_masks_ref

N_OWNERS, K = 3, 24


@pytest.fixture(scope="module")
def toy():
    key = jax.random.PRNGKey(0)
    params = {"w": jax.random.normal(key, (6,)), "b": jnp.zeros(())}
    batches = {"x": jax.random.normal(jax.random.PRNGKey(1), (K, 4, 6)),
               "y": jax.random.normal(jax.random.PRNGKey(2), (K, 4))}

    def loss_fn(p, b):
        return jnp.mean((b["x"] @ p["w"] + p["b"] - b["y"]) ** 2)

    priv = PrivatizerConfig(xi=1.0, granularity="example")
    return params, batches, loss_fn, priv


def _make_fed(loss_fn, priv, mechanism="tree", depth=None, horizon=16,
              pack=True, bank_dtype=None, mesh=None, **kw):
    owners = [DataOwner(n=200, epsilon=2.0, xi=1.0)
              for _ in range(N_OWNERS)]
    fed = Federation(owners, FederationConfig(horizon=horizon, sigma=1e-2,
                                              theta_max=10.0, lr_scale=5.0),
                     mechanism=mechanism,
                     **(dict(tree_depth=depth) if mechanism == "tree"
                        else {}), **kw)
    fed.make_step(loss_fn, privatizer=priv, pack_params=pack,
                  bank_dtype=bank_dtype, mesh=mesh)
    return fed


def _round_robin(k=K):
    return jnp.asarray(np.arange(k) % N_OWNERS, jnp.int32)


def _leaves_equal(a, b):
    return all(np.array_equal(np.asarray(x), np.asarray(y)) for x, y in
               zip(jax.tree_util.tree_leaves(a), jax.tree_util.tree_leaves(b)))


# --------------------- depth-0 degeneracy (parity anchor) -------------------
@pytest.mark.parametrize("pack,fused", [(False, False), (True, False),
                                        (True, True)])
def test_depth0_tree_is_paper_mechanism_bitwise(toy, pack, fused):
    params, batches, loss_fn, priv = toy
    priv = priv if not fused else PrivatizerConfig(
        xi=1.0, granularity="microbatch", n_microbatches=2,
        fused_kernel=True)
    seq, key = _round_robin(), jax.random.PRNGKey(7)
    fp = _make_fed(loss_fn, priv, mechanism="paper", pack=pack)
    sp, mp = fp.run_rounds(fp.init_state(params), batches, seq, key)
    ft = _make_fed(loss_fn, priv, depth=0, pack=pack)
    st = ft.init_state(params)
    assert isinstance(st.tree, TreeNoise) and st.tree.depth == 0
    st, mt = ft.run_rounds(st, batches, seq, key)
    assert _leaves_equal(sp.theta_L, st.theta_L)
    assert _leaves_equal(sp.bank, st.bank)
    for name in mp:
        np.testing.assert_array_equal(np.asarray(mp[name]),
                                      np.asarray(mt[name]))
    # the degenerate tree has no nodes and never counts leaves differently
    assert np.asarray(st.tree.counts).tolist() == [8, 8, 8]


# ----------------------- kernel family vs jnp oracle ------------------------
def test_tree_delta_kernel_matches_oracle_same_bits():
    # repro.kernels.tree_noise triple: drive the Pallas interpreter and
    # the ref transform with the SAME Laplace bits (the op-level paths
    # draw different shapes, so equality lives at the kernel/ref level).
    from repro.kernels.tree_noise.kernel import LANES, tree_delta_2d
    depth, rows = 4, 2
    rs = np.random.RandomState(0)
    nodes2d = jnp.asarray(rs.randn(depth, rows, LANES), jnp.float32)
    bits = jax.random.bits(jax.random.PRNGKey(3), (rows, LANES), jnp.uint32)
    for count in (0, 1, 2, 6, 7, 11):
        cnt = jnp.asarray(count, jnp.int32)
        d_k, n_k = tree_delta_2d(nodes2d, bits, cnt.reshape(1, 1),
                                 jnp.full((1, 1), 1.3, jnp.float32),
                                 block_rows=1, interpret=True)
        d_r, n_r = tree_delta_ref(nodes2d.reshape(depth, -1),
                                  bits.reshape(-1), cnt,
                                  jnp.float32(1.3))
        np.testing.assert_allclose(np.asarray(d_k).reshape(-1),
                                   np.asarray(d_r), rtol=1e-6, atol=1e-6)
        np.testing.assert_allclose(np.asarray(n_k).reshape(depth, -1),
                                   np.asarray(n_r), rtol=1e-6, atol=1e-6)


def test_tree_delta_row_op_padding_and_depth0():
    # non-lane-aligned P through the padded 2D path (interpreter) keeps
    # the structural invariants; depth 0 returns the raw draw untouched
    p, depth = 130, 3
    nodes = jnp.asarray(np.random.RandomState(1).randn(depth, p), jnp.float32)
    key = jax.random.PRNGKey(9)
    delta, new = tree_delta_row(nodes, 3, key, 1.0, block_rows=1,
                                interpret=True)
    assert delta.shape == (p,) and new.shape == (depth, p)
    retired, fresh = tree_masks_ref(jnp.int32(3), depth)
    # count 3 -> t1 = 4 = 0b100: levels 0,1 retire, level 2 is fresh
    assert np.asarray(retired).tolist() == [True, True, False]
    assert np.asarray(fresh).tolist() == [False, False, True]
    np.testing.assert_array_equal(np.asarray(new[0]), 0.0)
    np.testing.assert_array_equal(np.asarray(new[1]), 0.0)
    # telescoping: delta == fresh draw - retired sum  =>  fresh node
    # equals delta + sum(retired old nodes)
    np.testing.assert_allclose(np.asarray(new[2]),
                               np.asarray(delta + nodes[0] + nodes[1]),
                               rtol=1e-6, atol=1e-6)
    d0, n0 = tree_delta_row(jnp.zeros((0, p), jnp.float32), 5, key, 2.0)
    assert n0.shape == (0, p)
    from repro.kernels.dp_clip_noise.ref import laplace_from_bits
    bits = jax.random.bits(key, (p,), jnp.uint32)
    np.testing.assert_array_equal(np.asarray(d0),
                                  np.asarray(2.0 * laplace_from_bits(bits)))


# ------------------ popcount / O(log K) variance property -------------------
def _check_popcount_telescoping(depth, t, p):
    # Advance one owner's tree t <= 2^depth - 1 leaves; after every
    # increment the CUMULATIVE injected noise telescopes to the sum of
    # the currently-active nodes — popcount(t) of them, <= depth — which
    # is the whole O(log K) cost-of-privacy claim (cumulative variance
    # grows with popcount, not t). Node values are the unit-scale draws
    # themselves, so the identity is checked on the real sampler output.
    t = min(t, (1 << depth) - 1)
    nodes = jnp.zeros((depth, p), jnp.float32)
    cum = np.zeros((p,), np.float64)
    for leaf in range(t):
        delta, nodes = tree_delta_row(nodes, leaf, jax.random.PRNGKey(leaf),
                                      1.0, interpret="oracle")
        cum += np.asarray(delta, np.float64)
        n_active = sum(bool(np.any(np.asarray(nodes[lvl]) != 0.0))
                       for lvl in range(depth))
        assert n_active == bin(leaf + 1).count("1") <= depth
        np.testing.assert_allclose(cum, np.asarray(nodes).sum(axis=0),
                                   rtol=1e-5, atol=1e-5)


def _check_masks_binary_counter(count, depth):
    retired, fresh = tree_masks_ref(jnp.int32(count), depth)
    retired, fresh = np.asarray(retired), np.asarray(fresh)
    t1 = count + 1
    for lvl in range(depth):
        assert retired[lvl] == (t1 % (1 << (lvl + 1)) == 0)
        assert fresh[lvl] == (t1 % (1 << (lvl + 1)) == (1 << lvl))
    # at most one fresh level; every level below it retires
    assert fresh.sum() <= 1
    if fresh.any():
        lvl = int(np.argmax(fresh))
        assert retired[:lvl].all() and not retired[lvl:].any()


@pytest.mark.parametrize("depth,t,p", [(1, 1, 1), (3, 7, 2), (5, 31, 1),
                                       (6, 40, 3)])
def test_cumulative_noise_is_popcount_many_nodes(depth, t, p):
    _check_popcount_telescoping(depth, t, p)


@pytest.mark.parametrize("count", [0, 1, 2, 6, 7, 127, 1 << 19])
def test_tree_masks_binary_counter(count):
    for depth in (1, 3, 10, 21):
        _check_masks_binary_counter(count, depth)


try:                # property-based sweep where hypothesis is installed
    from hypothesis import given, settings, strategies as st

    SET = dict(max_examples=20, deadline=None, derandomize=True)

    @given(st.integers(1, 6), st.integers(1, 63), st.integers(1, 4))
    @settings(**SET)
    def test_cumulative_noise_popcount_property(depth, t, p):
        _check_popcount_telescoping(depth, t, p)

    @given(st.integers(0, 1 << 20), st.integers(1, 21))
    @settings(**SET)
    def test_tree_masks_binary_counter_property(count, depth):
        _check_masks_binary_counter(count, depth)
except ImportError:     # parametrized fallbacks above still run
    pass


# --------------------------- driver equivalence -----------------------------
def test_step_loop_matches_fused_scan_with_exhaustion(toy):
    # depth 2 -> capacity 3 < 8 rounds/owner: refusals hit MID-schedule
    params, batches, loss_fn, priv = toy
    seq, key = _round_robin(), jax.random.PRNGKey(11)
    keys = jax.random.split(key, K)
    fed_f = _make_fed(loss_fn, priv, depth=2)
    s_f, m_f = fed_f.run_rounds(fed_f.init_state(params), batches, seq, key)
    fed_l = _make_fed(loss_fn, priv, depth=2)
    s_l = fed_l.init_state(params)
    refused = []
    for k in range(K):
        b = {n: v[k] for n, v in batches.items()}
        s_l, m = fed_l.step(s_l, b, int(seq[k]), keys[k])
        refused.append(m["refused"])
    assert _leaves_equal(s_f.theta_L, s_l.theta_L)
    assert _leaves_equal(s_f.bank, s_l.bank)
    np.testing.assert_array_equal(np.asarray(s_f.tree.nodes),
                                  np.asarray(s_l.tree.nodes))
    np.testing.assert_array_equal(np.asarray(s_f.tree.counts),
                                  np.asarray(s_l.tree.counts))
    np.testing.assert_array_equal(np.asarray(m_f["refused"]),
                                  np.asarray(refused))
    assert fed_f.reconcile(s_f) == fed_l.ledger()


def test_grouped_rounds_advance_identical_tree_state(toy):
    # Node contents depend only on (key, count) — not on theta — so the
    # grouped driver must reproduce the sequential tree EXACTLY even
    # where theta_L deviates (documented group-mean reduction).
    params, batches, loss_fn, priv = toy
    seq, key = _round_robin(), jax.random.PRNGKey(13)
    fed_s = _make_fed(loss_fn, priv, depth=3)
    s_s, _ = fed_s.run_rounds(fed_s.init_state(params), batches, seq, key)
    fed_g = _make_fed(loss_fn, priv, depth=3)
    s_g, _ = fed_g.run_rounds(fed_g.init_state(params), batches, seq, key,
                              owner_parallel=True, max_group=N_OWNERS)
    np.testing.assert_array_equal(np.asarray(s_s.tree.counts),
                                  np.asarray(s_g.tree.counts))
    np.testing.assert_array_equal(np.asarray(s_s.tree.nodes),
                                  np.asarray(s_g.tree.nodes))
    assert fed_s.reconcile(s_s) == fed_g.reconcile(s_g)


@pytest.mark.parametrize("bank_dtype", [None, jnp.bfloat16, "int8", "fp8"])
def test_exhaustion_leaves_tree_bit_exact_per_codec(toy, bank_dtype):
    # After the cap (depth 2 -> 3 leaves/owner), EVERY further round must
    # be a bit-exact no-op on nodes and counters, whatever the bank codec.
    params, batches, loss_fn, priv = toy
    seq, key = _round_robin(), jax.random.PRNGKey(17)
    fed = _make_fed(loss_fn, priv, depth=2, bank_dtype=bank_dtype)
    state = fed.init_state(params)
    n_granted = 3 * N_OWNERS
    cut = {n: v[:n_granted] for n, v in batches.items()}
    rest = {n: v[n_granted:] for n, v in batches.items()}
    keys = jax.random.split(key, K)

    fused = fed._fused_fn
    state, _ = fused(state, cut, seq[:n_granted], keys[:n_granted])
    nodes0 = np.asarray(state.tree.nodes).copy()
    counts0 = np.asarray(state.tree.counts).copy()
    assert counts0.tolist() == [3, 3, 3]
    state, m = fused(state, rest, seq[n_granted:], keys[n_granted:])
    assert np.asarray(m["refused"]).all()
    np.testing.assert_array_equal(np.asarray(state.tree.nodes), nodes0)
    np.testing.assert_array_equal(np.asarray(state.tree.counts), counts0)


def test_sharded_1x1_mesh_tree_parity(toy):
    from repro.launch.mesh import make_debug_mesh
    from repro.sharding.rules import flat_shardings
    params, batches, loss_fn, priv = toy
    seq, key = _round_robin(), jax.random.PRNGKey(19)
    mesh = make_debug_mesh(1, 1)
    sh = flat_shardings(mesh, N_OWNERS, 7)
    assert sh.tree_nodes is not None
    fed_u = _make_fed(loss_fn, priv, depth=3)
    s_u, _ = fed_u.run_rounds(fed_u.init_state(params), batches, seq, key)
    fed_m = _make_fed(loss_fn, priv, depth=3, mesh=mesh)
    s_m, _ = fed_m.run_rounds(fed_m.init_state(params), batches, seq, key)
    np.testing.assert_array_equal(np.asarray(s_u.theta_L.buf),
                                  np.asarray(s_m.theta_L.buf))
    np.testing.assert_array_equal(np.asarray(s_u.tree.nodes),
                                  np.asarray(s_m.tree.nodes))
    np.testing.assert_array_equal(np.asarray(s_u.tree.counts),
                                  np.asarray(s_m.tree.counts))


# ------------------------------- accounting ---------------------------------
def test_tree_mechanism_scales_and_cap():
    owners = [DataOwner(n=100, epsilon=2.0, xi=1.0)]
    cfg = FederationConfig(horizon=1000)
    mech = make_mechanism("tree", owners, cfg, tree_depth=9)
    assert mech.cap == 511 and mech.capacity == 511
    # per-node scale: d * 2 Xi R / (n eps) with R = 511
    np.testing.assert_allclose(
        np.asarray(mech.scales()), 9 * 2.0 * 1.0 * 511 / (100 * 2.0),
        rtol=1e-6)
    # default depth sizes the tree to the horizon: capacity >= T
    mech_d = make_mechanism("tree", owners, cfg)
    assert mech_d.tree_depth == 10 and mech_d.cap == 1000
    # degenerate depth: paper cap and paper scale
    mech0 = make_mechanism("tree", owners, cfg, tree_depth=0)
    assert mech0.cap is None and mech0.capacity is None
    np.testing.assert_allclose(
        np.asarray(mech0.scales()),
        np.asarray(make_mechanism("paper", owners, cfg).scales()))


def test_tree_ledger_summary_and_validation():
    owners = [DataOwner(n=50, epsilon=1.0, xi=1.0)]
    cfg = FederationConfig(horizon=100)
    mech = make_mechanism("tree", owners, cfg, tree_depth=4)
    assert mech.cap == 15
    for _ in range(5):
        assert mech.authorize(0)
    led = mech.ledger()[0]
    tree = led["tree"]
    assert tree["depth"] == 4 and tree["capacity"] == 15
    assert tree["nodes_completed_per_level"] == [5, 2, 1, 0]
    np.testing.assert_allclose(tree["eps_per_node"], 1.0 / (4 * 15))
    # eps/(d*R) per node * d node-queries per response recomposes to the
    # integer ledger's eps/R per response
    np.testing.assert_allclose(led["spent"], 5 * 1.0 / 15)
    with pytest.raises(ValueError, match="tree_depth"):
        make_mechanism("paper", owners, cfg, tree_depth=3)
    with pytest.raises(ValueError, match="int32"):
        TreeMechanism(owners, cfg, depth=31)
    with pytest.raises(ValueError, match="tree_depth"):
        make_mechanism(TreeMechanism(owners, cfg), owners, cfg,
                       tree_depth=2)


def test_tree_engine_guards(toy):
    params, batches, loss_fn, priv = toy
    cfg = AsyncDPConfig(n_owners=2, horizon=100, epsilons=(1.0, 1.0),
                        owner_sizes=(50, 50), privatizer=priv,
                        tree_depth=3)
    with pytest.raises(ValueError, match="holds 7 leaves"):
        make_train_step(loss_fn, cfg)     # caps default to T=100 > 7
    ok = AsyncDPConfig(n_owners=2, horizon=100, epsilons=(1.0, 1.0),
                       owner_sizes=(50, 50), privatizer=priv,
                       tree_depth=3, caps=(7, 7))
    step = make_train_step(loss_fn, ok)
    import dataclasses
    bare = init_state_flat(params, dataclasses.replace(ok, tree_depth=None))
    with pytest.raises(ValueError, match="no noise tree"):
        step(bare, {n: v[0] for n, v in batches.items()},
             jnp.int32(0), jax.random.PRNGKey(0))
    with pytest.raises(ValueError, match="no sync counterpart"):
        make_sync_dp_step(loss_fn, ok, lr=0.1)
    owners = [DataOwner(n=50, epsilon=1.0, xi=1.0) for _ in range(2)]
    with pytest.raises(ValueError, match="deep path"):
        fed = Federation(owners, FederationConfig(horizon=8),
                         mechanism="tree", tree_depth=2)
        fed.run(jax.random.PRNGKey(0), None)


def test_init_tree_noise_shapes(toy):
    params, _, _, priv = toy
    cfg = AsyncDPConfig(n_owners=3, horizon=7, epsilons=(1.0,) * 3,
                        owner_sizes=(10,) * 3, privatizer=priv,
                        tree_depth=3)
    tr = init_tree_noise(cfg, params)              # pytree representation
    assert tr.nodes["w"].shape == (3, 3, 6)
    assert tr.nodes["b"].shape == (3, 3)
    assert tr.counts.shape == (3,) and tr.depth == 3
    flat = init_state_flat(params, cfg)
    assert flat.tree.nodes.shape == (3, 3, 7)
    assert init_tree_noise(
        AsyncDPConfig(n_owners=3, horizon=7, epsilons=(1.0,) * 3,
                      owner_sizes=(10,) * 3, privatizer=priv), params) is None


def test_flatspec_pack_f32_roundtrip():
    params = {"w": jnp.ones((4,), jnp.bfloat16), "b": jnp.zeros((2, 3))}
    spec = flatten_spec(params)
    noise = {"w": jnp.asarray(np.random.RandomState(0).randn(4), jnp.float32),
             "b": jnp.asarray(np.random.RandomState(1).randn(2, 3),
                              jnp.float32)}
    buf = spec.pack_f32(noise)
    assert buf.dtype == jnp.float32
    back = spec.unpack_f32(buf)
    # no bf16 laundering: the f32 values survive bit-for-bit even though
    # the model leaf "w" is bf16
    np.testing.assert_array_equal(np.asarray(back["w"]),
                                  np.asarray(noise["w"]))
    np.testing.assert_array_equal(np.asarray(back["b"]),
                                  np.asarray(noise["b"]))
    assert back["w"].dtype == jnp.float32
    with pytest.raises(ValueError, match="shape"):
        spec.pack_f32({"w": jnp.zeros((5,)), "b": jnp.zeros((2, 3))})
    with pytest.raises(ValueError, match="buffer shape"):
        spec.unpack_f32(jnp.zeros((3,), jnp.float32))
