"""Runtime key-reuse sanitizer over the real federation engine (tier 1).

`dpcheck.sanitize()` runs the engine eagerly with every jax.random sampler
patched to hash-and-record the concrete key bytes it consumes. These tests
drive `Federation.run_rounds` through the sequential scan, the grouped
vmap driver, and the int8/fp8 quantized banks and assert (a) no key is
ever consumed twice, and (b) coverage is total — zero keys were skipped
as unverifiable, so the "no reuse" claim has no blind spots. A final test
proves the instrument works by feeding it deliberate reuse.
"""
import jax
import jax.numpy as jnp
import pytest

from repro.analysis.dpcheck import KeyReuseError, sanitize
from repro.federation import (DataOwner, Federation, FederationConfig,
                              PrivatizerConfig)

N_OWNERS, K = 4, 6


@pytest.fixture(scope="module")
def toy():
    key = jax.random.PRNGKey(0)
    params = {"w": jax.random.normal(key, (6, 3)), "b": jnp.zeros((3,))}
    batches = {"x": jax.random.normal(jax.random.PRNGKey(1), (K, 4, 6)),
               "y": jax.random.normal(jax.random.PRNGKey(2), (K, 4, 3))}

    def loss_fn(p, b):
        return jnp.mean((b["x"] @ p["w"] + p["b"] - b["y"]) ** 2)

    priv = PrivatizerConfig(xi=1.0, granularity="example")
    return params, batches, loss_fn, priv


def _make_fed(loss_fn, priv, **kw):
    owners = [DataOwner(n=100, epsilon=1.0, xi=1.0)
              for _ in range(N_OWNERS)]
    fed = Federation(owners, FederationConfig(horizon=8, sigma=1e-2,
                                              theta_max=10.0, lr_scale=5.0))
    fed.make_step(loss_fn, privatizer=priv, pack_params=True, **kw)
    return fed


SEQ = [0, 1, 2, 3, 0, 1]


def _run_sanitized(fed, params, batches, **kw):
    state = fed.init_state(params)
    seq = jnp.asarray(SEQ, jnp.int32)
    with sanitize() as rec:
        state, ms = fed.run_rounds(state, batches, seq,
                                   key=jax.random.PRNGKey(7), **kw)
    return rec


@pytest.mark.parametrize("bank", [None, "int8", "fp8"])
def test_run_rounds_sequential_no_key_reuse(toy, bank):
    params, batches, loss_fn, priv = toy
    kw = {"bank_dtype": bank} if bank else {}
    fed = _make_fed(loss_fn, priv, **kw)
    rec = _run_sanitized(fed, params, batches)
    assert rec.draws > 0                 # the mechanism actually drew noise
    assert rec.skipped == 0              # every key was verifiable


def test_run_rounds_grouped_no_key_reuse(toy):
    params, batches, loss_fn, priv = toy
    fed = _make_fed(loss_fn, priv)
    rec = _run_sanitized(fed, params, batches, max_group=2)
    assert rec.draws > 0
    assert rec.skipped == 0


def test_sanitizer_catches_deliberate_reuse():
    with pytest.raises(KeyReuseError, match="already consumed"):
        with sanitize():
            k = jax.random.PRNGKey(3)
            jax.random.normal(k, (2,))
            jax.random.laplace(k, (2,))


def test_sanitizer_catches_draw_after_split():
    with pytest.raises(KeyReuseError, match="already split"):
        with sanitize():
            k = jax.random.PRNGKey(3)
            jax.random.split(k)
            jax.random.normal(k, (2,))


def test_sanitizer_catches_double_split():
    with pytest.raises(KeyReuseError, match="already split"):
        with sanitize():
            k = jax.random.PRNGKey(3)
            jax.random.split(k)
            jax.random.split(k)


def test_sanitizer_allows_fold_in_derivation():
    with sanitize() as rec:
        k = jax.random.PRNGKey(3)
        jax.random.normal(jax.random.fold_in(k, 0), (2,))
        jax.random.normal(jax.random.fold_in(k, 1), (2,))
    assert rec.draws == 2 and rec.skipped == 0


def test_sanitizer_restores_jax_random():
    orig = jax.random.normal
    with sanitize():
        assert jax.random.normal is not orig
    assert jax.random.normal is orig
