"""Xi-enforcement (clipping) + privatized gradients for deep models."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.dp_sgd import PrivatizerConfig, clip_tree, private_grad


def _loss(params, batch):
    pred = batch["x"] @ params["w"] + params["b"]
    return jnp.mean((pred - batch["y"]) ** 2)


@pytest.fixture()
def setup(rng_key):
    k1, k2, k3 = jax.random.split(rng_key, 3)
    params = {"w": jax.random.normal(k1, (8, 4)), "b": jnp.zeros((4,))}
    batch = {"x": jax.random.normal(k2, (16, 8)),
             "y": jax.random.normal(k3, (16, 4))}
    return params, batch


def test_clip_tree_norm():
    tree = {"a": jnp.ones((10,)) * 3.0, "b": jnp.ones((5, 5)) * -2.0}
    clipped, norm = clip_tree(tree, 1.0)
    total = jnp.sqrt(sum(jnp.sum(leaf ** 2)
                         for leaf in jax.tree_util.tree_leaves(clipped)))
    assert float(total) == pytest.approx(1.0, rel=1e-5)
    assert float(norm) > 1.0
    small, _ = clip_tree(tree, 1e9)            # no-op below threshold
    assert jnp.allclose(small["a"], tree["a"])


@pytest.mark.parametrize("gran,nmb", [("example", None), ("microbatch", 4)])
def test_noiseless_matches_clipped_mean(setup, rng_key, gran, nmb):
    params, batch = setup
    cfg = PrivatizerConfig(xi=1e9, granularity=gran,
                           n_microbatches=nmb or 8)
    g, m = private_grad(_loss, params, batch, rng_key, cfg=cfg,
                        noise_scale=0.0)
    ref = jax.grad(lambda p: _loss(p, batch))(params)
    for a, b in zip(jax.tree_util.tree_leaves(g),
                    jax.tree_util.tree_leaves(ref)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)
    assert float(m["clip_frac"]) == 0.0


def test_example_clipping_binds(setup, rng_key):
    params, batch = setup
    cfg = PrivatizerConfig(xi=1e-3, granularity="example")
    g, m = private_grad(_loss, params, batch, rng_key, cfg=cfg,
                        noise_scale=0.0)
    norm = jnp.sqrt(sum(jnp.sum(leaf ** 2)
                        for leaf in jax.tree_util.tree_leaves(g)))
    assert float(norm) <= 1e-3 + 1e-6          # mean of clipped <= xi
    assert float(m["clip_frac"]) == 1.0


def test_noise_added(setup, rng_key):
    params, batch = setup
    cfg = PrivatizerConfig(xi=1e9, granularity="example")
    g1, _ = private_grad(_loss, params, batch, rng_key, cfg=cfg,
                         noise_scale=1.0)
    g0, _ = private_grad(_loss, params, batch, rng_key, cfg=cfg,
                         noise_scale=0.0)
    diff = jnp.concatenate([jnp.ravel(a - b) for a, b in zip(
        jax.tree_util.tree_leaves(g1), jax.tree_util.tree_leaves(g0))])
    assert float(jnp.std(diff)) == pytest.approx(np.sqrt(2.0), rel=0.5)


def test_gaussian_mechanism(setup, rng_key):
    params, batch = setup
    cfg = PrivatizerConfig(xi=1e9, granularity="example",
                           mechanism="gaussian")
    g, _ = private_grad(_loss, params, batch, rng_key, cfg=cfg,
                        noise_scale=2.0)
    assert all(jnp.all(jnp.isfinite(leaf))
               for leaf in jax.tree_util.tree_leaves(g))
