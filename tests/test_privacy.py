"""Theorem-1 mechanism + accounting."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.privacy import (PrivacyAccountant, capped_rounds,
                                laplace_noise, laplace_scale_theorem1)


def test_theorem1_scale_formula():
    # b = 2 Xi T / (n eps)
    assert laplace_scale_theorem1(2.0, 1000, 10_000, 1.0) == pytest.approx(0.4)
    assert laplace_scale_theorem1(1.0, 1, 1, 1.0) == pytest.approx(2.0)


def test_theorem1_scale_monotonicity():
    base = laplace_scale_theorem1(1.0, 1000, 10_000, 1.0)
    assert laplace_scale_theorem1(1.0, 2000, 10_000, 1.0) > base   # more rounds
    assert laplace_scale_theorem1(1.0, 1000, 20_000, 1.0) < base   # more data
    assert laplace_scale_theorem1(1.0, 1000, 10_000, 2.0) < base   # more budget


def test_strict_l1_slack():
    paper = laplace_scale_theorem1(1.0, 10, 100, 1.0)
    strict = laplace_scale_theorem1(1.0, 10, 100, 1.0, p=16, l1_slack="strict")
    assert strict == pytest.approx(4.0 * paper)


def test_laplace_noise_statistics(rng_key):
    x = laplace_noise(rng_key, (200_000,), scale=3.0)
    # Laplace(b): std = b*sqrt(2), mean 0
    assert abs(float(jnp.mean(x))) < 0.05
    assert float(jnp.std(x)) == pytest.approx(3.0 * np.sqrt(2), rel=0.02)


def test_accountant_paper_composition():
    acct = PrivacyAccountant({0: 1.0, 1: 2.0}, horizon=10)
    for _ in range(10):
        assert acct.record_response(0)
    assert not acct.record_response(0)          # horizon exhausted
    s = acct.summary()
    assert s[0]["spent"] == pytest.approx(1.0)  # full budget
    assert s[1]["spent"] == 0.0


def test_accountant_capped_rounds():
    # beyond-paper: cap at 2T/N responses -> per-response budget is larger,
    # so the noise scale shrinks by ~N/2
    T, N = 1000, 10
    acct = PrivacyAccountant({i: 1.0 for i in range(N)}, T,
                             composition="per_owner_rounds", n_owners=N)
    cap = capped_rounds(T, N)
    assert cap == 200
    s_paper = laplace_scale_theorem1(1.0, T, 1000, 1.0)
    s_capped = acct.scale_for(0, 1.0, 1000)
    assert s_capped == pytest.approx(s_paper * cap / T)
    for _ in range(cap):
        assert acct.record_response(0)
    assert not acct.record_response(0)
