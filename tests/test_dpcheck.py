"""dpcheck static-analyzer contract tests.

Every rule family gets a known-bad fixture (flagged with the right rule id
on the right line) and a known-good twin (clean). On top of that:

  * self-scan — src/repro/federation/ and src/repro/kernels/ must be clean
    with ZERO baseline entries (the acceptance bar for the DP engine);
  * the suppression comment and baseline workflows round-trip;
  * the deliberately-seeded key-reuse fixture is caught by BOTH halves:
    the static pass (DPC101) and the runtime sanitizer (KeyReuseError).
"""
import json
import os
import subprocess
import sys
import textwrap

import pytest

from repro.analysis.dpcheck import (RULE_DOCS, filter_new, load_baseline,
                                    run, write_baseline)

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

BAD_REUSE = textwrap.dedent("""
    import jax

    def draw(key):
        a = jax.random.normal(key, (2,))
        b = jax.random.laplace(key, (2,))
        return a + b
""")


def _scan_snippet(tmp_path, src, rel="snippet.py"):
    path = tmp_path / rel
    path.parent.mkdir(parents=True, exist_ok=True)
    path.write_text(textwrap.dedent(src))
    return run([str(path)], root=str(tmp_path))


def _rules(violations):
    return sorted({v.rule for v in violations})


# ------------------------- DPC1xx: key discipline --------------------------
def test_dpc101_double_consume(tmp_path):
    vs = _scan_snippet(tmp_path, BAD_REUSE)
    assert _rules(vs) == ["DPC101"]
    assert vs[0].line == 6          # the second sampler is the violation


def test_dpc101_good_twin_split(tmp_path):
    vs = _scan_snippet(tmp_path, """
        import jax

        def draw(key):
            ka, kb = jax.random.split(key)
            a = jax.random.normal(ka, (2,))
            b = jax.random.laplace(kb, (2,))
            return a + b
    """)
    assert vs == []


def test_dpc101_loop_invariant_key(tmp_path):
    vs = _scan_snippet(tmp_path, """
        import jax

        def draw(key, n):
            out = []
            for i in range(n):
                out.append(jax.random.normal(key, (2,)))
            return out
    """)
    assert "DPC101" in _rules(vs)


def test_dpc101_loop_fresh_key_ok(tmp_path):
    vs = _scan_snippet(tmp_path, """
        import jax

        def draw(key, n):
            out = []
            for k in jax.random.split(key, n):
                out.append(jax.random.normal(k, (2,)))
            return out
    """)
    assert vs == []


def test_dpc102_parent_used_after_split(tmp_path):
    vs = _scan_snippet(tmp_path, """
        import jax

        def draw(key):
            ks = jax.random.split(key, 3)
            return jax.random.normal(key, (2,))
    """)
    assert _rules(vs) == ["DPC102"]


def test_dpc102_rebound_parent_ok(tmp_path):
    vs = _scan_snippet(tmp_path, """
        import jax

        def draw(key):
            key, sub = jax.random.split(key)
            return jax.random.normal(key, (2,))
    """)
    assert vs == []


def test_dpc103_constant_seed_library_only(tmp_path):
    src = """
        import jax

        def setup():
            return jax.random.PRNGKey(0)
    """
    lib = _scan_snippet(tmp_path, src, rel="src/repro/thing.py")
    assert _rules(lib) == ["DPC103"]
    bench = _scan_snippet(tmp_path, src, rel="benchmarks/thing.py")
    assert bench == []


def test_dpc104_opaque_key_expression(tmp_path):
    vs = _scan_snippet(tmp_path, """
        import jax

        def draw(seed):
            return jax.random.normal(make_key(seed), (2,))
    """)
    assert _rules(vs) == ["DPC104"]


def test_dpc104_derived_key_ok(tmp_path):
    vs = _scan_snippet(tmp_path, """
        import jax

        def draw(key, i):
            return jax.random.normal(jax.random.fold_in(key, i), (2,))
    """)
    assert vs == []


def test_dpc105_double_escape(tmp_path):
    vs = _scan_snippet(tmp_path, """
        import jax

        def round(key, x):
            a = helper_one(x, key)
            b = helper_two(x, key)
            return a + b
    """)
    assert _rules(vs) == ["DPC105"]


def test_dpc105_fold_in_handoff_ok(tmp_path):
    vs = _scan_snippet(tmp_path, """
        import jax

        def round(key, x):
            a = helper_one(x, jax.random.fold_in(key, 0))
            b = helper_two(x, jax.random.fold_in(key, 1))
            return a + b
    """)
    assert vs == []


# ----------------------- DPC2xx: host-sync in scan -------------------------
_SCAN_MODULE = """
    import jax
    import jax.numpy as jnp
    from repro.federation.helpers import metric

    def make_rounds():
        def body(carry, x):
            return carry + metric(x), None

        def run(xs):
            return jax.lax.scan(body, 0.0, xs)
        return run
"""


def _write_fed(tmp_path, helper_src):
    fed = tmp_path / "src" / "repro" / "federation"
    fed.mkdir(parents=True)
    (fed / "deep.py").write_text(textwrap.dedent(_SCAN_MODULE))
    (fed / "convex.py").write_text("")
    (fed / "helpers.py").write_text(textwrap.dedent(helper_src))
    return run([str(tmp_path / "src")], root=str(tmp_path))


def test_dpc201_host_sync_reachable_from_scan(tmp_path):
    vs = _write_fed(tmp_path, """
        import numpy as np

        def metric(x):
            return float(np.asarray(x).mean())
    """)
    assert "DPC201" in _rules(vs)
    assert any(v.path.endswith("helpers.py") for v in vs)


def test_dpc201_good_twin_stays_on_device(tmp_path):
    vs = _write_fed(tmp_path, """
        import jax.numpy as jnp

        def metric(x):
            return jnp.mean(x)
    """)
    assert vs == []


def test_dpc202_branch_on_traced_value(tmp_path):
    vs = _write_fed(tmp_path, """
        import jax.numpy as jnp

        def metric(x):
            m = jnp.mean(x)
            if m > 0:
                return m
            return -m
    """)
    assert "DPC202" in _rules(vs)


def test_dpc202_static_config_branch_ok(tmp_path):
    vs = _write_fed(tmp_path, """
        import jax.numpy as jnp

        def metric(x, fused=False):
            if fused:
                return jnp.mean(x) * 2
            return jnp.mean(x)
    """)
    assert vs == []


def test_dpc204_hot_loop_element_sync(tmp_path):
    vs = _scan_snippet(tmp_path, """
        import jax

        def drive(fed, state):
            seq = jax.random.randint(jax.random.PRNGKey(0), (8,), 0, 4)
            for i in range(8):
                state = fed.step(state, int(seq[i]))
            return state
    """, rel="benchmarks/bench_x.py")
    assert "DPC204" in _rules(vs)


def test_dpc204_hoisted_good_twin(tmp_path):
    vs = _scan_snippet(tmp_path, """
        import jax
        import numpy as np

        def drive(fed, state):
            seq = np.asarray(
                jax.random.randint(jax.random.PRNGKey(0), (8,), 0, 4))
            for i in range(8):
                state = fed.step(state, int(seq[i]))
            return state
    """, rel="benchmarks/bench_x.py")
    assert vs == []


# ------------------------ DPC3xx: DP-order invariants ----------------------
def test_dpc301_noise_before_clip(tmp_path):
    vs = _scan_snippet(tmp_path, """
        import jax
        import jax.numpy as jnp

        def privatize(g, key, xi, scale):
            noisy = g + scale * jax.random.laplace(key, g.shape)
            norm = jnp.linalg.norm(noisy)
            return noisy * jnp.minimum(1.0, xi / norm)
    """)
    assert "DPC301" in _rules(vs)


def test_dpc301_clip_then_noise_ok(tmp_path):
    vs = _scan_snippet(tmp_path, """
        import jax
        import jax.numpy as jnp

        def privatize(g, key, xi, scale):
            norm = jnp.linalg.norm(g)
            g = g * jnp.minimum(1.0, xi / norm)
            return g + scale * jax.random.laplace(key, g.shape)
    """)
    assert vs == []


def test_dpc302_unmasked_bank_write(tmp_path):
    vs = _scan_snippet(tmp_path, """
        import jax.numpy as jnp

        def round(led, bank, new_i, owner_idx, theta):
            ok = led.authorized(owner_idx)
            theta = jnp.where(ok, theta, theta * 0)
            return _write_bank(bank, new_i, owner_idx)
    """)
    assert "DPC302" in _rules(vs)


def test_dpc302_masked_write_ok(tmp_path):
    vs = _scan_snippet(tmp_path, """
        import jax.numpy as jnp

        def round(led, bank, new_i, old_i, owner_idx):
            ok = led.authorized(owner_idx)
            masked = jnp.where(ok, new_i, old_i)
            return _write_bank(bank, masked, owner_idx)
    """)
    assert vs == []


def test_dpc302_fault_guard_masks_are_grant_sources(tmp_path):
    # PR 8 fault algebra: verify_row / finite_guard results and the
    # quarantine flags mask a write exactly as lawfully as .authorized
    vs = _scan_snippet(tmp_path, """
        import jax.numpy as jnp

        def round(led, fs, bank, new_i, old_i, owner_idx, corrupt):
            auth = led.authorized(owner_idx) & ~fs.quarantined[owner_idx]
            good = verify_row(fs.checksum, bank, owner_idx, corrupt)
            good = good & finite_guard(new_i)
            grant = auth & good
            masked = jnp.where(grant, new_i, old_i)
            return _write_bank(bank, masked, owner_idx)
    """)
    assert vs == []


def test_dpc302_deadline_guard_is_grant_source(tmp_path):
    # PR 10 staleness runtime: the learner-deadline mask converts an
    # answered-late round into a lawful masked write-back, so a write
    # masked by deadline_guard(...) composed into the grant is clean
    vs = _scan_snippet(tmp_path, """
        import jax.numpy as jnp

        def round(led, fs, bank, new_i, old_i, owner_idx, fcode):
            auth = led.authorized(owner_idx) & ~fs.quarantined[owner_idx]
            on_time = deadline_guard(fcode)
            grant = auth & on_time & finite_guard(new_i)
            masked = jnp.where(grant, new_i, old_i)
            return _write_bank(bank, masked, owner_idx)
    """)
    assert vs == []


def test_dpc302_homemade_deadline_mask_still_flagged(tmp_path):
    # an ad-hoc lateness comparison is NOT the guard: a write masked
    # only by it skips the TIMEOUT outcome algebra and stays flagged
    vs = _scan_snippet(tmp_path, """
        import jax.numpy as jnp

        def round(led, bank, new_i, old_i, owner_idx, lat, deadline):
            ok = led.authorized(owner_idx)
            theta = jnp.where(ok, new_i, old_i)
            on_time = lat <= deadline
            masked = jnp.where(on_time, new_i, old_i)
            return _write_bank(bank, masked, owner_idx)
    """)
    assert "DPC302" in _rules(vs)


def test_dpc302_unrelated_mask_still_flagged(tmp_path):
    # masking by a name that is NOT derived from the grant algebra does
    # not launder the write
    vs = _scan_snippet(tmp_path, """
        import jax.numpy as jnp

        def round(led, bank, new_i, old_i, owner_idx, mood):
            ok = led.authorized(owner_idx)
            theta = jnp.where(ok, new_i, old_i)
            masked = jnp.where(mood, new_i, old_i)
            return _write_bank(bank, masked, owner_idx)
    """)
    assert "DPC302" in _rules(vs)


def test_dpc302_sibling_closure_mask_does_not_vouch(tmp_path):
    # a grant mask bound inside one nested def must not vouch for a
    # write in a sibling closure of the same factory
    vs = _scan_snippet(tmp_path, """
        import jax.numpy as jnp

        def make(led, bank):
            def guarded(new_i, old_i, owner_idx):
                ok = led.authorized(owner_idx)
                ok_row = jnp.where(ok, new_i, old_i)
                return _write_bank(bank, ok_row, owner_idx)

            def sneaky(new_i, owner_idx):
                ok = led.authorized(owner_idx)
                return _write_bank(bank, new_i, owner_idx)

            return guarded, sneaky
    """)
    assert _rules(vs) == ["DPC302"]
    assert len([v for v in vs if v.rule == "DPC302"]) == 1


def test_dpc302_residency_hit_is_grant_source(tmp_path):
    # PR 9 paged bank: the HIT bit of `slot, hit = bank.lookup(i)` masks
    # a write as lawfully as .authorized — a non-resident row must be a
    # bit-exact no-op, and hit-masked writes encode exactly that
    vs = _scan_snippet(tmp_path, """
        import jax.numpy as jnp

        def round(bank, hot, new_i, old_i, owner_idx):
            slot, hit = bank.lookup(owner_idx)
            masked = jnp.where(hit, new_i, old_i)
            return _write_bank(hot, masked, slot)
    """)
    assert vs == []


def test_dpc302_residency_slot_does_not_vouch(tmp_path):
    # the slot INDEX from the same unpack must not launder an unmasked
    # write — only the hit bit is a grant source
    vs = _scan_snippet(tmp_path, """
        import jax.numpy as jnp

        def round(led, bank, hot, new_i, old_i, owner_idx):
            ok = led.authorized(owner_idx)
            slot, hit = bank.lookup(owner_idx)
            value = jnp.where(slot >= 0, new_i, old_i)
            return _write_bank(hot, value, slot)
    """)
    assert "DPC302" in _rules(vs)


# ----------------------- DPC4xx: kernel conformance ------------------------
def _kernel_tree(tmp_path, files, test_src=""):
    kd = tmp_path / "src" / "repro" / "kernels" / "mykern"
    kd.mkdir(parents=True, exist_ok=True)
    for name, src in files.items():
        (kd / name).write_text(textwrap.dedent(src))
    td = tmp_path / "tests"
    td.mkdir(exist_ok=True)
    (td / "test_k.py").write_text(test_src)
    return run([str(tmp_path / "src")], root=str(tmp_path))


def test_dpc401_missing_triple_member(tmp_path):
    vs = _kernel_tree(tmp_path, {"kernel.py": "def op_2d(x):\n    return x\n"})
    assert "DPC401" in _rules(vs)


def test_dpc403_no_oracle_test(tmp_path):
    files = {"kernel.py": "def op_2d(x):\n    return x\n",
             "ops.py": "def op_tree(t):\n    return t\n",
             "ref.py": "def op_ref(x):\n    return x\n"}
    vs = _kernel_tree(tmp_path, files, test_src="import os\n")
    assert "DPC403" in _rules(vs)
    vs = _kernel_tree(tmp_path, files,
                      test_src="from repro.kernels.mykern.ref import op_ref\n")
    assert vs == []


# -------------------------- DPC501: donation safety ------------------------
def test_dpc501_use_after_donation(tmp_path):
    vs = _scan_snippet(tmp_path, """
        import jax

        def drive(buf, x):
            g = jax.jit(update, donate_argnums=(0,))
            out = g(buf, x)
            return buf + out
    """)
    assert _rules(vs) == ["DPC501"]


def test_dpc501_rebound_state_ok(tmp_path):
    vs = _scan_snippet(tmp_path, """
        import jax

        def drive(state, xs):
            g = jax.jit(update, donate_argnums=0)
            for x in xs:
                state = g(state, x)
            return state
    """)
    assert vs == []


# ------------------- suppressions, baseline, CLI, self-scan ----------------
def test_inline_suppression(tmp_path):
    src = BAD_REUSE.replace(
        "b = jax.random.laplace(key, (2,))",
        "b = jax.random.laplace(key, (2,))  # dpcheck: ignore[DPC101]")
    assert _scan_snippet(tmp_path, src) == []


def test_suppression_wrong_rule_does_not_silence(tmp_path):
    src = BAD_REUSE.replace(
        "b = jax.random.laplace(key, (2,))",
        "b = jax.random.laplace(key, (2,))  # dpcheck: ignore[DPC999]")
    assert _rules(_scan_snippet(tmp_path, src)) == ["DPC101"]


def test_baseline_roundtrip(tmp_path):
    (tmp_path / "bad.py").write_text(BAD_REUSE)
    vs = run([str(tmp_path / "bad.py")], root=str(tmp_path))
    assert vs
    bl = tmp_path / "baseline.json"
    write_baseline(str(bl), vs)
    assert filter_new(vs, load_baseline(str(bl))) == []
    # a NEW violation still fails against the old baseline
    (tmp_path / "bad.py").write_text(
        BAD_REUSE + "\n\ndef more(key):\n"
        "    jax.random.normal(key, (2,))\n"
        "    return jax.random.normal(key, (2,))\n")
    vs2 = run([str(tmp_path / "bad.py")], root=str(tmp_path))
    assert len(filter_new(vs2, load_baseline(str(bl)))) == 1


def test_cli_json_and_exit_codes(tmp_path):
    (tmp_path / "bad.py").write_text(BAD_REUSE)
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    r = subprocess.run(
        [sys.executable, "-m", "repro.analysis.dpcheck", "bad.py",
         "--format=json"],
        cwd=str(tmp_path), env=env, capture_output=True, text=True)
    assert r.returncode == 1
    payload = json.loads(r.stdout)
    assert payload["new_count"] == 1
    assert payload["violations"][0]["rule"] == "DPC101"
    good = tmp_path / "good.py"
    good.write_text("x = 1\n")
    r = subprocess.run(
        [sys.executable, "-m", "repro.analysis.dpcheck", "good.py"],
        cwd=str(tmp_path), env=env, capture_output=True, text=True)
    assert r.returncode == 0


def test_rule_docs_cover_all_emitted_rules():
    assert {r for r in RULE_DOCS} >= {
        "DPC101", "DPC102", "DPC103", "DPC104", "DPC105",
        "DPC201", "DPC202", "DPC203", "DPC204",
        "DPC301", "DPC302", "DPC401", "DPC402", "DPC403", "DPC501"}


def test_self_scan_engine_clean_with_zero_baseline():
    """The DP engine and kernels pass with NO baseline suppressions."""
    vs = run(["src/repro/federation", "src/repro/kernels"], root=REPO)
    assert vs == [], [v.format() for v in vs]


def test_self_scan_whole_tree_clean():
    vs = run(["src", "benchmarks", "examples"], root=REPO)
    assert vs == [], [v.format() for v in vs]


def test_committed_baseline_is_empty():
    bl = os.path.join(REPO, ".dpcheck-baseline.json")
    assert load_baseline(bl) == set()


# ------------- seeded reuse caught by BOTH static and runtime --------------
def test_seeded_reuse_caught_by_both_halves(tmp_path):
    from repro.analysis.dpcheck import KeyReuseError, sanitize
    # static half
    vs = _scan_snippet(tmp_path, BAD_REUSE)
    assert _rules(vs) == ["DPC101"]
    # runtime half: execute the same snippet under the sanitizer
    ns = {}
    exec(compile(BAD_REUSE, "<fixture>", "exec"), ns)
    import jax
    with pytest.raises(KeyReuseError):
        with sanitize():
            ns["draw"](jax.random.PRNGKey(0))
