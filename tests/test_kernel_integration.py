"""Kernel <-> model integration: the Pallas flash-attention backend must
reproduce the jnp blockwise path inside full model forwards."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import build_model


@pytest.mark.parametrize("arch,window", [("yi-6b", None),
                                         ("mixtral-8x22b", 8)])
def test_pallas_attention_backend_matches_jnp(arch, window, rng_key):
    cfg = get_config(arch).reduced()
    batch = {"tokens": jax.random.randint(rng_key, (2, 32), 0, cfg.vocab)}
    batch["labels"] = batch["tokens"]

    m_jnp = build_model(cfg, remat=False, moe_mode="ragged",
                        attn_backend="jnp")
    m_pl = build_model(cfg, remat=False, moe_mode="ragged",
                       attn_backend="pallas")
    params = m_jnp.init(rng_key, jnp.float32)
    x1, _ = m_jnp.forward(params, batch, window=window)
    x2, _ = m_pl.forward(params, batch, window=window)
    np.testing.assert_allclose(np.asarray(x1), np.asarray(x2),
                               atol=2e-4, rtol=1e-3)


def test_dp_kernel_privatizer_matches_core(rng_key):
    """dp_privatize_tree (fused kernel) == core clip_tree + noiseless path."""
    from repro.core.dp_sgd import clip_tree
    from repro.kernels.dp_clip_noise.ops import dp_privatize_tree

    tree = {"w": jax.random.normal(rng_key, (64, 33)),
            "b": jax.random.normal(rng_key, (129,))}
    xi = 0.7
    fused = dp_privatize_tree(tree, rng_key, xi, 0.0, block_rows=8,
                              interpret=True)
    ref, _ = clip_tree(tree, xi)
    for a, b in zip(jax.tree_util.tree_leaves(fused),
                    jax.tree_util.tree_leaves(ref)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)


def test_ssd_kernel_inside_mamba_shapes(rng_key):
    """ssd_chunked_pallas is drop-in for models.ssm.ssd_chunked."""
    from repro.kernels.ssm_scan.ops import ssd_chunked_pallas
    from repro.models.ssm import ssd_chunked

    B, S, H, N, P = 1, 64, 2, 16, 32
    ks = jax.random.split(rng_key, 5)
    v = jax.random.normal(ks[0], (B, S, H, P))
    k = jax.random.normal(ks[1], (B, S, H, N))
    q = jax.random.normal(ks[2], (B, S, H, N))
    ld = -jax.nn.softplus(jax.random.normal(ks[3], (B, S, H)))
    g = jax.nn.sigmoid(jax.random.normal(ks[4], (B, S, H)))
    y1, h1 = ssd_chunked_pallas(v, ld, k, q, g, chunk=32, interpret=True)
    y2, h2 = ssd_chunked(v, ld, k, q, g, chunk=32)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=1e-4)
    np.testing.assert_allclose(np.asarray(h1), np.asarray(h2), atol=1e-4)
