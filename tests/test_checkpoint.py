"""Checkpoint store + crash-resume (PR 8).

Contracts under test:
  * atomic saves — `latest_step` never resumes from a temp/trash shard,
    and overwriting an existing step is torn-write safe;
  * extended dtypes (bf16, fp8) round-trip bit-exactly through the raw
    uint views;
  * every AsyncDPState variant (pytree bank, flat f32/bf16, QuantBank
    int8/fp8 with scales + EF residual, TreeNoise, FaultState) restores
    and CONTINUES `run_rounds` bit-for-bit vs an uninterrupted run;
  * reconcile-after-restore is idempotent: a subprocess that reconciles,
    checkpoints, keeps training and then dies resumes with exactly the
    uninterrupted run's accounting (no double-counted epsilon).
"""
import json
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import (latest_step, load_checkpoint, load_manifest,
                              save_checkpoint)
from repro.federation import (DataOwner, FaultPlan, FaultPolicy, Federation,
                              FederationConfig)
from repro.federation.dp_sgd import PrivatizerConfig

N_OWNERS, K = 3, 12


def loss_fn(params, batch):
    pred = batch["x"] @ params["w"] + params["b"]
    return jnp.mean((pred - batch["y"]) ** 2)


@pytest.fixture(scope="module")
def toy():
    params = {"w": jnp.zeros((6,), jnp.float32),
              "b": jnp.zeros((), jnp.float32)}
    kb = jax.random.PRNGKey(7)
    batches = {"x": jax.random.normal(kb, (K, 4, 6)),
               "y": jnp.ones((K, 4))}
    return params, batches


def _make_fed(*, fault_policy=None, pack=False, bank_dtype=None,
              mechanism="paper", tree_depth=None):
    owners = [DataOwner(n=200, epsilon=2.0, xi=1.0)] * N_OWNERS
    cfg = FederationConfig(horizon=16, sigma=1e-2, theta_max=10.0,
                           lr_scale=5.0)
    fed = Federation(owners, cfg, mechanism=mechanism,
                     tree_depth=tree_depth, fault_policy=fault_policy)
    fed.make_step(loss_fn, privatizer=PrivatizerConfig(
        xi=1.0, granularity="example"), pack_params=pack,
        bank_dtype=bank_dtype)
    return fed


def _leaves_equal(a, b):
    la = jax.tree_util.tree_leaves(a)
    lb = jax.tree_util.tree_leaves(b)
    assert len(la) == len(lb)
    return all(bool((np.asarray(x) == np.asarray(y)).all())
               for x, y in zip(la, lb))


# ----------------------------- store level ---------------------------------

def test_roundtrip_plain_pytree(tmp_path):
    state = {"a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
             "b": {"c": jnp.asarray(3, jnp.int32)}}
    save_checkpoint(str(tmp_path), 5, state)
    assert latest_step(str(tmp_path)) == 5
    back = load_checkpoint(str(tmp_path), 5, state)
    assert _leaves_equal(state, back)


def test_roundtrip_extended_dtypes(tmp_path):
    state = {"bf16": jnp.arange(8, dtype=jnp.bfloat16) / 3,
             "fp8": jnp.asarray([1.5, -2.0, 0.125, 7.0],
                                jnp.float8_e4m3fn)}
    save_checkpoint(str(tmp_path), 0, state)
    back = load_checkpoint(str(tmp_path), 0, state)
    for k in state:
        assert back[k].dtype == state[k].dtype
        assert bool((back[k].view(jnp.uint8)
                     == state[k].view(jnp.uint8)).all())


def test_extra_rides_in_manifest(tmp_path):
    extra = {"journal": {"version": 1, "spent": [1, 2, 3]}}
    save_checkpoint(str(tmp_path), 2, {"x": jnp.zeros(3)}, extra=extra)
    man = load_manifest(str(tmp_path), 2)
    assert man["extra"] == extra
    # and absent when not given
    save_checkpoint(str(tmp_path), 3, {"x": jnp.zeros(3)})
    assert "extra" not in load_manifest(str(tmp_path), 3)


def test_latest_step_ignores_temp_trash_and_foreign(tmp_path):
    save_checkpoint(str(tmp_path), 4, {"x": jnp.zeros(2)})
    # crash leftovers from the two-rename protocol + stray files
    os.makedirs(tmp_path / "_tmp_step_00000009.1234")
    os.makedirs(tmp_path / "_old_step_00000008.1234")
    os.makedirs(tmp_path / "step_garbage")
    (tmp_path / "README.txt").write_text("not a shard")
    assert latest_step(str(tmp_path)) == 4
    assert latest_step(str(tmp_path / "nope")) is None


def test_overwrite_existing_step_is_atomic(tmp_path):
    save_checkpoint(str(tmp_path), 1, {"x": jnp.zeros(4)})
    save_checkpoint(str(tmp_path), 1, {"x": jnp.ones(4)})
    back = load_checkpoint(str(tmp_path), 1, {"x": jnp.zeros(4)})
    assert bool((back["x"] == 1.0).all())
    # no temp/backup residue after a clean overwrite
    assert all(n.startswith("step_") for n in os.listdir(tmp_path))


def test_missing_leaf_and_shape_mismatch_fail_loudly(tmp_path):
    save_checkpoint(str(tmp_path), 0, {"x": jnp.zeros(4)})
    with pytest.raises(KeyError, match="missing leaf"):
        load_checkpoint(str(tmp_path), 0, {"x": jnp.zeros(4),
                                           "y": jnp.zeros(2)})
    with pytest.raises(ValueError, match="shape"):
        load_checkpoint(str(tmp_path), 0, {"x": jnp.zeros(5)})


# --------------------- session resume, every variant -----------------------

VARIANTS = [
    dict(pack=False, bank_dtype=None),                       # pytree bank
    dict(pack=True, bank_dtype=None),                        # flat f32
    dict(pack=True, bank_dtype=jnp.bfloat16),                # flat bf16
    dict(pack=True, bank_dtype="int8"),                      # QuantBank
    dict(pack=True, bank_dtype="fp8"),                       # QuantBank fp8
    dict(pack=False, bank_dtype=None, mechanism="tree",
         tree_depth=3),                                      # TreeNoise
]
IDS = ["pytree", "flat_f32", "flat_bf16", "int8", "fp8", "tree"]


@pytest.mark.parametrize("kw", VARIANTS, ids=IDS)
def test_restored_state_continues_bit_for_bit(toy, tmp_path, kw):
    params, batches = toy
    seq = jnp.asarray(np.arange(K) % N_OWNERS, jnp.int32)
    k1, k2 = jax.random.PRNGKey(31), jax.random.PRNGKey(32)
    cut = K // 2
    first = jax.tree_util.tree_map(lambda a: a[:cut], batches)
    rest = jax.tree_util.tree_map(lambda a: a[cut:], batches)
    pol = FaultPolicy(max_faults=4, window=8)
    plan = FaultPlan(drop=0.2, stale=0.1, nonfinite=0.1, corrupt=0.1)

    # uninterrupted reference
    fed_a = _make_fed(fault_policy=pol, **kw)
    s_a = fed_a.init_state(params)
    s_a, _ = fed_a.run_rounds(s_a, first, seq[:cut], k1, faults=plan)
    s_a, _ = fed_a.run_rounds(s_a, rest, seq[cut:], k2, faults=plan)
    led_a = fed_a.reconcile(s_a)

    # checkpoint at the cut, restore into a FRESH federation, continue
    fed_b = _make_fed(fault_policy=pol, **kw)
    s_b = fed_b.init_state(params)
    s_b, _ = fed_b.run_rounds(s_b, first, seq[:cut], k1, faults=plan)
    fed_b.reconcile(s_b)
    step = fed_b.save_session(str(tmp_path), s_b)
    assert latest_step(str(tmp_path)) == step

    fed_c = _make_fed(fault_policy=pol, **kw)
    s_c = fed_c.restore_session(str(tmp_path), fed_c.init_state(params))
    assert _leaves_equal(s_b, s_c)
    s_c, _ = fed_c.run_rounds(s_c, rest, seq[cut:], k2, faults=plan)

    assert _leaves_equal(s_a.theta_L, s_c.theta_L)
    assert _leaves_equal(s_a.bank, s_c.bank)
    assert _leaves_equal(s_a.faults, s_c.faults)
    if s_a.tree is not None:
        assert _leaves_equal(s_a.tree, s_c.tree)
    assert int(s_a.step) == int(s_c.step)
    assert fed_c.reconcile(s_c) == led_a


def test_restore_without_checkpoint_raises(toy, tmp_path):
    params, _ = toy
    fed = _make_fed(pack=True, bank_dtype="int8",
                    fault_policy=FaultPolicy(max_faults=4, window=8))
    with pytest.raises(FileNotFoundError, match="no checkpoint"):
        fed.restore_session(str(tmp_path / "empty"),
                            fed.init_state(params))


def test_reconcile_after_restore_is_idempotent(toy, tmp_path):
    # reconcile BEFORE saving, then reconcile again after restoring:
    # the journaled baselines mean the second fold sees zero new deltas
    params, batches = toy
    seq = jnp.asarray(np.arange(K) % N_OWNERS, jnp.int32)
    fed = _make_fed(pack=True, bank_dtype="int8",
                    fault_policy=FaultPolicy(max_faults=4, window=8))
    s = fed.init_state(params)
    s, _ = fed.run_rounds(s, batches, seq, jax.random.PRNGKey(41),
                          faults=FaultPlan(drop=0.3))
    led = fed.reconcile(s)
    fed.save_session(str(tmp_path), s)

    fed2 = _make_fed(pack=True, bank_dtype="int8",
                     fault_policy=FaultPolicy(max_faults=4, window=8))
    s2 = fed2.restore_session(str(tmp_path), fed2.init_state(params))
    assert fed2.reconcile(s2) == led
    assert fed2.reconcile(s2) == led        # idempotent: fold again


# ------------------------- subprocess crash test ---------------------------

_CHILD = textwrap.dedent("""
    import os, sys, json
    import jax, jax.numpy as jnp, numpy as np
    from repro.federation import (DataOwner, FaultPlan, FaultPolicy,
                                  Federation, FederationConfig)
    from repro.federation.dp_sgd import PrivatizerConfig

    ckpt = sys.argv[1]
    N_OWNERS, K = 3, 12

    def loss_fn(params, batch):
        pred = batch["x"] @ params["w"] + params["b"]
        return jnp.mean((pred - batch["y"]) ** 2)

    params = {"w": jnp.zeros((6,), jnp.float32),
              "b": jnp.zeros((), jnp.float32)}
    kb = jax.random.PRNGKey(7)
    batches = {"x": jax.random.normal(kb, (K, 4, 6)),
               "y": jnp.ones((K, 4))}
    owners = [DataOwner(n=200, epsilon=2.0, xi=1.0)] * N_OWNERS
    cfg = FederationConfig(horizon=16, sigma=1e-2, theta_max=10.0,
                           lr_scale=5.0)
    fed = Federation(owners, cfg, mechanism="paper",
                     fault_policy=FaultPolicy(max_faults=4, window=8))
    fed.make_step(loss_fn, privatizer=PrivatizerConfig(
        xi=1.0, granularity="example"), pack_params=True,
        bank_dtype="int8")
    seq = jnp.asarray(np.arange(K) % N_OWNERS, jnp.int32)
    cut = K // 2
    first = jax.tree_util.tree_map(lambda a: a[:cut], batches)
    rest = jax.tree_util.tree_map(lambda a: a[cut:], batches)
    s = fed.init_state(params)
    s, _ = fed.run_rounds(s, first, seq[:cut], jax.random.PRNGKey(51),
                          faults=FaultPlan(drop=0.2, stale=0.2))
    fed.reconcile(s)
    fed.save_session(ckpt, s)
    # keep training past the checkpoint, then die without saving —
    # everything after the checkpoint must be recomputed by the parent
    s, _ = fed.run_rounds(s, rest, seq[cut:], jax.random.PRNGKey(52),
                          faults=FaultPlan(drop=0.2, stale=0.2))
    os._exit(1)
""")


def test_crash_resume_matches_uninterrupted_run(toy, tmp_path):
    params, batches = toy
    ckpt = str(tmp_path / "ckpt")
    child = tmp_path / "child.py"
    child.write_text(_CHILD)
    env = dict(os.environ)
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env["PYTHONPATH"] = os.path.join(root, "src") + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else "")
    env.setdefault("JAX_PLATFORMS", "cpu")
    proc = subprocess.run([sys.executable, str(child), ckpt],
                          env=env, capture_output=True, text=True,
                          timeout=600)
    assert proc.returncode == 1, proc.stderr      # the crash, not a bug
    assert latest_step(ckpt) is not None

    seq = jnp.asarray(np.arange(K) % N_OWNERS, jnp.int32)
    cut = K // 2
    first = jax.tree_util.tree_map(lambda a: a[:cut], batches)
    rest = jax.tree_util.tree_map(lambda a: a[cut:], batches)
    pol = FaultPolicy(max_faults=4, window=8)
    plan = FaultPlan(drop=0.2, stale=0.2)

    # uninterrupted reference, same dispatch plan as the child
    fed_a = _make_fed(fault_policy=pol, pack=True, bank_dtype="int8")
    s_a = fed_a.init_state(params)
    s_a, _ = fed_a.run_rounds(s_a, first, seq[:cut],
                              jax.random.PRNGKey(51), faults=plan)
    s_a, _ = fed_a.run_rounds(s_a, rest, seq[cut:],
                              jax.random.PRNGKey(52), faults=plan)
    led_a = fed_a.reconcile(s_a)

    # resume from the child's shard and replay the post-crash chunk
    fed_b = _make_fed(fault_policy=pol, pack=True, bank_dtype="int8")
    s_b = fed_b.restore_session(ckpt, fed_b.init_state(params))
    s_b, _ = fed_b.run_rounds(s_b, rest, seq[cut:],
                              jax.random.PRNGKey(52), faults=plan)
    assert _leaves_equal(s_a.theta_L, s_b.theta_L)
    assert _leaves_equal(s_a.bank, s_b.bank)
    assert _leaves_equal(s_a.faults, s_b.faults)
    assert int(s_a.step) == int(s_b.step)
    assert fed_b.reconcile(s_b) == led_a
    # the crashed process's accounting is recovered exactly — nothing
    # double-counted, nothing lost
    assert json.dumps({str(k): v for k, v in led_a.items()},
                      sort_keys=True)
