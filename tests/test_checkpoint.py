"""Checkpoint store + crash-resume (PR 8).

Contracts under test:
  * atomic saves — `latest_step` never resumes from a temp/trash shard,
    and overwriting an existing step is torn-write safe;
  * extended dtypes (bf16, fp8) round-trip bit-exactly through the raw
    uint views;
  * every AsyncDPState variant (pytree bank, flat f32/bf16, QuantBank
    int8/fp8 with scales + EF residual, TreeNoise, FaultState) restores
    and CONTINUES `run_rounds` bit-for-bit vs an uninterrupted run;
  * reconcile-after-restore is idempotent: a subprocess that reconciles,
    checkpoints, keeps training and then dies resumes with exactly the
    uninterrupted run's accounting (no double-counted epsilon).
"""
import json
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import (latest_step, load_checkpoint, load_manifest,
                              save_checkpoint)
from repro.federation import (DataOwner, FaultPlan, FaultPolicy, Federation,
                              FederationConfig)
from repro.federation.dp_sgd import PrivatizerConfig

N_OWNERS, K = 3, 12


def loss_fn(params, batch):
    pred = batch["x"] @ params["w"] + params["b"]
    return jnp.mean((pred - batch["y"]) ** 2)


@pytest.fixture(scope="module")
def toy():
    params = {"w": jnp.zeros((6,), jnp.float32),
              "b": jnp.zeros((), jnp.float32)}
    kb = jax.random.PRNGKey(7)
    batches = {"x": jax.random.normal(kb, (K, 4, 6)),
               "y": jnp.ones((K, 4))}
    return params, batches


def _make_fed(*, fault_policy=None, pack=False, bank_dtype=None,
              mechanism="paper", tree_depth=None):
    owners = [DataOwner(n=200, epsilon=2.0, xi=1.0)] * N_OWNERS
    cfg = FederationConfig(horizon=16, sigma=1e-2, theta_max=10.0,
                           lr_scale=5.0)
    fed = Federation(owners, cfg, mechanism=mechanism,
                     tree_depth=tree_depth, fault_policy=fault_policy)
    fed.make_step(loss_fn, privatizer=PrivatizerConfig(
        xi=1.0, granularity="example"), pack_params=pack,
        bank_dtype=bank_dtype)
    return fed


def _leaves_equal(a, b):
    la = jax.tree_util.tree_leaves(a)
    lb = jax.tree_util.tree_leaves(b)
    assert len(la) == len(lb)
    return all(bool((np.asarray(x) == np.asarray(y)).all())
               for x, y in zip(la, lb))


# ----------------------------- store level ---------------------------------

def test_roundtrip_plain_pytree(tmp_path):
    state = {"a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
             "b": {"c": jnp.asarray(3, jnp.int32)}}
    save_checkpoint(str(tmp_path), 5, state)
    assert latest_step(str(tmp_path)) == 5
    back = load_checkpoint(str(tmp_path), 5, state)
    assert _leaves_equal(state, back)


def test_roundtrip_extended_dtypes(tmp_path):
    state = {"bf16": jnp.arange(8, dtype=jnp.bfloat16) / 3,
             "fp8": jnp.asarray([1.5, -2.0, 0.125, 7.0],
                                jnp.float8_e4m3fn)}
    save_checkpoint(str(tmp_path), 0, state)
    back = load_checkpoint(str(tmp_path), 0, state)
    for k in state:
        assert back[k].dtype == state[k].dtype
        assert bool((back[k].view(jnp.uint8)
                     == state[k].view(jnp.uint8)).all())


def test_extra_rides_in_manifest(tmp_path):
    extra = {"journal": {"version": 1, "spent": [1, 2, 3]}}
    save_checkpoint(str(tmp_path), 2, {"x": jnp.zeros(3)}, extra=extra)
    man = load_manifest(str(tmp_path), 2)
    assert man["extra"] == extra
    # and absent when not given
    save_checkpoint(str(tmp_path), 3, {"x": jnp.zeros(3)})
    assert "extra" not in load_manifest(str(tmp_path), 3)


def test_latest_step_ignores_temp_trash_and_foreign(tmp_path):
    save_checkpoint(str(tmp_path), 4, {"x": jnp.zeros(2)})
    # crash leftovers from the two-rename protocol + stray files
    os.makedirs(tmp_path / "_tmp_step_00000009.1234")
    os.makedirs(tmp_path / "_old_step_00000008.1234")
    os.makedirs(tmp_path / "step_garbage")
    (tmp_path / "README.txt").write_text("not a shard")
    assert latest_step(str(tmp_path)) == 4
    assert latest_step(str(tmp_path / "nope")) is None


def test_overwrite_existing_step_is_atomic(tmp_path):
    save_checkpoint(str(tmp_path), 1, {"x": jnp.zeros(4)})
    save_checkpoint(str(tmp_path), 1, {"x": jnp.ones(4)})
    back = load_checkpoint(str(tmp_path), 1, {"x": jnp.zeros(4)})
    assert bool((back["x"] == 1.0).all())
    # no temp/backup residue after a clean overwrite
    assert all(n.startswith("step_") for n in os.listdir(tmp_path))


def test_missing_leaf_and_shape_mismatch_fail_loudly(tmp_path):
    save_checkpoint(str(tmp_path), 0, {"x": jnp.zeros(4)})
    with pytest.raises(KeyError, match="missing leaf"):
        load_checkpoint(str(tmp_path), 0, {"x": jnp.zeros(4),
                                           "y": jnp.zeros(2)})
    with pytest.raises(ValueError, match="shape"):
        load_checkpoint(str(tmp_path), 0, {"x": jnp.zeros(5)})


# --------------------- session resume, every variant -----------------------

VARIANTS = [
    dict(pack=False, bank_dtype=None),                       # pytree bank
    dict(pack=True, bank_dtype=None),                        # flat f32
    dict(pack=True, bank_dtype=jnp.bfloat16),                # flat bf16
    dict(pack=True, bank_dtype="int8"),                      # QuantBank
    dict(pack=True, bank_dtype="fp8"),                       # QuantBank fp8
    dict(pack=False, bank_dtype=None, mechanism="tree",
         tree_depth=3),                                      # TreeNoise
]
IDS = ["pytree", "flat_f32", "flat_bf16", "int8", "fp8", "tree"]


@pytest.mark.parametrize("kw", VARIANTS, ids=IDS)
def test_restored_state_continues_bit_for_bit(toy, tmp_path, kw):
    params, batches = toy
    seq = jnp.asarray(np.arange(K) % N_OWNERS, jnp.int32)
    k1, k2 = jax.random.PRNGKey(31), jax.random.PRNGKey(32)
    cut = K // 2
    first = jax.tree_util.tree_map(lambda a: a[:cut], batches)
    rest = jax.tree_util.tree_map(lambda a: a[cut:], batches)
    pol = FaultPolicy(max_faults=4, window=8)
    plan = FaultPlan(drop=0.2, stale=0.1, nonfinite=0.1, corrupt=0.1)

    # uninterrupted reference
    fed_a = _make_fed(fault_policy=pol, **kw)
    s_a = fed_a.init_state(params)
    s_a, _ = fed_a.run_rounds(s_a, first, seq[:cut], k1, faults=plan)
    s_a, _ = fed_a.run_rounds(s_a, rest, seq[cut:], k2, faults=plan)
    led_a = fed_a.reconcile(s_a)

    # checkpoint at the cut, restore into a FRESH federation, continue
    fed_b = _make_fed(fault_policy=pol, **kw)
    s_b = fed_b.init_state(params)
    s_b, _ = fed_b.run_rounds(s_b, first, seq[:cut], k1, faults=plan)
    fed_b.reconcile(s_b)
    step = fed_b.save_session(str(tmp_path), s_b)
    assert latest_step(str(tmp_path)) == step

    fed_c = _make_fed(fault_policy=pol, **kw)
    s_c = fed_c.restore_session(str(tmp_path), fed_c.init_state(params))
    assert _leaves_equal(s_b, s_c)
    s_c, _ = fed_c.run_rounds(s_c, rest, seq[cut:], k2, faults=plan)

    assert _leaves_equal(s_a.theta_L, s_c.theta_L)
    assert _leaves_equal(s_a.bank, s_c.bank)
    assert _leaves_equal(s_a.faults, s_c.faults)
    if s_a.tree is not None:
        assert _leaves_equal(s_a.tree, s_c.tree)
    assert int(s_a.step) == int(s_c.step)
    assert fed_c.reconcile(s_c) == led_a


def test_restore_without_checkpoint_raises(toy, tmp_path):
    params, _ = toy
    fed = _make_fed(pack=True, bank_dtype="int8",
                    fault_policy=FaultPolicy(max_faults=4, window=8))
    with pytest.raises(FileNotFoundError, match="no checkpoint"):
        fed.restore_session(str(tmp_path / "empty"),
                            fed.init_state(params))


def test_reconcile_after_restore_is_idempotent(toy, tmp_path):
    # reconcile BEFORE saving, then reconcile again after restoring:
    # the journaled baselines mean the second fold sees zero new deltas
    params, batches = toy
    seq = jnp.asarray(np.arange(K) % N_OWNERS, jnp.int32)
    fed = _make_fed(pack=True, bank_dtype="int8",
                    fault_policy=FaultPolicy(max_faults=4, window=8))
    s = fed.init_state(params)
    s, _ = fed.run_rounds(s, batches, seq, jax.random.PRNGKey(41),
                          faults=FaultPlan(drop=0.3))
    led = fed.reconcile(s)
    fed.save_session(str(tmp_path), s)

    fed2 = _make_fed(pack=True, bank_dtype="int8",
                     fault_policy=FaultPolicy(max_faults=4, window=8))
    s2 = fed2.restore_session(str(tmp_path), fed2.init_state(params))
    assert fed2.reconcile(s2) == led
    assert fed2.reconcile(s2) == led        # idempotent: fold again


# ------------------------- subprocess crash test ---------------------------

_CHILD = textwrap.dedent("""
    import os, sys, json
    import jax, jax.numpy as jnp, numpy as np
    from repro.federation import (DataOwner, FaultPlan, FaultPolicy,
                                  Federation, FederationConfig)
    from repro.federation.dp_sgd import PrivatizerConfig

    ckpt = sys.argv[1]
    N_OWNERS, K = 3, 12

    def loss_fn(params, batch):
        pred = batch["x"] @ params["w"] + params["b"]
        return jnp.mean((pred - batch["y"]) ** 2)

    params = {"w": jnp.zeros((6,), jnp.float32),
              "b": jnp.zeros((), jnp.float32)}
    kb = jax.random.PRNGKey(7)
    batches = {"x": jax.random.normal(kb, (K, 4, 6)),
               "y": jnp.ones((K, 4))}
    owners = [DataOwner(n=200, epsilon=2.0, xi=1.0)] * N_OWNERS
    cfg = FederationConfig(horizon=16, sigma=1e-2, theta_max=10.0,
                           lr_scale=5.0)
    fed = Federation(owners, cfg, mechanism="paper",
                     fault_policy=FaultPolicy(max_faults=4, window=8))
    fed.make_step(loss_fn, privatizer=PrivatizerConfig(
        xi=1.0, granularity="example"), pack_params=True,
        bank_dtype="int8")
    seq = jnp.asarray(np.arange(K) % N_OWNERS, jnp.int32)
    cut = K // 2
    first = jax.tree_util.tree_map(lambda a: a[:cut], batches)
    rest = jax.tree_util.tree_map(lambda a: a[cut:], batches)
    s = fed.init_state(params)
    s, _ = fed.run_rounds(s, first, seq[:cut], jax.random.PRNGKey(51),
                          faults=FaultPlan(drop=0.2, stale=0.2))
    fed.reconcile(s)
    fed.save_session(ckpt, s)
    # keep training past the checkpoint, then die without saving —
    # everything after the checkpoint must be recomputed by the parent
    s, _ = fed.run_rounds(s, rest, seq[cut:], jax.random.PRNGKey(52),
                          faults=FaultPlan(drop=0.2, stale=0.2))
    os._exit(1)
""")


def test_crash_resume_matches_uninterrupted_run(toy, tmp_path):
    params, batches = toy
    ckpt = str(tmp_path / "ckpt")
    child = tmp_path / "child.py"
    child.write_text(_CHILD)
    env = dict(os.environ)
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env["PYTHONPATH"] = os.path.join(root, "src") + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else "")
    env.setdefault("JAX_PLATFORMS", "cpu")
    proc = subprocess.run([sys.executable, str(child), ckpt],
                          env=env, capture_output=True, text=True,
                          timeout=600)
    assert proc.returncode == 1, proc.stderr      # the crash, not a bug
    assert latest_step(ckpt) is not None

    seq = jnp.asarray(np.arange(K) % N_OWNERS, jnp.int32)
    cut = K // 2
    first = jax.tree_util.tree_map(lambda a: a[:cut], batches)
    rest = jax.tree_util.tree_map(lambda a: a[cut:], batches)
    pol = FaultPolicy(max_faults=4, window=8)
    plan = FaultPlan(drop=0.2, stale=0.2)

    # uninterrupted reference, same dispatch plan as the child
    fed_a = _make_fed(fault_policy=pol, pack=True, bank_dtype="int8")
    s_a = fed_a.init_state(params)
    s_a, _ = fed_a.run_rounds(s_a, first, seq[:cut],
                              jax.random.PRNGKey(51), faults=plan)
    s_a, _ = fed_a.run_rounds(s_a, rest, seq[cut:],
                              jax.random.PRNGKey(52), faults=plan)
    led_a = fed_a.reconcile(s_a)

    # resume from the child's shard and replay the post-crash chunk
    fed_b = _make_fed(fault_policy=pol, pack=True, bank_dtype="int8")
    s_b = fed_b.restore_session(ckpt, fed_b.init_state(params))
    s_b, _ = fed_b.run_rounds(s_b, rest, seq[cut:],
                              jax.random.PRNGKey(52), faults=plan)
    assert _leaves_equal(s_a.theta_L, s_b.theta_L)
    assert _leaves_equal(s_a.bank, s_b.bank)
    assert _leaves_equal(s_a.faults, s_b.faults)
    assert int(s_a.step) == int(s_b.step)
    assert fed_b.reconcile(s_b) == led_a
    # the crashed process's accounting is recovered exactly — nothing
    # double-counted, nothing lost
    assert json.dumps({str(k): v for k, v in led_a.items()},
                      sort_keys=True)


# ------------------- paged cold tier rides the shard (PR 10) ----------------

def test_aux_arrays_roundtrip(tmp_path):
    from repro.checkpoint import load_aux_arrays
    aux = {"cold/codes/ids": np.asarray([0, 2], np.int64),
           "cold/codes/rows": np.arange(14, dtype=np.int8).reshape(2, 7),
           "bf16": np.arange(6, dtype=np.float32).astype(jnp.bfloat16)}
    save_checkpoint(str(tmp_path), 3, {"x": jnp.zeros(2)}, aux_arrays=aux)
    back = load_aux_arrays(str(tmp_path), 3)
    assert sorted(back) == sorted(aux)
    for k in aux:
        assert back[k].dtype == np.asarray(aux[k]).dtype
        assert bool((back[k].view(np.uint8)
                     == np.asarray(aux[k]).view(np.uint8)).all())
    # the state pytree itself is unpolluted by aux entries
    st = load_checkpoint(str(tmp_path), 3, {"x": jnp.zeros(2)})
    assert list(st) == ["x"]
    # and a plain checkpoint has no aux payload
    save_checkpoint(str(tmp_path), 4, {"x": jnp.zeros(2)})
    assert load_aux_arrays(str(tmp_path), 4) == {}


# each dispatch chunk touches <= 2 distinct owners so an n_hot=2 hot
# tier pages: rows evict to the cold store between chunks
_CHUNKS = ([0, 1, 0], [1, 2, 2], [0, 0, 1], [2, 1, 2])


def _chunked(batches):
    n = len(_CHUNKS[0])
    return [(jnp.asarray(c, jnp.int32),
             jax.tree_util.tree_map(lambda a, lo=n * i: a[lo:lo + n],
                                    batches),
             jax.random.PRNGKey(60 + i))
            for i, c in enumerate(_CHUNKS)]


def test_paged_restored_state_continues_bit_for_bit(toy, tmp_path):
    # n_hot < N so the cold tier actually holds evicted rows at the cut
    params, batches = toy
    chunks = _chunked(batches)
    pol = FaultPolicy(max_faults=4, window=8)
    plan = FaultPlan(drop=0.2, stale=0.1, nonfinite=0.1, corrupt=0.1)

    fed_a = _make_fed(fault_policy=pol, pack=True, bank_dtype="int8")
    s_a = fed_a.init_paged_state(params, n_hot=2, bank_dtype="int8")
    for seq, b, k in chunks:
        s_a, _ = fed_a.run_rounds(s_a, b, seq, k, faults=plan)
    led_a = fed_a.reconcile(s_a)

    fed_b = _make_fed(fault_policy=pol, pack=True, bank_dtype="int8")
    s_b = fed_b.init_paged_state(params, n_hot=2, bank_dtype="int8")
    for seq, b, k in chunks[:2]:
        s_b, _ = fed_b.run_rounds(s_b, b, seq, k, faults=plan)
    fed_b.reconcile(s_b)
    step = fed_b.save_session(str(tmp_path), s_b)
    assert latest_step(str(tmp_path)) == step

    # fresh session: page in, restore, continue
    fed_c = _make_fed(fault_policy=pol, pack=True, bank_dtype="int8")
    s_c = fed_c.restore_session(
        str(tmp_path), fed_c.init_paged_state(params, n_hot=2,
                                              bank_dtype="int8"))
    assert _leaves_equal(s_b, s_c)
    for seq, b, k in chunks[2:]:
        s_c, _ = fed_c.run_rounds(s_c, b, seq, k, faults=plan)
    assert _leaves_equal(s_a.theta_L, s_c.theta_L)
    assert _leaves_equal(s_a.faults, s_c.faults)
    assert int(s_a.step) == int(s_c.step)
    assert fed_c.reconcile(s_c) == led_a
    # cold tiers agree row-by-row after a full flush on both sides
    fed_a.pager.flush(s_a, only_dirty=False)
    fed_c.pager.flush(s_c, only_dirty=False)
    for name, store in fed_a.pager.stores.items():
        ids = store.written_ids
        other = fed_c.pager.stores[name]
        assert bool((ids == other.written_ids).all())
        assert bool((store.read_rows(ids).view(np.uint8)
                     == other.read_rows(ids).view(np.uint8)).all())

    # restore-into-used-session: fed_b trained PAST the save (its cold
    # store now holds newer rows); restoring must wipe them and rewind
    for seq, b, _ in chunks[2:]:
        s_b, _ = fed_b.run_rounds(s_b, b, seq, jax.random.PRNGKey(99),
                                  faults=plan)
    s_b2 = fed_b.restore_session(str(tmp_path), s_b)
    for seq, b, k in chunks[2:]:
        s_b2, _ = fed_b.run_rounds(s_b2, b, seq, k, faults=plan)
    assert _leaves_equal(s_a.theta_L, s_b2.theta_L)
    assert fed_b.reconcile(s_b2) == led_a


def test_paged_restore_error_paths(toy, tmp_path):
    params, batches = toy
    seq = jnp.asarray(np.arange(K) % N_OWNERS, jnp.int32)
    pol = FaultPolicy(max_faults=4, window=8)

    fed = _make_fed(fault_policy=pol, pack=True, bank_dtype="int8")
    s = fed.init_paged_state(params, n_hot=N_OWNERS, bank_dtype="int8")
    s, _ = fed.run_rounds(s, batches, seq, jax.random.PRNGKey(31))
    fed.save_session(str(tmp_path / "paged"), s)

    # paged checkpoint into a session that never paged in
    flat = _make_fed(fault_policy=pol, pack=True, bank_dtype="int8")
    with pytest.raises(ValueError, match="init_paged_state"):
        flat.restore_session(str(tmp_path / "paged"),
                             flat.init_state(params))

    # non-paged checkpoint into a paged session
    flat2 = _make_fed(fault_policy=pol, pack=True, bank_dtype="int8")
    s2 = flat2.init_state(params)
    s2, _ = flat2.run_rounds(s2, batches, seq, jax.random.PRNGKey(31))
    flat2.save_session(str(tmp_path / "flat"), s2)
    paged = _make_fed(fault_policy=pol, pack=True, bank_dtype="int8")
    with pytest.raises(ValueError, match="no cold-tier snapshot"):
        paged.restore_session(
            str(tmp_path / "flat"),
            paged.init_paged_state(params, n_hot=N_OWNERS,
                                   bank_dtype="int8"))

    # codec mismatch: the cold stores disagree
    other = _make_fed(fault_policy=pol, pack=True, bank_dtype="fp8")
    with pytest.raises(ValueError, match="stores"):
        other.restore_session(
            str(tmp_path / "paged"),
            other.init_paged_state(params, n_hot=N_OWNERS,
                                   bank_dtype="fp8"))


_PAGED_CHILD = textwrap.dedent("""
    import os, sys
    import jax, jax.numpy as jnp, numpy as np
    from repro.federation import (DataOwner, FaultPlan, FaultPolicy,
                                  Federation, FederationConfig, LatencyPlan,
                                  StalenessPolicy)
    from repro.federation.dp_sgd import PrivatizerConfig

    ckpt = sys.argv[1]
    N_OWNERS, K = 3, 12

    def loss_fn(params, batch):
        pred = batch["x"] @ params["w"] + params["b"]
        return jnp.mean((pred - batch["y"]) ** 2)

    params = {"w": jnp.zeros((6,), jnp.float32),
              "b": jnp.zeros((), jnp.float32)}
    kb = jax.random.PRNGKey(7)
    batches = {"x": jax.random.normal(kb, (K, 4, 6)),
               "y": jnp.ones((K, 4))}
    owners = [DataOwner(n=200, epsilon=2.0, xi=1.0)] * N_OWNERS
    cfg = FederationConfig(horizon=16, sigma=1e-2, theta_max=10.0,
                           lr_scale=5.0)
    fed = Federation(owners, cfg, mechanism="paper",
                     fault_policy=FaultPolicy(max_faults=4, window=8),
                     staleness=StalenessPolicy(deadline=1.0, max_retries=2,
                                               decay=0.9))
    fed.make_step(loss_fn, privatizer=PrivatizerConfig(
        xi=1.0, granularity="example"), pack_params=True,
        bank_dtype="int8")
    # chunks touch <= 2 owners so the n_hot=2 hot tier actually pages
    CHUNKS = ([0, 1, 0], [1, 2, 2], [0, 0, 1], [2, 1, 2])
    chunks = [(jnp.asarray(c, jnp.int32),
               jax.tree_util.tree_map(lambda a, lo=3 * i: a[lo:lo + 3],
                                      batches),
               jax.random.PRNGKey(60 + i))
              for i, c in enumerate(CHUNKS)]
    plan = FaultPlan(drop=0.2, stale=0.2)
    lat = LatencyPlan(base=(0.2, 2.0, 0.2), jitter=0.5)
    s = fed.init_paged_state(params, n_hot=2, bank_dtype="int8")
    for seq, b, k in chunks[:2]:
        s, _ = fed.run_rounds(s, b, seq, k, faults=plan, latency=lat)
    fed.reconcile(s)
    fed.save_session(ckpt, s)
    # keep training past the checkpoint, then die without saving
    for seq, b, k in chunks[2:]:
        s, _ = fed.run_rounds(s, b, seq, k, faults=plan, latency=lat)
    os._exit(1)
""")


def test_paged_crash_resume_matches_uninterrupted_run(toy, tmp_path):
    from repro.federation import LatencyPlan, StalenessPolicy
    params, batches = toy
    ckpt = str(tmp_path / "ckpt")
    child = tmp_path / "child.py"
    child.write_text(_PAGED_CHILD)
    env = dict(os.environ)
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env["PYTHONPATH"] = os.path.join(root, "src") + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else "")
    env.setdefault("JAX_PLATFORMS", "cpu")
    proc = subprocess.run([sys.executable, str(child), ckpt],
                          env=env, capture_output=True, text=True,
                          timeout=600)
    assert proc.returncode == 1, proc.stderr      # the crash, not a bug
    assert latest_step(ckpt) is not None

    chunks = _chunked(batches)
    pol = FaultPolicy(max_faults=4, window=8)
    plan = FaultPlan(drop=0.2, stale=0.2)
    spol = StalenessPolicy(deadline=1.0, max_retries=2, decay=0.9)
    lat = LatencyPlan(base=(0.2, 2.0, 0.2), jitter=0.5)

    def make():
        owners = [DataOwner(n=200, epsilon=2.0, xi=1.0)] * N_OWNERS
        cfg = FederationConfig(horizon=16, sigma=1e-2, theta_max=10.0,
                               lr_scale=5.0)
        fed = Federation(owners, cfg, mechanism="paper", fault_policy=pol,
                         staleness=spol)
        fed.make_step(loss_fn, privatizer=PrivatizerConfig(
            xi=1.0, granularity="example"), pack_params=True,
            bank_dtype="int8")
        return fed

    # uninterrupted reference, same dispatch plan as the child
    fed_a = make()
    s_a = fed_a.init_paged_state(params, n_hot=2, bank_dtype="int8")
    for seq, b, k in chunks:
        s_a, _ = fed_a.run_rounds(s_a, b, seq, k, faults=plan,
                                  latency=lat)
    led_a = fed_a.reconcile(s_a)

    # resume from the crashed child's shard, replay the post-crash chunk
    fed_b = make()
    s_b = fed_b.restore_session(
        ckpt, fed_b.init_paged_state(params, n_hot=2, bank_dtype="int8"))
    for seq, b, k in chunks[2:]:
        s_b, _ = fed_b.run_rounds(s_b, b, seq, k, faults=plan,
                                  latency=lat)
    assert _leaves_equal(s_a.theta_L, s_b.theta_L)
    assert _leaves_equal(s_a.bank, s_b.bank)
    assert _leaves_equal(s_a.faults, s_b.faults)
    assert _leaves_equal(s_a.stale, s_b.stale)
    assert int(s_a.step) == int(s_b.step)
    # epsilon recovered exactly: nothing double-counted, nothing lost
    assert fed_b.reconcile(s_b) == led_a
