"""Fault layer (PR 8): in-graph injection, guards, quarantine, parity.

The contracts under test:
  * zero-fault FaultPlan reproduces the fault-OFF engine bit-for-bit
    (every codec);
  * under a fixed fault key, step loop == fused scan == grouped driver
    produce bit-identical params/bank/ledger/tree/fault state;
  * a faulted round leaves bank rows, scales, EF residual and tree nodes
    bit-exactly untouched;
  * epsilon is charged at response time (DROP spends nothing, a
    guard-rejected answer spends);
  * owners exceeding the FaultPolicy budget are quarantined in-graph.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.federation import (CORRUPT_PAYLOAD, DROP, NONFINITE_GRAD, OK,
                              STALE, DataOwner, FaultPlan, FaultPolicy,
                              Federation, FederationConfig, QuantBank,
                              as_fault_codes, bank_checksums)
from repro.federation import faults as faults_mod
from repro.federation.dp_sgd import PrivatizerConfig
from repro.federation.schedules import AvailabilityTraceSchedule

N_OWNERS, K = 3, 12
CODECS = [None, jnp.bfloat16, "int8", "fp8"]


@pytest.fixture(scope="module")
def toy():
    def loss_fn(params, batch):
        pred = batch["x"] @ params["w"] + params["b"]
        return jnp.mean((pred - batch["y"]) ** 2)

    params = {"w": jnp.zeros((6,), jnp.float32), "b": jnp.zeros((), jnp.float32)}
    kb = jax.random.PRNGKey(7)
    batches = {"x": jax.random.normal(kb, (K, 4, 6)),
               "y": jnp.ones((K, 4))}
    return loss_fn, params, batches


def _make_fed(loss_fn, *, fault_policy=None, pack=False, bank_dtype=None,
              mechanism="paper", tree_depth=None, horizon=16):
    owners = [DataOwner(n=200, epsilon=2.0, xi=1.0)] * N_OWNERS
    cfg = FederationConfig(horizon=horizon, sigma=1e-2, theta_max=10.0,
                           lr_scale=5.0)
    fed = Federation(owners, cfg, mechanism=mechanism,
                     tree_depth=tree_depth, fault_policy=fault_policy)
    fed.make_step(loss_fn, privatizer=PrivatizerConfig(
        xi=1.0, granularity="example"), pack_params=pack,
        bank_dtype=bank_dtype)
    return fed


def _round_robin():
    return jnp.asarray(np.arange(K) % N_OWNERS, jnp.int32)


def _leaves_equal(a, b):
    la = jax.tree_util.tree_leaves(a)
    lb = jax.tree_util.tree_leaves(b)
    assert len(la) == len(lb)
    return all(bool((np.asarray(x) == np.asarray(y)).all())
               for x, y in zip(la, lb))


PLAN = FaultPlan(drop=0.2, stale=0.1, nonfinite=0.2, corrupt=0.2)
POLICY = FaultPolicy(max_faults=2, window=8)


# ------------------------- zero-fault parity -------------------------------

@pytest.mark.parametrize("bank_dtype", CODECS)
def test_zero_fault_plan_matches_fault_off_engine(toy, bank_dtype):
    loss_fn, params, batches = toy
    key = jax.random.PRNGKey(3)
    seq = _round_robin()
    pack = bank_dtype is not None

    fed_off = _make_fed(loss_fn, pack=pack, bank_dtype=bank_dtype)
    s_off = fed_off.init_state(params)
    s_off, m_off = fed_off.run_rounds(s_off, batches, seq, key)

    fed_on = _make_fed(loss_fn, fault_policy=POLICY, pack=pack,
                       bank_dtype=bank_dtype)
    s_on = fed_on.init_state(params)
    s_on, m_on = fed_on.run_rounds(s_on, batches, seq, key,
                                   faults=FaultPlan())

    assert _leaves_equal(s_off.theta_L, s_on.theta_L)
    assert _leaves_equal(s_off.bank, s_on.bank)
    assert int(s_off.step) == int(s_on.step)
    assert not bool(np.asarray(m_on["faulted"]).any())
    assert fed_off.reconcile(s_off) == fed_on.reconcile(s_on)


# ------------------ three-driver equivalence with faults -------------------

@pytest.mark.parametrize("bank_dtype", CODECS)
def test_drivers_bit_identical_under_faults(toy, bank_dtype):
    loss_fn, params, batches = toy
    key = jax.random.PRNGKey(5)
    seq = _round_robin()
    pack = bank_dtype is not None

    # fused scan
    fed_f = _make_fed(loss_fn, fault_policy=POLICY, pack=pack,
                      bank_dtype=bank_dtype)
    s_f = fed_f.init_state(params)
    s_f, m_f = fed_f.run_rounds(s_f, batches, seq, key, faults=PLAN)
    led_f = fed_f.reconcile(s_f)

    # per-round step loop under the same codes + keys
    codes = PLAN.draw(key, K)
    keys = jax.random.split(key, K)
    fed_l = _make_fed(loss_fn, fault_policy=POLICY, pack=pack,
                      bank_dtype=bank_dtype)
    s_l = fed_l.init_state(params)
    for k in range(K):
        b = jax.tree_util.tree_map(lambda a: a[k], batches)
        s_l, _ = fed_l.step(s_l, b, int(seq[k]), keys[k],
                            fault_code=int(codes[k]))

    # grouped driver (round-robin -> real multi-member groups)
    fed_g = _make_fed(loss_fn, fault_policy=POLICY, pack=pack,
                      bank_dtype=bank_dtype)
    s_g = fed_g.init_state(params)
    s_g, m_g = fed_g.run_rounds(s_g, batches, seq, key, faults=PLAN,
                                owner_parallel=True, max_group=N_OWNERS)

    for other in (s_l, s_g):
        assert _leaves_equal(s_f.theta_L, other.theta_L)
        assert _leaves_equal(s_f.bank, other.bank)
        assert _leaves_equal(s_f.faults, other.faults)
        assert int(s_f.step) == int(other.step)
    assert led_f == fed_l.ledger()
    assert led_f == fed_g.reconcile(s_g)
    for name in ("faulted", "dropped", "quarantined", "refused"):
        assert bool((np.asarray(m_f[name]) == np.asarray(m_g[name])).all())


def test_drivers_bit_identical_under_faults_tree_mechanism(toy):
    loss_fn, params, batches = toy
    key = jax.random.PRNGKey(9)
    seq = _round_robin()

    fed_f = _make_fed(loss_fn, fault_policy=POLICY, mechanism="tree",
                      tree_depth=4)
    s_f = fed_f.init_state(params)
    s_f, _ = fed_f.run_rounds(s_f, batches, seq, key, faults=PLAN)

    codes = PLAN.draw(key, K)
    keys = jax.random.split(key, K)
    fed_l = _make_fed(loss_fn, fault_policy=POLICY, mechanism="tree",
                      tree_depth=4)
    s_l = fed_l.init_state(params)
    for k in range(K):
        b = jax.tree_util.tree_map(lambda a: a[k], batches)
        s_l, _ = fed_l.step(s_l, b, int(seq[k]), keys[k],
                            fault_code=int(codes[k]))

    assert _leaves_equal(s_f.theta_L, s_l.theta_L)
    assert _leaves_equal(s_f.tree.nodes, s_l.tree.nodes)
    assert bool((np.asarray(s_f.tree.counts)
                 == np.asarray(s_l.tree.counts)).all())
    assert _leaves_equal(s_f.faults, s_l.faults)
    assert fed_f.reconcile(s_f) == fed_l.ledger()


# ---------------------- faulted rounds are no-ops --------------------------

@pytest.mark.parametrize("code", [DROP, STALE, NONFINITE_GRAD,
                                  CORRUPT_PAYLOAD])
@pytest.mark.parametrize("bank_dtype", CODECS)
def test_faulted_round_leaves_owner_state_untouched(toy, bank_dtype, code):
    loss_fn, params, batches = toy
    key = jax.random.PRNGKey(11)
    seq = _round_robin()
    pack = bank_dtype is not None
    cut = 4

    # lenient policy: every fault ticks the window, so a strict one would
    # quarantine mid-dispatch and relabel the later rounds
    fed = _make_fed(loss_fn, fault_policy=FaultPolicy(max_faults=99,
                                                      window=8),
                    pack=pack, bank_dtype=bank_dtype,
                    mechanism="tree" if not pack else "paper",
                    tree_depth=3 if not pack else None)
    s = fed.init_state(params)
    part = jax.tree_util.tree_map(lambda a: a[:cut], batches)
    s, _ = fed.run_rounds(s, part, seq[:cut], key)   # warm the bank

    # one all-faulted dispatch: every round must be a bit-exact no-op on
    # bank rows, scales, EF residual, tree nodes and the checksums
    rest = jax.tree_util.tree_map(lambda a: a[cut:], batches)
    codes = jnp.full((K - cut,), code, jnp.int8)
    before_bank = jax.tree_util.tree_map(jnp.copy, s.bank)
    before_tree = None if s.tree is None else jax.tree_util.tree_map(
        jnp.copy, s.tree)
    s2, m = fed.run_rounds(s, rest, seq[cut:], jax.random.PRNGKey(12),
                           faults=codes)
    assert _leaves_equal(before_bank, s2.bank)
    if before_tree is not None:
        assert _leaves_equal(before_tree, s2.tree)
    assert _leaves_equal(s.theta_L, s2.theta_L)
    assert int(s.step) == int(s2.step)
    assert bool((np.asarray(s.faults.checksum)
                 == np.asarray(s2.faults.checksum)).all())
    if code == DROP:
        assert bool(np.asarray(m["dropped"]).all())
    else:
        assert bool(np.asarray(m["faulted"]).all())


def test_epsilon_charged_at_response_time(toy):
    loss_fn, params, batches = toy
    seq = _round_robin()
    fed = _make_fed(loss_fn, fault_policy=FaultPolicy(max_faults=99,
                                                      window=8))
    s = fed.init_state(params)
    codes = jnp.asarray([DROP, STALE, OK] * (K // 3), jnp.int8)
    s, _ = fed.run_rounds(s, batches, seq, jax.random.PRNGKey(13),
                          faults=codes)
    led = fed.reconcile(s)
    per = K // N_OWNERS
    # the code cycle aligns with the round-robin: owner 0 always DROPs
    # (query never answered -> no eps), owner 1 is always STALE (answered
    # then guard-rejected -> eps IS spent), owner 2 always answers OK
    assert led[0]["dropped"] == per and led[0]["responses"] == 0
    assert led[1]["faulted"] == per and led[1]["responses"] == per
    assert led[2]["responses"] == per
    assert led[2]["dropped"] == 0 and led[2]["faulted"] == 0


# ------------------------------ quarantine ---------------------------------

def test_owner_quarantined_after_fault_budget(toy):
    loss_fn, params, batches = toy
    fed = _make_fed(loss_fn, fault_policy=FaultPolicy(max_faults=2,
                                                      window=16))
    s = fed.init_state(params)
    seq = jnp.zeros((K,), jnp.int32)          # hammer owner 0
    codes = jnp.full((K,), STALE, jnp.int8)
    s, m = fed.run_rounds(s, batches, seq, jax.random.PRNGKey(14),
                          faults=codes)
    assert bool(s.faults.quarantined[0])
    assert not bool(np.asarray(s.faults.quarantined[1:]).any())
    q = np.asarray(m["quarantined"])
    # two faults trip the policy; every later round is masked out
    assert not q[:2].any() and q[2:].all()
    led = fed.reconcile(s)
    assert led[0]["faulted"] == 2
    assert led[0]["quarantined"] == K - 2
    assert led[0]["responses"] == 2            # eps spent on the 2 answers
    # healthy owners keep training after the quarantine
    s2, m2 = fed.run_rounds(s, batches, jnp.ones((K,), jnp.int32),
                            jax.random.PRNGKey(15), faults=FaultPlan())
    assert not bool(np.asarray(m2["quarantined"]).any())
    assert int(s2.step) - int(s.step) == K


def test_genuine_bank_corruption_is_detected(toy):
    loss_fn, params, batches = toy
    fed = _make_fed(loss_fn, fault_policy=POLICY, pack=True,
                    bank_dtype="int8")
    s = fed.init_state(params)
    cut = 4
    part = jax.tree_util.tree_map(lambda a: a[:cut], batches)
    s, _ = fed.run_rounds(s, part, _round_robin()[:cut],
                          jax.random.PRNGKey(16))
    # flip one bit of owner 1's resident codes OUT-OF-BAND (rot, torn
    # write...): the stored checksum no longer matches the row
    codes = s.bank.codes.at[1, 0].add(1)
    s = s._replace(bank=QuantBank(codes, s.bank.scales, s.bank.residual,
                                  s.bank.codec))
    rest = jax.tree_util.tree_map(lambda a: a[cut:], batches)
    seq = jnp.ones((K - cut,), jnp.int32)
    s2, m = fed.run_rounds(s, rest, seq, jax.random.PRNGKey(17),
                           faults=FaultPlan())
    # the checksum guard rejects every contact until the fault budget
    # (max_faults=2) trips, then the owner sits in quarantine
    f, q = np.asarray(m["faulted"]), np.asarray(m["quarantined"])
    assert f[:2].all() and not q[:2].any()
    assert q[2:].all() and not f[2:].any()
    assert bool(s2.faults.quarantined[1])
    assert _leaves_equal(s.theta_L, s2.theta_L)


# --------------------------- plan / code plumbing --------------------------

def test_fault_plan_draw_is_deterministic_and_salted():
    plan = FaultPlan(drop=0.3, stale=0.2, nonfinite=0.1, corrupt=0.1)
    key = jax.random.PRNGKey(21)
    a = plan.draw(key, 64)
    assert a.dtype == jnp.int8
    assert bool((a == plan.draw(key, 64)).all())
    assert not bool((a == plan.draw(jax.random.PRNGKey(22), 64)).all())
    # empirically every code shows up at these rates
    assert set(np.unique(np.asarray(a))) <= set(faults_mod.FAULT_CODES)


def test_fault_plan_validation():
    with pytest.raises(ValueError, match=">= 0"):
        FaultPlan(drop=-0.1)
    with pytest.raises(ValueError, match="sum"):
        FaultPlan(drop=0.6, stale=0.6)
    with pytest.raises(ValueError, match="max_faults"):
        FaultPolicy(max_faults=0)
    with pytest.raises(ValueError, match="window"):
        FaultPolicy(window=0)


def test_as_fault_codes_validation():
    assert as_fault_codes([0, 1, 4], 3).dtype == jnp.int8
    with pytest.raises(ValueError, match="1-D"):
        as_fault_codes([[0, 1]])
    with pytest.raises(ValueError, match="integer"):
        as_fault_codes([0.5, 1.0])
    with pytest.raises(ValueError, match="3 fault codes"):
        as_fault_codes([0, 1, 2], 5)
    with pytest.raises(ValueError, match="must lie in"):
        as_fault_codes([0, 9])


def test_faults_on_unarmed_state_raise(toy):
    loss_fn, params, batches = toy
    fed = _make_fed(loss_fn)                       # no fault_policy
    s = fed.init_state(params)
    with pytest.raises(ValueError, match="fault-armed"):
        fed.run_rounds(s, batches, _round_robin(), jax.random.PRNGKey(1),
                       faults=FaultPlan())
    b = jax.tree_util.tree_map(lambda a: a[0], batches)
    with pytest.raises(ValueError, match="fault-armed"):
        fed.step(s, b, 0, jax.random.PRNGKey(2), fault_code=DROP)


def test_checksums_cover_codes_and_scales(toy):
    loss_fn, params, batches = toy
    fed = _make_fed(loss_fn, fault_policy=POLICY, pack=True,
                    bank_dtype="fp8")
    s = fed.init_state(params)
    base = bank_checksums(s.bank)
    assert bool((base == s.faults.checksum).all())
    tweaked = QuantBank(s.bank.codes,
                        s.bank.scales.at[2].add(1.0),
                        s.bank.residual, s.bank.codec)
    assert int(bank_checksums(tweaked)[2]) != int(base[2])
    # the shared EF residual belongs to no owner: not in any checksum
    tweaked = QuantBank(s.bank.codes, s.bank.scales,
                        s.bank.residual + 1.0, s.bank.codec)
    assert bool((bank_checksums(tweaked) == base).all())


# ------------------------- trace schedule validation -----------------------

def test_trace_schedule_rejects_out_of_range_ids():
    windows = (((0.0, 1.0),) * 3)
    with pytest.raises(ValueError, match=r"\[3, 7\] out of range"):
        AvailabilityTraceSchedule(windows, trace=(0, 3, 1, 7))
    with pytest.raises(ValueError, match="empty trace"):
        AvailabilityTraceSchedule(windows, trace=())


def test_trace_schedule_replays_and_tiles():
    sched = AvailabilityTraceSchedule(((0.0, 1.0),) * 3, trace=(2, 0, 1))
    seq = sched.draw(jax.random.PRNGKey(0), 3, 7)
    assert seq.tolist() == [2, 0, 1, 2, 0, 1, 2]
