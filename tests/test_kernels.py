"""Pallas kernels vs pure-jnp oracles (interpret=True on CPU), with
shape/dtype sweeps per kernel."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.dp_clip_noise.kernel import (LANES, dp_round_2d,
                                                scale_noise_2d, sqnorm_2d)
from repro.kernels.dp_clip_noise.ops import dp_privatize_tree, dp_round_flat
from repro.kernels.dp_clip_noise.ref import (dp_round_ref, laplace_from_bits,
                                             scale_noise_ref, sqnorm_ref)
from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.ssm_scan.ops import ssd_chunked_pallas
from repro.kernels.ssm_scan.ref import ssd_ref


# --------------------------- flash attention ------------------------------
@pytest.mark.parametrize("B,S,H,Kv,hd,win", [
    (2, 128, 4, 2, 64, None),
    (1, 256, 4, 4, 32, 64),
    (2, 96, 2, 1, 128, None),       # MQA + ragged final block
    (1, 128, 8, 8, 80, 32),         # non-128 head dim (padded)
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_sweep(B, S, H, Kv, hd, win, dtype, rng_key):
    ks = jax.random.split(rng_key, 3)
    q = jax.random.normal(ks[0], (B, S, H, hd), dtype)
    k = jax.random.normal(ks[1], (B, S, Kv, hd), dtype)
    v = jax.random.normal(ks[2], (B, S, Kv, hd), dtype)
    out = flash_attention(q, k, v, causal=True, window=win, bq=64, bk=64,
                          interpret=True)
    G = H // Kv
    ref = attention_ref(q.transpose(0, 2, 1, 3),
                        jnp.repeat(k, G, 2).transpose(0, 2, 1, 3),
                        jnp.repeat(v, G, 2).transpose(0, 2, 1, 3),
                        causal=True, window=win).transpose(0, 2, 1, 3)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), atol=tol)


# --------------------------- dp clip + noise ------------------------------
@pytest.mark.parametrize("shape", [(256, LANES), (512, LANES)])
def test_scale_noise_blocks_match_ref(shape, rng_key):
    g = jax.random.normal(rng_key, shape, jnp.float32)
    bits = jax.random.bits(rng_key, shape, jnp.uint32)
    cs = jnp.full((1, 1), 0.37, jnp.float32)
    ns = jnp.full((1, 1), 1.7, jnp.float32)
    out = scale_noise_2d(g, bits, cs, ns, block_rows=128, interpret=True)
    ref = scale_noise_ref(g, bits, 0.37, 1.7)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-6)


def test_sqnorm_matches_ref(rng_key):
    g = jax.random.normal(rng_key, (512, LANES), jnp.float32)
    out = sqnorm_2d(g, block_rows=128, interpret=True)
    assert float(out) == pytest.approx(float(sqnorm_ref(g)), rel=1e-5)


@pytest.mark.parametrize("shapes", [
    {"a": (300, 77), "b": (5000,)},
    {"w": (64, 64), "v": (8, 8, 8)},
])
def test_dp_privatize_tree_clip_only(shapes, rng_key):
    tree = {k: jax.random.normal(jax.random.fold_in(rng_key, i), s)
            for i, (k, s) in enumerate(shapes.items())}
    xi = 0.5
    out = dp_privatize_tree(tree, rng_key, xi, 0.0, block_rows=8,
                            interpret=True)
    gn = float(jnp.sqrt(sum(jnp.sum(leaf ** 2)
                            for leaf in jax.tree_util.tree_leaves(tree))))
    scale = min(1.0, xi / gn)
    for k in tree:
        np.testing.assert_allclose(np.asarray(out[k]),
                                   np.asarray(tree[k] * scale), atol=1e-5)


def test_dp_privatize_tree_noise_stats(rng_key):
    tree = {"a": jnp.zeros((120_000,))}
    b = 3.0
    out = dp_privatize_tree(tree, rng_key, 1e9, b, block_rows=8,
                            interpret=True)
    x = np.asarray(out["a"])
    assert abs(x.mean()) < 0.05
    assert x.std() == pytest.approx(b * np.sqrt(2), rel=0.03)


def test_laplace_bits_transform_range(rng_key):
    bits = jax.random.bits(rng_key, (4096,), jnp.uint32)
    lap = laplace_from_bits(bits)
    assert bool(jnp.all(jnp.isfinite(lap)))


# ------------------------- fused dp_round (flat) --------------------------
_ROUND_KW = dict(sigma=1e-2, lr_own=0.31, lr_l=0.07, n_owners=16,
                 theta_max=2.5)


@pytest.mark.parametrize("rows,block_rows", [(256, 128), (16, 8)])
def test_dp_round_blocks_match_ref(rows, block_rows, rng_key):
    ks = jax.random.split(rng_key, 3)
    tb = 3.0 * jax.random.normal(ks[0], (rows, LANES), jnp.float32)
    acc = jax.random.normal(ks[1], (rows, LANES), jnp.float32)
    bits = jax.random.bits(ks[2], (rows, LANES), jnp.uint32)
    gn = jnp.full((1, 1), 0.25, jnp.float32)    # group-mean gain (G=4)
    ns = jnp.full((1, 1), 1.3, jnp.float32)
    w = jnp.full((1, 1), 0.0625, jnp.float32)
    new_l, new_i = dp_round_2d(tb, acc, bits, gn, ns, w,
                               block_rows=block_rows, interpret=True,
                               **_ROUND_KW)
    ref_l, ref_i = dp_round_ref(tb, acc, bits, 0.25, 1.3, 0.0625,
                                **_ROUND_KW)
    np.testing.assert_allclose(np.asarray(new_l), np.asarray(ref_l),
                               atol=1e-6)
    np.testing.assert_allclose(np.asarray(new_i), np.asarray(ref_i),
                               atol=1e-6)
    # theta_max projection binds on the 3-sigma tails of tb
    assert np.abs(np.asarray(new_l)).max() == _ROUND_KW["theta_max"]


def test_dp_round_flat_pads_and_slices(rng_key):
    # a (P,) buffer that is NOT a whole number of blocks round-trips
    # through the pad/unpad with the oracle transform on the live prefix
    P = 5000
    ks = jax.random.split(rng_key, 3)
    tb = jax.random.normal(ks[0], (P,), jnp.float32)
    acc = jax.random.normal(ks[1], (P,), jnp.float32)
    new_l, new_i = dp_round_flat(tb, acc, ks[2], 0.5, 0.9, 0.125,
                                 block_rows=8, interpret=True, **_ROUND_KW)
    assert new_l.shape == new_i.shape == (P,)
    per_block = 8 * LANES
    pad = (-P) % per_block
    bits = jax.random.bits(ks[2], ((P + pad) // LANES, LANES), jnp.uint32)
    ref_l, ref_i = dp_round_ref(
        jnp.pad(tb, (0, pad)).reshape(-1, LANES),
        jnp.pad(acc, (0, pad)).reshape(-1, LANES),
        bits, 0.5, 0.9, 0.125, **_ROUND_KW)
    np.testing.assert_allclose(np.asarray(new_l),
                               np.asarray(ref_l).reshape(-1)[:P], atol=1e-6)
    np.testing.assert_allclose(np.asarray(new_i),
                               np.asarray(ref_i).reshape(-1)[:P], atol=1e-6)


def test_dp_round_traced_scalars_jit(rng_key):
    # gain / noise_scale / w arrive as traced scalars inside jit (the fused
    # multi-round scan body's calling convention)
    ks = jax.random.split(rng_key, 3)
    tb = jax.random.normal(ks[0], (100,), jnp.float32)
    acc = jax.random.normal(ks[1], (100,), jnp.float32)

    @jax.jit
    def f(g, n, w):
        return dp_round_flat(tb, acc, ks[2], g, n, w, block_rows=8,
                             interpret=True, **_ROUND_KW)

    new_l, new_i = f(jnp.float32(1.0), jnp.float32(0.0), jnp.float32(0.25))
    # noise_scale=0: pure deterministic update, checkable in closed form
    q = acc * 1.0
    g_reg = _ROUND_KW["sigma"] * tb
    exp_i = jnp.clip(tb - 0.31 * (g_reg / 32 + 0.25 * q), -2.5, 2.5)
    exp_l = jnp.clip(tb - 0.07 * g_reg, -2.5, 2.5)
    np.testing.assert_allclose(np.asarray(new_i), np.asarray(exp_i),
                               atol=1e-6)
    np.testing.assert_allclose(np.asarray(new_l), np.asarray(exp_l),
                               atol=1e-6)


# --------------------------- ssm chunk scan -------------------------------
@pytest.mark.parametrize("B,S,H,N,P,Q", [
    (2, 128, 3, 16, 32, 32),
    (1, 100, 2, 8, 16, 32),         # ragged last chunk
    (2, 64, 4, 64, 64, 64),
    (1, 256, 1, 32, 64, 128),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_ssd_chunk_scan_sweep(B, S, H, N, P, Q, dtype, rng_key):
    ks = jax.random.split(rng_key, 5)
    v = jax.random.normal(ks[0], (B, S, H, P), dtype)
    k = jax.random.normal(ks[1], (B, S, H, N), dtype)
    q = jax.random.normal(ks[2], (B, S, H, N), dtype)
    ld = -jax.nn.softplus(jax.random.normal(ks[3], (B, S, H))).astype(jnp.float32)
    g = jax.nn.sigmoid(jax.random.normal(ks[4], (B, S, H))).astype(jnp.float32)
    y1, h1 = ssd_chunked_pallas(v, ld, k, q, g, chunk=Q, interpret=True)
    y2, h2 = ssd_ref(v, ld, k, q, g, chunk=Q)
    tol = 1e-4 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(np.asarray(y1, np.float32),
                               np.asarray(y2, np.float32), atol=tol)
    np.testing.assert_allclose(np.asarray(h1), np.asarray(h2), atol=tol)
