"""Pallas kernels vs pure-jnp oracles (interpret=True on CPU), with
shape/dtype sweeps per kernel."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.dp_clip_noise.ops import dp_privatize_tree
from repro.kernels.dp_clip_noise.kernel import scale_noise_2d, sqnorm_2d, LANES
from repro.kernels.dp_clip_noise.ref import (laplace_from_bits,
                                             scale_noise_ref, sqnorm_ref)
from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.ssm_scan.ops import ssd_chunked_pallas
from repro.kernels.ssm_scan.ref import ssd_ref


# --------------------------- flash attention ------------------------------
@pytest.mark.parametrize("B,S,H,Kv,hd,win", [
    (2, 128, 4, 2, 64, None),
    (1, 256, 4, 4, 32, 64),
    (2, 96, 2, 1, 128, None),       # MQA + ragged final block
    (1, 128, 8, 8, 80, 32),         # non-128 head dim (padded)
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_sweep(B, S, H, Kv, hd, win, dtype, rng_key):
    ks = jax.random.split(rng_key, 3)
    q = jax.random.normal(ks[0], (B, S, H, hd), dtype)
    k = jax.random.normal(ks[1], (B, S, Kv, hd), dtype)
    v = jax.random.normal(ks[2], (B, S, Kv, hd), dtype)
    out = flash_attention(q, k, v, causal=True, window=win, bq=64, bk=64,
                          interpret=True)
    G = H // Kv
    ref = attention_ref(q.transpose(0, 2, 1, 3),
                        jnp.repeat(k, G, 2).transpose(0, 2, 1, 3),
                        jnp.repeat(v, G, 2).transpose(0, 2, 1, 3),
                        causal=True, window=win).transpose(0, 2, 1, 3)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), atol=tol)


# --------------------------- dp clip + noise ------------------------------
@pytest.mark.parametrize("shape", [(256, LANES), (512, LANES)])
def test_scale_noise_blocks_match_ref(shape, rng_key):
    g = jax.random.normal(rng_key, shape, jnp.float32)
    bits = jax.random.bits(rng_key, shape, jnp.uint32)
    cs = jnp.full((1, 1), 0.37, jnp.float32)
    ns = jnp.full((1, 1), 1.7, jnp.float32)
    out = scale_noise_2d(g, bits, cs, ns, block_rows=128, interpret=True)
    ref = scale_noise_ref(g, bits, 0.37, 1.7)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-6)


def test_sqnorm_matches_ref(rng_key):
    g = jax.random.normal(rng_key, (512, LANES), jnp.float32)
    out = sqnorm_2d(g, block_rows=128, interpret=True)
    assert float(out) == pytest.approx(float(sqnorm_ref(g)), rel=1e-5)


@pytest.mark.parametrize("shapes", [
    {"a": (300, 77), "b": (5000,)},
    {"w": (64, 64), "v": (8, 8, 8)},
])
def test_dp_privatize_tree_clip_only(shapes, rng_key):
    tree = {k: jax.random.normal(jax.random.fold_in(rng_key, i), s)
            for i, (k, s) in enumerate(shapes.items())}
    xi = 0.5
    out = dp_privatize_tree(tree, rng_key, xi, 0.0, block_rows=8,
                            interpret=True)
    gn = float(jnp.sqrt(sum(jnp.sum(l ** 2)
                            for l in jax.tree_util.tree_leaves(tree))))
    scale = min(1.0, xi / gn)
    for k in tree:
        np.testing.assert_allclose(np.asarray(out[k]),
                                   np.asarray(tree[k] * scale), atol=1e-5)


def test_dp_privatize_tree_noise_stats(rng_key):
    tree = {"a": jnp.zeros((120_000,))}
    b = 3.0
    out = dp_privatize_tree(tree, rng_key, 1e9, b, block_rows=8,
                            interpret=True)
    x = np.asarray(out["a"])
    assert abs(x.mean()) < 0.05
    assert x.std() == pytest.approx(b * np.sqrt(2), rel=0.03)


def test_laplace_bits_transform_range(rng_key):
    bits = jax.random.bits(rng_key, (4096,), jnp.uint32)
    lap = laplace_from_bits(bits)
    assert bool(jnp.all(jnp.isfinite(lap)))


# --------------------------- ssm chunk scan -------------------------------
@pytest.mark.parametrize("B,S,H,N,P,Q", [
    (2, 128, 3, 16, 32, 32),
    (1, 100, 2, 8, 16, 32),         # ragged last chunk
    (2, 64, 4, 64, 64, 64),
    (1, 256, 1, 32, 64, 128),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_ssd_chunk_scan_sweep(B, S, H, N, P, Q, dtype, rng_key):
    ks = jax.random.split(rng_key, 5)
    v = jax.random.normal(ks[0], (B, S, H, P), dtype)
    k = jax.random.normal(ks[1], (B, S, H, N), dtype)
    q = jax.random.normal(ks[2], (B, S, H, N), dtype)
    ld = -jax.nn.softplus(jax.random.normal(ks[3], (B, S, H))).astype(jnp.float32)
    g = jax.nn.sigmoid(jax.random.normal(ks[4], (B, S, H))).astype(jnp.float32)
    y1, h1 = ssd_chunked_pallas(v, ld, k, q, g, chunk=Q, interpret=True)
    y2, h2 = ssd_ref(v, ld, k, q, g, chunk=Q)
    tol = 1e-4 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(np.asarray(y1, np.float32),
                               np.asarray(y2, np.float32), atol=tol)
    np.testing.assert_allclose(np.asarray(h1), np.asarray(h2), atol=tol)
