"""AsyncDPTrainer: the paper's update rule on deep-model pytrees."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.async_trainer import (AsyncDPConfig, init_state,
                                      make_sync_dp_step, make_train_step)
from repro.core.dp_sgd import PrivatizerConfig
from repro.models import build_model


@pytest.fixture(scope="module")
def setup():
    cfg = get_config("yi-6b").reduced()
    model = build_model(cfg, remat=False)
    key = jax.random.PRNGKey(0)
    params = model.init(key, jnp.float32)
    acfg = AsyncDPConfig(
        n_owners=3, horizon=100, rho=1.0, sigma=1e-2,
        epsilons=(1.0, 1.0, 1.0), owner_sizes=(500, 500, 500),
        xi=1.0, theta_max=50.0,
        privatizer=PrivatizerConfig(xi=1.0, granularity="microbatch",
                                    n_microbatches=2),
        lr_scale=100.0)
    batch = {"tokens": jax.random.randint(key, (4, 16), 0, cfg.vocab),
             "labels": jax.random.randint(key, (4, 16), 0, cfg.vocab)}
    def loss_fn(p, b):
        return model.loss(p, b)[0]
    return model, params, acfg, batch, loss_fn, key


def test_bank_initialized_from_params(setup):
    _, params, acfg, *_ = setup
    state = init_state(params, acfg)
    leaf = jax.tree_util.tree_leaves(params)[0]
    bleaf = jax.tree_util.tree_leaves(state.bank)[0]
    assert bleaf.shape == (acfg.n_owners,) + leaf.shape
    np.testing.assert_allclose(np.asarray(bleaf[1]), np.asarray(leaf))


def test_step_updates_only_selected_owner(setup):
    _, params, acfg, batch, loss_fn, key = setup
    step = jax.jit(make_train_step(loss_fn, acfg))
    state = init_state(params, acfg)
    new_state, metrics = step(state, batch, jnp.int32(1), key)

    def owner_delta(i):
        return max(float(jnp.max(jnp.abs(a[i] - b[i]))) for a, b in zip(
            jax.tree_util.tree_leaves(new_state.bank),
            jax.tree_util.tree_leaves(state.bank)))

    assert owner_delta(1) > 0.0                 # selected owner moved
    assert owner_delta(0) == 0.0                # others untouched
    assert owner_delta(2) == 0.0
    # central model moved (inertia blend + reg step)
    dL = max(float(jnp.max(jnp.abs(a - b))) for a, b in zip(
        jax.tree_util.tree_leaves(new_state.theta_L),
        jax.tree_util.tree_leaves(state.theta_L)))
    assert dL >= 0.0
    assert int(new_state.step) == 1
    assert float(metrics["grad_noise_scale"]) == pytest.approx(
        2 * 1.0 * 100 / (500 * 1.0))            # Theorem 1


def test_projection_enforced(setup):
    _, params, acfg, batch, loss_fn, key = setup
    import dataclasses
    tight = dataclasses.replace(acfg, theta_max=0.01)
    step = jax.jit(make_train_step(loss_fn, tight))
    state = init_state(params, tight)
    state, _ = step(state, batch, jnp.int32(0), key)
    for leaf in jax.tree_util.tree_leaves(state.bank):
        assert float(jnp.max(jnp.abs(leaf[0]))) <= 0.01 + 1e-6


def test_sync_baseline_runs(setup):
    _, params, acfg, batch, loss_fn, key = setup
    step = make_sync_dp_step(loss_fn, acfg, lr=1e-3)
    batches = jax.tree_util.tree_map(
        lambda a: jnp.stack([a] * acfg.n_owners), batch)
    new = step(params, batches, key)
    assert all(jnp.all(jnp.isfinite(leaf))
               for leaf in jax.tree_util.tree_leaves(new))
