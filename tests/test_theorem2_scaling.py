"""Theorem 2 validation: cost of privacy ~ (1/n^2) * sum 1/eps_i^2.

These are the paper's central claims (eqs. 10-11, Figs. 4/5/10) run at
test scale: CoP decreases with n and eps, and the fitted eq.-(11) bound
dominates the observations while staying within an order of magnitude at
the fit points (tightness).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import Algo1Config, bound_asymptotic, fit_constants, make_problem, run_many
from repro.core.cop import budget_sum
from repro.data import owner_shards

REG, SIGMA, T, RUNS = 1e-5, 2e-5, 400, 6


def _cop(n_per_owner, eps, seed=0):
    shards = owner_shards("lending", [n_per_owner] * 3, seed=seed)
    prob, owners = make_problem(shards, reg=REG, theta_max=2.0)
    cfg = Algo1Config(horizon=T, rho=1.0, sigma=SIGMA, epsilons=[eps] * 3)
    tr = run_many(jax.random.PRNGKey(seed), prob, owners, cfg, RUNS)
    noiseless = Algo1Config(horizon=T, rho=1.0, sigma=SIGMA,
                            epsilons=[eps] * 3, noiseless=True)
    tr0 = run_many(jax.random.PRNGKey(seed), prob, owners, noiseless, 2)
    # cost of privacy: excess relative fitness attributable to DP noise
    return max(float(jnp.mean(tr.psi[:, -1]) - jnp.mean(tr0.psi[:, -1])), 1e-9)


@pytest.fixture(scope="module")
def cop_grid():
    ns = [10_000, 40_000]
    epss = [2.0, 8.0]
    return {(n, e): _cop(n, e) for n in ns for e in epss}


def test_cop_decreases_with_n(cop_grid):
    for e in (2.0, 8.0):
        assert cop_grid[(40_000, e)] < cop_grid[(10_000, e)]


def test_cop_decreases_with_eps(cop_grid):
    for n in (10_000, 40_000):
        assert cop_grid[(n, 8.0)] < cop_grid[(n, 2.0)]


def test_cop_scaling_rate(cop_grid):
    # eq. (11): at fixed eps, CoP ~ 1/n^2 (second term dominates at small
    # eps*n). 4x n should cut CoP by well over 4x in that regime.
    ratio = cop_grid[(10_000, 2.0)] / cop_grid[(40_000, 2.0)]
    assert ratio > 4.0


def test_fitted_bound_dominates(cop_grid):
    ns, ss, obs = [], [], []
    for (n, e), v in cop_grid.items():
        ns.append(3 * n)
        ss.append(budget_sum([e] * 3))
        obs.append(v)
    c1, c2 = fit_constants(np.array(ns), np.array(ss), np.array(obs))
    # inflate to a strict upper bound (the paper fits by eye, Figs. 4/5)
    c1b, c2b = 2.0 * c1 + 1e-12, 2.0 * c2 + 1e-12
    for (n, e), v in cop_grid.items():
        bound = bound_asymptotic(3 * n, [e] * 3, c1b, c2b)
        assert bound >= v * 0.99
        assert bound < max(v * 50.0, 1e-6)     # and not vacuous
