"""Paged owner bank: cold-tier row stores, the in-graph page table, and
the bit-exactness contract.

The load-bearing claim: with every touched row resident (n_hot >= N, or
a pager that prefetches each dispatch's window), the PAGED engine
reproduces the FLAT engine bit-for-bit — params, bank rows, ledger
counters, and per-round metrics — on all three drivers (per-round step,
fused scan, grouped owner-parallel), every bank codec (f32/bf16 dense,
int8/fp8 error-feedback), under refusals and injected faults. A
non-resident row is a lawful masked no-op: no epsilon spent, the round
lands in `refused`, model state untouched.
"""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import MemmapRowStore, MemoryRowStore
from repro.federation import (DataOwner, FaultPlan, FaultPolicy, Federation,
                              FederationConfig, PrivatizerConfig)
from repro.federation.deep import AsyncDPConfig, make_fused_rounds
from repro.federation.flatten import PagedBank, QuantBank
from repro.federation.paging import init_paged_state
from repro.federation.schedules import AvailabilityTraceSchedule

N, K = 8, 24


def _leaves_equal(a, b):
    return all(np.array_equal(np.asarray(x), np.asarray(y)) for x, y in
               zip(jax.tree_util.tree_leaves(a), jax.tree_util.tree_leaves(b)))


@pytest.fixture(scope="module")
def toy():
    key = jax.random.PRNGKey(0)
    params = {"w": jax.random.normal(key, (6,)), "b": jnp.zeros(())}
    batches = {"x": jax.random.normal(jax.random.PRNGKey(1), (K, 4, 6)),
               "y": jax.random.normal(jax.random.PRNGKey(2), (K, 4))}
    def loss_fn(p, b):
        return jnp.mean((b["x"] @ p["w"] + p["b"] - b["y"]) ** 2)
    priv = PrivatizerConfig(xi=1.0, granularity="example")
    return params, batches, loss_fn, priv


def _make_fed(loss_fn, priv, horizon=3, bank_dtype=None, mesh=None, **kw):
    owners = [DataOwner(n=100, epsilon=1.0, xi=1.0) for _ in range(N)]
    fed = Federation(owners, FederationConfig(horizon=horizon, sigma=1e-2,
                                              theta_max=10.0, lr_scale=5.0),
                     **kw)
    fed.make_step(loss_fn, privatizer=priv, pack_params=True,
                  bank_dtype=bank_dtype, mesh=mesh)
    return fed


def _bank_arrays(bank):
    if isinstance(bank, PagedBank):
        bank = bank.hot
    if isinstance(bank, QuantBank):
        return {"codes": np.asarray(bank.codes),
                "scales": np.asarray(bank.scales),
                "residual": np.asarray(bank.residual)}
    return {"rows": np.asarray(bank)}


def _assert_banks_equal(flat_bank, paged_bank, n=N):
    fa, pa = _bank_arrays(flat_bank), _bank_arrays(paged_bank)
    assert fa.keys() == pa.keys()
    for k in fa:
        a, b = fa[k], pa[k]
        if a.ndim >= 1 and a.shape[0] >= n and b.shape[0] >= n:
            a, b = a[:n], b[:n]
        np.testing.assert_array_equal(a, b, err_msg=k)


# ------------------------- cold-tier row stores -----------------------------
@pytest.mark.parametrize("dtype", ["float32", "bfloat16", "int8",
                                   "float8_e4m3fn"])
@pytest.mark.parametrize("kind", ["memory", "memmap"])
def test_row_store_bit_exact_roundtrip(tmp_path, kind, dtype):
    import ml_dtypes
    dt = np.dtype(getattr(ml_dtypes, dtype, dtype))
    rng = np.random.default_rng(3)
    default = rng.standard_normal(5).astype(dt)
    if kind == "memory":
        store = MemoryRowStore(10, (5,), dt, default)
    else:
        store = MemmapRowStore(str(tmp_path / dtype), 10, (5,), dt, default)
    # unwritten rows read as the default, bit-for-bit
    out = store.read_rows(np.array([0, 7]))
    np.testing.assert_array_equal(out.view(np.uint8),
                                  np.stack([default] * 2).view(np.uint8))
    vals = rng.standard_normal((3, 5)).astype(dt)
    store.write_rows(np.array([2, 7, 9]), vals)
    back = store.read_rows(np.array([2, 7, 9, 0]))
    np.testing.assert_array_equal(back[:3].view(np.uint8),
                                  vals.view(np.uint8))
    np.testing.assert_array_equal(back[3].view(np.uint8),
                                  default.view(np.uint8))
    assert store.written == 3


def test_row_store_bounds_checked(tmp_path):
    store = MemoryRowStore(4, (2,), np.float32, np.zeros(2, np.float32))
    with pytest.raises(IndexError):
        store.read_rows(np.array([4]))
    with pytest.raises(IndexError):
        store.write_rows(np.array([-1]), np.zeros((1, 2), np.float32))


def test_memmap_store_is_lazy(tmp_path):
    # a million-row store must not cost a million rows of disk up front
    store = MemmapRowStore(str(tmp_path / "big"), 1_000_000, (64,),
                           np.float32, np.zeros(64, np.float32))
    store.write_rows(np.array([123_456]), np.ones((1, 64), np.float32))
    store.flush()
    path = os.path.join(str(tmp_path / "big"), "rows.npy")
    # apparent size is the full matrix; blocks actually allocated are not
    blocks = os.stat(path).st_blocks * 512
    assert blocks < 8 * 64 * 4 * 1_000_000 / 100


# ------------------------------ page table ----------------------------------
def test_paged_bank_lookup():
    hot = jnp.zeros((4, 3), jnp.float32)
    ids = jnp.asarray(np.array([2, 5, 9, 12], np.int32))
    bank = PagedBank(hot, ids, 20)
    for owner, want_slot, want_hit in [(2, 0, True), (5, 1, True),
                                       (9, 2, True), (12, 3, True),
                                       (0, 0, False), (7, 2, False),
                                       (19, 3, False)]:
        slot, hit = bank.lookup(jnp.int32(owner))
        assert bool(hit) is want_hit, owner
        if want_hit:
            assert int(slot) == want_slot
        assert 0 <= int(slot) < 4          # always gather-safe


def test_lookup_with_sentinel_padding():
    # empty slots carry the sentinel n_owners, which sorts last — a
    # partially-filled table still resolves every resident owner
    ids = jnp.asarray(np.array([3, 6, 10, 10, 10], np.int32))
    bank = PagedBank(jnp.zeros((5, 2)), ids, 10)
    assert bool(bank.lookup(jnp.int32(3))[1])
    assert bool(bank.lookup(jnp.int32(6))[1])
    assert not bool(bank.lookup(jnp.int32(9))[1])


# ------------------- full-residency bit-parity (tentpole) -------------------
@pytest.mark.parametrize("bank_dtype", [None, "bfloat16", "int8", "fp8"])
def test_fused_paged_matches_flat_bit_exact(toy, bank_dtype):
    # horizon=3 over K=24 rounds: refusals interleave mid-schedule, so
    # the paged ledger masking is exercised, not just the happy path
    params, batches, loss_fn, priv = toy
    seq = np.asarray(jax.random.randint(jax.random.PRNGKey(3), (K,), 0, N))
    root = jax.random.PRNGKey(4)

    fed_f = _make_fed(loss_fn, priv, bank_dtype=bank_dtype)
    sf = fed_f.init_state(params)
    sf, mf = fed_f.run_rounds(sf, batches, seq, key=root)

    fed_p = _make_fed(loss_fn, priv, bank_dtype=bank_dtype)
    sp = fed_p.init_paged_state(params, n_hot=N, bank_dtype=bank_dtype)
    sp, mp = fed_p.run_rounds(sp, batches, seq, key=root)

    assert np.asarray(mf["refused"]).sum() > 0
    np.testing.assert_array_equal(np.asarray(sf.theta_L.buf),
                                  np.asarray(sp.theta_L.buf))
    _assert_banks_equal(sf.bank, sp.bank)
    assert _leaves_equal(sf.ledger, sp.ledger)
    assert _leaves_equal(mf, mp)


@pytest.mark.parametrize("bank_dtype", [None, "int8"])
def test_step_loop_paged_matches_flat(toy, bank_dtype):
    params, batches, loss_fn, priv = toy
    seq = np.asarray(jax.random.randint(jax.random.PRNGKey(5), (K,), 0, N))
    keys = jax.random.split(jax.random.PRNGKey(6), K)

    fed_f = _make_fed(loss_fn, priv, bank_dtype=bank_dtype)
    sf = fed_f.init_state(params)
    fed_p = _make_fed(loss_fn, priv, bank_dtype=bank_dtype)
    sp = fed_p.init_paged_state(params, n_hot=3)   # forced paging traffic
    for k in range(K):
        b = jax.tree_util.tree_map(lambda a: a[k], batches)
        sf, mf = fed_f.step(sf, b, int(seq[k]), keys[k])
        sp, mp = fed_p.step(sp, b, int(seq[k]), keys[k])
        assert mf["refused"] == mp["refused"], k
    np.testing.assert_array_equal(np.asarray(sf.theta_L.buf),
                                  np.asarray(sp.theta_L.buf))
    snap = fed_p.pager.snapshot(sp)
    fa = _bank_arrays(sf.bank)
    for k in fa.keys() & snap.keys():
        np.testing.assert_array_equal(fa[k], snap[k], err_msg=k)
    assert fed_f.ledger() == fed_p.ledger()


@pytest.mark.parametrize("bank_dtype", [None, "bfloat16", "fp8"])
def test_grouped_paged_matches_flat(toy, bank_dtype):
    params, batches, loss_fn, priv = toy
    seq = np.asarray(jax.random.randint(jax.random.PRNGKey(7), (K,), 0, N))
    root = jax.random.PRNGKey(8)

    fed_f = _make_fed(loss_fn, priv, bank_dtype=bank_dtype)
    sf = fed_f.init_state(params)
    sf, mf = fed_f.run_rounds(sf, batches, seq, key=root,
                              owner_parallel=True, max_group=4)

    fed_p = _make_fed(loss_fn, priv, bank_dtype=bank_dtype)
    sp = fed_p.init_paged_state(params, n_hot=N)
    sp, mp = fed_p.run_rounds(sp, batches, seq, key=root,
                              owner_parallel=True, max_group=4)

    np.testing.assert_array_equal(np.asarray(sf.theta_L.buf),
                                  np.asarray(sp.theta_L.buf))
    _assert_banks_equal(sf.bank, sp.bank)
    assert _leaves_equal(sf.ledger, sp.ledger)
    assert _leaves_equal(mf, mp)


@pytest.mark.parametrize("owner_parallel", [False, True])
def test_faulted_paged_matches_flat(toy, owner_parallel):
    params, batches, loss_fn, priv = toy
    plan = FaultPlan(drop=0.2, stale=0.1, nonfinite=0.2, corrupt=0.2)
    pol = FaultPolicy(max_faults=2, window=8)
    seq = np.asarray(jax.random.randint(jax.random.PRNGKey(9), (K,), 0, N))
    root = jax.random.PRNGKey(10)
    kw = dict(owner_parallel=True, max_group=4) if owner_parallel else {}

    fed_f = _make_fed(loss_fn, priv, fault_policy=pol)
    sf = fed_f.init_state(params)
    sf, mf = fed_f.run_rounds(sf, batches, seq, key=root, faults=plan, **kw)

    fed_p = _make_fed(loss_fn, priv, fault_policy=pol)
    sp = fed_p.init_paged_state(params, n_hot=N)
    sp, mp = fed_p.run_rounds(sp, batches, seq, key=root, faults=plan, **kw)

    assert np.asarray(mf["faulted"]).sum() > 0
    np.testing.assert_array_equal(np.asarray(sf.theta_L.buf),
                                  np.asarray(sp.theta_L.buf))
    _assert_banks_equal(sf.bank, sp.bank)
    assert _leaves_equal(sf.ledger, sp.ledger)
    assert _leaves_equal(sf.faults, sp.faults)
    assert _leaves_equal(mf, mp)


# ---------------- eviction round trips + trace streaming --------------------
@pytest.mark.parametrize("bank_dtype", [None, "int8"])
def test_eviction_roundtrip_bit_exact(toy, tmp_path, bank_dtype):
    # n_hot=3 over 8 owners forces load/evict cycles every dispatch;
    # the flat reference runs the SAME chunked dispatches (same keys),
    # so any row corrupted through the cold tier breaks parity
    params, batches, loss_fn, priv = toy
    seq = np.asarray(jax.random.randint(jax.random.PRNGKey(11), (K,), 0, N))
    root = jax.random.PRNGKey(12)
    chunks = [(lo, min(lo + 3, K)) for lo in range(0, K, 3)]
    keys = jax.random.split(root, len(chunks))

    fed_f = _make_fed(loss_fn, priv, bank_dtype=bank_dtype)
    sf = fed_f.init_state(params)
    fed_p = _make_fed(loss_fn, priv, bank_dtype=bank_dtype)
    sp = fed_p.init_paged_state(params, n_hot=3, bank_dtype=bank_dtype,
                                cold_dir=str(tmp_path))
    for (lo, hi), kk in zip(chunks, keys):
        b = jax.tree_util.tree_map(lambda a: a[lo:hi], batches)
        sf, _ = fed_f.run_rounds(sf, b, seq[lo:hi], key=kk)
        sp, _ = fed_p.run_rounds(sp, b, seq[lo:hi], key=kk)

    assert fed_p.pager.stats["evictions"] > 0
    np.testing.assert_array_equal(np.asarray(sf.theta_L.buf),
                                  np.asarray(sp.theta_L.buf))
    snap = fed_p.pager.snapshot(sp)
    fa = _bank_arrays(sf.bank)
    for k in fa.keys() & snap.keys():
        np.testing.assert_array_equal(
            fa[k].view(np.uint8) if fa[k].dtype.itemsize == 2 else fa[k],
            snap[k].view(np.uint8) if snap[k].dtype.itemsize == 2
            else snap[k], err_msg=k)
    assert _leaves_equal(sf.ledger, sp.ledger)


def test_trace_ring_run_matches_materialized_trace(toy):
    params, batches, loss_fn, priv = toy
    trace = (0, 5, 2, 7, 1, 3)
    root = jax.random.PRNGKey(13)
    wins = tuple((0.0, 1.0) for _ in range(N))

    fed_a = _make_fed(loss_fn, priv)
    sa = fed_a.init_state(params)
    sa, ma = fed_a.run_rounds(sa, batches, np.resize(trace, K), key=root)

    fed_b = _make_fed(loss_fn, priv)
    sb = fed_b.init_paged_state(params, n_hot=6)
    ring = AvailabilityTraceSchedule(wins, trace=trace).trace_ring(chunk=5)
    sb, mb = fed_b.run_rounds(sb, batches, ring, key=root)

    np.testing.assert_array_equal(np.asarray(sa.theta_L.buf),
                                  np.asarray(sb.theta_L.buf))
    np.testing.assert_array_equal(np.asarray(ma["refused"]),
                                  np.asarray(mb["refused"]))
    _assert_banks_equal(sa.bank, fed_b.pager.snapshot(sb)["rows"][:N])


# ------------------------- miss semantics -----------------------------------
def test_page_miss_is_refused_and_spends_nothing(toy):
    # drive the fused driver DIRECTLY (no pager prefetch): owners beyond
    # the initial residency miss the page table — each such round must
    # land in `refused`, spend no epsilon, and leave all state unchanged
    params, batches, loss_fn, priv = toy
    cfg = AsyncDPConfig(n_owners=N, horizon=16, epsilons=(1.0,) * N,
                        owner_sizes=(100,) * N, caps=(5,) * N,
                        privatizer=priv)
    state, _ = init_paged_state(params, cfg, n_hot=3)   # resident: {0,1,2}
    run = make_fused_rounds(loss_fn, cfg)
    seq = np.array([0, 6, 1, 7, 2, 5], np.int32)
    keys = jax.random.split(jax.random.PRNGKey(14), len(seq))
    b = jax.tree_util.tree_map(lambda a: a[:len(seq)], batches)
    out, m = run(state, b, jnp.asarray(seq), keys)

    np.testing.assert_array_equal(np.asarray(m["refused"]),
                                  [False, True, False, True, False, True])
    spent = np.asarray(out.ledger.spent)
    assert spent[5] == spent[6] == spent[7] == 0
    np.testing.assert_array_equal(np.asarray(out.ledger.refused),
                                  [0, 0, 0, 0, 0, 1, 1, 1])
    # resident rows trained; the hot tier's page table is untouched
    np.testing.assert_array_equal(np.asarray(out.bank.hot_ids),
                                  np.asarray(state.bank.hot_ids))


def test_prefetch_rejects_oversized_window(toy):
    params, _, loss_fn, priv = toy
    fed = _make_fed(loss_fn, priv)
    state = fed.init_paged_state(params, n_hot=3)
    with pytest.raises(ValueError, match="n_hot"):
        fed.pager.prefetch(state, np.arange(5))


def test_save_session_round_trips_paged_states(toy, tmp_path):
    # PR 10: paged sessions checkpoint (cold tier + page table ride in the
    # same shard); a fresh paged session restores the hot tier bit-exactly.
    params, batches, loss_fn, priv = toy
    seq = np.asarray(jax.random.randint(jax.random.PRNGKey(21), (K,), 0, N))
    fed_a = _make_fed(loss_fn, priv, horizon=K)
    sa = fed_a.init_paged_state(params, n_hot=N)
    sa, _ = fed_a.run_rounds(sa, batches, seq, key=jax.random.PRNGKey(22))
    led = fed_a.reconcile(sa)
    fed_a.save_session(str(tmp_path), sa)

    fed_b = _make_fed(loss_fn, priv, horizon=K)
    sb = fed_b.init_paged_state(params, n_hot=N)
    sb = fed_b.restore_session(str(tmp_path), sb)
    assert _leaves_equal(sb.theta_L, sa.theta_L)
    assert _leaves_equal(sb.bank.hot, sa.bank.hot)
    np.testing.assert_array_equal(np.asarray(sb.bank.hot_ids),
                                  np.asarray(sa.bank.hot_ids))
    assert fed_b.reconcile(sb) == led


# ------------------------------- sharding -----------------------------------
def test_paged_engine_on_1x1_mesh_bit_exact(toy):
    from repro.launch.mesh import make_host_mesh
    params, batches, loss_fn, priv = toy
    seq = np.asarray(jax.random.randint(jax.random.PRNGKey(15), (K,), 0, N))
    root = jax.random.PRNGKey(16)
    mesh = make_host_mesh(model=1)

    fed_a = _make_fed(loss_fn, priv)
    sa = fed_a.init_paged_state(params, n_hot=N)
    sa, ma = fed_a.run_rounds(sa, batches, seq, key=root)

    fed_b = _make_fed(loss_fn, priv, mesh=mesh)
    sb = fed_b.init_paged_state(params, n_hot=N, mesh=mesh)
    sb, mb = fed_b.run_rounds(sb, batches, seq, key=root)

    np.testing.assert_array_equal(np.asarray(sa.theta_L.buf),
                                  np.asarray(sb.theta_L.buf))
    _assert_banks_equal(sa.bank, sb.bank)
    assert _leaves_equal(ma, mb)
