# The federation as a first-class API: DataOwners + FederationConfig +
# pluggable Mechanism/Schedule -> one Federation session surface that
# dispatches to the convex lax.scan fast path (LinearProblem) or the jitted
# bank-sharded deep-model path, with the privacy ledger inside the
# mechanism. repro.core re-exports the legacy names as shims.
from repro.federation.clocks import (Schedule, owner_counts,
                                     poisson_schedule, uniform_schedule)
from repro.federation.config import FederationConfig
from repro.federation.convex import (Algo1Config, Algo1Trace, SyncTrace,
                                     run_algorithm1, run_many, scan_engine,
                                     stack_gram, sync_scan_engine)
from repro.federation.deep import (AsyncDPConfig, AsyncDPState, TreeNoise,
                                   init_state, init_state_flat,
                                   init_tree_noise, make_fused_rounds,
                                   make_group_rounds, make_sync_dp_step,
                                   make_train_step)
from repro.federation.dp_sgd import (PrivatizerConfig, clip_tree,
                                     private_grad, resolve_interpret)
from repro.federation.faults import (CORRUPT_PAYLOAD, DROP, NONFINITE_GRAD,
                                     OK, STALE, TIMEOUT, FaultPlan,
                                     FaultPolicy, FaultState, as_fault_codes,
                                     bank_checksums, init_fault_state)
from repro.federation.flatten import (BankCodec, FlatSpec, PagedBank,
                                      ParamFlat, QuantBank, as_bank_codec,
                                      flatten_spec, init_flat_bank,
                                      pack_params)
from repro.federation.linear import (LinearProblem, Owner, fitness,
                                     make_problem, owner_grad,
                                     record_grad_bound, relative_fitness)
from repro.federation.mechanisms import (CappedRoundsMechanism,
                                         LedgerDriftError, Mechanism,
                                         PaperMechanism, StrictMechanism,
                                         TreeMechanism, make_mechanism)
from repro.federation.owners import DataOwner, federate_problem, with_budgets
from repro.federation.paging import OwnerPager, init_paged_state
from repro.federation.privacy import (DeviceLedger, PrivacyAccountant,
                                      capped_rounds, laplace_noise,
                                      laplace_noise_tree,
                                      laplace_scale_theorem1,
                                      make_device_ledger)
from repro.federation.schedules import (AvailabilityTraceSchedule,
                                        PoissonSchedule, ScheduleProtocol,
                                        TraceRing, UniformSchedule,
                                        as_owner_seq, auto_max_group,
                                        pack_groups,
                                        partition_conflict_free)
from repro.federation.session import Federation
from repro.federation.staleness import (STALE_SALT, LatencyPlan,
                                        StalenessPolicy, StalenessState,
                                        as_tick_times, deadline_guard,
                                        init_staleness_state,
                                        merge_timeout_codes,
                                        staleness_tick, staleness_weight)
