"""DataOwner: one private participant of the federation.

An owner is (n_i records, budget eps_i, gradient bound Xi_i) plus an
optional convex Gram payload (A_i, b_i) that unlocks the O(p^2) lax.scan
fast path. Deep-model owners carry no payload — their data arrives per-step
as batches from the host-side pipeline.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence, Tuple, Union

import jax.numpy as jnp
import numpy as np

from repro.federation.linear import (LinearProblem, Owner, make_problem,
                                     record_grad_bound)


@dataclasses.dataclass(frozen=True)
class DataOwner:
    n: int                       # records held (n_i)
    epsilon: float               # privacy budget (eps_i)
    xi: float                    # Assumption-2 gradient-norm bound (Xi_i)
    gram: Optional[Owner] = None  # convex fast-path payload (A_i, b_i)

    @classmethod
    def from_arrays(cls, X: np.ndarray, y: np.ndarray, epsilon: float, *,
                    theta_max: float) -> "DataOwner":
        """Build a convex owner from its raw records (never leaves the
        owner's side; only Gram aggregates enter the engine)."""
        n_i = X.shape[0]
        A = jnp.asarray(X.T @ X / n_i)
        b = jnp.asarray(X.T @ y / n_i)
        xi = record_grad_bound(X, y, theta_max)
        return cls(n=n_i, epsilon=epsilon, xi=xi,
                   gram=Owner(A, b, n_i, xi))

    @classmethod
    def from_gram(cls, owner: Owner, epsilon: float) -> "DataOwner":
        return cls(n=owner.n, epsilon=epsilon, xi=owner.xi, gram=owner)


def _broadcast_budgets(epsilons: Union[float, Sequence[float]],
                       n_owners: int) -> List[float]:
    if isinstance(epsilons, (int, float)):
        return [float(epsilons)] * n_owners
    epsilons = list(epsilons)
    if len(epsilons) != n_owners:
        raise ValueError(f"{len(epsilons)} budgets for {n_owners} owners")
    return [float(e) for e in epsilons]


def federate_problem(shards: List[Tuple[np.ndarray, np.ndarray]],
                     epsilons: Union[float, Sequence[float]], *,
                     reg: float = 1e-5, theta_max: float = 10.0
                     ) -> Tuple[LinearProblem, List[DataOwner]]:
    """shards [(X_i, y_i)] + per-owner budgets -> (LinearProblem, owners).

    The convex analogue of handing each owner's records to its own
    DataOwner: builds the global problem and the per-owner Gram payloads in
    one pass (a scalar budget is broadcast to every owner).
    """
    prob, gram = make_problem(shards, reg=reg, theta_max=theta_max)
    eps = _broadcast_budgets(epsilons, len(gram))
    return prob, [DataOwner.from_gram(o, e) for o, e in zip(gram, eps)]


def with_budgets(owners: Sequence[DataOwner],
                 epsilons: Union[float, Sequence[float]]
                 ) -> List[DataOwner]:
    """Same owners, renegotiated budgets (Section 6's budget negotiation)."""
    eps = _broadcast_budgets(epsilons, len(owners))
    return [dataclasses.replace(o, epsilon=e) for o, e in zip(owners, eps)]
