"""Deep-model federation engine: Algorithm 1 as a first-class
distributed-training strategy for pytree models.

State = central params theta_L + an owner-copy BANK: every parameter leaf
gains a leading axis N_owners, sharded with the same FSDP x TP rules as the
model itself (see DESIGN.md §3 — one copy costs P/(|data|*|model|) bytes per
chip). A training step consumes `owner_idx` (drawn host-side from the
schedule), gathers that owner's copy, performs the paper's inertia update
(eqs. 5-7) with a privatized gradient (Theorem-1 Laplace scale, Xi enforced
by clipping per federation.dp_sgd), and writes the copy back.

The step intentionally contains NO cross-owner collective — that is the
paper's asynchrony, mapped to SPMD (the only collectives are model/data-axis
ones from sharding).

`lr_scale` (default 1.0) multiplies the paper's rho/T^2 constant rate —
the paper's exact rate is extremely small for deep nets; the override is a
recorded deviation for the practical examples, while paper-faithful runs
keep lr_scale=1.

Canonical home of the deep path; ``repro.core.async_trainer`` is a
compatibility shim over this module. The session-level entrypoint is
``repro.federation.Federation``: it injects per-owner noise `scales` from a
pluggable ``Mechanism`` (whose internal ledger refuses budget-exhausted
owners before the step is ever called).

Three drivers share the exact same round math (`_round_math`):

  make_train_step   — one host-authorized round per dispatch (the
                      mechanism's Python ledger decides refusal).
  make_fused_rounds — K rounds per dispatch via lax.scan, with budget
                      accounting device-resident (`AsyncDPState.ledger`, a
                      privacy.DeviceLedger): authorization is an in-graph
                      predicate and refusal is jnp.where masking, so
                      thousands of asynchronous rounds run without a host
                      round-trip. Bit-for-bit equal to the per-round loop
                      under the same per-round keys.
  make_group_rounds — owner-parallel mode: lax.scan over CONFLICT-FREE
                      round groups (consecutive rounds with distinct
                      owners, see schedules.partition_conflict_free), vmap
                      over the members of each group, ONE inertia
                      reduction of theta_L per group. Ledger spend is
                      exactly the sequential scan's; theta_L takes the
                      mean of the group's eq.(7) targets (a documented,
                      bounded deviation that vanishes at group size 1).

Every driver accepts `mesh=None`: given a Mesh, flat states are pinned to
the `repro.sharding.rules.flat_shardings` layout (bank rows over the data
axes, P like the model) with `jax.lax.with_sharding_constraint` INSIDE the
scan bodies, so the bank row gather/scatter stays local in P and the scan
carry never gathers to one device.

DP-FTRL tree noise (cfg.tree_depth, `TreeNoise` on the state): every
driver advances the per-owner binary noise tree INSIDE its scan body —
one row gather, a popcount-pattern node refresh (Pallas kernel family
`repro.kernels.tree_noise` on the fused flat path, its jnp oracle
elsewhere), one row scatter — with refusals masked to bit-exact no-ops
exactly like the bank, and tree nodes sharded like bank rows.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, NamedTuple, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.federation import faults as _faults
from repro.federation.config import paper_rates
from repro.federation.dp_sgd import (PrivatizerConfig, _group_batch,
                                     private_grad, resolve_interpret)
from repro.federation.faults import FaultPolicy, FaultState, init_fault_state
from repro.federation.flatten import (FlatSpec, PagedBank, ParamFlat,
                                      QuantBank, init_flat_bank, pack_params)
from repro.federation.privacy import DeviceLedger, make_device_ledger
from repro.federation.staleness import (StalenessPolicy, StalenessState,
                                        deadline_guard, init_staleness_state,
                                        staleness_tick, staleness_weight)


@dataclasses.dataclass(frozen=True)
class AsyncDPConfig:
    n_owners: int
    horizon: int                       # T
    rho: float = 1.0
    sigma: float = 1e-4                # strong-convexity of g = (sigma/2)||.||^2
    epsilons: Sequence[float] = ()     # per-owner budgets
    owner_sizes: Sequence[int] = ()    # n_i (records per owner)
    xi: float = 1.0                    # clip norm / Assumption-2 bound
    theta_max: float = 100.0           # Theta projection radius (l_inf)
    privatizer: PrivatizerConfig = PrivatizerConfig(xi=1.0)
    lr_scale: float = 1.0              # 1.0 == paper-faithful
    init_bank_zero: bool = False       # paper inits all copies to 0
    caps: Optional[Sequence[int]] = None  # per-owner response caps (None = T)
    # DP-FTRL tree-aggregated noise (Kairouz et al. 2021): None = the
    # paper's independent per-round mechanism; d >= 1 = each owner carries
    # a depth-d binary noise tree (AsyncDPState.tree) and every response
    # releases the active-node-sum DELTA, so cumulative noise over t
    # responses is popcount(t) <= d node draws at per-node scale d*b(R),
    # R = min(cap, 2^d - 1). d = 0 is the degenerate tree: bit-for-bit
    # the paper mechanism (parity contract, exercised by tests).
    tree_depth: Optional[int] = None
    # Fault tolerance (see repro.federation.faults): None = the fault
    # layer is OFF and every driver traces the PR-7 program verbatim;
    # a FaultPolicy arms the in-graph guards (payload checksums,
    # non-finite detection, stale rejection) and quarantine windows,
    # and the state gains a FaultState (AsyncDPState.faults).
    fault_policy: Optional[FaultPolicy] = None
    # Asynchronous runtime (see repro.federation.staleness): None = no
    # latency/deadline/retry/decay concept and the drivers trace the
    # fault-armed program verbatim; a StalenessPolicy adds the TIMEOUT
    # outcome, per-owner retry-with-backoff counters and the
    # decay**age inertia weight, and the state gains a StalenessState
    # (AsyncDPState.stale). Requires fault_policy (TIMEOUT lives in the
    # fault algebra) — a never-quarantine policy changes nothing.
    staleness: Optional[StalenessPolicy] = None

    @property
    def n_total(self) -> int:
        return sum(self.owner_sizes)

    @property
    def effective_caps(self) -> Tuple[int, ...]:
        if self.caps is None:
            return (self.horizon,) * self.n_owners
        return tuple(self.caps)


class AsyncDPState(NamedTuple):
    theta_L: Any                       # central model pytree
    bank: Any                          # same pytree, leaves (N, ...)
    step: jax.Array                    # () int32
    # Device-resident budget counters (see privacy.DeviceLedger). The
    # per-round step() leaves it untouched (host authorization); the fused
    # multi-round driver spends/refuses in-graph.
    ledger: Optional[DeviceLedger] = None
    # Device-resident DP-FTRL noise trees (TreeNoise) when
    # cfg.tree_depth is set; None for the independent-noise mechanisms.
    tree: Optional[Any] = None
    # Device-resident fault-layer arrays (faults.FaultState) when
    # cfg.fault_policy is set: per-owner bank-row checksums, fault
    # windows, quarantine flags. None = fault layer off.
    faults: Optional[FaultState] = None
    # Device-resident async-runtime counters (staleness.StalenessState)
    # when cfg.staleness is set: round clock, per-owner last-grant ages,
    # backoff cooldowns, retry budgets. None = runtime layer off.
    stale: Optional[StalenessState] = None


@jax.tree_util.register_pytree_node_class
class TreeNoise:
    """Per-owner DP-FTRL noise-tree state (device-resident).

    `nodes` holds every owner's live node values: for flat states one
    (N_owners, depth, P) f32 matrix; for pytree states the model pytree
    with (N_owners, depth, *leaf.shape) f32 leaves (ALWAYS f32 — the
    noise calibration must not be laundered through a bf16 model dtype).
    `counts` is (N_owners,) int32 leaves released so far — the online
    binary counter whose bit pattern determines which nodes retire and
    which level holds the fresh draw at each increment. `depth` is static
    pytree metadata (it selects the traced program).
    """

    def __init__(self, nodes: Any, counts: jax.Array, depth: int):
        self.nodes = nodes
        self.counts = counts
        self.depth = depth

    def tree_flatten(self):
        return (self.nodes, self.counts), self.depth

    @classmethod
    def tree_unflatten(cls, depth, children):
        return cls(*children, depth=depth)

    def replace(self, **kw) -> "TreeNoise":
        fields = {"nodes": self.nodes, "counts": self.counts,
                  "depth": self.depth}
        fields.update(kw)
        return TreeNoise(**fields)


def init_tree_noise(cfg: AsyncDPConfig, theta_L) -> Optional[TreeNoise]:
    """Fresh (all-zero) noise trees matching `theta_L`'s representation;
    None when cfg.tree_depth is None (independent-noise mechanisms)."""
    if cfg.tree_depth is None:
        return None
    d, n = cfg.tree_depth, cfg.n_owners
    if isinstance(theta_L, ParamFlat):
        nodes = jnp.zeros((n, d, theta_L.size), jnp.float32)
    else:
        nodes = jax.tree_util.tree_map(
            lambda leaf: jnp.zeros((n, d) + leaf.shape, jnp.float32), theta_L)
    return TreeNoise(nodes, jnp.zeros((n,), jnp.int32), d)


def _tree_row_of(tree: TreeNoise, owner_idx, row_idx=None):
    """Gather one owner's (depth, ...) node row + its leaf count.

    `row_idx` separates the NODE-ROW index from the COUNTER index for
    paged states (nodes page with the bank's hot slots, (n_hot, d, P);
    the leaf counters stay a per-owner (N,) column). None keeps both
    equal to `owner_idx` — the non-paged trace, verbatim."""
    ridx = owner_idx if row_idx is None else row_idx
    row = jax.tree_util.tree_map(
        lambda leaf: jax.lax.dynamic_index_in_dim(leaf, ridx, 0,
                                                  keepdims=False),
        tree.nodes)
    return row, tree.counts[owner_idx]


def _tree_write(tree: TreeNoise, new_row, owner_idx, grant=1,
                row_idx=None) -> TreeNoise:
    """Scatter an owner's node row back and bump its leaf counter by
    `grant` (0/1 — the fused driver passes the grant bit; callers mask
    `new_row` back to the old row on refusal, so a refused round is a
    bit-exact no-op on the whole tree). `row_idx` (paged states) puts
    the node scatter at the hot slot while the counter stays per-owner."""
    ridx = owner_idx if row_idx is None else row_idx
    nodes = jax.tree_util.tree_map(
        lambda leaf, v: jax.lax.dynamic_update_index_in_dim(leaf, v,
                                                            ridx, 0),
        tree.nodes, new_row)
    return tree.replace(nodes=nodes,
                        counts=tree.counts.at[owner_idx].add(grant))


def _init_staleness(cfg: AsyncDPConfig) -> Optional[StalenessState]:
    """Fresh runtime counters when cfg.staleness is armed; refuses a
    staleness config without the fault layer (TIMEOUT is a fault code,
    and every driver's staleness algebra lives in its faulted body)."""
    if cfg.staleness is None:
        return None
    if cfg.fault_policy is None:
        raise ValueError(
            "cfg.staleness rides on the fault algebra (TIMEOUT is a fault "
            "code); arm cfg.fault_policy too — a never-quarantine "
            "FaultPolicy(max_faults=2**30, window=2**30) changes nothing")
    return init_staleness_state(cfg.n_owners, cfg.staleness)


def init_state(params, cfg: AsyncDPConfig) -> AsyncDPState:
    if cfg.init_bank_zero:
        params = jax.tree_util.tree_map(jnp.zeros_like, params)
    bank = jax.tree_util.tree_map(
        lambda leaf: jnp.broadcast_to(leaf[None], (cfg.n_owners,) + leaf.shape), params)
    faults = (None if cfg.fault_policy is None
              else init_fault_state(bank, cfg.n_owners))
    return AsyncDPState(params, bank, jnp.zeros((), jnp.int32),
                        make_device_ledger(cfg.effective_caps),
                        init_tree_noise(cfg, params), faults,
                        _init_staleness(cfg))


def init_state_flat(params, cfg: AsyncDPConfig,
                    bank_dtype=None, mesh=None) -> AsyncDPState:
    """Flat-buffer state: theta_L is a ParamFlat (one contiguous (P,) f32
    buffer) and the owner bank is a single (N_owners, P) matrix, so bank
    gather/scatter is one row slice instead of per-leaf dynamic indexing.
    Both drivers accept either state kind and dispatch on it.

    `bank_dtype` (None = float32) narrows the bank STORAGE only — e.g.
    bf16 halves the N*P resident bytes and the fused scan's loop-carry
    traffic; rows upcast to f32 on gather. The strings "int8"/"fp8" (or
    a flatten.BankCodec) build a QUANTIZED bank instead: 1-byte codes +
    per-row f32 scales + an error-feedback residual row, ~4x below f32
    (rows decode on gather; granted rounds re-encode with stochastic
    rounding driven by the round key). f32 keeps the bit-parity
    contract with the tree path.

    `mesh` (None = single-device) lays the state out under the
    repro.sharding.rules.flat_shardings rules: bank rows over the data
    axes, P like the model, ledger counters replicated. Pass the same
    mesh to the driver builders so the scan bodies keep the layout."""
    if cfg.init_bank_zero:
        params = jax.tree_util.tree_map(jnp.zeros_like, params)
    flat = pack_params(params)
    ledger = make_device_ledger(cfg.effective_caps)
    tree = init_tree_noise(cfg, flat)
    if mesh is None:
        bank = init_flat_bank(flat, cfg.n_owners, bank_dtype)
    else:
        if (mesh.devices.size > 1
                and not jax.config.jax_threefry_partitionable):
            import warnings
            warnings.warn(
                "multi-device federation without "
                "jax_threefry_partitionable: the legacy threefry lowering "
                "re-associates counters under SPMD partitioning, so noise "
                "draws (still lawful Laplace samples) differ from the "
                "single-device program under the same keys; enable "
                "jax.config.update('jax_threefry_partitionable', True) "
                "for topology-independent draws", UserWarning,
                stacklevel=2)
        from repro.sharding.rules import flat_shardings
        sh = flat_shardings(mesh, cfg.n_owners, flat.size)
        flat = ParamFlat(jax.device_put(flat.buf, sh.theta), flat.spec)
        bank = init_flat_bank(flat, cfg.n_owners, bank_dtype,
                              sharding=sh.bank,
                              scales_sharding=sh.bank_scales,
                              residual_sharding=sh.row)
        ledger = jax.device_put(ledger, sh.ledger)
        if tree is not None:
            tree = TreeNoise(jax.device_put(tree.nodes, sh.tree_nodes),
                             jax.device_put(tree.counts, sh.ledger),
                             tree.depth)
    faults = (None if cfg.fault_policy is None
              else init_fault_state(bank, cfg.n_owners))
    if faults is not None and mesh is not None:
        from repro.sharding.rules import flat_shardings
        sh = flat_shardings(mesh, cfg.n_owners, flat.size)
        faults = jax.device_put(faults, sh.faults)
    stale = _init_staleness(cfg)
    if stale is not None and mesh is not None:
        # per-owner (N,) runtime counters replicate like the ledger
        stale = jax.device_put(stale, sh.ledger)
    return AsyncDPState(flat, bank, jnp.zeros((), jnp.int32), ledger, tree,
                        faults, stale)


def _flat_shardings_for(mesh, theta_L, bank):
    """FlatShardings for a flat state on `mesh` (None for tree states or
    no mesh). Called at TRACE time — shapes are static there, so the
    divisibility degrades in the rules see the real N and P."""
    if mesh is None or not isinstance(theta_L, ParamFlat):
        return None
    from repro.sharding.rules import flat_shardings
    if isinstance(bank, PagedBank):
        # hot rows shard like bank rows, n_hot standing in for N (the
        # per-owner (N,) counters are replicated either way)
        bank = bank.hot
    n = bank.n_owners if isinstance(bank, QuantBank) else bank.shape[0]
    return flat_shardings(mesh, n, theta_L.size)


def _constrain(x, sharding):
    """with_sharding_constraint that understands ParamFlat and None."""
    if sharding is None:
        return x
    if isinstance(x, ParamFlat):
        return x.replace_buf(
            jax.lax.with_sharding_constraint(x.buf, sharding))
    return jax.lax.with_sharding_constraint(x, sharding)


def _constrain_bank(bank, sh):
    """Pin a bank to the mesh layout: dense (N, P) matrices to sh.bank;
    quantized banks pin codes/scales/residual to their bundle entries.
    Paged banks pin the hot tier recursively (sh was built from n_hot)
    and the page table to the replicated counter rule."""
    if sh is None:
        return bank
    if isinstance(bank, PagedBank):
        return bank.replace(
            hot=_constrain_bank(bank.hot, sh),
            hot_ids=jax.lax.with_sharding_constraint(bank.hot_ids,
                                                     sh.ledger))
    if isinstance(bank, QuantBank):
        return QuantBank(
            jax.lax.with_sharding_constraint(bank.codes, sh.bank),
            jax.lax.with_sharding_constraint(bank.scales, sh.bank_scales),
            jax.lax.with_sharding_constraint(bank.residual, sh.row),
            bank.codec)
    return jax.lax.with_sharding_constraint(bank, sh.bank)


def _constrain_tree(tr, sh):
    """Pin flat TreeNoise nodes to the (N, depth, P) rule; pytree nodes
    and meshless runs pass through."""
    if tr is None or sh is None or getattr(sh, "tree_nodes", None) is None:
        return tr
    if not isinstance(tr.nodes, jax.Array):
        return tr
    return tr.replace(nodes=jax.lax.with_sharding_constraint(
        tr.nodes, sh.tree_nodes))


def _require_tree(cfg: AsyncDPConfig, state: AsyncDPState):
    """The state's TreeNoise when cfg asks for one (raising on states
    built before the tree was configured); None otherwise."""
    if cfg.tree_depth is not None and state.tree is None:
        raise ValueError(
            "cfg.tree_depth is set but the state carries no noise tree; "
            "build the state with init_state / init_state_flat / "
            "Federation.init_state under the same config")
    return state.tree


# --------------------- quantized-bank row round-trip -----------------------
# The codec RNG stream is the round key folded with a fixed salt, so the
# stochastic-rounding draws never collide with (or shift) the privacy
# noise draws inside private_grad — an int8/fp8 run sees the SAME Laplace
# noise as the f32 run under the same keys, isolating quantization as the
# only trajectory difference.
_CODEC_SALT = 0x5142                    # "QB"


def _codec_key(key):
    return jax.random.fold_in(key, _CODEC_SALT)


def _decode_bank_row(bank: QuantBank, owner_idx, pcfg: PrivatizerConfig):
    """Gather one owner row: slice codes+scales, decode to (P,) f32."""
    from repro.kernels.bank_codec.ops import decode_row
    codes = jax.lax.dynamic_index_in_dim(bank.codes, owner_idx, 0,
                                         keepdims=False)
    scales = jax.lax.dynamic_index_in_dim(bank.scales, owner_idx, 0,
                                          keepdims=False)
    return decode_row(codes, scales, bank.codec.fmt,
                      block_elems=bank.codec.block_elems,
                      block_rows=pcfg.kernel_block_rows,
                      interpret=resolve_interpret(pcfg.kernel_interpret))


def _encode_bank_row(bank: QuantBank, value, key,
                     pcfg: PrivatizerConfig):
    """Encode one f32 row (+ the EF residual already folded into `value`)
    -> (codes (P,), scales (nb,), err (P,))."""
    from repro.kernels.bank_codec.ops import encode_row
    return encode_row(value, _codec_key(key), bank.codec.fmt,
                      block_elems=bank.codec.block_elems,
                      block_rows=pcfg.kernel_block_rows,
                      interpret=resolve_interpret(pcfg.kernel_interpret))


def _quant_write(bank, new_i, owner_idx, key,
                 pcfg: PrivatizerConfig, ok=None) -> QuantBank:
    """Scatter a granted owner update into a quantized bank.

    The shared residual row is folded into the value BEFORE encoding
    (error feedback), and the fresh quantization error becomes the next
    residual. `ok` (a traced bool, fused-driver refusal masking) selects
    between the new row and the owner's untouched codes/scales — and
    leaves the residual alone on refusal, so a refused round stays a
    bit-exact no-op on the whole bank. A PagedBank recurses on its hot
    tier — `owner_idx` is then the HOT SLOT the caller resolved."""
    if isinstance(bank, PagedBank):
        return bank.replace(hot=_quant_write(bank.hot, new_i, owner_idx,
                                             key, pcfg, ok=ok))
    codes_n, scales_n, err = _encode_bank_row(bank, new_i + bank.residual,
                                              key, pcfg)
    if ok is None:
        residual = err
    else:
        codes_o = jax.lax.dynamic_index_in_dim(bank.codes, owner_idx, 0,
                                               keepdims=False)
        scales_o = jax.lax.dynamic_index_in_dim(bank.scales, owner_idx, 0,
                                                keepdims=False)
        codes_n = jnp.where(ok, codes_n, codes_o)
        scales_n = jnp.where(ok, scales_n, scales_o)
        residual = jnp.where(ok, err, bank.residual)
    return QuantBank(
        jax.lax.dynamic_update_index_in_dim(bank.codes, codes_n,
                                            owner_idx, 0),
        jax.lax.dynamic_update_index_in_dim(bank.scales, scales_n,
                                            owner_idx, 0),
        residual, bank.codec)


def _noise_scales(cfg: AsyncDPConfig) -> jnp.ndarray:
    """Theorem-1 scale per owner (for the averaged clipped gradient).

    Under the tree mechanism (cfg.tree_depth = d >= 1) this is the
    PER-NODE scale: each response participates in d node queries over a
    horizon of at most R = effective cap responses, so Laplace
    composition gives b_node = d * b_theorem1(R). depth 0 degenerates to
    the paper scale exactly (levels = 1, horizon = T)."""
    from repro.federation.privacy import laplace_scale_theorem1
    levels = cfg.tree_depth if cfg.tree_depth else 1
    horizons = (cfg.effective_caps if cfg.tree_depth
                else (cfg.horizon,) * cfg.n_owners)
    return jnp.asarray([
        levels * laplace_scale_theorem1(cfg.xi, h, n_i, e)
        for h, n_i, e in zip(horizons, cfg.owner_sizes, cfg.epsilons)],
        jnp.float32)


def _round_math(loss_fn, cfg: AsyncDPConfig, scales: Optional[jax.Array]):
    """The paper's inertia round (eqs. 5-7), shared VERBATIM between the
    per-round step and the fused multi-round driver so both trace the exact
    same op sequence (bit-for-bit equivalence under fixed keys).

    Returns compute(theta_L, bank, batch, owner_idx, key, tree_row=None,
    tree_count=None) -> (new_L, new_i, theta_i, metrics, new_tree_row).
    The bank-gather-free core is exposed as
    `compute.inner(theta_L, theta_i, batch, owner_idx, key, noise_extra)`:
    the flat engine's reference mode traces that SAME function on its
    unpacked buffers, which is what makes flat-vs-tree bit parity hold.
    `noise_extra` (None for the independent mechanisms) is the DP-FTRL
    retired-node correction added to the response; when it is given,
    inner also returns the fresh Laplace draw so the caller can install
    it as the tree's new node WITHOUT re-consuming the round key."""
    scales = _noise_scales(cfg) if scales is None else jnp.asarray(
        scales, jnp.float32)
    n_i = jnp.asarray(cfg.owner_sizes, jnp.float32)
    n = float(cfg.n_total)
    N, T = cfg.n_owners, cfg.horizon
    lr_own, lr_L = paper_rates(N, T, cfg.rho, cfg.sigma, cfg.lr_scale)

    def project(tree):
        return jax.tree_util.tree_map(
            lambda leaf: jnp.clip(leaf, -cfg.theta_max, cfg.theta_max), tree)

    def inner(theta_L, theta_i, batch, owner_idx, key, noise_extra=None):
        theta_bar = jax.tree_util.tree_map(
            lambda a, b: 0.5 * (a + b), theta_L, theta_i)             # (6)

        if noise_extra is None:
            qbar, pm = private_grad(loss_fn, theta_bar, batch, key,
                                    cfg=cfg.privatizer,
                                    noise_scale=scales[owner_idx])    # (3)+(4)
            zeta = None
        else:
            # tree mechanism: the response carries zeta - sum(retired
            # nodes); `noise_extra` IS that retired-node sum (negated),
            # and the fresh draw comes back so the caller installs it as
            # the new node from the SAME single key consumption.
            qbar, pm, zeta = private_grad(loss_fn, theta_bar, batch, key,
                                          cfg=cfg.privatizer,
                                          noise_scale=scales[owner_idx],
                                          return_noise=True)
            qbar = jax.tree_util.tree_map(
                lambda q, e: (q.astype(jnp.float32) + e).astype(q.dtype),
                qbar, noise_extra)
        g_reg = jax.tree_util.tree_map(
            lambda leaf: cfg.sigma * leaf.astype(jnp.float32), theta_bar)   # grad g

        w_i = n_i[owner_idx] / n
        new_i = project(jax.tree_util.tree_map(
            lambda tb, gg, q: tb - lr_own * (gg / (2 * N)
                                             + w_i * q.astype(jnp.float32)
                                             ).astype(tb.dtype),
            theta_bar, g_reg, qbar))                                   # (5)
        new_L = project(jax.tree_util.tree_map(
            lambda tb, gg: tb - (lr_L * gg).astype(tb.dtype),
            theta_bar, g_reg))                                         # (7)
        metrics = {"clip_frac": pm["clip_frac"],
                   "max_grad_norm": pm["max_grad_norm"],
                   "grad_noise_scale": scales[owner_idx]}
        return new_L, new_i, metrics, zeta

    def compute(theta_L, bank, batch, owner_idx, key,
                tree_row=None, tree_count=None, row_idx=None,
                stale_w=None):
        if isinstance(bank, PagedBank):
            raise TypeError(
                "PagedBank needs the flat engine (paging.init_paged_state "
                "builds ParamFlat states); the pytree path cannot page")
        del row_idx                 # pytree banks index rows by owner
        theta_i = jax.tree_util.tree_map(
            lambda leaf: jax.lax.dynamic_index_in_dim(leaf, owner_idx, 0,
                                                   keepdims=False),
            bank)
        # decayed inertia target (staleness.staleness_weight): the round
        # runs against a copy pulled toward theta_L, but the RAW row is
        # what comes back — masked rounds write it back verbatim. The
        # hook is statically absent at decay=1 (verbatim trace).
        theta_eff = theta_i if stale_w is None else jax.tree_util.tree_map(
            lambda l, i: (l.astype(jnp.float32) + stale_w
                          * (i.astype(jnp.float32) - l.astype(jnp.float32))
                          ).astype(i.dtype), theta_L, theta_i)
        d = cfg.tree_depth
        if tree_row is None or not d:
            # no tree, or the degenerate depth-0 tree: the round IS the
            # independent-noise round (bit-for-bit — parity contract)
            new_L, new_i, metrics, _ = inner(theta_L, theta_eff, batch,
                                             owner_idx, key)
            return new_L, new_i, theta_i, metrics, tree_row
        if cfg.privatizer.fused_kernel:
            raise ValueError(
                "tree mechanism with fused_kernel needs the flat engine "
                "(init_state_flat) — the pytree path's fused privatizer "
                "adds its noise in-kernel and cannot split out the draw")
        from repro.kernels.tree_noise.ref import tree_masks_ref
        retired, fresh = tree_masks_ref(tree_count, d)        # (d,) bools

        def bcast(m, leaf):
            return m.reshape((d,) + (1,) * (leaf.ndim - 1))

        extra = jax.tree_util.tree_map(
            lambda nd: -jnp.sum(jnp.where(bcast(retired, nd), nd, 0.0),
                                axis=0), tree_row)
        new_L, new_i, metrics, zeta = inner(theta_L, theta_eff, batch,
                                            owner_idx, key,
                                            noise_extra=extra)
        new_row = jax.tree_util.tree_map(
            lambda nd, z: jnp.where(
                bcast(fresh, nd), z[None].astype(jnp.float32),
                jnp.where(bcast(retired, nd), 0.0, nd)),
            tree_row, zeta)
        return new_L, new_i, theta_i, metrics, new_row

    compute.inner = inner
    return compute


def _flat_clipped_grad_acc(loss_fn, spec: FlatSpec, pcfg: PrivatizerConfig,
                           tb: jax.Array, batch):
    """Sum of per-group clipped (P,) gradients at theta_bar + group gain.

    The gradient is the ordinary tree gradient at `spec.unpack(tb)` packed
    into ONE concat (cheaper than differentiating through the unpack,
    whose transpose pads every leaf cotangent to (P,)); per-group clip
    norms run through the blockwise Pallas squared-norm pass (jnp oracle
    off-TPU). Returns (acc, gain, metrics) with the group-mean divide
    DEFERRED into `gain` so dp_round can fuse it with the noise add and
    the inertia updates.
    """
    from repro.kernels.dp_clip_noise.ops import fused_sqnorm_tree
    interp = resolve_interpret(pcfg.kernel_interpret)
    tb_tree = spec.unpack(tb)

    def flat_grad(mb):
        return spec.pack(jax.grad(loss_fn)(tb_tree, mb))   # (P,)

    def sqnorm(g):
        return fused_sqnorm_tree(g, block_rows=pcfg.kernel_block_rows,
                                 interpret=interp)

    if pcfg.granularity == "example":
        B = jax.tree_util.tree_leaves(batch)[0].shape[0]
        grads = jax.vmap(lambda ex: flat_grad(
            jax.tree_util.tree_map(lambda a: a[None], ex)))(batch)  # (B, P)
        norms = jnp.sqrt(jnp.sum(jnp.square(grads), axis=1))
        scale = jnp.minimum(1.0, pcfg.xi / jnp.maximum(norms, 1e-12))
        acc = jnp.sum(grads * scale[:, None], axis=0)
        return acc, 1.0 / B, {
            "clip_frac": jnp.mean((norms > pcfg.xi).astype(jnp.float32)),
            "max_grad_norm": jnp.max(norms)}
    if pcfg.granularity != "microbatch":
        raise ValueError(pcfg.granularity)

    G = pcfg.n_microbatches
    B = jax.tree_util.tree_leaves(batch)[0].shape[0]
    if not pcfg.pre_grouped:
        assert B % G == 0, (B, G)

    if G == 1:
        # single-group fast path: no scan wrapper, no accumulator init
        mb = (jax.tree_util.tree_map(lambda a: a[0], batch)
              if pcfg.pre_grouped else batch)
        g = flat_grad(mb)
        norm = jnp.sqrt(sqnorm(g))
        s = jnp.minimum(1.0, pcfg.xi / jnp.maximum(norm, 1e-12))
        return g * s, 1.0, {
            "clip_frac": (norm > pcfg.xi).astype(jnp.float32),
            "max_grad_norm": norm}

    def body(carry, mb):
        acc, nclip, mx = carry
        g = flat_grad(mb)
        norm = jnp.sqrt(sqnorm(g))
        s = jnp.minimum(1.0, pcfg.xi / jnp.maximum(norm, 1e-12))
        return (acc + g * s, nclip + (norm > pcfg.xi),
                jnp.maximum(mx, norm)), None

    xs = batch if pcfg.pre_grouped else _group_batch(batch, G)
    (acc, nclip, mx), _ = jax.lax.scan(
        body, (jnp.zeros_like(tb), jnp.zeros((), jnp.float32),
               jnp.zeros((), jnp.float32)), xs)
    return acc, 1.0 / G, {"clip_frac": nclip / G, "max_grad_norm": mx}


def _round_math_flat(loss_fn, cfg: AsyncDPConfig, scales: Optional[jax.Array],
                     tree_inner, mesh=None):
    """The same inertia round over the flat representation.

    With `privatizer.fused_kernel=False` this is the REFERENCE mode: the
    owner's bank row is gathered as ONE (P,) slice, theta_L and the row are
    unpacked behind an optimization barrier, and the round runs the
    IDENTICAL `tree_inner` trace as the tree path (same per-leaf RNG
    splits, same op sequence — the barrier keeps XLA from re-fusing the
    slice views into it), so results are bit-for-bit `spec.pack()` of the
    tree path's output for f32 models under the same per-round keys.

    With `fused_kernel=True` the gradient is taken directly w.r.t. the
    flat buffer and the whole post-gradient round — group mean, Laplace
    add, eqs. (5)/(7), projection — is ONE `dp_round` Pallas pass over the
    buffer (in-kernel inverse-CDF noise: statistically, not bitwise,
    equivalent — PR 2's kernel contract).
    """
    scales = _noise_scales(cfg) if scales is None else jnp.asarray(
        scales, jnp.float32)
    n_i = jnp.asarray(cfg.owner_sizes, jnp.float32)
    n = float(cfg.n_total)
    N = cfg.n_owners
    lr_own, lr_L = paper_rates(N, cfg.horizon, cfg.rho, cfg.sigma,
                               cfg.lr_scale)
    pcfg = cfg.privatizer

    def compute(theta_L: ParamFlat, bank, batch, owner_idx, key,
                tree_row=None, tree_count=None, row_idx=None,
                stale_w=None):
        spec = theta_L.spec
        sh = _flat_shardings_for(mesh, theta_L, bank)
        d = cfg.tree_depth
        tree_on = tree_row is not None and d          # static (trace-time)
        # paged banks gather from the hot tier at the RESOLVED slot;
        # row_idx=None + a non-paged bank leaves the trace verbatim
        # (ridx IS owner_idx). Scales/weights always index by owner.
        hot = bank.hot if isinstance(bank, PagedBank) else bank
        ridx = owner_idx if row_idx is None else row_idx
        if isinstance(hot, QuantBank):
            theta_i = _decode_bank_row(hot, ridx, pcfg)            # (P,)
        else:
            theta_i = jax.lax.dynamic_index_in_dim(hot, ridx, 0,
                                                   keepdims=False)  # (P,)
        if sh is not None:
            # the gathered row keeps the bank's P-axis layout (== theta's),
            # so theta_bar and the whole round stay local in P
            theta_i = jax.lax.with_sharding_constraint(theta_i, sh.row)
        # decayed inertia target — same contract as the pytree path: the
        # round consumes the pulled-in copy, the RAW row is returned for
        # the masked write-backs. Statically absent at decay=1.
        theta_eff = (theta_i if stale_w is None
                     else theta_L.buf + stale_w * (theta_i - theta_L.buf))
        if pcfg.fused_kernel:
            if pcfg.mechanism != "laplace":
                raise ValueError(
                    "fused_kernel implements the laplace mechanism")
            from repro.kernels.dp_clip_noise.ops import dp_round_flat
            tb = 0.5 * (theta_L.buf + theta_eff)                   # (6)
            ns = scales[owner_idx]
            acc, gain, pm = _flat_clipped_grad_acc(loss_fn, spec, pcfg,
                                                   tb, batch)
            if tree_on:
                # tree mechanism: the round key feeds ONLY the tree op
                # (the fresh node draw); the response adds the node
                # DELTA, then the epilogue repeats dp_round_ref's exact
                # op order so depth-0 (no node traffic, delta == draw)
                # stays bit-identical to the dp_round_flat path.
                from repro.kernels.tree_noise.ops import tree_delta_row
                delta, new_row = tree_delta_row(
                    tree_row, tree_count, key, ns,
                    block_rows=min(pcfg.kernel_block_rows, 64),
                    interpret=resolve_interpret(pcfg.kernel_interpret))
                q = acc * gain + delta                              # (4)
                g_reg = cfg.sigma * tb
                new_i = jnp.clip(
                    tb - lr_own * (g_reg * (1.0 / (2 * N))
                                   + (n_i[owner_idx] / n) * q),
                    -cfg.theta_max, cfg.theta_max)                  # (5)
                new_L = jnp.clip(tb - lr_L * g_reg,
                                 -cfg.theta_max, cfg.theta_max)     # (7)
            else:
                new_L, new_i = dp_round_flat(              # (4)+(5)+(7)+Pi
                    tb, acc, key, gain, ns, n_i[owner_idx] / n,
                    sigma=cfg.sigma, lr_own=lr_own, lr_l=lr_L, n_owners=N,
                    theta_max=cfg.theta_max,
                    block_rows=pcfg.kernel_block_rows,
                    interpret=resolve_interpret(pcfg.kernel_interpret))
                new_row = tree_row
            metrics = {"clip_frac": pm["clip_frac"],
                       "max_grad_norm": pm["max_grad_norm"],
                       "grad_noise_scale": ns}
        else:
            if tree_on:
                from repro.kernels.tree_noise.ref import tree_masks_ref
                retired, fresh = tree_masks_ref(tree_count, d)  # (d,) bool
                extra = spec.unpack_f32(
                    -jnp.sum(jnp.where(retired[:, None], tree_row, 0.0),
                             axis=0))
            else:
                extra = None
            try:
                tl_tree, ti_tree = jax.lax.optimization_barrier(
                    (spec.unpack(theta_L.buf), spec.unpack(theta_eff)))
            except NotImplementedError:
                # no batching rule for the barrier (vmapped by the
                # owner-parallel grouped driver). The barrier is
                # semantically identity — only an anti-fusion hint that
                # protects the scan-carry BIT-parity contract, which the
                # grouped mode does not promise for groups > 1 anyway.
                tl_tree, ti_tree = (spec.unpack(theta_L.buf),
                                    spec.unpack(theta_eff))
            new_L_t, new_i_t, metrics, zeta = tree_inner(
                tl_tree, ti_tree, batch, owner_idx, key,
                noise_extra=extra)
            new_L, new_i = spec.pack(new_L_t), spec.pack(new_i_t)
            if tree_on:
                zf = spec.pack_f32(zeta)
                new_row = jnp.where(fresh[:, None], zf[None],
                                    jnp.where(retired[:, None], 0.0,
                                              tree_row))
            else:
                new_row = tree_row
        return ParamFlat(new_L, spec), new_i, theta_i, metrics, new_row

    return compute


def _round_compute(loss_fn, cfg: AsyncDPConfig, scales: Optional[jax.Array],
                   mesh=None):
    """Dispatch the round math on the state representation: ParamFlat
    states run the flat engine, pytree states the reference tree path.
    All drivers share this, so one built step function serves either
    state kind (jit specializes per structure)."""
    if cfg.tree_depth is not None:
        if not 0 <= cfg.tree_depth <= 30:
            raise ValueError(
                f"tree_depth must be in [0, 30], got {cfg.tree_depth}")
        if cfg.tree_depth:
            cap_max = (1 << cfg.tree_depth) - 1
            if max(cfg.effective_caps) > cap_max:
                # past 2^d - 1 leaves the online binary counter has no
                # level left for the fresh node and the variance
                # accounting silently breaks — refuse at build time
                raise ValueError(
                    f"depth-{cfg.tree_depth} tree holds {cap_max} leaves "
                    f"but effective caps reach "
                    f"{max(cfg.effective_caps)}; lower cfg.caps or deepen "
                    f"the tree")
    tree_c = _round_math(loss_fn, cfg, scales)
    flat_c = _round_math_flat(loss_fn, cfg, scales, tree_c.inner, mesh=mesh)

    def compute(theta_L, bank, batch, owner_idx, key,
                tree_row=None, tree_count=None, row_idx=None,
                stale_w=None):
        if isinstance(theta_L, ParamFlat):
            return flat_c(theta_L, bank, batch, owner_idx, key,
                          tree_row=tree_row, tree_count=tree_count,
                          row_idx=row_idx, stale_w=stale_w)
        return tree_c(theta_L, bank, batch, owner_idx, key,
                      tree_row=tree_row, tree_count=tree_count,
                      row_idx=row_idx, stale_w=stale_w)

    return compute


def _write_bank(bank, value, owner_idx):
    if isinstance(bank, PagedBank):    # paged: callers pass the HOT SLOT
        return bank.replace(hot=_write_bank(bank.hot, value, owner_idx))
    if isinstance(bank, jax.Array):    # flat (N, P) bank: one row scatter
        return jax.lax.dynamic_update_index_in_dim(
            bank, value.astype(bank.dtype), owner_idx, 0)
    return jax.tree_util.tree_map(
        lambda leaf, v: jax.lax.dynamic_update_index_in_dim(
            leaf, v.astype(leaf.dtype), owner_idx, 0),
        bank, value)


def _bank_slot(bank, owner_idx):
    """(row_idx, hit) for one owner contact.

    Paged banks resolve owner -> hot slot in-graph via the device page
    table (see PagedBank.lookup); the drivers fold `hit` into their
    grant mask, so a non-resident owner is a bit-exact masked no-op.
    Non-paged banks index rows BY OWNER: (None, None) keeps every
    downstream trace verbatim (no lookup op, unconditional grant).
    """
    if isinstance(bank, PagedBank):
        return bank.lookup(owner_idx)
    return None, None


def _bank_is_quant(bank) -> bool:
    """Static: does this bank store quantized rows (possibly paged)?"""
    hot = bank.hot if isinstance(bank, PagedBank) else bank
    return isinstance(hot, QuantBank)


def _require_fault_policy(cfg: AsyncDPConfig, state: AsyncDPState):
    """Trace-time consistency check between the config's fault policy and
    the state's FaultState (both present or both absent)."""
    if state.faults is not None and cfg.fault_policy is None:
        raise ValueError(
            "the state carries fault counters but cfg.fault_policy is "
            "None; build the driver and the state from the same config")
    return cfg.fault_policy


def _require_staleness(cfg: AsyncDPConfig, state: AsyncDPState):
    """Trace-time consistency check between cfg.staleness and the
    state's StalenessState (both armed or both absent)."""
    if (state.stale is None) != (cfg.staleness is None):
        raise ValueError(
            "cfg.staleness and the state's runtime counters must be armed "
            "together; build the driver and the state from the same config")
    if state.stale is not None and state.faults is None:
        raise ValueError(
            "the staleness runtime rides on the fault algebra; the state "
            "must carry a FaultState (arm cfg.fault_policy)")
    return cfg.staleness


def _guarded_round(compute, cfg: AsyncDPConfig, state: AsyncDPState,
                   batch, owner_idx, key, fcode, answered, sh,
                   row_idx=None, stale_w=None):
    """One fault-guarded round, shared by the per-round step and the
    fused scan (scalar `owner_idx`/`fcode`).

    `answered` is the caller's grant bit (ledger-authorized, not
    quarantined, not dropped — and, for paged banks, resident: the
    caller folds the page-table `hit` in, so a miss reaches here
    already masked). The guards verify the owner's resident payload
    against its stored checksum, NaN-poison the update when the round
    carries NONFINITE_GRAD, and reject stale replays; a rejected round
    is a bit-exact no-op on theta/bank/tree (same jnp.where masking as
    ledger refusal) and its rejection bit comes back as
    `metrics["faulted"]` — epsilon for it was already charged at
    response time (see faults module docstring). `row_idx` (paged
    banks) is the resolved hot slot every row gather/scatter uses,
    while checksum/counter columns stay per-owner. A TIMEOUT code fails
    the `deadline_guard` and masks the round like any other guard, but
    lands in `metrics["timed_out"]` instead of `metrics["faulted"]`
    (lateness dominates payload inspection: the learner discards a late
    response unexamined — epsilon spent either way). `stale_w`
    (staleness decay armed) is the round's lambda**age inertia weight,
    handed through to compute.

    Returns (theta_L, bank, tree, faults, metrics, apply, guard_rej,
    timed).
    """
    fs = state.faults
    tr = state.tree
    widx = owner_idx if row_idx is None else row_idx
    row, cnt = (None, None) if tr is None else _tree_row_of(tr, owner_idx,
                                                            row_idx)
    # payload integrity is judged on the PRE-ROUND bank (what the round
    # actually consumed), before any write
    payload_ok = _faults.verify_row(fs.checksum, state.bank, owner_idx,
                                    fcode == _faults.CORRUPT_PAYLOAD,
                                    row_idx=row_idx)
    new_L, new_i, theta_i, metrics, new_row = compute(
        state.theta_L, state.bank, batch, owner_idx, key,
        tree_row=row, tree_count=cnt, row_idx=row_idx, stale_w=stale_w)
    new_i = _faults.inject_nonfinite(new_i, fcode == _faults.NONFINITE_GRAD)
    guard_ok = (payload_ok & _faults.finite_guard((new_i, new_L))
                & (fcode != _faults.STALE))
    on_time = deadline_guard(fcode)
    apply = answered & guard_ok & on_time
    timed = answered & ~on_time
    guard_rej = answered & on_time & ~guard_ok
    theta_L = jax.tree_util.tree_map(
        lambda nl, ol: jnp.where(apply, nl, ol), new_L, state.theta_L)
    if _bank_is_quant(state.bank):
        # same key as compute() by contract: _quant_write folds in
        # _CODEC_SALT, so SR bits never touch the privacy stream
        bank = _quant_write(state.bank, new_i, widx, key,  # dpcheck: ignore[DPC105]
                            cfg.privatizer, ok=apply)
    else:
        bank = _write_bank(
            state.bank,
            jax.tree_util.tree_map(lambda a, b: jnp.where(apply, a, b),
                                   new_i, theta_i),
            widx)
    if tr is not None:
        masked_row = jax.tree_util.tree_map(
            lambda a, b: jnp.where(apply, a, b), new_row, row)
        tr = _tree_write(tr, masked_row, owner_idx,
                         grant=apply.astype(jnp.int32), row_idx=row_idx)
    if sh is not None:
        theta_L = _constrain(theta_L, sh.theta)
        bank = _constrain_bank(bank, sh)
        tr = _constrain_tree(tr, sh)
    # re-derive the stored checksum from the POST-WRITE row; masked
    # rounds drop the scatter, so the stored sum stays in lockstep with
    # the row it describes
    fs = _faults.update_checksum(fs, bank, owner_idx, apply,
                                 row_idx=row_idx)
    metrics = dict(metrics)
    metrics.update(faulted=guard_rej, timed_out=timed)
    return theta_L, bank, tr, fs, metrics, apply, guard_rej, timed


def make_train_step(loss_fn, cfg: AsyncDPConfig,
                    scales: Optional[jax.Array] = None, mesh=None):
    """Returns step(state, batch, owner_idx, key) -> (state, metrics).

    loss_fn(params, batch) -> scalar. batch holds ONE owner's microbatch.
    `scales` overrides the per-owner Theorem-1 noise scales (the Federation
    session passes its Mechanism's ledgered scales here); None recomputes
    them from cfg exactly as before. The device ledger (if any) passes
    through untouched — this path is host-authorized.

    States built by `init_state_flat` (ParamFlat theta_L + (N, P) bank) run
    the flat-buffer engine; pytree states run the reference tree path —
    the same returned step function serves both. `mesh` pins flat states
    to the flat_shardings layout (see module docstring).
    """
    compute = _round_compute(loss_fn, cfg, scales, mesh=mesh)

    def step(state: AsyncDPState, batch, owner_idx: jax.Array, key,
             fault_code=None) -> Tuple[AsyncDPState, Dict]:
        tr = _require_tree(cfg, state)
        sh = _flat_shardings_for(mesh, state.theta_L, state.bank)
        slot, hit = _bank_slot(state.bank, owner_idx)
        if state.faults is not None:
            # fault-armed state: host-side the session has already
            # handled DROP and quarantine (neither reaches the step), so
            # the round is answered and only the in-graph guards decide.
            # Paged states additionally gate on page residency — a miss
            # (the pager failed its prefetch contract) is a masked no-op
            answered = jnp.bool_(True) if hit is None else hit
            policy = _require_fault_policy(cfg, state)
            spolicy = _require_staleness(cfg, state)
            ss = state.stale
            fcode = (jnp.int8(_faults.OK) if fault_code is None
                     else jnp.asarray(fault_code, jnp.int8))
            stale_w = None
            if ss is not None and spolicy.decay != 1.0:
                stale_w = staleness_weight(ss, owner_idx, ss.clock, spolicy)
            theta_L, bank, tr, fs, metrics, apply, guard_rej, timed = \
                _guarded_round(compute, cfg, state, batch, owner_idx, key,
                               fcode, answered, sh, row_idx=slot,
                               stale_w=stale_w)
            fs = _faults.fault_tick(fs, owner_idx, guard_rej, policy,
                                    active=answered)
            if ss is not None:
                # dispatched rounds are never retries (the session masks
                # cooldown rounds host-side, before the step is called)
                ss = staleness_tick(ss, owner_idx, ss.clock,
                                    is_retry=jnp.bool_(False), apply=apply,
                                    timed=timed, policy=spolicy,
                                    active=jnp.bool_(True), ticks=1)
            return AsyncDPState(theta_L, bank,
                                state.step + apply.astype(jnp.int32),
                                state.ledger, tr, fs, ss), metrics
        if fault_code is not None:
            raise ValueError(
                "fault injection needs a fault-armed state; build the "
                "config with fault_policy=FaultPolicy(...)")
        row, cnt = (None, None) if tr is None else _tree_row_of(tr,
                                                                owner_idx,
                                                                slot)
        new_L, new_i, theta_i, metrics, new_row = compute(
            state.theta_L, state.bank, batch, owner_idx, key,
            tree_row=row, tree_count=cnt, row_idx=slot)
        if hit is not None:
            # paged, host-authorized: no ledger in-graph, so residency is
            # the only grant bit — a miss masks every write bit-exactly
            new_L = jax.tree_util.tree_map(
                lambda nl, ol: jnp.where(hit, nl, ol), new_L,
                state.theta_L)
        widx = owner_idx if slot is None else slot
        if _bank_is_quant(state.bank):
            # same key as compute() by contract: _quant_write folds in
            # _CODEC_SALT, so SR bits never touch the privacy stream
            bank = _quant_write(state.bank, new_i, widx, key,  # dpcheck: ignore[DPC105]
                                cfg.privatizer, ok=hit)
        else:
            value = (new_i if hit is None
                     else jnp.where(hit, new_i, theta_i))
            bank = _write_bank(state.bank, value, widx)
        if tr is not None:
            # host-authorized path: the round always counts (refusal
            # happens before step() is called), so the leaf lands unless
            # a paged state missed
            if hit is None:
                tr = _tree_write(tr, new_row, owner_idx)
            else:
                masked_row = jax.tree_util.tree_map(
                    lambda a, b: jnp.where(hit, a, b), new_row, row)
                tr = _tree_write(tr, masked_row, owner_idx,
                                 grant=hit.astype(jnp.int32), row_idx=slot)
        if sh is not None:
            new_L = _constrain(new_L, sh.theta)
            bank = _constrain_bank(bank, sh)
            tr = _constrain_tree(tr, sh)
        bump = 1 if hit is None else hit.astype(jnp.int32)
        return AsyncDPState(new_L, bank, state.step + bump,
                            state.ledger, tr, state.faults,
                            state.stale), metrics

    return step


def make_fused_rounds(loss_fn, cfg: AsyncDPConfig,
                      scales: Optional[jax.Array] = None, mesh=None,
                      unroll: int = 1):
    """Device-resident multi-round driver: K rounds in ONE dispatch.

    Returns run(state, batches, owner_seq, keys) -> (state, metrics) where
    every batch leaf carries a leading (K,) round axis, owner_seq is (K,)
    int32, keys is (K,) PRNG keys, and metrics are stacked (K,) arrays.

    Authorization is in-graph: round k is granted iff
    `state.ledger.spent[i_k] < cap[i_k]` at that point of the scan. A
    refused round is a no-op on model state EXACTLY as the host-authorized
    per-round path — the computed update is discarded with `jnp.where`, the
    owner's own copy is written back unchanged, and the refusal lands in
    `ledger.refused` for `Federation.reconcile()` to fold into the host
    accountant. Granted rounds run the exact same `_round_math` trace as
    `make_train_step`, so a fused schedule reproduces the per-round loop
    bit-for-bit under the same per-round keys. Flat states (see
    `init_state_flat`) run the flat-buffer engine inside the same scan.
    `mesh` pins flat states to the flat_shardings layout: the constraint
    sits INSIDE the scan body, so the carry stays distributed across all
    K rounds (no per-round gather, no host transfer of the bank).
    `unroll` is handed to the lax.scan (identical values at any setting):
    unrolled blocks amortize the loop-carry bank copy XLA:CPU pays per
    scan iteration — measured +24% at unroll=4 at the MLP-scale config.
    Quantized banks (QuantBank states) decode the owner row on gather and
    re-encode granted updates with stochastic rounding + error feedback;
    refused rounds leave codes/scales/residual untouched.
    """
    compute = _round_compute(loss_fn, cfg, scales, mesh=mesh)

    def body(state: AsyncDPState, xs):
        batch, owner_idx, key = xs
        led = state.ledger
        tr = state.tree
        sh = _flat_shardings_for(mesh, state.theta_L, state.bank)
        slot, hit = _bank_slot(state.bank, owner_idx)
        ok = led.authorized(owner_idx)
        if hit is not None:
            # paged: residency folds into the grant BEFORE the ledger
            # update — a miss spends nothing and lands in `refused`
            # (lawful: no epsilon without a response; in a correctly
            # prefetched session misses never occur, so a nonzero
            # refused count under an authorized schedule flags a pager
            # bug, not a privacy event)
            ok = ok & hit
        oki = ok.astype(jnp.int32)
        row, cnt = (None, None) if tr is None else _tree_row_of(tr,
                                                                owner_idx,
                                                                slot)
        new_L, new_i, theta_i, metrics, new_row = compute(
            state.theta_L, state.bank, batch, owner_idx, key,
            tree_row=row, tree_count=cnt, row_idx=slot)
        theta_L = jax.tree_util.tree_map(
            lambda nl, ol: jnp.where(ok, nl, ol), new_L, state.theta_L)
        widx = owner_idx if slot is None else slot
        if _bank_is_quant(state.bank):
            bank = _quant_write(state.bank, new_i, widx, key,
                                cfg.privatizer, ok=ok)
        else:
            bank = _write_bank(
                state.bank,
                jax.tree_util.tree_map(lambda a, b: jnp.where(ok, a, b),
                                       new_i, theta_i),
                widx)
        if tr is not None:
            # refusal masking: the old row is written back and the leaf
            # counter bumps by the grant bit, so a refused round is a
            # bit-exact no-op on the tree (same contract as the bank)
            masked_row = jax.tree_util.tree_map(
                lambda a, b: jnp.where(ok, a, b), new_row, row)
            tr = _tree_write(tr, masked_row, owner_idx, grant=oki,
                             row_idx=slot)
        if sh is not None:
            theta_L = _constrain(theta_L, sh.theta)
            bank = _constrain_bank(bank, sh)
            tr = _constrain_tree(tr, sh)
        ledger = led.replace(spent=led.spent.at[owner_idx].add(oki),
                             refused=led.refused.at[owner_idx].add(1 - oki))
        metrics = dict(metrics)
        metrics.update(refused=~ok, owner=owner_idx)
        return AsyncDPState(theta_L, bank, state.step + oki, ledger,
                            tr, state.faults, state.stale), metrics

    def body_faulted(state: AsyncDPState, xs):
        # fault-armed scan round: same algebra as the per-round step's
        # faulted branch, with ledger authorization and quarantine
        # resolved in-graph. Epsilon is charged at response time: spent
        # counts every ANSWERED round (guard-rejected and timed-out ones
        # included), a DROP before the answer spends nothing
        # (ledger.dropped), and quarantined rounds are masked without
        # refusal accounting (ledger.quarantined). With the staleness
        # runtime armed, an owner whose backoff cooldown is live is a
        # masked RE-DISPATCH (ledger.retried, no epsilon — the learner
        # never sent the query); precedence is quarantine > backoff >
        # budget > drop.
        batch, owner_idx, key, fcode = xs
        led = state.ledger
        fs = state.faults
        ss = state.stale
        policy = cfg.fault_policy
        spolicy = cfg.staleness
        sh = _flat_shardings_for(mesh, state.theta_L, state.bank)
        slot, hit = _bank_slot(state.bank, owner_idx)
        quar = fs.quarantined[owner_idx]
        led_auth = led.authorized(owner_idx)
        if hit is not None:
            # paged: a page miss refuses like budget exhaustion (spends
            # nothing, counts in `refused` unless quarantined) — see the
            # plain body
            led_auth = led_auth & hit
        if ss is not None:
            in_backoff = ss.cooldown[owner_idx] > 0
            is_retry = ~quar & in_backoff
            avail = ~quar & ~in_backoff
        else:
            is_retry = None
            avail = ~quar
        auth = led_auth & avail
        is_drop = fcode == _faults.DROP
        answered = auth & ~is_drop
        stale_w = None
        if ss is not None and spolicy.decay != 1.0:
            stale_w = staleness_weight(ss, owner_idx, ss.clock, spolicy)
        theta_L, bank, tr, fs, metrics, apply, guard_rej, timed = \
            _guarded_round(compute, cfg, state, batch, owner_idx, key,
                           fcode, answered, sh, row_idx=slot,
                           stale_w=stale_w)
        upd = dict(
            spent=led.spent.at[owner_idx].add(answered.astype(jnp.int32)),
            refused=led.refused.at[owner_idx].add(
                (avail & ~led_auth).astype(jnp.int32)),
            dropped=led.dropped.at[owner_idx].add(
                (auth & is_drop).astype(jnp.int32)),
            faulted=led.faulted.at[owner_idx].add(
                guard_rej.astype(jnp.int32)),
            quarantined=led.quarantined.at[owner_idx].add(
                quar.astype(jnp.int32)),
            timed_out=led.timed_out.at[owner_idx].add(
                timed.astype(jnp.int32)))
        if ss is not None:
            upd["retried"] = led.retried.at[owner_idx].add(
                is_retry.astype(jnp.int32))
        ledger = led.replace(**upd)
        # timeouts and retries are NOT quarantine events: slowness has
        # its own escalation path (backoff); a backed-off round is not
        # even a contact (the learner never dispatched)
        fs = _faults.fault_tick(fs, owner_idx, guard_rej | (auth & is_drop),
                                policy, active=avail)
        metrics.update(refused=avail & ~led_auth, dropped=auth & is_drop,
                       quarantined=quar, owner=owner_idx)
        if ss is not None:
            metrics.update(retried=is_retry)
            ss = staleness_tick(ss, owner_idx, ss.clock, is_retry=is_retry,
                                apply=apply, timed=timed, policy=spolicy,
                                active=jnp.bool_(True), ticks=1)
        return AsyncDPState(theta_L, bank,
                            state.step + apply.astype(jnp.int32),
                            ledger, tr, fs, ss), metrics

    def run(state: AsyncDPState, batches, owner_seq, keys,
            fault_codes=None):
        if state.ledger is None:
            raise ValueError(
                "fused rounds need a device ledger on the state; build the "
                "state with init_state / Federation.init_state")
        _require_tree(cfg, state)
        if state.faults is None:
            if fault_codes is not None:
                raise ValueError(
                    "fault codes need a fault-armed state; build the "
                    "config with fault_policy=FaultPolicy(...)")
            return jax.lax.scan(body, state, (batches, owner_seq, keys),
                                unroll=unroll)
        _require_fault_policy(cfg, state)
        _require_staleness(cfg, state)
        if fault_codes is None:
            fault_codes = jnp.zeros(owner_seq.shape, jnp.int8)
        return jax.lax.scan(body_faulted, state,
                            (batches, owner_seq, keys, fault_codes),
                            unroll=unroll)

    return run


def _member_mask(mask, like):
    """(G,) bool -> broadcastable against a (G, ...) stacked leaf."""
    return mask.reshape((-1,) + (1,) * (like.ndim - 1))


def _write_bank_rows(bank, rows, owner_idx):
    """Scatter a GROUP of rows at once. `owner_idx` entries are distinct
    among valid members (the conflict-free partition guarantees it);
    padded members carry an out-of-range index and are dropped."""
    if isinstance(bank, jax.Array):    # flat (N, P) bank
        return bank.at[owner_idx].set(rows.astype(bank.dtype), mode="drop")
    return jax.tree_util.tree_map(
        lambda leaf, v: leaf.at[owner_idx].set(v.astype(leaf.dtype), mode="drop"),
        bank, rows)


def make_group_rounds(loss_fn, cfg: AsyncDPConfig,
                      scales: Optional[jax.Array] = None, mesh=None):
    """Owner-parallel multi-round driver: a dynamic-trip-count loop over
    CONFLICT-FREE round groups, vmap over the members of each group.

    Returns run(state, batches, owner_seq, keys, group_idx, group_valid,
    n_groups) -> (state, metrics) where batches/owner_seq/keys are the
    (K,)-leading inputs of `make_fused_rounds` and (group_idx,
    group_valid) are the (rows, G_max) arrays from `schedules.pack_groups`
    — group_idx[g] holds the round indices of group g, group_valid masks
    padding. The group axis may be padded with fully-invalid rows for
    jit-cache shape stability; `n_groups` (a TRACED count, so it never
    recompiles) bounds a `fori_loop`, so the padded rows NEVER execute —
    before this, every padded group still paid the full (N, P) bank
    loop-carry copy of one scan step, the single largest per-step cost at
    MLP scale. Metrics come back GROUP-MAJOR ((rows, G_max) leading, the
    never-executed padded rows zero-filled) — the session scatters them
    back to round order.

    Semantics vs the sequential scan, for groups whose owners are all
    distinct (the partition's invariant):

      * Ledger spend is EXACTLY sequential. Authorization depends only on
        the owner's prior grant count, and an owner appears at most once
        per group, so every member sees the same count it would have seen
        sequentially. Spent/refused land via a disjoint scatter.
      * Bank rows are disjoint: each granted member writes its own
        eq.(5) copy computed from the group-entry theta_L.
      * theta_L takes ONE inertia reduction per group: the mean of the
        granted members' eq.(7) targets. The mean of projected targets
        stays inside Theta (convex), and for a single granted member
        reduces to sum/1.0 — exactly that member's sequential update.
        For larger groups every member sees the group-entry theta_L
        instead of its sequential predecessor: a bounded deviation of
        the same character as the paper's own asynchrony (stale reads),
        measured in the benchmarks and tests, NOT a change to the noise
        or the privacy accounting.
    """
    compute = _round_compute(loss_fn, cfg, scales, mesh=mesh)
    n_owners = cfg.n_owners

    def vmap_rounds(theta_L, bank, tr, batch_g, owners, keys_g, slots,
                    stale_w=None):
        """vmapped round compute over the group members. `slots` is the
        per-member hot-slot vector for paged banks, None otherwise (the
        non-paged call chain is verbatim — no extra traced operand);
        `stale_w` is the per-member (G,) decay-weight vector when the
        staleness decay is armed, statically absent otherwise.
        Returns (new_L, new_i, theta_i, metrics, new_rows, rows_t)."""
        if stale_w is not None:
            # decayed-inertia variant: the same per-member calls with the
            # weight vector mapped alongside
            if tr is not None:
                if slots is None:
                    rows_t, cnts = jax.vmap(
                        lambda o: _tree_row_of(tr, o))(owners)
                    new_L, new_i, theta_i, metrics, new_rows = jax.vmap(
                        lambda b, o, k, r, c, w: compute(
                            theta_L, bank, b, o, k, tree_row=r,
                            tree_count=c, stale_w=w))(
                            batch_g, owners, keys_g, rows_t, cnts, stale_w)
                else:
                    rows_t, cnts = jax.vmap(
                        lambda o, s: _tree_row_of(tr, o, s))(owners, slots)
                    new_L, new_i, theta_i, metrics, new_rows = jax.vmap(
                        lambda b, o, k, r, c, s, w: compute(
                            theta_L, bank, b, o, k, tree_row=r,
                            tree_count=c, row_idx=s, stale_w=w))(
                            batch_g, owners, keys_g, rows_t, cnts, slots,
                            stale_w)
                return new_L, new_i, theta_i, metrics, new_rows, rows_t
            if slots is None:
                new_L, new_i, theta_i, metrics, _ = jax.vmap(
                    lambda b, o, k, w: compute(theta_L, bank, b, o, k,
                                               stale_w=w))(
                        batch_g, owners, keys_g, stale_w)
            else:
                new_L, new_i, theta_i, metrics, _ = jax.vmap(
                    lambda b, o, k, s, w: compute(theta_L, bank, b, o, k,
                                                  row_idx=s, stale_w=w))(
                        batch_g, owners, keys_g, slots, stale_w)
            return new_L, new_i, theta_i, metrics, None, None
        if tr is not None:
            # distinct owners per group (the partition's invariant), so
            # the per-member tree rows are disjoint reads AND writes
            if slots is None:
                rows_t, cnts = jax.vmap(
                    lambda o: _tree_row_of(tr, o))(owners)
                new_L, new_i, theta_i, metrics, new_rows = jax.vmap(
                    lambda b, o, k, r, c: compute(theta_L, bank, b, o, k,
                                                  tree_row=r,
                                                  tree_count=c))(
                        batch_g, owners, keys_g, rows_t, cnts)
            else:
                rows_t, cnts = jax.vmap(
                    lambda o, s: _tree_row_of(tr, o, s))(owners, slots)
                new_L, new_i, theta_i, metrics, new_rows = jax.vmap(
                    lambda b, o, k, r, c, s: compute(
                        theta_L, bank, b, o, k, tree_row=r, tree_count=c,
                        row_idx=s))(batch_g, owners, keys_g, rows_t,
                                    cnts, slots)
            return new_L, new_i, theta_i, metrics, new_rows, rows_t
        if slots is None:
            new_L, new_i, theta_i, metrics, _ = jax.vmap(
                lambda b, o, k: compute(theta_L, bank, b, o, k))(
                    batch_g, owners, keys_g)
        else:
            new_L, new_i, theta_i, metrics, _ = jax.vmap(
                lambda b, o, k, s: compute(theta_L, bank, b, o, k,
                                           row_idx=s))(
                    batch_g, owners, keys_g, slots)
        return new_L, new_i, theta_i, metrics, None, None

    def scatter_indices(bank, owners, valid, slots, hit_g):
        """(idx_w, idx_c): the row-scatter and safe-gather index vectors.

        Non-paged banks index rows by owner (pad -> the n_owners drop
        sentinel). Paged banks index by hot slot, and members that
        MISSED drop from the scatter entirely: distinct owners can clamp
        to the SAME slot on a miss, so the write-own-row-back idiom
        could collide — dropping is the same bit-exact no-op."""
        if slots is None:
            return (jnp.where(valid, owners, n_owners),
                    jnp.where(valid, owners, 0))
        resident = valid & hit_g
        return (jnp.where(resident, slots, bank.n_hot),
                jnp.where(resident, slots, 0))

    def body(state: AsyncDPState, xs):
        batch_g, owners, keys_g, valid = xs
        led = state.ledger
        tr = state.tree
        sh = _flat_shardings_for(mesh, state.theta_L, state.bank)
        theta_L, bank = state.theta_L, state.bank
        if isinstance(bank, PagedBank):
            slots, hit_g = jax.vmap(bank.lookup)(owners)       # (G,)
            # residency folds into the grant BEFORE the ledger update —
            # a miss spends nothing and lands in `refused` (see the
            # fused driver's body)
            ok = jax.vmap(led.authorized)(owners) & valid & hit_g
        else:
            slots, hit_g = None, None
            ok = jax.vmap(led.authorized)(owners) & valid      # (G,)
        oki = ok.astype(jnp.int32)

        # fully-invalid groups are jit-cache shape padding only; the
        # dynamic trip count in run() means they never reach this body,
        # so every executed group has at least one valid member
        new_L, new_i, theta_i, metrics, new_rows, rows_t = vmap_rounds(
            theta_L, bank, tr, batch_g, owners, keys_g, slots)

        owners_w = jnp.where(valid, owners, n_owners)          # pad -> drop
        idx_w, idx_c = scatter_indices(bank, owners, valid, slots, hit_g)
        n_ok = jnp.sum(ok.astype(jnp.float32))
        denom = jnp.maximum(n_ok, 1.0)
        hot = bank.hot if slots is not None else bank
        if isinstance(hot, QuantBank):
            # error feedback under member-parallelism: members chain the
            # shared residual IN ROUND ORDER (groups are consecutive runs
            # of the schedule), exactly as the fused scan would — encode
            # every member against the carried residual, advance the
            # carry only on a grant. Bit-identical to the sequential
            # driver; a fully-refused group leaves the residual untouched.
            def _ef_chain(res, inp):
                v, k, grant = inp
                c_n, s_n, err = _encode_bank_row(hot, v + res, k,
                                                 cfg.privatizer)
                return jnp.where(grant, err, res), (c_n, s_n)

            residual, (codes_n, scales_n) = jax.lax.scan(
                _ef_chain, hot.residual, (new_i, keys_g, ok))
            codes_w = jnp.where(_member_mask(ok, codes_n), codes_n,
                                hot.codes[idx_c])
            scales_w = jnp.where(ok[:, None], scales_n,
                                 hot.scales[idx_c])
            new_hot = QuantBank(
                hot.codes.at[idx_w].set(codes_w, mode="drop"),
                hot.scales.at[idx_w].set(scales_w, mode="drop"),
                residual, hot.codec)
        else:
            # refused/padded members write their own row back unchanged
            rows = jax.tree_util.tree_map(
                lambda a, b: jnp.where(_member_mask(ok, a), a, b),
                new_i, theta_i)
            new_hot = _write_bank_rows(hot, rows, idx_w)
        bank = (bank.replace(hot=new_hot) if slots is not None
                else new_hot)

        if tr is not None:
            # refused/padded members scatter their own row back unchanged
            rows_m = jax.tree_util.tree_map(
                lambda a, b: jnp.where(_member_mask(ok, a), a, b),
                new_rows, rows_t)
            nodes = jax.tree_util.tree_map(
                lambda leaf, v: leaf.at[idx_w].set(v, mode="drop"),
                tr.nodes, rows_m)
            tr = tr.replace(nodes=nodes,
                            counts=tr.counts.at[owners_w].add(
                                oki, mode="drop"))

        # single inertia reduction: mean of the granted eq.(7) targets

        def reduce_theta(stacked, base):
            s = jnp.sum(jnp.where(_member_mask(ok, stacked), stacked,
                                  jnp.zeros_like(stacked)), axis=0) / denom
            return jnp.where(n_ok > 0, s.astype(base.dtype), base)

        theta_L = jax.tree_util.tree_map(reduce_theta, new_L, theta_L)
        if sh is not None:
            theta_L = _constrain(theta_L, sh.theta)
            bank = _constrain_bank(bank, sh)
            tr = _constrain_tree(tr, sh)
        ledger = led.replace(
            spent=led.spent.at[owners_w].add(oki, mode="drop"),
            refused=led.refused.at[owners_w].add(
                (valid & ~ok).astype(jnp.int32), mode="drop"))
        metrics = dict(metrics)
        metrics.update(refused=~ok, owner=owners)
        return AsyncDPState(theta_L, bank, state.step + jnp.sum(oki),
                            ledger, tr, state.faults, state.stale), metrics

    def body_faulted(state: AsyncDPState, xs):
        # fault-armed group: the per-member grant algebra of the fused
        # driver's faulted body, vectorized over the group members.
        # Distinct owners per group (the partition's invariant) keep the
        # per-owner gathers (quarantine flags, checksums, windows) and
        # every scatter disjoint, and the tumbling windows key on each
        # owner's own contact count, so grouping never moves a window
        # boundary.
        batch_g, owners, keys_g, valid, fcodes_g = xs
        led = state.ledger
        fs = state.faults
        ss = state.stale
        policy = cfg.fault_policy
        spolicy = cfg.staleness
        tr = state.tree
        sh = _flat_shardings_for(mesh, state.theta_L, state.bank)
        theta_L, bank = state.theta_L, state.bank
        led_auth = jax.vmap(led.authorized)(owners)
        if isinstance(bank, PagedBank):
            slots, hit_g = jax.vmap(bank.lookup)(owners)       # (G,)
            # a page miss refuses like budget exhaustion (see the fused
            # driver's faulted body)
            led_auth = led_auth & hit_g
        else:
            slots, hit_g = None, None
        quar = fs.quarantined[owners]
        if ss is not None:
            # each member's round position within the dispatch: groups
            # are consecutive runs of the schedule and members sit in
            # round order, so the valid-rank offset from the group-entry
            # clock IS the sequential round index (ages/`last_grant`
            # stamps match the fused scan exactly; per-owner counters
            # are group-entry reads, exact because owners are distinct
            # within a group)
            t_g = ss.clock + jnp.cumsum(valid.astype(jnp.int32)) - 1
            in_backoff = ss.cooldown[owners] > 0
            is_retry = valid & ~quar & in_backoff
            avail = ~quar & ~in_backoff
        else:
            t_g = None
            is_retry = None
            avail = ~quar
        auth = led_auth & avail & valid                        # (G,)
        is_drop = fcodes_g == _faults.DROP
        answered = auth & ~is_drop
        stale_w = None
        if ss is not None and spolicy.decay != 1.0:
            stale_w = staleness_weight(ss, owners, t_g, spolicy)
        if slots is None:
            payload_ok = jax.vmap(
                lambda o, c: _faults.verify_row(fs.checksum, bank, o, c))(
                owners, fcodes_g == _faults.CORRUPT_PAYLOAD)
        else:
            payload_ok = jax.vmap(
                lambda o, c, s: _faults.verify_row(fs.checksum, bank, o,
                                                   c, row_idx=s))(
                owners, fcodes_g == _faults.CORRUPT_PAYLOAD, slots)

        new_L, new_i, theta_i, metrics, new_rows, rows_t = vmap_rounds(
            theta_L, bank, tr, batch_g, owners, keys_g, slots,
            stale_w=stale_w)
        new_i = _faults.inject_nonfinite(
            new_i, fcodes_g == _faults.NONFINITE_GRAD)
        finite = jax.vmap(_faults.finite_guard)((new_i, new_L))
        guard_ok = payload_ok & finite & (fcodes_g != _faults.STALE)
        on_time = deadline_guard(fcodes_g)
        apply = answered & guard_ok & on_time
        timed = answered & ~on_time
        guard_rej = answered & on_time & ~guard_ok

        owners_w = jnp.where(valid, owners, n_owners)          # pad -> drop
        idx_w, idx_c = scatter_indices(bank, owners, valid, slots, hit_g)
        n_ok = jnp.sum(apply.astype(jnp.float32))
        denom = jnp.maximum(n_ok, 1.0)
        hot = bank.hot if slots is not None else bank
        if isinstance(hot, QuantBank):
            # same residual chain as the plain body; a NaN-poisoned
            # member never advances the carry (its `apply` is False by
            # the finite guard), so poison cannot leak into the shared
            # residual
            def _ef_chain(res, inp):
                v, k, grant = inp
                c_n, s_n, err = _encode_bank_row(hot, v + res, k,
                                                 cfg.privatizer)
                return jnp.where(grant, err, res), (c_n, s_n)

            residual, (codes_n, scales_n) = jax.lax.scan(
                _ef_chain, hot.residual, (new_i, keys_g, apply))
            codes_w = jnp.where(_member_mask(apply, codes_n), codes_n,
                                hot.codes[idx_c])
            scales_w = jnp.where(apply[:, None], scales_n,
                                 hot.scales[idx_c])
            new_hot = QuantBank(
                hot.codes.at[idx_w].set(codes_w, mode="drop"),
                hot.scales.at[idx_w].set(scales_w, mode="drop"),
                residual, hot.codec)
        else:
            rows = jax.tree_util.tree_map(
                lambda a, b: jnp.where(_member_mask(apply, a), a, b),
                new_i, theta_i)
            new_hot = _write_bank_rows(hot, rows, idx_w)
        bank = (bank.replace(hot=new_hot) if slots is not None
                else new_hot)

        if tr is not None:
            rows_m = jax.tree_util.tree_map(
                lambda a, b: jnp.where(_member_mask(apply, a), a, b),
                new_rows, rows_t)
            nodes = jax.tree_util.tree_map(
                lambda leaf, v: leaf.at[idx_w].set(v, mode="drop"),
                tr.nodes, rows_m)
            tr = tr.replace(nodes=nodes,
                            counts=tr.counts.at[owners_w].add(
                                apply.astype(jnp.int32), mode="drop"))

        def reduce_theta(stacked, base):
            s = jnp.sum(jnp.where(_member_mask(apply, stacked), stacked,
                                  jnp.zeros_like(stacked)), axis=0) / denom
            return jnp.where(n_ok > 0, s.astype(base.dtype), base)

        theta_L = jax.tree_util.tree_map(reduce_theta, new_L, theta_L)
        if sh is not None:
            theta_L = _constrain(theta_L, sh.theta)
            bank = _constrain_bank(bank, sh)
            tr = _constrain_tree(tr, sh)
        fs = _faults.update_checksum(fs, bank, owners, apply,
                                     row_idx=slots)
        upd = dict(
            spent=led.spent.at[owners_w].add(
                answered.astype(jnp.int32), mode="drop"),
            refused=led.refused.at[owners_w].add(
                (valid & avail & ~led_auth).astype(jnp.int32), mode="drop"),
            dropped=led.dropped.at[owners_w].add(
                (auth & is_drop).astype(jnp.int32), mode="drop"),
            faulted=led.faulted.at[owners_w].add(
                guard_rej.astype(jnp.int32), mode="drop"),
            quarantined=led.quarantined.at[owners_w].add(
                (valid & quar).astype(jnp.int32), mode="drop"),
            timed_out=led.timed_out.at[owners_w].add(
                timed.astype(jnp.int32), mode="drop"))
        if ss is not None:
            upd["retried"] = led.retried.at[owners_w].add(
                is_retry.astype(jnp.int32), mode="drop")
        ledger = led.replace(**upd)
        # see the fused body: timeouts/retries are not quarantine events
        fs = _faults.fault_tick(fs, owners, guard_rej | (auth & is_drop),
                                policy, active=valid & avail)
        metrics = dict(metrics)
        metrics.update(refused=valid & avail & ~led_auth,
                       dropped=auth & is_drop, faulted=guard_rej,
                       quarantined=valid & quar, timed_out=timed,
                       owner=owners)
        if ss is not None:
            metrics.update(retried=is_retry)
            ss = staleness_tick(ss, owners, t_g, is_retry=is_retry,
                                apply=apply, timed=timed, policy=spolicy,
                                active=valid,
                                ticks=jnp.sum(valid.astype(jnp.int32)))
        return AsyncDPState(theta_L, bank,
                            state.step + jnp.sum(apply.astype(jnp.int32)),
                            ledger, tr, fs, ss), metrics

    def run(state: AsyncDPState, batches, owner_seq, keys, group_idx,
            group_valid, n_groups=None, fault_codes=None):
        if state.ledger is None:
            raise ValueError(
                "grouped rounds need a device ledger on the state; build "
                "the state with init_state / Federation.init_state")
        _require_tree(cfg, state)
        if state.faults is None:
            if fault_codes is not None:
                raise ValueError(
                    "fault codes need a fault-armed state; build the "
                    "config with fault_policy=FaultPolicy(...)")
            b = body
            extra = ()
        else:
            _require_fault_policy(cfg, state)
            _require_staleness(cfg, state)
            if fault_codes is None:
                fault_codes = jnp.zeros(owner_seq.shape, jnp.int8)
            b = body_faulted
            extra = (fault_codes[group_idx],)
        xs = (jax.tree_util.tree_map(lambda a: a[group_idx], batches),
              owner_seq[group_idx], keys[group_idx], group_valid) + extra
        rows = group_idx.shape[0]
        if rows == 0:
            return jax.lax.scan(b, state, xs)          # empty dispatch
        if n_groups is None:
            n_groups = rows
        # dynamic trip count: the group axis is padded to a shape bucket
        # for the jit cache, but only the real groups execute — metrics
        # land in pre-allocated group-major buffers via one-row updates,
        # the padded rows stay zero (and masked-out downstream)
        m_shape = jax.eval_shape(
            lambda s, x: b(s, x)[1], state,
            jax.tree_util.tree_map(lambda a: a[0], xs))
        mets0 = jax.tree_util.tree_map(
            lambda sd: jnp.zeros((rows,) + sd.shape, sd.dtype), m_shape)

        def body_at(g, carry):
            st, mets = carry
            xg = jax.tree_util.tree_map(
                lambda a: jax.lax.dynamic_index_in_dim(a, g, 0,
                                                       keepdims=False), xs)
            st, m = b(st, xg)
            mets = jax.tree_util.tree_map(
                lambda buf, v: jax.lax.dynamic_update_index_in_dim(
                    buf, v, g, 0), mets, m)
            return st, mets

        return jax.lax.fori_loop(0, n_groups, body_at, (state, mets0))

    return run


def make_sync_dp_step(loss_fn, cfg: AsyncDPConfig, lr: float,
                      scales: Optional[jax.Array] = None):
    """Synchronous DP-SGD baseline (the paper's related-work comparator,
    [12]/[14]-style): every owner contributes a privatized gradient each
    round; the learner averages them. Used by benchmarks to quantify what
    asynchrony costs/buys.

    step(params, batches, key, weights=None): `weights` (N,) rescales each
    owner's contribution — the Federation session passes 0/1 liveness there
    so budget-exhausted owners drop out of the round.

    The per-owner accumulation is a `lax.scan` over the stacked (N, B, ...)
    batches, so trace size and compile time stay O(1) in N (the unrolled
    Python loop grew both linearly — prohibitive at hundreds of owners).
    The scan body accumulates in the same owner order with the same ops as
    the old loop, so results are unchanged.
    """
    if cfg.tree_depth is not None:
        raise ValueError(
            "the synchronous baseline draws independent per-round noise; "
            "the tree mechanism (cfg.tree_depth) has no sync counterpart")
    scales = _noise_scales(cfg) if scales is None else jnp.asarray(
        scales, jnp.float32)
    n_i = jnp.asarray(cfg.owner_sizes, jnp.float32)
    n = float(cfg.n_total)

    def step(params, batches, key, weights=None):
        keys = jax.random.split(key, cfg.n_owners)
        w_all = (n_i / n if weights is None
                 else weights * n_i / n)                       # (N,)

        def body(acc, xs):
            b_i, k_i, s_i, w_i = xs
            q, _ = private_grad(loss_fn, params, b_i, k_i,
                                cfg=cfg.privatizer, noise_scale=s_i)
            return jax.tree_util.tree_map(
                lambda a, g: a + w_i * g.astype(jnp.float32), acc, q), None

        zeros = jax.tree_util.tree_map(
            lambda leaf: jnp.zeros(leaf.shape, jnp.float32), params)
        acc, _ = jax.lax.scan(body, zeros, (batches, keys, scales, w_all))
        reg = jax.tree_util.tree_map(
            lambda leaf: cfg.sigma * leaf.astype(jnp.float32), params)
        new = jax.tree_util.tree_map(
            lambda p, g, r: (p - lr * (g + r).astype(p.dtype)).astype(p.dtype),
            params, acc, reg)
        return jax.tree_util.tree_map(
            lambda leaf: jnp.clip(leaf, -cfg.theta_max, cfg.theta_max), new)

    return step
