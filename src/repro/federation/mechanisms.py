"""Pluggable privacy Mechanisms — noise calibration WITH the ledger inside.

A Mechanism owns both sides of Theorem 1: it calibrates every owner's
Laplace scale AND ledgers every authorized response in an internal
PrivacyAccountant, so accounting can never drift from the noise actually
emitted (previously the accountant was wired in by hand in example
scripts — or not at all). Budget-exhausted owners are refused at this
layer; the Federation session turns a refusal into a no-op round.

Variants:
  'paper'            — Theorem 1's exact scale b_i = 2 Xi T / (n_i eps_i).
  'strict'           — rigorous L1 slack: multiplies by sqrt(p)
                       (||v||_1 <= sqrt(p) ||v||_2; see privacy.py's
                       faithfulness note).
  'per_owner_rounds' — beyond-paper composition: owners enforce a hard
                       response cap R_i = ceil(slack*T/N), so the same
                       eps_i is met with scale 2 Xi R_i/(n_i eps_i); the
                       cap is actually ENFORCED here (refusal), unlike the
                       legacy Algo1Config path which only rescaled noise.
  'tree'             — DP-FTRL binary-tree correlated noise (Kairouz et
                       al. 2021): O(log K) cumulative noise per owner,
                       per-node scale d * b(R) at R = min(T, 2^d - 1)
                       (== the enforced response cap). Deep path only —
                       the node buffer lives inside AsyncDPState.

Gaussian/RDP composition (see PAPERS.md) slots in as a further Mechanism
implementation without touching the engines.
"""
from __future__ import annotations

from typing import Dict, Optional, Protocol, Sequence, Union, runtime_checkable

import jax.numpy as jnp
import numpy as np

from repro.federation.config import FederationConfig
from repro.federation.owners import DataOwner
from repro.federation.privacy import (DeviceLedger, PrivacyAccountant,
                                      laplace_scale_theorem1)


class LedgerDriftError(RuntimeError):
    """The device ledger and the host accountant disagree.

    Raised by reconcile() instead of silently absorbing the mismatch —
    accounting must never drift from the noise that was actually emitted.
    Typical cause: host-authorized step() rounds interleaved with fused
    run_rounds() on a stale state ledger."""


@runtime_checkable
class Mechanism(Protocol):
    """What the Federation session needs from a privacy mechanism."""

    name: str

    @property
    def cap(self) -> Optional[int]:
        """Per-owner response cap the engine must enforce (None = T)."""
        ...

    def scales(self, p: Optional[int] = None,
               clip_norm: Optional[float] = None) -> jnp.ndarray:
        """(N,) per-owner noise scales; p is the query dimension.

        clip_norm overrides each owner's Xi_i as the sensitivity bound —
        the deep path passes its privatizer's clip norm here, because the
        ENFORCED norm is the true sensitivity (per-owner Xi_i would
        under-noise any owner whose gradients are clipped to a larger
        norm than its own bound)."""
        ...

    def authorize(self, owner_idx: int) -> bool:
        """Ledger one response; False = refused (budget exhausted)."""
        ...

    def authorize_many(self, owner_idx: int, count: int) -> int:
        """Bulk-ledger `count` responses, returning how many were granted
        (the Federation session falls back to repeated authorize() if a
        custom mechanism does not provide this)."""
        ...

    def ledger(self) -> Dict[int, Dict]:
        """Per-owner accounting summary, including refusals."""
        ...

    def device_ledger(self) -> DeviceLedger:
        """Snapshot the accountant as device-resident counters (the fused
        multi-round driver authorizes in-graph against these)."""
        ...

    def reconcile(self, ledger: DeviceLedger) -> Dict[int, Dict]:
        """Fold a device ledger back into the host accountant bit-exactly;
        returns the updated ledger() summary."""
        ...


class _LedgeredMechanism:
    """Shared ledger plumbing for the Theorem-1 mechanism family."""

    name = "base"

    def __init__(self, owners: Sequence[DataOwner], cfg: FederationConfig, *,
                 composition: str = "paper", cap_slack: float = 2.0,
                 tree_depth: Optional[int] = None):
        self.owners = list(owners)
        self.cfg = cfg
        self._accountant = PrivacyAccountant(
            {i: o.epsilon for i, o in enumerate(self.owners)}, cfg.horizon,
            composition=composition, cap_slack=cap_slack,
            n_owners=len(self.owners), tree_depth=tree_depth)
        self.refusals = {i: 0 for i in range(len(self.owners))}
        # Fault-outcome tallies (PR 8). None of these touch the
        # accountant: `dropped` and `quarantined` rounds never produced a
        # response (no epsilon), and `faulted` rounds are already inside
        # the `spent` count (epsilon is charged at response time — see
        # DeviceLedger's docstring).
        self.dropped_rounds = {i: 0 for i in range(len(self.owners))}
        self.faulted_rounds = {i: 0 for i in range(len(self.owners))}
        self.quarantined_rounds = {i: 0 for i in range(len(self.owners))}
        # Staleness-runtime tallies (PR 10): `timed_out` rounds answered
        # past the deadline (epsilon already in `spent`, like `faulted`);
        # `retried` rounds masked in backoff (never dispatched: no
        # epsilon, like `quarantined`).
        self.timed_out_rounds = {i: 0 for i in range(len(self.owners))}
        self.retried_rounds = {i: 0 for i in range(len(self.owners))}
        # Device-ledger counters already folded back by reconcile() —
        # deltas against these make reconcile idempotent over chunked
        # run_rounds()/reconcile() cycles.
        self._folded_spent = {i: 0 for i in range(len(self.owners))}
        self._folded_refused = {i: 0 for i in range(len(self.owners))}
        self._folded_dropped = {i: 0 for i in range(len(self.owners))}
        self._folded_faulted = {i: 0 for i in range(len(self.owners))}
        self._folded_quarantined = {i: 0 for i in range(len(self.owners))}
        self._folded_timed_out = {i: 0 for i in range(len(self.owners))}
        self._folded_retried = {i: 0 for i in range(len(self.owners))}
        self._snapshot_sid = 0       # generation of the live device ledger

    @property
    def cap(self) -> Optional[int]:
        return self._accountant.ledgers[0].cap if self.owners else None

    def effective_horizon(self) -> int:
        c = self.cap
        return c if c is not None else self.cfg.horizon

    def _scale_one(self, owner: DataOwner, p: Optional[int],
                   xi: float) -> float:
        raise NotImplementedError

    def scales(self, p: Optional[int] = None,
               clip_norm: Optional[float] = None) -> jnp.ndarray:
        return jnp.asarray([
            0.0 if self.cfg.noiseless else
            self._scale_one(o, p, clip_norm if clip_norm is not None
                            else o.xi)
            for o in self.owners], jnp.float32)

    def authorize(self, owner_idx: int) -> bool:
        ok = self._accountant.record_response(int(owner_idx))
        if not ok:
            self.refusals[int(owner_idx)] += 1
        return ok

    def exhausted(self, owner_idx: int) -> bool:
        """Peek: is the owner's budget spent? (No refusal is recorded —
        use authorize() to actually charge or refuse a round.)"""
        return self._accountant.ledgers[int(owner_idx)].exhausted

    def record_dropped(self, owner_idx: int) -> None:
        """Tally a round lost BEFORE the owner answered (no epsilon)."""
        self.dropped_rounds[int(owner_idx)] += 1

    def record_faulted(self, owner_idx: int) -> None:
        """Tally an answered-then-rejected round. The epsilon was already
        charged by authorize() — this only records that the spend bought
        no progress."""
        self.faulted_rounds[int(owner_idx)] += 1

    def record_quarantined(self, owner_idx: int) -> None:
        """Tally a round masked because the owner was quarantined (no
        answer, no epsilon, no refusal)."""
        self.quarantined_rounds[int(owner_idx)] += 1

    def record_timed_out(self, owner_idx: int) -> None:
        """Tally a round answered past the learner deadline. The epsilon
        was already charged by authorize() — this only records that the
        spend arrived too late to apply."""
        self.timed_out_rounds[int(owner_idx)] += 1

    def record_retried(self, owner_idx: int) -> None:
        """Tally a round masked because the owner sat in retry backoff
        (never dispatched: no answer, no epsilon, no refusal)."""
        self.retried_rounds[int(owner_idx)] += 1

    def authorize_many(self, owner_idx: int, count: int) -> int:
        """Bulk-ledger `count` responses for one owner (order-free: how
        many are granted depends only on the cap, not the sequence)."""
        granted = self._accountant.record_responses(int(owner_idx),
                                                    int(count))
        self.refusals[int(owner_idx)] += int(count) - granted
        return granted

    def ledger(self) -> Dict[int, Dict]:
        summary = self._accountant.summary()
        for i in self.refusals:
            summary[i]["refused"] = self.refusals[i]
            summary[i]["dropped"] = self.dropped_rounds[i]
            summary[i]["faulted"] = self.faulted_rounds[i]
            summary[i]["quarantined"] = self.quarantined_rounds[i]
            summary[i]["timed_out"] = self.timed_out_rounds[i]
            summary[i]["retried"] = self.retried_rounds[i]
        return summary

    def device_ledger(self) -> DeviceLedger:
        """Snapshot the accountant as a DeviceLedger for in-graph
        authorization. Both counters are seeded from the CURRENT host
        totals (spent from responses, refused from ledgered refusals) and
        the snapshot gets a fresh generation id: only the LATEST
        snapshot's state chain may reconcile — a superseded state raises
        instead of folding divergent counters against this baseline."""
        self._snapshot_sid += 1
        n = len(self.owners)

        def col(d):
            return jnp.asarray([d[i] for i in range(n)], jnp.int32)

        led = self._accountant.device_ledger()
        led = led.replace(
            refused=col(self.refusals),
            dropped=col(self.dropped_rounds),
            faulted=col(self.faulted_rounds),
            quarantined=col(self.quarantined_rounds),
            timed_out=col(self.timed_out_rounds),
            retried=col(self.retried_rounds),
            sid=self._snapshot_sid)
        for i in range(n):
            self._folded_spent[i] = self._accountant.ledgers[i].responses
            self._folded_refused[i] = self.refusals[i]
            self._folded_dropped[i] = self.dropped_rounds[i]
            self._folded_faulted[i] = self.faulted_rounds[i]
            self._folded_quarantined[i] = self.quarantined_rounds[i]
            self._folded_timed_out[i] = self.timed_out_rounds[i]
            self._folded_retried[i] = self.retried_rounds[i]
        return led

    def reconcile(self, ledger: DeviceLedger) -> Dict[int, Dict]:
        """Fold the device counters back into the host accountant.

        The delta since the last fold is ledgered via the same
        record_responses() path host authorization uses; any disagreement
        (a device grant the host cap refuses, or counters that went
        backwards) raises LedgerDriftError rather than being absorbed.
        Validate-then-apply: a raised drift error leaves the accountant
        untouched, so callers can recover from a consistent state."""
        spent = np.asarray(ledger.spent)
        refused = np.asarray(ledger.refused)
        dropped = np.asarray(ledger.dropped)
        faulted = np.asarray(ledger.faulted)
        quarantined = np.asarray(ledger.quarantined)
        timed_out = np.asarray(ledger.timed_out)
        retried = np.asarray(ledger.retried)
        if spent.shape != (len(self.owners),):
            raise ValueError(f"device ledger for {spent.shape[0]} owners, "
                             f"mechanism has {len(self.owners)}")
        if ledger.sid != self._snapshot_sid:
            raise LedgerDriftError(
                f"state ledger is from snapshot {ledger.sid}, but the live "
                f"snapshot is {self._snapshot_sid}: a newer init_state()/"
                "device_ledger() superseded this state, so its counters "
                "cannot be folded against the current baseline (two live "
                "device states per session would under-count spend)")
        deltas = []
        for i in range(len(self.owners)):
            d_spent = int(spent[i]) - self._folded_spent[i]
            d_refused = int(refused[i]) - self._folded_refused[i]
            d_dropped = int(dropped[i]) - self._folded_dropped[i]
            d_faulted = int(faulted[i]) - self._folded_faulted[i]
            d_quar = int(quarantined[i]) - self._folded_quarantined[i]
            d_timed = int(timed_out[i]) - self._folded_timed_out[i]
            d_retry = int(retried[i]) - self._folded_retried[i]
            if min(d_spent, d_refused, d_dropped, d_faulted, d_quar,
                   d_timed, d_retry) < 0:
                raise LedgerDriftError(
                    f"owner {i}: device counters went backwards "
                    f"(spent {spent[i]} < folded {self._folded_spent[i]}, "
                    f"refused {refused[i]} < {self._folded_refused[i]}, or a "
                    "fault-outcome column shrank); was the state ledger "
                    "rebuilt without device_ledger()?")
            led_i = self._accountant.ledgers[i]
            room = led_i.effective_horizon - led_i.responses
            if d_spent > room:
                raise LedgerDriftError(
                    f"owner {i}: device granted {d_spent} responses but the "
                    f"host cap admits only {max(0, room)} — the state ledger "
                    "is stale (host-authorized rounds ran after the "
                    "snapshot); take a fresh Federation.init_state / "
                    "device_ledger()")
            deltas.append((d_spent, d_refused, d_dropped, d_faulted, d_quar,
                           d_timed, d_retry))
        for i, (d_spent, d_refused, d_dropped, d_faulted, d_quar,
                d_timed, d_retry) in enumerate(deltas):
            granted = self._accountant.record_responses(i, d_spent)
            assert granted == d_spent, (i, granted, d_spent)
            self.refusals[i] += d_refused
            # Fault/staleness outcomes carry no epsilon of their own
            # (faulted and timed-out rounds are a subset of the d_spent
            # just ledgered; retried rounds never dispatched) — they fold
            # into the host tallies without touching the accountant.
            self.dropped_rounds[i] += d_dropped
            self.faulted_rounds[i] += d_faulted
            self.quarantined_rounds[i] += d_quar
            self.timed_out_rounds[i] += d_timed
            self.retried_rounds[i] += d_retry
            self._folded_spent[i] = int(spent[i])
            self._folded_refused[i] = int(refused[i])
            self._folded_dropped[i] = int(dropped[i])
            self._folded_faulted[i] = int(faulted[i])
            self._folded_quarantined[i] = int(quarantined[i])
            self._folded_timed_out[i] = int(timed_out[i])
            self._folded_retried[i] = int(retried[i])
        return self.ledger()

    def export_journal(self) -> Dict:
        """Host-accountant snapshot for crash-resume (PR 8).

        Saved alongside the device checkpoint by
        ``Federation.save_session``, this records everything reconcile()
        depends on: per-owner response/refusal/fault tallies, the
        folded-counter baselines, and the snapshot generation id. A
        restored mechanism therefore reconciles the restored device
        ledger against the SAME baseline the crashed process would have —
        replaying a partially-reconciled dispatch cannot double-count
        epsilon. All per-owner vectors are lists indexed by owner id
        (msgpack map keys must be strings, so no int-keyed dicts)."""
        n = len(self.owners)

        def col(d):
            return [int(d[i]) for i in range(n)]

        return {
            "version": 1,
            "sid": int(self._snapshot_sid),
            "responses": [int(self._accountant.ledgers[i].responses)
                          for i in range(n)],
            "refusals": col(self.refusals),
            "dropped": col(self.dropped_rounds),
            "faulted": col(self.faulted_rounds),
            "quarantined": col(self.quarantined_rounds),
            "timed_out": col(self.timed_out_rounds),
            "retried": col(self.retried_rounds),
            "folded_spent": col(self._folded_spent),
            "folded_refused": col(self._folded_refused),
            "folded_dropped": col(self._folded_dropped),
            "folded_faulted": col(self._folded_faulted),
            "folded_quarantined": col(self._folded_quarantined),
            "folded_timed_out": col(self._folded_timed_out),
            "folded_retried": col(self._folded_retried),
        }

    def restore_journal(self, journal: Dict) -> None:
        """Rewind the host accountant to an export_journal() snapshot.

        The mechanism must have been built from the same owners/config
        (scales and caps are re-derived, not journaled)."""
        if int(journal.get("version", -1)) != 1:
            raise ValueError(f"unknown journal version "
                             f"{journal.get('version')!r}")
        n = len(self.owners)
        cols = ("responses", "refusals", "dropped", "faulted",
                "quarantined", "folded_spent", "folded_refused",
                "folded_dropped", "folded_faulted", "folded_quarantined")
        for c in cols:
            if len(journal[c]) != n:
                raise ValueError(
                    f"journal column {c!r} has {len(journal[c])} owners, "
                    f"mechanism has {n} — restore with the same federation")
        # Staleness columns joined the version-1 journal in PR 10; a
        # pre-staleness journal simply has nothing to tally in them.
        zeros = [0] * n
        timed_out = [int(v) for v in journal.get("timed_out", zeros)]
        retried = [int(v) for v in journal.get("retried", zeros)]
        f_timed = [int(v) for v in journal.get("folded_timed_out", zeros)]
        f_retry = [int(v) for v in journal.get("folded_retried", zeros)]
        for c, col in (("timed_out", timed_out), ("retried", retried),
                       ("folded_timed_out", f_timed),
                       ("folded_retried", f_retry)):
            if len(col) != n:
                raise ValueError(
                    f"journal column {c!r} has {len(col)} owners, "
                    f"mechanism has {n} — restore with the same federation")
        for i in range(n):
            self._accountant.ledgers[i].responses = int(
                journal["responses"][i])
            self.refusals[i] = int(journal["refusals"][i])
            self.dropped_rounds[i] = int(journal["dropped"][i])
            self.faulted_rounds[i] = int(journal["faulted"][i])
            self.quarantined_rounds[i] = int(journal["quarantined"][i])
            self.timed_out_rounds[i] = timed_out[i]
            self.retried_rounds[i] = retried[i]
            self._folded_spent[i] = int(journal["folded_spent"][i])
            self._folded_refused[i] = int(journal["folded_refused"][i])
            self._folded_dropped[i] = int(journal["folded_dropped"][i])
            self._folded_faulted[i] = int(journal["folded_faulted"][i])
            self._folded_quarantined[i] = int(
                journal["folded_quarantined"][i])
            self._folded_timed_out[i] = f_timed[i]
            self._folded_retried[i] = f_retry[i]
        self._snapshot_sid = int(journal["sid"])


class PaperMechanism(_LedgeredMechanism):
    name = "paper"

    def _scale_one(self, owner: DataOwner, p: Optional[int],
                   xi: float) -> float:
        return laplace_scale_theorem1(xi, self.cfg.horizon, owner.n,
                                      owner.epsilon)


class StrictMechanism(_LedgeredMechanism):
    name = "strict"

    def _scale_one(self, owner: DataOwner, p: Optional[int],
                   xi: float) -> float:
        if p is None:
            raise ValueError("strict L1 slack needs the query dimension p")
        return laplace_scale_theorem1(xi, self.cfg.horizon, owner.n,
                                      owner.epsilon, p=p, l1_slack="strict")


class CappedRoundsMechanism(_LedgeredMechanism):
    name = "per_owner_rounds"

    def __init__(self, owners, cfg, *, cap_slack: float = 2.0):
        super().__init__(owners, cfg, composition="per_owner_rounds",
                         cap_slack=cap_slack)

    def _scale_one(self, owner: DataOwner, p: Optional[int],
                   xi: float) -> float:
        return laplace_scale_theorem1(xi, self.effective_horizon(),
                                      owner.n, owner.epsilon)


class TreeMechanism(_LedgeredMechanism):
    """DP-FTRL binary-tree correlated noise (Kairouz et al. 2021).

    Every response releases the DELTA of a depth-`tree_depth` noise tree
    (see kernels/tree_noise and privacy.py's composition='tree' note):
    the cumulative noise an owner's query prefix sees is popcount(t)
    node draws — O(log K) — instead of t independent ones, with no
    sampling/shuffling amplification assumption. The per-NODE Laplace
    scale composes over the d levels each response touches:
    d * b(R) with R = min(T, 2^d - 1) the tree's leaf capacity, which is
    also the enforced response cap (a leaf past capacity would have no
    level for its fresh node). The integer response ledger — and
    therefore device-ledger reconciliation — is identical to the paper
    mechanism's at horizon R.

    `depth=None` sizes the tree to the horizon (T.bit_length(), capacity
    >= T: no extra refusals vs the paper mechanism). depth=0 is the
    degenerate tree: per-round independent Laplace at the paper scale,
    bit-for-bit the PaperMechanism path — the parity anchor the tests
    pin. This mechanism carries device-resident state (node buffer +
    leaf counters inside AsyncDPState), so it is DEEP-PATH only: the
    convex scan engine draws independent per-round noise and would
    misread the node scale.
    """

    name = "tree"

    def __init__(self, owners, cfg, *, depth: Optional[int] = None):
        if depth is None:
            depth = int(cfg.horizon).bit_length()
        depth = int(depth)
        if depth < 0:
            raise ValueError(f"tree depth must be >= 0, got {depth}")
        if depth > 30:
            raise ValueError(f"tree depth {depth} overflows the int32 "
                             "leaf counters (max 30)")
        self.tree_depth = depth
        super().__init__(owners, cfg, composition="tree", tree_depth=depth)

    @property
    def capacity(self) -> Optional[int]:
        """Leaves the tree holds before refusal (None: degenerate tree)."""
        return None if self.tree_depth == 0 else (1 << self.tree_depth) - 1

    def _scale_one(self, owner: DataOwner, p: Optional[int],
                   xi: float) -> float:
        levels = max(1, self.tree_depth)
        return levels * laplace_scale_theorem1(
            xi, self.effective_horizon(), owner.n, owner.epsilon)


_MECHANISMS = {
    "paper": PaperMechanism,
    "strict": StrictMechanism,
    "per_owner_rounds": CappedRoundsMechanism,
    "tree": TreeMechanism,
}


def make_mechanism(spec: Union[str, Mechanism],
                   owners: Sequence[DataOwner], cfg: FederationConfig, *,
                   cap_slack: Optional[float] = None,
                   tree_depth: Optional[int] = None) -> Mechanism:
    if not isinstance(spec, str):
        if cap_slack is not None:
            raise ValueError("cap_slack cannot be applied to a "
                             "pre-built mechanism instance")
        if tree_depth is not None:
            raise ValueError("tree_depth cannot be applied to a "
                             "pre-built mechanism instance")
        return spec
    try:
        cls = _MECHANISMS[spec]
    except KeyError:
        raise ValueError(
            f"unknown mechanism {spec!r}; one of {sorted(_MECHANISMS)}")
    if tree_depth is not None and cls is not TreeMechanism:
        raise ValueError("tree_depth only applies to mechanism='tree'")
    if cls is CappedRoundsMechanism:
        return cls(owners, cfg, cap_slack=2.0 if cap_slack is None
                   else cap_slack)
    if cap_slack is not None:
        raise ValueError("cap_slack only applies to "
                         "mechanism='per_owner_rounds'")
    if cls is TreeMechanism:
        return cls(owners, cfg, depth=tree_depth)
    return cls(owners, cfg)
