"""Scalable gradient privatization: Xi-enforcement for non-convex models.

Canonical home; ``repro.core.dp_sgd`` is a compatibility shim over this
module.

Assumption 2 (bounded per-record gradient) does not hold for transformers;
we enforce it by clipping before averaging — the standard DP-SGD adaptation.
Granularities:

  'example'    — per-example grads via vmap(grad), clip each to xi, average.
                 Exact Assumption-2 enforcement; memory O(batch * params):
                 use for small models / smoke tests.
  'microbatch' — lax.scan over microbatch groups; each *group* gradient is
                 clipped to xi and groups are averaged. Memory O(params);
                 required at 100B scale. DP adjacency unit becomes a GROUP
                 (group-level DP) — the accountant records n = n_groups.

The fused clip+noise hot-path has a Pallas kernel
(`repro.kernels.dp_clip_noise`) — a single HBM pass instead of three.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.federation.privacy import laplace_noise_tree

LossFn = Callable[[Any, Dict[str, jax.Array]], jax.Array]


@dataclasses.dataclass(frozen=True)
class PrivatizerConfig:
    xi: float                       # clip norm (== Assumption-2 bound)
    granularity: str = "microbatch"  # 'example' | 'microbatch'
    n_microbatches: int = 8
    mechanism: str = "laplace"      # 'laplace' | 'gaussian' (beyond-paper)
    # pre_grouped: batch leaves arrive (G, B/G, ...) microbatch-major.
    # §Perf iteration 11: the in-graph (B,)->(G,B/G) reshape of a
    # batch-sharded tensor defeats GSPMD on the multi-pod mesh
    # ("involuntary full rematerialization" -> pod axis replicated, train
    # steps get NO multi-pod speedup). Grouping at the input layout fixes it.
    pre_grouped: bool = False
    # fused_kernel: route the clip-norm reduction and the final
    # mean+Laplace-add through the Pallas dp_clip_noise kernels (one HBM
    # pass instead of three), traced-scalar-safe so it fuses into the
    # multi-round scan body. The in-kernel inverse-CDF Laplace draw is a
    # different lawful sample than jax.random.laplace, so this backend is
    # statistically (not bitwise) equivalent to the jnp one. laplace only.
    fused_kernel: bool = False
    kernel_block_rows: int = 256
    # None = auto-detect the kernel backend: compiled Pallas on TPU, the
    # kernel's jnp oracle transform elsewhere (same math, no emulation
    # plumbing). True forces the Pallas interpreter (kernel debugging);
    # False forces the compiled kernel.
    kernel_interpret: Optional[bool] = None


def resolve_interpret(flag: Optional[bool]):
    """Kernel-backend auto-detection for the `interpret` argument of the
    dp_clip_noise ops: explicit True/False forces the Pallas interpreter /
    compiled kernel; None picks per backend — compiled on TPU (no manual
    config needed), the op's jnp "oracle" transform elsewhere (the Pallas
    interpreter is a debugging device, not an execution backend)."""
    if flag is None:
        return False if jax.default_backend() == "tpu" else "oracle"
    return bool(flag)


def _global_norm(tree) -> jax.Array:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(leaf.astype(jnp.float32)))
                        for leaf in leaves))


def clip_tree(tree, max_norm: float):
    norm = _global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-12))
    return jax.tree_util.tree_map(
        lambda leaf: (leaf.astype(jnp.float32) * scale).astype(leaf.dtype), tree), norm


def _group_batch(batch, n_groups):
    """Reshape every leaf (B, ...) -> (G, B/G, ...) for scan-over-groups."""
    return jax.tree_util.tree_map(
        lambda a: a.reshape((n_groups, a.shape[0] // n_groups) + a.shape[1:]),
        batch)


def private_grad(loss_fn: LossFn, params, batch, key, *,
                 cfg: PrivatizerConfig, noise_scale: float,
                 return_noise: bool = False
                 ) -> Tuple[Any, ...]:
    """Clipped-average gradient + mechanism noise (the DP response, eq. 4).

    noise_scale is the Theorem-1 scale for the *averaged* query; returns
    (noisy grad pytree, metrics).

    `return_noise=True` appends the drawn noise pytree as a THIRD return
    value — (noisy, metrics, noise) — without changing the draw or the
    noisy sum in any way. The tree mechanism needs the fresh draw
    separately (it becomes the tree's fresh node while retired nodes are
    subtracted from the response), and re-drawing it outside would
    double-consume the round key; jnp laplace/gaussian only — the fused
    kernel adds its noise in-kernel and never materializes it.
    """
    if return_noise and cfg.fused_kernel:
        raise ValueError("return_noise requires the jnp mechanism path "
                         "(fused_kernel adds noise in-kernel)")
    B = jax.tree_util.tree_leaves(batch)[0].shape[0]
    if cfg.pre_grouped and cfg.granularity == "microbatch":
        B = cfg.n_microbatches * jax.tree_util.tree_leaves(batch)[0].shape[1]

    if cfg.granularity == "example":
        def one(ex):
            ex1 = jax.tree_util.tree_map(lambda a: a[None], ex)
            return jax.grad(lambda p: loss_fn(p, ex1))(params)
        grads = jax.vmap(one)(batch)                 # leaves (B, ...)
        norms = jax.vmap(lambda i: _global_norm(
            jax.tree_util.tree_map(lambda leaf: leaf[i], grads)))(jnp.arange(B))
        scale = jnp.minimum(1.0, cfg.xi / jnp.maximum(norms, 1e-12))
        mean_grad = jax.tree_util.tree_map(
            lambda leaf: jnp.mean(leaf.astype(jnp.float32)
                               * scale.reshape((-1,) + (1,) * (leaf.ndim - 1)),
                               axis=0), grads)
        clip_frac = jnp.mean((norms > cfg.xi).astype(jnp.float32))
        max_norm = jnp.max(norms)
    elif cfg.granularity == "microbatch":
        G = cfg.n_microbatches
        assert B % G == 0, (B, G)

        def body(carry, mb):
            acc, nclip, mx = carry
            g = jax.grad(lambda p: loss_fn(p, mb))(params)
            if cfg.fused_kernel:
                from repro.kernels.dp_clip_noise.ops import fused_sqnorm_tree
                norm = jnp.sqrt(fused_sqnorm_tree(
                    g, block_rows=cfg.kernel_block_rows,
                    interpret=resolve_interpret(cfg.kernel_interpret)))
                s = jnp.minimum(1.0, cfg.xi / jnp.maximum(norm, 1e-12))
                g = jax.tree_util.tree_map(
                    lambda leaf: (leaf.astype(jnp.float32) * s).astype(leaf.dtype), g)
            else:
                g, norm = clip_tree(g, cfg.xi)
            acc = jax.tree_util.tree_map(
                lambda a, x: a + x.astype(jnp.float32), acc, g)
            return (acc, nclip + (norm > cfg.xi), jnp.maximum(mx, norm)), None

        zeros = jax.tree_util.tree_map(
            lambda leaf: jnp.zeros(leaf.shape, jnp.float32), params)
        xs = batch if cfg.pre_grouped else _group_batch(batch, G)
        (acc, nclip, max_norm), _ = jax.lax.scan(
            body, (zeros, jnp.zeros((), jnp.float32),
                   jnp.zeros((), jnp.float32)), xs)
        mean_grad = jax.tree_util.tree_map(lambda a: a / G, acc)
        clip_frac = nclip / G
    else:
        raise ValueError(cfg.granularity)

    if cfg.fused_kernel:
        if cfg.mechanism != "laplace":
            raise ValueError("fused_kernel implements the laplace mechanism")
        from repro.kernels.dp_clip_noise.ops import fused_scale_noise_tree
        # One pass: the group-mean divide (gain=1/G) and the Laplace add
        # fuse with the write-out; for 'example' the mean is already taken.
        src, gain = ((acc, 1.0 / G) if cfg.granularity == "microbatch"
                     else (mean_grad, 1.0))
        noisy = fused_scale_noise_tree(src, key, gain, noise_scale,
                                       block_rows=cfg.kernel_block_rows,
                                       interpret=resolve_interpret(
                                           cfg.kernel_interpret))
        return noisy, {"clip_frac": clip_frac, "max_grad_norm": max_norm}

    if cfg.mechanism == "laplace":
        noise = laplace_noise_tree(key, mean_grad, noise_scale)
    elif cfg.mechanism == "gaussian":
        leaves, treedef = jax.tree_util.tree_flatten(mean_grad)
        ks = jax.random.split(key, len(leaves))
        noise = jax.tree_util.tree_unflatten(
            treedef, [noise_scale * jax.random.normal(k, leaf.shape, jnp.float32)
                      for k, leaf in zip(ks, leaves)])
    else:
        raise ValueError(cfg.mechanism)
    noisy = jax.tree_util.tree_map(lambda g, w: g + w, mean_grad, noise)
    metrics = {"clip_frac": clip_frac, "max_grad_norm": max_norm}
    if return_noise:
        return noisy, metrics, noise
    return noisy, metrics
