"""Convex federation engine: Algorithm 1 (paper-faithful) as one lax.scan.

Per iteration k = 1..T (eqs. 5-7):
    i_k ~ Schedule (uniform/Poisson/availability-trace)
    theta_bar = (theta_L + theta_{i_k}) / 2                       (6)
    Qbar     = Q_{i_k}(theta_bar) + Laplace(b_{i_k})              (4)
    theta_{i_k} = Proj[ theta_bar - (N rho / (T^2 sigma)) *
                        ( (1/2N) grad g(theta_bar) + (n_i/n) Qbar ) ]   (5)
    theta_L  = Proj[ theta_bar - ((N-1) rho / (N T^2 sigma)) grad g ]   (7)

Everything is a single jax.lax.scan; vmap over `run_algorithm1` gives the
100-run percentile statistics of Figs. 2/8 in seconds on CPU.

Canonical home of the convex scan path; ``repro.core.algorithm1`` is a
compatibility shim over this module. The session-level entrypoint is
``repro.federation.Federation``, which feeds this engine per-owner noise
scales from a pluggable ``Mechanism`` and an owner sequence from a pluggable
``Schedule``.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, List, NamedTuple, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.federation.clocks import uniform_schedule
from repro.federation.config import paper_rates
from repro.federation.linear import (LinearProblem, Owner, reg_grad,
                                     relative_fitness)
from repro.federation.privacy import laplace_scale_theorem1


@dataclasses.dataclass(frozen=True)
class Algo1Config:
    horizon: int                 # T
    rho: float                   # step-size knob; alpha = rho / T^2
    sigma: float                 # strong-convexity modulus of g
    epsilons: Sequence[float]    # per-owner privacy budgets
    composition: str = "paper"   # 'paper' | 'per_owner_rounds' (beyond-paper)
    cap_slack: float = 2.0
    noiseless: bool = False      # eps -> inf (for cost-of-privacy deltas)


class Algo1Trace(NamedTuple):
    theta_L: jax.Array           # (p,) final central model
    psi: jax.Array               # (T,) relative fitness of theta_L over time
    owners_seq: jax.Array        # (T,) i_k sequence
    theta_bank: jax.Array        # (N, p) final owner copies


class SyncTrace(NamedTuple):
    theta_L: jax.Array           # (p,) final central model
    psi: jax.Array               # (T,) relative fitness over rounds


def stack_gram(owners: Sequence[Owner]) -> Tuple[jax.Array, jax.Array,
                                                 jax.Array]:
    """Stack per-owner Gram payloads into the (N, ...) engine arrays."""
    A = jnp.stack([o.A for o in owners])              # (N,p,p)
    b = jnp.stack([o.b for o in owners])              # (N,p)
    n_i = jnp.asarray([o.n for o in owners], jnp.float32)
    return A, b, n_i


def scan_engine(key, prob: LinearProblem, A: jax.Array, b: jax.Array,
                n_i: jax.Array, scales: jax.Array, *, horizon: int,
                rho: float, sigma: float, lr_scale: float = 1.0,
                draw: Optional[Callable] = None,
                cap: Optional[int] = None) -> Algo1Trace:
    """The asynchronous scan over the owner schedule.

    `draw(key, N, T) -> (T,) int32` supplies the i_k sequence (defaults to
    the i.i.d.-uniform shortcut). `cap`, when set, refuses an owner's round
    once it has responded `cap` times — the refused round is a no-op for
    both models (refusal is data-independent, hence privacy-free).
    """
    N = A.shape[0]
    p = prob.G.shape[0]
    T = horizon
    n = prob.n_total

    k_sched, k_noise = jax.random.split(key)
    owners_seq = (draw or uniform_schedule)(k_sched, N, T)
    noise_keys = jax.random.split(k_noise, T)

    lr_own, lr_L = paper_rates(N, T, rho, sigma, lr_scale)
    def proj(t):
        return jnp.clip(t, -prob.theta_max, prob.theta_max)

    def update(theta_L, bank, i_k, nk):
        theta_i = bank[i_k]
        theta_bar = 0.5 * (theta_L + theta_i)                       # (6)
        q = 2.0 * (A[i_k] @ theta_bar - b[i_k])                     # (3)
        w = scales[i_k] * jax.random.laplace(nk, (p,))              # Thm 1
        qbar = q + w                                                # (4)
        gg = reg_grad(prob, theta_bar)
        new_i = proj(theta_bar - lr_own * (gg / (2 * N)
                                           + (n_i[i_k] / n) * qbar))  # (5)
        new_L = proj(theta_bar - lr_L * gg)                           # (7)
        return new_L, bank.at[i_k].set(new_i)

    theta0 = jnp.zeros((p,))
    bank0 = jnp.zeros((N, p))
    if cap is None:
        def step(carry, xs):
            theta_L, bank = carry
            new_L, bank = update(theta_L, bank, *xs)
            return (new_L, bank), relative_fitness(prob, new_L)

        (theta_L, bank), psis = jax.lax.scan(step, (theta0, bank0),
                                             (owners_seq, noise_keys))
    else:
        def step(carry, xs):
            theta_L, bank, counts = carry
            i_k, nk = xs
            respond = counts[i_k] < cap
            new_L, new_bank = update(theta_L, bank, i_k, nk)
            theta_L = jnp.where(respond, new_L, theta_L)
            bank = jnp.where(respond, new_bank, bank)
            counts = counts.at[i_k].add(respond.astype(jnp.int32))
            return (theta_L, bank, counts), relative_fitness(prob, theta_L)

        (theta_L, bank, _), psis = jax.lax.scan(
            step, (theta0, bank0, jnp.zeros((N,), jnp.int32)),
            (owners_seq, noise_keys))
    return Algo1Trace(theta_L, psis, owners_seq, bank)


def sync_scan_engine(key, prob: LinearProblem, A: jax.Array, b: jax.Array,
                     n_i: jax.Array, scales: jax.Array, *, horizon: int,
                     lr: float) -> SyncTrace:
    """Synchronous all-owners-per-round DP baseline (the [14]-style
    comparator the paper argues does not scale); same per-owner budget
    split over T rounds."""
    p = prob.G.shape[0]
    N = A.shape[0]

    def step(theta, k):
        ks = jax.random.fold_in(key, k)
        noise = scales[:, None] * jax.random.laplace(ks, (N, p))
        q = 2.0 * (jnp.einsum("npq,q->np", A, theta) - b) + noise
        g = reg_grad(prob, theta) + jnp.einsum(
            "n,np->p", n_i / prob.n_total, q)
        theta = jnp.clip(theta - lr * g, -prob.theta_max, prob.theta_max)
        return theta, relative_fitness(prob, theta)

    theta, psis = jax.lax.scan(step, jnp.zeros(p), jnp.arange(horizon))
    return SyncTrace(theta, psis)


def run_algorithm1(key, prob: LinearProblem, owners: List[Owner],
                   cfg: Algo1Config) -> Algo1Trace:
    """Legacy entrypoint, kept bit-compatible with the original seed.

    Deliberate compat decision: with composition='per_owner_rounds' this
    path only RESCALES noise to the capped horizon and does not enforce the
    response cap the reduced scale relies on (owners drawn more than R_i
    times exceed their stated eps_i). The Federation session enforces the
    cap (refusal + ledger); use it for budget-honest capped runs.
    """
    T = cfg.horizon
    A, b, n_i = stack_gram(owners)
    if cfg.composition == "per_owner_rounds":
        from repro.federation.privacy import capped_rounds
        T_eff = capped_rounds(T, len(owners), cfg.cap_slack)
    else:
        T_eff = T
    scales = jnp.asarray([
        0.0 if cfg.noiseless else
        laplace_scale_theorem1(o.xi, T_eff, o.n, e)
        for o, e in zip(owners, cfg.epsilons)], jnp.float32)
    return scan_engine(key, prob, A, b, n_i, scales, horizon=T,
                       rho=cfg.rho, sigma=cfg.sigma)


def run_many(key, prob: LinearProblem, owners: List[Owner], cfg: Algo1Config,
             n_runs: int) -> Algo1Trace:
    """vmapped multi-seed runs (percentile statistics of Figs. 2/8)."""
    keys = jax.random.split(key, n_runs)
    return jax.vmap(lambda k: run_algorithm1(k, prob, owners, cfg))(keys)
