"""Differential-privacy mechanisms and accounting (Theorem 1).

Canonical home; ``repro.core.privacy`` is a compatibility shim over this
module. The pluggable session-level mechanisms live in
``repro.federation.mechanisms``.

Theorem 1 (paper): over a horizon of at most T query rounds, owner i's
responses are eps_i-DP if each response adds i.i.d. Laplace noise with scale

    b_i = 2 * Xi * T / (n_i * eps_i)

where Xi bounds the per-record gradient norm (Assumption 2) and n_i is the
owner's dataset size. The proof splits eps_i evenly over T rounds and uses
L1 sensitivity ||Q(D) - Q(D')||_1 = 2*Xi/n_i for the *averaged* gradient.

Faithfulness note: the paper treats the sup of the L2 gradient norm (Xi) as
an L1 sensitivity bound, which is loose-in-the-wrong-direction for p > 1
(||v||_1 <= sqrt(p) ||v||_2). We default to the paper's exact scale
(`l1_slack='paper'`) and offer the rigorous `l1_slack='strict'` variant that
multiplies by sqrt(p). All paper-reproduction experiments use 'paper'.

Beyond-paper composition (`composition='per_owner_rounds'`): the paper
calibrates to the worst case of ALL T rounds hitting one owner. Under
uniform selection, owner i answers ~T/N rounds; if the owner enforces a hard
response cap R_i = ceil(c*T/N) (refusing afterwards — refusal is
data-independent, hence free), the same eps_i is achieved with scale
2*Xi*R_i/(n_i*eps_i): an ~N/c-fold noise reduction. Recorded in
EXPERIMENTS.md as a beyond-paper optimization.

`composition='tree'` (DP-FTRL, Kairouz et al. 2021): responses carry
CORRELATED noise from a binary tree of depth d — each response releases
the delta of the active-node sum, so the cumulative noise over an
owner's t responses is popcount(t) <= d node draws instead of t. Each
response's gradient enters exactly d node queries (one per level), so
Laplace composition charges eps/(d*R) per node participation at
per-node scale d * b(R), where R = min(T, 2^d - 1) is the tree's leaf
capacity (enforced as the response cap). The integer response ledger is
UNCHANGED — each grant still costs eps/R — which keeps DeviceLedger
reconciliation bit-exact; `summary()` exposes the per-level
node-completion view.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, Optional, Sequence

import jax
import jax.numpy as jnp


def laplace_scale_theorem1(xi: float, horizon: int, n_records: int,
                           epsilon: float, *, p: Optional[int] = None,
                           l1_slack: str = "paper") -> float:
    """Noise scale b_i of Theorem 1."""
    if epsilon <= 0:
        raise ValueError("epsilon must be > 0")
    b = 2.0 * xi * horizon / (n_records * epsilon)
    if l1_slack == "strict":
        if p is None:
            raise ValueError("strict L1 slack needs the dimension p")
        b *= math.sqrt(p)
    elif l1_slack != "paper":
        raise ValueError(l1_slack)
    return b


def capped_rounds(horizon: int, n_owners: int, slack: float = 2.0) -> int:
    """Response cap R_i for the beyond-paper per-owner-rounds composition."""
    return max(1, math.ceil(slack * horizon / n_owners))


def laplace_noise(key, shape, scale: float, dtype=jnp.float32) -> jax.Array:
    return scale * jax.random.laplace(key, shape, dtype)


def laplace_noise_tree(key, tree, scale: float):
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    keys = jax.random.split(key, len(leaves))
    noisy = [laplace_noise(k, leaf.shape, scale, jnp.float32).astype(leaf.dtype)
             for k, leaf in zip(keys, leaves)]
    return jax.tree_util.tree_unflatten(treedef, noisy)


@jax.tree_util.register_pytree_node_class
class DeviceLedger:
    """Device-resident mirror of the PrivacyAccountant's counters.

    Lives INSIDE the deep-path training state so authorization becomes an
    in-graph predicate (`spent[i] < cap[i]`) instead of a host round-trip:
    the fused multi-round driver scans thousands of rounds per dispatch and
    masks refused rounds with `jnp.where`. `spent` counts responses GRANTED
    in-graph (seeded from the host accountant at session init); `refused`
    counts in-graph refusals. The host accountant stays the single source
    of truth — `Federation.reconcile()` folds these counters back into it
    bit-exactly after every fused run.

    `sid` is the snapshot generation, carried as STATIC pytree metadata
    (not a traced leaf): every `device_ledger()` snapshot gets a fresh id,
    and reconcile only accepts the lineage of the latest snapshot — two
    live states from one session would otherwise fold divergent counter
    chains against a single baseline and silently under-count spend.

    Fault-outcome columns (PR 8 — epsilon is charged AT RESPONSE TIME):
    `spent` counts every round the owner ANSWERED, including rounds the
    in-graph guards subsequently rejected — the noisy query left the
    owner, so its budget is gone whether or not the learner kept the
    update. `dropped` counts rounds lost BEFORE the query was answered
    (owner unreachable): no response happened, no epsilon is spent.
    `faulted` counts answered-then-rejected rounds (non-finite update,
    payload-checksum mismatch, stale replay) — a subset of `spent`'s
    increments, recorded so the host accountant can see budget that
    bought no progress. `quarantined` counts rounds masked because the
    owner was quarantined (no answer, no epsilon, no refusal).

    Staleness-runtime columns (PR 10, same response-time rule):
    `timed_out` counts rounds the owner ANSWERED but past the learner
    deadline — epsilon is spent (a subset of `spent`'s increments, like
    `faulted`) and the update is masked. `retried` counts rounds masked
    because the owner sat in its retry-backoff cooldown: the learner
    never dispatched the query, so no answer and no epsilon (like
    `quarantined`, but temporary).
    """

    def __init__(self, spent: jax.Array, cap: jax.Array, refused: jax.Array,
                 dropped: Optional[jax.Array] = None,
                 faulted: Optional[jax.Array] = None,
                 quarantined: Optional[jax.Array] = None,
                 timed_out: Optional[jax.Array] = None,
                 retried: Optional[jax.Array] = None,
                 sid: int = 0):
        self.spent = spent      # (N,) int32 — responses granted so far
        self.cap = cap          # (N,) int32 — per-owner response cap (T_eff)
        self.refused = refused  # (N,) int32 — in-graph refusals
        # distinct zero buffers per field — donated states may not alias
        self.dropped = (jnp.zeros_like(spent) if dropped is None
                        else dropped)        # lost pre-answer: no eps
        self.faulted = (jnp.zeros_like(spent) if faulted is None
                        else faulted)        # answered, rejected: eps spent
        self.quarantined = (jnp.zeros_like(spent) if quarantined is None
                            else quarantined)  # masked while quarantined
        self.timed_out = (jnp.zeros_like(spent) if timed_out is None
                          else timed_out)    # answered late: eps spent
        self.retried = (jnp.zeros_like(spent) if retried is None
                        else retried)        # masked in backoff: no eps
        self.sid = sid

    def tree_flatten(self):
        return (self.spent, self.cap, self.refused, self.dropped,
                self.faulted, self.quarantined, self.timed_out,
                self.retried), self.sid

    @classmethod
    def tree_unflatten(cls, sid, children):
        return cls(*children, sid=sid)

    def replace(self, **kw) -> "DeviceLedger":
        fields = {"spent": self.spent, "cap": self.cap,
                  "refused": self.refused, "dropped": self.dropped,
                  "faulted": self.faulted,
                  "quarantined": self.quarantined,
                  "timed_out": self.timed_out, "retried": self.retried,
                  "sid": self.sid}
        fields.update(kw)
        return DeviceLedger(**fields)

    def remaining(self) -> jax.Array:
        return jnp.maximum(self.cap - self.spent, 0)

    def authorized(self, owner_idx: jax.Array) -> jax.Array:
        """() bool — may `owner_idx` answer one more query?"""
        return self.spent[owner_idx] < self.cap[owner_idx]


def make_device_ledger(caps: Sequence[int],
                       spent: Optional[Sequence[int]] = None,
                       refused: Optional[Sequence[int]] = None,
                       dropped: Optional[Sequence[int]] = None,
                       faulted: Optional[Sequence[int]] = None,
                       quarantined: Optional[Sequence[int]] = None,
                       timed_out: Optional[Sequence[int]] = None,
                       retried: Optional[Sequence[int]] = None,
                       sid: int = 0) -> DeviceLedger:
    caps = jnp.asarray(caps, jnp.int32)

    def col(v):
        # distinct buffers per field — donated states may not alias leaves
        return (jnp.zeros(caps.shape, jnp.int32) if v is None
                else jnp.asarray(v, jnp.int32))

    return DeviceLedger(spent=col(spent), cap=caps, refused=col(refused),
                        dropped=col(dropped), faulted=col(faulted),
                        quarantined=col(quarantined),
                        timed_out=col(timed_out), retried=col(retried),
                        sid=sid)


@dataclasses.dataclass
class OwnerLedger:
    epsilon: float
    horizon: int
    responses: int = 0
    cap: Optional[int] = None        # None -> paper composition (cap = T)

    @property
    def effective_horizon(self) -> int:
        return self.cap if self.cap is not None else self.horizon

    @property
    def spent(self) -> float:
        """Budget consumed so far (eps_i/T_eff per response)."""
        return self.responses * self.epsilon / self.effective_horizon

    @property
    def exhausted(self) -> bool:
        return self.responses >= self.effective_horizon


class PrivacyAccountant:
    """Tracks per-owner budget spend across the training horizon."""

    def __init__(self, epsilons: Dict[int, float], horizon: int,
                 composition: str = "paper", cap_slack: float = 2.0,
                 n_owners: Optional[int] = None,
                 tree_depth: Optional[int] = None):
        if composition not in ("paper", "per_owner_rounds", "tree"):
            raise ValueError(composition)
        cap = None
        if composition == "per_owner_rounds":
            cap = capped_rounds(horizon, n_owners or len(epsilons), cap_slack)
        elif composition == "tree":
            # A depth-d tree holds 2^d - 1 leaves; past that the online
            # binary counter has no level for the fresh node, so the cap
            # doubles as the correctness bound the engine refuses at.
            # depth 0 is the degenerate no-tree mechanism: paper cap (T).
            if tree_depth is None:
                raise ValueError("tree composition needs tree_depth")
            if tree_depth > 0:
                cap = min(horizon, (1 << tree_depth) - 1)
        elif tree_depth is not None:
            raise ValueError("tree_depth only applies to composition='tree'")
        self.ledgers = {i: OwnerLedger(e, horizon, cap=cap)
                        for i, e in epsilons.items()}
        self.composition = composition
        self.tree_depth = tree_depth

    def record_response(self, owner: int) -> bool:
        """Returns True if the owner may respond (budget remains)."""
        led = self.ledgers[owner]
        if led.exhausted:
            return False
        led.responses += 1
        return True

    def record_responses(self, owner: int, count: int) -> int:
        """Bulk path: grant up to `count` responses, return how many were
        granted (the rest would exceed the owner's cap)."""
        led = self.ledgers[owner]
        granted = max(0, min(count, led.effective_horizon - led.responses))
        led.responses += granted
        return granted

    def scale_for(self, owner: int, xi: float, n_records: int, **kw) -> float:
        led = self.ledgers[owner]
        return laplace_scale_theorem1(xi, led.effective_horizon, n_records,
                                      led.epsilon, **kw)

    def summary(self) -> Dict[int, Dict]:
        out = {i: {"epsilon": led.epsilon, "responses": led.responses,
                   "spent": led.spent, "exhausted": led.exhausted}
               for i, led in self.ledgers.items()}
        if self.composition == "tree" and (self.tree_depth or 0) > 0:
            d = self.tree_depth
            for i, led in self.ledgers.items():
                # Tree-completion view of the SAME integer spend: after t
                # leaves, level l has completed t >> l nodes, and every
                # response participates in exactly d node queries, so the
                # per-node budget eps/(d * R) recomposes to the eps/R per
                # response the integer ledger charges — which is why
                # reconcile() needs no tree-specific arithmetic.
                r = led.effective_horizon
                out[i]["tree"] = {
                    "depth": d,
                    "capacity": (1 << d) - 1,
                    "nodes_completed_per_level": [led.responses >> lvl
                                                  for lvl in range(d)],
                    "eps_per_node": led.epsilon / (d * r),
                }
        return out

    def device_ledger(self) -> DeviceLedger:
        """Snapshot the counters as a DeviceLedger (owners 0..N-1 dense).

        `spent` is seeded from the CURRENT response counts, so a device
        ledger created mid-session refuses exactly where the host would.
        """
        idx = sorted(self.ledgers)
        if idx != list(range(len(idx))):
            raise ValueError("device ledger needs dense owner ids 0..N-1")
        return make_device_ledger(
            caps=[self.ledgers[i].effective_horizon for i in idx],
            spent=[self.ledgers[i].responses for i in idx])
