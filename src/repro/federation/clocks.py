"""Poisson-clock owner scheduling (Section 3).

Canonical home; ``repro.core.clocks`` is a compatibility shim. The pluggable
session-level schedules live in ``repro.federation.schedules``.

Each owner carries an independent rate-1 Poisson point process; whenever a
clock ticks, that owner communicates with the learner. Symmetric rates make
the communicating-owner sequence i_k i.i.d. uniform over owners — which is
exactly line 3 of Algorithm 1. We provide both the continuous-time
simulation (for communication-timing figures, Figs. 3/9) and the uniform
shortcut used inside training loops.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class Schedule(NamedTuple):
    times: jax.Array    # (T,) fp32 — communication instants t_k
    owners: jax.Array   # (T,) int32 — communicating owner i_k


def poisson_schedule(key, n_owners: int, horizon: int, rate: float = 1.0
                     ) -> Schedule:
    """Continuous-time simulation: superpose N rate-`rate` processes.

    The superposition is a rate-(N*rate) Poisson process whose marks are
    i.i.d. uniform — we sample inter-arrival gaps and marks directly.
    """
    k1, k2 = jax.random.split(key)
    gaps = jax.random.exponential(k1, (horizon,)) / (n_owners * rate)
    times = jnp.cumsum(gaps)
    owners = jax.random.randint(k2, (horizon,), 0, n_owners)
    return Schedule(times, owners)


def uniform_schedule(key, n_owners: int, horizon: int) -> jax.Array:
    """The i.i.d.-uniform i_k sequence (equivalent in distribution)."""
    return jax.random.randint(key, (horizon,), 0, n_owners)


def owner_counts(owners: jax.Array, n_owners: int) -> jax.Array:
    return jnp.bincount(owners, length=n_owners)
