"""Pluggable owner Schedules — who communicates at tick k.

A Schedule turns a PRNG key into the (T,) i_k owner sequence the engines
scan over. All three variants are jit/vmap-safe, so multi-seed statistics
stay one vmap away.

Device contract: `draw` MUST return a device-resident (T,) int32 array
from jax ops only (no host materialization) — the fused multi-round driver
(`Federation.run_rounds`) feeds it straight into a `lax.scan`, so a
schedule that round-trips through numpy would reintroduce the per-round
host sync the driver exists to remove. `as_owner_seq` is the shared
normalizer that enforces the dtype/shape of hand-rolled sequences.

  UniformSchedule           — line 3 of Algorithm 1: i.i.d. uniform draws
                              (the distributional shortcut for symmetric
                              rate-1 Poisson clocks).
  PoissonSchedule           — the continuous-time simulation itself, for
                              communication-timing studies (Figs. 3/9).
  AvailabilityTraceSchedule — beyond-paper: geographically-scattered owners
                              that only answer inside per-owner availability
                              windows of a recurring period (e.g. business
                              hours across timezones). Ticks still arrive
                              from superposed Poisson clocks; the mark is
                              drawn uniformly among the owners whose window
                              contains that instant.

DP-FTRL-style participation schedules (see PAPERS.md) are further
implementations of the same one-method protocol.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Protocol, Tuple, runtime_checkable

import jax
import jax.numpy as jnp
import numpy as np

from repro.federation.clocks import (Schedule, poisson_schedule,
                                     uniform_schedule)


@runtime_checkable
class ScheduleProtocol(Protocol):
    def draw(self, key, n_owners: int, horizon: int) -> jax.Array:
        """(T,) int32 DEVICE owner sequence (jit-safe jax ops only)."""
        ...


def as_owner_seq(seq, n_owners: int) -> jax.Array:
    """Normalize an owner sequence to the engines' (T,) int32 device form,
    validating statically-known bounds (host lists fail fast here instead
    of as an out-of-range gather inside the scan)."""
    seq = jnp.asarray(seq)
    if seq.ndim != 1:
        raise ValueError(f"owner sequence must be 1-D, got {seq.shape}")
    if not jnp.issubdtype(seq.dtype, jnp.integer):
        raise ValueError(f"owner sequence must be integer, got {seq.dtype}")
    if isinstance(seq, jax.core.Tracer):
        return seq.astype(jnp.int32)
    if seq.size and (int(seq.min()) < 0 or int(seq.max()) >= n_owners):
        raise ValueError(
            f"owner sequence out of range for {n_owners} owners")
    return seq.astype(jnp.int32)


# ------------------ schedule analysis: conflict-free groups ----------------
# Rounds touching DISTINCT owners only interact through theta_L (each reads
# and writes its own bank row), so a run of consecutive rounds with no
# repeated owner can execute as one owner-parallel batch. These two helpers
# are the host-side analysis pass behind `Federation.run_rounds(...,
# owner_parallel=True)`: partition the (K,) sequence into maximal
# conflict-free groups, then pack the groups into the rectangular
# (n_groups, G_max) index/mask arrays the grouped driver scans over.

def partition_conflict_free(owner_seq,
                            max_group: Optional[int] = None
                            ) -> List[Tuple[int, int]]:
    """Greedy maximal partition of a CONCRETE (K,) owner sequence into
    consecutive (start, length) groups with all-distinct owners.

    Greedy left-to-right is optimal here (fewest groups): a group ends
    exactly when the next owner would repeat — ending it earlier can never
    reduce the group count. `max_group` caps group length (max_group=1
    degenerates to the sequential schedule). Host-side by design: this is
    the schedule-analysis pass, run once per dispatch, not per round."""
    seq = np.asarray(owner_seq)
    if seq.ndim != 1:
        raise ValueError(f"owner sequence must be 1-D, got {seq.shape}")
    if max_group is not None and max_group < 1:
        raise ValueError(f"max_group must be >= 1, got {max_group}")
    groups: List[Tuple[int, int]] = []
    start, seen = 0, set()
    for k, o in enumerate(seq.tolist()):
        if o in seen or (max_group is not None and k - start >= max_group):
            groups.append((start, k - start))
            start, seen = k, {o}
        else:
            seen.add(o)
    if len(seq) > start:
        groups.append((start, len(seq) - start))
    return groups


def auto_max_group(owner_seq, step_overhead: float = 4.0,
                   cap: int = 16) -> int:
    """Pick the owner-parallel group cap from the schedule's own repeat
    statistics (the `max_group="auto"` default of `Federation.run_rounds`).

    Every candidate cap c is scored on the CONCRETE sequence by actually
    partitioning it (empirical owner-repeat statistics, not a
    distributional model): a dispatch costs ~one scan step per group —
    each paying a fixed overhead of `step_overhead` member-compute units
    (the (N, P) bank loop-carry copy dominates it at MLP scale on CPU) —
    plus the vmapped member compute, padded to c slots. Minimizing
    n_groups(c) * (c + step_overhead) therefore trades padding waste
    against step count; `cap` bounds the search. Ties go to the SMALLER
    cap: less padding at equal cost. Returns 1 when grouping cannot win
    (e.g. a single-owner schedule), which the session routes to the
    strictly sequential scan.

    Candidates come from a FIXED ladder (1,2,3,4,6,8,12,16), not every
    integer: the chosen cap is also the member-axis shape the session
    compiles the grouped program for, and schedule-drawn dispatches pick
    a fresh cap every call — a dense candidate range would recompile the
    whole K-round program on nearly every dispatch, while the ladder
    bounds the jit cache at its own size (and the host-side scoring at
    |ladder| partitions)."""
    seq = np.asarray(owner_seq)
    if seq.size == 0:
        return 1
    longest = max(length for _, length in partition_conflict_free(seq))
    best_c, best_cost = 1, float("inf")
    for c in (1, 2, 3, 4, 6, 8, 12, 16):
        if c > min(longest, cap):
            break
        n_g = len(partition_conflict_free(seq, c))
        cost = n_g * (c + step_overhead)
        if cost < best_cost:
            best_c, best_cost = c, cost
    return best_c


def pack_groups(groups: List[Tuple[int, int]]
                ) -> Tuple[np.ndarray, np.ndarray]:
    """(start, length) groups -> (idx, valid), both (n_groups, G_max).

    `idx[g, j]` is the ROUND index of member j of group g (so `a[idx]`
    gathers any (K,)-leading array into group-major layout); padding
    repeats round index 0 with `valid=False` — the grouped driver masks
    padded members out of every write."""
    if not groups:
        return (np.zeros((0, 1), np.int32), np.zeros((0, 1), bool))
    gmax = max(length for _, length in groups)
    idx = np.zeros((len(groups), gmax), np.int32)
    valid = np.zeros((len(groups), gmax), bool)
    for g, (start, length) in enumerate(groups):
        idx[g, :length] = np.arange(start, start + length)
        valid[g, :length] = True
    return idx, valid


@dataclasses.dataclass(frozen=True)
class UniformSchedule:
    def draw(self, key, n_owners: int, horizon: int) -> jax.Array:
        return uniform_schedule(key, n_owners, horizon).astype(jnp.int32)


@dataclasses.dataclass(frozen=True)
class PoissonSchedule:
    rate: float = 1.0

    def draw_with_times(self, key, n_owners: int, horizon: int) -> Schedule:
        return poisson_schedule(key, n_owners, horizon, self.rate)

    def draw(self, key, n_owners: int, horizon: int) -> jax.Array:
        return self.draw_with_times(key, n_owners, horizon).owners.astype(
            jnp.int32)


@dataclasses.dataclass(frozen=True)
class AvailabilityTraceSchedule:
    """Per-owner availability windows over a recurring period.

    windows[i] = (start, end) as fractions of `period` in [0, 1);
    wrap-around windows (start > end) model e.g. an owner whose business
    hours straddle the period boundary. If no owner is available at a tick
    (a gap in the trace), every owner is considered available so the clock
    keeps ticking — the learner never idles on an empty federation.

    `trace` replays a RECORDED owner sequence instead of sampling one
    (tiled to the horizon if shorter): deterministic replay of a
    production availability log, e.g. for chaos/regression studies. The
    ids are validated against the windowed owner count AT CONSTRUCTION —
    an out-of-range id would otherwise scatter with mode='drop' inside
    the fused scan and silently lose the round.
    """
    windows: Tuple[Tuple[float, float], ...]
    period: float = 24.0
    rate: float = 1.0
    trace: Optional[Tuple[int, ...]] = None

    def __post_init__(self):
        if self.trace is None:
            return
        trace = tuple(int(o) for o in self.trace)
        if not trace:
            raise ValueError("an empty trace cannot schedule any round")
        n = len(self.windows)
        bad = sorted({o for o in trace if not 0 <= o < n})
        if bad:
            raise ValueError(
                f"trace owner ids {bad} out of range for the {n} windowed "
                "owners — inside the fused scan an out-of-range id would "
                "scatter with mode='drop' and silently lose the round")
        object.__setattr__(self, "trace", trace)

    def _tiled(self, horizon: int) -> jax.Array:
        """The recorded trace tiled to `horizon`, as a DEVICE int32 array,
        cached on the instance keyed by horizon.

        draw() used to rebuild the tiling with `np.resize` and re-upload
        it on EVERY dispatch — O(horizon) host work and one
        host->device transfer per call for a bit-identical result. The
        cache keeps one device copy per distinct horizon for the
        instance's lifetime (sessions dispatch a fixed k_rounds, so in
        practice that is one entry). Mutating a frozen dataclass's
        `__dict__` is deliberate: `_tiled_cache` is not a field, so
        equality/hash/replace semantics are untouched."""
        cache = self.__dict__.get("_tiled_cache")
        if cache is None:
            cache = {}
            object.__setattr__(self, "_tiled_cache", cache)
        out = cache.get(horizon)
        if out is None:
            out = jnp.asarray(np.resize(
                np.asarray(self.trace, np.int32), horizon))
            cache[horizon] = out
        return out

    def draw_with_times(self, key, n_owners: int, horizon: int) -> Schedule:
        if len(self.windows) != n_owners:
            raise ValueError(
                f"{len(self.windows)} windows for {n_owners} owners")
        k_time, k_pick = jax.random.split(key)
        times = poisson_schedule(k_time, n_owners, horizon, self.rate).times
        if self.trace is not None:
            return Schedule(times, self._tiled(horizon))
        inside = self.available(times, fallback=True)            # (T, N)
        gumbel = jax.random.gumbel(k_pick, (horizon, n_owners))
        owners = jnp.argmax(jnp.where(inside, gumbel, -jnp.inf),
                            axis=1).astype(jnp.int32)
        return Schedule(times, owners)

    def draw(self, key, n_owners: int, horizon: int) -> jax.Array:
        return self.draw_with_times(key, n_owners, horizon).owners

    def trace_ring(self, chunk: int = 4096) -> "TraceRing":
        """A streaming view of the recorded trace (see TraceRing) —
        multi-hour traces feed the engines chunk-by-chunk instead of
        materializing the whole tiled (K,) sequence device-side."""
        if self.trace is None:
            raise ValueError("trace_ring needs a recorded trace")
        return TraceRing(self.trace, chunk=chunk)

    def available(self, times: jax.Array,
                  fallback: bool = False) -> jax.Array:
        """(T, N) availability mask at the given instants.

        fallback=True applies the same everyone-available escape hatch at
        trace gaps that draw_with_times uses, so the mask matches what the
        draw actually sampled from; fallback=False is the raw window
        membership (for tests/plots)."""
        phase = (times / self.period) % 1.0
        starts = jnp.asarray([w[0] for w in self.windows])
        ends = jnp.asarray([w[1] for w in self.windows])
        inside = jnp.where(
            starts <= ends,
            (phase[:, None] >= starts) & (phase[:, None] < ends),
            (phase[:, None] >= starts) | (phase[:, None] < ends))
        if fallback:
            inside = jnp.where(inside.any(axis=1, keepdims=True), inside,
                               True)
        return inside


class TraceRing:
    """Device-resident ring buffer over a recorded availability trace.

    Multi-hour production traces reach tens of millions of rounds;
    materializing the whole tiled (K,) owner sequence device-side per
    dispatch (what ``AvailabilityTraceSchedule.draw`` does) costs memory
    and upload time proportional to the TRACE, not the dispatch. The
    ring streams it instead: the host keeps the raw trace, the device
    holds ONE `chunk`-sized int32 buffer, and

      * ``next(k)`` returns the next consecutive (k,) int32 device
        window — a single ``lax.dynamic_slice`` whose offset is a traced
        operand, so every same-k call shares one compiled executable —
        uploading a fresh chunk only when the cursor crosses a chunk
        boundary (one host->device transfer per `chunk` rounds);
      * ``window(k)`` is the HOST peek the paging prefetcher keys on:
        the owner ids the next dispatch will touch, with no cursor
        advance and no device sync.

    Wrap semantics match ``np.resize`` tiling (the trace repeats
    end-to-end), so a session replaying through the ring sees the exact
    sequence ``AvailabilityTraceSchedule.draw`` would hand it
    (property-tested in tests/test_property.py).
    """

    def __init__(self, trace, chunk: int = 4096):
        trace = np.asarray(trace, np.int32).reshape(-1)
        if trace.size == 0:
            raise ValueError("an empty trace cannot schedule any round")
        if chunk < 1:
            raise ValueError(f"chunk must be >= 1, got {chunk}")
        self._trace = trace
        self.chunk = int(chunk)
        self.cursor = 0                 # absolute position in the tiling
        self._chunk_start = 0           # absolute start of resident chunk
        self._buf: Optional[jax.Array] = None

    def __len__(self) -> int:
        return int(self._trace.size)

    @property
    def resident_bytes(self) -> int:
        """Device bytes the ring holds — O(chunk), independent of the
        trace length (asserted by the paged-bank benchmarks)."""
        return 0 if self._buf is None else int(self._buf.nbytes)

    def window(self, k: int) -> np.ndarray:
        """(k,) int32 HOST view of the next k owner ids (no advance)."""
        if k < 0:
            raise ValueError(f"k must be >= 0, got {k}")
        return np.take(self._trace, self.cursor + np.arange(k),
                       mode="wrap")

    def _refill(self, start: int) -> None:
        idx = (start + np.arange(self.chunk)) % self._trace.size
        self._buf = jnp.asarray(self._trace[idx])
        self._chunk_start = start

    def next(self, k: int) -> jax.Array:
        """The next consecutive (k,) int32 DEVICE owner window; advances
        the cursor. k larger than the chunk degrades to one direct
        upload of exactly k ids (correct, just unbuffered) — size the
        chunk at or above the dispatch length to stay on the ring."""
        if k < 1:
            raise ValueError(f"k must be >= 1, got {k}")
        if k > self.chunk:
            out = jnp.asarray(self.window(k))
            self.cursor += k
            return out
        if (self._buf is None
                or self.cursor + k > self._chunk_start + self.chunk):
            self._refill(self.cursor)
        off = self.cursor - self._chunk_start
        out = jax.lax.dynamic_slice(self._buf,
                                    (jnp.asarray(off, jnp.int32),), (k,))
        self.cursor += k
        return out
