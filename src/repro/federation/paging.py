"""Paged owner bank: host-side pager + paged-state construction.

The flat engine's owner bank is the algorithm's dominant memory cost —
N owner copies of the model, (N, P) resident on device. That caps the
federation size at whatever N*P fits in accelerator memory, even though
any single dispatch only ever touches the few owners its schedule window
names. This module splits the bank into two tiers:

  * HOT  — a device-resident working set of `n_hot` rows
    (``flatten.PagedBank``: a dense (n_hot, P) matrix or a QuantBank
    with n_hot code rows, plus the sorted (n_hot,) page table). The
    DP-FTRL tree's node rows page WITH their bank rows ((n_hot, d, P));
    every (N,)-scalar column — ledger counters, tree leaf counts, fault
    checksums/windows/quarantine — stays resident, so paging changes
    WHERE rows live, never what the accounting sees.
  * COLD — a host row store (``repro.checkpoint.MemoryRowStore`` /
    ``MemmapRowStore``) with default-row lazy semantics: a never-written
    owner reads as the shared init row, so a million-owner federation
    costs O(rows actually trained), not O(N*P), until trained.

``OwnerPager`` is the host half: before each dispatch the session hands
it the schedule's upcoming window (``prefetch``), and the pager makes
every owner in it resident — evicting the least-recently-dispatched
rows to the cold tier (dirty rows write back; clean rows just drop) and
installing the needed rows via ONE device gather + scatter that keeps
the page table sorted. Inside the scan the drivers resolve owner id ->
hot slot with ``PagedBank.lookup`` (searchsorted over the sorted table
— no host sync), and a row that is somehow NOT resident is a bit-exact
masked no-op charged as a refusal, so the engine stays lawful even if
the prefetch contract is violated.

Bit-exactness contract: row bits round-trip the cold tier exactly for
every storage dtype (f32/bf16 and the int8/fp8 codec's codes+scales go
through the checkpoint module's raw-bit views), the shared EF residual
belongs to the session and never pages, and with ``n_hot >= N`` every
row is permanently resident — the paged engine then reproduces the flat
engine bit-for-bit on all three drivers (parity-tested).
"""
from __future__ import annotations

import os
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import MemmapRowStore, MemoryRowStore
from repro.federation.deep import (AsyncDPConfig, AsyncDPState, TreeNoise,
                                   _init_staleness, init_fault_state)
from repro.federation.flatten import (PagedBank, ParamFlat, QuantBank,
                                      as_bank_codec, init_flat_bank,
                                      pack_params)
from repro.federation.privacy import make_device_ledger


def _as_host(a) -> np.ndarray:
    """Device -> host copy preserving raw bits (bf16/fp8 come back as
    their ml_dtypes numpy counterparts, which the row stores round-trip
    through uint views)."""
    return np.asarray(jax.device_get(a))


class OwnerPager:
    """Host half of the paged owner bank (see module docstring).

    Tracks a host mirror of the device page table, the dirty set (owners
    dispatched since their row was last written back — the device may
    have rewritten any dispatched row, so dispatch marks dirty), and an
    LRU stamp per resident owner. All device traffic is batched: one
    row gather + one scatter per prefetch that changes residency, one
    read-back per eviction/flush.
    """

    def __init__(self, n_owners: int, n_hot: int, hot_ids: np.ndarray,
                 stores: Dict[str, Any]):
        self.n_owners = int(n_owners)
        self.n_hot = int(n_hot)
        self._sentinel = self.n_owners
        self._hot_ids = np.array(hot_ids, np.int32)   # host mirror, sorted
        self.stores = stores                          # name -> row store
        self.dirty: set = set()
        self._clock = 0
        self._last_used: Dict[int, int] = {
            int(o): 0 for o in self._hot_ids if o != self._sentinel}
        self.stats = {"prefetches": 0, "loads": 0, "evictions": 0,
                      "writebacks": 0}

    # ------------------------------------------------------------- views

    @property
    def resident_ids(self) -> np.ndarray:
        """Sorted real owner ids currently resident (host mirror)."""
        return self._hot_ids[self._hot_ids != self._sentinel]

    def _slot_of(self) -> Dict[int, int]:
        return {int(o): s for s, o in enumerate(self._hot_ids)
                if o != self._sentinel}

    # ----------------------------------------------------- device access

    def _read_slots(self, state: AsyncDPState,
                    slots: np.ndarray) -> Dict[str, np.ndarray]:
        """Batched host read of the named slots' row payloads."""
        hot = state.bank.hot
        out: Dict[str, np.ndarray] = {}
        if isinstance(hot, QuantBank):
            out["codes"] = _as_host(hot.codes[slots])
            out["scales"] = _as_host(hot.scales[slots])
        else:
            out["rows"] = _as_host(hot[slots])
        if "tree" in self.stores:
            out["tree"] = _as_host(state.tree.nodes[slots])
        return out

    def _install(self, state: AsyncDPState, new_ids: np.ndarray,
                 src: np.ndarray, fresh_pos: np.ndarray,
                 fresh: Dict[str, np.ndarray]) -> AsyncDPState:
        """Re-lay the hot tier: slot i takes old slot src[i], then the
        fresh (cold-loaded or default) rows land at fresh_pos. One
        gather + one scatter per buffer, page table uploaded once."""
        src_d = jnp.asarray(src, jnp.int32)
        pos_d = jnp.asarray(fresh_pos, jnp.int32)
        hot = state.bank.hot

        def relay(buf, key):
            new = buf[src_d]
            if fresh_pos.size:
                new = new.at[pos_d].set(
                    jnp.asarray(fresh[key], dtype=buf.dtype))
            return new

        if isinstance(hot, QuantBank):
            hot = QuantBank(relay(hot.codes, "codes"),
                            relay(hot.scales, "scales"),
                            hot.residual, hot.codec)
        else:
            hot = relay(hot, "rows")
        bank = state.bank.replace(hot=hot,
                                  hot_ids=jnp.asarray(new_ids, jnp.int32))
        tree = state.tree
        if "tree" in self.stores:
            tree = tree.replace(nodes=relay(tree.nodes, "tree"))
        self._hot_ids = np.array(new_ids, np.int32)
        return state._replace(bank=bank, tree=tree)

    # -------------------------------------------------------- operations

    def prefetch(self, state: AsyncDPState, window) -> AsyncDPState:
        """Make every owner in the upcoming dispatch window resident.

        `window` is the HOST owner-id view of the rounds the next
        dispatch will run (e.g. ``TraceRing.window(k)`` or the (K,)
        sequence about to be passed to the driver). Owners already
        resident cost nothing; the rest are loaded from the cold tier
        into slots freed by evicting the least-recently-dispatched
        rows (dirty rows write back first). Raises if the window's
        working set exceeds n_hot. Every owner in the window is marked
        dirty — the device may rewrite any dispatched row."""
        ids = np.unique(np.asarray(window, np.int64).reshape(-1))
        if ids.size and (ids.min() < 0 or ids.max() >= self.n_owners):
            raise ValueError(
                f"window owner ids out of range for {self.n_owners} owners")
        if ids.size > self.n_hot:
            raise ValueError(
                f"dispatch window touches {ids.size} distinct owners but "
                f"the hot tier holds {self.n_hot} rows; raise n_hot or "
                f"shorten the dispatch")
        self.stats["prefetches"] += 1
        self._clock += 1
        id_list = [int(i) for i in ids]
        for o in id_list:
            self._last_used[o] = self._clock
        resident = set(int(o) for o in self.resident_ids)
        need = [o for o in id_list if o not in resident]
        self.dirty.update(id_list)
        if not need:
            return state

        # pick victims: least-recently-dispatched residents not needed now
        keep_free = resident - set(id_list)
        n_free = self.n_hot - len(resident)
        n_evict = max(0, len(need) - n_free)
        victims = sorted(keep_free,
                         key=lambda o: (self._last_used.get(o, -1), o)
                         )[:n_evict]
        slot_of = self._slot_of()
        if victims:
            self._evict(state, victims, slot_of)

        new_res = sorted((resident - set(victims)) | set(need))
        new_ids = np.full((self.n_hot,), self._sentinel, np.int32)
        new_ids[:len(new_res)] = new_res      # sentinel sorts last: sorted

        # source map: surviving rows permute from their old slot; loaded
        # and sentinel rows come in fresh (cold tier serves the default
        # row for never-written owners — its lazy-init contract)
        src = np.zeros((self.n_hot,), np.int32)
        fresh_pos: List[int] = []
        fresh_ids: List[int] = []
        survivors = resident - set(victims)
        for pos, o in enumerate(new_ids.tolist()):
            if o != self._sentinel and o in survivors:
                src[pos] = slot_of[o]
            else:
                # needed ids load from cold; sentinel slots take the
                # store default row so freed slots never keep stale bits
                fresh_pos.append(pos)
                fresh_ids.append(o)
        fresh: Dict[str, np.ndarray] = {}
        for key, store in self.stores.items():
            rows = np.stack([
                store._default if o == self._sentinel
                else store.read_rows([o])[0]
                for o in fresh_ids]) if fresh_ids else np.zeros(
                (0,) + store.row_shape, store._default.dtype)
            fresh[key] = rows
        self.stats["loads"] += sum(1 for o in fresh_ids
                                   if o != self._sentinel)
        return self._install(state, new_ids, src,
                             np.asarray(fresh_pos, np.int32), fresh)

    def _evict(self, state: AsyncDPState, victims: List[int],
               slot_of: Dict[int, int]) -> None:
        """Write back the victims' device rows (dirty ones) to cold."""
        self.stats["evictions"] += len(victims)
        dirty_victims = [v for v in victims if v in self.dirty]
        if dirty_victims:
            slots = np.asarray([slot_of[v] for v in dirty_victims],
                               np.int64)
            data = self._read_slots(state, slots)
            for key, store in self.stores.items():
                store.write_rows(dirty_victims, data[key])
            self.stats["writebacks"] += len(dirty_victims)
            self.dirty.difference_update(dirty_victims)

    def flush(self, state: AsyncDPState, only_dirty: bool = True) -> None:
        """Write resident rows back to the cold tier WITHOUT evicting
        (session checkpoint/shutdown path). `only_dirty=False` forces
        every resident row out (snapshot support)."""
        slot_of = self._slot_of()
        ids = [o for o in (int(i) for i in self.resident_ids)
               if not only_dirty or o in self.dirty]
        if not ids:
            return
        slots = np.asarray([slot_of[o] for o in ids], np.int64)
        data = self._read_slots(state, slots)
        for key, store in self.stores.items():
            store.write_rows(ids, data[key])
        self.stats["writebacks"] += len(ids)
        self.dirty.difference_update(ids)

    def adopt(self, state: AsyncDPState) -> None:
        """Re-sync the host mirrors to a RESTORED state (crash-resume).

        The restored device page table is authoritative: the checkpoint
        was saved through ``flush(only_dirty=False)``, so every resident
        row's bits already live in the (restored) cold tier — nothing is
        dirty — and the LRU stamps restart, so the next prefetch evicts
        by post-restore recency only."""
        self._hot_ids = np.array(jax.device_get(state.bank.hot_ids),
                                 np.int32)
        self.dirty = set()
        self._clock = 0
        self._last_used = {int(o): 0 for o in self._hot_ids
                           if o != self._sentinel}

    def snapshot(self, state: AsyncDPState) -> Dict[str, np.ndarray]:
        """Full (N, ...) host materialization of every paged column —
        testing/inspection only (this is exactly the O(N*P) cost paging
        exists to avoid). Flushes resident rows first so the cold tier
        is authoritative."""
        self.flush(state, only_dirty=False)
        all_ids = np.arange(self.n_owners, dtype=np.int64)
        return {key: store.read_rows(all_ids)
                for key, store in self.stores.items()}


def init_paged_state(params, cfg: AsyncDPConfig, n_hot: int,
                     bank_dtype=None, mesh=None,
                     cold_dir: Optional[str] = None
                     ) -> Tuple[AsyncDPState, OwnerPager]:
    """Flat-engine state with a PAGED owner bank + its host pager.

    Exactly ``init_state_flat`` except the (N, P) bank (and the tree's
    (N, d, P) node matrix) become an (n_hot, ...) hot tier backed by a
    cold row store — device-resident bytes are O(n_hot * row),
    independent of N. `bank_dtype` selects the same storage codecs as
    the flat bank (None/f32, "bfloat16", "int8"/"fp8"). `cold_dir`
    (None = in-memory dict store) puts the cold tier on disk via
    ``MemmapRowStore`` — lazily allocated, so a million-owner store
    costs no real disk until rows are evicted. `mesh` lays the hot tier
    out under ``sharding.rules.paged_shardings`` (hot rows shard like
    bank rows with n_hot standing in for N).

    At init every row — hot, cold, and never-materialized — equals the
    default row (the packed central params, encoded per the storage
    codec), which is what lets the fault layer tile one checksum across
    the (N,) column instead of materializing the bank.
    """
    n_hot = int(n_hot)
    if n_hot < 1:
        raise ValueError(f"n_hot must be >= 1, got {n_hot}")
    if cfg.init_bank_zero:
        params = jax.tree_util.tree_map(jnp.zeros_like, params)
    flat = pack_params(params)
    N = cfg.n_owners
    ledger = make_device_ledger(cfg.effective_caps)
    codec = as_bank_codec(bank_dtype)
    sh = None
    if mesh is not None:
        from repro.sharding.rules import paged_shardings
        sh = paged_shardings(mesh, n_hot, flat.size)
        flat = ParamFlat(jax.device_put(flat.buf, sh.theta), flat.spec)
    hot = init_flat_bank(
        flat, n_hot, bank_dtype,
        sharding=None if sh is None else sh.bank,
        scales_sharding=None if sh is None else sh.bank_scales,
        residual_sharding=None if sh is None else sh.row)
    m = min(n_hot, N)
    ids = np.full((n_hot,), N, np.int32)    # sentinel N sorts last
    ids[:m] = np.arange(m, dtype=np.int32)
    hot_ids = jnp.asarray(ids)
    if sh is not None:
        hot_ids = jax.device_put(hot_ids, sh.ledger)
        ledger = jax.device_put(ledger, sh.ledger)
    bank = PagedBank(hot, hot_ids, N)

    tree = None
    if cfg.tree_depth is not None:
        d = cfg.tree_depth
        nodes = jnp.zeros((n_hot, d, flat.size), jnp.float32)
        counts = jnp.zeros((N,), jnp.int32)
        if sh is not None:
            nodes = jax.device_put(nodes, sh.tree_nodes)
            counts = jax.device_put(counts, sh.ledger)
        tree = TreeNoise(nodes, counts, d)

    faults = (None if cfg.fault_policy is None
              else init_fault_state(bank, N))
    if faults is not None and sh is not None:
        faults = jax.device_put(faults, sh.faults)
    # async-runtime counters are (N,)-scalar columns: like the ledger and
    # the fault windows they stay RESIDENT — paging moves rows, never the
    # accounting (clock/ages/backoff replicate under a mesh)
    stale = _init_staleness(cfg)
    if stale is not None and sh is not None:
        stale = jax.device_put(stale, sh.ledger)

    # cold tier: one store per paged buffer, default = the init row
    def make_store(name, row_shape, dtype, default):
        if cold_dir is None:
            return MemoryRowStore(N, row_shape, dtype, default)
        return MemmapRowStore(os.path.join(cold_dir, name), N, row_shape,
                              dtype, default)

    stores: Dict[str, Any] = {}
    if isinstance(hot, QuantBank):
        codes0 = _as_host(hot.codes[0])
        scales0 = _as_host(hot.scales[0])
        stores["codes"] = make_store("codes", codes0.shape, codes0.dtype,
                                     codes0)
        stores["scales"] = make_store("scales", scales0.shape,
                                      scales0.dtype, scales0)
    else:
        row0 = _as_host(hot[0])
        stores["rows"] = make_store("rows", row0.shape, row0.dtype, row0)
    if tree is not None and cfg.tree_depth:
        zrow = np.zeros((cfg.tree_depth, flat.size), np.float32)
        stores["tree"] = make_store("tree", zrow.shape, zrow.dtype, zrow)

    state = AsyncDPState(flat, bank, jnp.zeros((), jnp.int32), ledger,
                         tree, faults, stale)
    pager = OwnerPager(N, n_hot, ids, stores)
    return state, pager


__all__ = ["OwnerPager", "init_paged_state"]
