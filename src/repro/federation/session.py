"""Federation: the one session surface over every path in the repo.

A Federation is the paper's central object — a learner interacting
one-on-one with N private DataOwners under per-owner budgets and Theorem-1
noise. One construction serves every workload:

    fed = Federation(owners, FederationConfig(horizon=1000, sigma=2e-5))

    # convex (LinearProblem, lax.scan fast path; Figs. 2/6/8)
    trace = fed.run(key, prob)                  # ledgered single session
    traces = fed.run(key, prob, n_runs=100)     # vmapped percentile stats

    # deep models (jitted bank-sharded path)
    step = fed.make_step(loss_fn)
    state = fed.init_state(params)
    state, metrics = fed.step(state, batch, owner_idx, key)

    # fused driver: K rounds per dispatch, accounting on-device
    state, metrics = fed.run_rounds(state, batches, owner_seq, key)
    fed.reconcile(state)                        # fold device ledger -> host

    fed.ledger()                                # per-owner spend + refusals

The Mechanism (noise calibration + internal PrivacyAccountant) and the
Schedule (who communicates when) are pluggable; budget-exhausted owners are
refused AT THIS LAYER — a refused round is a no-op for model state and is
reported in the ledger, so accounting can never drift from the noise that
was actually emitted. The fused `run_rounds` driver makes the same
refusal decision on-device (DeviceLedger masking inside the scan) and
`reconcile()` folds it back into the host accountant bit-exactly. The
synchronous all-owners-per-round DP baseline is the same surface with
strategy="sync".
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.federation.config import FederationConfig
from repro.federation.convex import (Algo1Trace, SyncTrace, scan_engine,
                                     stack_gram, sync_scan_engine)
from repro.federation.deep import (AsyncDPConfig, AsyncDPState, init_state,
                                   init_state_flat, make_fused_rounds,
                                   make_group_rounds, make_sync_dp_step,
                                   make_train_step)
from repro.federation.dp_sgd import PrivatizerConfig
from repro.federation.faults import (DROP, OK, FaultPlan, FaultPolicy,
                                     as_fault_codes, fault_tick)
from repro.federation.flatten import ParamFlat
from repro.federation.linear import LinearProblem
from repro.federation.mechanisms import Mechanism, make_mechanism
from repro.federation.owners import DataOwner
from repro.federation.schedules import (ScheduleProtocol, TraceRing,
                                        UniformSchedule, as_owner_seq,
                                        auto_max_group, pack_groups,
                                        partition_conflict_free)
from repro.federation.staleness import (LatencyPlan, StalenessPolicy,
                                        as_tick_times, merge_timeout_codes,
                                        staleness_tick)

_STRATEGIES = ("async", "sync")


class Federation:
    def __init__(self, owners: Sequence[DataOwner], config: FederationConfig,
                 *, mechanism: Union[str, Mechanism] = "paper",
                 schedule: Optional[ScheduleProtocol] = None,
                 strategy: str = "async",
                 cap_slack: Optional[float] = None,
                 tree_depth: Optional[int] = None,
                 fault_policy: Optional[FaultPolicy] = None,
                 staleness: Optional[StalenessPolicy] = None):
        if strategy not in _STRATEGIES:
            raise ValueError(f"strategy must be one of {_STRATEGIES}")
        self.owners = list(owners)
        self.config = config
        self.schedule = schedule if schedule is not None else UniformSchedule()
        self.strategy = strategy
        # fault_policy arms the in-graph fault layer (deep path only):
        # states grow FaultState counters, drivers accept fault codes, and
        # owners exceeding the policy's fault budget are quarantined.
        # None keeps every driver tracing the fault-free program verbatim.
        self.fault_policy = fault_policy
        # staleness arms the async-runtime layer (deadlines -> TIMEOUT,
        # retry backoff, decayed inertia). It rides on the fault algebra,
        # so a staleness-only federation auto-arms a never-quarantine
        # fault policy — faults become expressible but nothing changes
        # until codes are actually injected.
        self.staleness = staleness
        if staleness is not None and fault_policy is None:
            self.fault_policy = FaultPolicy(max_faults=2**30, window=2**30)
        self.mechanism = make_mechanism(mechanism, self.owners, config,
                                        cap_slack=cap_slack,
                                        tree_depth=tree_depth)
        self._step_fn = None
        self._fused_fn = None
        self._group_fn = None
        self._tick_fn = None
        self._stale_tick_fn = None
        self._pack_params = False
        self._bank_dtype = None
        self._mesh = None
        self._pager = None
        self._ran = False

    def _claim_session(self):
        # The jitted engines start from fresh per-owner counters, so a
        # second ledgered run would emit responses the cumulative ledger
        # refuses — budget spend and accounting would silently drift apart.
        if self._ran:
            raise RuntimeError(
                "this Federation already ran its ledgered session; use "
                "n_runs for statistical replicas or build a new Federation "
                "to renegotiate budgets")
        self._ran = True

    @property
    def n_owners(self) -> int:
        return len(self.owners)

    def ledger(self) -> Dict[int, Dict]:
        return self.mechanism.ledger()

    def _authorize_many(self, owner_idx: int, count: int) -> int:
        bulk = getattr(self.mechanism, "authorize_many", None)
        if bulk is not None:
            return bulk(owner_idx, count)
        return sum(self.mechanism.authorize(owner_idx)
                   for _ in range(count))

    def _reject_tree(self, engine: str):
        # The convex/sync scan engines draw INDEPENDENT per-round noise in
        # one fused trace; they carry no noise-tree state, so running them
        # under a tree mechanism would silently emit the wrong mechanism.
        if getattr(self.mechanism, "tree_depth", None) is not None:
            raise ValueError(
                f"{engine} draws independent per-round noise; the tree "
                f"mechanism needs the deep path (make_step/run_rounds)")

    # ------------------------- convex fast path ---------------------------
    def _gram(self):
        if any(o.gram is None for o in self.owners):
            raise ValueError("convex path needs Gram payloads on every "
                             "owner (DataOwner.from_arrays/from_gram)")
        return stack_gram([o.gram for o in self.owners])

    def run(self, key, problem: LinearProblem,
            n_runs: Optional[int] = None) -> Algo1Trace:
        """Run the asynchronous session on a LinearProblem.

        n_runs=None runs ONE ledgered session (every response — and
        refusal — lands in .ledger()). n_runs=k vmaps k statistical
        replicas for percentile figures; replicas model hypothetical
        re-runs, so they are NOT ledgered.
        """
        if self.strategy != "async":
            raise ValueError("run() is the async path; use run_sync()")
        self._reject_tree("the convex scan engine")
        A, b, n_i = self._gram()
        scales = self.mechanism.scales(p=problem.G.shape[0])
        cfg = self.config

        def run_one(k):
            return scan_engine(k, problem, A, b, n_i, scales,
                               horizon=cfg.horizon, rho=cfg.rho,
                               sigma=cfg.sigma, lr_scale=cfg.lr_scale,
                               draw=self.schedule.draw,
                               cap=self.mechanism.cap)

        if n_runs is None:
            self._claim_session()
            trace = run_one(key)
            counts = np.bincount(np.asarray(trace.owners_seq),
                                 minlength=self.n_owners)
            for i, c in enumerate(counts):
                self._authorize_many(i, int(c))
            return trace
        return jax.vmap(run_one)(jax.random.split(key, n_runs))

    def run_sync(self, key, problem: LinearProblem,
                 lr: float, n_runs: Optional[int] = None) -> SyncTrace:
        """The synchronous all-owners-per-round baseline on the same
        surface (strategy='sync' federations only)."""
        if self.strategy != "sync":
            raise ValueError("run_sync() needs strategy='sync'")
        self._reject_tree("the synchronous scan engine")
        if self.mechanism.cap is not None:
            raise ValueError(
                "per_owner_rounds is an asynchronous composition: the sync "
                "engine queries every owner all T rounds, so a capped noise "
                "scale would violate the owners' budgets; use 'paper' or "
                "'strict'")
        A, b, n_i = self._gram()
        scales = self.mechanism.scales(p=problem.G.shape[0])
        cfg = self.config

        def run_one(k):
            return sync_scan_engine(k, problem, A, b, n_i, scales,
                                    horizon=cfg.horizon, lr=lr)

        if n_runs is None:
            self._claim_session()
            trace = run_one(key)
            for i in range(self.n_owners):
                self._authorize_many(i, cfg.horizon)
            return trace
        return jax.vmap(run_one)(jax.random.split(key, n_runs))

    # -------------------------- deep-model path ---------------------------
    def as_async_config(self, privatizer: Optional[PrivatizerConfig] = None
                        ) -> AsyncDPConfig:
        """The low-level engine config this session implies."""
        xi = max(o.xi for o in self.owners)
        cfg = self.config
        cap = self.mechanism.cap
        return AsyncDPConfig(
            n_owners=self.n_owners, horizon=cfg.horizon, rho=cfg.rho,
            sigma=cfg.sigma,
            epsilons=tuple(o.epsilon for o in self.owners),
            owner_sizes=tuple(o.n for o in self.owners),
            xi=xi, theta_max=cfg.theta_max,
            privatizer=privatizer or PrivatizerConfig(xi=xi),
            lr_scale=cfg.lr_scale,
            caps=None if cap is None else (cap,) * self.n_owners,
            tree_depth=getattr(self.mechanism, "tree_depth", None),
            fault_policy=self.fault_policy,
            staleness=self.staleness)

    def init_state(self, params, pack_params: Optional[bool] = None,
                   bank_dtype=None, mesh=None) -> AsyncDPState:
        """Build the deep-path training state. `pack_params=None` follows
        the flag given to make_step (default tree); True packs the model
        into the flat-buffer representation (ParamFlat theta_L + one
        (N, P) bank matrix) that the flat round engine runs on.
        `bank_dtype` (flat states only, None follows make_step) narrows
        the bank storage — bf16 halves the dominant state memory and the
        fused scan's carry traffic at the cost of quantized owner copies,
        and the strings "int8"/"fp8" build the error-feedback quantized
        bank (~4x below f32; see flatten.QuantBank). f32 keeps the
        bit-parity contract. `mesh` (flat states only,
        None follows make_step) lays the buffers out across the device
        mesh under repro.sharding.rules.flat_shardings — bank rows over
        the data axes, P like the model."""
        pack = self._pack_params if pack_params is None else pack_params
        if pack:
            if bank_dtype is None:
                bank_dtype = self._bank_dtype
            if mesh is None:
                mesh = self._mesh
            state = init_state_flat(params, self.as_async_config(),
                                    bank_dtype=bank_dtype, mesh=mesh)
        else:
            # the make_step-configured bank dtype/mesh are simply
            # irrelevant to a tree state; only an EXPLICIT request here
            # is an error
            if bank_dtype is not None:
                raise ValueError("bank_dtype is a flat-engine option; "
                                 "pass pack_params=True")
            if mesh is not None:
                raise ValueError("mesh sharding is a flat-engine option; "
                                 "pass pack_params=True")
            state = init_state(params, self.as_async_config())
        snapshot = getattr(self.mechanism, "device_ledger", None)
        if snapshot is not None:
            # In-graph authorization must refuse exactly where the host
            # would: seed the device counters from the live accountant.
            ledger = snapshot()
            if mesh is not None:
                ledger = jax.device_put(
                    ledger, jax.sharding.NamedSharding(
                        mesh, jax.sharding.PartitionSpec()))
            state = state._replace(ledger=ledger)
        return state

    def init_paged_state(self, params, n_hot: int, bank_dtype=None,
                         mesh=None, cold_dir=None) -> AsyncDPState:
        """Flat-engine state whose owner bank is PAGED: an n_hot-row
        device-resident working set over a host cold tier, so resident
        bytes are O(n_hot * P) independent of N (see
        federation.paging). The pager is attached to this session —
        `step()` and `run_rounds()` prefetch the rows each dispatch
        touches automatically, and every driver resolves owner -> hot
        slot in-graph (no host sync inside the scan). With n_hot >=
        n_owners the paged engine reproduces the flat engine
        bit-for-bit. Requires a flat make_step (pack_params=True).
        `cold_dir` puts the cold tier on disk (lazy memmap); None keeps
        it in host memory."""
        if not self._pack_params:
            raise ValueError("the paged bank is a flat-engine option; "
                             "call make_step(..., pack_params=True) first")
        if bank_dtype is None:
            bank_dtype = self._bank_dtype
        if mesh is None:
            mesh = self._mesh
        from repro.federation.paging import init_paged_state
        state, pager = init_paged_state(params, self.as_async_config(),
                                        n_hot, bank_dtype=bank_dtype,
                                        mesh=mesh, cold_dir=cold_dir)
        self._pager = pager
        snapshot = getattr(self.mechanism, "device_ledger", None)
        if snapshot is not None:
            ledger = snapshot()
            if mesh is not None:
                ledger = jax.device_put(
                    ledger, jax.sharding.NamedSharding(
                        mesh, jax.sharding.PartitionSpec()))
            state = state._replace(ledger=ledger)
        return state

    @property
    def pager(self):
        """The OwnerPager attached by init_paged_state (None for
        non-paged sessions) — exposes resident_ids, stats, flush()."""
        return self._pager

    def params_of(self, state: AsyncDPState):
        """The central model as a pytree, whichever representation the
        state carries (flat buffers are unpacked)."""
        theta = state.theta_L
        return theta.unpack() if isinstance(theta, ParamFlat) else theta

    def make_step(self, loss_fn, *,
                  privatizer: Optional[PrivatizerConfig] = None,
                  lr: Optional[float] = None, n_params: Optional[int] = None,
                  jit: bool = True, donate: bool = False,
                  pack_params: bool = False, bank_dtype=None, mesh=None,
                  unroll: int = 1):
        """Build (and cache for .step()) the jitted per-round function.

        async: step(state, batch, owner_idx, key) -> (state, metrics)
        sync:  step(params, batches, key[, weights]) -> params  (needs lr)
        n_params feeds dimension-aware mechanisms (e.g. 'strict').

        pack_params=True opts `init_state` into the flat-buffer engine
        (the model packed into one contiguous (P,) f32 buffer, the bank a
        single (N, P) matrix). The built step functions serve BOTH
        representations — they dispatch on the state — so this flag only
        selects what `init_state` constructs. Default off: the pytree
        path stays the reference.

        `bank_dtype` narrows the bank storage (see init_state): a real
        dtype (bf16) stores quantized rows densely; the strings
        "int8"/"fp8" (or a flatten.BankCodec) build the error-feedback
        QUANTIZED bank — ~4x below f32 resident bytes and scan-carry
        traffic. `donate=True` donates the state through the dispatch
        boundary (the K-round scan then reuses the bank's buffers instead
        of allocating a second copy — pair it with the quantized bank for
        the full in-place carry win; the passed-in state is consumed).
        `unroll` (async only) unrolls the fused scan body by that factor —
        identical results, fewer loop-carry copies per round on XLA:CPU
        (measured +24% at unroll=4, MLP scale).

        `mesh` (flat engine only) makes the whole round engine
        sharding-native: `init_state` places theta_L/bank under the
        repro.sharding.rules.flat_shardings layout and every driver pins
        that layout inside its scan body with with_sharding_constraint,
        so K rounds run distributed with no host transfer of the bank.
        A 1x1 mesh reproduces the unsharded engine bit-for-bit.

        Deep-path sensitivity is the privatizer's ENFORCED clip norm, not
        each owner's nominal Xi_i — clipping to a norm above an owner's
        bound would otherwise under-noise that owner.
        """
        if mesh is not None and not pack_params:
            raise ValueError("mesh sharding is a flat-engine option; "
                             "pass pack_params=True")
        self._pack_params = pack_params
        self._bank_dtype = bank_dtype
        self._mesh = mesh
        if self.fault_policy is not None and self.strategy == "async":
            # Host-protocol rounds that never enter the step graph
            # (drops, refusals) still advance the fault window exactly as
            # the fused driver's in-graph tick would.
            pol = self.fault_policy
            self._tick_fn = jax.jit(
                lambda fs, i, f: fault_tick(fs, jnp.int32(i), jnp.bool_(f),
                                            pol, active=jnp.bool_(True)))
        if self.staleness is not None and self.strategy == "async":
            # Host-masked rounds (quarantine, retry, drop, refusal) must
            # advance the staleness clock exactly as the fused driver's
            # in-graph tick does: same scatter, flags all-False except
            # is_retry, so ages stay driver-order-free.
            spol = self.staleness
            self._stale_tick_fn = jax.jit(
                lambda ss, i, r: staleness_tick(
                    ss, jnp.int32(i), ss.clock, is_retry=jnp.bool_(r),
                    apply=jnp.bool_(False), timed=jnp.bool_(False),
                    policy=spol, active=jnp.bool_(True), ticks=1))
        acfg = self.as_async_config(privatizer)
        scales = self.mechanism.scales(p=n_params,
                                       clip_norm=acfg.privatizer.xi)
        donate_args = (0,) if donate else ()
        if self.strategy == "sync":
            if lr is None:
                raise ValueError("sync strategy needs an explicit lr")
            step = make_sync_dp_step(loss_fn, acfg, lr, scales=scales)
        else:
            step = make_train_step(loss_fn, acfg, scales=scales, mesh=mesh)
            fused = make_fused_rounds(loss_fn, acfg, scales=scales,
                                      mesh=mesh, unroll=unroll)
            group = make_group_rounds(loss_fn, acfg, scales=scales,
                                      mesh=mesh)
            self._fused_fn = (jax.jit(fused, donate_argnums=donate_args)
                              if jit else fused)
            self._group_fn = (jax.jit(group, donate_argnums=donate_args)
                              if jit else group)
        if jit:
            step = jax.jit(step, donate_argnums=donate_args)
        self._step_fn = step
        return step

    def _require_step(self):
        if self._step_fn is None:
            raise RuntimeError("call make_step(loss_fn) before step()")
        return self._step_fn

    def step(self, state: AsyncDPState, batch, owner_idx, key,
             fault_code: Optional[int] = None
             ) -> Tuple[AsyncDPState, Dict[str, Any]]:
        """One ledgered asynchronous round. A budget-exhausted owner is
        refused: model state (central AND bank) is returned untouched and
        the refusal is recorded in the ledger.

        With a fault-armed federation (fault_policy set), `fault_code`
        injects one of faults.OK/DROP/STALE/NONFINITE_GRAD/
        CORRUPT_PAYLOAD/TIMEOUT into the round. The host mirrors the
        fused driver's outcome order exactly: quarantined owners are
        masked before anything else (no epsilon, no refusal, no window
        tick); with a staleness-armed federation an owner in backoff is
        masked next (a retried round — the learner never sends the
        query, so no epsilon and no fault-window contact); a DROP on an
        exhausted owner is a refusal (the budget check precedes the
        contact); a plain DROP costs no epsilon; every answered round is
        charged at response time even if the in-graph guards then reject
        it (metrics['faulted']) or the deadline already passed
        (metrics['timed_out'])."""
        if self.strategy != "async":
            raise ValueError("step() is the async path; use sync_round()")
        step_fn = self._require_step()
        i = int(owner_idx)
        if self._pager is not None:
            # make this round's row resident before dispatch; refusal/
            # quarantine paths tolerate the (bit-exact) extra residency
            state = self._pager.prefetch(state, np.asarray([i]))
        if state.faults is None:
            if fault_code is not None:
                raise ValueError(
                    "fault injection needs a fault-armed state; build the "
                    "Federation with fault_policy=FaultPolicy(...)")
            if not self.mechanism.authorize(i):
                return state, {"refused": True, "owner": i}
            new_state, metrics = step_fn(state, batch, jnp.int32(i), key)
            metrics = dict(metrics)
            metrics.update(refused=False, owner=i)
            return new_state, metrics

        fc = OK if fault_code is None else int(fault_code)
        flags = {"refused": False, "dropped": False, "faulted": False,
                 "quarantined": False, "timed_out": False, "owner": i}
        stale_armed = (state.stale is not None
                       and self._stale_tick_fn is not None)
        if stale_armed:
            flags["retried"] = False

        def ticked(st, retry=False):
            # host-masked rounds still advance the staleness clock —
            # same scatter as the fused in-graph tick, so ages stay
            # driver-order-free
            if not stale_armed:
                return st
            return st._replace(
                stale=self._stale_tick_fn(st.stale, i, retry))

        if bool(state.faults.quarantined[i]):
            # masked before any budget decision; the fused tick is also
            # inactive for quarantined owners, so no window advance
            self.mechanism.record_quarantined(i)
            return ticked(state), dict(flags, quarantined=True)
        if stale_armed and int(state.stale.cooldown[i]) > 0:
            # in backoff: a masked re-dispatch. The learner never sends
            # the query — no epsilon, no budget decision, and no fault-
            # window contact — and one cooldown round burns.
            self.mechanism.record_retried(i)
            return ticked(state, retry=True), dict(flags, retried=True)
        if fc == DROP:
            if self.mechanism.exhausted(i):
                # refusal takes precedence: the budget check happens
                # before the contact could be lost
                self.mechanism.authorize(i)      # records the refusal
                faults = self._tick_fn(state.faults, i, False)
                return (ticked(state._replace(faults=faults)),
                        dict(flags, refused=True))
            self.mechanism.record_dropped(i)     # no answer -> no epsilon
            faults = self._tick_fn(state.faults, i, True)
            return (ticked(state._replace(faults=faults)),
                    dict(flags, dropped=True))
        if not self.mechanism.authorize(i):
            faults = self._tick_fn(state.faults, i, False)
            return (ticked(state._replace(faults=faults)),
                    dict(flags, refused=True))
        new_state, metrics = step_fn(state, batch, jnp.int32(i), key,
                                     jnp.int8(fc))
        metrics = dict(metrics)
        if bool(metrics["faulted"]):
            self.mechanism.record_faulted(i)     # epsilon already charged
        timed = bool(metrics.get("timed_out", False))
        if timed:
            self.mechanism.record_timed_out(i)   # answered late: epsilon
        metrics.update(flags, faulted=bool(metrics["faulted"]),
                       timed_out=timed)
        return new_state, metrics

    def run_rounds(self, state: AsyncDPState, batches, owner_seq=None,
                   key=None, *, faults=None, latency=None, times=None,
                   owner_parallel: bool = False,
                   max_group: Union[int, str, None] = "auto"
                   ) -> Tuple[AsyncDPState, Dict[str, Any]]:
        """K asynchronous rounds in ONE dispatch (lax.scan over the jitted
        deep step, authorization decided on-device).

        `batches` leaves carry a leading (K,) round axis (round k consumes
        owner i_k's microbatch). `owner_seq` is a (K,) int32 device
        sequence; None draws it from the pluggable Schedule; a
        `schedules.TraceRing` streams a long availability trace in
        chunks — the call consumes the next K entries without ever
        materializing the full trace on device. Per-round keys
        are `jax.random.split(key, K)` — drive a per-round `step()` loop
        with the same split and it reproduces this call bit-for-bit
        (params, bank, and granted-round metrics).

        Host-sync contract: one dispatch costs AT MOST one device->host
        copy of the (K,) owner sequence, shared by every host-side
        consumer (the paged-bank prefetch, `auto_max_group`, and the
        conflict-free partition); with none of those enabled a
        schedule-drawn sequence never leaves the device.

        Budget-exhausted owners are refused IN-GRAPH via the state's
        DeviceLedger: a refused round is a no-op on model state exactly as
        in `step()`. Refusals accumulate on-device; call `reconcile(state)`
        afterwards to fold them into `ledger()` — until then the host
        accountant lags the device by the rounds of this call.

        `owner_parallel=True` batches non-conflicting rounds: the schedule
        is partitioned host-side into maximal groups of consecutive rounds
        with DISTINCT owners (`schedules.partition_conflict_free`;
        `max_group` caps group size) and the grouped driver runs
        group-at-a-time, vmapping the round over each group's members with
        one theta_L inertia reduction per group. `max_group="auto"` (the
        default) picks the cap per dispatch from the sequence's own
        owner-repeat statistics (`schedules.auto_max_group`: padding waste
        vs per-step bank-carry overhead; caps come from a fixed ladder,
        so re-tuning every dispatch cannot churn the jit cache beyond the
        ladder size); None means unbounded maximal groups; an int is a
        hard cap. Ledger spend (and therefore the
        privacy accounting) is exactly the sequential scan's; theta_L
        trajectories deviate boundedly for groups larger than one (see
        `make_group_rounds`). When every group has size 1 the sequential
        scan runs — bit-for-bit identical output.

        metrics are stacked (K,) round-order arrays either way (refused
        mask, owner, clip_frac, max_grad_norm, grad_noise_scale).

        `faults` (fault-armed federations only) injects per-round faults
        in-graph: a `FaultPlan` draws one int8 code per round
        deterministically from this call's key (domain-separated from the
        round keys, so the same key reproduces the same faults on every
        driver), or pass a (K,) code array to replay a recorded trace.
        Fault outcomes land in the device ledger's dropped/faulted/
        quarantined columns and fold back on `reconcile(state)`.

        `latency` (staleness-armed federations only) models response
        TIME: a `staleness.LatencyPlan` draws one latency per round from
        this call's key (STALE_SALT stream — disjoint from the round
        keys and fault codes, so every driver sees the same runtime), or
        pass a (K,) array to replay recorded latencies. Rounds later
        than the policy deadline upgrade to TIMEOUT in the fault-code
        trace (`staleness.merge_timeout_codes`) — answered-late, epsilon
        spent, update masked — and land in the ledger's timed_out
        column. `times` supplies per-round arrival instants (e.g.
        `Schedule.draw_with_times(...).times`) that tighten each round's
        effective deadline to the gap before the next tick; with
        latency armed, owner_seq=None, and a schedule that exposes
        `draw_with_times`, the times are drawn alongside the owner
        sequence automatically.
        """
        if self.strategy != "async":
            raise ValueError("run_rounds() is the async path")
        if key is None:
            raise ValueError("run_rounds needs an explicit PRNG key")
        self._require_step()
        if self._fused_fn is None:
            raise RuntimeError("call make_step(loss_fn) before run_rounds()")
        # Host-sync contract: everything below shares ONE host copy of
        # the owner sequence (`host_seq`), materialized lazily and at
        # most once per call. The pager's prefetch, auto_max_group and
        # partition_conflict_free all read it; the schedule-drawn path
        # with none of those enabled never syncs at all.
        seq_host = None
        user_times = times is not None

        def host_seq() -> np.ndarray:
            nonlocal seq_host
            if seq_host is None:
                seq_host = np.asarray(owner_seq)
            return seq_host

        if isinstance(owner_seq, TraceRing):
            # streamed availability trace: peek the window host-side for
            # the pager, then advance the ring — the device sequence is
            # a chunk-buffer slice, never the materialized (K,) trace
            ring = owner_seq
            k = jax.tree_util.tree_leaves(batches)[0].shape[0]
            seq_host = np.asarray(ring.window(k), np.int32)
            if seq_host.size and (seq_host.min() < 0
                                  or seq_host.max() >= self.n_owners):
                raise ValueError("trace names owners outside this "
                                 f"federation (n_owners={self.n_owners})")
            owner_seq = ring.next(k).astype(jnp.int32)
        elif owner_seq is None:
            # schedule-drawn: in-range by construction, stays on-device
            # (as_owner_seq's bounds check would force a host sync here)
            k_sched, key = jax.random.split(key)
            k = jax.tree_util.tree_leaves(batches)[0].shape[0]
            draw_wt = getattr(self.schedule, "draw_with_times", None)
            if latency is not None and times is None and draw_wt is not None:
                # the schedule's own wall clock feeds the deadline model:
                # arrival gaps tighten per-round deadlines (times are
                # non-decreasing by construction, no host check needed)
                sched = draw_wt(k_sched, self.n_owners, k)
                times = sched.times
                owner_seq = sched.owners.astype(jnp.int32)
            else:
                owner_seq = self.schedule.draw(k_sched, self.n_owners,
                                               k).astype(jnp.int32)
        else:
            owner_seq = as_owner_seq(owner_seq, self.n_owners)
        k_rounds = owner_seq.shape[0]
        if user_times:
            # hand-rolled times validate like hand-rolled sequences
            # (schedule-drawn times are in-contract by construction)
            times = as_tick_times(times, k_rounds)
        if self._pager is not None:
            # page in every row this dispatch touches (evicting stale
            # rows to the cold tier) before the scan launches
            state = self._pager.prefetch(state, host_seq())
        fault_codes = None
        if faults is not None:
            if state.faults is None:
                raise ValueError(
                    "fault injection needs a fault-armed state; build the "
                    "Federation with fault_policy=FaultPolicy(...)")
            if isinstance(faults, FaultPlan):
                # drawn from THIS key (salted fold-in keeps the stream
                # disjoint from the per-round keys split below), so fixed
                # key -> identical faults on every driver
                fault_codes = faults.draw(key, k_rounds)
            else:
                fault_codes = as_fault_codes(faults, k_rounds)
        if latency is not None:
            if self.staleness is None:
                raise ValueError(
                    "latency modeling needs a staleness-armed Federation; "
                    "pass staleness=StalenessPolicy(...) at construction")
            if state.faults is None:
                raise ValueError(
                    "latency injection needs a fault-armed state (TIMEOUT "
                    "is a fault code); rebuild the state from this "
                    "staleness-armed federation")
            # drawn from THIS key (STALE_SALT fold-in keeps the latency
            # stream disjoint from the fault codes and the round keys),
            # so fixed key -> identical timeouts on every driver
            lat = (latency.draw(key, owner_seq)  # dpcheck: ignore[DPC105]
                   if isinstance(latency, LatencyPlan)
                   else jnp.asarray(latency, jnp.float32))
            if fault_codes is None:
                fault_codes = jnp.full((k_rounds,), OK, jnp.int8)
            fault_codes = merge_timeout_codes(
                fault_codes, lat, self.staleness.deadline, times=times)
        # same key as FaultPlan.draw by contract: draw folds in
        # FAULT_SALT, so the fault stream never touches the round keys
        keys = jax.random.split(key, k_rounds)  # dpcheck: ignore[DPC105]
        if not owner_parallel:
            if fault_codes is None:
                return self._fused_fn(state, batches, owner_seq, keys)
            return self._fused_fn(state, batches, owner_seq, keys,
                                  fault_codes)

        # schedule analysis is a host-side pass over the shared host
        # copy: at most one device->host sync per dispatch, not one per
        # consumer (previously auto_max_group and the partition each
        # pulled the full (K,) sequence)
        if max_group == "auto":
            max_group = auto_max_group(host_seq())
        groups = partition_conflict_free(host_seq(), max_group)
        if all(length <= 1 for _, length in groups):
            # every group is a single round: the sequential scan IS the
            # grouped execution, bit-for-bit
            if fault_codes is None:
                return self._fused_fn(state, batches, owner_seq, keys)
            return self._fused_fn(state, batches, owner_seq, keys,
                                  fault_codes)
        idx, valid = pack_groups(groups)
        # Shape-stabilize for the jit cache: schedule-drawn partitions
        # give a different (n_groups, G_max) almost every dispatch, and
        # each new shape would recompile the whole K-round program. Pad
        # the member axis to max_group (its natural cap; next power of
        # two when unbounded) and the group axis to the next multiple of
        # 4. Padded members are masked; padded groups are pure shape
        # padding — the driver's fori_loop stops at the TRACED real group
        # count, so they never execute (and never pay the (N, P) bank
        # loop-carry copy a scanned no-op step used to cost).
        n_g, gmax = idx.shape
        gpad = (max_group if max_group is not None
                else 1 << max(gmax - 1, 0).bit_length())
        rows = -(-n_g // 4) * 4
        idx = np.pad(idx, ((0, rows - n_g), (0, gpad - gmax)))
        valid = np.pad(valid, ((0, rows - n_g), (0, gpad - gmax)))
        if fault_codes is None:
            state, gm = self._group_fn(state, batches, owner_seq, keys,
                                       jnp.asarray(idx), jnp.asarray(valid),
                                       jnp.int32(n_g))
        else:
            state, gm = self._group_fn(state, batches, owner_seq, keys,
                                       jnp.asarray(idx), jnp.asarray(valid),
                                       jnp.int32(n_g), fault_codes)
        # group-major (n_groups, G_max) -> round-order (K,): groups are
        # consecutive and in order, so the valid entries flatten in order
        order = np.flatnonzero(valid.reshape(-1))
        metrics = {name: v.reshape((-1,) + v.shape[2:])[order]
                   for name, v in gm.items()}
        return state, metrics

    def reconcile(self, state: AsyncDPState) -> Dict[int, Dict]:
        """Fold the state's device ledger back into the host accountant
        (bit-exact, drift raises) and return the updated ledger()."""
        if state.ledger is None:
            raise ValueError("state carries no device ledger")
        fold = getattr(self.mechanism, "reconcile", None)
        if fold is None:
            raise NotImplementedError(
                f"mechanism {self.mechanism.name!r} has no reconcile()")
        return fold(state.ledger)

    # --------------------------- crash-resume ------------------------------
    def save_session(self, directory, state: AsyncDPState,
                     step: Optional[int] = None) -> int:
        """Checkpoint the device state AND the host accountant together.

        Atomically writes the full AsyncDPState (params, bank, ledger,
        tree, fault counters, staleness counters) plus the mechanism's
        dispatch journal — everything `reconcile` depends on — so a
        process killed any time after this call resumes via
        `restore_session` with exactly the accounting the crashed
        process had. A PAGED state checkpoints both tiers: resident
        rows are flushed so the cold tier is authoritative, then its
        materialized rows ride in the same atomic npz shard as the hot
        state (never-written rows reconstruct from the default row for
        free). Returns the step the checkpoint was filed under
        (state.step when not given)."""
        from repro.checkpoint import save_checkpoint
        if step is None:
            step = int(state.step)
        extra = {}
        aux = None
        if self._pager is not None:
            # flush first: after this the cold tier holds the exact bits
            # of every resident row, so checkpointing its written rows
            # (plus the hot state above) captures the whole bank
            self._pager.flush(state, only_dirty=False)
            aux = {}
            for name, store in self._pager.stores.items():
                ids = store.written_ids
                aux[f"cold/{name}/ids"] = ids
                aux[f"cold/{name}/rows"] = store.read_rows(ids)
            extra["paging"] = {"stores": sorted(self._pager.stores),
                               "dtypes": {n: str(s.dtype) for n, s
                                          in self._pager.stores.items()},
                               "n_hot": self._pager.n_hot}
        exp = getattr(self.mechanism, "export_journal", None)
        if exp is not None:
            extra["journal"] = exp()
        save_checkpoint(directory, step, state, extra=extra or None,
                        aux_arrays=aux)
        return int(step)

    def restore_session(self, directory, like: AsyncDPState,
                        step: Optional[int] = None) -> AsyncDPState:
        """Restore a save_session checkpoint into THIS federation.

        `like` is a template state (e.g. a fresh `init_state(params)`)
        supplying structure, dtypes, and static metadata. The mechanism's
        journal is replayed first, rewinding the host accountant to the
        saved baselines, and the restored ledger adopts the journaled
        snapshot generation — so `reconcile` after resume folds exactly
        the deltas the crashed process had not yet folded, never
        double-counting epsilon. The federation must be built from the
        same owners/config as the one that saved. Restoring into a
        PAGED session (init_paged_state before this call, so `like` and
        the cold stores exist) wipes the stores and replays the
        checkpoint's cold-tier rows, then re-syncs the pager's host
        mirrors to the restored page table — the paged state resumes
        bit-exactly on every storage codec."""
        from repro.checkpoint import (latest_step, load_aux_arrays,
                                      load_checkpoint, load_manifest)
        if step is None:
            step = latest_step(directory)
            if step is None:
                raise FileNotFoundError(
                    f"no checkpoint under {directory!r}")
        manifest = load_manifest(directory, step)
        paging = (manifest.get("extra") or {}).get("paging")
        if self._pager is None and paging is not None:
            raise ValueError(
                "checkpoint holds a paged bank; call init_paged_state "
                "first so this session has a pager and cold stores to "
                "restore into")
        if self._pager is not None and paging is None:
            raise ValueError(
                "checkpoint carries no cold-tier snapshot (saved from "
                "a non-paged session); restore it into a non-paged "
                "state instead")
        state = load_checkpoint(directory, step, like)
        if self._pager is not None:
            mine = {"stores": sorted(self._pager.stores),
                    "dtypes": {n: str(s.dtype) for n, s
                               in self._pager.stores.items()}}
            theirs = {"stores": paging["stores"],
                      "dtypes": paging.get("dtypes", mine["dtypes"])}
            if mine != theirs:
                raise ValueError(
                    f"checkpoint cold tier has stores {theirs} but this "
                    f"session pages {mine} — codec/tree configuration "
                    "mismatch")
            aux = load_aux_arrays(directory, step)
            for name, store in self._pager.stores.items():
                # wipe first: rows written AFTER the save must read as
                # the default row again, exactly as at save time
                store.clear()
                ids = aux[f"cold/{name}/ids"]
                if ids.size:
                    store.write_rows(ids, aux[f"cold/{name}/rows"])
            self._pager.adopt(state)
        journal = (manifest.get("extra") or {}).get("journal")
        if journal is not None:
            rest = getattr(self.mechanism, "restore_journal", None)
            if rest is None:
                raise NotImplementedError(
                    f"mechanism {self.mechanism.name!r} cannot replay the "
                    "checkpoint's dispatch journal")
            rest(journal)
            if state.ledger is not None:
                # sid is static pytree metadata, so it came from `like`,
                # not the checkpoint — adopt the journaled generation
                state = state._replace(
                    ledger=state.ledger.replace(sid=int(journal["sid"])))
        return state

    def sync_round(self, params, batches, key):
        """One ledgered synchronous round: every live owner contributes;
        exhausted owners are zero-weighted out. A fully-refused round is a
        no-op (the regularizer must not keep shrinking a model nobody is
        training)."""
        if self.strategy != "sync":
            raise ValueError("sync_round() needs strategy='sync'")
        step_fn = self._require_step()
        live = [self.mechanism.authorize(i) for i in range(self.n_owners)]
        if not any(live):
            return params
        return step_fn(params, batches, key,
                       jnp.asarray(live, jnp.float32))
