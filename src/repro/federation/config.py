"""FederationConfig: the session-level hyperparameters of Algorithm 1.

Owner-local quantities (n_i, eps_i, Xi_i) live on DataOwner; mechanism- and
schedule-specific knobs live on those objects. What remains here is exactly
the learner's contract: horizon T, step-size knob rho, strong-convexity
modulus sigma of the regularizer g, the projection radius Theta, and the
recorded-deviation lr_scale for deep models.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple


def paper_rates(n_owners: int, horizon: int, rho: float, sigma: float,
                lr_scale: float = 1.0) -> Tuple[float, float]:
    """The paper's per-round rates (eqs. 5 and 7): (lr_own, lr_L).

    Single home for the formula — the convex and deep engines and
    `FederationConfig.effective_lr`/`from_target_lr` all read it from here
    so they cannot silently diverge."""
    lr_own = lr_scale * n_owners * rho / (horizon ** 2 * sigma)
    lr_L = (lr_scale * (n_owners - 1) * rho
            / (n_owners * horizon ** 2 * sigma))
    return lr_own, lr_L


@dataclasses.dataclass(frozen=True)
class FederationConfig:
    horizon: int                 # T
    rho: float = 1.0             # step-size knob; alpha = rho / T^2
    sigma: float = 1e-4          # strong-convexity modulus of g
    theta_max: float = 100.0     # Theta projection radius (l_inf), deep path
    lr_scale: float = 1.0        # 1.0 == paper-faithful
    noiseless: bool = False      # eps -> inf (for cost-of-privacy deltas)

    @classmethod
    def from_target_lr(cls, target_lr: float, *, n_owners: int, horizon: int,
                       sigma: float, rho: float = 1.0, **kw
                       ) -> "FederationConfig":
        """Solve lr_scale so the effective owner-update rate
        lr_scale * N * rho / (T^2 * sigma) equals `target_lr`.

        The paper's exact rho/T^2 rate is ~0 for deep nets; pinning the
        effective rate instead is the recorded deviation the practical
        examples use (previously an inline conversion in async_dp_llm.py).
        """
        lr_scale = target_lr * horizon ** 2 * sigma / (n_owners * rho)
        return cls(horizon=horizon, rho=rho, sigma=sigma,
                   lr_scale=lr_scale, **kw)

    def effective_lr(self, n_owners: int) -> float:
        """The owner-update rate lr_own implied by this config."""
        return paper_rates(n_owners, self.horizon, self.rho, self.sigma,
                           self.lr_scale)[0]
