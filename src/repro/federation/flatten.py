"""Flat-parameter representation for the deep-path round engine.

The inertia round (eqs. 5-7) is elementwise in every parameter: averaging,
the regularizer gradient, both updates, and the Theta projection never mix
elements. Running it as ~7 `tree_map` passes over L leaves costs O(L) kernel
launches per pass and defeats fusion across leaves; the owner BANK pays a
per-leaf `dynamic_index/update` on top. Packing the model into ONE
contiguous f32 buffer turns the whole round into a handful of 1-D ops over
a single array, the bank into an `(N_owners, P)` matrix whose gather/
scatter is one row slice, and gives the Pallas `dp_round` kernel a layout
it can stream in a single HBM pass.

    spec = flatten_spec(params)         # static: treedef/shapes/dtypes
    flat = pack_params(params)          # ParamFlat: (P,) f32 + spec
    tree = flat.unpack()                # exact round-trip

`ParamFlat` is a registered pytree whose buffer is the only traced leaf and
whose `FlatSpec` rides as static aux data, so jitted functions specialize
per model structure exactly as they would on the pytree itself.

Exactness contract: the buffer is float32. Packing is bit-exact for every
floating dtype of itemsize <= 4 (f32 trivially; f16/bf16 embed exactly in
f32 and round-trip exactly back). Wider or non-floating leaves would make
the round-trip lossy, so they are rejected loudly.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

_PACKABLE = ("float32", "bfloat16", "float16")
_QUANT_FMTS = ("int8", "fp8")


def _check_dtype(dt: np.dtype) -> np.dtype:
    if dt.name not in _PACKABLE:
        raise TypeError(
            f"cannot pack dtype {dt.name!r} into the f32 flat buffer "
            f"without losing bits (packable: {', '.join(_PACKABLE)})")
    return dt


@dataclasses.dataclass(frozen=True)
class FlatSpec:
    """Static shape/dtype/layout metadata of a packed pytree.

    Hashable (usable as static jit aux data); equality means two buffers
    describe the same model structure and may be exchanged.
    """
    treedef: Any
    shapes: Tuple[Tuple[int, ...], ...]
    dtypes: Tuple[Any, ...]                # np.dtype per leaf
    offsets: Tuple[int, ...]               # start of each leaf in the buffer
    size: int                              # P = total elements

    @property
    def n_leaves(self) -> int:
        return len(self.shapes)

    def validate(self, tree) -> list:
        leaves, treedef = jax.tree_util.tree_flatten(tree)
        if treedef != self.treedef:
            raise ValueError(f"tree structure mismatch: got {treedef}, "
                             f"spec has {self.treedef}")
        for leaf, shape, dt in zip(leaves, self.shapes, self.dtypes):
            if tuple(leaf.shape) != shape:
                raise ValueError(f"leaf shape mismatch: got {leaf.shape}, "
                                 f"spec has {shape}")
            if np.dtype(leaf.dtype) != dt:
                # silent astype through the f32 buffer could drop bits
                # (f64 under x64, ints); the contract is loud rejection
                raise TypeError(f"leaf dtype mismatch: got {leaf.dtype}, "
                                f"spec has {dt}")
        return leaves

    def pack(self, tree, sharding=None) -> jax.Array:
        """Pytree -> (P,) f32 buffer. Exact (see module docstring).

        `sharding` (a NamedSharding, e.g. `FlatShardings.theta` from
        repro.sharding.rules) lays the buffer out on the mesh: under a
        trace it becomes a `with_sharding_constraint` (so packing inside
        a jitted round keeps the buffer sharded instead of gathering it),
        eagerly it reshards the concrete array. Values are identical
        either way.

        Implementation note: the buffer is assembled with a chain of
        static dynamic_update_slice ops, NOT one jnp.concatenate. The
        placement is bit-identical, but XLA:CPU's SPMD partitioner
        (jaxlib 0.4.3x) miscompiles concatenate of slices of a PARTIALLY
        sharded operand — e.g. a (P,) buffer sharded over 'model' on a
        (data, model) mesh comes back scaled by the unused axis size —
        while the update-slice chain lowers to local writes under every
        sharding (verified by the sharded-engine parity tests)."""
        leaves = self.validate(tree)
        buf = jnp.zeros((self.size,), jnp.float32)
        for off, leaf in zip(self.offsets, leaves):
            buf = jax.lax.dynamic_update_slice(
                buf, jnp.ravel(leaf).astype(jnp.float32), (off,))
        if sharding is not None:
            buf = jax.lax.with_sharding_constraint(buf, sharding)
        return buf

    def unpack(self, buf: jax.Array) -> Any:
        """(P,) buffer -> pytree with the original shapes/dtypes."""
        if buf.shape != (self.size,):
            raise ValueError(f"buffer shape {buf.shape} != ({self.size},)")
        leaves = []
        for off, shape, dt in zip(self.offsets, self.shapes, self.dtypes):
            n = int(np.prod(shape, dtype=np.int64)) if shape else 1
            leaves.append(buf[off:off + n].reshape(shape).astype(dt))
        return jax.tree_util.tree_unflatten(self.treedef, leaves)

    # Shape-only variants for SIDE-CHANNEL buffers that ride the model's
    # layout but must stay f32 regardless of leaf dtype — e.g. the tree
    # mechanism's noise rows and retired-node corrections: casting those
    # through a bf16 model's leaf dtypes would corrupt the noise the DP
    # guarantee is calibrated to.

    def pack_f32(self, tree) -> jax.Array:
        """Pytree with the spec's SHAPES (any floating dtype) -> (P,) f32
        buffer; shapes are validated, leaf dtypes are NOT."""
        leaves, treedef = jax.tree_util.tree_flatten(tree)
        if treedef != self.treedef:
            raise ValueError(f"tree structure mismatch: got {treedef}, "
                             f"spec has {self.treedef}")
        buf = jnp.zeros((self.size,), jnp.float32)
        for off, shape, leaf in zip(self.offsets, self.shapes, leaves):
            if tuple(leaf.shape) != shape:
                raise ValueError(f"leaf shape mismatch: got {leaf.shape}, "
                                 f"spec has {shape}")
            buf = jax.lax.dynamic_update_slice(
                buf, jnp.ravel(leaf).astype(jnp.float32), (off,))
        return buf

    def unpack_f32(self, buf: jax.Array) -> Any:
        """(P,) f32 buffer -> pytree with the spec's shapes, dtype KEPT
        f32 (no per-leaf downcast)."""
        if buf.shape != (self.size,):
            raise ValueError(f"buffer shape {buf.shape} != ({self.size},)")
        leaves = []
        for off, shape in zip(self.offsets, self.shapes):
            n = int(np.prod(shape, dtype=np.int64)) if shape else 1
            leaves.append(buf[off:off + n].reshape(shape))
        return jax.tree_util.tree_unflatten(self.treedef, leaves)


def flatten_spec(tree) -> FlatSpec:
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    if not leaves:
        raise ValueError("cannot flatten a pytree with no array leaves")
    shapes, dtypes, offsets = [], [], []
    off = 0
    for leaf in leaves:
        shapes.append(tuple(leaf.shape))
        dtypes.append(_check_dtype(np.dtype(leaf.dtype)))
        offsets.append(off)
        off += int(np.prod(leaf.shape, dtype=np.int64)) if leaf.shape else 1
    return FlatSpec(treedef=treedef, shapes=tuple(shapes),
                    dtypes=tuple(dtypes), offsets=tuple(offsets), size=off)


@jax.tree_util.register_pytree_node_class
class ParamFlat:
    """One contiguous f32 master copy of a model pytree.

    Traced leaf: `buf` (P,) f32. Static aux: `spec` (FlatSpec). Elementwise
    updates on `buf` are bit-identical to the same per-leaf updates on the
    f32 pytree, which is what makes the flat round engine's
    `fused_kernel=False` mode exactly reproduce the tree path.
    """

    def __init__(self, buf: jax.Array, spec: FlatSpec):
        self.buf = buf
        self.spec = spec

    def tree_flatten(self):
        return (self.buf,), self.spec

    @classmethod
    def tree_unflatten(cls, spec, children):
        return cls(children[0], spec)

    @property
    def size(self) -> int:
        return self.spec.size

    def unpack(self) -> Any:
        return self.spec.unpack(self.buf)

    def replace_buf(self, buf: jax.Array) -> "ParamFlat":
        return ParamFlat(buf, self.spec)

    def __repr__(self) -> str:
        return (f"ParamFlat(P={self.spec.size}, "
                f"n_leaves={self.spec.n_leaves})")


def pack_params(tree, spec: FlatSpec = None, sharding=None) -> ParamFlat:
    """Pack a model pytree into a ParamFlat (spec inferred if omitted).
    `sharding` lays the buffer out on a mesh (see FlatSpec.pack)."""
    spec = flatten_spec(tree) if spec is None else spec
    return ParamFlat(spec.pack(tree, sharding=sharding), spec)


@dataclasses.dataclass(frozen=True)
class BankCodec:
    """Static configuration of a quantized owner bank (hashable: rides as
    pytree aux data, so jitted round functions specialize per codec).

    fmt          — "int8" (symmetric linear code, q in [-127, 127]) or
                   "fp8" (float8_e4m3fn grid). 1 byte/element either way.
    block_elems  — None: one f32 scale per bank row (the default, and the
                   only layout the Pallas kernel path supports). An int
                   switches to per-block scales: each row is cut into
                   ceil(P/block_elems) segments with their own absmax
                   scale (oracle backend only; finer dynamic range for
                   rows that mix layer magnitudes).
    """
    fmt: str
    block_elems: Optional[int] = None

    def __post_init__(self):
        if self.fmt not in _QUANT_FMTS:
            raise ValueError(f"unknown bank codec {self.fmt!r} "
                             f"(supported: {', '.join(_QUANT_FMTS)})")
        if self.block_elems is not None and self.block_elems < 1:
            raise ValueError(f"block_elems must be >= 1, "
                             f"got {self.block_elems}")

    @property
    def code_dtype(self):
        from repro.kernels.bank_codec.ops import code_dtype
        return code_dtype(self.fmt)

    def n_scales(self, p: int) -> int:
        from repro.kernels.bank_codec.ops import n_scales
        return n_scales(p, self.block_elems)


def as_bank_codec(dtype) -> Optional[BankCodec]:
    """Normalize a `bank_dtype` option: "int8"/"fp8" (or a BankCodec) mean
    the quantized bank; None or a real floating dtype mean the dense
    storage path (returns None). Unknown strings fail loudly."""
    if isinstance(dtype, BankCodec):
        return dtype
    if isinstance(dtype, str):
        if dtype in _QUANT_FMTS:
            return BankCodec(dtype)
        if dtype not in _PACKABLE:           # "bfloat16" etc: dense path
            raise ValueError(
                f"unknown bank_dtype {dtype!r}: expected a floating dtype "
                f"({', '.join(_PACKABLE)}) or a quantized format "
                f"({', '.join(_QUANT_FMTS)})")
    return None


@jax.tree_util.register_pytree_node_class
class QuantBank:
    """Quantized owner bank: `(N_owners, P)` 1-byte codes, `(N_owners, nb)`
    f32 scales, and ONE shared `(P,)` f32 error-feedback residual row.

    The residual holds the quantization error of the LAST granted scatter
    (err = value - decode(encode(value))); the round engine adds it to the
    next granted update before encoding, so quantization error is
    re-injected into training instead of lost — the total error in flight
    is always one row's worth, never accumulating. A refused round leaves
    codes, scales AND residual untouched (refusal stays a bit-exact no-op
    on the bank).

    Resident bytes: N*P (codes) + 4*N*nb (scales) + 4*P (residual) —
    ~N*P/(4*N*P) = 4x below the f32 bank as N grows (3.6x at N=32).
    Traced leaves: codes/scales/residual; the BankCodec is static aux.
    """

    def __init__(self, codes: jax.Array, scales: jax.Array,
                 residual: jax.Array, codec: BankCodec):
        self.codes = codes
        self.scales = scales
        self.residual = residual
        self.codec = codec

    def tree_flatten(self):
        return (self.codes, self.scales, self.residual), self.codec

    @classmethod
    def tree_unflatten(cls, codec, children):
        return cls(*children, codec)

    @property
    def n_owners(self) -> int:
        return self.codes.shape[0]

    @property
    def size(self) -> int:
        return self.codes.shape[-1]

    @property
    def nbytes(self) -> int:
        return self.codes.nbytes + self.scales.nbytes + self.residual.nbytes

    def decode_rows(self, interpret="oracle") -> jax.Array:
        """(N, P) f32 view of every owner copy (tests/inspection)."""
        from repro.kernels.bank_codec.ops import decode_row
        return jax.vmap(lambda c, s: decode_row(
            c, s, self.codec.fmt, block_elems=self.codec.block_elems,
            interpret=interpret))(self.codes, self.scales)

    def replace(self, **kw) -> "QuantBank":
        args = {"codes": self.codes, "scales": self.scales,
                "residual": self.residual, "codec": self.codec}
        args.update(kw)
        return QuantBank(**args)

    def __repr__(self) -> str:
        return (f"QuantBank(fmt={self.codec.fmt!r}, "
                f"N={self.n_owners}, P={self.size})")


@jax.tree_util.register_pytree_node_class
class PagedBank:
    """Paged owner bank: a device-resident working set of `n_hot` rows
    backed by a host cold tier (see ``repro.federation.paging``).

    `hot` is the resident tier — a dense `(n_hot, P)` matrix or a
    `QuantBank` with `n_hot` rows (codes + scales + the shared EF
    residual, which belongs to the *session*, not to any owner, and
    therefore never pages). `hot_ids` is the device-resident page table:
    a SORTED `(n_hot,)` int32 vector of the owner ids resident in each
    slot, with the sentinel `n_owners` marking empty slots (the sentinel
    sorts after every real id, so the vector stays sorted by
    construction). `n_owners` (static aux) is the federation size N —
    resident bytes are O(n_hot * row), independent of N.

    `lookup` resolves owner id -> hot slot IN-GRAPH via
    ``jnp.searchsorted`` over the sorted page table — no host sync
    inside a scan body — and returns a `hit` bit the drivers fold into
    their grant mask, so a round touching a non-resident owner is a
    bit-exact masked no-op (the clamped slot's row is written back to
    itself), exactly like a ledger refusal. The host-side
    ``paging.OwnerPager`` keeps the working set ahead of the schedule so
    misses never occur in a correctly-driven session.
    """

    def __init__(self, hot, hot_ids: jax.Array, n_owners: int):
        self.hot = hot
        self.hot_ids = hot_ids
        self.n_owners = n_owners

    def tree_flatten(self):
        return (self.hot, self.hot_ids), self.n_owners

    @classmethod
    def tree_unflatten(cls, n_owners, children):
        return cls(children[0], children[1], n_owners)

    @property
    def n_hot(self) -> int:
        return self.hot_ids.shape[0]

    @property
    def size(self) -> int:
        return self.hot.size if isinstance(self.hot, QuantBank) \
            else self.hot.shape[-1]

    @property
    def codec(self) -> Optional[BankCodec]:
        return self.hot.codec if isinstance(self.hot, QuantBank) else None

    @property
    def nbytes(self) -> int:
        """Device-resident bytes: the hot tier + the page table."""
        hot = (self.hot.nbytes if isinstance(self.hot, QuantBank)
               else self.hot.nbytes)
        return hot + self.hot_ids.nbytes

    def lookup(self, owner_idx) -> Tuple[jax.Array, jax.Array]:
        """owner id -> (slot, hit), both traced; vmap-safe.

        `slot` is clamped into [0, n_hot) so it is ALWAYS a safe gather
        index; `hit` is False when the owner is not resident (the
        clamped slot then points at some other owner's row, which the
        drivers' masked writes leave bit-exactly untouched)."""
        slot = jnp.searchsorted(self.hot_ids,
                                jnp.asarray(owner_idx, jnp.int32))
        slot = jnp.minimum(slot, self.n_hot - 1).astype(jnp.int32)
        hit = self.hot_ids[slot] == owner_idx
        return slot, hit

    def replace(self, **kw) -> "PagedBank":
        args = {"hot": self.hot, "hot_ids": self.hot_ids,
                "n_owners": self.n_owners}
        args.update(kw)
        return PagedBank(**args)

    def __repr__(self) -> str:
        fmt = self.codec.fmt if self.codec is not None else str(
            self.hot.dtype)
        return (f"PagedBank(n_hot={self.n_hot}, N={self.n_owners}, "
                f"P={self.size}, storage={fmt!r})")


def init_flat_bank(flat: ParamFlat, n_owners: int, dtype=None,
                   sharding=None, scales_sharding=None,
                   residual_sharding=None):
    """(N_owners, P) owner-copy bank, every row the central buffer.

    `dtype` (default float32) is the bank STORAGE dtype. The bank is the
    algorithm's dominant memory cost (N_owners copies of the model) and,
    in the fused multi-round scan, its dominant loop-carry traffic;
    bf16 storage halves both. The strings "int8"/"fp8" (or a `BankCodec`)
    select the QUANTIZED bank instead: 1-byte codes + per-row f32 scales
    + an error-feedback residual row (~4x below f32, see `QuantBank`).
    The initial encode is the deterministic round-to-nearest (keyless,
    reproducible); its one-time O(scale) error is identical across rows
    and the residual starts at zero. Dense rows upcast to f32 on gather
    and re-quantize on scatter (a refused round's untouched row
    round-trips exactly). Only f32 storage preserves the flat-vs-tree
    bit-parity contract — narrower banks are a recorded (opt-in)
    deviation.

    `sharding` (e.g. `FlatShardings.bank`: owner rows over the data axes,
    P like the model) materializes the bank already distributed — the
    broadcast never exists replicated on one device. Quantized banks
    take `scales_sharding`/`residual_sharding` for their extra buffers
    (`FlatShardings.bank_scales` / `.row`).
    """
    codec = as_bank_codec(dtype)
    if codec is not None:
        from repro.federation.dp_sgd import resolve_interpret
        from repro.kernels.bank_codec.ops import encode_row
        codes_row, scales_row, _ = encode_row(
            flat.buf, None, codec.fmt, block_elems=codec.block_elems,
            deterministic=True, interpret=resolve_interpret(None))
        codes = jnp.broadcast_to(codes_row[None], (n_owners, flat.size))
        scales = jnp.broadcast_to(scales_row[None],
                                  (n_owners, scales_row.shape[0]))
        residual = jnp.zeros((flat.size,), jnp.float32)
        if sharding is not None:
            codes = jax.lax.with_sharding_constraint(codes, sharding)
        if scales_sharding is not None:
            scales = jax.lax.with_sharding_constraint(scales,
                                                      scales_sharding)
        if residual_sharding is not None:
            residual = jax.lax.with_sharding_constraint(residual,
                                                        residual_sharding)
        return QuantBank(codes, scales, residual, codec)
    bank = jnp.broadcast_to(flat.buf[None], (n_owners, flat.size))
    if dtype is not None:
        bank = bank.astype(dtype)
    if sharding is not None:
        bank = jax.lax.with_sharding_constraint(bank, sharding)
    return bank
