"""Flat-parameter representation for the deep-path round engine.

The inertia round (eqs. 5-7) is elementwise in every parameter: averaging,
the regularizer gradient, both updates, and the Theta projection never mix
elements. Running it as ~7 `tree_map` passes over L leaves costs O(L) kernel
launches per pass and defeats fusion across leaves; the owner BANK pays a
per-leaf `dynamic_index/update` on top. Packing the model into ONE
contiguous f32 buffer turns the whole round into a handful of 1-D ops over
a single array, the bank into an `(N_owners, P)` matrix whose gather/
scatter is one row slice, and gives the Pallas `dp_round` kernel a layout
it can stream in a single HBM pass.

    spec = flatten_spec(params)         # static: treedef/shapes/dtypes
    flat = pack_params(params)          # ParamFlat: (P,) f32 + spec
    tree = flat.unpack()                # exact round-trip

`ParamFlat` is a registered pytree whose buffer is the only traced leaf and
whose `FlatSpec` rides as static aux data, so jitted functions specialize
per model structure exactly as they would on the pytree itself.

Exactness contract: the buffer is float32. Packing is bit-exact for every
floating dtype of itemsize <= 4 (f32 trivially; f16/bf16 embed exactly in
f32 and round-trip exactly back). Wider or non-floating leaves would make
the round-trip lossy, so they are rejected loudly.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Tuple

import jax
import jax.numpy as jnp
import numpy as np

_PACKABLE = ("float32", "bfloat16", "float16")


def _check_dtype(dt: np.dtype) -> np.dtype:
    if dt.name not in _PACKABLE:
        raise TypeError(
            f"cannot pack dtype {dt.name!r} into the f32 flat buffer "
            f"without losing bits (packable: {', '.join(_PACKABLE)})")
    return dt


@dataclasses.dataclass(frozen=True)
class FlatSpec:
    """Static shape/dtype/layout metadata of a packed pytree.

    Hashable (usable as static jit aux data); equality means two buffers
    describe the same model structure and may be exchanged.
    """
    treedef: Any
    shapes: Tuple[Tuple[int, ...], ...]
    dtypes: Tuple[Any, ...]                # np.dtype per leaf
    offsets: Tuple[int, ...]               # start of each leaf in the buffer
    size: int                              # P = total elements

    @property
    def n_leaves(self) -> int:
        return len(self.shapes)

    def validate(self, tree) -> list:
        leaves, treedef = jax.tree_util.tree_flatten(tree)
        if treedef != self.treedef:
            raise ValueError(f"tree structure mismatch: got {treedef}, "
                             f"spec has {self.treedef}")
        for leaf, shape, dt in zip(leaves, self.shapes, self.dtypes):
            if tuple(leaf.shape) != shape:
                raise ValueError(f"leaf shape mismatch: got {leaf.shape}, "
                                 f"spec has {shape}")
            if np.dtype(leaf.dtype) != dt:
                # silent astype through the f32 buffer could drop bits
                # (f64 under x64, ints); the contract is loud rejection
                raise TypeError(f"leaf dtype mismatch: got {leaf.dtype}, "
                                f"spec has {dt}")
        return leaves

    def pack(self, tree, sharding=None) -> jax.Array:
        """Pytree -> (P,) f32 buffer. Exact (see module docstring).

        `sharding` (a NamedSharding, e.g. `FlatShardings.theta` from
        repro.sharding.rules) lays the buffer out on the mesh: under a
        trace it becomes a `with_sharding_constraint` (so packing inside
        a jitted round keeps the buffer sharded instead of gathering it),
        eagerly it reshards the concrete array. Values are identical
        either way.

        Implementation note: the buffer is assembled with a chain of
        static dynamic_update_slice ops, NOT one jnp.concatenate. The
        placement is bit-identical, but XLA:CPU's SPMD partitioner
        (jaxlib 0.4.3x) miscompiles concatenate of slices of a PARTIALLY
        sharded operand — e.g. a (P,) buffer sharded over 'model' on a
        (data, model) mesh comes back scaled by the unused axis size —
        while the update-slice chain lowers to local writes under every
        sharding (verified by the sharded-engine parity tests)."""
        leaves = self.validate(tree)
        buf = jnp.zeros((self.size,), jnp.float32)
        for off, leaf in zip(self.offsets, leaves):
            buf = jax.lax.dynamic_update_slice(
                buf, jnp.ravel(leaf).astype(jnp.float32), (off,))
        if sharding is not None:
            buf = jax.lax.with_sharding_constraint(buf, sharding)
        return buf

    def unpack(self, buf: jax.Array) -> Any:
        """(P,) buffer -> pytree with the original shapes/dtypes."""
        if buf.shape != (self.size,):
            raise ValueError(f"buffer shape {buf.shape} != ({self.size},)")
        leaves = []
        for off, shape, dt in zip(self.offsets, self.shapes, self.dtypes):
            n = int(np.prod(shape, dtype=np.int64)) if shape else 1
            leaves.append(buf[off:off + n].reshape(shape).astype(dt))
        return jax.tree_util.tree_unflatten(self.treedef, leaves)


def flatten_spec(tree) -> FlatSpec:
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    if not leaves:
        raise ValueError("cannot flatten a pytree with no array leaves")
    shapes, dtypes, offsets = [], [], []
    off = 0
    for leaf in leaves:
        shapes.append(tuple(leaf.shape))
        dtypes.append(_check_dtype(np.dtype(leaf.dtype)))
        offsets.append(off)
        off += int(np.prod(leaf.shape, dtype=np.int64)) if leaf.shape else 1
    return FlatSpec(treedef=treedef, shapes=tuple(shapes),
                    dtypes=tuple(dtypes), offsets=tuple(offsets), size=off)


@jax.tree_util.register_pytree_node_class
class ParamFlat:
    """One contiguous f32 master copy of a model pytree.

    Traced leaf: `buf` (P,) f32. Static aux: `spec` (FlatSpec). Elementwise
    updates on `buf` are bit-identical to the same per-leaf updates on the
    f32 pytree, which is what makes the flat round engine's
    `fused_kernel=False` mode exactly reproduce the tree path.
    """

    def __init__(self, buf: jax.Array, spec: FlatSpec):
        self.buf = buf
        self.spec = spec

    def tree_flatten(self):
        return (self.buf,), self.spec

    @classmethod
    def tree_unflatten(cls, spec, children):
        return cls(children[0], spec)

    @property
    def size(self) -> int:
        return self.spec.size

    def unpack(self) -> Any:
        return self.spec.unpack(self.buf)

    def replace_buf(self, buf: jax.Array) -> "ParamFlat":
        return ParamFlat(buf, self.spec)

    def __repr__(self) -> str:
        return (f"ParamFlat(P={self.spec.size}, "
                f"n_leaves={self.spec.n_leaves})")


def pack_params(tree, spec: FlatSpec = None, sharding=None) -> ParamFlat:
    """Pack a model pytree into a ParamFlat (spec inferred if omitted).
    `sharding` lays the buffer out on a mesh (see FlatSpec.pack)."""
    spec = flatten_spec(tree) if spec is None else spec
    return ParamFlat(spec.pack(tree, sharding=sharding), spec)


def init_flat_bank(flat: ParamFlat, n_owners: int,
                   dtype=None, sharding=None) -> jax.Array:
    """(N_owners, P) owner-copy bank, every row the central buffer.

    `dtype` (default float32) is the bank STORAGE dtype. The bank is the
    algorithm's dominant memory cost (N_owners copies of the model) and,
    in the fused multi-round scan, its dominant loop-carry traffic;
    bf16 storage halves both. Rows are upcast to f32 on gather and
    re-quantized on scatter (a refused round's untouched row round-trips
    exactly). Only f32 storage preserves the flat-vs-tree bit-parity
    contract — narrower banks are a recorded (opt-in) deviation.

    `sharding` (e.g. `FlatShardings.bank`: owner rows over the data axes,
    P like the model) materializes the bank already distributed — the
    broadcast never exists replicated on one device.
    """
    bank = jnp.broadcast_to(flat.buf[None], (n_owners, flat.size))
    if dtype is not None:
        bank = bank.astype(dtype)
    if sharding is not None:
        bank = jax.lax.with_sharding_constraint(bank, sharding)
    return bank
