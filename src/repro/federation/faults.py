"""In-graph fault layer: injection, guards, checksums, quarantine.

The paper's owners are *intermittently available*; real deployments add
failure modes on top of mere absence — dropped contacts, stale replays,
non-finite gradients, corrupted payloads (Li et al. 1912.07902). This
module gives the engine a deterministic, in-graph model of those faults
so every driver (per-round step, fused scan, grouped vmap) experiences
an IDENTICAL fault sequence under fixed keys, and the DP accounting
stays exact *through* faults:

  * ``FaultPlan`` draws one int8 fault code per round from a dedicated
    key stream (``fold_in(key, FAULT_SALT)`` — disjoint from the round
    keys by construction), or a precomputed trace replays via
    :func:`as_fault_codes`.
  * ``FaultState`` rides inside ``AsyncDPState``: a per-owner int32
    checksum column next to the bank (payload integrity), tumbling
    fault-window counters, and a quarantine flag. All updates are
    where-masked scatters — a faulted round is a bit-exact no-op on the
    bank, scales, EF residual and tree nodes.
  * epsilon is charged **at response time**: a DROP (owner never
    answered) spends nothing; a round that answered and was then
    rejected by the guards (non-finite update, checksum mismatch, stale
    replay) HAS spent its budget — the noisy query left the owner. The
    ``DeviceLedger`` records the distinction in its ``dropped`` /
    ``faulted`` columns.
  * owners exceeding ``FaultPolicy.max_faults`` fault events within a
    ``window``-contact tumbling window are quarantined in-graph:
    subsequent rounds are masked no-ops charged to the ``quarantined``
    ledger column (no epsilon, no refusal).

Checksums are exact int32 bit-sums (wraparound addition is associative
and commutative, so grouped/vmapped verification is reduction-order
free). Corruption injection never touches the payload — it offsets the
*observed* checksum by a fixed nonzero delta, so detection is
guaranteed rather than probabilistic.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.federation.flatten import PagedBank, QuantBank

# Per-round fault codes (int8 in traced code, plain ints here so host
# and device comparisons both work).
OK = 0                  # healthy round
DROP = 1                # owner unreachable: query never answered, no eps
STALE = 2               # owner answered with a stale/replayed update
NONFINITE_GRAD = 3      # owner answered with a non-finite update
CORRUPT_PAYLOAD = 4     # owner's resident bank row arrived corrupted
TIMEOUT = 5             # owner answered AFTER the learner deadline: the
                        # noisy query left the owner (eps spent), but the
                        # update is masked (see federation.staleness)

FAULT_CODES = (OK, DROP, STALE, NONFINITE_GRAD, CORRUPT_PAYLOAD, TIMEOUT)

# Dedicated fold_in stream for fault draws — disjoint from round keys
# (raw split) and codec bits (_CODEC_SALT) by construction.
FAULT_SALT = 0x4654     # "FT"

# Fixed nonzero offset added to the OBSERVED row checksum when a round
# carries CORRUPT_PAYLOAD: obs != stored always holds (delta != 0 mod
# 2^32), so corruption detection is exact, and the payload itself is
# never modified.
CORRUPT_CSUM_DELTA = 1 << 30


@dataclasses.dataclass(frozen=True)
class FaultPlan:
    """Per-round fault rates; drawn once per dispatch from a salted key.

    Rates are bucket probabilities over [0, 1): a single uniform per
    round selects DROP / STALE / NONFINITE_GRAD / CORRUPT_PAYLOAD /
    OK by cumulative thresholds, so the draw is one op and the code
    stream is identical across drivers under the same key.
    """

    drop: float = 0.0
    stale: float = 0.0
    nonfinite: float = 0.0
    corrupt: float = 0.0

    def __post_init__(self):
        rates = (self.drop, self.stale, self.nonfinite, self.corrupt)
        if any(r < 0.0 for r in rates):
            raise ValueError(f"fault rates must be >= 0, got {rates}")
        if sum(rates) > 1.0:
            raise ValueError(
                f"fault rates sum to {sum(rates)} > 1; they are bucket "
                "probabilities over a single per-round uniform")

    def draw(self, key, k: int):
        """(k,) int8 fault codes from the dedicated FAULT_SALT stream."""
        u = jax.random.uniform(jax.random.fold_in(key, FAULT_SALT), (k,))
        t1 = self.drop
        t2 = t1 + self.stale
        t3 = t2 + self.nonfinite
        t4 = t3 + self.corrupt
        return jnp.where(
            u < t1, DROP,
            jnp.where(u < t2, STALE,
                      jnp.where(u < t3, NONFINITE_GRAD,
                                jnp.where(u < t4, CORRUPT_PAYLOAD,
                                          OK)))).astype(jnp.int8)


@dataclasses.dataclass(frozen=True)
class FaultPolicy:
    """Quarantine policy: > ``max_faults - 1`` fault events within one
    ``window``-contact tumbling window quarantines the owner (masked
    no-ops from then on; permanent for the session)."""

    max_faults: int = 3
    window: int = 16

    def __post_init__(self):
        if self.max_faults < 1:
            raise ValueError(f"max_faults must be >= 1, got {self.max_faults}")
        if self.window < 1:
            raise ValueError(f"window must be >= 1, got {self.window}")


class FaultState(NamedTuple):
    """Per-owner fault-layer arrays carried inside ``AsyncDPState``.

    ``checksum``    (N,) int32  bit-sum of each owner's resident bank row
    ``win_faults``  (N,) int32  fault events in the current window
    ``contacts``    (N,) int32  contacts while not quarantined (windows
                                tumble per-owner on this counter, which
                                keeps grouped execution order-free)
    ``quarantined`` (N,) bool   masked out of every subsequent round
    """

    checksum: jax.Array
    win_faults: jax.Array
    contacts: jax.Array
    quarantined: jax.Array


def _is_float_dtype(dt) -> bool:
    """Static float-dtype check covering the ml_dtypes extensions
    (bf16/fp8 register with numpy as kind 'V', not 'f')."""
    dt = np.dtype(dt)
    return dt.kind == "f" or dt.name.startswith(("bfloat16", "float8"))


def _bits32(leaf) -> jax.Array:
    """Exact int32 view of a buffer's bits (f32/bf16/f16/fp8/int8/...).

    Sub-4-byte dtypes widen through their unsigned bit pattern so every
    payload bit lands in the sum; int32 wraparound addition is exact,
    associative and commutative, so any reduction order agrees.
    """
    dt = np.dtype(leaf.dtype)
    if dt == np.float32:
        return jax.lax.bitcast_convert_type(leaf, jnp.int32)
    if dt.itemsize == 2:
        return jax.lax.bitcast_convert_type(leaf, jnp.uint16).astype(jnp.int32)
    if dt.itemsize == 1:
        return jax.lax.bitcast_convert_type(leaf, jnp.uint8).astype(jnp.int32)
    return leaf.astype(jnp.int32)


def row_checksum(bank, owner_idx) -> jax.Array:
    """() int32 checksum of one owner's resident row.

    Covers QuantBank codes + per-block scales (the shared EF residual is
    owned by no one and excluded), a flat (N, P) row, or every leaf row
    of a pytree bank. For a PagedBank, `owner_idx` must be the HOT SLOT
    (the caller resolves owner -> slot via ``bank.lookup``); the sum
    covers the slot's resident payload, whose bits round-trip the cold
    tier exactly, so a row keeps its checksum across evict/refetch.
    vmap-safe: index with dynamic_index_in_dim.
    """
    if isinstance(bank, PagedBank):
        return row_checksum(bank.hot, owner_idx)
    if isinstance(bank, QuantBank):
        c = jax.lax.dynamic_index_in_dim(bank.codes, owner_idx, 0,
                                         keepdims=False)
        s = jax.lax.dynamic_index_in_dim(bank.scales, owner_idx, 0,
                                         keepdims=False)
        return (jnp.sum(_bits32(c), dtype=jnp.int32)
                + jnp.sum(_bits32(s), dtype=jnp.int32))
    if isinstance(bank, jax.Array):
        row = jax.lax.dynamic_index_in_dim(bank, owner_idx, 0, keepdims=False)
        return jnp.sum(_bits32(row), dtype=jnp.int32)
    tot = jnp.int32(0)
    for leaf in jax.tree_util.tree_leaves(bank):
        row = jax.lax.dynamic_index_in_dim(leaf, owner_idx, 0, keepdims=False)
        tot = tot + jnp.sum(_bits32(row), dtype=jnp.int32)
    return tot


def bank_checksums(bank) -> jax.Array:
    """(N,) int32 checksums for every owner row (init / audit)."""
    if isinstance(bank, QuantBank):
        n = bank.n_owners
    elif isinstance(bank, jax.Array):
        n = bank.shape[0]
    else:
        n = jax.tree_util.tree_leaves(bank)[0].shape[0]
    idx = jnp.arange(n, dtype=jnp.int32)
    return jax.vmap(lambda i: row_checksum(bank, i))(idx)


def init_fault_state(bank, n_owners: int) -> FaultState:
    if isinstance(bank, PagedBank):
        # at init every row — hot, cold, and never-materialized — equals
        # the default row (paging.init_paged_state's contract), so the
        # (N,) checksum column is one row's sum tiled, never an O(N*P)
        # materialization
        one = row_checksum(bank.hot, jnp.int32(0))
        return FaultState(
            checksum=jnp.broadcast_to(one, (n_owners,)).astype(jnp.int32),
            win_faults=jnp.zeros((n_owners,), jnp.int32),
            contacts=jnp.zeros((n_owners,), jnp.int32),
            quarantined=jnp.zeros((n_owners,), jnp.bool_))
    # distinct zero buffers per field — donated states may not alias leaves
    return FaultState(
        checksum=bank_checksums(bank),
        win_faults=jnp.zeros((n_owners,), jnp.int32),
        contacts=jnp.zeros((n_owners,), jnp.int32),
        quarantined=jnp.zeros((n_owners,), jnp.bool_))


def verify_row(checksum, bank, owner_idx, corrupt,
               row_idx=None) -> jax.Array:
    """bool: does the owner's resident row match its stored checksum?

    ``corrupt`` (CORRUPT_PAYLOAD this round) offsets the *observed* sum
    by a fixed nonzero delta — detection is guaranteed and the payload
    is untouched, so a masked-out round stays bit-exact.

    ``row_idx`` separates the PAYLOAD index from the CHECKSUM-COLUMN
    index for paged banks: the observed sum reads the hot slot, the
    stored sum lives in the per-owner (N,) column. None (flat banks)
    keeps both equal to ``owner_idx``.
    """
    ridx = owner_idx if row_idx is None else row_idx
    obs = row_checksum(bank, ridx) + jnp.where(
        corrupt, jnp.int32(CORRUPT_CSUM_DELTA), jnp.int32(0))
    return obs == checksum[owner_idx]


def inject_nonfinite(tree, flag):
    """NaN-poison float leaves where ``flag`` is set (bit-identity off).

    ``flag`` is scalar (per-round drivers) or (G,) (grouped members);
    it broadcasts against each leaf's leading axes.
    """
    def poison(leaf):
        if not _is_float_dtype(leaf.dtype):
            return leaf
        fl = flag
        if np.ndim(fl):
            fl = jnp.reshape(fl, np.shape(fl)
                             + (1,) * (np.ndim(leaf) - np.ndim(fl)))
        return jnp.where(fl, jnp.asarray(jnp.nan, leaf.dtype), leaf)
    return jax.tree_util.tree_map(poison, tree)


def finite_guard(tree) -> jax.Array:
    """bool: every float leaf of ``tree`` is fully finite."""
    ok = jnp.bool_(True)
    for leaf in jax.tree_util.tree_leaves(tree):
        if _is_float_dtype(leaf.dtype):
            ok = ok & jnp.all(jnp.isfinite(leaf))
    return ok


def update_checksum(fs: FaultState, bank, owner_idx, apply,
                    row_idx=None) -> FaultState:
    """Re-derive the stored checksum from the POST-WRITE bank row.

    Scatter-dropped where ``apply`` is False, so a masked round leaves
    the stored checksum (and therefore future verification) untouched.
    Handles a scalar owner (step / fused) or a (G,) group (vmapped
    members; owners within a group are distinct, so scatters are
    disjoint). ``row_idx`` (paged banks) reads the payload from the hot
    slot while the stored sum scatters into the per-owner column.
    """
    n = fs.checksum.shape[0]
    ridx = owner_idx if row_idx is None else row_idx
    if np.ndim(owner_idx) == 0:
        new = row_checksum(bank, ridx)
    else:
        new = jax.vmap(lambda r: row_checksum(bank, r))(ridx)
    idx = jnp.where(apply, owner_idx, n)
    return fs._replace(checksum=fs.checksum.at[idx].set(new, mode="drop"))


def fault_tick(fs: FaultState, owner_idx, faulted, policy: FaultPolicy,
               active) -> FaultState:
    """Advance the per-owner fault window after a contact.

    ``active`` gates the whole tick (quarantined owners and padded group
    slots tick nothing — their window state freezes, which makes the
    quarantine permanent). Windows tumble on each owner's own contact
    count, so grouped execution produces the same window boundaries as
    the sequential drivers. Works for a scalar owner or a (G,) group of
    distinct owners.
    """
    n = fs.checksum.shape[0]
    w = jnp.int32(policy.window)
    base = jnp.where(fs.contacts[owner_idx] % w == 0,
                     jnp.int32(0), fs.win_faults[owner_idx])
    wf = base + jnp.asarray(faulted, jnp.bool_).astype(jnp.int32)
    idx = jnp.where(active, owner_idx, n)
    return FaultState(
        checksum=fs.checksum,
        win_faults=fs.win_faults.at[idx].set(wf, mode="drop"),
        contacts=fs.contacts.at[idx].add(1, mode="drop"),
        quarantined=fs.quarantined.at[idx].set(
            wf >= policy.max_faults, mode="drop"))


def as_fault_codes(codes, k: Optional[int] = None) -> jax.Array:
    """Validate + coerce an explicit per-round fault-code trace.

    Host-side bounds check (skipped for tracers, mirroring
    ``as_owner_seq``): every code must be one of FAULT_CODES, and the
    length must match the dispatch when ``k`` is given.
    """
    codes = jnp.asarray(codes)
    if codes.ndim != 1:
        raise ValueError(f"fault codes must be 1-D, got shape {codes.shape}")
    if not jnp.issubdtype(codes.dtype, jnp.integer):
        raise ValueError(f"fault codes must be integer, got {codes.dtype}")
    if k is not None and codes.shape[0] != k:
        raise ValueError(
            f"{codes.shape[0]} fault codes for a {k}-round dispatch")
    if isinstance(codes, jax.core.Tracer):
        return codes.astype(jnp.int8)
    arr = jax.device_get(codes)
    if arr.size and (arr.min() < OK or arr.max() > TIMEOUT):
        raise ValueError(
            f"fault codes must lie in {FAULT_CODES}, got range "
            f"[{arr.min()}, {arr.max()}]")
    return codes.astype(jnp.int8)


__all__ = [
    "OK", "DROP", "STALE", "NONFINITE_GRAD", "CORRUPT_PAYLOAD", "TIMEOUT",
    "FAULT_CODES", "FAULT_SALT", "CORRUPT_CSUM_DELTA",
    "FaultPlan", "FaultPolicy", "FaultState",
    "init_fault_state", "bank_checksums", "row_checksum", "verify_row",
    "inject_nonfinite", "finite_guard", "update_checksum", "fault_tick",
    "as_fault_codes",
]
