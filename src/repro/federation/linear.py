"""The paper's convex learning problem (Section 5): ridge linear regression.

Canonical home of the federation's convex primitives; ``repro.core.linear``
is a compatibility shim over this module.

    f(theta) = reg * ||theta||^2 + (1/n) sum_j ||y_j - theta^T x_j||^2

Per-owner gradient queries (eq. 3) reduce to Gram-matrix form
    Q_i(theta) = 2 (A_i theta - b_i),   A_i = X_i^T X_i / n_i,  b_i = X_i^T y_i / n_i
so each Algorithm-1 iteration is O(p^2) regardless of n_i. The bound Xi
(Assumption 2) is computed from public data bounds; because it is a true
upper bound, per-record clipping never binds and the Gram shortcut is exact.
"""
from __future__ import annotations

from typing import List, NamedTuple, Tuple

import jax
import jax.numpy as jnp
import numpy as np


class Owner(NamedTuple):
    A: jax.Array        # (p, p) = X^T X / n
    b: jax.Array        # (p,)   = X^T y / n
    n: int
    xi: float           # per-record gradient norm bound for this owner


class LinearProblem(NamedTuple):
    G: jax.Array        # (p, p) global X^T X / n
    h: jax.Array        # (p,)   global X^T y / n
    c: jax.Array        # ()     mean y^2
    reg: float
    theta_max: float
    theta_star: jax.Array
    f_star: jax.Array
    n_total: int
    xi: float           # global Xi = max_i xi_i


def record_grad_bound(X: np.ndarray, y: np.ndarray, theta_max: float) -> float:
    """Xi = sup_theta max_j ||grad l_j||_2 <= 2 max_j ||x_j|| (theta_max ||x_j||_1 + |y_j|)."""
    xn2 = np.linalg.norm(X, axis=1)
    xn1 = np.abs(X).sum(axis=1)
    return float(2.0 * np.max(xn2 * (theta_max * xn1 + np.abs(y))))


def fitness(prob: LinearProblem, theta: jax.Array) -> jax.Array:
    quad = theta @ prob.G @ theta - 2.0 * theta @ prob.h + prob.c
    return prob.reg * theta @ theta + quad


def relative_fitness(prob: LinearProblem, theta: jax.Array) -> jax.Array:
    """psi(theta) = f(theta)/f(theta*) - 1 >= 0 (Section 5)."""
    return fitness(prob, theta) / prob.f_star - 1.0


def owner_grad(owner: Owner, theta: jax.Array) -> jax.Array:
    """Q_i(theta) of eq. (3) for the squared loss."""
    return 2.0 * (owner.A @ theta - owner.b)


def reg_grad(prob: LinearProblem, theta: jax.Array) -> jax.Array:
    return 2.0 * prob.reg * theta


def make_problem(shards: List[Tuple[np.ndarray, np.ndarray]], *,
                 reg: float = 1e-5, theta_max: float = 10.0
                 ) -> Tuple[LinearProblem, List[Owner]]:
    """shards: [(X_i, y_i)] per owner."""
    p = shards[0][0].shape[1]
    owners = []
    G = np.zeros((p, p))
    h = np.zeros(p)
    c = 0.0
    n_total = sum(X.shape[0] for X, _ in shards)
    for X, y in shards:
        n_i = X.shape[0]
        A = X.T @ X / n_i
        b = X.T @ y / n_i
        xi = record_grad_bound(X, y, theta_max)
        owners.append(Owner(jnp.asarray(A), jnp.asarray(b), n_i, xi))
        G += X.T @ X
        h += X.T @ y
        c += float(y @ y)
    G, h, c = G / n_total, h / n_total, c / n_total
    theta_star = np.linalg.solve(G + reg * np.eye(p), h)
    assert np.max(np.abs(theta_star)) <= theta_max, (
        "theta_max too small: unconstrained optimum outside Theta "
        f"(max |theta*| = {np.max(np.abs(theta_star)):.3f})")
    f_star = reg * theta_star @ theta_star + (
        theta_star @ G @ theta_star - 2 * theta_star @ h + c)
    prob = LinearProblem(jnp.asarray(G), jnp.asarray(h), jnp.asarray(c),
                         reg, theta_max, jnp.asarray(theta_star),
                         jnp.asarray(f_star), n_total,
                         max(o.xi for o in owners))
    return prob, owners
