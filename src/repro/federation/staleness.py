"""In-graph asynchronous runtime: latency, deadlines, retries, staleness.

PR 8's fault layer models *whether* an owner answers; this module models
*when*. The paper's owners are geographically scattered — a response
takes time to arrive, and a learner that waits forever is synchronous in
disguise. Three pieces, all deterministic and in-graph so every driver
(per-round step, fused scan, grouped vmap) sees the identical runtime
under fixed keys:

  * ``LatencyPlan`` draws one response latency per round: a per-owner
    deterministic ``base`` plus optional exponential ``jitter`` from a
    dedicated key stream (``fold_in(key, STALE_SALT)`` — disjoint from
    the round keys and the FAULT_SALT stream by construction, the same
    contract as ``FaultPlan``). A zero-latency plan draws nothing and
    reproduces the latency-free engine bit-for-bit.
  * ``StalenessPolicy.deadline`` converts late responses into the
    TIMEOUT outcome of the fault algebra (:func:`merge_timeout_codes`):
    the owner DID answer — the noisy query left the owner, so epsilon
    is spent exactly as for a guard-rejected response — but the learner
    has moved on, so the update is masked. An owner that never answered
    (DROP) stays a DROP: no response, no epsilon. When per-tick arrival
    instants are available (``Schedule.draw_with_times``), the
    effective deadline additionally tightens to the gap before the next
    tick — the learner serves whoever arrives next.
  * timed-out owners re-enter through an in-graph retry queue:
    ``StalenessState`` carries per-owner exponential-backoff counters
    and a retry budget. While an owner's ``cooldown`` is positive its
    scheduled rounds are masked re-dispatches — ledgered in the new
    ``DeviceLedger.retried`` column, spending no epsilon (the learner
    never sent the query) — and each one decrements the cooldown.
  * per-owner AGE counters (rounds since the last granted update)
    drive a ``decay**age`` weight on the eq. 5-7 inertia target
    (:func:`staleness_weight`): the round runs against
    ``theta_L + w * (theta_i - theta_L)``, pulling a stale owner copy
    toward the fresh central model (Li et al. 1912.07902). ``decay=1``
    is STATICALLY gated out by the drivers, so the default traces the
    undecayed program verbatim (bit-parity contract).

Outcome algebra (extends the PR 8 table; epsilon at response time):

    round in backoff   -> retried      masked, no epsilon, no refusal
    answered late      -> timed_out    masked, epsilon SPENT
    answered on time   -> PR 8 guards decide (apply / faulted)
    never answered     -> dropped      no epsilon

Lateness dominates the payload guards: a late response is discarded
before the learner inspects it, so a late corrupt payload counts as
``timed_out``, not ``faulted`` (either way the epsilon is spent and the
update is masked — only the ledger column differs). Timeouts do NOT
tick the fault-quarantine window: slowness has its own escalation path
(backoff), and conflating it with byzantine faults would quarantine
every distant owner.
"""
from __future__ import annotations

import dataclasses
import math
from typing import NamedTuple, Optional, Sequence, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.federation.faults import DROP, TIMEOUT

# Dedicated fold_in stream for latency draws — disjoint from round keys
# (raw split), fault codes (FAULT_SALT) and codec bits (_CODEC_SALT).
STALE_SALT = 0x5354     # "ST"


@dataclasses.dataclass(frozen=True)
class LatencyPlan:
    """Per-owner response-latency model, drawn once per dispatch.

    ``base`` is the deterministic per-owner floor (a scalar applies to
    every owner; a sequence is indexed by owner id). ``jitter`` adds an
    exponential tail of that scale from the STALE_SALT key stream — the
    classic heavy-ish straggler model. Units are whatever the schedule's
    tick times use (abstract rounds when no times are in play). The
    all-zero default draws nothing and times nothing out.
    """

    base: Union[float, Sequence[float]] = 0.0
    jitter: float = 0.0

    def __post_init__(self):
        base = np.atleast_1d(np.asarray(self.base, np.float64))
        if base.ndim != 1:
            raise ValueError(f"base must be a scalar or a per-owner "
                             f"vector, got shape {base.shape}")
        if base.size and base.min() < 0.0:
            raise ValueError(f"base latencies must be >= 0, got "
                             f"{base.min()}")
        if self.jitter < 0.0:
            raise ValueError(f"jitter must be >= 0, got {self.jitter}")

    def draw(self, key, owner_seq) -> jax.Array:
        """(K,) f32 response latencies for a dispatch's owner sequence.

        Deterministic in (key, owner_seq); the jitter stream folds in
        STALE_SALT, so under the run_rounds contract (latencies drawn
        from the SAME key as the round keys and the fault codes) all
        three streams stay disjoint. A zero-jitter plan consumes no
        randomness at all.
        """
        owner_seq = jnp.asarray(owner_seq)
        k = owner_seq.shape[0]
        base = np.asarray(self.base, np.float32)
        if base.ndim == 0:
            lat = jnp.full((k,), float(base), jnp.float32)
        else:
            lat = jnp.asarray(base, jnp.float32)[owner_seq]
        if self.jitter:
            u = jax.random.exponential(
                jax.random.fold_in(key, STALE_SALT), (k,), jnp.float32)
            lat = lat + jnp.float32(self.jitter) * u
        return lat


@dataclasses.dataclass(frozen=True)
class StalenessPolicy:
    """Learner-side runtime policy: deadline, retry budget, decay.

    ``deadline``     responses later than this are TIMEOUT (inf = wait
                     forever: nothing ever times out).
    ``max_retries``  per-owner retry budget, refilled on every granted
                     round; a timeout with budget left schedules a
                     backoff cooldown, past the budget the owner just
                     keeps being served (and keeps timing out) with no
                     retry masking.
    ``backoff_cap``  exponent cap: the j-th consecutive timeout waits
                     ``2**min(j, backoff_cap)`` scheduled rounds.
    ``decay``        lambda of the ``lambda**age`` inertia weight
                     (eq. 5-7 target); 1.0 (the default) disables the
                     decay STATICALLY — the undecayed trace is verbatim.
    """

    deadline: float = math.inf
    max_retries: int = 0
    backoff_cap: int = 4
    decay: float = 1.0

    def __post_init__(self):
        if not self.deadline > 0.0:
            raise ValueError(f"deadline must be > 0, got {self.deadline}")
        if self.max_retries < 0:
            raise ValueError(
                f"max_retries must be >= 0, got {self.max_retries}")
        if not 0 <= self.backoff_cap <= 30:
            raise ValueError(f"backoff_cap must be in [0, 30], got "
                             f"{self.backoff_cap} (int32 cooldowns)")
        if not 0.0 < self.decay <= 1.0:
            raise ValueError(
                f"decay must be in (0, 1], got {self.decay}")


class StalenessState(NamedTuple):
    """Per-owner runtime counters carried inside ``AsyncDPState``.

    ``clock``       ()   int32  rounds scheduled so far (every round
                                counts — refused, dropped, retried —
                                so ages are driver-order-free)
    ``last_grant``  (N,) int32  clock value of the owner's last granted
                                update (age = clock - last_grant)
    ``cooldown``    (N,) int32  scheduled rounds left in backoff; > 0
                                masks the owner's rounds as retries
    ``backoff``     (N,) int32  consecutive-timeout exponent (resets on
                                a granted round)
    ``retry_left``  (N,) int32  retry budget left (refills on a grant)
    """

    clock: jax.Array
    last_grant: jax.Array
    cooldown: jax.Array
    backoff: jax.Array
    retry_left: jax.Array


def init_staleness_state(n_owners: int,
                         policy: StalenessPolicy) -> StalenessState:
    # distinct zero buffers per field — donated states may not alias
    return StalenessState(
        clock=jnp.zeros((), jnp.int32),
        last_grant=jnp.zeros((n_owners,), jnp.int32),
        cooldown=jnp.zeros((n_owners,), jnp.int32),
        backoff=jnp.zeros((n_owners,), jnp.int32),
        retry_left=jnp.full((n_owners,), policy.max_retries, jnp.int32))


def deadline_guard(fcode) -> jax.Array:
    """bool: did the response beat the learner deadline?

    False exactly on TIMEOUT rounds — the response exists (epsilon is
    spent) but arrived too late to apply. Drivers mask the round's
    writes on this bit, the same grant discipline as the PR 8 payload
    guards (dpcheck DPC302 recognizes it as a grant source).
    """
    return jnp.asarray(fcode) != TIMEOUT


def merge_timeout_codes(codes, latencies, deadline,
                        times=None) -> jax.Array:
    """Fold a latency draw into a per-round fault-code trace.

    Every ANSWERED round whose latency exceeds the effective deadline
    upgrades to TIMEOUT; a DROP stays a DROP (an owner that never
    answered cannot answer late — and spends no epsilon, where a
    timeout does). With per-tick arrival instants ``times`` (shape
    (K,), non-decreasing), the effective deadline for round k tightens
    to ``min(deadline, times[k+1] - times[k])`` — the learner stops
    waiting when the next scheduled round arrives; the last round has
    no successor and keeps the policy deadline.
    """
    codes = jnp.asarray(codes, jnp.int8)
    lat = jnp.asarray(latencies, jnp.float32)
    if codes.shape != lat.shape:
        raise ValueError(f"{lat.shape[0] if lat.ndim else 0} latencies "
                         f"for {codes.shape[0]} fault codes")
    eff = jnp.full(lat.shape, deadline, jnp.float32)
    if times is not None:
        times = jnp.asarray(times, jnp.float32)
        if times.shape != lat.shape:
            raise ValueError(
                f"{times.shape} tick times for {lat.shape} latencies")
        gaps = jnp.concatenate(
            [times[1:] - times[:-1],
             jnp.full((1,), jnp.inf, jnp.float32)])
        eff = jnp.minimum(eff, gaps)
    late = (lat > eff) & (codes != DROP)
    return jnp.where(late, jnp.int8(TIMEOUT), codes)


def staleness_weight(ss: StalenessState, owner_idx, t,
                     policy: StalenessPolicy) -> jax.Array:
    """f32 ``decay**age`` inertia weight for a round at clock ``t``.

    ``age`` is the owner's rounds-since-last-grant at dispatch time —
    monotone between grants by construction (the clock only moves
    forward) and reset exactly when a round applies. Drivers only call
    this when ``policy.decay != 1.0`` (a traced multiply by 1.0 is NOT
    a bitwise no-op: it flushes signed zeros), so the default policy
    keeps the undecayed trace verbatim.
    """
    age = jnp.maximum(t - ss.last_grant[owner_idx], 0)
    return jnp.power(jnp.float32(policy.decay), age.astype(jnp.float32))


def staleness_tick(ss: StalenessState, owner_idx, t, *, is_retry, apply,
                   timed, policy: StalenessPolicy, active,
                   ticks) -> StalenessState:
    """Advance the runtime counters after a round (or a group).

    Works for a scalar owner or a (G,) group of DISTINCT owners (the
    conflict-free partition's invariant keeps every scatter disjoint).
    ``t`` is each round's clock position, ``active`` masks padded group
    slots, and ``ticks`` is the number of real rounds consumed — the
    clock advance (1 for the scalar drivers, sum(valid) for a group).

      * a masked retry burns one cooldown round;
      * a timeout with retry budget schedules ``2**min(backoff, cap)``
        cooldown rounds, bumps the exponent, spends one retry;
      * a granted round resets the exponent, refills the retry budget,
        and stamps ``last_grant`` (the only age reset).
    """
    n = ss.last_grant.shape[0]
    cd = ss.cooldown[owner_idx]
    bo = ss.backoff[owner_idx]
    rl = ss.retry_left[owner_idx]
    sched = timed & (rl > 0)
    cap = jnp.int32(policy.backoff_cap)
    new_cd = jnp.where(
        sched, jnp.left_shift(jnp.int32(1), jnp.minimum(bo, cap)),
        jnp.where(is_retry, cd - 1, cd))
    new_bo = jnp.where(sched, bo + 1,
                       jnp.where(apply, jnp.int32(0), bo))
    new_rl = jnp.where(sched, rl - 1,
                       jnp.where(apply, jnp.int32(policy.max_retries), rl))
    new_lg = jnp.where(apply, jnp.asarray(t, jnp.int32),
                       ss.last_grant[owner_idx])
    idx = jnp.where(active, owner_idx, n)
    return StalenessState(
        clock=ss.clock + jnp.asarray(ticks, jnp.int32),
        last_grant=ss.last_grant.at[idx].set(new_lg, mode="drop"),
        cooldown=ss.cooldown.at[idx].set(new_cd, mode="drop"),
        backoff=ss.backoff.at[idx].set(new_bo, mode="drop"),
        retry_left=ss.retry_left.at[idx].set(new_rl, mode="drop"))


def as_tick_times(times, k: Optional[int] = None) -> jax.Array:
    """Validate + coerce a per-round arrival-instant vector.

    Host-side checks (skipped for tracers, mirroring ``as_owner_seq``):
    1-D float times, length matching the dispatch when ``k`` is given,
    finite and non-decreasing — the latency model reads inter-tick gaps
    as deadlines, and a time machine would mint negative deadlines.
    """
    times = jnp.asarray(times, jnp.float32)
    if times.ndim != 1:
        raise ValueError(f"tick times must be 1-D, got shape {times.shape}")
    if k is not None and times.shape[0] != k:
        raise ValueError(
            f"{times.shape[0]} tick times for a {k}-round dispatch")
    if isinstance(times, jax.core.Tracer):
        return times
    arr = jax.device_get(times)
    if arr.size and not np.isfinite(arr).all():
        raise ValueError("tick times must be finite")
    if arr.size > 1 and (np.diff(arr) < 0).any():
        raise ValueError("tick times must be non-decreasing")
    return times


__all__ = [
    "STALE_SALT", "LatencyPlan", "StalenessPolicy", "StalenessState",
    "init_staleness_state", "deadline_guard", "merge_timeout_codes",
    "staleness_weight", "staleness_tick", "as_tick_times",
]
