"""Step builders: (arch x shape x mesh) -> jittable step + shardings.

train  -> the paper's async-DP step (AsyncDPTrainer, owner bank in state)
prefill-> full-sequence forward, last-position logits
decode -> one-token serve_step against the KV/SSM cache
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeConfig
from repro.federation.deep import (AsyncDPConfig, init_state,
                                   make_train_step)
from repro.federation.dp_sgd import PrivatizerConfig
from repro.launch import specs as specs_mod
from repro.models.model import LM, build_model
from repro.sharding import rules


@dataclasses.dataclass
class StepBundle:
    step: Callable                 # the function to jit
    args: Tuple[Any, ...]          # ShapeDtypeStruct pytrees, in order
    in_shardings: Tuple[Any, ...]
    donate_argnums: Tuple[int, ...]
    kind: str


def default_async_cfg(n_owners: int = 4, horizon: int = 1000,
                      n_microbatches: int = 8, xi: float = 1.0,
                      pre_grouped: bool = True) -> AsyncDPConfig:
    return AsyncDPConfig(
        n_owners=n_owners, horizon=horizon, rho=1.0, sigma=1e-4,
        epsilons=tuple([1.0] * n_owners),
        owner_sizes=tuple([1_000_000] * n_owners), xi=xi, theta_max=100.0,
        privatizer=PrivatizerConfig(xi=xi, granularity="microbatch",
                                    n_microbatches=n_microbatches,
                                    pre_grouped=pre_grouped))


def _replicated(mesh):
    return NamedSharding(mesh, P())


def build_train_step(cfg: ModelConfig, shape: ShapeConfig, mesh, *,
                     model: Optional[LM] = None,
                     async_cfg: Optional[AsyncDPConfig] = None,
                     dtype=jnp.bfloat16) -> StepBundle:
    model = model or build_model(cfg)
    acfg = async_cfg or default_async_cfg()
    w = specs_mod.effective_window(cfg, shape)

    def loss_fn(params, batch):
        return model.loss(params, batch, window=w)[0]

    raw_step = make_train_step(loss_fn, acfg)

    def step(state, batch, owner_idx, noise_key):
        key = jax.random.wrap_key_data(noise_key, impl="threefry2x32")
        return raw_step(state, batch, owner_idx, key)

    mb = (acfg.privatizer.n_microbatches
          if acfg.privatizer.pre_grouped
          and acfg.privatizer.granularity == "microbatch" else 0)
    p_sds = specs_mod.params_specs(model, dtype)
    state_sds = jax.eval_shape(lambda p: init_state(p, acfg), p_sds)
    batch_sds = specs_mod.train_batch_specs(cfg, shape, microbatches=mb)

    p_spec = rules.param_specs(p_sds, cfg, mesh)
    bank_spec = rules.param_specs(
        jax.tree_util.tree_map(lambda leaf: jax.ShapeDtypeStruct(
            (acfg.n_owners,) + leaf.shape, leaf.dtype), p_sds),
        cfg, mesh, bank_axis=True)
    state_spec = type(state_sds)(theta_L=p_spec, bank=bank_spec, step=P())
    b_spec = rules.batch_specs(batch_sds, shape, mesh, microbatches=mb)

    def sh(t):
        return jax.tree_util.tree_map(
            lambda s: NamedSharding(mesh, s), t,
            is_leaf=lambda x: isinstance(x, P))
    return StepBundle(
        step=step,
        args=(state_sds, batch_sds, jax.ShapeDtypeStruct((), jnp.int32),
              jax.ShapeDtypeStruct((2,), jnp.uint32)),
        in_shardings=(sh(state_spec), sh(b_spec), _replicated(mesh),
                      _replicated(mesh)),
        donate_argnums=(0,),
        kind="train")


def build_prefill_step(cfg: ModelConfig, shape: ShapeConfig, mesh, *,
                       model: Optional[LM] = None,
                       dtype=jnp.bfloat16) -> StepBundle:
    model = model or build_model(cfg)
    w = specs_mod.effective_window(cfg, shape)

    def step(params, batch):
        x, _ = model.forward(params, batch, window=w)
        logits = jnp.einsum("bd,dv->bv", x[:, -1],
                            model._unembed(params))
        return logits

    p_sds = specs_mod.params_specs(model, dtype)
    batch_sds = specs_mod.train_batch_specs(cfg, shape, with_labels=False)
    p_spec = rules.param_specs(p_sds, cfg, mesh)
    b_spec = rules.batch_specs(batch_sds, shape, mesh)
    def sh(t):
        return jax.tree_util.tree_map(
            lambda s: NamedSharding(mesh, s), t,
            is_leaf=lambda x: isinstance(x, P))
    return StepBundle(step, (p_sds, batch_sds),
                      (sh(p_spec), sh(b_spec)), (), "prefill")


def build_serve_step(cfg: ModelConfig, shape: ShapeConfig, mesh, *,
                     model: Optional[LM] = None,
                     dtype=jnp.bfloat16) -> StepBundle:
    model = model or build_model(cfg)
    w = specs_mod.effective_window(cfg, shape)

    def step(params, cache, tokens, pos):
        return model.decode_step(params, cache, tokens, pos, window=w)

    p_sds = specs_mod.params_specs(model, dtype)
    cache_sds = specs_mod.cache_specs_struct(model, shape, dtype)
    tok_sds, pos_sds = specs_mod.decode_input_specs(cfg, shape)

    p_spec = rules.param_specs(p_sds, cfg, mesh)
    c_spec = rules.cache_specs(cache_sds, cfg, mesh, shape.global_batch)
    da = rules.data_axes(mesh)
    B = shape.global_batch
    tok_spec = P(da, None) if B % rules.axis_size(mesh, da) == 0 else P(None, None)

    def sh(t):
        return jax.tree_util.tree_map(
            lambda s: NamedSharding(mesh, s), t,
            is_leaf=lambda x: isinstance(x, P))
    return StepBundle(step, (p_sds, cache_sds, tok_sds, pos_sds),
                      (sh(p_spec), sh(c_spec), NamedSharding(mesh, tok_spec),
                       _replicated(mesh)),
                      (1,), "decode")


def build_step(cfg: ModelConfig, shape: ShapeConfig, mesh, *,
               n_microbatches: int = 8, model_kw: Optional[dict] = None,
               **kw) -> StepBundle:
    """model_kw: LM construction knobs (remat_groups, moe_mode, kv_chunk...)
    — the §Perf hillclimb surface."""
    model = build_model(cfg, **(model_kw or {}))
    if shape.kind == "train":
        return build_train_step(
            cfg, shape, mesh, model=model,
            async_cfg=kw.pop("async_cfg", None)
            or default_async_cfg(n_microbatches=n_microbatches), **kw)
    if shape.kind == "prefill":
        return build_prefill_step(cfg, shape, mesh, model=model, **kw)
    return build_serve_step(cfg, shape, mesh, model=model, **kw)
