"""Batched serving driver: greedy decode with KV/SSM caches.

    PYTHONPATH=src python -m repro.launch.serve --arch zamba2-2.7b \
        --batch 4 --prompt-len 16 --gen 32 --reduced

Serving is DP-free: the trained model is the eps-DP artifact
(post-processing invariance); the serving runtime here is the same
decode_step the decode-shape dry-runs lower at pod scale.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models import build_model


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="yi-6b")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--window", type=int, default=None)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    model = build_model(cfg, remat=False, moe_mode="ragged")
    key = jax.random.PRNGKey(args.seed)
    key, k_init, k_frames, k_prompt = jax.random.split(key, 4)
    params = model.init(k_init, jnp.float32)

    B = args.batch
    total = args.prompt_len + args.gen
    cache = model.init_cache(B, total, window=args.window, dtype=jnp.float32)
    if cfg.family == "audio":
        frames = jax.random.normal(k_frames, (B, cfg.enc_seq, cfg.d_model))
        cache = model.prime_cross_cache(params, cache, frames)

    prompt = jax.random.randint(k_prompt, (B, args.prompt_len), 0, cfg.vocab)
    step = jax.jit(
        lambda p, c, t, pos: model.decode_step(p, c, t, pos,
                                               window=args.window))

    toks = prompt[:, :1]
    out = [toks]
    t0 = time.time()
    for t in range(total - 1):
        logits, cache = step(params, cache, toks, jnp.int32(t))
        if t + 1 < args.prompt_len:
            toks = prompt[:, t + 1:t + 2]
        else:
            toks = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
        out.append(toks)
    dt = time.time() - t0
    seqs = np.asarray(jnp.concatenate(out, axis=1))
    print(f"arch={cfg.name} decoded {B}x{total} tokens in {dt:.2f}s "
          f"({B*total/dt:.1f} tok/s)")
    print("first sequence:", seqs[0][:40], "...")
    return seqs


if __name__ == "__main__":
    main()
