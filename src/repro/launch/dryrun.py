import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST be the first lines: jax locks the device count on first init.
# The 512 placeholder host devices exist ONLY for this dry-run entrypoint.

"""Multi-pod dry-run: lower + compile every (arch x input-shape x mesh)
combination against ShapeDtypeStruct inputs — proves the distribution
config is coherent without hardware, and extracts the roofline terms.

    PYTHONPATH=src python -m repro.launch.dryrun --arch yi-6b --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both

Results land in results/dryrun/<arch>__<shape>__<mesh>.json and are read by
benchmarks/roofline and EXPERIMENTS.md §Dry-run / §Roofline.
"""
import argparse
import json
import time
import traceback

import jax

from repro.analysis.hlo_cost import analyze as hlo_analyze
from repro.analysis.roofline import model_flops, roofline_terms
from repro.configs import INPUT_SHAPES, get_config, list_archs
from repro.launch.mesh import make_production_mesh
from repro.launch.steps import build_step


def run_one(arch: str, shape_name: str, multi_pod: bool, out_dir: str,
            skip_existing: bool = False, variant: str = "",
            step_kw: dict = None) -> dict:
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    tag = f"{arch}__{shape_name}__{mesh_name}"
    if variant:
        tag += f"__{variant}"
    path = os.path.join(out_dir, tag + ".json")
    if skip_existing and os.path.exists(path):
        with open(path) as f:
            return json.load(f)
    cfg = get_config(arch)
    shape = INPUT_SHAPES[shape_name]
    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_name, "ok": False,
           "variant": variant, "step_kw": {
               k: v for k, v in (step_kw or {}).items()}}
    try:
        mesh = make_production_mesh(multi_pod=multi_pod)
        chips = mesh.size
        bundle = build_step(cfg, shape, mesh, **(step_kw or {}))
        jitted = jax.jit(bundle.step, in_shardings=bundle.in_shardings,
                         donate_argnums=bundle.donate_argnums)
        t0 = time.time()
        with mesh:
            lowered = jitted.lower(*bundle.args)
            t1 = time.time()
            compiled = lowered.compile()
            t2 = time.time()
        rec["lower_s"] = round(t1 - t0, 2)
        rec["compile_s"] = round(t2 - t1, 2)

        mem = None
        try:
            ma = compiled.memory_analysis()
            if ma is not None:
                mem = {k: int(getattr(ma, k)) for k in (
                    "argument_size_in_bytes", "output_size_in_bytes",
                    "temp_size_in_bytes", "generated_code_size_in_bytes")
                    if hasattr(ma, k)}
        except Exception as e:  # CPU backend may not implement it
            mem = {"error": str(e)}
        rec["memory_analysis"] = mem
        print(f"[{tag}] memory_analysis: {mem}")

        ca = compiled.cost_analysis() or {}
        rec["cost_analysis_xla"] = {
            "flops": float(ca.get("flops", 0.0)),
            "bytes_accessed": float(ca.get("bytes accessed", 0.0)),
            "note": "XLA-CPU counts while bodies once; see hlo_walker",
        }

        # loop-aware per-device cost from the post-optimization HLO text
        hlo = compiled.as_text()
        try:
            import zstandard
            os.makedirs(os.path.join(out_dir, "hlo"), exist_ok=True)
            with open(os.path.join(out_dir, "hlo", tag + ".hlo.zst"),
                      "wb") as f:
                f.write(zstandard.ZstdCompressor(level=6)
                        .compress(hlo.encode()))
        except Exception:
            pass
        walked = hlo_analyze(hlo)
        rec["hlo_walker"] = walked
        flops = walked["flops"]
        byts = walked["traffic_bytes"]
        coll_total = walked["collective_bytes_total"]
        print(f"[{tag}] walker: flops={flops:.3e} traffic={byts:.3e} "
              f"coll={coll_total:.3e}")

        terms = roofline_terms(flops, byts, coll_total)
        tokens = shape.global_batch * (shape.seq_len if shape.kind != "decode"
                                       else 1)
        mf = model_flops(cfg.active_param_count(), tokens,
                         "train" if shape.kind == "train" else "infer")
        terms["model_flops_total"] = mf
        terms["hlo_flops_total"] = flops * chips
        terms["useful_flops_ratio"] = (mf / (flops * chips)
                                       if flops else 0.0)
        rec["roofline"] = terms
        rec["chips"] = chips
        rec["params"] = cfg.param_count()
        rec["active_params"] = cfg.active_param_count()
        rec["ok"] = True
        print(f"[{tag}] roofline: {terms}")
    except Exception as e:
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-4000:]
        print(f"[{tag}] FAILED: {rec['error']}")
    os.makedirs(out_dir, exist_ok=True)
    with open(path, "w") as f:
        json.dump(rec, f, indent=1)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=list_archs())
    ap.add_argument("--shape", choices=sorted(INPUT_SHAPES))
    ap.add_argument("--mesh", choices=["single", "multi", "both"],
                    default="single")
    ap.add_argument("--all", action="store_true",
                    help="run every (arch x shape)")
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--variant", default="",
                    help="tag suffix for §Perf A/B runs")
    ap.add_argument("--remat-groups", type=int, default=0)
    ap.add_argument("--microbatches", type=int, default=8)
    ap.add_argument("--moe-mode", default="onehot",
                    choices=["onehot", "ragged"])
    ap.add_argument("--moe-group-tokens", type=int, default=512)
    ap.add_argument("--kv-chunk", type=int, default=1024)
    args = ap.parse_args()

    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]
    archs = list_archs() if args.all or not args.arch else [args.arch]
    shapes = sorted(INPUT_SHAPES) if args.all or not args.shape else [args.shape]
    step_kw = {"n_microbatches": args.microbatches,
               "model_kw": {"remat_groups": args.remat_groups,
                            "moe_mode": args.moe_mode,
                            "moe_group_tokens": args.moe_group_tokens,
                            "kv_chunk": args.kv_chunk}}

    n_ok = n_fail = 0
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                rec = run_one(arch, shape, mp, args.out,
                              skip_existing=args.skip_existing,
                              variant=args.variant, step_kw=step_kw)
                n_ok += rec["ok"]
                n_fail += not rec["ok"]
    print(f"\ndry-run complete: {n_ok} ok, {n_fail} failed")
    raise SystemExit(1 if n_fail else 0)


if __name__ == "__main__":
    main()
