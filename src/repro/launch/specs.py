"""ShapeDtypeStruct stand-ins for every model input — shardable, weak-type
correct, zero allocation. The dry-run lowers against these.

Modality carve-out: for [audio]/[vlm] archs the stubbed frontend's outputs
(frame/patch embeddings) appear here as inputs of the right shape.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models.model import LM

SDS = jax.ShapeDtypeStruct


def effective_window(cfg: ModelConfig, shape: ShapeConfig) -> Optional[int]:
    """Sliding window in effect for this (arch, shape).

    long_500k on archs with full attention uses the documented SWA override;
    otherwise the arch's native window (mixtral) or None.
    """
    if shape.name == "long_500k" and cfg.long_context_override:
        return cfg.long_context_override
    return cfg.sliding_window


def train_batch_specs(cfg: ModelConfig, shape: ShapeConfig,
                      with_labels: bool = True,
                      microbatches: int = 0) -> Dict[str, SDS]:
    """microbatches > 0: microbatch-major layout (G, B/G, ...) — keeps the
    DP microbatch scan shard-aligned on multi-pod meshes (§Perf iter. 11)."""
    B, S = shape.global_batch, shape.seq_len
    lead = ((microbatches, B // microbatches) if microbatches else (B,))
    specs: Dict[str, SDS] = {}
    s_txt = S - (cfg.n_patches if cfg.family == "vlm" else 0)
    specs["tokens"] = SDS(lead + (s_txt,), jnp.int32)
    if with_labels:
        specs["labels"] = SDS(lead + (s_txt,), jnp.int32)
    if cfg.family == "vlm":
        specs["patches"] = SDS(lead + (cfg.n_patches, cfg.d_model),
                               jnp.bfloat16)
    if cfg.family == "audio":
        specs["frames"] = SDS(lead + (cfg.enc_seq, cfg.d_model),
                              jnp.bfloat16)
    return specs


def params_specs(model: LM, dtype=jnp.bfloat16) -> Any:
    # abstract key: eval_shape never materializes randomness, so no
    # concrete seed belongs here (dpcheck DPC103)
    key_spec = SDS((2,), jnp.uint32)
    return jax.eval_shape(lambda k: model.init(k, dtype), key_spec)


def cache_specs_struct(model: LM, shape: ShapeConfig,
                       dtype=jnp.bfloat16) -> Any:
    w = effective_window(model.cfg, shape)
    return jax.eval_shape(
        lambda: model.init_cache(shape.global_batch, shape.seq_len,
                                 window=w, dtype=dtype))


def decode_input_specs(cfg: ModelConfig, shape: ShapeConfig
                       ) -> Tuple[SDS, SDS]:
    B = shape.global_batch
    return SDS((B, 1), jnp.int32), SDS((), jnp.int32)


def input_specs(cfg: ModelConfig, shape: ShapeConfig, model: LM) -> Dict:
    """Everything the lowered step consumes, by shape kind."""
    if shape.kind == "train":
        return {"batch": train_batch_specs(cfg, shape),
                "owner_idx": SDS((), jnp.int32),
                "noise_key": SDS((2,), jnp.uint32)}
    if shape.kind == "prefill":
        return {"batch": train_batch_specs(cfg, shape, with_labels=False)}
    if shape.kind == "decode":
        toks, pos = decode_input_specs(cfg, shape)
        return {"cache": cache_specs_struct(model, shape),
                "tokens": toks, "pos": pos}
    raise ValueError(shape.kind)
