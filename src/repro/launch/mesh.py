"""Production mesh builders.

Target: TPU v5e pods — 256 chips/pod arranged (data=16, model=16);
multi-pod doubles with a leading 'pod' axis (2, 16, 16) = 512 chips.
A FUNCTION (not a module-level constant) so importing never touches jax
device state.
"""
from __future__ import annotations

import jax
import numpy as np


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    need = int(np.prod(shape))
    devs = jax.devices()
    if len(devs) == need:
        return jax.make_mesh(shape, axes)
    if len(devs) > need:   # e.g. single-pod mesh on a 512-device dry-run host
        return jax.make_mesh(shape, axes, devices=devs[:need])
    raise RuntimeError(
        f"need {need} devices for mesh {shape}, have {len(devs)} — "
        "run under launch/dryrun.py (it forces 512 host devices)")


def make_debug_mesh(data: int = 1, model: int = 1):
    """Tiny mesh over however many devices the host actually has."""
    devs = jax.devices()[: data * model]
    return jax.make_mesh((data, model), ("data", "model"), devices=devs)


def make_host_mesh(*, model: int = 1):
    """(data, model) mesh over ALL visible devices: data = n_devices/model.

    The topology builder for the sharded federation engine off-pod: on a
    laptop it is a 1x1 mesh (the sharded code paths run but every spec
    degrades to replication); under XLA_FLAGS=--xla_force_host_platform_
    device_count=8 (the CI smoke job) it is a real 8-way mesh. `model`
    must divide the device count."""
    n = len(jax.devices())
    if n % model:
        raise ValueError(f"model={model} does not divide {n} devices")
    return make_debug_mesh(n // model, model)
