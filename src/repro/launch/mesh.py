"""Production mesh builders.

Target: TPU v5e pods — 256 chips/pod arranged (data=16, model=16);
multi-pod doubles with a leading 'pod' axis (2, 16, 16) = 512 chips.
A FUNCTION (not a module-level constant) so importing never touches jax
device state.
"""
from __future__ import annotations

import numpy as np
import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    need = int(np.prod(shape))
    devs = jax.devices()
    if len(devs) == need:
        return jax.make_mesh(shape, axes)
    if len(devs) > need:   # e.g. single-pod mesh on a 512-device dry-run host
        return jax.make_mesh(shape, axes, devices=devs[:need])
    raise RuntimeError(
        f"need {need} devices for mesh {shape}, have {len(devs)} — "
        "run under launch/dryrun.py (it forces 512 host devices)")


def make_debug_mesh(data: int = 1, model: int = 1):
    """Tiny mesh over however many devices the host actually has."""
    devs = jax.devices()[: data * model]
    return jax.make_mesh((data, model), ("data", "model"), devices=devs)
