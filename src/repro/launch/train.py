"""Runnable async-DP training driver (CPU-scale; same code path the pod
dry-run lowers).

    PYTHONPATH=src python -m repro.launch.train --arch yi-6b --steps 20 \
        --owners 4 --eps 1.0 --reduced

Runs Algorithm 1 over owner-sharded synthetic token data: uniform owner
schedule (== rate-1 Poisson clocks), per-owner Theorem-1 Laplace noise,
inertia updates, owner-copy bank, checkpointing.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import save_checkpoint
from repro.configs import get_config
from repro.data import OwnerDataPipeline, synthetic_owner_shards
from repro.federation.deep import (AsyncDPConfig, init_state,
                                   make_train_step)
from repro.federation.dp_sgd import PrivatizerConfig
from repro.federation.privacy import PrivacyAccountant
from repro.models import build_model


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="yi-6b")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--owners", type=int, default=4)
    ap.add_argument("--records", type=int, default=1024,
                    help="records per owner")
    ap.add_argument("--eps", type=float, default=1.0)
    ap.add_argument("--xi", type=float, default=1.0)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--horizon", type=int, default=1000)
    ap.add_argument("--lr-scale", type=float, default=100.0,
                    help="practical-rate override (1.0 = paper-faithful)")
    ap.add_argument("--sigma", type=float, default=1e-2)
    ap.add_argument("--granularity", default="example",
                    choices=["example", "microbatch"])
    ap.add_argument("--composition", default="paper",
                    choices=["paper", "per_owner_rounds"])
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    model = build_model(cfg, remat=False, moe_mode="ragged")
    key, init_key = jax.random.split(jax.random.PRNGKey(args.seed))
    params = model.init(init_key, jnp.float32)
    n_params = sum(np.prod(leaf.shape) for leaf in jax.tree_util.tree_leaves(params))
    print(f"arch={cfg.name} family={cfg.family} params={n_params/1e6:.1f}M "
          f"owners={args.owners}")

    shards = synthetic_owner_shards(args.owners, args.records, args.seq,
                                    cfg.vocab, seed=args.seed)
    pipe = OwnerDataPipeline(shards, args.batch, seed=args.seed)
    acct = PrivacyAccountant({i: args.eps for i in range(args.owners)},
                             args.horizon, composition=args.composition,
                             n_owners=args.owners)

    acfg = AsyncDPConfig(
        n_owners=args.owners, horizon=args.horizon, rho=1.0, sigma=args.sigma,
        epsilons=tuple([args.eps] * args.owners),
        owner_sizes=tuple(pipe.owner_sizes), xi=args.xi, theta_max=100.0,
        privatizer=PrivatizerConfig(xi=args.xi,
                                    granularity=args.granularity,
                                    n_microbatches=min(4, args.batch)),
        lr_scale=args.lr_scale)

    def loss_fn(p, b):
        return model.loss(p, b)[0]

    step_fn = jax.jit(make_train_step(loss_fn, acfg), donate_argnums=0)
    state = init_state(params, acfg)

    it = iter(pipe)
    t0 = time.time()
    for k in range(1, args.steps + 1):
        owner, batch = next(it)
        if not acct.record_response(owner):
            print(f"step {k}: owner {owner} budget exhausted — skipping")
            continue
        batch = {k2: jnp.asarray(v) for k2, v in batch.items()}
        key, sub = jax.random.split(key)
        state, metrics = step_fn(state, batch, jnp.int32(owner), sub)
        if k % max(1, args.steps // 10) == 0 or k == 1:
            loss = float(loss_fn(state.theta_L, batch))
            print(f"step {k:4d} owner={owner} loss={loss:.4f} "
                  f"clip_frac={float(metrics['clip_frac']):.2f} "
                  f"noise_scale={float(metrics['grad_noise_scale']):.2e} "
                  f"({time.time()-t0:.1f}s)")
    print("privacy ledger:", acct.summary())
    if args.ckpt_dir:
        path = save_checkpoint(args.ckpt_dir, args.steps, state)
        print("checkpoint:", path)
    return state


if __name__ == "__main__":
    main()
