from repro.checkpoint.store import (latest_step, load_checkpoint,
                                    load_manifest, save_checkpoint)

__all__ = ["latest_step", "load_checkpoint", "load_manifest",
           "save_checkpoint"]
