from repro.checkpoint.store import (MemmapRowStore, MemoryRowStore,
                                    latest_step, load_aux_arrays,
                                    load_checkpoint, load_manifest,
                                    save_checkpoint)

__all__ = ["MemmapRowStore", "MemoryRowStore", "latest_step",
           "load_aux_arrays", "load_checkpoint", "load_manifest",
           "save_checkpoint"]
