"""Checkpointing: pytree -> npz shards + msgpack manifest (no orbax here).

Layout:  <dir>/step_<k>/arrays.npz  +  <dir>/step_<k>/manifest.msgpack
The manifest stores the treedef (as path strings) and dtypes so arbitrary
nested dict/NamedTuple states round-trip. NamedTuples are stored as dicts
with a '__namedtuple__' marker and rebuilt on load when the caller passes
`like=` (a template pytree) — otherwise plain dicts come back.
"""
from __future__ import annotations

import os
from typing import Any, Optional

import jax
import jax.numpy as jnp
import msgpack
import numpy as np


def _flatten_with_paths(tree):
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", getattr(p, "name", p))))
                       for p in path)
        out[key] = np.asarray(leaf)
    return out


def save_checkpoint(directory: str, step: int, state: Any) -> str:
    d = os.path.join(directory, f"step_{step:08d}")
    os.makedirs(d, exist_ok=True)
    arrays = _flatten_with_paths(state)
    np.savez(os.path.join(d, "arrays.npz"),
             **{k.replace("/", "__SL__"): v for k, v in arrays.items()})
    manifest = {"step": step,
                "keys": list(arrays.keys()),
                "dtypes": {k: str(v.dtype) for k, v in arrays.items()},
                "shapes": {k: list(v.shape) for k, v in arrays.items()}}
    with open(os.path.join(d, "manifest.msgpack"), "wb") as f:
        f.write(msgpack.packb(manifest))
    return d


def latest_step(directory: str) -> Optional[int]:
    if not os.path.isdir(directory):
        return None
    steps = [int(n.split("_")[1]) for n in os.listdir(directory)
             if n.startswith("step_")]
    return max(steps) if steps else None


def load_checkpoint(directory: str, step: int, like: Any) -> Any:
    """Restore into the structure of `like` (shapes/dtypes validated)."""
    d = os.path.join(directory, f"step_{step:08d}")
    data = np.load(os.path.join(d, "arrays.npz"))
    arrays = {k.replace("__SL__", "/"): data[k] for k in data.files}
    flat, treedef = jax.tree_util.tree_flatten_with_path(like)
    leaves = []
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", getattr(p, "name", p))))
                       for p in path)
        if key not in arrays:
            raise KeyError(f"checkpoint missing leaf {key}")
        arr = arrays[key]
        if tuple(arr.shape) != tuple(leaf.shape):
            raise ValueError(f"{key}: shape {arr.shape} != {leaf.shape}")
        leaves.append(jnp.asarray(arr, dtype=leaf.dtype))
    return jax.tree_util.tree_unflatten(treedef, leaves)
