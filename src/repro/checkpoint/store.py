"""Checkpointing: pytree -> npz shards + msgpack manifest (no orbax here).

Layout:  <dir>/step_<k>/arrays.npz  +  <dir>/step_<k>/manifest.msgpack
The manifest stores the treedef (as path strings) and dtypes so arbitrary
nested dict/NamedTuple states round-trip. NamedTuples are stored as dicts
with a '__namedtuple__' marker and rebuilt on load when the caller passes
`like=` (a template pytree) — otherwise plain dicts come back.

Saves are ATOMIC: the shard is written to a temp sibling and os.rename'd
into `step_<k>`, so a crash mid-save never leaves a half-written shard
that `latest_step` would resume from (rename is atomic on POSIX; the
temp/backup names never match the `step_` prefix, so a leftover from a
crash between the two renames is invisible to `latest_step`).

Extended dtypes (bfloat16, float8_*) are stored as raw bit patterns
(np.load would otherwise hand back opaque void scalars): the npz holds a
uint view of the buffer and the manifest records the logical dtype, which
the loader views back before casting into the template's dtype.
"""
from __future__ import annotations

import os
import shutil
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import msgpack
import numpy as np


def _flatten_with_paths(tree):
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", getattr(p, "name", p))))
                       for p in path)
        out[key] = np.asarray(leaf)
    return out


def _storage_view(a: np.ndarray) -> np.ndarray:
    """A bit-identical view np.savez/np.load round-trips losslessly."""
    if a.dtype.kind in "biufc":
        return a
    # ml_dtypes arrays (bf16, fp8) come back from np.load as void
    # scalars — store the raw bits in a same-width uint view instead
    return a.view({1: np.uint8, 2: np.uint16, 4: np.uint32}[a.dtype.itemsize])


# npz namespace for save_checkpoint's aux arrays — keeps them out of the
# pytree-leaf keyspace so load_checkpoint never mistakes one for a leaf
_AUX_PREFIX = "__AUX__"


def save_checkpoint(directory: str, step: int, state: Any,
                    extra: Optional[Dict] = None,
                    aux_arrays: Optional[Dict[str, Any]] = None) -> str:
    """Atomically write `state` under <directory>/step_<k>.

    `extra` (msgpack-serializable dict) rides along in the manifest —
    e.g. a mechanism dispatch journal — and comes back via
    load_manifest()['extra']. `aux_arrays` (name -> array) are sidecar
    arrays that are NOT part of the state pytree — e.g. a paged
    session's cold-tier rows — stored in the SAME npz shard (so the
    atomic-rename guarantee covers them too) under a reserved prefix,
    and read back via load_aux_arrays()."""
    final = os.path.join(directory, f"step_{step:08d}")
    tmp = os.path.join(directory, f"_tmp_step_{step:08d}.{os.getpid()}")
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    arrays = _flatten_with_paths(state)
    payload = {k.replace("/", "__SL__"): _storage_view(v)
               for k, v in arrays.items()}
    aux = {k: np.asarray(v) for k, v in (aux_arrays or {}).items()}
    payload.update({_AUX_PREFIX + k.replace("/", "__SL__"):
                    _storage_view(v) for k, v in aux.items()})
    np.savez(os.path.join(tmp, "arrays.npz"), **payload)
    manifest = {"step": step,
                "keys": list(arrays.keys()),
                "dtypes": {k: str(v.dtype) for k, v in arrays.items()},
                "shapes": {k: list(v.shape) for k, v in arrays.items()}}
    if aux:
        manifest["aux_keys"] = list(aux.keys())
        manifest["aux_dtypes"] = {k: str(v.dtype) for k, v in aux.items()}
    if extra is not None:
        manifest["extra"] = extra
    with open(os.path.join(tmp, "manifest.msgpack"), "wb") as f:
        f.write(msgpack.packb(manifest))
    if os.path.isdir(final):
        # overwrite in two renames: demote the old shard out of the
        # step_ namespace first, so no moment exists where `final` is
        # half-written — a crash in between leaves the old shard gone
        # but the fully-written tmp shard on disk, never a torn one
        trash = os.path.join(directory, f"_old_step_{step:08d}.{os.getpid()}")
        if os.path.exists(trash):
            shutil.rmtree(trash)
        os.rename(final, trash)
        os.rename(tmp, final)
        shutil.rmtree(trash)
    else:
        os.rename(tmp, final)
    return final


def latest_step(directory: str) -> Optional[int]:
    if not os.path.isdir(directory):
        return None
    steps = []
    for n in os.listdir(directory):
        if not n.startswith("step_"):
            continue        # skips _tmp_step_* / _old_step_* leftovers
        try:
            steps.append(int(n.split("_")[1]))
        except ValueError:
            continue        # stray non-checkpoint entry
    return max(steps) if steps else None


def load_manifest(directory: str, step: int) -> Dict:
    d = os.path.join(directory, f"step_{step:08d}")
    with open(os.path.join(d, "manifest.msgpack"), "rb") as f:
        return msgpack.unpackb(f.read())


def load_aux_arrays(directory: str, step: int) -> Dict[str, np.ndarray]:
    """The sidecar arrays a save_checkpoint(aux_arrays=...) stored —
    {} for checkpoints saved without any. Extended dtypes view back
    through the logical dtype recorded in the manifest, bit-exact."""
    d = os.path.join(directory, f"step_{step:08d}")
    manifest = load_manifest(directory, step)
    data = np.load(os.path.join(d, "arrays.npz"))
    dtypes = manifest.get("aux_dtypes") or {}
    out: Dict[str, np.ndarray] = {}
    for k in manifest.get("aux_keys") or []:
        arr = data[_AUX_PREFIX + k.replace("/", "__SL__")]
        logical = dtypes.get(k)
        if logical is not None and logical != str(arr.dtype):
            import ml_dtypes  # noqa: F401
            arr = arr.view(np.dtype(logical))
        out[k] = arr
    return out


# --------------------- per-row cold-tier stores -----------------------------
# Backing tier for the paged owner bank (repro.federation.paging): a row
# store holds one fixed-shape row per owner, supports PARTIAL read/write
# (only the rows a prefetch touches move), and reads never-written rows
# as a shared immutable `default` row — so a 10^5-owner bank costs O(rows
# actually trained) host memory/disk instead of materializing N*P at
# init. Round-trips are bit-exact for every storage dtype the bank uses
# (f32/bf16/int8/fp8 via the same raw-bit views the checkpoints use).


class MemoryRowStore:
    """Dict-backed row store: rows live host-side as numpy copies."""

    def __init__(self, n_rows: int, row_shape, dtype, default: np.ndarray):
        default = np.asarray(default)
        if tuple(default.shape) != tuple(row_shape):
            raise ValueError(f"default row shape {default.shape} != "
                             f"{tuple(row_shape)}")
        self.n_rows = int(n_rows)
        self.row_shape = tuple(row_shape)
        self.dtype = np.dtype(dtype) if np.dtype(dtype).kind in "biufc" \
            else default.dtype
        self._default = np.ascontiguousarray(default)
        self._default.setflags(write=False)
        self._rows: Dict[int, np.ndarray] = {}

    def __len__(self) -> int:
        return self.n_rows

    @property
    def written(self) -> int:
        """Rows that hold real (non-default) data."""
        return len(self._rows)

    @property
    def written_ids(self) -> np.ndarray:
        """Sorted (written,) int64 ids of rows holding real data — the
        exact set a checkpoint must persist (unwritten rows reconstruct
        from the default row for free)."""
        return np.asarray(sorted(self._rows), np.int64)

    def clear(self) -> None:
        """Forget every written row (all ids read as default again) —
        the restore path wipes post-checkpoint writes before replaying
        a snapshot."""
        self._rows.clear()

    def _check(self, ids: np.ndarray):
        if ids.size and (ids.min() < 0 or ids.max() >= self.n_rows):
            raise IndexError(
                f"row ids out of range for {self.n_rows}-row store")

    def read_rows(self, ids) -> np.ndarray:
        """(k, *row_shape) stacked rows; unwritten ids read as default."""
        ids = np.asarray(ids, np.int64).reshape(-1)
        self._check(ids)
        return np.stack([self._rows.get(int(i), self._default)
                         for i in ids]) if ids.size else np.zeros(
            (0,) + self.row_shape, self._default.dtype)

    def write_rows(self, ids, values) -> None:
        ids = np.asarray(ids, np.int64).reshape(-1)
        self._check(ids)
        values = np.asarray(values)
        if values.shape != (ids.size,) + self.row_shape:
            raise ValueError(f"values shape {values.shape} != "
                             f"{(ids.size,) + self.row_shape}")
        for j, i in enumerate(ids):
            self._rows[int(i)] = np.copy(values[j])


class MemmapRowStore:
    """Disk-backed row store on ``np.lib.format.open_memmap``.

    The data file is created lazily as a sparse (n_rows, *row_shape)
    .npy next to a written-row bitmap; unwritten rows read as the
    `default` row, so creating a million-owner store costs no real disk
    until rows are actually evicted to it. Extended dtypes (bf16/fp8)
    are stored through the same-width uint view `_storage_view` uses, so
    round-trips stay bit-exact.
    """

    def __init__(self, path: str, n_rows: int, row_shape, dtype,
                 default: np.ndarray):
        default = np.asarray(default)
        if tuple(default.shape) != tuple(row_shape):
            raise ValueError(f"default row shape {default.shape} != "
                             f"{tuple(row_shape)}")
        self.n_rows = int(n_rows)
        self.row_shape = tuple(row_shape)
        self._logical_dtype = default.dtype
        self._default = np.ascontiguousarray(default)
        self._default.setflags(write=False)
        os.makedirs(path, exist_ok=True)
        self._data_path = os.path.join(path, "rows.npy")
        store_view = _storage_view(self._default)
        self._store_dtype = store_view.dtype
        self._mm = np.lib.format.open_memmap(
            self._data_path, mode="w+",
            dtype=self._store_dtype, shape=(self.n_rows,) + self.row_shape)
        self._written = np.zeros((self.n_rows,), bool)

    def __len__(self) -> int:
        return self.n_rows

    @property
    def written(self) -> int:
        return int(self._written.sum())

    @property
    def written_ids(self) -> np.ndarray:
        """Sorted (written,) int64 ids of rows holding real data."""
        return np.flatnonzero(self._written).astype(np.int64)

    def clear(self) -> None:
        """Forget every written row (all ids read as default again);
        the sparse pages stay allocated but are no longer visible."""
        self._written[:] = False

    def _check(self, ids: np.ndarray):
        if ids.size and (ids.min() < 0 or ids.max() >= self.n_rows):
            raise IndexError(
                f"row ids out of range for {self.n_rows}-row store")

    def read_rows(self, ids) -> np.ndarray:
        ids = np.asarray(ids, np.int64).reshape(-1)
        self._check(ids)
        out = np.array(self._mm[ids])             # copy out of the map
        out = out.view(self._logical_dtype)
        unwritten = ~self._written[ids]
        if unwritten.any():
            out[unwritten] = self._default
        return out

    def write_rows(self, ids, values) -> None:
        ids = np.asarray(ids, np.int64).reshape(-1)
        self._check(ids)
        values = np.asarray(values)
        if values.shape != (ids.size,) + self.row_shape:
            raise ValueError(f"values shape {values.shape} != "
                             f"{(ids.size,) + self.row_shape}")
        self._mm[ids] = _storage_view(np.ascontiguousarray(values))
        self._written[ids] = True

    def flush(self) -> None:
        self._mm.flush()


def load_checkpoint(directory: str, step: int, like: Any) -> Any:
    """Restore into the structure of `like` (shapes/dtypes validated)."""
    d = os.path.join(directory, f"step_{step:08d}")
    manifest = load_manifest(directory, step)
    data = np.load(os.path.join(d, "arrays.npz"))
    arrays = {k.replace("__SL__", "/"): data[k] for k in data.files
              if not k.startswith(_AUX_PREFIX)}
    flat, treedef = jax.tree_util.tree_flatten_with_path(like)
    leaves = []
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", getattr(p, "name", p))))
                       for p in path)
        if key not in arrays:
            raise KeyError(f"checkpoint missing leaf {key}")
        arr = arrays[key]
        logical = manifest["dtypes"].get(key)
        if logical is not None and logical != str(arr.dtype):
            # stored as raw bits — view back through the logical dtype
            # (ml_dtypes registers bf16/fp8 with numpy on import)
            import ml_dtypes  # noqa: F401
            arr = arr.view(np.dtype(logical))
        if tuple(arr.shape) != tuple(leaf.shape):
            raise ValueError(f"{key}: shape {arr.shape} != {leaf.shape}")
        leaves.append(jnp.asarray(arr, dtype=leaf.dtype))
    return jax.tree_util.tree_unflatten(treedef, leaves)
