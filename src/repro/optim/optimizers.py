"""Minimal optax-style optimizers in pure JAX (no optax in this container).

Each optimizer is (init_fn, update_fn):
    state = init_fn(params)
    updates, state = update_fn(grads, state, params, step)
    params = apply_updates(params, updates)

`inertia_sgd` is the paper's Algorithm-1 update rule expressed as an
optimizer transform: constant rate alpha = rho/T^2 scaled by N/sigma, plus
the l_inf projection. It is stateless — the *inertia* lives in the trainer
(the theta_bar blend), not here.
"""
from __future__ import annotations

from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

tmap = jax.tree_util.tree_map


class OptState(NamedTuple):
    mu: Any = None
    nu: Any = None
    count: jax.Array = None


def apply_updates(params, updates):
    return tmap(lambda p, u: (p.astype(jnp.float32) + u).astype(p.dtype),
                params, updates)


def sgd(lr: Callable[[jax.Array], jax.Array], momentum: float = 0.0):
    def init(params):
        mu = tmap(lambda p: jnp.zeros(p.shape, jnp.float32), params) \
            if momentum else None
        return OptState(mu=mu, count=jnp.zeros((), jnp.int32))

    def update(grads, state, params):
        del params
        if momentum:
            mu = tmap(lambda m, g: momentum * m + g.astype(jnp.float32),
                      state.mu, grads)
            upd = tmap(lambda m: -lr(state.count) * m, mu)
            return upd, OptState(mu=mu, count=state.count + 1)
        upd = tmap(lambda g: -lr(state.count) * g.astype(jnp.float32), grads)
        return upd, OptState(count=state.count + 1)

    return init, update


def adamw(lr: Callable[[jax.Array], jax.Array], b1=0.9, b2=0.95, eps=1e-8,
          weight_decay=0.0):
    def init(params):
        def z(p):
            return jnp.zeros(p.shape, jnp.float32)
        return OptState(mu=tmap(z, params), nu=tmap(z, params),
                        count=jnp.zeros((), jnp.int32))

    def update(grads, state, params):
        c = state.count + 1
        mu = tmap(lambda m, g: b1 * m + (1 - b1) * g.astype(jnp.float32),
                  state.mu, grads)
        nu = tmap(lambda v, g: b2 * v + (1 - b2)
                  * jnp.square(g.astype(jnp.float32)), state.nu, grads)
        mhat = tmap(lambda m: m / (1 - b1 ** c), mu)
        vhat = tmap(lambda v: v / (1 - b2 ** c), nu)
        upd = tmap(lambda m, v, p: -lr(state.count)
                   * (m / (jnp.sqrt(v) + eps)
                      + weight_decay * p.astype(jnp.float32)),
                   mhat, vhat, params)
        return upd, OptState(mu=mu, nu=nu, count=c)

    return init, update


def inertia_sgd(n_owners: int, horizon: int, rho: float, sigma: float,
                theta_max: float):
    """Algorithm 1's constant-rate projected step (owner-copy side, eq. 5)."""
    alpha = n_owners * rho / (horizon ** 2 * sigma)

    def init(params):
        return OptState(count=jnp.zeros((), jnp.int32))

    def update(grads, state, params):
        upd = tmap(lambda g, p: jnp.clip(
            p.astype(jnp.float32) - alpha * g.astype(jnp.float32),
            -theta_max, theta_max) - p.astype(jnp.float32), grads, params)
        return upd, OptState(count=state.count + 1)

    return init, update
