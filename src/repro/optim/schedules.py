"""Learning-rate schedules (step -> lr)."""
from __future__ import annotations

import jax.numpy as jnp


def constant(lr: float):
    return lambda step: jnp.asarray(lr, jnp.float32)


def linear_warmup(lr: float, warmup: int):
    def f(step):
        w = jnp.minimum(step.astype(jnp.float32) / max(warmup, 1), 1.0)
        return lr * w
    return f


def cosine_decay(lr: float, total: int, warmup: int = 0, floor: float = 0.0):
    def f(step):
        s = step.astype(jnp.float32)
        w = jnp.minimum(s / max(warmup, 1), 1.0) if warmup else 1.0
        t = jnp.clip((s - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = 0.5 * (1.0 + jnp.cos(jnp.pi * t))
        return w * (floor + (lr - floor) * cos)
    return f
