from repro.optim.optimizers import (OptState, adamw, apply_updates, inertia_sgd,
                                    sgd)
from repro.optim.schedules import constant, cosine_decay, linear_warmup
