"""Deprecated shim — gradient privatization moved to
``repro.federation.dp_sgd`` as part of the unified federation API. Import
from ``repro.federation`` instead; this module keeps the old names
importable."""
import warnings

from repro.federation.dp_sgd import (LossFn, PrivatizerConfig, clip_tree,
                                     private_grad)

warnings.warn(
    "repro.core.dp_sgd is a deprecated shim; import from repro.federation "
    "instead (it will be removed in a future PR)",
    DeprecationWarning, stacklevel=2)

__all__ = ["LossFn", "PrivatizerConfig", "clip_tree", "private_grad"]
