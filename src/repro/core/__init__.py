# The paper's primary contribution: asynchronous differentially-private
# collaborative learning (Algorithm 1 + Theorems 1-2) and its pod-scale
# adaptation (AsyncDPTrainer with a sharded owner-copy bank).
#
# Every name except the cop module's lives in repro.federation now; the
# submodules here are deprecated shims that warn on import. The package
# surface re-exports LAZILY (PEP 562) so `from repro.core import
# bound_asymptotic` — cop was never moved and has no federation
# replacement — does not trip six shim warnings for modules it never
# touches; accessing a MOVED name still imports its shim and warns.
from repro.core.cop import (bound_asymptotic, bound_theorem2, budget_sum,
                            fit_constants, min_owners_for_benefit)

_SHIMMED = {
    "algorithm1": ("Algo1Config", "Algo1Trace", "run_algorithm1",
                   "run_many"),
    "async_trainer": ("AsyncDPConfig", "AsyncDPState", "init_state",
                      "make_sync_dp_step", "make_train_step"),
    "clocks": ("Schedule", "poisson_schedule", "uniform_schedule"),
    "dp_sgd": ("PrivatizerConfig", "clip_tree", "private_grad"),
    "linear": ("LinearProblem", "Owner", "fitness", "make_problem",
               "owner_grad", "record_grad_bound", "relative_fitness"),
    "privacy": ("PrivacyAccountant", "capped_rounds", "laplace_noise",
                "laplace_noise_tree", "laplace_scale_theorem1"),
}
_NAME_TO_MODULE = {name: mod for mod, names in _SHIMMED.items()
                   for name in names}
__all__ = sorted(set(_NAME_TO_MODULE) | {
    "bound_asymptotic", "bound_theorem2", "budget_sum", "fit_constants",
    "min_owners_for_benefit"})


def __getattr__(name):
    import importlib
    if name in _SHIMMED:
        # the eager surface also bound the submodules themselves
        # (`repro.core.clocks.uniform_schedule` worked without importing
        # the submodule); keep that working — the import warns
        return importlib.import_module(f"repro.core.{name}")
    module = _NAME_TO_MODULE.get(name)
    if module is None:
        raise AttributeError(f"module 'repro.core' has no attribute "
                             f"{name!r}")
    return getattr(importlib.import_module(f"repro.core.{module}"), name)


def __dir__():
    return __all__
