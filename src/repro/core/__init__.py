# The paper's primary contribution: asynchronous differentially-private
# collaborative learning (Algorithm 1 + Theorems 1-2) and its pod-scale
# adaptation (AsyncDPTrainer with a sharded owner-copy bank).
from repro.core.algorithm1 import Algo1Config, Algo1Trace, run_algorithm1, run_many
from repro.core.async_trainer import (AsyncDPConfig, AsyncDPState, init_state,
                                      make_sync_dp_step, make_train_step)
from repro.core.clocks import Schedule, poisson_schedule, uniform_schedule
from repro.core.cop import (bound_asymptotic, bound_theorem2, budget_sum,
                            fit_constants, min_owners_for_benefit)
from repro.core.dp_sgd import PrivatizerConfig, clip_tree, private_grad
from repro.core.linear import (LinearProblem, Owner, fitness, make_problem,
                               owner_grad, record_grad_bound, relative_fitness)
from repro.core.privacy import (PrivacyAccountant, capped_rounds,
                                laplace_noise, laplace_noise_tree,
                                laplace_scale_theorem1)
