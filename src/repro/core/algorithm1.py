"""Algorithm 1 (paper-faithful): asynchronous DP learning, convex problems.

Per iteration k = 1..T (eqs. 5-7):
    i_k ~ Uniform{1..N}
    theta_bar = (theta_L + theta_{i_k}) / 2                       (6)
    Qbar     = Q_{i_k}(theta_bar) + Laplace(b_{i_k})              (4)
    theta_{i_k} = Proj[ theta_bar - (N rho / (T^2 sigma)) *
                        ( (1/2N) grad g(theta_bar) + (n_i/n) Qbar ) ]   (5)
    theta_L  = Proj[ theta_bar - ((N-1) rho / (N T^2 sigma)) grad g ]   (7)

Everything is a single jax.lax.scan; vmap over `run_algorithm1` gives the
100-run percentile statistics of Figs. 2/8 in seconds on CPU.
"""
from __future__ import annotations

import dataclasses
from typing import List, NamedTuple, Optional, Sequence

import jax
import jax.numpy as jnp

from repro.core.clocks import uniform_schedule
from repro.core.linear import (LinearProblem, Owner, owner_grad, reg_grad,
                               relative_fitness)
from repro.core.privacy import laplace_scale_theorem1


@dataclasses.dataclass(frozen=True)
class Algo1Config:
    horizon: int                 # T
    rho: float                   # step-size knob; alpha = rho / T^2
    sigma: float                 # strong-convexity modulus of g
    epsilons: Sequence[float]    # per-owner privacy budgets
    composition: str = "paper"   # 'paper' | 'per_owner_rounds' (beyond-paper)
    cap_slack: float = 2.0
    noiseless: bool = False      # eps -> inf (for cost-of-privacy deltas)


class Algo1Trace(NamedTuple):
    theta_L: jax.Array           # (p,) final central model
    psi: jax.Array               # (T,) relative fitness of theta_L over time
    owners_seq: jax.Array        # (T,) i_k sequence
    theta_bank: jax.Array        # (N, p) final owner copies


def run_algorithm1(key, prob: LinearProblem, owners: List[Owner],
                   cfg: Algo1Config) -> Algo1Trace:
    N = len(owners)
    p = prob.G.shape[0]
    T = cfg.horizon
    n = prob.n_total

    A = jnp.stack([o.A for o in owners])              # (N,p,p)
    b = jnp.stack([o.b for o in owners])              # (N,p)
    n_i = jnp.asarray([o.n for o in owners], jnp.float32)
    if cfg.composition == "per_owner_rounds":
        from repro.core.privacy import capped_rounds
        T_eff = capped_rounds(T, N, cfg.cap_slack)
    else:
        T_eff = T
    scales = jnp.asarray([
        0.0 if cfg.noiseless else
        laplace_scale_theorem1(o.xi, T_eff, o.n, e)
        for o, e in zip(owners, cfg.epsilons)], jnp.float32)

    k_sched, k_noise = jax.random.split(key)
    owners_seq = uniform_schedule(k_sched, N, T)
    noise_keys = jax.random.split(k_noise, T)

    lr_own = N * cfg.rho / (T ** 2 * cfg.sigma)
    lr_L = (N - 1) * cfg.rho / (N * T ** 2 * cfg.sigma)
    proj = lambda t: jnp.clip(t, -prob.theta_max, prob.theta_max)

    def step(carry, xs):
        theta_L, bank = carry
        i_k, nk = xs
        theta_i = bank[i_k]
        theta_bar = 0.5 * (theta_L + theta_i)                       # (6)
        q = 2.0 * (A[i_k] @ theta_bar - b[i_k])                     # (3)
        w = scales[i_k] * jax.random.laplace(nk, (p,))              # Thm 1
        qbar = q + w                                                # (4)
        gg = reg_grad(prob, theta_bar)
        new_i = proj(theta_bar - lr_own * (gg / (2 * N)
                                           + (n_i[i_k] / n) * qbar))  # (5)
        new_L = proj(theta_bar - lr_L * gg)                           # (7)
        bank = bank.at[i_k].set(new_i)
        psi = relative_fitness(prob, new_L)
        return (new_L, bank), psi

    theta0 = jnp.zeros((p,))
    bank0 = jnp.zeros((N, p))
    (theta_L, bank), psis = jax.lax.scan(step, (theta0, bank0),
                                         (owners_seq, noise_keys))
    return Algo1Trace(theta_L, psis, owners_seq, bank)


def run_many(key, prob: LinearProblem, owners: List[Owner], cfg: Algo1Config,
             n_runs: int) -> Algo1Trace:
    """vmapped multi-seed runs (percentile statistics of Figs. 2/8)."""
    keys = jax.random.split(key, n_runs)
    return jax.vmap(lambda k: run_algorithm1(k, prob, owners, cfg))(keys)
