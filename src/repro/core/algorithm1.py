"""Deprecated shim — Algorithm 1's convex engine moved to
``repro.federation.convex`` as part of the unified federation API. The
session-level entrypoint is ``repro.federation.Federation`` (pluggable
Mechanism + Schedule, ledger inside); this module keeps the old names
importable and behaving exactly as before."""
from repro.federation.convex import (Algo1Config, Algo1Trace, run_algorithm1,
                                     run_many)

__all__ = ["Algo1Config", "Algo1Trace", "run_algorithm1", "run_many"]
