"""Deprecated shim — Algorithm 1's convex engine moved to
``repro.federation.convex`` as part of the unified federation API. The
session-level entrypoint is ``repro.federation.Federation`` (pluggable
Mechanism + Schedule, ledger inside); this module keeps the old names
importable and behaving exactly as before."""
import warnings

from repro.federation.convex import (Algo1Config, Algo1Trace, run_algorithm1,
                                     run_many)

warnings.warn(
    "repro.core.algorithm1 is a deprecated shim; import from repro.federation "
    "instead (it will be removed in a future PR)",
    DeprecationWarning, stacklevel=2)

__all__ = ["Algo1Config", "Algo1Trace", "run_algorithm1", "run_many"]
