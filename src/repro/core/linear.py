"""Deprecated shim — the convex problem moved to ``repro.federation.linear``
as part of the unified federation API. Import from ``repro.federation``
instead; this module keeps the old names importable."""
import warnings

from repro.federation.linear import (LinearProblem, Owner, fitness,
                                     make_problem, owner_grad,
                                     record_grad_bound, reg_grad,
                                     relative_fitness)

warnings.warn(
    "repro.core.linear is a deprecated shim; import from repro.federation "
    "instead (it will be removed in a future PR)",
    DeprecationWarning, stacklevel=2)

__all__ = ["LinearProblem", "Owner", "fitness", "make_problem", "owner_grad",
           "record_grad_bound", "reg_grad", "relative_fitness"]
