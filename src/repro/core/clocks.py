"""Deprecated shim — Poisson-clock scheduling moved to
``repro.federation.clocks`` as part of the unified federation API. Import
from ``repro.federation`` instead; this module keeps the old names
importable. The session-level pluggable schedules (uniform / Poisson /
availability-trace) live in ``repro.federation.schedules``."""
import warnings

from repro.federation.clocks import (Schedule, owner_counts,
                                     poisson_schedule, uniform_schedule)

warnings.warn(
    "repro.core.clocks is a deprecated shim; import from repro.federation "
    "instead (it will be removed in a future PR)",
    DeprecationWarning, stacklevel=2)

__all__ = ["Schedule", "owner_counts", "poisson_schedule",
           "uniform_schedule"]
