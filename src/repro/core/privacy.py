"""Deprecated shim — DP mechanisms/accounting moved to
``repro.federation.privacy`` as part of the unified federation API. Import
from ``repro.federation`` instead; this module keeps the old names
importable. The session-level pluggable mechanisms (with the accountant
inside) live in ``repro.federation.mechanisms``."""
import warnings

from repro.federation.privacy import (OwnerLedger, PrivacyAccountant,
                                      capped_rounds, laplace_noise,
                                      laplace_noise_tree,
                                      laplace_scale_theorem1)

warnings.warn(
    "repro.core.privacy is a deprecated shim; import from repro.federation "
    "instead (it will be removed in a future PR)",
    DeprecationWarning, stacklevel=2)

__all__ = ["OwnerLedger", "PrivacyAccountant", "capped_rounds",
           "laplace_noise", "laplace_noise_tree", "laplace_scale_theorem1"]
