"""Deprecated shim — the deep-model AsyncDPTrainer moved to
``repro.federation.deep`` as part of the unified federation API. The
session-level entrypoint is ``repro.federation.Federation`` (pluggable
Mechanism + Schedule, ledger inside); this module keeps the old names
importable and behaving exactly as before."""
import warnings

from repro.federation.deep import (AsyncDPConfig, AsyncDPState, init_state,
                                   make_sync_dp_step, make_train_step)

warnings.warn(
    "repro.core.async_trainer is a deprecated shim; import from repro.federation "
    "instead (it will be removed in a future PR)",
    DeprecationWarning, stacklevel=2)

__all__ = ["AsyncDPConfig", "AsyncDPState", "init_state",
           "make_sync_dp_step", "make_train_step"]
