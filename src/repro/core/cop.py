"""Cost of Privacy (Theorem 2): bounds, constant fitting, collaboration value.

Eq. (11), large-T form:
    E{f(theta_L,T)} - f(theta*) <= (c1/n) sqrt(S) + (c2/n^2) S,
    S := sum_i 1/eps_i^2.

These forecasts are first-class: they let data owners predict private-model
quality during budget negotiation *without* revealing data (Section 6).
"""
from __future__ import annotations

from typing import Sequence, Tuple

import numpy as np


def budget_sum(epsilons: Sequence[float]) -> float:
    return float(sum(1.0 / e ** 2 for e in epsilons))


def bound_theorem2(T: int, N: int, n: int, epsilons: Sequence[float],
                   c1: float, c2: float) -> float:
    """Finite-T bound, eq. (8)/(9) inner term."""
    s = sum((1.0 / T + 2.0 * np.sqrt(2.0) / (n * e)) ** 2 for e in epsilons)
    inner = 1.0 / T ** 2 + N * s
    return c1 * np.sqrt(inner) + c2 * inner


def bound_asymptotic(n: int, epsilons: Sequence[float], c1b: float,
                     c2b: float) -> float:
    """Large-T bound, eqs. (10)/(11)."""
    S = budget_sum(epsilons)
    return c1b / n * np.sqrt(S) + c2b / n ** 2 * S


def fit_constants(ns: np.ndarray, eps_sums: np.ndarray, observed: np.ndarray,
                  nonneg: bool = True) -> Tuple[float, float]:
    """Least-squares fit of (c1bar, c2bar) in eq. (11) to observed CoP.

    Design: observed ~= c1b * sqrt(S)/n + c2b * S/n^2.
    """
    x1 = np.sqrt(eps_sums) / ns
    x2 = eps_sums / ns ** 2
    X = np.stack([x1, x2], axis=1)
    coef, *_ = np.linalg.lstsq(X, observed, rcond=None)
    if nonneg:
        coef = np.maximum(coef, 0.0)
        # refit the active coordinate if one was clipped
        if coef[0] == 0.0:
            coef[1] = max(float(np.linalg.lstsq(X[:, 1:], observed,
                                                rcond=None)[0][0]), 0.0)
        elif coef[1] == 0.0:
            coef[0] = max(float(np.linalg.lstsq(X[:, :1], observed,
                                                rcond=None)[0][0]), 0.0)
    return float(coef[0]), float(coef[1])


def min_owners_for_benefit(psi_isolated: float, n_per_owner: int,
                           epsilon: float, c1b: float, c2b: float,
                           max_n: int = 4096) -> int:
    """Smallest N such that the predicted private-collaboration CoP beats
    training alone without privacy (the black region of Fig. 6)."""
    for N in range(1, max_n + 1):
        eps = [epsilon] * N
        if bound_asymptotic(N * n_per_owner, eps, c1b, c2b) < psi_isolated:
            return N
    return -1
