"""Pure-jnp oracle for the flash-attention kernel (small shapes only)."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp


def attention_ref(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                  causal: bool = True, window: Optional[int] = None
                  ) -> jnp.ndarray:
    """q,k,v: (B, H, S, hd). Materializes (S, Skv) — oracle only."""
    B, H, S, hd = q.shape
    Skv = k.shape[2]
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * hd ** -0.5
    qpos = jnp.arange(S)[:, None]
    kpos = jnp.arange(Skv)[None, :]
    mask = jnp.ones((S, Skv), bool)
    if causal:
        mask &= kpos <= qpos
    if window is not None:
        mask &= (qpos - kpos) < window
    s = jnp.where(mask[None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhqk,bhkd->bhqd", p, v.astype(jnp.float32))
    return out.astype(q.dtype)
