"""jit'd public wrapper: GQA-aware flash attention in model layout.

Model layout (B, S, H, hd) with Kv <= H kv heads; this wrapper expands kv
heads to query heads, pads hd to a multiple of 128 (MXU lane width) and S
to the block size, and calls the Pallas kernel (interpret=True on CPU).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.kernel import flash_attention_bhsd


@functools.partial(jax.jit, static_argnames=("causal", "window", "bq", "bk",
                                             "interpret"))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, window: Optional[int] = None,
                    bq: int = 256, bk: int = 256,
                    interpret: bool = False) -> jax.Array:
    """q: (B,S,H,hd); k,v: (B,Skv,Kv,hd). Returns (B,S,H,hd)."""
    B, S, H, hd = q.shape
    Skv, Kv = k.shape[1], k.shape[2]
    G = H // Kv
    if G > 1:
        k = jnp.repeat(k, G, axis=2)
        v = jnp.repeat(v, G, axis=2)
    qt = q.transpose(0, 2, 1, 3)
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)

    hd_pad = (-hd) % 128
    if hd_pad:
        pad = [(0, 0), (0, 0), (0, 0), (0, hd_pad)]
        qt, kt, vt = jnp.pad(qt, pad), jnp.pad(kt, pad), jnp.pad(vt, pad)
    bq_eff = min(bq, S)
    bk_eff = min(bk, Skv)
    sq_pad = (-S) % bq_eff
    sk_pad = (-Skv) % bk_eff
    if sq_pad:
        qt = jnp.pad(qt, [(0, 0), (0, 0), (0, sq_pad), (0, 0)])
    if sk_pad:
        # padded kv positions fall outside causal/window masks for real
        # queries as long as they trail the sequence; mask handles them
        # only under `causal`; for bidirectional use exact shapes.
        kt = jnp.pad(kt, [(0, 0), (0, 0), (0, sk_pad), (0, 0)])
        vt = jnp.pad(vt, [(0, 0), (0, 0), (0, sk_pad), (0, 0)])
        assert causal, "non-causal padding not supported"
    out = flash_attention_bhsd(qt, kt, vt, causal=causal, window=window,
                               bq=bq_eff, bk=bk_eff, scale=hd ** -0.5,
                               interpret=interpret)
    out = out[:, :, :S, :hd]
    return out.transpose(0, 2, 1, 3)
