"""Pallas TPU flash attention (forward): blockwise online softmax.

Grid (B, H, n_q_blocks, n_kv_blocks); the kv axis is the minor-most grid
dimension, so VMEM scratch (m, l, acc) persists across kv iterations for a
fixed q block (TPU grids iterate sequentially). Causal and sliding-window
masks supported; out-of-window / beyond-causal kv blocks are skipped with
pl.when so the MXU never sees them.

Block sizes are multiples of (8, 128) to match TPU tiling; hd is padded to
128 by ops.py when needed.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                  scale: float, causal: bool, window: Optional[int],
                  bq: int, bk: int, n_kv: int):
    j = pl.program_id(3)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    i = pl.program_id(2)
    q_start = i * bq
    k_start = j * bk

    # static-shape guards are not possible for dynamic program ids; use
    # pl.when to skip fully-masked blocks.
    beyond_causal = causal and (k_start > q_start + bq - 1)
    # (evaluated as traced bool)
    run = jnp.asarray(True)
    if causal:
        run = run & (k_start <= q_start + bq - 1)
    if window is not None:
        run = run & (q_start - (k_start + bk - 1) < window)

    @pl.when(run)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)          # (bq, hd)
        k = k_ref[0, 0].astype(jnp.float32)          # (bk, hd)
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        qpos = q_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        mask = jnp.ones((bq, bk), jnp.bool_)
        if causal:
            mask &= kpos <= qpos
        if window is not None:
            mask &= (qpos - kpos) < window
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=1, keepdims=True)
        acc_ref[...] = acc_ref[...] * corr + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(j == n_kv - 1)
    def _finalize():
        o_ref[0, 0] = (acc_ref[...]
                       / jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


def flash_attention_bhsd(q: jax.Array, k: jax.Array, v: jax.Array, *,
                         causal: bool = True, window: Optional[int] = None,
                         bq: int = 256, bk: int = 256,
                         scale: Optional[float] = None,
                         interpret: bool = False) -> jax.Array:
    """q,k,v: (B, H, S, hd) with matching H (GQA expanded by ops.py).

    `scale` defaults to hd**-0.5 of the given (possibly padded) hd; callers
    that zero-pad hd must pass the unpadded scale.
    """
    B, H, S, hd = q.shape
    Skv = k.shape[2]
    bq = min(bq, S)
    bk = min(bk, Skv)
    assert S % bq == 0 and Skv % bk == 0, (S, bq, Skv, bk)
    nq, nk = S // bq, Skv // bk
    scale = hd ** -0.5 if scale is None else scale

    kernel = functools.partial(
        _flash_kernel, scale=scale, causal=causal, window=window,
        bq=bq, bk=bk, n_kv=nk)
    grid = (B, H, nq, nk)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, bq, hd), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, bk, hd), lambda b, h, i, j: (b, h, j, 0)),
            pl.BlockSpec((1, 1, bk, hd), lambda b, h, i, j: (b, h, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, hd), lambda b, h, i, j: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, S, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, hd), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
