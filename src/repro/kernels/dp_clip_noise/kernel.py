"""Pallas TPU kernel: fused gradient scale + Laplace-noise add (eq. 4),
plus the whole-round `dp_round` kernel for the flat-buffer engine.

The DP response Qbar = clip(g) + Laplace(b) is HBM-bound: the naive
implementation makes three passes over the gradient (norm, scale, add
noise). The fused kernel does the scale-and-noise in ONE pass: it consumes
pre-generated uniform random bits (threefry bits from jax.random — kept
outside so the privacy-critical RNG stays the library one), converts them
to Laplace via inverse-CDF in VMEM, and writes g*clip_scale + b*lap.

The squared-norm reduction (pass 1) is also provided as a blockwise kernel
(partial sums per block, combined by the caller) so the full privatization
is 2 HBM passes instead of 3+.

`dp_round` goes further for flat-packed models: the paper's whole inertia
round past the gradient — group-mean, Laplace add (eq. 4), the owner and
learner updates (eqs. 5/7, regularizer gradient included), and the
theta_max projection — is elementwise in the flat buffer, so one kernel
streams theta_bar + the accumulated clipped gradient once and writes both
updated buffers: ONE HBM pass instead of the ~7 tree_map passes of the
pytree path.

Layout: gradients are flattened and padded to (rows, 1024) fp32 blocks of
(block_rows, 1024) — 8x128-aligned VMEM tiles.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LANES = 1024


def _laplace_from_bits(bits):
    # uniform in (0,1): use top 24 bits
    u01 = (bits >> 8).astype(jnp.float32) * (1.0 / (1 << 24))
    v = u01 - 0.5
    # inverse CDF of Laplace(0,1): -sign(v) * log(1 - 2|v|)
    return -jnp.sign(v) * jnp.log1p(-2.0 * jnp.abs(jnp.clip(v, -0.4999999,
                                                            0.4999999)))


def _scale_noise_kernel(g_ref, u_ref, cs_ref, ns_ref, o_ref):
    g = g_ref[...].astype(jnp.float32)
    lap = _laplace_from_bits(u_ref[...])
    cs = cs_ref[0, 0]
    ns = ns_ref[0, 0]
    o_ref[...] = (g * cs + ns * lap).astype(o_ref.dtype)


def _dp_round_kernel(tb_ref, acc_ref, u_ref, gn_ref, ns_ref, w_ref,
                     ol_ref, oi_ref, *, sigma, lr_own, lr_l, inv_2n,
                     theta_max):
    """One block of the fused inertia round (eqs. 4-5-7 + projection).

    tb = theta_bar (eq. 6, precomputed: the gradient was taken at it);
    acc = sum of per-group clipped gradients. In-block:

        q     = acc * gain + noise_scale * Laplace(bits)      (eq. 4)
        g_reg = sigma * tb                                    (grad of g)
        oi    = Pi[ tb - lr_own * (g_reg/(2N) + w * q) ]      (eq. 5)
        ol    = Pi[ tb - lr_L * g_reg ]                       (eq. 7)

    sigma/lr_own/lr_l/inv_2n/theta_max are compile-time constants; the
    per-round traced scalars (group-mean gain, Theorem-1 noise scale, and
    the owner weight w = n_i/n) arrive as (1,1) refs.
    """
    tb = tb_ref[...].astype(jnp.float32)
    acc = acc_ref[...].astype(jnp.float32)
    lap = _laplace_from_bits(u_ref[...])
    q = acc * gn_ref[0, 0] + ns_ref[0, 0] * lap
    g_reg = sigma * tb
    oi_ref[...] = jnp.clip(tb - lr_own * (g_reg * inv_2n + w_ref[0, 0] * q),
                           -theta_max, theta_max).astype(oi_ref.dtype)
    ol_ref[...] = jnp.clip(tb - lr_l * g_reg,
                           -theta_max, theta_max).astype(ol_ref.dtype)


def _sqnorm_kernel(g_ref, o_ref):
    g = g_ref[...].astype(jnp.float32)
    o_ref[0, 0] = jnp.sum(g * g)


def scale_noise_2d(g: jax.Array, bits: jax.Array, clip_scale: jax.Array,
                   noise_scale: jax.Array, *, block_rows: int = 256,
                   interpret: bool = False) -> jax.Array:
    """g: (R, LANES) fp32; bits: (R, LANES) uint32; scalars as (1,1) f32."""
    R, C = g.shape
    assert C == LANES and R % block_rows == 0, (g.shape, block_rows)
    grid = (R // block_rows,)
    return pl.pallas_call(
        _scale_noise_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_rows, LANES), lambda i: (i, 0)),
            pl.BlockSpec((block_rows, LANES), lambda i: (i, 0)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_rows, LANES), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((R, C), g.dtype),
        interpret=interpret,
    )(g, bits, clip_scale, noise_scale)


def dp_round_2d(tb: jax.Array, acc: jax.Array, bits: jax.Array,
                gain: jax.Array, noise_scale: jax.Array, w: jax.Array, *,
                sigma: float, lr_own: float, lr_l: float, n_owners: int,
                theta_max: float, block_rows: int = 256,
                interpret: bool = False):
    """Whole inertia round on (R, LANES) blocks -> (new_L, new_i).

    tb/acc: (R, LANES) f32; bits: (R, LANES) uint32; gain/noise_scale/w:
    traced scalars as (1,1) f32. The remaining round constants are baked
    into the kernel at trace time.
    """
    R, C = tb.shape
    assert C == LANES and R % block_rows == 0, (tb.shape, block_rows)
    assert acc.shape == tb.shape and bits.shape == tb.shape
    grid = (R // block_rows,)
    blk = pl.BlockSpec((block_rows, LANES), lambda i: (i, 0))
    one = pl.BlockSpec((1, 1), lambda i: (0, 0))
    kern = functools.partial(_dp_round_kernel, sigma=sigma, lr_own=lr_own,
                             lr_l=lr_l, inv_2n=1.0 / (2 * n_owners),
                             theta_max=theta_max)
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[blk, blk, blk, one, one, one],
        out_specs=[blk, blk],
        out_shape=[jax.ShapeDtypeStruct((R, C), jnp.float32),
                   jax.ShapeDtypeStruct((R, C), jnp.float32)],
        interpret=interpret,
    )(tb, acc, bits, gain, noise_scale, w)


def sqnorm_2d(g: jax.Array, *, block_rows: int = 256,
              interpret: bool = False) -> jax.Array:
    """Blockwise partial squared norms; caller sums. g: (R, LANES) fp32."""
    R, C = g.shape
    assert C == LANES and R % block_rows == 0
    grid = (R // block_rows,)
    partial = pl.pallas_call(
        _sqnorm_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((block_rows, LANES), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((1, 1), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((R // block_rows, 1), jnp.float32),
        interpret=interpret,
    )(g)
    return jnp.sum(partial)
