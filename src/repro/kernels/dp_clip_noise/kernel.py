"""Pallas TPU kernel: fused gradient scale + Laplace-noise add (eq. 4).

The DP response Qbar = clip(g) + Laplace(b) is HBM-bound: the naive
implementation makes three passes over the gradient (norm, scale, add
noise). The fused kernel does the scale-and-noise in ONE pass: it consumes
pre-generated uniform random bits (threefry bits from jax.random — kept
outside so the privacy-critical RNG stays the library one), converts them
to Laplace via inverse-CDF in VMEM, and writes g*clip_scale + b*lap.

The squared-norm reduction (pass 1) is also provided as a blockwise kernel
(partial sums per block, combined by the caller) so the full privatization
is 2 HBM passes instead of 3+.

Layout: gradients are flattened and padded to (rows, 1024) fp32 blocks of
(block_rows, 1024) — 8x128-aligned VMEM tiles.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LANES = 1024


def _scale_noise_kernel(g_ref, u_ref, cs_ref, ns_ref, o_ref):
    g = g_ref[...].astype(jnp.float32)
    bits = u_ref[...]
    # uniform in (0,1): use top 24 bits
    u01 = (bits >> 8).astype(jnp.float32) * (1.0 / (1 << 24))
    v = u01 - 0.5
    # inverse CDF of Laplace(0,1): -sign(v) * log(1 - 2|v|)
    lap = -jnp.sign(v) * jnp.log1p(-2.0 * jnp.abs(jnp.clip(v, -0.4999999,
                                                           0.4999999)))
    cs = cs_ref[0, 0]
    ns = ns_ref[0, 0]
    o_ref[...] = (g * cs + ns * lap).astype(o_ref.dtype)


def _sqnorm_kernel(g_ref, o_ref):
    g = g_ref[...].astype(jnp.float32)
    o_ref[0, 0] = jnp.sum(g * g)


def scale_noise_2d(g: jax.Array, bits: jax.Array, clip_scale: jax.Array,
                   noise_scale: jax.Array, *, block_rows: int = 256,
                   interpret: bool = False) -> jax.Array:
    """g: (R, LANES) fp32; bits: (R, LANES) uint32; scalars as (1,1) f32."""
    R, C = g.shape
    assert C == LANES and R % block_rows == 0, (g.shape, block_rows)
    grid = (R // block_rows,)
    return pl.pallas_call(
        _scale_noise_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_rows, LANES), lambda i: (i, 0)),
            pl.BlockSpec((block_rows, LANES), lambda i: (i, 0)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_rows, LANES), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((R, C), g.dtype),
        interpret=interpret,
    )(g, bits, clip_scale, noise_scale)


def sqnorm_2d(g: jax.Array, *, block_rows: int = 256,
              interpret: bool = False) -> jax.Array:
    """Blockwise partial squared norms; caller sums. g: (R, LANES) fp32."""
    R, C = g.shape
    assert C == LANES and R % block_rows == 0
    grid = (R // block_rows,)
    partial = pl.pallas_call(
        _sqnorm_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((block_rows, LANES), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((1, 1), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((R // block_rows, 1), jnp.float32),
        interpret=interpret,
    )(g)
    return jnp.sum(partial)
