"""jit'd wrapper: privatize a gradient PYTREE with the fused Pallas kernel.

    noisy = dp_privatize_tree(grads, key, xi=..., noise_scale=..., interpret=...)

Two HBM passes total: (1) blockwise squared-norm partials -> global norm ->
clip factor; (2) fused scale+Laplace-add. The Laplace bits come from
jax.random (threefry) so the DP guarantee rides on the library RNG.

The traced-scalar entry points accept ``interpret`` as True (Pallas
interpreter — kernel debugging), False (compiled Pallas — TPU), or the
string ``"oracle"``: the kernel's pure-jnp transform from ``ref.py``,
executed directly on the unpadded arrays. The oracle is the production
backend off-TPU (no interpreter plumbing, no block padding); its noise
stream differs from the kernel's (unpadded draw shape), which is lawful
under the same statistical-equivalence contract as the kernel itself.
"""
from __future__ import annotations

import functools
from typing import Any, Tuple

import jax
import jax.numpy as jnp

from repro.kernels.dp_clip_noise.kernel import (LANES, dp_round_2d,
                                                scale_noise_2d, sqnorm_2d)

tmap = jax.tree_util.tree_map


def _pack(leaf: jax.Array, block_rows: int) -> Tuple[jax.Array, int]:
    flat = leaf.astype(jnp.float32).reshape(-1)
    n = flat.shape[0]
    per_block = block_rows * LANES
    pad = (-n) % per_block
    if pad:
        flat = jnp.pad(flat, (0, pad))
    return flat.reshape(-1, LANES), n


@functools.partial(jax.jit,
                   static_argnames=("block_rows", "interpret"))
def dp_privatize_tree(grads: Any, key, xi: float, noise_scale: float, *,
                      block_rows: int = 256, interpret: bool = False) -> Any:
    """Clip the tree to global norm xi, add Laplace(noise_scale) noise."""
    leaves, treedef = jax.tree_util.tree_flatten(grads)
    packed = [_pack(leaf, block_rows) for leaf in leaves]

    sq = sum(sqnorm_2d(p, block_rows=block_rows, interpret=interpret)
             for p, _ in packed)
    norm = jnp.sqrt(sq)
    clip = jnp.minimum(1.0, xi / jnp.maximum(norm, 1e-12))
    cs = clip.reshape(1, 1).astype(jnp.float32)
    ns = jnp.full((1, 1), noise_scale, jnp.float32)

    keys = jax.random.split(key, len(leaves))
    out = []
    for (p, n), leaf, k in zip(packed, leaves, keys):
        bits = jax.random.bits(k, p.shape, jnp.uint32)
        y = scale_noise_2d(p, bits, cs, ns, block_rows=block_rows,
                           interpret=interpret)
        out.append(y.reshape(-1)[:n].reshape(leaf.shape).astype(leaf.dtype))
    return jax.tree_util.tree_unflatten(treedef, out)


def dp_round_flat(tb: jax.Array, acc: jax.Array, key, gain, noise_scale,
                  w, *, sigma: float, lr_own: float, lr_l: float,
                  n_owners: int, theta_max: float, block_rows: int = 256,
                  interpret: bool = False) -> Tuple[jax.Array, jax.Array]:
    """Whole inertia round on a (P,) flat buffer -> (new_L, new_i).

    Fuses group-mean (`gain`), the Laplace add (eq. 4), the eq. (5)/(7)
    inertia updates and the theta_max projection into ONE HBM pass over the
    padded 2-D view of the buffer. `gain`, `noise_scale` and `w` may be
    traced scalars (scan-body safe); the structural round constants are
    baked into the kernel. The Laplace bits come from jax.random (threefry)
    converted in-kernel by inverse CDF — a DIFFERENT lawful draw than
    jax.random.laplace, so this backend is statistically (not bitwise)
    equivalent to the jnp path: the same contract as fused_scale_noise_tree.
    """
    if interpret == "oracle":
        from repro.kernels.dp_clip_noise.ref import dp_round_ref
        bits = jax.random.bits(key, tb.shape, jnp.uint32)
        return dp_round_ref(tb, acc, bits, gain, noise_scale, w,
                            sigma=sigma, lr_own=lr_own, lr_l=lr_l,
                            n_owners=n_owners, theta_max=theta_max)
    (p_tb, n) = _pack(tb, block_rows)
    (p_acc, _) = _pack(acc, block_rows)
    bits = jax.random.bits(key, p_tb.shape, jnp.uint32)
    gn = jnp.asarray(gain, jnp.float32).reshape(1, 1)
    ns = jnp.asarray(noise_scale, jnp.float32).reshape(1, 1)
    wv = jnp.asarray(w, jnp.float32).reshape(1, 1)
    new_l, new_i = dp_round_2d(p_tb, p_acc, bits, gn, ns, wv, sigma=sigma,
                               lr_own=lr_own, lr_l=lr_l, n_owners=n_owners,
                               theta_max=theta_max, block_rows=block_rows,
                               interpret=interpret)
    return new_l.reshape(-1)[:n], new_i.reshape(-1)[:n]


# --------- traced-scalar entry points for in-graph (scan-body) use ---------
# dp_privatize_tree above is a jit boundary of its own; the deep path's
# fused multi-round driver instead calls these INSIDE its lax.scan body,
# where xi / noise_scale arrive as traced per-owner scalars gathered from
# the mechanism's scales array.

def fused_sqnorm_tree(tree: Any, *, block_rows: int = 256,
                      interpret=False) -> jax.Array:
    """Global squared L2 norm of a pytree via the blockwise Pallas pass."""
    leaves = jax.tree_util.tree_leaves(tree)
    if interpret == "oracle":
        from repro.kernels.dp_clip_noise.ref import sqnorm_ref
        return sum(sqnorm_ref(leaf) for leaf in leaves)
    return sum(sqnorm_2d(_pack(leaf, block_rows)[0], block_rows=block_rows,
                         interpret=interpret)
               for leaf in leaves)


def fused_scale_noise_tree(tree: Any, key, gain, noise_scale, *,
                           block_rows: int = 256,
                           interpret=False) -> Any:
    """leaf * gain + Laplace(noise_scale) in ONE fused HBM pass per leaf.

    `gain` and `noise_scale` may be traced scalars (e.g. a clip factor and
    an owner-indexed Theorem-1 scale). The Laplace bits come from
    jax.random (threefry), converted in-kernel by inverse CDF — note this
    is a DIFFERENT lawful draw than jax.random.laplace, so the jnp and
    fused backends are statistically, not bitwise, equivalent.
    """
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    keys = jax.random.split(key, len(leaves))
    if interpret == "oracle":
        from repro.kernels.dp_clip_noise.ref import scale_noise_ref
        out = [scale_noise_ref(leaf, jax.random.bits(k, leaf.shape, jnp.uint32),
                               gain, noise_scale)
               for leaf, k in zip(leaves, keys)]
        return jax.tree_util.tree_unflatten(treedef, out)
    packed = [_pack(leaf, block_rows) for leaf in leaves]
    cs = jnp.asarray(gain, jnp.float32).reshape(1, 1)
    ns = jnp.asarray(noise_scale, jnp.float32).reshape(1, 1)
    out = []
    for (p, n), leaf, k in zip(packed, leaves, keys):
        bits = jax.random.bits(k, p.shape, jnp.uint32)
        y = scale_noise_2d(p, bits, cs, ns, block_rows=block_rows,
                           interpret=interpret)
        out.append(y.reshape(-1)[:n].reshape(leaf.shape).astype(leaf.dtype))
    return jax.tree_util.tree_unflatten(treedef, out)
