"""Pure-jnp oracle for the dp_clip_noise kernel (bit-exact transform)."""
from __future__ import annotations

import jax.numpy as jnp


def laplace_from_bits(bits: jnp.ndarray) -> jnp.ndarray:
    u01 = (bits >> 8).astype(jnp.float32) * (1.0 / (1 << 24))
    v = u01 - 0.5
    return -jnp.sign(v) * jnp.log1p(
        -2.0 * jnp.abs(jnp.clip(v, -0.4999999, 0.4999999)))


def scale_noise_ref(g: jnp.ndarray, bits: jnp.ndarray, clip_scale,
                    noise_scale) -> jnp.ndarray:
    lap = laplace_from_bits(bits)
    return (g.astype(jnp.float32) * clip_scale + noise_scale * lap
            ).astype(g.dtype)


def sqnorm_ref(g: jnp.ndarray) -> jnp.ndarray:
    gf = g.astype(jnp.float32)
    return jnp.sum(gf * gf)


def dp_round_ref(tb: jnp.ndarray, acc: jnp.ndarray, bits: jnp.ndarray,
                 gain, noise_scale, w, *, sigma, lr_own, lr_l, n_owners,
                 theta_max):
    """Oracle for the fused dp_round kernel (bit-exact transform)."""
    tbf = tb.astype(jnp.float32)
    q = acc.astype(jnp.float32) * gain + noise_scale * laplace_from_bits(bits)
    g_reg = sigma * tbf
    new_i = jnp.clip(tbf - lr_own * (g_reg * (1.0 / (2 * n_owners)) + w * q),
                     -theta_max, theta_max)
    new_l = jnp.clip(tbf - lr_l * g_reg, -theta_max, theta_max)
    return new_l, new_i
