"""Pure-jnp oracle for the tree_noise kernel (bit-exact transform).

DP-FTRL binary-counter node refresh (Kairouz et al. 2021): advancing an
owner's leaf count from t to t+1 retires the node at every level that
held a trailing one bit of t, installs ONE fresh node at the level of
the lowest set bit of t+1, and leaves higher levels untouched. The
per-round injected noise delta is the fresh draw minus the retired
nodes, so the cumulative injected noise after t leaves telescopes to
the sum of the ACTIVE nodes — popcount(t) independent draws instead of
t, the O(log K) cumulative-noise property the mechanism buys.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.kernels.dp_clip_noise.ref import laplace_from_bits


def tree_masks_ref(count, depth: int):
    """(retired, fresh) (depth,) bool masks for the count -> count+1 leaf.

    Level l retires iff 2^(l+1) divides count+1 (it held a trailing one
    bit of count); level l is fresh iff it is the lowest set bit of
    count+1. Exactly one level is fresh while count+1 < 2^depth.
    """
    t1 = jnp.asarray(count, jnp.int32) + 1
    lvl = jnp.arange(depth, dtype=jnp.int32)
    pw = jnp.left_shift(jnp.int32(1), lvl + 1)
    rem = jnp.remainder(t1, pw)
    return rem == 0, rem == jnp.left_shift(jnp.int32(1), lvl)


def tree_delta_ref(nodes, bits, count, noise_scale):
    """One leaf increment -> (delta (P,), new_nodes (depth, P)).

    `nodes` (depth, P) f32 holds the owner's SCALED node noise (each
    level a noise_scale * Laplace(1) draw); `bits` (P,) uint32 feeds the
    fresh draw through the same inverse-CDF transform as the
    dp_clip_noise kernels; `count` () int32 is the leaves released
    before this one. depth == 0 degenerates to fresh independent noise
    with no retirement — exactly the per-round Laplace mechanism.
    """
    depth = nodes.shape[0]
    zeta = noise_scale * laplace_from_bits(bits)
    if depth == 0:
        return zeta, nodes
    retired, fresh = tree_masks_ref(count, depth)
    delta = zeta - jnp.sum(jnp.where(retired[:, None], nodes, 0.0), axis=0)
    new_nodes = jnp.where(fresh[:, None], zeta[None],
                          jnp.where(retired[:, None], 0.0, nodes))
    return delta, new_nodes
