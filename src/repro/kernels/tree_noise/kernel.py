"""Pallas TPU kernel: DP-FTRL tree-noise node refresh + per-round delta.

The binary-counter update for one leaf increment is elementwise over the
(P,)-flat node buffer at every level, so one kernel streams the owner's
(depth, P) node row exactly once: it converts pre-generated uniform bits
to the fresh Laplace node (inverse CDF in VMEM — the same lawful draw as
the dp_clip_noise kernels), subtracts the retired levels from the fresh
draw, zeroes them, writes the fresh level, and emits the injected delta.
Which levels retire/refresh depends only on the (1, 1) leaf count, never
on the data, so the level loop unrolls statically (depth ~ log2(T)).

Layout: nodes ride as (depth, R, 1024) with blocks of
(depth, block_rows, 1024) — the whole level axis stays resident in VMEM
per block, so keep block_rows SMALL: in/out node blocks plus bits and
delta cost (2*depth + 2) * block_rows * 4 KB; the default 64 is ~5.5 MB
at depth 10, comfortably under the ~16 MB VMEM budget where the
dp_clip_noise default of 256 would blow it.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LANES = 1024


def _laplace_from_bits(bits):
    u01 = (bits >> 8).astype(jnp.float32) * (1.0 / (1 << 24))
    v = u01 - 0.5
    return -jnp.sign(v) * jnp.log1p(
        -2.0 * jnp.abs(jnp.clip(v, -0.4999999, 0.4999999)))


def _tree_delta_kernel(nodes_ref, u_ref, cnt_ref, ns_ref, delta_ref,
                       out_ref, *, depth):
    t1 = cnt_ref[0, 0] + 1
    zeta = ns_ref[0, 0] * _laplace_from_bits(u_ref[...])
    acc = zeta
    for lvl in range(depth):
        rem = jax.lax.rem(t1, jnp.int32(1 << (lvl + 1)))
        retired = rem == 0
        fresh = rem == jnp.int32(1 << lvl)
        nd = nodes_ref[lvl].astype(jnp.float32)
        acc = acc - jnp.where(retired, nd, jnp.zeros_like(nd))
        out_ref[lvl] = jnp.where(fresh, zeta,
                                 jnp.where(retired, jnp.zeros_like(nd), nd))
    delta_ref[...] = acc


def tree_delta_2d(nodes, bits, count, noise_scale, *, block_rows: int = 64,
                  interpret=False):
    """nodes (depth>=1, R, LANES) f32, bits (R, LANES) uint32,
    count/noise_scale (1, 1) -> (delta (R, LANES), new_nodes like nodes)."""
    depth, rows, cols = nodes.shape
    assert cols == LANES and rows % block_rows == 0, (nodes.shape, block_rows)
    assert depth >= 1, "depth-0 trees bypass the kernel (ops.tree_delta_row)"
    kern = functools.partial(_tree_delta_kernel, depth=depth)
    node_spec = pl.BlockSpec((depth, block_rows, LANES), lambda i: (0, i, 0))
    row_spec = pl.BlockSpec((block_rows, LANES), lambda i: (i, 0))
    one = pl.BlockSpec((1, 1), lambda i: (0, 0))
    return pl.pallas_call(
        kern,
        grid=(rows // block_rows,),
        in_specs=[node_spec, row_spec, one, one],
        out_specs=[row_spec, node_spec],
        out_shape=[jax.ShapeDtypeStruct((rows, cols), jnp.float32),
                   jax.ShapeDtypeStruct((depth, rows, cols), jnp.float32)],
        interpret=interpret,
    )(nodes, bits, count, noise_scale)
