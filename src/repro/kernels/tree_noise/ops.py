"""jit-safe entry: advance one owner's DP-FTRL noise tree by one leaf.

    delta, new_nodes = tree_delta_row(nodes, count, key, noise_scale, ...)

`nodes` is the owner's (depth, P) f32 node row (depth may be 0 — the
degenerate per-round-Laplace tree), `count` the () int32 leaves released
so far, `noise_scale` a traced per-NODE scalar (the TreeMechanism's
level-composed Theorem-1 scale). The Laplace bits come from jax.random
(threefry) converted by inverse CDF — the same lawful-draw contract as
the dp_clip_noise kernels, so the fused and jnp backends are
statistically (not bitwise) equivalent. ``interpret`` follows the repo
convention: True = Pallas interpreter, False = compiled, "oracle" = the
ref.py jnp transform on the unpadded arrays (the production backend
off-TPU).
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.kernels.tree_noise.kernel import LANES, tree_delta_2d
from repro.kernels.tree_noise.ref import tree_delta_ref


def tree_delta_row(nodes, count, key, noise_scale, *, block_rows: int = 64,
                   interpret=False) -> Tuple[jax.Array, jax.Array]:
    """(delta (P,), new_nodes (depth, P)) for one leaf increment."""
    depth, p = nodes.shape
    cnt = jnp.asarray(count, jnp.int32)
    ns = jnp.asarray(noise_scale, jnp.float32)
    if depth == 0 or interpret == "oracle":
        # depth 0 has no node traffic at all — the kernel's padded pass
        # would only launder the bits draw through a different shape
        bits = jax.random.bits(key, (p,), jnp.uint32)
        return tree_delta_ref(nodes, bits, cnt, ns)
    per_block = block_rows * LANES
    pad = (-p) % per_block
    nodes2d = jnp.pad(nodes, ((0, 0), (0, pad))).reshape(depth, -1, LANES)
    bits = jax.random.bits(key, nodes2d.shape[1:], jnp.uint32)
    delta, new_nodes = tree_delta_2d(nodes2d, bits, cnt.reshape(1, 1),
                                     ns.reshape(1, 1),
                                     block_rows=block_rows,
                                     interpret=interpret)
    return (delta.reshape(-1)[:p],
            new_nodes.reshape(depth, -1)[:, :p])
