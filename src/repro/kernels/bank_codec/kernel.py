"""Pallas TPU kernels: fused owner-bank row encode/decode (int8 / fp8).

The owner bank is the deep engine's dominant state: `(N_owners, P)` model
copies that the fused multi-round scan carries through every round.
Quantized storage (1 byte/element + per-row f32 scales) cuts the resident
bytes and the scan's loop-carry traffic ~4x vs f32; these kernels make the
row round-trip cheap enough to sit inside the scan body:

  absmax (pass 1)  — blockwise partial |x| maxima; the caller combines
                     them into the per-row scale, exactly like
                     dp_clip_noise's sqnorm pass.
  encode (pass 2)  — ONE fused pass that stochastically rounds the row
                     onto the int8/fp8 grid AND writes the quantization
                     error row (the error-feedback residual), so EF costs
                     no extra read of the f32 row.
  decode           — codes * scale in one pass.

The stochastic-rounding bits are pre-generated uint32s from jax.random
(the round key), same contract as the Laplace bits in dp_clip_noise: the
privacy-adjacent RNG stays the library one. The numeric transform is
imported from ref.py so kernel and jnp oracle can never drift.

Layout: rows are flattened and padded to (rows, 1024) blocks of
(block_rows, 1024) — 8x128-aligned VMEM tiles. Zero padding is inert for
absmax and is sliced off after encode/decode. (int8/fp8 VMEM tiles want
32 sublanes; block_rows defaults far above that.)
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.bank_codec.ref import (CODE_DTYPES, decode_fp8_ref,
                                          decode_int8_ref, encode_fp8_ref,
                                          encode_int8_ref, row_scales_ref)

LANES = 1024


def _absmax_kernel(x_ref, o_ref):
    o_ref[0, 0] = jnp.max(jnp.abs(x_ref[...].astype(jnp.float32)))


def _encode_kernel(x_ref, u_ref, s_ref, q_ref, e_ref, *, fmt):
    enc = encode_int8_ref if fmt == "int8" else encode_fp8_ref
    codes, err = enc(x_ref[...], u_ref[...], s_ref[0, 0])
    q_ref[...] = codes
    e_ref[...] = err


def _decode_kernel(q_ref, s_ref, o_ref, *, fmt):
    dec = decode_int8_ref if fmt == "int8" else decode_fp8_ref
    o_ref[...] = dec(q_ref[...], s_ref[0, 0])


def absmax_2d(x: jax.Array, *, block_rows: int = 256,
              interpret: bool = False) -> jax.Array:
    """Blockwise partial absmax; caller takes the max. x: (R, LANES) f32."""
    R, C = x.shape
    assert C == LANES and R % block_rows == 0, (x.shape, block_rows)
    grid = (R // block_rows,)
    partial = pl.pallas_call(
        _absmax_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((block_rows, LANES), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((1, 1), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((R // block_rows, 1), jnp.float32),
        interpret=interpret,
    )(x)
    return jnp.max(partial)


def row_scale_2d(x: jax.Array, qmax: float, *, block_rows: int = 256,
                 interpret: bool = False) -> jax.Array:
    """Per-row scale from the blockwise absmax pass (same floor as the
    oracle's row_scales_ref)."""
    return jnp.maximum(absmax_2d(x, block_rows=block_rows,
                                 interpret=interpret), 1e-30) / qmax


def encode_2d(x: jax.Array, bits: jax.Array, scale: jax.Array, fmt: str, *,
              block_rows: int = 256, interpret: bool = False):
    """Fused stochastic-round encode + error write -> (codes, err).

    x: (R, LANES) f32; bits: (R, LANES) uint32; scale: (1, 1) f32 (traced).
    """
    R, C = x.shape
    assert C == LANES and R % block_rows == 0, (x.shape, block_rows)
    assert bits.shape == x.shape
    code_dtype = CODE_DTYPES[fmt]
    grid = (R // block_rows,)
    blk = pl.BlockSpec((block_rows, LANES), lambda i: (i, 0))
    one = pl.BlockSpec((1, 1), lambda i: (0, 0))
    return pl.pallas_call(
        functools.partial(_encode_kernel, fmt=fmt),
        grid=grid,
        in_specs=[blk, blk, one],
        out_specs=[blk, blk],
        out_shape=[jax.ShapeDtypeStruct((R, C), code_dtype),
                   jax.ShapeDtypeStruct((R, C), jnp.float32)],
        interpret=interpret,
    )(x, bits, scale)


def decode_2d(codes: jax.Array, scale: jax.Array, fmt: str, *,
              block_rows: int = 256, interpret: bool = False) -> jax.Array:
    """decode(codes) * scale in one pass. codes: (R, LANES) int8 /
    e4m3fn-pattern uint8; scale: (1, 1) f32."""
    R, C = codes.shape
    assert C == LANES and R % block_rows == 0, (codes.shape, block_rows)
    grid = (R // block_rows,)
    blk = pl.BlockSpec((block_rows, LANES), lambda i: (i, 0))
    return pl.pallas_call(
        functools.partial(_decode_kernel, fmt=fmt),
        grid=grid,
        in_specs=[blk, pl.BlockSpec((1, 1), lambda i: (0, 0))],
        out_specs=blk,
        out_shape=jax.ShapeDtypeStruct((R, C), jnp.float32),
        interpret=interpret,
    )(codes, scale)


__all__ = ["LANES", "absmax_2d", "row_scale_2d", "encode_2d", "decode_2d",
           "row_scales_ref"]
