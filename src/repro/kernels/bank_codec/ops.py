"""Row-level entry points for the owner-bank codec (int8 / fp8 + EF).

    codes, scales, err = encode_row(row, key, "int8")   # (P,),(nb,),(P,)
    row_hat = decode_row(codes, scales, "int8")         # (P,) f32

Backend contract (same as dp_clip_noise): ``interpret`` is True (Pallas
interpreter — kernel debugging), False (compiled Pallas — TPU), or the
string ``"oracle"`` — the kernel's pure-jnp transform from ``ref.py`` run
directly on the unpadded row, the production backend off-TPU. Oracle and
kernel apply the IDENTICAL numeric transform (the kernel imports it from
ref.py); their stochastic draws differ only through the padded draw shape,
the same lawful-stream caveat as the Laplace kernels.

RNG contract: unlike the Laplace bits, the stochastic-rounding bits are
NOT privacy-critical (they perturb storage precision, never the DP
response), so they come from a cheap counter hash seeded by ONE scalar
threefry draw from the round key per encode (`ref.counter_bits`) — a
P-element threefry draw per round would cost more than the bank-carry
traffic the codec exists to cut.

Scales are per-row by default; ``block_elems`` switches to per-block f32
scales (the row is cut into ceil(P/block_elems) segments, each with its
own absmax scale — finer dynamic range for banks whose rows mix layer
magnitudes). Per-block runs on the oracle backend only; the kernel path
keeps the per-row (1,1)-scalar contract.

``deterministic=True`` replaces the stochastic bits with the exact-0.5
pattern (round-to-nearest): the reproducible, keyless encode used when a
bank is first materialized. All entry points are scan-body safe — scales
are traced, shapes static.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels.bank_codec.kernel import (LANES, decode_2d, encode_2d,
                                             row_scale_2d)
from repro.kernels.bank_codec.ref import (CODE_DTYPES, DECODERS, ENCODERS,
                                          QMAX, counter_bits, det_bits,
                                          row_scales_ref)

FORMATS = tuple(ENCODERS)


def _sr_bits(key, shape, deterministic: bool) -> jax.Array:
    """Stochastic-rounding bits for one encode: a (), uint32 seed from the
    round key (tiny threefry call) expanded by the cheap counter hash —
    a P-element threefry draw per round would cost more than the bank
    carry it is meant to save (see ref.counter_bits; SR bits are not
    privacy-critical)."""
    if deterministic:
        return det_bits(shape)
    return counter_bits(jax.random.bits(key, (), jnp.uint32), shape)


def code_dtype(fmt: str):
    if fmt not in CODE_DTYPES:
        raise ValueError(f"unknown bank codec {fmt!r} "
                         f"(supported: {', '.join(FORMATS)})")
    return CODE_DTYPES[fmt]


def _as_blocks(x: jax.Array, block_elems: Optional[int]
               ) -> Tuple[jax.Array, int]:
    """(P,) -> (nb, be) zero-padded view + the true P."""
    p = x.shape[0]
    be = p if block_elems is None else int(block_elems)
    pad = (-p) % be
    if pad:
        x = jnp.pad(x, (0, pad))
    return x.reshape(-1, be), p


def _pack2d(x: jax.Array, block_rows: int) -> Tuple[jax.Array, int]:
    """(P,) -> (R, LANES) zero-padded kernel view + the true P."""
    p = x.shape[0]
    per_block = block_rows * LANES
    pad = (-p) % per_block
    if pad:
        x = jnp.pad(x, (0, pad))
    return x.reshape(-1, LANES), p


def n_scales(p: int, block_elems: Optional[int]) -> int:
    return 1 if block_elems is None else -(-p // int(block_elems))


def encode_row(x: jax.Array, key, fmt: str, *,
               block_elems: Optional[int] = None,
               deterministic: bool = False, block_rows: int = 256,
               interpret=False):
    """Quantize one (P,) f32 row -> (codes (P,), scales (nb,), err (P,)).

    `err = x - decode(codes, scales)` in f32 — the error-feedback
    residual. Stochastic rounding is driven by `key` (ignored when
    `deterministic`, which rounds to nearest with the 0.5 pattern).
    """
    dt = code_dtype(fmt)
    if interpret == "oracle" or block_elems is not None:
        if block_elems is not None and interpret != "oracle":
            raise NotImplementedError(
                "per-block scales run on the oracle backend only "
                "(the kernel keeps the per-row scalar-scale contract)")
        x2, p = _as_blocks(x, block_elems)
        scales = row_scales_ref(x2, QMAX[fmt])                  # (nb,)
        bits = _sr_bits(key, x2.shape, deterministic)
        codes2, err2 = ENCODERS[fmt](x2, bits, scales[:, None])
        return (codes2.reshape(-1)[:p].astype(dt), scales,
                err2.reshape(-1)[:p])
    x2, p = _pack2d(x.astype(jnp.float32), block_rows)
    scale = row_scale_2d(x2, QMAX[fmt], block_rows=block_rows,
                         interpret=interpret)
    bits = _sr_bits(key, x2.shape, deterministic)
    codes2, err2 = encode_2d(x2, bits, scale.reshape(1, 1), fmt,
                             block_rows=block_rows, interpret=interpret)
    return (codes2.reshape(-1)[:p], scale.reshape(1),
            err2.reshape(-1)[:p])


def decode_row(codes: jax.Array, scales: jax.Array, fmt: str, *,
               block_elems: Optional[int] = None, block_rows: int = 256,
               interpret=False) -> jax.Array:
    """(P,) codes + (nb,) scales -> (P,) f32 row."""
    code_dtype(fmt)                                   # validate fmt
    if interpret == "oracle" or block_elems is not None:
        if block_elems is not None and interpret != "oracle":
            raise NotImplementedError(
                "per-block scales run on the oracle backend only")
        c2, p = _as_blocks(codes, block_elems)
        return DECODERS[fmt](c2, scales[:, None]).reshape(-1)[:p]
    c2, p = _pack2d(codes, block_rows)
    out = decode_2d(c2, scales.astype(jnp.float32).reshape(1, 1), fmt,
                    block_rows=block_rows, interpret=interpret)
    return out.reshape(-1)[:p]
