"""Pure-jnp oracle for the bank_codec kernels (bit-exact transform).

Two row codecs for the `(N_owners, P)` owner bank:

  int8 — symmetric linear code. q = floor(x/scale + u), clipped to
    [-127, 127]; decode is q * scale. `floor(v + u)` with u ~ U[0, 1) IS
    stochastic rounding (P[round up] == frac(v)), and u == 0.5 is the
    deterministic round-to-nearest used for bank init.
  fp8 — float8_e4m3fn. Stochastic rounding happens ON THE fp8 GRID: the
    two representable neighbours bracketing |x|/scale are found via uint8
    bit-pattern steps (the e4m3fn patterns of same-sign finite values are
    monotone), and the upper one is chosen with probability proportional
    to the distance from the lower. The sign rides as the top bit.

Both encoders also return the quantization error x - decode(encode(x)),
computed in f32 — the error-feedback residual the round engine folds into
the next granted update.

`u` is uniform in [0, 1) from the top 24 bits of uint32 random bits (the
same convention as dp_clip_noise's Laplace path), so the privacy-adjacent
RNG stays the jax.random stream of the round key.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

INT8_QMAX = 127.0
FP8_QMAX = 448.0          # largest finite float8_e4m3fn
_TINY = 1e-30             # scale floor: an all-zero row decodes to zeros


def u01_from_bits(bits: jnp.ndarray) -> jnp.ndarray:
    return (bits >> 8).astype(jnp.float32) * (1.0 / (1 << 24))


def det_bits(shape) -> jnp.ndarray:
    """The uint32 pattern whose u01 transform is exactly 0.5 — feeding
    these to either encoder makes it the deterministic round-to-nearest
    used for bank init (no key needed, reproducible)."""
    return jnp.full(shape, jnp.uint32(1) << 31, jnp.uint32)


def counter_bits(seed: jnp.ndarray, shape) -> jnp.ndarray:
    """Cheap counter-based uint32 stream: murmur3's fmix32 finalizer over
    (golden-ratio-striped counter + seed).

    Stochastic-rounding bits are NOT privacy-critical — they perturb
    storage precision, never the DP noise, which stays on the threefry
    stream — so the codec trades threefry's ~50 ops/word for ~7. The
    `seed` is a (), uint32 scalar drawn from the round key (one tiny
    threefry call per round instead of a P-element one); on TPU the
    in-kernel analogue is pltpu.prng_random_bits. Full-avalanche mixing,
    so consecutive counters give independent-looking rounding decisions.
    """
    n = 1
    for s in shape:
        n *= s
    i = jax.lax.iota(jnp.uint32, n)
    x = i * jnp.uint32(0x9E3779B9) + seed.astype(jnp.uint32)
    x = x ^ (x >> 16)
    x = x * jnp.uint32(0x85EBCA6B)
    x = x ^ (x >> 13)
    x = x * jnp.uint32(0xC2B2AE35)
    x = x ^ (x >> 16)
    return x.reshape(shape)


def row_scales_ref(x2d: jnp.ndarray, qmax: float) -> jnp.ndarray:
    """(nb, be) f32 -> (nb,) scales = absmax/qmax, floored away from 0."""
    return jnp.maximum(jnp.max(jnp.abs(x2d.astype(jnp.float32)), axis=-1),
                       _TINY) / qmax


def encode_int8_ref(x: jnp.ndarray, bits: jnp.ndarray, scale
                    ) -> tuple:
    """-> (codes int8, err f32) with err == x - codes*scale exactly."""
    xf = x.astype(jnp.float32)
    u = u01_from_bits(bits)
    q = jnp.clip(jnp.floor(xf / scale + u), -INT8_QMAX, INT8_QMAX)
    return q.astype(jnp.int8), xf - q * scale


def decode_int8_ref(codes: jnp.ndarray, scale) -> jnp.ndarray:
    return codes.astype(jnp.float32) * scale


# The fp8 transforms below work on the e4m3fn BIT PATTERNS with ordinary
# vectorized int/float ops instead of ml_dtypes casts: XLA:CPU lowers
# float8 astype to scalar library calls (~15x slower than the int8 path,
# measured), while frexp/ldexp/floor vectorize. The magnitude patterns of
# finite e4m3fn values are monotone, so bits+1 is the next grid point —
# and since the encoder clips to FP8_QMAX (0x7E), the NaN pattern 0x7F is
# never produced.

def _fp8_decode_mag(b8: jnp.ndarray) -> jnp.ndarray:
    """|value| of e4m3fn magnitude bit patterns (sign bit must be 0).

    Pure integer construction (frexp/ldexp lower to scalar libm calls on
    XLA:CPU): normal = (8+m) * 2^(e-10), with the power of two built
    directly as an f32 bit pattern ((e-10)+127 biased exponent)."""
    e = (b8 >> 3).astype(jnp.int32)
    m = (b8 & jnp.uint8(7)).astype(jnp.int32)
    two_pow = jax.lax.bitcast_convert_type(
        ((e + 117) << 23).astype(jnp.int32), jnp.float32)
    normal = (8 + m).astype(jnp.float32) * two_pow
    subnormal = m * jnp.float32(1.0 / (1 << 9))
    return jnp.where(e > 0, normal, subnormal)


def _fp8_floor_bits(a: jnp.ndarray) -> jnp.ndarray:
    """Largest e4m3fn magnitude pattern <= a (a in [0, FP8_QMAX]).

    Truncates the f32 bit pattern directly: for normal e4m3 values the
    f32 fields map as e = E - 120, m = top 3 mantissa bits (truncation
    IS floor for non-negative values)."""
    ab = jax.lax.bitcast_convert_type(a, jnp.int32)
    e = ((ab >> 23) & 0xFF) - 120            # e4m3 exponent field
    m = (ab >> 20) & 0x7                     # top 3 mantissa bits
    normal = ((e << 3) | m).astype(jnp.uint8)
    subnormal = jnp.floor(a * (1 << 9)).astype(jnp.uint8)
    return jnp.where(a < 1.0 / (1 << 6), subnormal, normal)


def fp8_sr(y: jnp.ndarray, u: jnp.ndarray) -> jnp.ndarray:
    """Stochastically round f32 onto the float8_e4m3fn grid. |y| must
    already be clipped to FP8_QMAX. Returns the uint8 BIT PATTERNS, not
    an f8-typed array: XLA:CPU scalar-emulates every op on float8 arrays
    (even select and scatter), so the codec keeps fp8 codes as raw bytes
    end to end and only materializes f32 values (`fp8_to_f32`)."""
    a = jnp.abs(y)
    lo8 = _fp8_floor_bits(a)
    hi8 = lo8 + jnp.uint8(1)
    lo = _fp8_decode_mag(lo8)
    hi = _fp8_decode_mag(hi8)
    p = jnp.where(a > lo, (a - lo) / (hi - lo), 0.0)
    out8 = jnp.where(u < p, hi8, lo8)
    return jnp.where(y < 0, out8 | jnp.uint8(0x80), out8)


def fp8_to_f32(codes: jnp.ndarray) -> jnp.ndarray:
    """Vectorized e4m3fn -> f32 (signed) from uint8 bit patterns (an
    f8-typed array is accepted and viewed as bytes), bypassing astype."""
    if codes.dtype != jnp.uint8:
        codes = jax.lax.bitcast_convert_type(codes, jnp.uint8)
    b = codes
    mag = _fp8_decode_mag(b & jnp.uint8(0x7F))
    return jnp.where((b >> 7) > 0, -mag, mag)


def encode_fp8_ref(x: jnp.ndarray, bits: jnp.ndarray, scale) -> tuple:
    """-> (codes float8_e4m3fn, err f32)."""
    xf = x.astype(jnp.float32)
    y = jnp.clip(xf / scale, -FP8_QMAX, FP8_QMAX)
    codes = fp8_sr(y, u01_from_bits(bits))
    return codes, xf - fp8_to_f32(codes) * scale


def decode_fp8_ref(codes: jnp.ndarray, scale) -> jnp.ndarray:
    return fp8_to_f32(codes) * scale


ENCODERS = {"int8": encode_int8_ref, "fp8": encode_fp8_ref}
DECODERS = {"int8": decode_int8_ref, "fp8": decode_fp8_ref}
QMAX = {"int8": INT8_QMAX, "fp8": FP8_QMAX}
# fp8 codes are stored as raw e4m3fn bit patterns (see fp8_sr)
CODE_DTYPES = {"int8": jnp.int8, "fp8": jnp.uint8}
