"""Pallas TPU kernel: intra-chunk SSD contraction (Mamba2 / mLSTM).

Per (batch, head, chunk) the kernel computes, entirely in VMEM:
    cum      = inclusive cumsum of log-decay within the chunk        (Q,)
    y_intra  = tril((q k^T) * exp(cum_i - cum_j)) @ (g * v)          (Q,P)
    h_add    = (k * exp(tot - cum) * g)^T @ v                        (N,P)
    dec_tot  = exp(tot)                                              (1,)
The inter-chunk recurrence (h = dec_tot*h + h_add; y += q*exp(cum) @ h_prev)
is a tiny sequential jnp scan in ops.py — the quadratic work lives here.

Blocks: Q<=256, N,P<=128 -> every operand tile fits VMEM (Q*N + Q*P + Q*Q
fp32 ~ 0.5 MB at Q=256, N=P=64).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _ssd_chunk_kernel(v_ref, k_ref, q_ref, ld_ref, g_ref,
                      y_ref, hadd_ref, cum_ref, tot_ref):
    v = v_ref[0, 0, 0].astype(jnp.float32)       # (Q, P)
    k = k_ref[0, 0, 0].astype(jnp.float32)       # (Q, N)
    q = q_ref[0, 0, 0].astype(jnp.float32)       # (Q, N)
    ld = ld_ref[0, 0, 0].astype(jnp.float32)     # (Q, 1)
    g = g_ref[0, 0, 0].astype(jnp.float32)       # (Q, 1)

    cum = jnp.cumsum(ld, axis=0)                 # (Q, 1) inclusive
    tot = cum[-1:, :]                            # (1, 1)
    Q = v.shape[0]

    qk = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)  # (Q,Q)
    ii = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 1)
    # mask BEFORE exp: above-diagonal differences are positive and overflow
    dec = jnp.exp(jnp.where(jj <= ii, cum - cum.T, -jnp.inf))
    gv = g * v                                   # (Q, P)
    y = jax.lax.dot_general(qk * dec, gv, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)

    w = jnp.exp(tot - cum)                       # (Q, 1)
    h_add = jax.lax.dot_general(k * w, gv, (((0,), (0,)), ((), ())),
                                preferred_element_type=jnp.float32)  # (N,P)

    y_ref[0, 0, 0] = y.astype(y_ref.dtype)
    hadd_ref[0, 0, 0] = h_add.astype(hadd_ref.dtype)
    cum_ref[0, 0, 0] = cum.astype(cum_ref.dtype)
    tot_ref[0, 0, 0] = tot[0].astype(tot_ref.dtype)


def ssd_chunk_scan(v: jax.Array, k: jax.Array, q: jax.Array, ld: jax.Array,
                   g: jax.Array, *, interpret: bool = False):
    """All inputs chunked: v (B,H,nc,Q,P); k,q (B,H,nc,Q,N);
    ld,g (B,H,nc,Q,1). Returns (y_intra, h_add, cum, tot)."""
    B, H, nc, Q, P = v.shape
    N = k.shape[-1]
    grid = (B, H, nc)
    def sp(*dims):
        return pl.BlockSpec((1, 1, 1) + dims,
                            lambda b, h, c: (b, h, c, 0, 0))
    y, hadd, cum, tot = pl.pallas_call(
        _ssd_chunk_kernel,
        grid=grid,
        in_specs=[sp(Q, P), sp(Q, N), sp(Q, N), sp(Q, 1), sp(Q, 1)],
        out_specs=[sp(Q, P), sp(N, P), sp(Q, 1),
                   pl.BlockSpec((1, 1, 1, 1), lambda b, h, c: (b, h, c, 0))],
        out_shape=[
            jax.ShapeDtypeStruct((B, H, nc, Q, P), jnp.float32),
            jax.ShapeDtypeStruct((B, H, nc, N, P), jnp.float32),
            jax.ShapeDtypeStruct((B, H, nc, Q, 1), jnp.float32),
            jax.ShapeDtypeStruct((B, H, nc, 1), jnp.float32),
        ],
        interpret=interpret,
    )(v, k, q, ld, g)
    return y, hadd, cum, tot
