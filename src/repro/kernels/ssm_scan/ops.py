"""jit'd wrapper: full chunked SSD scan with the Pallas intra-chunk kernel.

Mirrors repro.models.ssm.ssd_chunked's signature so the model can swap
implementations (`use_pallas` plumbed from the model when running on TPU).
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.kernels.ssm_scan.kernel import ssd_chunk_scan


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_chunked_pallas(v: jax.Array, ld: jax.Array, k: jax.Array,
                       q: jax.Array, g: jax.Array, *, chunk: int,
                       interpret: bool = False
                       ) -> Tuple[jax.Array, jax.Array]:
    """Same contract as models.ssm.ssd_chunked."""
    B, S, H, P = v.shape
    N = k.shape[-1]
    Q = min(chunk, S)
    pad = (-S) % Q
    if pad:
        def zpad(a):
            return jnp.pad(a, [(0, 0), (0, pad)] + [(0, 0)] * (a.ndim - 2))
        v, k, q = zpad(v), zpad(k), zpad(q)
        g = jnp.pad(g, ((0, 0), (0, pad), (0, 0)))
        ld = jnp.pad(ld, ((0, 0), (0, pad), (0, 0)))
    Sp = S + pad
    nc = Sp // Q

    def chunked(a, feat):
        # (B,S,H,F) -> (B,H,nc,Q,F)
        if feat:
            return a.reshape(B, nc, Q, H, a.shape[-1]).transpose(0, 3, 1, 2, 4)
        return a.reshape(B, nc, Q, H, 1).transpose(0, 3, 1, 2, 4)

    vc = chunked(v, True)
    kc = chunked(k, True)
    qc = chunked(q, True)
    ldc = chunked(ld[..., None], False)
    gc = chunked(g[..., None], False)

    y_in, h_add, cum, tot = ssd_chunk_scan(vc, kc, qc, ldc, gc,
                                           interpret=interpret)

    # inter-chunk recurrence over nc (small, sequential)
    def step(h, xs):
        hadd_c, tot_c = xs                       # (B,H,N,P), (B,H,1)
        h_new = jnp.exp(tot_c)[..., None] * h + hadd_c
        return h_new, h

    h0 = jnp.zeros((B, H, N, P), jnp.float32)
    hs_in = (h_add.transpose(2, 0, 1, 3, 4), tot.transpose(2, 0, 1, 3))
    h_fin, h_prevs = jax.lax.scan(step, h0, hs_in)   # h_prevs: (nc,B,H,N,P)

    q_dec = qc.astype(jnp.float32) * jnp.exp(cum)    # (B,H,nc,Q,N)
    y_st = jnp.einsum("bhcqn,cbhnp->bhcqp", q_dec, h_prevs)
    y = (y_in + y_st).transpose(0, 2, 3, 1, 4).reshape(B, Sp, H, P)[:, :S]
    return y.astype(v.dtype), h_fin
