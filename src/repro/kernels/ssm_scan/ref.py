"""Oracle for the ssm_scan kernel: the pure-jnp generalized SSD scan from
repro.models.ssm (the model's own reference path)."""
from repro.models.ssm import ssd_chunked, ssd_step  # noqa: F401


def ssd_ref(v, ld, k, q, g, *, chunk):
    """v: (B,S,H,P); ld,g: (B,S,H); k,q: (B,S,H,N) -> (y, h_final)."""
    return ssd_chunked(v, ld, k, q, g, chunk=chunk)
