"""Qwen3-30B-A3B — fine-grained MoE, 128 experts top-8, d_expert=768
[hf:Qwen/Qwen3-30B-A3B]."""
from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="qwen3-moe-30b-a3b",
    family="moe",
    n_layers=48,
    d_model=2048,
    n_heads=32,
    n_kv_heads=4,
    d_ff=768,                      # per-expert hidden dim per assignment
    vocab=151936,
    head_dim=128,                  # qwen3 uses hd=128 (> d_model/n_heads)
    moe=MoEConfig(n_experts=128, top_k=8, d_expert=768),
    rope_theta=1e6,
    source="hf:Qwen/Qwen3-30B-A3B",
)
