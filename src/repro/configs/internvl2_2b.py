"""InternVL2-2B — InternViT frontend (stubbed) + InternLM2 LLM backbone
[arXiv:2404.16821]. The vision encoder is a STUB per the carve-out:
``input_specs`` provides 256 precomputed patch embeddings per image.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-2b",
    family="vlm",
    n_layers=24,
    d_model=2048,
    n_heads=16,
    n_kv_heads=8,
    d_ff=8192,
    vocab=92553,
    n_patches=256,
    source="arXiv:2404.16821",
)
