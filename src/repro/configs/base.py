"""Config system: model architecture + input-shape configs.

Every assigned architecture gets one file in this package exporting
``CONFIG: ModelConfig`` with the exact published dimensions (source cited in
the file). ``ModelConfig.reduced()`` produces the CPU-smoke variant
(<=2 layers, d_model<=512, <=4 experts) used by tests.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

Family = str  # 'dense' | 'moe' | 'ssm' | 'hybrid' | 'vlm' | 'audio'


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_expert: int  # per-expert hidden dim
    router_jitter: float = 0.0
    load_balance_coef: float = 0.01


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    """Mamba2 (SSD) block dims."""
    d_state: int = 64
    d_conv: int = 4
    expand: int = 2          # d_inner = expand * d_model
    head_dim: int = 64       # SSD head dim; n_ssm_heads = d_inner // head_dim
    chunk: int = 256         # chunked-scan block length


@dataclasses.dataclass(frozen=True)
class XLSTMConfig:
    slstm_indices: Tuple[int, ...] = ()   # which layers are sLSTM (rest mLSTM)
    mlstm_proj_factor: float = 2.0
    slstm_proj_factor: float = 4.0 / 3.0
    conv_kernel: int = 4


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: Family
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: Optional[int] = None          # default d_model // n_heads
    qkv_bias: bool = False
    tie_embeddings: bool = False
    rope_theta: float = 10000.0
    norm_eps: float = 1e-5
    sliding_window: Optional[int] = None    # native SWA (mixtral)
    # sub-quadratic override used ONLY for the long_500k shape on archs with
    # full attention; recorded in DESIGN.md §Arch-applicability.
    long_context_override: Optional[int] = 8192
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    xlstm: Optional[XLSTMConfig] = None
    # hybrid (zamba2): a *shared* full-attention block applied every
    # `attn_every` layers, on top of the per-layer Mamba2 blocks.
    attn_every: Optional[int] = None
    # enc-dec (whisper): encoder depth and fixed encoder sequence length
    # (frames after the stubbed conv frontend).
    enc_layers: int = 0
    enc_seq: int = 0
    # vlm (internvl2): number of patch embeddings prepended by the stubbed
    # vision frontend.
    n_patches: int = 0
    source: str = ""                        # citation

    def __post_init__(self):
        if self.head_dim is None:
            object.__setattr__(self, "head_dim", self.d_model // self.n_heads)
        assert self.n_heads % max(self.n_kv_heads, 1) == 0, self.name

    # ---- derived -----------------------------------------------------
    @property
    def is_decoder(self) -> bool:
        return True  # every assigned arch has a decoder

    def param_count(self) -> int:
        """Analytic parameter count (matches init within ties/norms)."""
        d, hd, H, Kv = self.d_model, self.head_dim, self.n_heads, self.n_kv_heads
        emb = self.vocab * d
        out = 0 if self.tie_embeddings else self.vocab * d
        per_layer = 0
        if self.family in ("dense", "moe", "vlm", "audio"):
            attn = d * H * hd + 2 * d * Kv * hd + H * hd * d
            if self.qkv_bias:
                attn += (H + 2 * Kv) * hd
            if self.moe is not None:
                ffn = self.moe.n_experts * 3 * d * self.moe.d_expert + d * self.moe.n_experts
            else:
                ffn = 3 * d * self.d_ff
            per_layer = attn + ffn + 2 * d
        elif self.family == "ssm":  # xlstm
            x = self.xlstm or XLSTMConfig()
            dm = int(d * x.mlstm_proj_factor)
            per_layer = 2 * d * dm + dm * d // 2  # rough: up/gate/down + qkv-ish
        elif self.family == "hybrid":
            s = self.ssm or SSMConfig()
            d_in = s.expand * d
            per_layer = d * (2 * d_in) + d_in * d + d_in * 2 * s.d_state
            attn = d * H * hd + 2 * d * Kv * hd + H * hd * d  # shared once
            return emb + out + self.n_layers * per_layer + attn
        total = emb + out + self.n_layers * per_layer
        if self.enc_layers:
            attn = d * H * hd * 4
            total += self.enc_layers * (attn + 2 * d * self.d_ff + 2 * d)
        return total

    def active_param_count(self) -> int:
        """Params touched per token (MoE: top_k of n_experts)."""
        if self.moe is None:
            return self.param_count()
        m = self.moe
        dense_like = self.param_count()
        all_exp = self.n_layers * m.n_experts * 3 * self.d_model * m.d_expert
        act_exp = self.n_layers * m.top_k * 3 * self.d_model * m.d_expert
        return dense_like - all_exp + act_exp

    # ---- smoke variant ----------------------------------------------
    def reduced(self) -> "ModelConfig":
        """2-layer, d_model<=512, <=4-expert variant of the same family."""
        d = min(self.d_model, 256)
        H = min(self.n_heads, 4)
        ratio = max(1, self.n_heads // max(self.n_kv_heads, 1))
        Kv = max(1, H // ratio)
        kw = dict(
            name=self.name + "-smoke",
            family=self.family,
            n_layers=2,
            d_model=d,
            n_heads=H,
            n_kv_heads=Kv,
            d_ff=min(self.d_ff, 512) if self.d_ff else 0,
            vocab=min(self.vocab, 512),
            head_dim=d // H,
            qkv_bias=self.qkv_bias,
            rope_theta=self.rope_theta,
            sliding_window=min(self.sliding_window, 64) if self.sliding_window else None,
            long_context_override=64 if self.long_context_override else None,
            source=self.source,
        )
        if self.moe:
            kw["moe"] = dataclasses.replace(
                self.moe, n_experts=min(self.moe.n_experts, 4),
                top_k=min(self.moe.top_k, 2), d_expert=min(self.moe.d_expert, 128))
        if self.ssm:
            kw["ssm"] = dataclasses.replace(
                self.ssm, d_state=min(self.ssm.d_state, 16), head_dim=32, chunk=32)
        if self.xlstm:
            kw["xlstm"] = dataclasses.replace(self.xlstm, slstm_indices=(1,))
        if self.attn_every:
            kw["attn_every"] = 2
        if self.enc_layers:
            kw["enc_layers"] = 2
            kw["enc_seq"] = 16
        if self.n_patches:
            kw["n_patches"] = 4
        return ModelConfig(**kw)


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # 'train' | 'prefill' | 'decode'


INPUT_SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}
