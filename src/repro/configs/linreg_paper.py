"""The paper's own experimental models (Section 5).

Linear regression y = theta^T x with
  g(theta)  = 1e-5 * ||theta||^2          (strongly convex regulariser)
  loss      = ||y - theta^T x||^2
on ~10 PCA features. Two dataset stand-ins (offline container -> synthetic
generators matching the published dimensions and statistics):
  - 'lending': Lending Club interest-rate regression (Fig. 2-6)
  - 'health' : NY SPARCS length-of-stay regression  (Fig. 7-10)
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class PaperConfig:
    name: str = "linreg-paper"
    n_features: int = 10           # top-10 PCA features (Sec. 5.1.1)
    reg_coef: float = 1e-5         # g(theta) = reg_coef * theta^T theta
    theta_max: float = 10.0        # Theta = {||theta||_inf <= theta_max}
    horizon: int = 1000            # T
    rho: float = 1.0               # Algorithm 1 step-size knob (alpha = rho/T^2)
    dataset: str = "lending"       # 'lending' | 'health'

    @property
    def sigma(self) -> float:
        """Strong-convexity modulus of g (g = c*||theta||^2 -> sigma=2c)."""
        return 2.0 * self.reg_coef


LENDING = PaperConfig(dataset="lending")
HEALTH = PaperConfig(dataset="health")
CONFIG = LENDING
