from repro.configs.base import (
    INPUT_SHAPES,
    ModelConfig,
    MoEConfig,
    ShapeConfig,
    SSMConfig,
    XLSTMConfig,
)
from repro.configs.registry import all_configs, get_config, get_shape, list_archs

__all__ = [
    "INPUT_SHAPES",
    "ModelConfig",
    "MoEConfig",
    "ShapeConfig",
    "SSMConfig",
    "XLSTMConfig",
    "all_configs",
    "get_config",
    "get_shape",
    "list_archs",
]
