"""Whisper-medium — encoder-decoder backbone [arXiv:2212.04356].

The mel-spectrogram + conv frontend is a STUB per the carve-out:
``input_specs`` supplies 1500 precomputed frame embeddings (B, 1500, 1024).
n_layers=24 is the decoder depth; the encoder is 24 layers as well.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-medium",
    family="audio",
    n_layers=24,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    d_ff=4096,
    vocab=51865,
    enc_layers=24,
    enc_seq=1500,
    source="arXiv:2212.04356",
)
