"""Zamba2-2.7B — Mamba2 backbone + shared attention blocks [arXiv:2411.15242].

54 Mamba2 layers, d_model=2560; a single *shared* full-attention block
(32 heads, kv=32) is applied every 6 layers (weights reused — Zamba's
signature parameter-sharing trick). ssm_state=64.
"""
from repro.configs.base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="zamba2-2.7b",
    family="hybrid",
    n_layers=54,
    d_model=2560,
    n_heads=32,
    n_kv_heads=32,
    d_ff=10240,
    vocab=32000,
    ssm=SSMConfig(d_state=64, d_conv=4, expand=2, head_dim=64, chunk=256),
    attn_every=6,
    long_context_override=8192,  # shared-attn blocks window at 500k
    source="arXiv:2411.15242",
)
