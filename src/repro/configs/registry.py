"""Architecture registry: ``--arch <id>`` resolution."""
from __future__ import annotations

import importlib
from typing import Dict, List

from repro.configs.base import ModelConfig, ShapeConfig, INPUT_SHAPES

_ARCH_MODULES = {
    "zamba2-2.7b": "repro.configs.zamba2_2p7b",
    "mixtral-8x22b": "repro.configs.mixtral_8x22b",
    "internvl2-2b": "repro.configs.internvl2_2b",
    "qwen1.5-110b": "repro.configs.qwen1p5_110b",
    "yi-6b": "repro.configs.yi_6b",
    "whisper-medium": "repro.configs.whisper_medium",
    "xlstm-125m": "repro.configs.xlstm_125m",
    "granite-20b": "repro.configs.granite_20b",
    "qwen3-moe-30b-a3b": "repro.configs.qwen3_moe_30b_a3b",
    "command-r-35b": "repro.configs.command_r_35b",
}


def list_archs() -> List[str]:
    return sorted(_ARCH_MODULES)


def get_config(arch: str) -> ModelConfig:
    if arch not in _ARCH_MODULES:
        raise KeyError(f"unknown arch {arch!r}; known: {list_archs()}")
    return importlib.import_module(_ARCH_MODULES[arch]).CONFIG


def get_shape(name: str) -> ShapeConfig:
    return INPUT_SHAPES[name]


def all_configs() -> Dict[str, ModelConfig]:
    return {a: get_config(a) for a in list_archs()}
