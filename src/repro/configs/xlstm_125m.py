"""xLSTM-125M — sLSTM + mLSTM blocks [arXiv:2405.04517].

12 blocks at ratio ~7:1 mLSTM:sLSTM -> sLSTM at layer index 6.
d_ff=0: xLSTM blocks carry their own gated up/down projections.
"""
from repro.configs.base import ModelConfig, XLSTMConfig

CONFIG = ModelConfig(
    name="xlstm-125m",
    family="ssm",
    n_layers=12,
    d_model=768,
    n_heads=4,
    n_kv_heads=4,
    d_ff=0,
    vocab=50304,
    head_dim=192,
    xlstm=XLSTMConfig(slstm_indices=(6,)),
    long_context_override=None,  # recurrent: natively O(1)-state decode
    source="arXiv:2405.04517",
)
