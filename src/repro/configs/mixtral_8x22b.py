"""Mixtral-8x22B — sparse MoE, 8 experts top-2, SWA [arXiv:2401.04088]."""
from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="mixtral-8x22b",
    family="moe",
    n_layers=56,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_ff=16384,
    vocab=32768,
    sliding_window=4096,          # native SWA -> long_500k is sub-quadratic
    long_context_override=None,   # not needed: native window
    moe=MoEConfig(n_experts=8, top_k=2, d_expert=16384),
    rope_theta=1e6,
    source="arXiv:2401.04088",
)
