"""Roofline-term derivation from dry-run artifacts (no real hardware).

Inputs: `compiled.cost_analysis()` (per-device FLOPs / bytes for the SPMD-
partitioned module) + collective operand bytes parsed from the
post-optimization HLO text. Terms (TPU v5e):

    compute    = flops_per_device / PEAK_FLOPS       [s]
    memory     = bytes_per_device / HBM_BW           [s]
    collective = coll_bytes_per_device / ICI_BW      [s]

Note on normalization: cost_analysis runs on the per-device partitioned
module, so dividing by per-chip peaks is identical to the spec's
"HLO_FLOPs_total / (chips * peak)".
"""
from __future__ import annotations

import re
from typing import Dict

PEAK_FLOPS = 197e12          # bf16 / chip
HBM_BW = 819e9               # bytes/s / chip
ICI_BW = 50e9                # bytes/s per link

_DTYPE_BYTES = {
    "pred": 1, "s4": 0.5, "u4": 0.5, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "s32": 4, "u32": 4, "s64": 8, "u64": 8, "f8e4m3fn": 1, "f8e5m2": 1,
    "bf16": 2, "f16": 2, "f32": 4, "f64": 8, "c64": 8, "c128": 16,
}
_SHAPE_RE = re.compile(
    r"\b(" + "|".join(_DTYPE_BYTES) + r")\[([0-9,]*)\]")
_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")


def _shape_bytes(dtype: str, dims: str) -> float:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES[dtype]


def parse_collective_bytes(hlo_text: str) -> Dict[str, float]:
    """Sum operand bytes of every collective op in post-optimization HLO.

    `-start` variants are counted; their `-done` halves are skipped so
    async collectives are not double-counted.
    """
    out: Dict[str, float] = {c: 0.0 for c in _COLLECTIVES}
    for line in hlo_text.splitlines():
        if " = " not in line:
            continue
        rhs = line.split(" = ", 1)[1]
        m = re.match(r"(?:\(?[a-z0-9_\[\],\s/]*\)?\s+)?([a-z0-9-]+)\(", rhs)
        # robust: find the op token right before the first '('
        call = rhs.find("(")
        if call < 0:
            continue
        head = rhs[:call].strip()
        op = head.split()[-1] if head else ""
        base = None
        for c in _COLLECTIVES:
            if op == c or op == c + "-start":
                base = c
                break
        if base is None:
            continue
        operands = rhs[call:]
        for dm in _SHAPE_RE.finditer(operands):
            out[base] += _shape_bytes(dm.group(1), dm.group(2))
    out["total"] = sum(out[c] for c in _COLLECTIVES)
    return out


def roofline_terms(flops_per_dev: float, bytes_per_dev: float,
                   coll_bytes_per_dev: float) -> Dict[str, float]:
    compute = flops_per_dev / PEAK_FLOPS
    memory = bytes_per_dev / HBM_BW
    coll = coll_bytes_per_dev / ICI_BW
    dom = max((compute, "compute"), (memory, "memory"),
              (coll, "collective"))[1]
    return {"compute_s": compute, "memory_s": memory, "collective_s": coll,
            "dominant": dom,
            "step_lower_bound_s": max(compute, memory, coll)}


def model_flops(n_params_active: int, tokens: int, kind: str) -> float:
    """6*N*D (train: fwd+bwd) or 2*N*D (inference fwd only)."""
    per_tok = 6 if kind == "train" else 2
    return float(per_tok) * n_params_active * tokens
