"""Recompute roofline terms from SAVED dry-run HLO (no recompilation).

    PYTHONPATH=src python -m repro.analysis.reanalyze [--dir results/dryrun]

Used when the cost model in hlo_cost.py changes: the dry-run campaign saves
results/dryrun/hlo/<tag>.hlo.zst; this rewrites every JSON's hlo_walker +
roofline sections in place.
"""
from __future__ import annotations

import argparse
import glob
import json
import os

import zstandard

from repro.analysis.hlo_cost import analyze
from repro.analysis.roofline import model_flops, roofline_terms
from repro.configs import INPUT_SHAPES, get_config


def reanalyze_one(json_path: str) -> bool:
    with open(json_path) as f:
        rec = json.load(f)
    if not rec.get("ok"):
        return False
    tag = os.path.basename(json_path)[:-len(".json")]
    hlo_path = os.path.join(os.path.dirname(json_path), "hlo",
                            tag + ".hlo.zst")
    if not os.path.exists(hlo_path):
        return False
    with open(hlo_path, "rb") as f:
        hlo = zstandard.ZstdDecompressor().decompress(f.read()).decode()
    walked = analyze(hlo)
    rec["hlo_walker"] = walked
    shape = INPUT_SHAPES[rec["shape"]]
    cfg = get_config(rec["arch"])
    terms = roofline_terms(walked["flops"], walked["traffic_bytes"],
                           walked["collective_bytes_total"])
    tokens = shape.global_batch * (shape.seq_len
                                   if shape.kind != "decode" else 1)
    mf = model_flops(cfg.active_param_count(), tokens,
                     "train" if shape.kind == "train" else "infer")
    terms["model_flops_total"] = mf
    terms["hlo_flops_total"] = walked["flops"] * rec["chips"]
    terms["useful_flops_ratio"] = (mf / (walked["flops"] * rec["chips"])
                                   if walked["flops"] else 0.0)
    rec["roofline"] = terms
    with open(json_path, "w") as f:
        json.dump(rec, f, indent=1)
    return True


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="results/dryrun")
    args = ap.parse_args()
    n = 0
    for p in sorted(glob.glob(os.path.join(args.dir, "*.json"))):
        if reanalyze_one(p):
            n += 1
            print("reanalyzed", os.path.basename(p))
    print(f"{n} records updated")


if __name__ == "__main__":
    main()
