"""Emit EXPERIMENTS.md §Dry-run / §Roofline markdown tables from the
dry-run artifacts.

    PYTHONPATH=src python -m repro.analysis.report [--dir results/dryrun]
"""
from __future__ import annotations

import argparse
import glob
import json
import os


def fmt_bytes(b: float) -> str:
    for unit in ("B", "KiB", "MiB", "GiB", "TiB"):
        if b < 1024 or unit == "TiB":
            return f"{b:.1f}{unit}"
        b /= 1024
    return f"{b:.1f}TiB"


def load(d, include_variants: bool = False):
    recs = []
    for p in sorted(glob.glob(os.path.join(d, "*.json"))):
        with open(p) as f:
            r = json.load(f)
        if r.get("variant") and not include_variants:
            continue   # §Perf A/B runs live in their own table
        recs.append(r)
    return recs


def dryrun_table(recs, mesh):
    out = ["| arch | shape | ok | lower s | compile s | arg bytes/dev | "
           "temp bytes/dev | coll bytes/dev |",
           "|---|---|---|---|---|---|---|---|"]
    for r in recs:
        if r["mesh"] != mesh:
            continue
        ma = r.get("memory_analysis") or {}
        w = r.get("hlo_walker", {})
        out.append(
            f"| {r['arch']} | {r['shape']} | {'YES' if r['ok'] else 'NO'} "
            f"| {r.get('lower_s', '-')} | {r.get('compile_s', '-')} "
            f"| {fmt_bytes(ma.get('argument_size_in_bytes', 0))} "
            f"| {fmt_bytes(ma.get('temp_size_in_bytes', 0))} "
            f"| {fmt_bytes(w.get('collective_bytes_total', 0))} |")
    return "\n".join(out)


def roofline_table(recs):
    out = ["| arch | shape | compute s | memory s | collective s | dominant "
           "| model TFLOPs | HLO/model | what would move the dominant term |",
           "|---|---|---|---|---|---|---|---|---|"]
    hints = {
        ("train", "memory"): "fp32 score traffic in blockwise attention -> "
                             "bf16 operands / Pallas flash (VMEM-resident)",
        ("prefill", "memory"): "same: attention score materialization; "
                               "Pallas flash kernel",
        ("decode", "memory"): "KV-cache streaming is intrinsic; "
                              "quantized (int8) cache halves it",
        ("train", "collective"): "fewer microbatches / hoist FSDP gathers",
        ("train", "compute"): "remat policy (save dots)",
    }
    for r in recs:
        if r["mesh"] != "pod16x16" or not r.get("ok"):
            continue
        rf = r["roofline"]
        kind = ("train" if r["shape"] == "train_4k"
                else "prefill" if r["shape"] == "prefill_32k" else "decode")
        hint = hints.get((kind, rf["dominant"]), "-")
        ratio = rf["useful_flops_ratio"]
        inv = 1.0 / ratio if ratio else float("inf")
        out.append(
            f"| {r['arch']} | {r['shape']} | {rf['compute_s']:.3g} "
            f"| {rf['memory_s']:.3g} | {rf['collective_s']:.3g} "
            f"| **{rf['dominant']}** | {rf['model_flops_total']/1e12:.3g} "
            f"| {inv:.2f}x | {hint} |")
    return "\n".join(out)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="results/dryrun")
    ap.add_argument("--section", choices=["dryrun", "roofline", "both"],
                    default="both")
    args = ap.parse_args()
    recs = load(args.dir)
    if args.section in ("dryrun", "both"):
        print("### Single-pod mesh (16 x 16 = 256 chips)\n")
        print(dryrun_table(recs, "pod16x16"))
        print("\n### Multi-pod mesh (2 x 16 x 16 = 512 chips)\n")
        print(dryrun_table(recs, "pod2x16x16"))
    if args.section in ("roofline", "both"):
        print("\n### Roofline (single-pod, per step)\n")
        print(roofline_table(recs))


if __name__ == "__main__":
    main()
