from repro.analysis.roofline import (HBM_BW, ICI_BW, PEAK_FLOPS, model_flops,
                                     parse_collective_bytes, roofline_terms)
