"""HLO-text cost walker: loop-aware FLOPs / traffic / collective bytes.

XLA-CPU's `compiled.cost_analysis()` counts while-loop *bodies once*,
so scanned-layer models are undercounted by ~(layers x microbatches x ...).
This walker parses the post-optimization HLO text, builds the computation
call graph, extracts loop trip counts from while-condition constants, and
accumulates per-device:

    flops            — 2 * prod(result dims) * prod(contracted dims) per dot
    traffic_bytes    — sum over instructions of (result + operand bytes);
                       fusion internals are NOT descended (post-fusion HBM
                       traffic proxy). Approximate: in-place updates
                       (donated buffers) are counted at full size.
    collective_bytes — operand bytes of all-reduce / all-gather /
                       reduce-scatter / all-to-all / collective-permute,
                       multiplied through enclosing loop trip counts.

Known approximations (documented in EXPERIMENTS.md):
  * `conditional` branches are costed at max-over-branches;
  * trip count = largest integer constant in the while condition
    computation (exact for jax.lax.scan/fori loops);
  * elementwise flops ignored (dot/conv dominate at these scales).
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional

_DTYPE_BYTES = {
    "pred": 1, "s2": 0.25, "u2": 0.25, "s4": 0.5, "u4": 0.5, "s8": 1, "u8": 1,
    "s16": 2, "u16": 2, "s32": 4, "u32": 4, "s64": 8, "u64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1, "f8e3m4": 1, "f8e8m0fnu": 1,
    "f4e2m1fn": 0.5, "bf16": 2, "f16": 2, "f32": 4, "f64": 8,
    "c64": 8, "c128": 16, "token": 0, "opaque": 0,
}
_SHAPE_RE = re.compile(r"\b(" + "|".join(sorted(_DTYPE_BYTES, key=len,
                                                reverse=True))
                       + r")\[([0-9,]*)\]")
_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute", "ragged-all-to-all")
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s+=\s+(.*)$")
_COMP_HDR = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*\((.*?)\)\s*->")
_CALL_ATTR = re.compile(
    r"(?:calls=|to_apply=|condition=|body=|branch_computations=\{|"
    r"true_computation=|false_computation=)")
# ops that materialize to HBM even under perfect elementwise fusion on TPU
_MATERIALIZING = frozenset({
    "copy", "transpose", "reshape", "dynamic-slice", "dynamic-update-slice",
    "gather", "scatter", "concatenate", "pad", "slice", "reverse",
    "broadcast-to", "rng", "rng-bit-generator", "cumsum", "iota-large",
})


def _shape_dims(dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n


def _shapes_bytes(text: str) -> float:
    return sum(_DTYPE_BYTES[m.group(1)] * _shape_dims(m.group(2))
               for m in _SHAPE_RE.finditer(text))


@dataclasses.dataclass
class Instr:
    name: str
    result_type: str
    op: str
    rhs: str


@dataclasses.dataclass
class Computation:
    name: str
    instrs: List[Instr]
    is_fused: bool = False


def parse_hlo(text: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    for raw in text.splitlines():
        line = raw.rstrip()
        s = line.strip()
        if not s or s.startswith("//") or s.startswith("HloModule"):
            continue
        hdr = _COMP_HDR.match(line)
        if hdr and line.endswith("{") and " = " not in line.split("->")[0]:
            name = hdr.group(1)
            cur = Computation(name, [],
                              is_fused=name.startswith("fused_computation")
                              or ".fused" in name)
            comps[name] = cur
            continue
        if s == "}" or s.startswith("}"):
            continue
        if cur is None:
            continue
        m = _DEF_RE.match(s)
        if not m:
            continue
        name, rest = m.group(1), m.group(2)
        # result type: either `dtype[dims]{layout}` or a tuple `(t1, t2, ...)`
        if rest.startswith("("):
            depth = 0
            end = 0
            for i, ch in enumerate(rest):
                if ch == "(":
                    depth += 1
                elif ch == ")":
                    depth -= 1
                    if depth == 0:
                        end = i + 1
                        break
            result_type = rest[:end]
            tail = rest[end:].strip()
        else:
            sp = rest.find(" ")
            if sp < 0:
                continue
            result_type = rest[:sp]
            tail = rest[sp + 1:].strip()
        call = tail.find("(")
        if call < 0:
            continue
        op = tail[:call].strip().split()[-1] if tail[:call].strip() else ""
        cur.instrs.append(Instr(name, result_type, op, tail))
    return comps


def _dot_flops(instr: Instr, symtab: Dict[str, str]) -> float:
    out_elems = 1
    for m in _SHAPE_RE.finditer(instr.result_type):
        out_elems *= _shape_dims(m.group(2))
    # contracted size: from lhs operand shape + lhs_contracting_dims
    ops = re.findall(r"%([\w\.\-]+)", instr.rhs[:instr.rhs.find(")")])
    cd = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", instr.rhs)
    contracted = 1
    if ops and cd:
        lhs_type = symtab.get(ops[0], "")
        sm = _SHAPE_RE.search(lhs_type)
        if sm:
            dims = [int(d) for d in sm.group(2).split(",") if d]
            for i in (int(x) for x in cd.group(1).split(",") if x):
                if i < len(dims):
                    contracted *= dims[i]
    return 2.0 * out_elems * contracted


def _operand_bytes(instr: Instr, symtab: Dict[str, str]) -> float:
    args = instr.rhs[instr.rhs.find("(") + 1:]
    depth = 1
    out = []
    for i, ch in enumerate(args):
        if ch == "(":
            depth += 1
        elif ch == ")":
            depth -= 1
            if depth == 0:
                args = args[:i]
                break
    total = 0.0
    for name in re.findall(r"%([\w\.\-]+)", args):
        total += _shapes_bytes(symtab.get(name, ""))
    return total


@dataclasses.dataclass
class Cost:
    flops: float = 0.0
    traffic: float = 0.0
    collectives: Dict[str, float] = dataclasses.field(
        default_factory=lambda: {c: 0.0 for c in _COLLECTIVES})

    def scaled(self, k: float) -> "Cost":
        return Cost(self.flops * k, self.traffic * k,
                    {c: v * k for c, v in self.collectives.items()})

    def add(self, other: "Cost"):
        self.flops += other.flops
        self.traffic += other.traffic
        for c, v in other.collectives.items():
            self.collectives[c] = self.collectives.get(c, 0.0) + v

    @property
    def collective_total(self) -> float:
        return sum(self.collectives.values())


class HloCostModel:
    def __init__(self, text: str):
        self.comps = parse_hlo(text)
        self._memo: Dict[str, Cost] = {}
        m = re.search(r"ENTRY\s+%?([\w\.\-]+)", text)
        if m and m.group(1) in self.comps:
            self.entry = m.group(1)
        else:
            self.entry = next((n for n in self.comps if n.startswith("main")),
                              next(iter(self.comps)))

    def _trip_count(self, cond_name: str) -> float:
        comp = self.comps.get(cond_name)
        if comp is None:
            return 1.0
        best = 1
        for ins in comp.instrs:
            if ins.op == "constant":
                m = re.search(r"constant\((-?\d+)\)", ins.rhs)
                if m:
                    best = max(best, int(m.group(1)))
        return float(best)

    def cost_of(self, comp_name: str) -> Cost:
        if comp_name in self._memo:
            return self._memo[comp_name]
        self._memo[comp_name] = Cost()  # cycle guard
        comp = self.comps.get(comp_name)
        if comp is None:
            return self._memo[comp_name]
        total = Cost()
        symtab = {i.name: i.result_type for i in comp.instrs}
        # parameters' types appear on their defs too (parameter(k) ops)
        for ins in comp.instrs:
            op = ins.op
            if op == "dot":
                total.flops += _dot_flops(ins, symtab)
                total.traffic += (_shapes_bytes(ins.result_type)
                                  + _operand_bytes(ins, symtab))
            elif op == "convolution":
                # rough: 2 * out_elems * (kernel elems) — rare in this code
                out_elems = 1
                for m in _SHAPE_RE.finditer(ins.result_type):
                    out_elems *= _shape_dims(m.group(2))
                total.flops += 2.0 * out_elems
                total.traffic += (_shapes_bytes(ins.result_type)
                                  + _operand_bytes(ins, symtab))
            elif op == "while":
                cond = re.search(r"condition=%?([\w\.\-]+)", ins.rhs)
                body = re.search(r"body=%?([\w\.\-]+)", ins.rhs)
                trips = self._trip_count(cond.group(1)) if cond else 1.0
                if body:
                    total.add(self.cost_of(body.group(1)).scaled(trips))
                if cond:
                    total.add(self.cost_of(cond.group(1)).scaled(trips))
            elif op == "conditional":
                branches = re.findall(
                    r"(?:true_computation|false_computation)=%?([\w\.\-]+)",
                    ins.rhs)
                if not branches:
                    bm = re.search(r"branch_computations=\{([^}]*)\}", ins.rhs)
                    if bm:
                        branches = re.findall(r"%?([\w\.\-]+)", bm.group(1))
                if branches:
                    costs = [self.cost_of(b) for b in branches]
                    worst = max(costs, key=lambda c: c.flops + c.traffic)
                    total.add(worst)
            elif op in ("call", "fusion", "custom-call", "map", "reduce",
                        "reduce-window", "sort", "scatter", "select-and-scatter"):
                m = re.search(r"(?:calls|to_apply)=%?([\w\.\-]+)", ins.rhs)
                if op == "fusion":
                    # fusion = one HBM-level op; count its boundary traffic
                    total.traffic += (_shapes_bytes(ins.result_type)
                                      + _operand_bytes(ins, symtab))
                    if m:  # dots can hide inside fusions
                        inner = self.cost_of(m.group(1))
                        total.flops += inner.flops
                        total.add(Cost(0.0, 0.0, inner.collectives))
                else:
                    total.traffic += (_shapes_bytes(ins.result_type)
                                      + _operand_bytes(ins, symtab))
                    if m:
                        total.add(self.cost_of(m.group(1)))
            else:
                base = op.split("-start")[0] if op.endswith("-start") else op
                if base in _COLLECTIVES:
                    b = _operand_bytes(ins, symtab)
                    total.collectives[base] = total.collectives.get(base, 0.0) + b
                    total.traffic += b + _shapes_bytes(ins.result_type)
                elif op in _MATERIALIZING:
                    # data movement that hits HBM even on the TPU target
                    total.traffic += (_shapes_bytes(ins.result_type)
                                      + _operand_bytes(ins, symtab))
                else:
                    # elementwise / shape ops: assumed fused on the TPU
                    # target (perfect elementwise fusion) — no HBM traffic
                    pass
        self._memo[comp_name] = total
        return total

    def entry_cost(self) -> Cost:
        return self.cost_of(self.entry)


def analyze(text: str) -> Dict[str, float]:
    cm = HloCostModel(text)
    c = cm.entry_cost()
    out = {"flops": c.flops, "traffic_bytes": c.traffic,
           "collective_bytes_total": c.collective_total}
    out.update({f"collective_{k}": v for k, v in c.collectives.items()})
    return out
