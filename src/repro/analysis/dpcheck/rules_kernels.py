"""DPC4xx — kernel-triple conformance.

Every directory under src/repro/kernels/ must ship the project's
kernel.py / ops.py / ref.py triple (DPC401), each exporting at least one
public function and ref.py exporting at least one ``*_ref`` oracle whose
stem matches a kernel/ops public name (DPC402), and at least one test
under tests/ must reference ``kernels.<name>`` so the oracle contract is
actually exercised (DPC403).
"""
from __future__ import annotations

import ast
import os
from typing import Dict, List

from repro.analysis.dpcheck.core import FileCtx, Violation

TRIPLE = ("kernel.py", "ops.py", "ref.py")


def _public_functions(ctx: FileCtx) -> List[str]:
    return [n.name for n in ctx.tree.body
            if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef))
            and not n.name.startswith("_")]


def check_project(ctxs: List[FileCtx], root: str) -> List[Violation]:
    out: List[Violation] = []
    by_rel = {c.rel: c for c in ctxs}
    kernel_dirs: Dict[str, List[str]] = {}
    for c in ctxs:
        parts = c.rel.split("/")
        if ("kernels" in parts
                and parts.index("kernels") + 3 == len(parts)
                and parts[-1] != "__init__.py"):
            kdir = "/".join(parts[:-1])
            kernel_dirs.setdefault(kdir, []).append(parts[-1])

    tests_dir = os.path.join(root, "tests")
    test_sources = ""
    if os.path.isdir(tests_dir):
        for f in sorted(os.listdir(tests_dir)):
            if f.endswith(".py"):
                with open(os.path.join(tests_dir, f),
                          encoding="utf-8") as fh:
                    test_sources += fh.read()

    for kdir, files in sorted(kernel_dirs.items()):
        kname = kdir.split("/")[-1]
        missing = [f for f in TRIPLE if f not in files]
        if missing:
            # anchor to a scanned file in the dir so inline suppression
            # (core.run only consults files it parsed) can silence it
            out.append(Violation(
                "DPC401", f"{kdir}/{sorted(files)[0]}", 1,
                f"kernel `{kname}` is missing {', '.join(missing)} — the "
                "kernel/ops/ref triple is mandatory"))
            continue
        pub: Dict[str, List[str]] = {}
        for f in TRIPLE:
            ctx = by_rel.get(f"{kdir}/{f}")
            pub[f] = _public_functions(ctx) if ctx else []
            if ctx and not pub[f]:
                out.append(Violation(
                    "DPC402", ctx.rel, 1,
                    f"kernel `{kname}`: {f} exports no public function"))
        refs = [n for n in pub["ref.py"] if n.endswith("_ref")]
        impl_tokens = {t for n in pub["kernel.py"] + pub["ops.py"]
                       for t in n.split("_")}
        if pub["ref.py"] and not any(
                set(r[: -len("_ref")].split("_")) & impl_tokens
                for r in refs):
            out.append(Violation(
                "DPC402", f"{kdir}/ref.py", 1,
                f"kernel `{kname}`: no *_ref oracle matching a public "
                "kernel/ops function"))
        if test_sources and f"kernels.{kname}" not in test_sources:
            out.append(Violation(
                "DPC403", f"{kdir}/kernel.py", 1,
                f"kernel `{kname}` has no kernel-vs-oracle test in tests/ "
                f"(no test imports kernels.{kname})"))
    return out
