"""dpcheck core: violation model, suppressions, baseline, scan runner.

The analyzer is a set of *file checkers* (one FileCtx at a time) and
*project checkers* (the whole file set — kernel-triple conformance and the
cross-module host-sync reachability pass). Rules report `Violation`s; a
per-line ``# dpcheck: ignore[RULE]`` comment or a committed baseline file
silences them. Inline suppression only applies when the violation's path
is one of the scanned files (checkers must anchor findings to real files;
a violation against an unscanned path is baseline-suppressible only).
See README.md § "Static analysis (dpcheck)".
"""
from __future__ import annotations

import ast
import dataclasses
import json
import os
import re
from typing import Callable, Dict, List, Optional, Sequence, Set

RULE_DOCS: Dict[str, str] = {
    "DPC101": "PRNG key consumed by more than one sampler",
    "DPC102": "PRNG key used by jax.random after being split",
    "DPC103": "constant PRNGKey(<literal>) in library code",
    "DPC104": "sampler key argument is an opaque expression "
              "(not a name or a split/fold_in derivation)",
    "DPC105": "PRNG key with mixed ownership: escaped to a helper and "
              "reused, or escaped twice",
    "DPC201": "host sync (.item()/np.asarray/device_get/float/int) "
              "reachable from a scan round body",
    "DPC202": "python `if` on a traced value reachable from a scan body",
    "DPC203": "jax.debug.print of a traced value reachable from a scan body",
    "DPC204": "per-iteration host sync on an array element in a hot loop",
    "DPC301": "noise added on a path not dominated by the clip step",
    "DPC302": "bank write not masked by the ledger grant",
    "DPC401": "kernel dir missing the kernel.py/ops.py/ref.py triple",
    "DPC402": "kernel triple file has no public function / ref exports "
              "no *_ref oracle",
    "DPC403": "kernel dir has no kernel-vs-oracle test in tests/",
    "DPC501": "donated buffer referenced after the donating call",
}


@dataclasses.dataclass(frozen=True)
class Violation:
    rule: str
    path: str          # repo-relative, posix separators
    line: int
    message: str

    def format(self) -> str:
        return f"{self.path}:{self.line}: {self.rule} {self.message}"

    @property
    def baseline_key(self) -> str:
        return f"{self.path}::{self.rule}::{self.message}"

    def to_json(self) -> Dict:
        return {"rule": self.rule, "path": self.path, "line": self.line,
                "message": self.message}


_SUPPRESS_RE = re.compile(r"#\s*dpcheck:\s*ignore\[([A-Za-z0-9_, ]+)\]")


def parse_suppressions(lines: Sequence[str]) -> Dict[int, Set[str]]:
    out: Dict[int, Set[str]] = {}
    for i, line in enumerate(lines, start=1):
        m = _SUPPRESS_RE.search(line)
        if m:
            out[i] = {r.strip() for r in m.group(1).split(",") if r.strip()}
    return out


def module_name(rel: str) -> str:
    mod = rel[:-3] if rel.endswith(".py") else rel
    if mod.startswith("src/"):
        mod = mod[len("src/"):]
    mod = mod.replace("/", ".")
    if mod.endswith(".__init__"):
        mod = mod[: -len(".__init__")]
    return mod


class FileCtx:
    """One parsed source file plus everything the rules need to know."""

    def __init__(self, path: str, root: str):
        self.path = path
        self.rel = os.path.relpath(path, root).replace(os.sep, "/")
        with open(path, encoding="utf-8") as f:
            self.source = f.read()
        self.lines = self.source.splitlines()
        self.tree = ast.parse(self.source, filename=path)
        self.module = module_name(self.rel)
        self.is_library = self.rel.startswith("src/repro/")
        self.suppressions = parse_suppressions(self.lines)

    def suppressed(self, v: Violation) -> bool:
        rules = self.suppressions.get(v.line)
        return bool(rules) and (v.rule in rules or "ALL" in rules)


FileChecker = Callable[[FileCtx], List[Violation]]
ProjectChecker = Callable[[List[FileCtx], str], List[Violation]]


def _checkers():
    from repro.analysis.dpcheck import (rules_donation, rules_dporder,
                                        rules_hostsync, rules_keys,
                                        rules_kernels)
    file_checkers: List[FileChecker] = [
        rules_keys.check_file,
        rules_dporder.check_file,
        rules_donation.check_file,
        rules_hostsync.check_file_loops,
    ]
    project_checkers: List[ProjectChecker] = [
        rules_hostsync.check_project,
        rules_kernels.check_project,
    ]
    return file_checkers, project_checkers


def collect_files(paths: Sequence[str], root: str) -> List[str]:
    out: List[str] = []
    for p in paths:
        p = p if os.path.isabs(p) else os.path.join(root, p)
        if os.path.isfile(p) and p.endswith(".py"):
            out.append(p)
            continue
        for dirpath, dirnames, filenames in os.walk(p):
            dirnames[:] = [d for d in dirnames
                           if d != "__pycache__" and not d.startswith(".")]
            out.extend(os.path.join(dirpath, f) for f in sorted(filenames)
                       if f.endswith(".py"))
    return sorted(set(out))


def run(paths: Sequence[str], root: Optional[str] = None) -> List[Violation]:
    root = os.path.abspath(root or os.getcwd())
    ctxs: List[FileCtx] = []
    violations: List[Violation] = []
    for path in collect_files(paths, root):
        try:
            ctxs.append(FileCtx(path, root))
        except SyntaxError as e:
            rel = os.path.relpath(path, root).replace(os.sep, "/")
            violations.append(Violation("DPC000", rel, e.lineno or 1,
                                        f"syntax error: {e.msg}"))
    file_checkers, project_checkers = _checkers()
    by_rel = {c.rel: c for c in ctxs}
    for ctx in ctxs:
        for checker in file_checkers:
            violations.extend(checker(ctx))
    for pchecker in project_checkers:
        violations.extend(pchecker(ctxs, root))
    violations = [v for v in violations
                  if not (v.path in by_rel and by_rel[v.path].suppressed(v))]
    return sorted(set(violations), key=lambda v: (v.path, v.line, v.rule))


def load_baseline(path: str) -> Set[str]:
    if not os.path.exists(path):
        return set()
    with open(path, encoding="utf-8") as f:
        data = json.load(f)
    return set(data.get("violations", []))


def write_baseline(path: str, violations: Sequence[Violation]) -> None:
    keys = sorted({v.baseline_key for v in violations})
    with open(path, "w", encoding="utf-8") as f:
        json.dump({"version": 1, "violations": keys}, f, indent=2)
        f.write("\n")


def filter_new(violations: Sequence[Violation],
               baseline: Set[str]) -> List[Violation]:
    return [v for v in violations if v.baseline_key not in baseline]
