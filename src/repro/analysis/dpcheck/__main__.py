"""CLI: ``python -m repro.analysis.dpcheck [paths...]``.

Exit status is 0 when no NEW violations remain (after per-line
suppressions and the baseline file), 1 otherwise. ``--write-baseline``
snapshots the current findings so CI fails only on regressions.
"""
from __future__ import annotations

import argparse
import json
import sys

from repro.analysis.dpcheck.core import (RULE_DOCS, filter_new,
                                         load_baseline, run, write_baseline)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis.dpcheck",
        description="DP-invariant static analyzer for the federation "
                    "engine (rules DPC1xx-DPC5xx).")
    ap.add_argument("paths", nargs="*", default=["src"],
                    help="files or directories to scan (default: src)")
    ap.add_argument("--format", choices=("text", "json"), default="text")
    ap.add_argument("--baseline", default=None,
                    help="baseline JSON; known violations do not fail")
    ap.add_argument("--write-baseline", action="store_true",
                    help="write current findings to --baseline and exit 0")
    ap.add_argument("--root", default=None,
                    help="repo root for relative paths (default: cwd)")
    ap.add_argument("--list-rules", action="store_true")
    args = ap.parse_args(argv)

    if args.list_rules:
        for rule, doc in sorted(RULE_DOCS.items()):
            print(f"{rule}  {doc}")
        return 0

    violations = run(args.paths or ["src"], root=args.root)
    if args.write_baseline:
        if not args.baseline:
            ap.error("--write-baseline requires --baseline")
        write_baseline(args.baseline, violations)
        print(f"wrote {len(violations)} entries to {args.baseline}")
        return 0

    baseline = load_baseline(args.baseline) if args.baseline else set()
    new = filter_new(violations, baseline)

    if args.format == "json":
        print(json.dumps({
            "violations": [v.to_json() for v in violations],
            "new": [v.to_json() for v in new],
            "baseline_entries": len(baseline),
            "count": len(violations),
            "new_count": len(new),
        }, indent=2))
    else:
        for v in new:
            print(v.format())
        known = len(violations) - len(new)
        tail = f" ({known} known in baseline)" if known else ""
        print(f"dpcheck: {len(new)} new violation(s)"
              f", {len(violations)} total{tail}")
    return 1 if new else 0


if __name__ == "__main__":
    sys.exit(main())
