"""DPC2xx — host-sync / tracer-leak detection.

The project-level half builds the set of functions reachable from the
lax.scan / fori_loop round bodies in federation/deep.py and
federation/convex.py (cross-module, factory-aware) and flags anything in
them that would force a device->host sync or leak a tracer:

    DPC201  .item(), np.asarray, jax.device_get, float()/int() on a
            traced value
    DPC202  bare python `if` on a traced value (tracer boolean coercion)
    DPC203  jax.debug.print of a traced value

Taint (= "traced value") is deliberately narrow: results of jax.*/jnp.*
calls and arithmetic on them. Parameters are NOT tainted — static-config
dispatch (`if cfg.fused_kernel:`) must stay legal.

The file-level half (DPC204) catches the bench/example hot-loop pattern
`int(owner_seq[i])` — a blocking transfer per iteration inside a python
for/while — anywhere, not just in scan-reachable code. String-literal
subscripts (metric dicts) and names rebound via np.asarray are exempt.
"""
from __future__ import annotations

import ast
from typing import Dict, List, Set, Tuple

from repro.analysis.dpcheck.core import FileCtx, Violation
from repro.analysis.dpcheck.dataflow import (ModuleIndex, assigned_names,
                                             call_name, reachable_functions,
                                             scan_body_roots)

ROOT_MODULES = ("repro.federation.deep", "repro.federation.convex")


def _is_jaxish(name: str) -> bool:
    return name.split(".")[0] in ("jax", "jnp", "lax")


class _Taint(ast.NodeVisitor):
    """Names assigned from jax/jnp call results (or derived) in one fn."""

    def __init__(self) -> None:
        self.tainted: Set[str] = set()

    def is_tainted(self, e: ast.AST) -> bool:
        if isinstance(e, ast.Name):
            return e.id in self.tainted
        if isinstance(e, ast.Call):
            return _is_jaxish(call_name(e))
        if isinstance(e, ast.BinOp):
            return self.is_tainted(e.left) or self.is_tainted(e.right)
        if isinstance(e, ast.UnaryOp):
            return self.is_tainted(e.operand)
        if isinstance(e, ast.Compare):
            return (self.is_tainted(e.left)
                    or any(self.is_tainted(c) for c in e.comparators))
        if isinstance(e, ast.Subscript):
            return self.is_tainted(e.value)
        if isinstance(e, (ast.BoolOp,)):
            return any(self.is_tainted(v) for v in e.values)
        return False

    def visit_Assign(self, node: ast.Assign) -> None:
        if self.is_tainted(node.value):
            for t in node.targets:
                self.tainted.update(assigned_names(t))
        self.generic_visit(node)


def _fn_statements(fn: ast.AST):
    """Walk a def without descending into nested defs (own reachability)."""
    todo = list(fn.body)
    while todo:
        s = todo.pop(0)
        yield s
        for child in ast.iter_child_nodes(s):
            if isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef,
                                  ast.ClassDef)):
                continue
            if isinstance(child, ast.stmt):
                todo.append(child)


def _check_reachable_fn(ctx: FileCtx, qual: str,
                        fn: ast.AST) -> List[Violation]:
    out: List[Violation] = []
    taint = _Taint()
    for s in _fn_statements(fn):
        taint.visit(s)
    where = f"in `{qual}` (reachable from a scan round body)"
    for s in _fn_statements(fn):
        if isinstance(s, (ast.If, ast.While)) and taint.is_tainted(s.test):
            out.append(Violation(
                "DPC202", ctx.rel, s.lineno,
                f"python branch on a traced value {where} — use jnp.where/"
                "lax.cond"))
        for node in ast.walk(s if not isinstance(s, (ast.If, ast.While))
                             else s.test):
            if not isinstance(node, ast.Call):
                continue
            name = call_name(node)
            if name.endswith(".item"):
                out.append(Violation(
                    "DPC201", ctx.rel, node.lineno,
                    f".item() host sync {where}"))
            elif name in ("np.asarray", "numpy.asarray", "np.array",
                          "numpy.array", "jax.device_get"):
                out.append(Violation(
                    "DPC201", ctx.rel, node.lineno,
                    f"{name} forces a device->host transfer {where}"))
            elif name in ("float", "int", "bool") and node.args and \
                    taint.is_tainted(node.args[0]):
                out.append(Violation(
                    "DPC201", ctx.rel, node.lineno,
                    f"{name}() on a traced value {where}"))
            elif name == "jax.debug.print" and any(
                    taint.is_tainted(a) for a in node.args[1:]
                    ) or name == "jax.debug.print" and any(
                    taint.is_tainted(kw.value) for kw in node.keywords):
                out.append(Violation(
                    "DPC203", ctx.rel, node.lineno,
                    f"jax.debug.print of a traced (private) value {where}"))
    return out


def check_project(ctxs: List[FileCtx], root: str) -> List[Violation]:
    indexes: Dict[str, ModuleIndex] = {
        c.module: ModuleIndex(c.module, c.tree) for c in ctxs}
    roots: List[Tuple[str, str]] = []
    for mod in ROOT_MODULES:
        if mod in indexes:
            roots.extend(scan_body_roots(indexes[mod]))
    reach = reachable_functions(indexes, roots)
    by_module = {c.module: c for c in ctxs}
    out: List[Violation] = []
    for module, qual in sorted(reach):
        ctx = by_module[module]
        fn = indexes[module].functions[qual]
        out.extend(_check_reachable_fn(ctx, qual, fn))
    return out


_SYNC_CASTS = ("int", "float")


def check_file_loops(ctx: FileCtx) -> List[Violation]:
    """DPC204 — per-element host sync inside a python hot loop."""
    out: List[Violation] = []
    for fn in ast.walk(ctx.tree):
        if not isinstance(fn, (ast.For, ast.While)):
            continue
        jax_names: Set[str] = set()
        host_names: Set[str] = set()
        # names visible to the loop: any assignment in the enclosing module
        for node in ast.walk(ctx.tree):
            if isinstance(node, ast.Assign) and isinstance(node.value,
                                                           ast.Call):
                cname = call_name(node.value)
                names = [n for t in node.targets
                         for n in assigned_names(t)]
                if cname in ("np.asarray", "numpy.asarray", "np.array",
                             "numpy.array", "jax.device_get", "list",
                             "range"):
                    host_names.update(names)
                elif _is_jaxish(cname) or "." in cname:
                    jax_names.update(names)
        for node in ast.walk(fn):
            sub = None
            kind = None
            if (isinstance(node, ast.Call)
                    and call_name(node) in _SYNC_CASTS and node.args
                    and isinstance(node.args[0], ast.Subscript)):
                sub, kind = node.args[0], call_name(node)
            elif (isinstance(node, ast.Call)
                  and call_name(node).endswith(".item")
                  and isinstance(node.func, ast.Attribute)
                  and isinstance(node.func.value, ast.Subscript)):
                sub, kind = node.func.value, ".item()"
            if sub is None or not isinstance(sub.value, ast.Name):
                continue
            idx = sub.slice
            if isinstance(idx, ast.Constant) and isinstance(idx.value, str):
                continue                # metric-dict lookup, not an array
            name = sub.value.id
            if name in host_names or name not in jax_names:
                continue
            out.append(Violation(
                "DPC204", ctx.rel, node.lineno,
                f"{kind} on `{name}[...]` inside a python loop — one "
                "blocking device->host sync per iteration; hoist with "
                "np.asarray before the loop"))
    return out
