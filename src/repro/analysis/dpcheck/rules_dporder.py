"""DPC3xx — DP-order invariants.

DPC301 (clip dominates noise): in any function that both clips and adds
mechanism noise, the clip must come first on every path. Clip markers are
calls whose name mentions ``clip`` (excluding jnp.clip — that is the
theta_max projection, not sensitivity enforcement) and the inline
``jnp.minimum(1.0, xi / ...)`` clip-factor pattern; a nested def containing
a clip marker counts at its def site (the closure runs inside the scan).
Noise markers are the mechanism entry points themselves. Functions with
only one of the two families are skipped — convex owners bound sensitivity
analytically and never clip, which is lawful.

DPC302 (grant masks the bank write): in a function that consults the
ledger (``.authorized(``), every bank-write call must be refusal-masked:
either it takes an ``ok=``/``respond=`` keyword or its value arguments are
derived from the grant mask (jnp.where on it). An unmasked write would let
a refused round mutate owner state, voiding the budget accounting. The
fault layer's masks are grant sources too: ``verify_row(...)`` /
``finite_guard(...)`` results and the quarantine flags
(``.quarantined`` reads) — a write masked by the fault-guard algebra is
exactly as refusal-safe as one masked by ``.authorized`` alone.
"""
from __future__ import annotations

import ast
from typing import List, Set

from repro.analysis.dpcheck.core import FileCtx, Violation
from repro.analysis.dpcheck.dataflow import (assigned_names, call_name,
                                             iter_functions)

NOISE_MARKERS = {
    "jax.random.laplace", "jax.random.normal",
    "laplace_noise_tree", "fused_scale_noise_tree",
    "dp_round_flat", "dp_privatize_tree", "tree_delta_row",
}
BANK_WRITERS = ("_write_bank", "_write_bank_rows", "_quant_write",
                "dynamic_update_index_in_dim")
MASK_KWARGS = ("ok", "respond", "granted")


def _is_clip_call(call: ast.Call) -> bool:
    name = call_name(call)
    if not name:
        return False
    last = name.split(".")[-1]
    if name in ("jnp.clip", "np.clip", "jax.numpy.clip"):
        return False
    if "clip" in last.lower():
        return True
    # jnp.minimum(1.0, xi / max(norm, eps)) — the clip-factor idiom
    if name in ("jnp.minimum", "jax.numpy.minimum") and call.args:
        a0 = call.args[0]
        return isinstance(a0, ast.Constant) and a0.value == 1.0
    return False


def _is_noise_call(call: ast.Call) -> bool:
    name = call_name(call)
    return bool(name) and (name in NOISE_MARKERS
                           or name.split(".")[-1] in NOISE_MARKERS)


def _stmt_markers(s: ast.stmt) -> Set[str]:
    """{'clip'}/{'noise'} markers contained in one statement."""
    out: Set[str] = set()
    if isinstance(s, (ast.FunctionDef, ast.AsyncFunctionDef)):
        # closure defined here, executed later: its clip counts at def site
        for node in ast.walk(s):
            if isinstance(node, ast.Call) and _is_clip_call(node):
                out.add("clip")
        return out
    for node in ast.walk(s):
        if isinstance(node, ast.Call):
            if _is_clip_call(node):
                out.add("clip")
            if _is_noise_call(node):
                out.add("noise")
    return out


class _OrderWalker:
    """Linear walk; flags noise seen on a path with no prior clip."""

    def __init__(self, ctx: FileCtx):
        self.ctx = ctx
        self.out: List[Violation] = []

    def block(self, stmts, clip_seen: bool) -> (bool, bool):
        """-> (clip_seen after block, path terminated)."""
        for s in stmts:
            if isinstance(s, (ast.Return, ast.Raise)):
                clip_seen = self.stmt(s, clip_seen)
                return clip_seen, True
            if isinstance(s, ast.If):
                c1, d1 = self.block(s.body, clip_seen)
                c2, d2 = self.block(s.orelse, clip_seen)
                if d1 and not d2:
                    clip_seen = c2
                elif d2 and not d1:
                    clip_seen = c1
                else:
                    clip_seen = c1 and c2
                continue
            if isinstance(s, (ast.For, ast.While, ast.With, ast.Try)):
                inner = list(getattr(s, "body", []))
                for h in getattr(s, "handlers", []):
                    inner.extend(h.body)
                inner.extend(getattr(s, "orelse", []))
                inner.extend(getattr(s, "finalbody", []))
                clip_seen, _ = self.block(inner, clip_seen)
                continue
            clip_seen = self.stmt(s, clip_seen)
        return clip_seen, False

    def stmt(self, s: ast.stmt, clip_seen: bool) -> bool:
        markers = _stmt_markers(s)
        if "noise" in markers and not clip_seen and "clip" not in markers:
            self.pending.append(s.lineno)
        return clip_seen or "clip" in markers

    def check(self, qual: str, fn: ast.AST) -> List[Violation]:
        all_markers: Set[str] = set()
        for s in fn.body:
            all_markers |= _stmt_markers(s)
            for node in ast.walk(s):
                if isinstance(node, ast.stmt):
                    all_markers |= _stmt_markers(node)
        if not ("clip" in all_markers and "noise" in all_markers):
            return []
        self.pending: List[int] = []
        self.block(fn.body, False)
        return [Violation(
            "DPC301", self.ctx.rel, line,
            f"noise added in `{qual}` on a path where the clip step has "
            "not run — clipping must dominate the mechanism add")
            for line in self.pending]


# Calls whose result is a fault-layer guard mask (PR 8): payload checksum
# verification and the non-finite update guard. The staleness runtime
# (PR 10) adds the learner-deadline mask — an answered-late round is a
# lawful masked write-back exactly like a guard-rejected one.
GUARD_CALLS = ("verify_row", "finite_guard", "deadline_guard")


def _own_nodes(fn: ast.AST):
    """Walk a def WITHOUT descending into nested defs: every nested def
    is checked as its own function (iter_functions yields it), so masks
    bound in one closure must not vouch for writes in a sibling."""
    todo = list(ast.iter_child_nodes(fn))
    while todo:
        node = todo.pop()
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            continue
        yield node
        todo.extend(ast.iter_child_nodes(node))


def _grant_masks(fn: ast.AST) -> Set[str]:
    """Names bound from grant/guard sources and names derived from them.

    Sources: `.authorized(...)` ledger reads, `verify_row(...)` /
    `finite_guard(...)` fault guards, `.quarantined` flag reads, and the
    HIT bit of a page-residency lookup (`slot, hit = bank.lookup(i)` —
    paged-bank writes masked on residency are lawful no-ops for
    non-resident rows; the slot index itself vouches for nothing)."""
    masks: Set[str] = set()
    changed = True
    while changed:
        changed = False
        for node in _own_nodes(fn):
            if not isinstance(node, ast.Assign):
                continue
            # residency lookup: ONLY the second target of the 2-name
            # unpack becomes a mask — `slot` must never launder a write
            if (isinstance(node.value, ast.Call)
                    and (call_name(node.value) or "").endswith(".lookup")
                    and len(node.targets) == 1
                    and isinstance(node.targets[0], ast.Tuple)
                    and len(node.targets[0].elts) == 2
                    and isinstance(node.targets[0].elts[1], ast.Name)):
                hit = node.targets[0].elts[1].id
                if hit not in masks:
                    masks.add(hit)
                    changed = True
                continue
            derived = False
            for sub in ast.walk(node.value):
                if isinstance(sub, ast.Call):
                    name = call_name(sub)
                    if (name.endswith(".authorized")
                            or name.split(".")[-1] in GUARD_CALLS):
                        derived = True
                if isinstance(sub, ast.Attribute) and \
                        sub.attr == "quarantined":
                    derived = True
                if isinstance(sub, ast.Name) and sub.id in masks:
                    derived = True
            if derived:
                for t in node.targets:
                    for n in assigned_names(t):
                        if n not in masks:
                            masks.add(n)
                            changed = True
    return masks


def _check_bank_writes(ctx: FileCtx, qual: str,
                       fn: ast.AST) -> List[Violation]:
    masks = _grant_masks(fn)
    if not masks:
        return []
    out: List[Violation] = []
    for node in _own_nodes(fn):
        if not isinstance(node, ast.Call):
            continue
        name = call_name(node)
        last = name.split(".")[-1]
        if last not in BANK_WRITERS:
            continue
        if any(kw.arg in MASK_KWARGS for kw in node.keywords):
            continue
        uses_mask = any(isinstance(sub, ast.Name) and sub.id in masks
                        for a in node.args for sub in ast.walk(a))
        if not uses_mask:
            out.append(Violation(
                "DPC302", ctx.rel, node.lineno,
                f"bank write `{last}` in `{qual}` is not masked by the "
                "ledger grant — refused rounds must be bit-exact no-ops"))
    return out


def check_file(ctx: FileCtx) -> List[Violation]:
    out: List[Violation] = []
    for qual, fn in iter_functions(ctx.tree):
        out.extend(_OrderWalker(ctx).check(qual, fn))
        out.extend(_check_bank_writes(ctx, qual, fn))
    return out
