"""dpcheck — DP-invariant static analyzer + runtime sanitizer.

Static half: ``python -m repro.analysis.dpcheck [paths]`` runs the DPC1xx
(PRNG key discipline), DPC2xx (host sync in scan-reachable code), DPC3xx
(clip-before-noise, masked bank writes), DPC4xx (kernel triple) and
DPC501 (donation safety) rule families over the tree. Runtime half:
``with dpcheck.sanitize(): ...`` wraps the jax.random samplers to record
consumed key material and raise on reuse.
"""
from repro.analysis.dpcheck.core import (RULE_DOCS, Violation, filter_new,
                                         load_baseline, run, write_baseline)

__all__ = ["RULE_DOCS", "Violation", "run", "load_baseline",
           "write_baseline", "filter_new", "sanitize", "KeyReuseError"]


def __getattr__(name):
    # The runtime half needs jax; the static half (and the CLI, which CI
    # runs in a jax-free lint venv) must not. PEP 562 keeps the import
    # lazy so `python -m repro.analysis.dpcheck` works without jax.
    if name in ("sanitize", "KeyReuseError"):
        import importlib
        mod = importlib.import_module("repro.analysis.dpcheck._sanitize")
        globals()["sanitize"] = mod.sanitize
        globals()["KeyReuseError"] = mod.KeyReuseError
        return globals()[name]
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
