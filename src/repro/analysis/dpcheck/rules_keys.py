"""DPC1xx — PRNG key discipline.

Intraprocedural abstract interpretation of jax.random key values. Each
local name that ever receives a key gets a state:

    FRESH    assigned from PRNGKey/fold_in/split-result/subscript/param
    CONSUMED a known sampler drew from it
    SPLIT    jax.random.split read it without rebinding it
    ESCAPED  passed to an opaque call (ownership now shared)

Transitions that indicate reuse of threefry state are violations:

    DPC101  sampler(k) with k CONSUMED        (two draws, same stream)
    DPC102  jax.random.*(k) with k SPLIT      (parent reused after split)
    DPC103  PRNGKey(<literal>) in src/repro/  (constant seed in library)
    DPC104  sampler key arg is an opaque Call (derivation not visible)
    DPC105  jax.random use of an ESCAPED key, or a second escape

Branches of an `if` are merged pessimistically (worst state wins); `for`/
`while` bodies are interpreted twice so a loop-invariant key consumed each
iteration trips DPC101 on the second pass.
"""
from __future__ import annotations

import ast
from typing import Dict, List, Optional, Tuple

from repro.analysis.dpcheck.core import FileCtx, Violation
from repro.analysis.dpcheck.dataflow import (assigned_names, call_name,
                                             iter_functions, param_names)

SAMPLERS = {
    "normal", "uniform", "laplace", "bernoulli", "randint", "bits",
    "gumbel", "exponential", "gamma", "beta", "cauchy", "dirichlet",
    "truncated_normal", "categorical", "poisson", "rademacher",
    "permutation", "choice", "shuffle", "ball", "maxwell", "logistic",
    "loggamma", "t", "weibull_min", "rayleigh", "pareto", "multivariate_normal",
}
DERIVERS = {"split", "fold_in", "PRNGKey", "key", "wrap_key_data",
            "key_data", "clone"}

FRESH, CONSUMED, SPLIT, ESCAPED = "fresh", "consumed", "split", "escaped"
_RANK = {FRESH: 0, CONSUMED: 1, SPLIT: 2, ESCAPED: 3}

# calls that read a value without taking ownership of it — not escapes
NEUTRAL_CALLS = {
    "isinstance", "len", "print", "str", "repr", "type", "getattr",
    "hasattr", "id", "hash", "zip", "enumerate", "list", "tuple", "sorted",
    "format", "min", "max", "sum", "abs", "range", "jnp.asarray",
    "np.asarray", "jnp.stack", "jnp.array", "jax.random.key_data",
}


def _keyish(name: str) -> bool:
    low = name.lower()
    return "key" in low or low in ("k", "rng", "nk", "ks", "subkey", "rootkey")


def _rand_fn(call: ast.Call) -> Optional[str]:
    """'split' for jax.random.split(...) / random.split(...), else None."""
    name = call_name(call)
    parts = name.split(".")
    if len(parts) >= 2 and parts[-2] == "random" and (
            len(parts) == 2 or parts[-3] == "jax"):
        return parts[-1]
    return None


class _FnChecker:
    def __init__(self, ctx: FileCtx, fn: ast.AST):
        self.ctx = ctx
        self.fn = fn
        self.out: List[Violation] = []
        self.state: Dict[str, str] = {p: FRESH for p in param_names(fn)
                                      if _keyish(p)}

    def emit(self, rule: str, node: ast.AST, msg: str) -> None:
        self.out.append(Violation(rule, self.ctx.rel, node.lineno, msg))

    # -- statement walk -------------------------------------------------
    def run(self) -> List[Violation]:
        self.block(self.fn.body)
        return self.out

    def block(self, stmts: List[ast.stmt]) -> bool:
        """Interpret a statement list; True if it terminates the path."""
        for s in stmts:
            if isinstance(s, (ast.Return, ast.Raise, ast.Break,
                              ast.Continue)):
                self.stmt(s)
                return True
            self.stmt(s)
        return False

    def stmt(self, s: ast.stmt) -> None:
        if isinstance(s, (ast.FunctionDef, ast.AsyncFunctionDef,
                          ast.ClassDef)):
            return                      # nested defs get their own pass
        if isinstance(s, ast.If):
            before = dict(self.state)
            self.expr(s.test)
            body_done = self.block(s.body)
            after_body = self.state
            self.state = dict(before)
            else_done = self.block(s.orelse)
            # a branch that returned/raised contributes nothing downstream
            if body_done and not else_done:
                return                  # keep else-state
            if else_done and not body_done:
                self.state = after_body
                return
            merged = dict(self.state)
            for k, v in after_body.items():
                cur = merged.get(k, FRESH)
                merged[k] = v if _RANK[v] > _RANK[cur] else cur
            self.state = merged
            return
        if isinstance(s, (ast.For, ast.While)):
            loop_targets = (assigned_names(s.target)
                            if isinstance(s, ast.For) else [])
            key_iter = False
            if isinstance(s, ast.For):
                self.expr(s.iter)
                iter_names = {n.id for n in ast.walk(s.iter)
                              if isinstance(n, ast.Name)}
                key_iter = bool(iter_names & set(self.state)) or any(
                    isinstance(n, ast.Call) and _rand_fn(n) in DERIVERS
                    for n in ast.walk(s.iter))
            for _ in range(2):          # 2nd pass: loop-carried reuse
                for t in loop_targets:  # loop var rebinds every iteration
                    if t in self.state or (key_iter and _keyish(t)):
                        self.state[t] = FRESH
                self.block(s.body)
            self.block(s.orelse)
            return
        if isinstance(s, (ast.Try,)):
            self.block(s.body)
            for h in s.handlers:
                self.block(h.body)
            self.block(s.orelse)
            self.block(s.finalbody)
            return
        if isinstance(s, (ast.With,)):
            self.block(s.body)
            return
        # ordinary statement: evaluate RHS calls left-to-right, then binds
        targets: List[str] = []
        if isinstance(s, ast.Assign):
            for t in s.targets:
                targets.extend(assigned_names(t))
            self.expr(s.value)
        elif isinstance(s, ast.AugAssign):
            self.expr(s.value)
        elif isinstance(s, (ast.Expr, ast.Return)) and s.value is not None:
            self.expr(s.value)
        elif isinstance(s, ast.AnnAssign) and s.value is not None:
            targets.extend(assigned_names(s.target))
            self.expr(s.value)
        # rebinding a name gives it a fresh identity (key, sub = split(key))
        value = getattr(s, "value", None)
        derives = (isinstance(value, ast.Call)
                   and _rand_fn(value) in DERIVERS)
        key_subscript = (isinstance(value, ast.Subscript)
                         and isinstance(value.value, ast.Name)
                         and (value.value.id in self.state
                              or _keyish(value.value.id)))
        for t in targets:
            if derives or (key_subscript and (_keyish(t)
                                              or len(targets) == 1)):
                self.state[t] = FRESH   # fresh key identity
            elif t in self.state:
                del self.state[t]       # rebound to a non-key value

    def expr(self, e: ast.AST) -> None:
        for node in ast.walk(e):
            if isinstance(node, ast.Call):
                self.call(node)

    # -- call transition ------------------------------------------------
    def call(self, call: ast.Call) -> None:
        fn = _rand_fn(call)
        if fn == "PRNGKey" or fn == "key":
            if (self.ctx.is_library and call.args
                    and isinstance(call.args[0], ast.Constant)):
                self.emit("DPC103", call,
                          f"jax.random.{fn}({call.args[0].value!r}) — "
                          "constant seed in library code; thread a key in")
            return
        if fn == "fold_in":
            return                      # derives; does not consume
        if fn == "split":
            if call.args and isinstance(call.args[0], ast.Name):
                name = call.args[0].id
                st = self.state.get(name)
                if st == ESCAPED:
                    self.emit("DPC105", call,
                              f"key `{name}` split after escaping to a "
                              "helper — ownership is ambiguous")
                self.state[name] = SPLIT
            return
        if fn in SAMPLERS:
            if call.args:
                self.key_arg(call, call.args[0], fn)
            for kw in call.keywords:
                if kw.arg == "key":
                    self.key_arg(call, kw.value, fn)
            return
        if fn is not None:
            # other jax.random op on a tracked name: treat as a read
            for a in call.args:
                if isinstance(a, ast.Name) and self.state.get(a.id) == SPLIT:
                    self.emit("DPC102", call,
                              f"key `{a.id}` used by jax.random.{fn} "
                              "after being split")
            return
        # opaque call: any tracked key passed through escapes
        cname = call_name(call)
        if cname in NEUTRAL_CALLS or cname.split(".")[-1] in ("append",
                                                              "get"):
            return
        for a in list(call.args) + [kw.value for kw in call.keywords]:
            if isinstance(a, ast.Name) and a.id in self.state:
                st = self.state[a.id]
                if st == ESCAPED:
                    self.emit("DPC105", call,
                              f"key `{a.id}` passed to a second helper "
                              f"({call_name(call) or '<call>'}) — two "
                              "callees may draw from the same stream")
                elif st in (CONSUMED, SPLIT):
                    pass                # already flagged if re-drawn
                else:
                    self.state[a.id] = ESCAPED

    def key_arg(self, call: ast.Call, arg: ast.AST, sampler: str) -> None:
        if isinstance(arg, ast.Name):
            st = self.state.get(arg.id)
            if st == CONSUMED:
                self.emit("DPC101", call,
                          f"key `{arg.id}` consumed by a second sampler "
                          f"(jax.random.{sampler}) — same threefry stream "
                          "drawn twice")
            elif st == SPLIT:
                self.emit("DPC102", call,
                          f"key `{arg.id}` consumed by jax.random."
                          f"{sampler} after being split")
            elif st == ESCAPED:
                self.emit("DPC105", call,
                          f"key `{arg.id}` consumed by jax.random."
                          f"{sampler} after escaping to a helper")
            self.state[arg.id] = CONSUMED
        elif isinstance(arg, ast.Call):
            fn = _rand_fn(arg)
            if fn not in DERIVERS:
                self.emit("DPC104", call,
                          f"key argument of jax.random.{sampler} is an "
                          "opaque call — derive keys via split/fold_in")


def check_file(ctx: FileCtx) -> List[Violation]:
    out: List[Violation] = []
    for _, fn in iter_functions(ctx.tree):
        out.extend(_FnChecker(ctx, fn).run())
    # module-level statements (scripts, examples)
    mod_fn = ast.Module(body=[s for s in ctx.tree.body
                              if not isinstance(s, (ast.FunctionDef,
                                                    ast.AsyncFunctionDef,
                                                    ast.ClassDef))],
                        type_ignores=[])
    mod_fn.args = ast.arguments(posonlyargs=[], args=[], kwonlyargs=[],
                                kw_defaults=[], defaults=[])
    mod_fn.body = mod_fn.body
    checker = _FnChecker.__new__(_FnChecker)
    checker.ctx = ctx
    checker.fn = mod_fn
    checker.out = []
    checker.state = {}
    checker.block(mod_fn.body)
    out.extend(checker.out)
    return out
