"""Shared AST plumbing for the dpcheck rules.

Small, deliberately intraprocedural helpers: dotted-name resolution,
per-module function indexing (including nested defs), import maps, and the
cross-module reachability walk used by the host-sync rules to find every
function callable from the lax.scan / fori_loop round bodies.
"""
from __future__ import annotations

import ast
from typing import Dict, Iterator, List, Optional, Set, Tuple

FuncDef = (ast.FunctionDef, ast.AsyncFunctionDef)


def dotted(node: ast.AST) -> Optional[str]:
    """'jax.random.split' for an Attribute/Name chain, else None."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


def call_name(call: ast.Call) -> str:
    return dotted(call.func) or ""


def assigned_names(target: ast.AST) -> List[str]:
    """Plain names bound by an assignment target (tuples unpacked)."""
    if isinstance(target, ast.Name):
        return [target.id]
    if isinstance(target, (ast.Tuple, ast.List)):
        out: List[str] = []
        for elt in target.elts:
            out.extend(assigned_names(elt))
        return out
    return []


def iter_functions(tree: ast.AST) -> Iterator[Tuple[str, ast.AST]]:
    """(qualname, node) for every def in the module, nested included."""
    def walk(node: ast.AST, prefix: str) -> Iterator[Tuple[str, ast.AST]]:
        for child in ast.iter_child_nodes(node):
            if isinstance(child, FuncDef):
                q = f"{prefix}{child.name}"
                yield q, child
                yield from walk(child, q + ".")
            elif isinstance(child, ast.ClassDef):
                yield from walk(child, f"{prefix}{child.name}.")
            else:
                yield from walk(child, prefix)
    yield from walk(tree, "")


def param_names(fn: ast.AST) -> Set[str]:
    a = fn.args
    names = [p.arg for p in
             a.posonlyargs + a.args + a.kwonlyargs]
    if a.vararg:
        names.append(a.vararg.arg)
    if a.kwarg:
        names.append(a.kwarg.arg)
    return set(names)


def import_map(tree: ast.AST) -> Dict[str, str]:
    """local name -> fully qualified origin, for module-level imports."""
    out: Dict[str, str] = {}
    for node in ast.walk(tree):
        if isinstance(node, ast.Import):
            for alias in node.names:
                out[alias.asname or alias.name.split(".")[0]] = alias.name
        elif isinstance(node, ast.ImportFrom) and node.module:
            for alias in node.names:
                out[alias.asname or alias.name] = (
                    f"{node.module}.{alias.name}")
    return out


class ModuleIndex:
    """Per-module lookup tables used by the reachability walk."""

    def __init__(self, module: str, tree: ast.AST):
        self.module = module
        self.tree = tree
        self.functions: Dict[str, ast.AST] = dict(iter_functions(tree))
        self.imports = import_map(tree)
        # factory pattern:  compute = _round_compute(...)  where the factory
        # is a local def whose `return` hands back one of its nested defs.
        self.factory_returns: Dict[str, str] = {}
        for qual, fn in self.functions.items():
            returned = self._returned_nested_def(qual, fn)
            if returned:
                self.factory_returns[qual] = returned

    def _returned_nested_def(self, qual: str, fn: ast.AST) -> Optional[str]:
        nested = {n.name: f"{qual}.{n.name}" for n in fn.body
                  if isinstance(n, FuncDef)}
        for node in ast.walk(fn):
            if (isinstance(node, ast.Return)
                    and isinstance(node.value, ast.Name)
                    and node.value.id in nested):
                return nested[node.value.id]
        return None

    def resolve_local(self, name: str, scope: str) -> Optional[str]:
        """Resolve a bare called name to a qualname in this module.

        Searches innermost-out from `scope` (a qualname prefix), then
        module level.
        """
        parts = scope.split(".") if scope else []
        while True:
            cand = ".".join(parts + [name]) if parts else name
            if cand in self.functions:
                return cand
            if not parts:
                return None
            parts.pop()


def reachable_functions(
        indexes: Dict[str, ModuleIndex],
        roots: List[Tuple[str, str]]) -> Set[Tuple[str, str]]:
    """Transitive closure of (module, qualname) callable from `roots`.

    Follows bare-name calls, the local factory pattern, and imports that
    land in another analyzed module. `jax.*` / `jnp.*` calls terminate.
    """
    seen: Set[Tuple[str, str]] = set()
    work = list(roots)
    while work:
        module, qual = work.pop()
        if (module, qual) in seen:
            continue
        idx = indexes.get(module)
        if idx is None or qual not in idx.functions:
            continue
        seen.add((module, qual))
        fn = idx.functions[qual]
        for node in ast.walk(fn):
            if not isinstance(node, ast.Call):
                continue
            name = call_name(node)
            if not name or name.split(".")[0] in ("jax", "jnp", "np"):
                continue
            head = name.split(".")[0]
            local = idx.resolve_local(head, qual)
            if local:
                work.append((module, local))
                if local in idx.factory_returns:
                    work.append((module, idx.factory_returns[local]))
                continue
            origin = idx.imports.get(head)
            if origin and origin in indexes:          # `import mod` form
                tail = name.split(".", 1)[1] if "." in name else ""
                if tail and tail in indexes[origin].functions:
                    work.append((origin, tail))
            elif origin:                               # from mod import f
                mod, _, f = origin.rpartition(".")
                if mod in indexes and f in indexes[mod].functions:
                    work.append((mod, f))
        # names bound from factory calls inside this fn:  b = factory(...)
        for node in ast.walk(fn):
            if isinstance(node, ast.Assign) and isinstance(node.value,
                                                           ast.Call):
                fname = call_name(node.value)
                local = idx.resolve_local(fname.split(".")[0], qual)
                if local and local in idx.factory_returns:
                    work.append((module, idx.factory_returns[local]))
    return seen


def scan_body_roots(index: ModuleIndex) -> List[Tuple[str, str]]:
    """Round-body functions handed to lax.scan / fori_loop in a module."""
    roots: List[Tuple[str, str]] = []
    for qual, fn in list(index.functions.items()) + [("", index.tree)]:
        for node in ast.walk(fn) if qual else ast.walk(index.tree):
            if not isinstance(node, ast.Call):
                continue
            name = call_name(node)
            body_arg: Optional[ast.AST] = None
            if name.endswith("lax.scan") and node.args:
                body_arg = node.args[0]
            elif name.endswith("lax.fori_loop") and len(node.args) >= 3:
                body_arg = node.args[2]
            if isinstance(body_arg, ast.Name):
                local = index.resolve_local(body_arg.id, qual)
                if local:
                    roots.append((index.module, local))
    return roots
