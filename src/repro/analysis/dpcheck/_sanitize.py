"""Runtime half of dpcheck: a key-reuse sanitizer for jax.random.

    with dpcheck.sanitize() as rec:
        fed.run_rounds(...)
    assert rec.draws > 0 and rec.skipped == 0

The context manager enters ``jax.disable_jit()`` (so keys are concrete and
lax.scan/fori_loop run their eager reference paths) and monkeypatches the
jax.random samplers plus ``split`` to hash the consumed key material and
raise ``KeyReuseError`` when

  * a sampler draws from a key that a sampler already consumed,
  * a sampler draws from a key that was already split,
  * the same key is split twice, or split after being consumed.

``fold_in`` is untouched — deriving is how fresh streams are made (the
codec-salt contract from PR 5 depends on it). Keys whose bytes cannot be
read (abstract tracers) are counted in ``rec.skipped`` instead of checked,
so the sanitizer never aborts a run it cannot see into; tests assert
``skipped == 0`` to prove full coverage.

Coverage caveat: patching happens on the ``jax.random`` module, so only
calls that go through attribute access (``jax.random.normal(...)``) are
recorded. References bound *before* entering the context —
``from jax.random import normal``, ``functools.partial(jax.random.normal)``,
module-level aliases — call the original sampler and are neither checked
nor counted in ``rec.skipped``. Code run under ``sanitize()`` must invoke
samplers via ``jax.random.*`` (all of ``src/repro`` does; dpcheck's static
pass has no rule for import-time binding, so new code should follow suit).
"""
from __future__ import annotations

import contextlib
import functools
import hashlib
from typing import Dict, Iterator, Optional

import jax
import numpy as np

SAMPLER_NAMES = (
    "normal", "uniform", "laplace", "bernoulli", "randint", "bits",
    "gumbel", "exponential", "gamma", "beta", "cauchy", "dirichlet",
    "truncated_normal", "categorical", "poisson", "rademacher",
    "permutation", "choice", "logistic",
)


class KeyReuseError(RuntimeError):
    """A jax.random key was consumed twice under dpcheck.sanitize()."""


def _concrete_key_bytes(key) -> Optional[bytes]:
    """Hashable bytes of a key's threefry state, or None if abstract."""
    try:
        data = key
        if hasattr(data, "dtype") and jax.dtypes.issubdtype(
                data.dtype, jax.dtypes.prng_key):
            data = jax.random.key_data(data)
        # vmap under disable_jit hands us BatchTracers over concrete
        # arrays; .val is the stacked concrete payload (one hash covers
        # the whole batch of lanes, which is exactly the reuse unit).
        for _ in range(4):
            if hasattr(data, "val"):
                data = data.val
            else:
                break
        arr = np.asarray(data)
    except Exception:
        return None
    return hashlib.sha1(
        arr.tobytes() + str(arr.shape).encode()).digest()


class Recorder:
    """Consumed/split key hashes plus coverage counters."""

    def __init__(self) -> None:
        self.consumed: Dict[bytes, str] = {}
        self.split: Dict[bytes, str] = {}
        self.draws = 0
        self.splits = 0
        self.skipped = 0

    def _use(self, key, what: str, is_split: bool) -> None:
        h = _concrete_key_bytes(key)
        if h is None:
            self.skipped += 1
            return
        if h in self.consumed:
            raise KeyReuseError(
                f"key reuse: {what} drew from a key already consumed by "
                f"{self.consumed[h]}")
        if is_split:
            if h in self.split:
                raise KeyReuseError(
                    f"key reuse: {what} split a key already split by "
                    f"{self.split[h]}")
            self.split[h] = what
            self.splits += 1
        else:
            if h in self.split:
                raise KeyReuseError(
                    f"key reuse: {what} drew from a key already split by "
                    f"{self.split[h]}")
            self.consumed[h] = what
            self.draws += 1


@contextlib.contextmanager
def sanitize() -> Iterator[Recorder]:
    """Patch jax.random and run eagerly; raise on any key reuse."""
    rec = Recorder()
    saved = {}

    def wrap(name: str, fn, is_split: bool):
        @functools.wraps(fn)
        def wrapper(key, *args, **kwargs):
            rec._use(key, f"jax.random.{name}", is_split)
            # forwarding wrapper: records the use, then delegates
            return fn(key, *args, **kwargs)  # dpcheck: ignore[DPC105]
        return wrapper

    with jax.disable_jit():
        try:
            for name in SAMPLER_NAMES:
                fn = getattr(jax.random, name, None)
                if fn is not None:
                    saved[name] = fn
                    setattr(jax.random, name, wrap(name, fn, False))
            saved["split"] = jax.random.split
            jax.random.split = wrap("split", jax.random.split, True)
            yield rec
        finally:
            for name, fn in saved.items():
                setattr(jax.random, name, fn)
