"""DPC501 — donation safety.

A buffer donated through ``jax.jit(..., donate_argnums=...)`` is dead
after the donating call; XLA may have aliased its memory into the output.
Flag, per function: ``g = jax.jit(f, donate_argnums=(i, ...))`` followed
by ``g(a, b, ...)`` and then any later read of a name that sat in a
donated position, unless the name was rebound first (the idiomatic
``state = step(state, ...)`` pattern is safe).
"""
from __future__ import annotations

import ast
from typing import Dict, List, Set, Tuple

from repro.analysis.dpcheck.core import FileCtx, Violation
from repro.analysis.dpcheck.dataflow import (assigned_names, call_name,
                                             iter_functions)


def _donate_positions(call: ast.Call) -> Tuple[int, ...]:
    for kw in call.keywords:
        if kw.arg == "donate_argnums":
            v = kw.value
            if isinstance(v, ast.Constant) and isinstance(v.value, int):
                return (v.value,)
            if isinstance(v, (ast.Tuple, ast.List)):
                return tuple(e.value for e in v.elts
                             if isinstance(e, ast.Constant)
                             and isinstance(e.value, int))
    return ()


def _own_nodes(s: ast.stmt) -> List[ast.AST]:
    """The statement's own expressions — compound bodies are separate
    statements in the linear pass and must not be walked twice."""
    if isinstance(s, ast.For):
        return [s.target, s.iter]
    if isinstance(s, (ast.While, ast.If)):
        return [s.test]
    if isinstance(s, ast.With):
        return [i.context_expr for i in s.items]
    if isinstance(s, ast.Try):
        return []
    return [s]


def check_file(ctx: FileCtx) -> List[Violation]:
    out: List[Violation] = []
    for qual, fn in iter_functions(ctx.tree):
        donating: Dict[str, Tuple[int, ...]] = {}
        dead: Dict[str, int] = {}          # var -> line it was donated at
        # linear pass over this def's statements in source order, without
        # descending into nested defs (they get their own pass)
        stmts: List[ast.stmt] = []
        todo = [s for s in fn.body
                if not isinstance(s, (ast.FunctionDef, ast.AsyncFunctionDef,
                                      ast.ClassDef))]
        while todo:
            s = todo.pop(0)
            stmts.append(s)
            for child in ast.iter_child_nodes(s):
                if isinstance(child, ast.stmt) and not isinstance(
                        child, (ast.FunctionDef, ast.AsyncFunctionDef,
                                ast.ClassDef)):
                    todo.append(child)
        stmts.sort(key=lambda s: s.lineno)
        for s in stmts:
            bound: Set[str] = set()
            if isinstance(s, ast.Assign):
                for t in s.targets:
                    bound.update(assigned_names(t))
                if isinstance(s.value, ast.Call) and call_name(
                        s.value).endswith("jit"):
                    pos = _donate_positions(s.value)
                    if pos:
                        for n in bound:
                            donating[n] = pos
            own = _own_nodes(s)
            # reads of dead names (before this statement rebinds them)
            for node in (n for o in own for n in ast.walk(o)):
                if (isinstance(node, ast.Name)
                        and isinstance(node.ctx, ast.Load)
                        and node.id in dead):
                    out.append(Violation(
                        "DPC501", ctx.rel, node.lineno,
                        f"`{node.id}` read in `{qual}` after being donated "
                        f"(line {dead[node.id]}) — the buffer may be "
                        "aliased into the output"))
                    del dead[node.id]     # one report per donation
            # new donations made by this statement
            for node in (n for o in own for n in ast.walk(o)):
                if (isinstance(node, ast.Call)
                        and isinstance(node.func, ast.Name)
                        and node.func.id in donating):
                    for i in donating[node.func.id]:
                        if i < len(node.args) and isinstance(
                                node.args[i], ast.Name):
                            name = node.args[i].id
                            if name not in bound:   # rebound = safe
                                dead[name] = node.lineno
            for n in bound:
                dead.pop(n, None)
    return out
