"""Owner-sharded data pipeline for deep-model async-DP training.

Each owner holds a private token shard; `OwnerDataPipeline` yields
(owner_idx, batch) pairs following the Poisson/uniform schedule, so the
training loop touches exactly one owner's data per step — the asynchrony
contract of Algorithm 1.
"""
from __future__ import annotations

from typing import Dict, Iterator, List, Tuple

import numpy as np


class OwnerShard:
    def __init__(self, tokens: np.ndarray, owner_id: int):
        self.tokens = tokens          # (n_seqs, seq_len) int32
        self.owner_id = owner_id
        self._cursor = 0

    @property
    def n_records(self) -> int:
        return self.tokens.shape[0]

    def next_batch(self, batch: int) -> Dict[str, np.ndarray]:
        n = self.n_records
        idx = (self._cursor + np.arange(batch)) % n
        self._cursor = int((self._cursor + batch) % n)
        toks = self.tokens[idx]
        return {"tokens": toks, "labels": np.roll(toks, -1, axis=1)}


class OwnerDataPipeline:
    def __init__(self, shards: List[OwnerShard], batch: int, seed: int = 0):
        self.shards = shards
        self.batch = batch
        self.rng = np.random.default_rng(seed)

    @property
    def owner_sizes(self) -> List[int]:
        return [s.n_records for s in self.shards]

    def schedule(self, horizon: int) -> np.ndarray:
        """Uniform i_k sequence (≡ rate-1 Poisson clocks, see core.clocks)."""
        return self.rng.integers(0, len(self.shards), size=horizon)

    def __iter__(self) -> Iterator[Tuple[int, Dict[str, np.ndarray]]]:
        while True:
            i = int(self.rng.integers(0, len(self.shards)))
            yield i, self.shards[i].next_batch(self.batch)

    def batches_for(self, owner_seq: np.ndarray) -> Dict[str, np.ndarray]:
        """Stack one batch per round for a (K,) owner sequence — the input
        layout of the fused multi-round driver (`Federation.run_rounds`):
        leaf k holds owner_seq[k]'s next microbatch, leaves become
        (K, batch, ...). Each shard's cursor advances exactly as if the
        rounds were fetched one-by-one."""
        per_round = [self.shards[int(i)].next_batch(self.batch)
                     for i in np.asarray(owner_seq)]
        if not per_round:
            raise ValueError("empty owner sequence")
        return {k: np.stack([b[k] for b in per_round])
                for k in per_round[0]}


def synthetic_owner_shards(n_owners: int, records_per_owner: int,
                           seq_len: int, vocab: int, seed: int = 0
                           ) -> List[OwnerShard]:
    rng = np.random.default_rng(seed)
    return [OwnerShard(rng.integers(0, vocab,
                                    size=(records_per_owner, seq_len),
                                    dtype=np.int32), i)
            for i in range(n_owners)]
