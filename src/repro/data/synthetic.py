"""Synthetic stand-ins for the paper's datasets (offline container).

`lending`  — mimics the Lending Club interest-rate regression (Sec. 5.1):
             ~10 post-PCA features (decaying variance like PCA components),
             target = linear signal + noise, mildly heavy-tailed.
`health`   — mimics NY SPARCS length-of-stay (Sec. 5.2): mixed
             categorical-coded integer features + skewed positive target.

Both generators produce data whose *scale statistics* (feature variances,
target variance) are fixed and documented so Xi bounds and the fitted
Theorem-2 constants are stable across seeds.
"""
from __future__ import annotations

from typing import List, Tuple

import numpy as np


def lending(n: int, seed: int = 0, p: int = 10,
            theta_shift: np.ndarray = None) -> Tuple[np.ndarray, np.ndarray]:
    """Post-PCA features are normalized (the paper runs PCA "to improve
    numerical stability"), so feature magnitudes — and hence the gradient
    bound Xi — are O(1-10), matching the noise regime of Figs. 4-6."""
    rng = np.random.default_rng(seed)
    # PCA-like spectrum: component i has std ~ 0.3/sqrt(1+i)
    stds = 0.3 / np.sqrt(1.0 + np.arange(p))
    X = rng.normal(size=(n, p)) * stds
    X = np.clip(X, -1.0, 1.0)                    # bounded features (public)
    theta_true = rng.uniform(-1.0, 1.0, size=p)
    if theta_shift is not None:
        theta_true = theta_true + theta_shift
    y = X @ theta_true + 0.1 * rng.standard_t(df=6, size=n)
    y = np.clip(y, -2.0, 2.0)
    return X.astype(np.float64), y.astype(np.float64)


def health(n: int, seed: int = 0, p: int = 10,
           theta_shift: np.ndarray = None) -> Tuple[np.ndarray, np.ndarray]:
    rng = np.random.default_rng(seed + 7919)
    # integer-coded categorical-ish features, normalized
    levels = rng.integers(2, 12, size=p)
    X = np.stack([rng.integers(0, lv, size=n) / lv for lv in levels], axis=1)
    X = 0.5 * (X - X.mean(axis=0, keepdims=True))
    theta_true = rng.uniform(0.0, 1.5, size=p)
    if theta_shift is not None:
        theta_true = theta_true + theta_shift
    los = np.exp(0.5 * (X @ theta_true)) + rng.gamma(2.0, 0.3, size=n)
    y = np.clip(los, 0.0, 3.0)                   # length of stay (normalized)
    return X.astype(np.float64), y.astype(np.float64)


GENERATORS = {"lending": lending, "health": health}


def owner_shards(dataset: str, sizes: List[int], seed: int = 0, p: int = 10,
                 heterogeneity: float = 0.3
                 ) -> List[Tuple[np.ndarray, np.ndarray]]:
    """Per-owner shards with owner-level distribution shift.

    Real collaborating institutions (the paper's banks/hospitals) have
    different local y|x relationships; ``heterogeneity`` scales a per-owner
    perturbation of the generating coefficients. This is what makes the
    isolated single-owner model genuinely worse on the GLOBAL fitness
    (Fig. 6/7's psi(theta_1*) markers sit well above 0). heterogeneity=0
    recovers IID shards.
    """
    rng = np.random.default_rng(seed + 101)
    gen = GENERATORS[dataset]
    shards = []
    for i, s in enumerate(sizes):
        shift = heterogeneity * rng.normal(size=p)
        shards.append(gen(s, seed=seed + 13 * i, p=p, theta_shift=shift))
    return shards


def token_batch(rng: np.ndarray, batch: int, seq: int, vocab: int):
    """Synthetic LM batch for deep-model examples/benchmarks."""
    rng = np.random.default_rng(rng)
    toks = rng.integers(0, vocab, size=(batch, seq), dtype=np.int32)
    return {"tokens": toks, "labels": np.roll(toks, -1, axis=1)}
