from repro.data.pipeline import (OwnerDataPipeline, OwnerShard,
                                 synthetic_owner_shards)
from repro.data.synthetic import (GENERATORS, health, lending, owner_shards,
                                  token_batch)
