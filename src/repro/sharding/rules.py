"""Path-based sharding rules: param/batch/cache pytrees -> PartitionSpec.

Strategy (single-pod mesh (data=16, model=16); multi-pod adds pod=2):
  * weights: FSDP over 'data' on the d_model-like axis, TP over 'model' on
    heads / d_ff / experts / vocab. Replicated across 'pod' (pure DP between
    pods; cross-pod FSDP is a recorded §Perf candidate).
  * activations/batch: batch dim over ('pod','data'); long_500k (B=1)
    shards the KV-cache/sequence axis over ('pod','data') instead.
  * every rule degrades to None when the dim is not divisible by the axis
    size (e.g. MQA kv=1 -> shard head_dim instead of kv heads).
"""
from __future__ import annotations

from typing import Any, NamedTuple, Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeConfig


def data_axes(mesh: Mesh) -> Tuple[str, ...]:
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


def axis_size(mesh: Mesh, name) -> int:
    if isinstance(name, (tuple, list)):
        return int(np.prod([axis_size(mesh, a) for a in name]))
    return mesh.shape[name] if name in mesh.axis_names else 1


def _div(dim: int, size: int) -> bool:
    return size > 0 and dim % size == 0


def _maybe(mesh: Mesh, axis, dim: int):
    return axis if _div(dim, axis_size(mesh, axis)) else None


def _path_tokens(path) -> Tuple[str, ...]:
    toks = []
    for p in path:
        for attr in ("key", "name", "idx"):
            if hasattr(p, attr):
                toks.append(str(getattr(p, attr)))
                break
        else:
            toks.append(str(p))
    return tuple(toks)


def spec_for_param(path_tokens: Tuple[str, ...], shape: Tuple[int, ...],
                   cfg: ModelConfig, mesh: Mesh) -> P:
    t = set(path_tokens)
    last = path_tokens[-1] if path_tokens else ""
    M, D = "model", "data"
    ms = axis_size(mesh, M)

    if len(shape) <= 1:
        return P()  # norms, scalar gate params — replicate

    # --- embeddings -------------------------------------------------
    if last == "embed":
        return P(_maybe(mesh, M, shape[0]), _maybe(mesh, D, shape[1]))
    if last == "unembed":
        return P(_maybe(mesh, D, shape[0]), _maybe(mesh, M, shape[1]))
    if last in ("patch_proj",):
        return P(_maybe(mesh, D, shape[0]), _maybe(mesh, M, shape[1]))
    if last == "enc_pos":
        return P(None, None)

    # --- attention --------------------------------------------------
    if "attn" in t or "self" in t or "cross" in t or last == "shared_attn" \
            or any(x in ("attn", "self", "cross", "shared_attn")
                   for x in path_tokens):
        if last == "wq":
            return P(_maybe(mesh, D, shape[0]), _maybe(mesh, M, shape[1]), None)
        if last in ("wk", "wv"):
            if _div(shape[1], ms):
                return P(_maybe(mesh, D, shape[0]), M, None)
            return P(_maybe(mesh, D, shape[0]), None, _maybe(mesh, M, shape[2]))
        if last == "wo":
            return P(_maybe(mesh, M, shape[0]), None, _maybe(mesh, D, shape[2]))
        if last == "bq":
            return P(_maybe(mesh, M, shape[0]), None)
        if last in ("bk", "bv"):
            if _div(shape[0], ms):
                return P(M, None)
            return P(None, _maybe(mesh, M, shape[1]))

    # --- MoE ----------------------------------------------------------
    if last == "router":
        return P(_maybe(mesh, D, shape[0]), None)
    if last in ("w_gate", "w_up") and len(shape) == 3:   # (E, d, f)
        if _div(shape[0], ms):
            return P(M, _maybe(mesh, D, shape[1]), None)
        return P(None, _maybe(mesh, D, shape[1]), _maybe(mesh, M, shape[2]))
    if last == "w_down" and len(shape) == 3:             # (E, f, d)
        if _div(shape[0], ms):
            return P(M, None, _maybe(mesh, D, shape[2]))
        return P(None, _maybe(mesh, M, shape[1]), _maybe(mesh, D, shape[2]))

    # --- dense MLP ----------------------------------------------------
    if last in ("w_gate", "w_up"):                       # (d, f)
        return P(_maybe(mesh, D, shape[0]), _maybe(mesh, M, shape[1]))
    if last == "w_down":                                 # (f, d)
        return P(_maybe(mesh, M, shape[0]), _maybe(mesh, D, shape[1]))

    # --- Mamba2 ---------------------------------------------------------
    if last in ("w_z", "w_x"):                           # (d, d_in)
        return P(_maybe(mesh, D, shape[0]), _maybe(mesh, M, shape[1]))
    if last in ("w_B", "w_C", "w_dt"):                   # (d, N|H)
        return P(_maybe(mesh, D, shape[0]), None)
    if last == "conv":
        return P(None, None)
    if last == "w_out":                                  # (d_in, d)
        return P(_maybe(mesh, M, shape[0]), _maybe(mesh, D, shape[1]))

    # --- xLSTM ----------------------------------------------------------
    if last in ("w_q", "w_k", "w_v") and len(shape) == 3:  # (dm, H, N)
        return P(_maybe(mesh, M, shape[0]), None, None)
    if last in ("w_i", "w_f"):                           # (dm, H)
        return P(_maybe(mesh, M, shape[0]), None)
    if last == "w_in" and len(shape) == 4:               # (d, H, hd, 4)
        return P(_maybe(mesh, D, shape[0]), None, None, None)
    if last == "r":                                      # (H, hd, hd, 4)
        return P(None, None, None, None)

    # --- generic 2D fallback: FSDP x TP -------------------------------
    if len(shape) == 2:
        return P(_maybe(mesh, D, shape[0]), _maybe(mesh, M, shape[1]))
    return P(*([None] * len(shape)))


def param_specs(params: Any, cfg: ModelConfig, mesh: Mesh,
                bank_axis: bool = False) -> Any:
    """PartitionSpec pytree for params (or the owner bank if bank_axis)."""
    # stacked layer axis: scan-family blocks leaves carry a leading L dim —
    # strip it. List-family blocks (xLSTM) have a numeric index in the path
    # and NO leading layer dim.
    def g(path, leaf):
        toks = _path_tokens(path)
        shape = tuple(leaf.shape)
        off = 1 if bank_axis else 0
        core = shape[off:]
        is_list_block = any(t.isdigit() for t in toks)
        if ("blocks" in toks or "enc_blocks" in toks) and not is_list_block:
            spec = spec_for_param(toks, core[1:], cfg, mesh)
            spec = P(None, *spec)
        else:
            spec = spec_for_param(toks, core, cfg, mesh)
        if bank_axis:
            spec = P(None, *spec)
        return spec
    return jax.tree_util.tree_map_with_path(g, params)


def batch_specs(batch: Any, shape_cfg: ShapeConfig, mesh: Mesh,
                microbatches: int = 0) -> Any:
    """tokens/labels (B,S) or microbatch-major (G,m,S); patches/frames get
    one extra trailing dim."""
    B = shape_cfg.global_batch
    da = data_axes(mesh)
    rows = B // microbatches if microbatches else B
    bshard = da if _div(rows, axis_size(mesh, da)) else None

    def f(path, leaf):
        nd = len(leaf.shape)
        if microbatches:                       # (G, m, ...)
            return P(*((None, bshard) + (None,) * (nd - 2)))
        return P(*((bshard,) + (None,) * (nd - 1)))

    return jax.tree_util.tree_map_with_path(f, batch)


def cache_specs(cache: Any, cfg: ModelConfig, mesh: Mesh, batch: int) -> Any:
    """KV caches (L,B,C,Kv,hd) / states. B==1 -> shard cache seq axis."""
    da = data_axes(mesh)
    ds = axis_size(mesh, da)
    ms = axis_size(mesh, "model")
    bshard = da if _div(batch, ds) else None

    def f(path, leaf):
        toks = _path_tokens(path)
        s = tuple(leaf.shape)
        if "kv" in toks or "cross" in toks or "shared" in toks:
            # (L,B,C,Kv,hd) stacked or (B,C,Kv,hd) per-layer
            off = 1 if len(s) == 5 else 0
            Bc, C, Kv, hd = s[off:]
            kv_ax = ("model" if _div(Kv, ms) else None)
            hd_ax = (None if kv_ax else ("model" if _div(hd, ms) else None))
            if bshard is not None:
                spec = (bshard, None, kv_ax, hd_ax)
            else:
                spec = (None, da if _div(C, ds) else None, kv_ax, hd_ax)
            return P(*((None,) * off + spec))
        if "mamba" in toks:                      # h (B,H,N,P) / conv (B,K,C)
            if len(s) == 4:
                return P(bshard, "model" if _div(s[1], ms) else None, None, None)
            return P(bshard, None, "model" if _div(s[2], ms) else None)
        if "states" in toks:                     # xlstm states
            return P(*((bshard,) + (None,) * (len(s) - 1)))
        return P(*([None] * len(s)))

    return jax.tree_util.tree_map_with_path(f, cache)


def named(mesh: Mesh, spec_tree: Any) -> Any:
    return jax.tree_util.tree_map(lambda s: NamedSharding(mesh, s), spec_tree,
                                  is_leaf=lambda x: isinstance(x, P))


# ------------------- flat federation state (owner bank) ---------------------
# The deep-path flat engine's state is two buffers: theta_L (P,) and the
# owner bank (N_owners, P) — the algorithm's dominant memory (N model
# copies). The bank is the natural FSDP target: the owner axis N is the
# engine's data-parallel dimension (rounds touch one row each), so it
# shards over the data axes; P shards like the model over 'model'. When N
# does not divide the data axes (small federations on big meshes) the data
# axes fold into P instead, so the bank bytes still spread over every
# chip. theta_L and a gathered bank row always share the bank's P-axis
# sharding — the round's elementwise ops (theta_bar, eqs. 5/7) then never
# reshard. Every rule degrades to replication when the dim is not
# divisible (same convention as the model rules above).


class FlatShardings(NamedTuple):
    """NamedShardings for the flat-engine state buffers.

    Quantized banks (flatten.QuantBank) reuse the bundle: `bank` lays out
    the (N, P) code matrix, `bank_scales` the (N, nb) per-row/per-block
    scales (owner rows over the same data axes, the tiny scale axis
    replicated), and `row` the shared (P,) error-feedback residual —
    which, like a gathered row, must live exactly where theta lives.
    """
    theta: NamedSharding        # theta_L buffer (P,)
    bank: NamedSharding         # owner bank (N_owners, P) — codes if quant
    row: NamedSharding          # one gathered bank row / EF residual (P,)
    ledger: NamedSharding       # (N,) int32 counters — replicated (tiny)
    bank_scales: NamedSharding = None   # quant-bank scales (N_owners, nb)
    # DP-FTRL noise-tree node buffer (N_owners, depth, P): owner rows over
    # the data axes and P like the model — exactly the bank's layout with a
    # replicated depth axis in between, so the per-round row gather/scatter
    # and the tree-delta elementwise ops stay local in P.
    tree_nodes: NamedSharding = None
    # Fault-layer counters (faults.FaultState: four (N,) vectors) — tiny,
    # replicated exactly like the ledger.
    faults: NamedSharding = None


def flat_axes(mesh: Mesh, n_owners: int, p: int
              ) -> Tuple[Optional[Tuple[str, ...]], Optional[Tuple[str, ...]]]:
    """(owner-axis, P-axis) mesh axes for the (N_owners, P) bank."""
    da = data_axes(mesh)
    ds, ms = axis_size(mesh, da), axis_size(mesh, "model")
    n_ax = tuple(da) if (ds > 1 and _div(n_owners, ds)) else None
    p_axes = ["model"] if (ms > 1 and _div(p, ms)) else []
    if n_ax is None and ds > 1 and _div(p, ds * (ms if p_axes else 1)):
        p_axes.extend(da)
    return n_ax, (tuple(p_axes) if p_axes else None)


def flat_theta_spec(mesh: Mesh, n_owners: int, p: int) -> P:
    return P(flat_axes(mesh, n_owners, p)[1])


def flat_bank_spec(mesh: Mesh, n_owners: int, p: int) -> P:
    n_ax, p_ax = flat_axes(mesh, n_owners, p)
    return P(n_ax, p_ax)


def flat_shardings(mesh: Mesh, n_owners: int, p: int) -> FlatShardings:
    """The flat engine's sharding bundle, degraded to what divides."""
    n_ax, p_ax = flat_axes(mesh, n_owners, p)
    return FlatShardings(theta=NamedSharding(mesh, P(p_ax)),
                         bank=NamedSharding(mesh, P(n_ax, p_ax)),
                         row=NamedSharding(mesh, P(p_ax)),
                         ledger=NamedSharding(mesh, P()),
                         bank_scales=NamedSharding(mesh, P(n_ax)),
                         tree_nodes=NamedSharding(mesh, P(n_ax, None, p_ax)),
                         faults=NamedSharding(mesh, P()))


def paged_shardings(mesh: Mesh, n_hot: int, p: int) -> FlatShardings:
    """Sharding bundle for a PAGED flat state (flatten.PagedBank).

    Hot rows shard exactly like bank rows, with `n_hot` standing in for
    N on the owner axis — the resident working set is the only
    row-scaled buffer on device, so it (and the paged tree-node buffer,
    (n_hot, depth, P)) takes the data axes while the per-owner (N,)
    counter columns (ledger, tree leaf counts, fault state) stay
    replicated like every other counter. The page table (hot_ids) is a
    tiny (n_hot,) int32 vector and rides the replicated `ledger` rule.
    Divisibility degrades per-axis exactly as `flat_shardings` does —
    pick an n_hot that divides the data-axis size to keep rows spread.
    """
    return flat_shardings(mesh, n_hot, p)
