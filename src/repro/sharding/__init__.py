from repro.sharding.rules import (FlatShardings, axis_size, batch_specs,
                                  cache_specs, data_axes, flat_axes,
                                  flat_bank_spec, flat_shardings,
                                  flat_theta_spec, named, param_specs,
                                  spec_for_param)
