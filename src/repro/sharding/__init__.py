from repro.sharding.rules import (axis_size, batch_specs, cache_specs,
                                  data_axes, named, param_specs,
                                  spec_for_param)
