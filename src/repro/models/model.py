"""Unified model API over all assigned architecture families.

    lm = build_model(cfg)                       # cfg: ModelConfig
    params = lm.init(key, dtype)
    loss, metrics = lm.loss(params, batch)      # train / prefill
    cache = lm.init_cache(batch_size, max_seq, window=...)
    logits, cache = lm.decode_step(params, cache, tokens, pos, window=...)

Batch dict:  tokens (B,S) int32, labels (B,S) int32,
             + patches (B, n_patches, d) for VLM,
             + frames (B, enc_seq, d) for audio (stub frontends).

Train/prefill paths scan over stacked layer params (compile-time O(1) in
depth) with optional remat; decode scans where caches are homogeneous and
unrolls otherwise (xLSTM, zamba2).
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention as attn
from repro.models import mlp as mlp_mod
from repro.models import moe as moe_mod
from repro.models import ssm as ssm_mod
from repro.models import xlstm as xlstm_mod
from repro.models.layers import embed_init, rms_norm, split_keys

Params = Dict[str, Any]
Batch = Dict[str, jax.Array]


def _stack_init(init_one, keys):
    return jax.vmap(init_one)(jnp.stack(keys))


def chunked_lm_loss(x: jax.Array, unembed: jax.Array, labels: jax.Array,
                    chunk: int = 512) -> jax.Array:
    """Cross-entropy without materializing (B,S,V) fp32 logits.

    x: (B,S,d) final hiddens; unembed: (d,V); labels: (B,S) int32.
    Positions with label < 0 are masked out.
    """
    B, S, d = x.shape
    chunk = min(chunk, S)
    pad = (-S) % chunk
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0)))
        labels = jnp.pad(labels, ((0, 0), (0, pad)), constant_values=-1)
    Sp = S + pad
    nc = Sp // chunk
    xc = x.reshape(B, nc, chunk, d).swapaxes(0, 1)
    lc = labels.reshape(B, nc, chunk).swapaxes(0, 1)

    def step(carry, c):
        tot, cnt = carry
        xi, li = c
        logits = jnp.einsum("bsd,dv->bsv", xi, unembed).astype(jnp.float32)
        logz = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, jnp.maximum(li, 0)[..., None],
                                   axis=-1)[..., 0]
        mask = (li >= 0).astype(jnp.float32)
        tot = tot + jnp.sum((logz - gold) * mask)
        cnt = cnt + jnp.sum(mask)
        return (tot, cnt), None

    (tot, cnt), _ = jax.lax.scan(step, (jnp.zeros((), jnp.float32),
                                        jnp.zeros((), jnp.float32)), (xc, lc))
    return tot / jnp.maximum(cnt, 1.0)


# ===========================================================================
class LM:
    def __init__(self, cfg: ModelConfig, *, remat: bool = True,
                 moe_mode: str = "onehot", moe_group_tokens: int = 512,
                 kv_chunk: int = 1024, remat_groups: int = 0,
                 attn_backend: str = "jnp"):
        self.cfg = cfg
        self.remat = remat
        self.moe_mode = moe_mode
        self.moe_group_tokens = moe_group_tokens
        self.kv_chunk = kv_chunk
        self.attn_backend = attn_backend
        # remat_groups > 0: nested-remat — scan over `remat_groups` groups of
        # layers, checkpointing only each GROUP's input instead of every
        # layer's (residual stack shrinks L/remat_groups-fold; backward
        # recomputes one group at a time). §Perf knob.
        self.remat_groups = remat_groups

    # ---------------- init -------------------------------------------
    def init(self, key, dtype=jnp.float32) -> Params:
        cfg = self.cfg
        ks = split_keys(key, 8)
        p: Params = {
            "embed": embed_init(ks[0], (cfg.vocab, cfg.d_model), dtype),
            "ln_f": jnp.ones((cfg.d_model,), dtype),
        }
        if not cfg.tie_embeddings:
            p["unembed"] = embed_init(ks[1], (cfg.d_model, cfg.vocab), dtype)

        def init_dense_block(k):
            k1, k2 = jax.random.split(k)
            blk = {"ln1": jnp.ones((cfg.d_model,), dtype),
                   "ln2": jnp.ones((cfg.d_model,), dtype),
                   "attn": attn.init_attention(k1, cfg.d_model, cfg.n_heads,
                                               cfg.n_kv_heads, cfg.head_dim,
                                               cfg.qkv_bias, dtype)}
            if cfg.moe is not None:
                blk["ffn"] = moe_mod.init_moe(k2, cfg.d_model, cfg.moe, dtype)
            else:
                blk["ffn"] = mlp_mod.init_swiglu(k2, cfg.d_model, cfg.d_ff, dtype)
            return blk

        fam = cfg.family
        if fam in ("dense", "moe", "vlm", "audio"):
            p["blocks"] = _stack_init(init_dense_block,
                                      split_keys(ks[2], cfg.n_layers))
        if fam == "vlm":
            p["patch_proj"] = embed_init(ks[3], (cfg.d_model, cfg.d_model), dtype)
        if fam == "audio":
            def init_enc_block(k):
                k1, k2 = jax.random.split(k)
                return {"ln1": jnp.ones((cfg.d_model,), dtype),
                        "ln2": jnp.ones((cfg.d_model,), dtype),
                        "attn": attn.init_attention(k1, cfg.d_model, cfg.n_heads,
                                                    cfg.n_kv_heads, cfg.head_dim,
                                                    False, dtype),
                        "mlp": mlp_mod.init_gelu(k2, cfg.d_model, cfg.d_ff, dtype)}

            def init_dec_block(k):
                k1, k2, k3 = jax.random.split(k, 3)
                return {"ln1": jnp.ones((cfg.d_model,), dtype),
                        "ln2": jnp.ones((cfg.d_model,), dtype),
                        "ln3": jnp.ones((cfg.d_model,), dtype),
                        "self": attn.init_attention(k1, cfg.d_model, cfg.n_heads,
                                                    cfg.n_kv_heads, cfg.head_dim,
                                                    False, dtype),
                        "cross": attn.init_attention(k2, cfg.d_model, cfg.n_heads,
                                                     cfg.n_kv_heads, cfg.head_dim,
                                                     False, dtype),
                        "mlp": mlp_mod.init_gelu(k3, cfg.d_model, cfg.d_ff, dtype)}

            p["enc_blocks"] = _stack_init(init_enc_block,
                                          split_keys(ks[3], cfg.enc_layers))
            p["blocks"] = _stack_init(init_dec_block,
                                      split_keys(ks[2], cfg.n_layers))
            p["enc_pos"] = embed_init(ks[4], (cfg.enc_seq, cfg.d_model), dtype)
            p["enc_ln_f"] = jnp.ones((cfg.d_model,), dtype)
        if fam == "hybrid":
            def init_mamba_block(k):
                return {"ln": jnp.ones((cfg.d_model,), dtype),
                        "mamba": ssm_mod.init_mamba2(k, cfg.d_model, cfg.ssm, dtype)}
            p["blocks"] = _stack_init(init_mamba_block,
                                      split_keys(ks[2], cfg.n_layers))
            p["shared_ln"] = jnp.ones((cfg.d_model,), dtype)
            p["shared_attn"] = attn.init_attention(
                ks[3], cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim,
                cfg.qkv_bias, dtype)
        if fam == "ssm":  # xlstm
            xl = cfg.xlstm
            blocks = []
            for i, k in enumerate(split_keys(ks[2], cfg.n_layers)):
                if i in xl.slstm_indices:
                    blocks.append({"slstm": xlstm_mod.init_slstm(k, cfg, dtype)})
                else:
                    blocks.append({"mlstm": xlstm_mod.init_mlstm(k, cfg, dtype)})
            p["blocks"] = blocks
        return p

    def _unembed(self, params: Params) -> jax.Array:
        if self.cfg.tie_embeddings:
            return params["embed"].T
        return params["unembed"]

    # ---------------- forward (train / prefill) ----------------------
    def forward(self, params: Params, batch: Batch, *,
                window: Optional[int] = None) -> Tuple[jax.Array, jax.Array]:
        """Returns (final hiddens (B,S,d), moe aux loss scalar)."""
        cfg = self.cfg
        window = window if window is not None else cfg.sliding_window
        tokens = batch["tokens"]
        x = jnp.take(params["embed"], tokens, axis=0)
        aux = jnp.zeros((), jnp.float32)

        if cfg.family == "vlm":
            patches = jnp.einsum("bpd,de->bpe",
                                 batch["patches"].astype(x.dtype),
                                 params["patch_proj"])
            x = jnp.concatenate([patches, x], axis=1)

        B, S, _ = x.shape
        positions = jnp.arange(S)

        if cfg.family in ("dense", "moe", "vlm"):
            x, aux = self._dense_stack(params["blocks"], x, positions, window)
        elif cfg.family == "audio":
            enc = self._encode(params, batch["frames"])
            x, aux = self._audio_dec_stack(params["blocks"], x, enc,
                                           positions, window)
        elif cfg.family == "hybrid":
            x = self._hybrid_stack(params, x, positions, window)
        elif cfg.family == "ssm":
            x = self._xlstm_stack(params["blocks"], x)
        x = rms_norm(x, params["ln_f"], cfg.norm_eps)
        if cfg.family == "vlm":  # strip patch positions for the LM head
            x = x[:, batch["patches"].shape[1]:]
        return x, aux

    def _maybe_remat(self, f):
        return jax.checkpoint(f) if self.remat else f

    def _dense_stack(self, blocks, x, positions, window):
        cfg = self.cfg

        def body(carry, blk):
            h, aux = carry
            a = attn.attention_forward(blk["attn"],
                                       rms_norm(h, blk["ln1"], cfg.norm_eps),
                                       positions=positions,
                                       rope_theta=cfg.rope_theta,
                                       window=window, kv_chunk=self.kv_chunk,
                                       backend=self.attn_backend)
            h = h + a
            hin = rms_norm(h, blk["ln2"], cfg.norm_eps)
            if cfg.moe is not None:
                f, a_moe = moe_mod.moe_forward(
                    blk["ffn"], hin, cfg.moe, mode=self.moe_mode,
                    group_tokens=self.moe_group_tokens)
                aux = aux + a_moe
            else:
                f = mlp_mod.mlp_forward(blk["ffn"], hin)
            return (h + f, aux), None

        G = self.remat_groups
        if G and cfg.n_layers % G == 0 and G < cfg.n_layers:
            # nested remat: checkpoint BOTH levels. Forward saves only the G
            # group inputs; backward recomputes one group at a time, itself
            # under per-layer remat (transient residuals = L/G hiddens).
            # §Perf lesson: remat-outer with a plain inner scan is a trap —
            # the inner scan then saves every layer's full internals during
            # the recompute (measured 6x temp blow-up before this fix).
            grouped = jax.tree_util.tree_map(
                lambda leaf: leaf.reshape((G, cfg.n_layers // G) + leaf.shape[1:]),
                blocks)
            inner_body = self._maybe_remat(body)

            def group_body(carry, gblk):
                return jax.lax.scan(inner_body, carry, gblk)

            (x, aux), _ = jax.lax.scan(self._maybe_remat(group_body),
                                       (x, jnp.zeros((), jnp.float32)),
                                       grouped)
        else:
            (x, aux), _ = jax.lax.scan(self._maybe_remat(body),
                                       (x, jnp.zeros((), jnp.float32)),
                                       blocks)
        return x, aux / self.cfg.n_layers

    def _encode(self, params, frames):
        cfg = self.cfg
        x = frames.astype(params["enc_pos"].dtype) + params["enc_pos"][None]

        def body(h, blk):
            h = h + attn.encoder_attention(blk["attn"],
                                           rms_norm(h, blk["ln1"], cfg.norm_eps))
            h = h + mlp_mod.mlp_forward(blk["mlp"],
                                        rms_norm(h, blk["ln2"], cfg.norm_eps))
            return h, None

        x, _ = jax.lax.scan(self._maybe_remat(body), x, params["enc_blocks"])
        return rms_norm(x, params["enc_ln_f"], cfg.norm_eps)

    def _audio_dec_stack(self, blocks, x, enc, positions, window):
        cfg = self.cfg

        def body(carry, blk):
            h, _ = carry
            h = h + attn.attention_forward(
                blk["self"], rms_norm(h, blk["ln1"], cfg.norm_eps),
                positions=positions, rope_theta=cfg.rope_theta,
                window=window, kv_chunk=self.kv_chunk,
                backend=self.attn_backend)
            h = h + attn.cross_attention(
                blk["cross"], rms_norm(h, blk["ln2"], cfg.norm_eps),
                *attn.cross_kv(blk["cross"], enc))
            h = h + mlp_mod.mlp_forward(blk["mlp"],
                                        rms_norm(h, blk["ln3"], cfg.norm_eps))
            return (h, jnp.zeros((), jnp.float32)), None

        (x, _), _ = jax.lax.scan(self._maybe_remat(body),
                                 (x, jnp.zeros((), jnp.float32)), blocks)
        return x, jnp.zeros((), jnp.float32)

    def _hybrid_stack(self, params, x, positions, window):
        """Zamba2: scan over groups of `attn_every` Mamba2 layers; the
        SHARED attention block (reused weights) closes each group. Group
        scan keeps HLO trip counts static (no lax.cond)."""
        cfg = self.cfg
        shared = params["shared_attn"]
        shared_ln = params["shared_ln"]
        ae = cfg.attn_every
        n_groups = cfg.n_layers // ae
        grouped = jax.tree_util.tree_map(
            lambda leaf: leaf.reshape((n_groups, ae) + leaf.shape[1:]),
            params["blocks"])

        def inner(h, blk):
            h = h + ssm_mod.mamba2_forward(
                blk["mamba"], rms_norm(h, blk["ln"], cfg.norm_eps), cfg.ssm)
            return h, None

        def group(h, gblk):
            h, _ = jax.lax.scan(inner, h, gblk)
            h = h + attn.attention_forward(
                shared, rms_norm(h, shared_ln, cfg.norm_eps),
                positions=positions, rope_theta=cfg.rope_theta,
                window=window, kv_chunk=self.kv_chunk,
                backend=self.attn_backend)
            return h, None

        x, _ = jax.lax.scan(self._maybe_remat(group), x, grouped)
        return x

    def _xlstm_stack(self, blocks, x):
        cfg = self.cfg
        for i, blk in enumerate(blocks):
            if "slstm" in blk:
                x = x + xlstm_mod.slstm_forward(blk["slstm"], x, cfg)
            else:
                x = x + xlstm_mod.mlstm_forward(blk["mlstm"], x, cfg)
        return x

    # ---------------- loss -------------------------------------------
    def loss(self, params: Params, batch: Batch, *,
             window: Optional[int] = None) -> Tuple[jax.Array, Dict]:
        x, aux = self.forward(params, batch, window=window)
        ce = chunked_lm_loss(x, self._unembed(params), batch["labels"])
        lb = (self.cfg.moe.load_balance_coef if self.cfg.moe else 0.0)
        total = ce + lb * aux
        return total, {"ce": ce, "moe_aux": aux}

    # ---------------- decode -----------------------------------------
    def init_cache(self, batch: int, max_seq: int, *,
                   window: Optional[int] = None, dtype=jnp.bfloat16) -> Any:
        cfg = self.cfg
        window = window if window is not None else cfg.sliding_window
        cap = min(max_seq, window) if window else max_seq
        L = cfg.n_layers
        if cfg.family in ("dense", "moe", "vlm"):
            shape = (L, batch, cap, cfg.n_kv_heads, cfg.head_dim)
            return {"kv": attn.KVCache(jnp.zeros(shape, dtype),
                                       jnp.zeros(shape, dtype))}
        if cfg.family == "audio":
            shape = (L, batch, cap, cfg.n_kv_heads, cfg.head_dim)
            xshape = (L, batch, cfg.enc_seq, cfg.n_kv_heads, cfg.head_dim)
            return {"kv": attn.KVCache(jnp.zeros(shape, dtype),
                                       jnp.zeros(shape, dtype)),
                    "cross": attn.KVCache(jnp.zeros(xshape, dtype),
                                          jnp.zeros(xshape, dtype))}
        if cfg.family == "hybrid":
            n_apps = cfg.n_layers // cfg.attn_every
            shape = (batch, cap, cfg.n_kv_heads, cfg.head_dim)
            return {
                "mamba": [ssm_mod.init_mamba2_state(batch, cfg.d_model,
                                                    cfg.ssm, dtype=dtype)
                          for _ in range(L)],
                "shared": [attn.KVCache(jnp.zeros(shape, dtype),
                                        jnp.zeros(shape, dtype))
                           for _ in range(n_apps)],
            }
        if cfg.family == "ssm":
            states = []
            for i in range(L):
                if i in cfg.xlstm.slstm_indices:
                    states.append(xlstm_mod.init_slstm_state(batch, cfg))
                else:
                    states.append(xlstm_mod.init_mlstm_state(batch, cfg,
                                                             dtype=dtype))
            return {"states": states}
        raise ValueError(cfg.family)

    def prime_cross_cache(self, params: Params, cache, frames):
        """Whisper: run the encoder once, fill per-layer cross K/V."""
        enc = self._encode(params, frames)

        def fill(blk):
            k, v = attn.cross_kv(blk["cross"], enc)
            return k, v

        ks, vs = jax.vmap(fill)(params["blocks"])  # vmap over layer axis
        dt = cache["cross"].k.dtype
        return dict(cache, cross=attn.KVCache(ks.astype(dt), vs.astype(dt)))

    def decode_step(self, params: Params, cache, tokens: jax.Array,
                    pos: jax.Array, *, window: Optional[int] = None
                    ) -> Tuple[jax.Array, Any]:
        """tokens: (B,1) int32; pos: () int32. Returns (logits (B,1,V), cache)."""
        cfg = self.cfg
        window = window if window is not None else cfg.sliding_window
        x = jnp.take(params["embed"], tokens, axis=0)
        ring = window is not None

        if cfg.family in ("dense", "moe", "vlm"):
            def body(h, xs):
                blk, kc, vc = xs
                hin = rms_norm(h, blk["ln1"], cfg.norm_eps)
                a, new_kv = attn.attention_decode(
                    blk["attn"], hin, attn.KVCache(kc, vc), pos,
                    rope_theta=cfg.rope_theta, ring=ring, window=window)
                h = h + a
                hin = rms_norm(h, blk["ln2"], cfg.norm_eps)
                if cfg.moe is not None:
                    f, _ = moe_mod.moe_forward(blk["ffn"], hin, cfg.moe,
                                               mode=self.moe_mode,
                                               group_tokens=tokens.shape[0])
                else:
                    f = mlp_mod.mlp_forward(blk["ffn"], hin)
                return h + f, new_kv

            x, new_kv = jax.lax.scan(body, x, (params["blocks"],
                                               cache["kv"].k, cache["kv"].v))
            new_cache = {"kv": attn.KVCache(new_kv.k, new_kv.v)}
        elif cfg.family == "audio":
            def body(h, xs):
                blk, kc, vc, xk, xv = xs
                a, new_kv = attn.attention_decode(
                    blk["self"], rms_norm(h, blk["ln1"], cfg.norm_eps),
                    attn.KVCache(kc, vc), pos, rope_theta=cfg.rope_theta,
                    ring=ring, window=window)
                h = h + a
                q = rms_norm(h, blk["ln2"], cfg.norm_eps)
                c = attn.cross_attention(blk["cross"], q, xk, xv)
                h = h + c
                h = h + mlp_mod.mlp_forward(blk["mlp"],
                                            rms_norm(h, blk["ln3"], cfg.norm_eps))
                return h, new_kv

            x, new_kv = jax.lax.scan(body, x, (params["blocks"],
                                               cache["kv"].k, cache["kv"].v,
                                               cache["cross"].k, cache["cross"].v))
            new_cache = dict(cache, kv=attn.KVCache(new_kv.k, new_kv.v))
        elif cfg.family == "hybrid":
            new_m, new_s = [], list(cache["shared"])
            blocks = params["blocks"]
            for i in range(cfg.n_layers):
                blk = jax.tree_util.tree_map(lambda a: a[i], blocks)
                o, st = ssm_mod.mamba2_decode(
                    blk["mamba"], rms_norm(x, blk["ln"], cfg.norm_eps),
                    cache["mamba"][i], cfg.ssm)
                x = x + o
                new_m.append(st)
                if (i + 1) % cfg.attn_every == 0:
                    j = (i + 1) // cfg.attn_every - 1
                    a, kvn = attn.attention_decode(
                        params["shared_attn"],
                        rms_norm(x, params["shared_ln"], cfg.norm_eps),
                        new_s[j], pos, rope_theta=cfg.rope_theta,
                        ring=ring, window=window)
                    x = x + a
                    new_s[j] = kvn
            new_cache = {"mamba": new_m, "shared": new_s}
        elif cfg.family == "ssm":
            new_states = []
            for i, blk in enumerate(params["blocks"]):
                if "slstm" in blk:
                    o, st = xlstm_mod.slstm_decode(blk["slstm"], x,
                                                   cache["states"][i], cfg)
                else:
                    o, st = xlstm_mod.mlstm_decode(blk["mlstm"], x,
                                                   cache["states"][i], cfg)
                x = x + o
                new_states.append(st)
            new_cache = {"states": new_states}
        else:
            raise ValueError(cfg.family)

        x = rms_norm(x, params["ln_f"], cfg.norm_eps)
        logits = jnp.einsum("bsd,dv->bsv", x, self._unembed(params))
        return logits, new_cache


def build_model(cfg: ModelConfig, **kw) -> LM:
    return LM(cfg, **kw)
