"""xLSTM blocks [arXiv:2405.04517]: mLSTM (matrix memory, parallelizable)
and sLSTM (scalar memory, strictly sequential scan).

mLSTM maps onto the generalized SSD scan in `repro.models.ssm`:
    state C_t = f_t C_{t-1} + i_t k_t v_t^T   ->   ld = log f, g = i, k/q per head
with a normalizer obtained by augmenting v with a ones-channel, and
`y = num / max(|den|, 1)`.

TPU adaptation (recorded in DESIGN.md): gates are *bounded* —
f = sigmoid(f_raw), i = sigmoid(i_raw) — instead of the paper's exp input
gate + running-max stabilizer. The normalizer makes the block equivalent up
to the stabilizer; bounded gates keep the chunked scan overflow-free in bf16
without carrying a per-head running max through the chunk scan.
"""
from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, XLSTMConfig
from repro.models.layers import dense_init, rms_norm
from repro.models.ssm import causal_conv, causal_conv_step, ssd_chunked, ssd_step


# ---------------------------------------------------------------------------
# mLSTM block
# ---------------------------------------------------------------------------
class MLSTMParams(NamedTuple):
    w_up: jax.Array      # (d, dm)
    w_z: jax.Array       # (d, dm)
    conv: jax.Array      # (K, dm)
    w_q: jax.Array       # (dm, H, N)
    w_k: jax.Array       # (dm, H, N)
    w_v: jax.Array       # (dm, H, N)   (P == N == dm // H)
    w_i: jax.Array       # (dm, H)
    w_f: jax.Array       # (dm, H)
    b_f: jax.Array       # (H,) fp32 — init positive: remember by default
    norm: jax.Array      # (dm,)
    w_down: jax.Array    # (dm, d)


class MLSTMState(NamedTuple):
    h: jax.Array         # (B, H, N, P+1) fp32 — last channel = normalizer
    conv: jax.Array      # (B, K-1, dm)


def mlstm_dims(cfg: ModelConfig):
    x = cfg.xlstm or XLSTMConfig()
    dm = int(cfg.d_model * x.mlstm_proj_factor)
    H = cfg.n_heads
    N = dm // H
    return dm, H, N


def init_mlstm(key, cfg: ModelConfig, dtype) -> MLSTMParams:
    x = cfg.xlstm or XLSTMConfig()
    dm, H, N = mlstm_dims(cfg)
    ks = jax.random.split(key, 8)
    return MLSTMParams(
        w_up=dense_init(ks[0], (cfg.d_model, dm), dtype),
        w_z=dense_init(ks[1], (cfg.d_model, dm), dtype),
        conv=dense_init(ks[2], (x.conv_kernel, dm), dtype, scale=0.5),
        w_q=dense_init(ks[3], (dm, H, N), dtype),
        w_k=dense_init(ks[4], (dm, H, N), dtype),
        w_v=dense_init(ks[5], (dm, H, N), dtype),
        w_i=dense_init(ks[6], (dm, H), dtype),
        w_f=dense_init(ks[7], (dm, H), dtype),
        b_f=3.0 * jnp.ones((H,), jnp.float32),
        norm=jnp.ones((dm,), dtype),
        w_down=dense_init(jax.random.fold_in(key, 99), (dm, cfg.d_model), dtype),
    )


def _mlstm_qkvif(p: MLSTMParams, u: jax.Array, uc: jax.Array):
    q = jnp.einsum("bse,ehn->bshn", uc, p.w_q)
    k = jnp.einsum("bse,ehn->bshn", uc, p.w_k)
    v = jnp.einsum("bse,ehn->bshn", u, p.w_v)
    i_raw = jnp.einsum("bse,eh->bsh", uc, p.w_i).astype(jnp.float32)
    f_raw = jnp.einsum("bse,eh->bsh", uc, p.w_f).astype(jnp.float32) + p.b_f
    i_g = jax.nn.sigmoid(i_raw)
    log_f = -jax.nn.softplus(-f_raw)              # log sigmoid(f_raw)
    return q, k, v, i_g, log_f


def mlstm_forward(p: MLSTMParams, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    B, S, d = x.shape
    dm, H, N = mlstm_dims(cfg)
    u = jnp.einsum("bsd,de->bse", x, p.w_up)
    z = jnp.einsum("bsd,de->bse", x, p.w_z)
    uc = jax.nn.silu(causal_conv(u, p.conv).astype(jnp.float32)).astype(x.dtype)
    q, k, v, i_g, log_f = _mlstm_qkvif(p, u, uc)
    ones = jnp.ones(v.shape[:-1] + (1,), v.dtype)
    v_aug = jnp.concatenate([v, ones], axis=-1)           # (B,S,H,N+1)
    chunk = min(256, max(S, 8))
    y_aug, _ = ssd_chunked(v_aug, log_f, k, q, i_g, chunk=chunk)
    num, den = y_aug[..., :N].astype(jnp.float32), y_aug[..., N].astype(jnp.float32)
    y = num / jnp.maximum(jnp.abs(den), 1.0)[..., None]
    y = y.reshape(B, S, dm).astype(x.dtype)
    y = rms_norm(y, p.norm) * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype)
    return jnp.einsum("bse,ed->bsd", y, p.w_down)


def init_mlstm_state(batch: int, cfg: ModelConfig,
                     dtype=jnp.bfloat16) -> MLSTMState:
    x = cfg.xlstm or XLSTMConfig()
    dm, H, N = mlstm_dims(cfg)
    return MLSTMState(
        h=jnp.zeros((batch, H, N, N + 1), jnp.float32),
        conv=jnp.zeros((batch, x.conv_kernel - 1, dm), dtype))


def mlstm_decode(p: MLSTMParams, x: jax.Array, state: MLSTMState,
                 cfg: ModelConfig):
    B, _, d = x.shape
    dm, H, N = mlstm_dims(cfg)
    u = jnp.einsum("bsd,de->bse", x, p.w_up)
    z = jnp.einsum("bsd,de->bse", x, p.w_z)
    c_out, new_conv = causal_conv_step(state.conv.astype(u.dtype), u[:, 0], p.conv)
    uc = jax.nn.silu(c_out.astype(jnp.float32)).astype(x.dtype)[:, None]
    q, k, v, i_g, log_f = _mlstm_qkvif(p, u, uc)
    ones = jnp.ones(v.shape[:-1] + (1,), v.dtype)
    v_aug = jnp.concatenate([v, ones], axis=-1)
    y_aug, h_new = ssd_step(state.h, v_aug[:, 0], log_f[:, 0], k[:, 0],
                            q[:, 0], i_g[:, 0])
    num = y_aug[..., :N].astype(jnp.float32)
    den = y_aug[..., N].astype(jnp.float32)
    y = (num / jnp.maximum(jnp.abs(den), 1.0)[..., None]).reshape(B, 1, dm)
    y = rms_norm(y.astype(x.dtype), p.norm) * jax.nn.silu(
        z.astype(jnp.float32)).astype(x.dtype)
    out = jnp.einsum("bse,ed->bsd", y, p.w_down)
    return out, MLSTMState(h_new, new_conv.astype(state.conv.dtype))


# ---------------------------------------------------------------------------
# sLSTM block — strictly sequential exponential-gated scalar memory
# ---------------------------------------------------------------------------
class SLSTMParams(NamedTuple):
    w_in: jax.Array      # (d, H, hd, 4)  input weights for i,f,z,o
    r: jax.Array         # (H, hd, hd, 4) per-head recurrent weights
    b: jax.Array         # (H, hd, 4) fp32
    norm: jax.Array      # (d,)
    w_up: jax.Array      # (d, 2*fs)
    w_down: jax.Array    # (fs, d)


class SLSTMState(NamedTuple):
    c: jax.Array         # (B, H, hd) fp32
    n: jax.Array
    hst: jax.Array
    m: jax.Array


def slstm_dims(cfg: ModelConfig):
    x = cfg.xlstm or XLSTMConfig()
    H = cfg.n_heads
    hd = cfg.d_model // H
    fs = int(cfg.d_model * x.slstm_proj_factor)
    return H, hd, fs


def init_slstm(key, cfg: ModelConfig, dtype) -> SLSTMParams:
    H, hd, fs = slstm_dims(cfg)
    ks = jax.random.split(key, 4)
    b = jnp.zeros((H, hd, 4), jnp.float32).at[..., 1].set(3.0)  # f-bias > 0
    return SLSTMParams(
        w_in=dense_init(ks[0], (cfg.d_model, H, hd, 4), dtype),
        r=dense_init(ks[1], (H, hd, hd, 4), dtype, scale=0.3),
        b=b,
        norm=jnp.ones((cfg.d_model,), dtype),
        w_up=dense_init(ks[2], (cfg.d_model, 2 * fs), dtype),
        w_down=dense_init(ks[3], (fs, cfg.d_model), dtype),
    )


def _slstm_cell(p: SLSTMParams, zin: jax.Array, st: SLSTMState) -> Tuple[SLSTMState, jax.Array]:
    """zin: (B,H,hd,4) pre-activations from input; recurrent added here."""
    rec = jnp.einsum("bhd,hdkg->bhkg", st.hst.astype(jnp.float32),
                     p.r.astype(jnp.float32))
    pre = zin.astype(jnp.float32) + rec + p.b
    i_raw, f_raw, z_raw, o_raw = [pre[..., j] for j in range(4)]
    log_f = -jax.nn.softplus(-f_raw)             # log sigmoid — stabilized f
    m_new = jnp.maximum(log_f + st.m, i_raw)
    i_t = jnp.exp(i_raw - m_new)
    f_t = jnp.exp(log_f + st.m - m_new)
    z_t = jnp.tanh(z_raw)
    o_t = jax.nn.sigmoid(o_raw)
    c_new = f_t * st.c + i_t * z_t
    n_new = f_t * st.n + i_t
    h_new = o_t * c_new / jnp.maximum(n_new, 1e-6)
    return SLSTMState(c_new, n_new, h_new, m_new), h_new


def slstm_forward(p: SLSTMParams, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    B, S, d = x.shape
    H, hd, fs = slstm_dims(cfg)
    zin = jnp.einsum("bsd,dhkg->bshkg", x, p.w_in)

    def step(st, z_t):
        st2, h = _slstm_cell(p, z_t, st)
        return st2, h

    st0 = init_slstm_state(B, cfg)
    _, hs = jax.lax.scan(step, st0, zin.swapaxes(0, 1))
    y = hs.swapaxes(0, 1).reshape(B, S, d).astype(x.dtype)
    y = rms_norm(y, p.norm)
    up = jnp.einsum("bsd,df->bsf", y, p.w_up)
    a, g = jnp.split(up, 2, axis=-1)
    return jnp.einsum("bsf,fd->bsd", jax.nn.gelu(a.astype(jnp.float32)
                                                 ).astype(x.dtype) * g, p.w_down)


def init_slstm_state(batch: int, cfg: ModelConfig) -> SLSTMState:
    H, hd, _ = slstm_dims(cfg)
    z = jnp.zeros((batch, H, hd), jnp.float32)
    return SLSTMState(z, z, z, jnp.full((batch, H, hd), -1e30, jnp.float32))


def slstm_decode(p: SLSTMParams, x: jax.Array, st: SLSTMState, cfg: ModelConfig):
    B, _, d = x.shape
    H, hd, fs = slstm_dims(cfg)
    zin = jnp.einsum("bsd,dhkg->bshkg", x, p.w_in)[:, 0]
    st2, h = _slstm_cell(p, zin, st)
    y = h.reshape(B, 1, d).astype(x.dtype)
    y = rms_norm(y, p.norm)
    up = jnp.einsum("bsd,df->bsf", y, p.w_up)
    a, g = jnp.split(up, 2, axis=-1)
    out = jnp.einsum("bsf,fd->bsd", jax.nn.gelu(a.astype(jnp.float32)
                                                ).astype(x.dtype) * g, p.w_down)
    return out, st2
