from repro.models.model import LM, Batch, Params, build_model, chunked_lm_loss

__all__ = ["LM", "Batch", "Params", "build_model", "chunked_lm_loss"]
