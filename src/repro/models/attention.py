"""GQA/MQA attention: blockwise-online-softmax training path, cached decode.

Layout conventions:
  activations  x        : (B, S, d)
  queries      q        : (B, S, H, hd)
  keys/values  k, v     : (B, S, Kv, hd)
  weights      wq       : (d, H, hd)     wk/wv: (d, Kv, hd)    wo: (H, hd, d)
KV caches:
  full  : (B, S_max, Kv, hd), write at `pos`
  ring  : (B, W, Kv, hd), write at `pos % W`  (sliding-window layers)
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.models.layers import apply_rope, dense_init, rope_freqs

NEG_INF = -1e30


class AttnParams(NamedTuple):
    wq: jax.Array
    wk: jax.Array
    wv: jax.Array
    wo: jax.Array
    bq: Optional[jax.Array] = None
    bk: Optional[jax.Array] = None
    bv: Optional[jax.Array] = None


def init_attention(key, d_model: int, n_heads: int, n_kv: int, head_dim: int,
                   qkv_bias: bool, dtype) -> AttnParams:
    ks = jax.random.split(key, 4)
    wq = dense_init(ks[0], (d_model, n_heads, head_dim), dtype)
    wk = dense_init(ks[1], (d_model, n_kv, head_dim), dtype)
    wv = dense_init(ks[2], (d_model, n_kv, head_dim), dtype)
    wo = dense_init(ks[3], (n_heads, head_dim, d_model), dtype)
    if qkv_bias:
        z = jnp.zeros
        return AttnParams(wq, wk, wv, wo, z((n_heads, head_dim), dtype),
                          z((n_kv, head_dim), dtype), z((n_kv, head_dim), dtype))
    return AttnParams(wq, wk, wv, wo)


def qkv_proj(p: AttnParams, x: jax.Array):
    q = jnp.einsum("bsd,dhk->bshk", x, p.wq)
    k = jnp.einsum("bsd,dhk->bshk", x, p.wk)
    v = jnp.einsum("bsd,dhk->bshk", x, p.wv)
    if p.bq is not None:
        q, k, v = q + p.bq, k + p.bk, v + p.bv
    return q, k, v


def out_proj(p: AttnParams, o: jax.Array) -> jax.Array:
    return jnp.einsum("bshk,hkd->bsd", o, p.wo)


# ---------------------------------------------------------------------------
# training / prefill attention: scan over KV chunks with online softmax.
# Memory per step is O(S * kv_chunk) instead of O(S^2).
# ---------------------------------------------------------------------------
def blockwise_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                        q_positions: jax.Array, kv_positions: jax.Array,
                        causal: bool = True, window: Optional[int] = None,
                        kv_chunk: int = 1024) -> jax.Array:
    """q: (B,Sq,H,hd); k,v: (B,Skv,Kv,hd). Returns (B,Sq,H,hd).

    Matmuls keep bf16 operands with fp32 accumulation
    (preferred_element_type) — §Perf iteration 1: casting operands to fp32
    before the einsum doubled HBM traffic for zero MXU benefit.
    """
    B, Sq, H, hd = q.shape
    Skv, Kv = k.shape[1], k.shape[2]
    G = H // Kv
    kv_chunk = min(kv_chunk, Skv)
    n_chunks = -(-Skv // kv_chunk)
    pad = n_chunks * kv_chunk - Skv
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        kv_positions = jnp.pad(kv_positions, (0, pad), constant_values=-10 ** 9)

    qg = q.reshape(B, Sq, Kv, G, hd)
    scale = hd ** -0.5
    kc = k.reshape(B, n_chunks, kv_chunk, Kv, hd).swapaxes(0, 1)
    vc = v.reshape(B, n_chunks, kv_chunk, Kv, hd).swapaxes(0, 1)
    pc = kv_positions.reshape(n_chunks, kv_chunk)

    def step(carry, chunk):
        m, denom, acc = carry
        kj, vj, pj = chunk
        s = jnp.einsum("bqkgh,bckh->bqkgc", qg, kj,
                       preferred_element_type=jnp.float32) * scale
        dp = q_positions[None, :, None, None, None] - pj[None, None, None, None, :]
        if causal:
            mask = dp >= 0
        else:
            mask = pj[None, None, None, None, :] >= 0
        if window is not None:
            mask = mask & (dp < window)
        s = jnp.where(mask, s, NEG_INF)
        mj = jnp.max(s, axis=-1)
        m_new = jnp.maximum(m, mj)
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        denom_new = denom * corr + jnp.sum(p, axis=-1)
        o = jnp.einsum("bqkgc,bckh->bqkgh", p.astype(q.dtype), vj,
                       preferred_element_type=jnp.float32)
        acc_new = acc * corr[..., None] + o
        return (m_new, denom_new, acc_new), None

    m0 = jnp.full((B, Sq, Kv, G), NEG_INF, jnp.float32)
    denom0 = jnp.zeros((B, Sq, Kv, G), jnp.float32)
    a0 = jnp.zeros((B, Sq, Kv, G, hd), jnp.float32)
    (m, denom, acc), _ = jax.lax.scan(step, (m0, denom0, a0), (kc, vc, pc))
    out = acc / jnp.maximum(denom[..., None], 1e-30)
    return out.reshape(B, Sq, H, hd).astype(q.dtype)


def plain_attention(q, k, v, mask=None) -> jax.Array:
    """Small-S reference path (encoder / cross-attn / decode). GQA-aware."""
    B, Sq, H, hd = q.shape
    Kv = k.shape[2]
    G = H // Kv
    qg = q.reshape(B, Sq, Kv, G, hd).astype(jnp.float32)
    s = jnp.einsum("bqkgh,bckh->bqkgc", qg, k.astype(jnp.float32)) * hd ** -0.5
    if mask is not None:
        s = jnp.where(mask, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bqkgc,bckh->bqkgh", p, v.astype(jnp.float32))
    return o.reshape(B, Sq, H, hd).astype(q.dtype)


# ---------------------------------------------------------------------------
# full forward (train / prefill)
# ---------------------------------------------------------------------------
def attention_forward(p: AttnParams, x: jax.Array, *, positions: jax.Array,
                      rope_theta: float, causal: bool = True,
                      window: Optional[int] = None,
                      kv_chunk: int = 1024,
                      backend: str = "jnp") -> jax.Array:
    """backend: 'jnp' (blockwise online softmax — pjit/dry-run path) or
    'pallas' (the flash kernel; interpret mode on CPU, native on TPU).
    The kernel keeps score tiles in VMEM — see EXPERIMENTS.md §Perf for the
    traffic it removes."""
    q, k, v = qkv_proj(p, x)
    cos, sin = rope_freqs(positions, q.shape[-1], rope_theta)
    q = apply_rope(q, cos[None], sin[None])
    k = apply_rope(k, cos[None], sin[None])
    if backend == "pallas":
        from repro.kernels.flash_attention.ops import flash_attention
        o = flash_attention(q, k, v, causal=causal, window=window,
                            bq=min(256, q.shape[1]), bk=min(256, k.shape[1]),
                            interpret=jax.default_backend() == "cpu")
    else:
        o = blockwise_attention(q, k, v, q_positions=positions,
                                kv_positions=positions, causal=causal,
                                window=window, kv_chunk=kv_chunk)
    return out_proj(p, o)


def encoder_attention(p: AttnParams, x: jax.Array) -> jax.Array:
    """Bidirectional, no RoPE (whisper encoder uses learned abs pos)."""
    q, k, v = qkv_proj(p, x)
    return out_proj(p, plain_attention(q, k, v))


def cross_attention(p: AttnParams, x: jax.Array, enc_k: jax.Array,
                    enc_v: jax.Array) -> jax.Array:
    q = jnp.einsum("bsd,dhk->bshk", x, p.wq)
    if p.bq is not None:
        q = q + p.bq
    return out_proj(p, plain_attention(q, enc_k, enc_v))


def cross_kv(p: AttnParams, enc_out: jax.Array):
    k = jnp.einsum("bsd,dhk->bshk", enc_out, p.wk)
    v = jnp.einsum("bsd,dhk->bshk", enc_out, p.wv)
    if p.bk is not None:
        k, v = k + p.bk, v + p.bv
    return k, v


# ---------------------------------------------------------------------------
# decode (one token) against a cache
# ---------------------------------------------------------------------------
class KVCache(NamedTuple):
    k: jax.Array          # (B, C, Kv, hd) — C = S_max (full) or W (ring)
    v: jax.Array


def init_kv_cache(batch: int, capacity: int, n_kv: int, head_dim: int,
                  dtype) -> KVCache:
    shape = (batch, capacity, n_kv, head_dim)
    return KVCache(jnp.zeros(shape, dtype), jnp.zeros(shape, dtype))


def attention_decode(p: AttnParams, x: jax.Array, cache: KVCache,
                     pos: jax.Array, *, rope_theta: float, ring: bool,
                     window: Optional[int] = None):
    """One-token decode. x: (B, 1, d); pos: scalar int32 current position.

    ``ring`` is STATIC: True for sliding-window layers whose cache capacity
    is the window size (slot = pos % C); False for full caches (slot = pos).
    Returns (out, new_cache).
    """
    q, k, v = qkv_proj(p, x)                       # (B,1,H/Kv,hd)
    cos, sin = rope_freqs(pos[None], q.shape[-1], rope_theta)
    q = apply_rope(q, cos[None], sin[None])
    k = apply_rope(k, cos[None], sin[None])

    C = cache.k.shape[1]
    slot = pos % C if ring else jnp.minimum(pos, C - 1)
    new_k = jax.lax.dynamic_update_slice_in_dim(
        cache.k, k.astype(cache.k.dtype), slot, axis=1)
    new_v = jax.lax.dynamic_update_slice_in_dim(
        cache.v, v.astype(cache.v.dtype), slot, axis=1)

    idx = jnp.arange(C)
    if ring:
        # entry at slot i holds position: the largest p <= pos with p % C == i
        age = (slot - idx) % C                      # 0..C-1, 0 == current token
        kv_pos = pos - age
        valid = kv_pos >= 0
        if window is not None:
            valid &= (pos - kv_pos) < window
    else:
        valid = idx <= pos
        if window is not None:
            valid &= (pos - idx) < window
    mask = valid[None, None, None, None, :]         # (1,1,1,1,C)
    o = plain_attention(q, new_k, new_v, mask=mask)
    return out_proj(p, o), KVCache(new_k, new_v)
