"""Shared primitives: norms, rotary embeddings, initializers."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-5) -> jax.Array:
    """RMSNorm in fp32, cast back to input dtype."""
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps) * scale.astype(jnp.float32)
    return out.astype(x.dtype)


def layer_norm(x: jax.Array, scale: jax.Array, bias: jax.Array,
               eps: float = 1e-5) -> jax.Array:
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(xf - mu), axis=-1, keepdims=True)
    out = (xf - mu) * jax.lax.rsqrt(var + eps)
    out = out * scale.astype(jnp.float32) + bias.astype(jnp.float32)
    return out.astype(x.dtype)


def rope_freqs(positions: jax.Array, head_dim: int, theta: float) -> tuple:
    """Rotary angles for given positions. positions: (...,) int32.

    Returns (cos, sin) with shape (..., head_dim // 2), fp32 — safe at 500k+
    positions.
    """
    half = head_dim // 2
    inv = 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))
    ang = positions.astype(jnp.float32)[..., None] * inv
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: jax.Array, cos: jax.Array, sin: jax.Array) -> jax.Array:
    """x: (..., S, H, hd); cos/sin: (..., S, hd//2) broadcast over heads."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    c = cos[..., None, :].astype(jnp.float32)
    s = sin[..., None, :].astype(jnp.float32)
    x1f, x2f = x1.astype(jnp.float32), x2.astype(jnp.float32)
    out = jnp.concatenate([x1f * c - x2f * s, x1f * s + x2f * c], axis=-1)
    return out.astype(x.dtype)


def dense_init(key, shape, dtype, scale: float = None):
    """Truncated-normal fan-in init."""
    fan_in = 1
    for d in (shape[:-1] if len(shape) > 1 else shape):
        fan_in *= int(d)
    if scale is None:
        scale = 1.0 / max(fan_in, 1) ** 0.5
    return (scale * jax.random.truncated_normal(key, -2.0, 2.0, shape,
                                                jnp.float32)).astype(dtype)


def embed_init(key, shape, dtype):
    return (0.02 * jax.random.truncated_normal(key, -2.0, 2.0, shape,
                                               jnp.float32)).astype(dtype)


def split_keys(key, n):
    return list(jax.random.split(key, n))
