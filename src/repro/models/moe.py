"""Mixture-of-Experts with GSPMD-style grouped capacity dispatch.

Two dispatch modes:
  'onehot'  — grouped one-hot capacity einsum (GSPMD / t5x style). Robust
              under pjit sharding propagation; dispatch tensor memory is
              O(group * n_experts * capacity), tuned via `group_tokens`.
              This is the dry-run / production baseline.
  'ragged'  — sort-based grouped matmul via jax.lax.ragged_dot. Lower
              memory, no capacity drop; used single-device (tests, CPU
              examples) and as the beyond-paper §Perf candidate.

Router load-balance auxiliary loss (Switch-style) is returned so the
trainer can add `load_balance_coef * aux`.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import MoEConfig
from repro.models.layers import dense_init


class MoEParams(NamedTuple):
    router: jax.Array     # (d, E)
    w_gate: jax.Array     # (E, d, f)
    w_up: jax.Array       # (E, d, f)
    w_down: jax.Array     # (E, f, d)


def init_moe(key, d_model: int, m: MoEConfig, dtype) -> MoEParams:
    ks = jax.random.split(key, 4)
    E, f = m.n_experts, m.d_expert
    return MoEParams(
        dense_init(ks[0], (d_model, E), jnp.float32),  # router in fp32
        dense_init(ks[1], (E, d_model, f), dtype),
        dense_init(ks[2], (E, d_model, f), dtype),
        dense_init(ks[3], (E, f, d_model), dtype),
    )


def _router(p: MoEParams, x: jax.Array, m: MoEConfig):
    """x: (T, d) -> top-k weights (T, k) fp32, indices (T, k), aux loss."""
    logits = jnp.einsum("td,de->te", x.astype(jnp.float32), p.router)
    probs = jax.nn.softmax(logits, axis=-1)
    w, idx = jax.lax.top_k(probs, m.top_k)
    w = w / jnp.maximum(jnp.sum(w, axis=-1, keepdims=True), 1e-9)
    # Switch-style load balance: E * sum_e fraction_e * mean_prob_e
    E = m.n_experts
    onehot = jax.nn.one_hot(idx[:, 0], E, dtype=jnp.float32)
    frac = jnp.mean(onehot, axis=0)
    aux = E * jnp.sum(frac * jnp.mean(probs, axis=0))
    return w, idx, aux


def _expert_ffn(p: MoEParams, xe: jax.Array) -> jax.Array:
    """xe: (G, E, C, d) -> (G, E, C, d); SwiGLU per expert."""
    gate = jnp.einsum("gecd,edf->gecf", xe, p.w_gate)
    up = jnp.einsum("gecd,edf->gecf", xe, p.w_up)
    return jnp.einsum("gecf,efd->gecd", jax.nn.silu(gate) * up, p.w_down)


def moe_forward_onehot(p: MoEParams, x: jax.Array, m: MoEConfig, *,
                       group_tokens: int = 512,
                       capacity_factor: float = 1.25):
    """x: (B, S, d). Grouped capacity dispatch. Returns (y, aux)."""
    B, S, d = x.shape
    T = B * S
    t = min(group_tokens, T)
    assert T % t == 0, (T, t)
    G = T // t
    E, k = m.n_experts, m.top_k
    cap = max(int(t * k / E * capacity_factor), 1)

    xf = x.reshape(G, t, d)
    w, idx, aux = _router(p, xf.reshape(T, d), m)
    w = w.reshape(G, t, k)
    idx = idx.reshape(G, t, k)

    # slot order: token-major within group, k-minor; flatten (t, k) -> s
    s = t * k
    e_flat = idx.reshape(G, s)
    w_flat = w.reshape(G, s)
    onehot_e = jax.nn.one_hot(e_flat, E, dtype=jnp.bfloat16)       # (G,s,E)
    pos = jnp.cumsum(onehot_e.astype(jnp.float32), axis=1) - 1.0    # (G,s,E)
    pos = jnp.sum(pos * onehot_e.astype(jnp.float32), axis=-1)      # (G,s)
    keep = pos < cap
    w_flat = w_flat * keep.astype(w_flat.dtype)
    onehot_c = jax.nn.one_hot(pos.astype(jnp.int32), cap,
                              dtype=jnp.bfloat16)                   # (G,s,cap)

    x_rep = jnp.repeat(xf, k, axis=1)                               # (G,s,d)
    dispatch = onehot_e[..., :, None] * onehot_c[..., None, :]      # (G,s,E,cap)
    dispatch = dispatch * keep[..., None, None].astype(dispatch.dtype)
    xe = jnp.einsum("gsec,gsd->gecd", dispatch,
                    x_rep.astype(jnp.bfloat16))                     # (G,E,cap,d)
    ye = _expert_ffn(p, xe)                                         # (G,E,cap,d)
    combine = dispatch * w_flat[..., None, None].astype(dispatch.dtype)
    y = jnp.einsum("gsec,gecd->gsd", combine, ye)                   # (G,s,d)
    y = y.reshape(G, t, k, d).sum(axis=2)
    return y.reshape(B, S, d).astype(x.dtype), aux


def moe_forward_ragged(p: MoEParams, x: jax.Array, m: MoEConfig):
    """Sort-based grouped matmul (no capacity drops). x: (B, S, d)."""
    B, S, d = x.shape
    T = B * S
    E, k = m.n_experts, m.top_k
    xf = x.reshape(T, d)
    w, idx, aux = _router(p, xf, m)

    e_flat = idx.reshape(T * k)
    tok = jnp.repeat(jnp.arange(T), k)
    order = jnp.argsort(e_flat, stable=True)
    xs = xf[tok[order]]                                      # (T*k, d)
    group_sizes = jnp.bincount(e_flat, length=E)

    gate = jax.lax.ragged_dot(xs, p.w_gate, group_sizes)
    up = jax.lax.ragged_dot(xs, p.w_up, group_sizes)
    ys = jax.lax.ragged_dot((jax.nn.silu(gate) * up).astype(xs.dtype),
                            p.w_down, group_sizes)           # (T*k, d)

    wk = w.reshape(T * k)[order].astype(jnp.float32)
    y = jnp.zeros((T, d), jnp.float32).at[tok[order]].add(ys.astype(jnp.float32) * wk[:, None])
    return y.reshape(B, S, d).astype(x.dtype), aux


def moe_forward(p: MoEParams, x: jax.Array, m: MoEConfig, *,
                mode: str = "onehot", group_tokens: int = 512,
                capacity_factor: float = 1.25):
    if mode == "ragged":
        return moe_forward_ragged(p, x, m)
    return moe_forward_onehot(p, x, m, group_tokens=group_tokens,
                              capacity_factor=capacity_factor)
