"""Feed-forward blocks: SwiGLU (llama family) and GELU (whisper)."""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.models.layers import dense_init


class MLPParams(NamedTuple):
    w_gate: Optional[jax.Array]   # (d, f) — None for plain GELU MLP
    w_up: jax.Array               # (d, f)
    w_down: jax.Array             # (f, d)


def init_swiglu(key, d_model: int, d_ff: int, dtype) -> MLPParams:
    k1, k2, k3 = jax.random.split(key, 3)
    return MLPParams(dense_init(k1, (d_model, d_ff), dtype),
                     dense_init(k2, (d_model, d_ff), dtype),
                     dense_init(k3, (d_ff, d_model), dtype))


def init_gelu(key, d_model: int, d_ff: int, dtype) -> MLPParams:
    k1, k2 = jax.random.split(key, 2)
    return MLPParams(None, dense_init(k1, (d_model, d_ff), dtype),
                     dense_init(k2, (d_ff, d_model), dtype))


def mlp_forward(p: MLPParams, x: jax.Array) -> jax.Array:
    up = jnp.einsum("bsd,df->bsf", x, p.w_up)
    if p.w_gate is not None:
        gate = jnp.einsum("bsd,df->bsf", x, p.w_gate)
        h = jax.nn.silu(gate) * up
    else:
        h = jax.nn.gelu(up)
    return jnp.einsum("bsf,fd->bsd", h, p.w_down)
