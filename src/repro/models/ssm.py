"""State-space sequence mixing: generalized chunked SSD scan + Mamba2 block.

The generalized scan computes, per head h:
    S_t = exp(ld_t) * S_{t-1} + k_t (g_t v_t)^T        (state: N x P)
    y_t = q_t^T S_t
which covers:
  * Mamba2 (SSD): k = B_ssm, q = C_ssm (shared across heads, broadcast),
    g = dt, ld = dt * A  [arXiv:2405.21060 form]
  * mLSTM:        k/q per head, g = input gate, ld = log f-gate
Chunked evaluation: intra-chunk quadratic + inter-chunk state carry,
O(S/Q) sequential steps. The Pallas `ssm_scan` kernel implements the same
contraction; `repro/kernels/ssm_scan/ref.py` delegates here.
"""
from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import SSMConfig
from repro.models.layers import dense_init, rms_norm


def ssd_chunked(v: jax.Array, ld: jax.Array, k: jax.Array, q: jax.Array,
                g: jax.Array, *, chunk: int,
                h0: Optional[jax.Array] = None) -> Tuple[jax.Array, jax.Array]:
    """v: (B,S,H,P); ld,g: (B,S,H); k,q: (B,S,H,N).

    Returns (y: (B,S,H,P) fp32-accumulated in input dtype, h_final: (B,H,N,P)).
    """
    B, S, H, P = v.shape
    N = k.shape[-1]
    Q = min(chunk, S)
    pad = (-S) % Q
    if pad:
        def zpad(a):
            return jnp.pad(a, [(0, 0), (0, pad)] + [(0, 0)] * (a.ndim - 2))
        v, k, q = zpad(v), zpad(k), zpad(q)
        g = jnp.pad(g, ((0, 0), (0, pad), (0, 0)))
        ld = jnp.pad(ld, ((0, 0), (0, pad), (0, 0)))
    Sp = S + pad
    nc = Sp // Q

    def chunked(a):
        return a.reshape((B, nc, Q) + a.shape[2:]).swapaxes(0, 1)

    vf = chunked(v.astype(jnp.float32) * g.astype(jnp.float32)[..., None])
    kc = chunked(k.astype(jnp.float32))
    qc = chunked(q.astype(jnp.float32))
    ldc = chunked(ld.astype(jnp.float32))
    cum = jnp.cumsum(ldc, axis=2)                       # (nc,B,Q,H) inclusive
    tot = cum[:, :, -1, :]                              # (nc,B,H)

    tri = jnp.tril(jnp.ones((Q, Q), jnp.float32))

    def step(h, c):
        vj, kj, qj, cumj, totj = c
        qk = jnp.einsum("bthn,bshn->btsh", qj, kj)
        # mask BEFORE exp: above-diagonal cum differences are positive and
        # overflow fp32 for long chunks (exp(+large) -> inf -> inf*0 = NaN)
        delta = cumj[:, :, None, :] - cumj[:, None, :, :]
        dec = jnp.exp(jnp.where(tri[None, :, :, None] > 0, delta, -jnp.inf))
        y_in = jnp.einsum("btsh,bshp->bthp", qk * dec, vj)
        q_dec = qj * jnp.exp(cumj)[..., None]
        y_st = jnp.einsum("bthn,bhnp->bthp", q_dec, h)
        w = jnp.exp(totj[:, None, :] - cumj)            # (B,Q,H)
        h_new = (jnp.exp(totj)[:, :, None, None] * h
                 + jnp.einsum("bshn,bshp->bhnp", kj * w[..., None], vj))
        return h_new, y_in + y_st

    if h0 is None:
        h0 = jnp.zeros((B, H, N, P), jnp.float32)
    h_fin, yc = jax.lax.scan(step, h0, (vf, kc, qc, cum, tot))
    y = yc.swapaxes(0, 1).reshape(B, Sp, H, P)[:, :S]
    return y.astype(v.dtype), h_fin


def ssd_step(h: jax.Array, v: jax.Array, ld: jax.Array, k: jax.Array,
             q: jax.Array, g: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Single-token recurrence. h: (B,H,N,P); v: (B,H,P); ld,g: (B,H);
    k,q: (B,H,N). Returns (y: (B,H,P), h_new)."""
    hf = h.astype(jnp.float32)
    a = jnp.exp(ld.astype(jnp.float32))[..., None, None]
    upd = jnp.einsum("bhn,bhp->bhnp", k.astype(jnp.float32),
                     v.astype(jnp.float32) * g.astype(jnp.float32)[..., None])
    h_new = a * hf + upd
    y = jnp.einsum("bhn,bhnp->bhp", q.astype(jnp.float32), h_new)
    return y.astype(v.dtype), h_new


# ---------------------------------------------------------------------------
# causal depthwise conv (mamba2 / xLSTM frontends)
# ---------------------------------------------------------------------------
def causal_conv(x: jax.Array, w: jax.Array) -> jax.Array:
    """x: (B,S,C); w: (K,C) depthwise. Returns (B,S,C)."""
    K = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
    out = jnp.zeros_like(x, dtype=jnp.float32)
    for i in range(K):
        out = out + xp[:, i:i + x.shape[1]].astype(jnp.float32) * w[i].astype(jnp.float32)
    return out.astype(x.dtype)


def causal_conv_step(state: jax.Array, x1: jax.Array, w: jax.Array):
    """state: (B,K-1,C) past inputs; x1: (B,C). Returns (y: (B,C), new_state)."""
    K = w.shape[0]
    hist = jnp.concatenate([state, x1[:, None]], axis=1)      # (B,K,C)
    y = jnp.einsum("bkc,kc->bc", hist.astype(jnp.float32),
                   w.astype(jnp.float32)).astype(x1.dtype)
    return y, hist[:, 1:]


# ---------------------------------------------------------------------------
# Mamba2 block
# ---------------------------------------------------------------------------
class Mamba2Params(NamedTuple):
    w_z: jax.Array        # (d, d_in)
    w_x: jax.Array        # (d, d_in)
    w_B: jax.Array        # (d, N)
    w_C: jax.Array        # (d, N)
    w_dt: jax.Array       # (d, H)
    conv: jax.Array       # (K, d_in + 2N)
    A_log: jax.Array      # (H,) fp32
    D: jax.Array          # (H,) fp32
    dt_bias: jax.Array    # (H,) fp32
    norm: jax.Array       # (d_in,)
    w_out: jax.Array      # (d_in, d)


class Mamba2State(NamedTuple):
    h: jax.Array          # (B, H, N, P) fp32
    conv: jax.Array       # (B, K-1, d_in + 2N)


def mamba2_dims(d_model: int, s: SSMConfig):
    d_in = s.expand * d_model
    H = d_in // s.head_dim
    return d_in, H


def init_mamba2(key, d_model: int, s: SSMConfig, dtype) -> Mamba2Params:
    d_in, H = mamba2_dims(d_model, s)
    ks = jax.random.split(key, 7)
    dt0 = jnp.log(jnp.expm1(jnp.linspace(1e-3, 1e-1, H)))  # softplus^-1
    return Mamba2Params(
        w_z=dense_init(ks[0], (d_model, d_in), dtype),
        w_x=dense_init(ks[1], (d_model, d_in), dtype),
        w_B=dense_init(ks[2], (d_model, s.d_state), dtype),
        w_C=dense_init(ks[3], (d_model, s.d_state), dtype),
        w_dt=dense_init(ks[4], (d_model, H), dtype),
        conv=dense_init(ks[5], (s.d_conv, d_in + 2 * s.d_state), dtype, scale=0.5),
        A_log=jnp.log(jnp.linspace(1.0, 16.0, H)).astype(jnp.float32),
        D=jnp.ones((H,), jnp.float32),
        dt_bias=dt0.astype(jnp.float32),
        norm=jnp.ones((d_in,), dtype),
        w_out=dense_init(ks[6], (d_in, d_model), dtype),
    )


def _mamba2_proj(p: Mamba2Params, x: jax.Array, s: SSMConfig):
    z = jnp.einsum("bsd,de->bse", x, p.w_z)
    xc = jnp.einsum("bsd,de->bse", x, p.w_x)
    Bm = jnp.einsum("bsd,dn->bsn", x, p.w_B)
    Cm = jnp.einsum("bsd,dn->bsn", x, p.w_C)
    dt_raw = jnp.einsum("bsd,dh->bsh", x, p.w_dt)
    return z, jnp.concatenate([xc, Bm, Cm], axis=-1), dt_raw


def mamba2_forward(p: Mamba2Params, x: jax.Array, s: SSMConfig) -> jax.Array:
    B_, S, d = x.shape
    d_in, H = mamba2_dims(d, s)
    N, P = s.d_state, s.head_dim
    z, xbc, dt_raw = _mamba2_proj(p, x, s)
    xbc = jax.nn.silu(causal_conv(xbc, p.conv).astype(jnp.float32)).astype(x.dtype)
    xc, Bm, Cm = jnp.split(xbc, [d_in, d_in + N], axis=-1)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p.dt_bias)     # (B,S,H)
    A = -jnp.exp(p.A_log)                                            # (H,)
    ld = dt * A
    v = xc.reshape(B_, S, H, P)
    k = jnp.broadcast_to(Bm[:, :, None, :], (B_, S, H, N))
    q = jnp.broadcast_to(Cm[:, :, None, :], (B_, S, H, N))
    y, _ = ssd_chunked(v, ld, k, q, dt, chunk=s.chunk)
    y = y + (p.D[None, None, :, None]
             * v.astype(jnp.float32)).astype(y.dtype)
    y = y.reshape(B_, S, d_in)
    y = rms_norm(y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype), p.norm)
    return jnp.einsum("bse,ed->bsd", y, p.w_out)


def init_mamba2_state(batch: int, d_model: int, s: SSMConfig,
                      dtype=jnp.bfloat16) -> Mamba2State:
    d_in, H = mamba2_dims(d_model, s)
    return Mamba2State(
        h=jnp.zeros((batch, H, s.d_state, s.head_dim), jnp.float32),
        conv=jnp.zeros((batch, s.d_conv - 1, d_in + 2 * s.d_state), dtype))


def mamba2_decode(p: Mamba2Params, x: jax.Array, state: Mamba2State,
                  s: SSMConfig):
    """x: (B, 1, d). Returns (out (B,1,d), new_state)."""
    B_, _, d = x.shape
    d_in, H = mamba2_dims(d, s)
    N, P = s.d_state, s.head_dim
    z, xbc, dt_raw = _mamba2_proj(p, x, s)
    conv_out, new_conv = causal_conv_step(state.conv.astype(xbc.dtype),
                                          xbc[:, 0], p.conv)
    xbc1 = jax.nn.silu(conv_out.astype(jnp.float32)).astype(x.dtype)  # (B,C)
    xc, Bm, Cm = jnp.split(xbc1, [d_in, d_in + N], axis=-1)
    dt = jax.nn.softplus(dt_raw[:, 0].astype(jnp.float32) + p.dt_bias)  # (B,H)
    ld = dt * (-jnp.exp(p.A_log))
    v = xc.reshape(B_, H, P)
    k = jnp.broadcast_to(Bm[:, None, :], (B_, H, N))
    q = jnp.broadcast_to(Cm[:, None, :], (B_, H, N))
    y, h_new = ssd_step(state.h, v, ld, k, q, dt)
    y = y + (p.D[None, :, None] * v.astype(jnp.float32)).astype(y.dtype)
    y = y.reshape(B_, 1, d_in)
    y = rms_norm(y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype), p.norm)
    out = jnp.einsum("bse,ed->bsd", y, p.w_out)
    return out, Mamba2State(h_new, new_conv.astype(state.conv.dtype))
