"""Benchmark harness — one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--fast] [--only NAME]
                                            [--json-dir DIR]

Prints ``name,us_per_call,derived`` CSV and writes one machine-readable
``BENCH_<suite>.json`` per suite into --json-dir (default: the repo
root, wherever the harness is launched from, so bench-smoke refreshes
the COMMITTED per-PR perf trajectory in place; pass --json-dir '' to
disable) with us/round + every derived metric (rounds/sec etc.) parsed
into numbers. ``benchmarks/check_regression.py`` diffs a fresh
BENCH_fused_rounds.json against the committed baseline in CI.
Mapping to the paper:
    bench_convergence   -> Figs. 2 & 8 (psi percentiles vs k)
    bench_comm_timing   -> Figs. 3 & 9 (Poisson schedule)
    bench_cop_surface   -> Figs. 4, 5 & 10 (CoP vs n, eps + fitted bound)
    bench_collaboration -> Figs. 6 & 7 (value of collaboration)
    bench_async_vs_sync -> Sec. 2 comparison ([14]-style sync baseline)
                           + beyond-paper capped-rounds composition
                           + deep-path fused vs per-round driver
    bench_fused_rounds  -> beyond-paper: rounds/sec scaling of the fused
                           multi-round driver (device-resident ledger)
    bench_serving       -> beyond-paper: serving-path latency (no paper
                           figure; guards the hybrid-serving example)
    bench_chaos         -> beyond-paper: fault-layer guard overhead +
                           convergence degradation under injected faults
    bench_paged_bank    -> beyond-paper: paged owner bank — full-residency
                           parity overhead + resident-bytes scaling on
                           10k/100k-owner availability traces
    bench_kernels       -> kernel-path microbenches (CPU)
    bench_roofline      -> §Roofline table from the dry-run artifacts
"""
from __future__ import annotations

import argparse
import os
import sys
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None)
    ap.add_argument("--fast", action="store_true",
                    help="reduced run counts (CI mode)")
    ap.add_argument("--json-dir",
                    default=os.path.dirname(os.path.dirname(
                        os.path.abspath(__file__))),
                    help="where BENCH_<suite>.json files land "
                         "(default: the repo root; '' disables)")
    args = ap.parse_args()

    from benchmarks import (bench_async_vs_sync, bench_chaos,
                            bench_collaboration, bench_comm_timing,
                            bench_convergence, bench_cop_surface,
                            bench_fused_rounds, bench_kernels,
                            bench_paged_bank, bench_roofline,
                            bench_serving)

    suites = {
        "comm_timing": bench_comm_timing.run,
        "kernels": bench_kernels.run,
        "serving": bench_serving.run,
        "roofline": bench_roofline.run,
        "convergence": (lambda: bench_convergence.run(n_runs=20)) if args.fast
        else bench_convergence.run,
        "cop_surface": bench_cop_surface.run,
        "collaboration": bench_collaboration.run,
        "async_vs_sync": lambda: bench_async_vs_sync.run(fast=args.fast),
        "fused_rounds": lambda: bench_fused_rounds.run(fast=args.fast),
        "chaos": lambda: bench_chaos.run(fast=args.fast),
        "paged_bank": lambda: bench_paged_bank.run(fast=args.fast),
    }
    from benchmarks.common import write_bench_json

    print("name,us_per_call,derived")
    failures = 0
    for name, fn in suites.items():
        if args.only and args.only not in name:
            continue
        t0 = time.time()
        try:
            rows = list(fn())
            for row in rows:
                print(f"{row[0]},{row[1]:.1f},{row[2]}")
            if args.json_dir:
                write_bench_json(
                    os.path.join(args.json_dir, f"BENCH_{name}.json"),
                    name, rows, time.time() - t0)
        except Exception as e:  # keep the harness going
            failures += 1
            print(f"{name},0.0,ERROR:{type(e).__name__}:{e}")
        print(f"# {name} done in {time.time()-t0:.1f}s", file=sys.stderr)
    if failures:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
