"""Figs. 4, 5 & 10: relative fitness after T iterations versus dataset size
and privacy budget + fitted Theorem-2 constants (the mesh surface)."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.cop import bound_asymptotic, budget_sum, fit_constants
from repro.data import owner_shards
from repro.federation import Algo1Config, make_problem, run_many

N_OWNERS, T, RUNS, SIGMA = 3, 1000, 30, 2e-5
NS = (10_000, 50_000, 250_000)
EPS = (1.0, 3.0, 10.0)


def run(dataset: str = "lending"):
    rows = []
    obs = {}
    t0 = time.perf_counter()
    for n in NS:
        shards = owner_shards(dataset, [n] * N_OWNERS, seed=0, heterogeneity=0.0)
        prob, owners = make_problem(shards, reg=1e-5, theta_max=2.0)
        # noiseless floor: convergence error of Algorithm 1 itself — the
        # cost of PRIVACY is the excess over it (eq. 11 measures DP noise)
        cfg0 = Algo1Config(horizon=T, rho=1.0, sigma=SIGMA,
                           epsilons=[1.0] * N_OWNERS, noiseless=True)
        floor = float(jnp.mean(run_many(jax.random.PRNGKey(1), prob, owners,
                                        cfg0, 2).psi[:, -1]))
        for eps in EPS:
            cfg = Algo1Config(horizon=T, rho=1.0, sigma=SIGMA,
                              epsilons=[eps] * N_OWNERS)
            tr = run_many(jax.random.PRNGKey(1), prob, owners, cfg, RUNS)
            obs[(n, eps)] = max(float(jnp.mean(tr.psi[:, -1])) - floor, 1e-9)
    us = (time.perf_counter() - t0) * 1e6 / (len(NS) * len(EPS))

    ns = np.array([N_OWNERS * n for (n, e) in obs])
    ss = np.array([budget_sum([e] * N_OWNERS) for (n, e) in obs])
    vals = np.array(list(obs.values()))
    c1b, c2b = fit_constants(ns, ss, vals)
    for (n, e), v in obs.items():
        pred = bound_asymptotic(N_OWNERS * n, [e] * N_OWNERS, c1b, c2b)
        rows.append((f"cop_surface/{dataset}/n{n}/eps{e}", us,
                     f"psi={v:.4g};bound_fit={pred:.4g}"))
    rows.append((f"cop_surface/{dataset}/fitted_constants", us,
                 f"c1bar={c1b:.4g};c2bar={c2b:.4g}"))
    return rows


if __name__ == "__main__":
    from benchmarks.common import fmt_rows
    print(fmt_rows(run()))
