"""Figs. 6 & 7: the value of collaboration — private N-owner training
vs the non-private isolated model of a single owner, measured through the
`Federation` session surface.

The paper's headline: with n_i = 10,000 records each, collaboration wins
for >10 owners at eps >= 1 (fewer owners needed at higher budgets)."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.data import owner_shards
from repro.federation import (Federation, FederationConfig, federate_problem,
                              relative_fitness, with_budgets)

N_PER, T, RUNS, SIGMA = 10_000, 1000, 12, 2e-5
NS = (2, 5, 10, 25, 50)
EPS = (1.0, 2.5, 10.0)


def run(dataset: str = "lending"):
    rows = []
    cfg = FederationConfig(horizon=T, rho=1.0, sigma=SIGMA)
    t0 = time.perf_counter()
    for N in NS:
        shards = owner_shards(dataset, [N_PER] * N, seed=2)
        prob, owners = federate_problem(shards, 1.0, reg=1e-5, theta_max=2.0)
        # isolated, non-private exact model of owner 0
        X0, y0 = shards[0]
        G0, h0 = X0.T @ X0 / N_PER, X0.T @ y0 / N_PER
        p = X0.shape[1]
        theta_iso = np.linalg.solve(G0 + 1e-5 * np.eye(p), h0)
        psi_iso = float(relative_fitness(prob, jnp.asarray(theta_iso)))
        for eps in EPS:
            fed = Federation(with_budgets(owners, eps), cfg)
            tr = fed.run(jax.random.PRNGKey(0), prob, n_runs=RUNS)
            psi = float(jnp.mean(tr.psi[:, -1]))
            wins = psi < psi_iso
            rows.append((f"collaboration/{dataset}/N{N}/eps{eps}",
                         (time.perf_counter() - t0) * 1e6,
                         f"psi_collab={psi:.4g};psi_iso={psi_iso:.4g};"
                         f"collab_wins={int(wins)}"))
    return rows


if __name__ == "__main__":
    from benchmarks.common import fmt_rows
    print(fmt_rows(run()))
