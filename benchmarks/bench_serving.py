"""Serving-path microbench: batched one-token decode steps/sec on CPU for
every assigned architecture (reduced configs — the pod-scale numbers are the
decode rows of bench_roofline)."""
from __future__ import annotations

import time
import zlib

import jax
import jax.numpy as jnp

from repro.configs import get_config, list_archs
from repro.models import build_model


def run(archs=None, batch: int = 2, steps: int = 3):
    rows = []
    key = jax.random.PRNGKey(0)
    for arch in archs or list_archs():
        cfg = get_config(arch).reduced()
        model = build_model(cfg, remat=False, moe_mode="ragged")
        k_init, k_frames = jax.random.split(jax.random.fold_in(
            key, zlib.crc32(arch.encode()) & 0x7FFFFFFF))
        params = model.init(k_init, jnp.float32)
        cache = model.init_cache(batch, 32, dtype=jnp.float32)
        if cfg.family == "audio":
            frames = jax.random.normal(k_frames,
                                       (batch, cfg.enc_seq, cfg.d_model))
            cache = model.prime_cross_cache(params, cache, frames)
        step = jax.jit(lambda p, c, t, pos: model.decode_step(p, c, t, pos))
        toks = jnp.zeros((batch, 1), jnp.int32)
        logits, cache = step(params, cache, toks, jnp.int32(0))  # compile
        jax.block_until_ready(logits)
        t0 = time.perf_counter()
        for t in range(1, steps + 1):
            logits, cache = step(params, cache, toks, jnp.int32(t))
        jax.block_until_ready(logits)
        us = (time.perf_counter() - t0) * 1e6 / steps
        rows.append((f"serving/{arch}/decode_step", us,
                     f"tok_per_s={batch/(us/1e6):.1f};family={cfg.family}"))
    return rows


if __name__ == "__main__":
    from benchmarks.common import fmt_rows
    print(fmt_rows(run()))
