"""Related-work comparator ([14]-style synchronous DP): asynchronous
Algorithm 1 vs a synchronous all-owners-per-round DP baseline at equal
total privacy budget, plus the beyond-paper capped-rounds composition."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import Algo1Config, make_problem, run_many
from repro.core.linear import owner_grad, reg_grad
from repro.core.privacy import laplace_scale_theorem1
from repro.data import owner_shards

N, N_PER, T, RUNS, SIGMA = 5, 50_000, 800, 10, 2e-5


def _sync_dp(key, prob, owners, eps, T, lr=0.4):
    """Every round queries ALL owners (the synchronous pattern the paper
    argues does not scale); same per-owner budget split over T rounds."""
    p = prob.G.shape[0]
    scales = jnp.asarray([laplace_scale_theorem1(o.xi, T, o.n, eps)
                          for o in owners])
    n_i = jnp.asarray([o.n for o in owners], jnp.float32)
    A = jnp.stack([o.A for o in owners])
    b = jnp.stack([o.b for o in owners])

    def step(theta, k):
        ks = jax.random.fold_in(key, k)
        noise = scales[:, None] * jax.random.laplace(ks, (len(owners), p))
        q = 2.0 * (jnp.einsum("npq,q->np", A, theta) - b) + noise
        g = reg_grad(prob, theta) + jnp.einsum(
            "n,np->p", n_i / prob.n_total, q)
        theta = jnp.clip(theta - lr * g, -prob.theta_max, prob.theta_max)
        return theta, None

    theta, _ = jax.lax.scan(step, jnp.zeros(p), jnp.arange(T))
    return theta


def run(dataset: str = "lending"):
    rows = []
    shards = owner_shards(dataset, [N_PER] * N, seed=4, heterogeneity=0.0)
    prob, owners = make_problem(shards, reg=1e-5, theta_max=2.0)
    from repro.core.linear import relative_fitness
    for eps in (1.0, 5.0):
        t0 = time.perf_counter()
        cfg = Algo1Config(horizon=T, rho=1.0, sigma=SIGMA, epsilons=[eps] * N)
        tr = run_many(jax.random.PRNGKey(0), prob, owners, cfg, RUNS)
        psi_async = float(jnp.mean(tr.psi[:, -1]))
        cfgc = Algo1Config(horizon=T, rho=1.0, sigma=SIGMA,
                           epsilons=[eps] * N,
                           composition="per_owner_rounds")
        trc = run_many(jax.random.PRNGKey(0), prob, owners, cfgc, RUNS)
        psi_capped = float(jnp.mean(trc.psi[:, -1]))
        psis = []
        for r in range(RUNS):
            th = _sync_dp(jax.random.PRNGKey(100 + r), prob, owners, eps, T)
            psis.append(float(relative_fitness(prob, th)))
        psi_sync = float(np.mean(psis))
        us = (time.perf_counter() - t0) * 1e6 / (3 * RUNS * T)
        rows.append((f"async_vs_sync/{dataset}/eps{eps}", us,
                     f"psi_async={psi_async:.4g};psi_sync={psi_sync:.4g};"
                     f"psi_async_capped={psi_capped:.4g}"))
    return rows


if __name__ == "__main__":
    from benchmarks.common import fmt_rows
    print(fmt_rows(run()))
