"""Related-work comparator ([14]-style synchronous DP): asynchronous
Algorithm 1 vs a synchronous all-owners-per-round DP baseline at equal
total privacy budget, plus the beyond-paper capped-rounds composition —
all three behind the same `Federation` session surface (the sync baseline
is just strategy='sync')."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.data import owner_shards
from repro.federation import (Federation, FederationConfig, federate_problem,
                              with_budgets)

N, N_PER, T, RUNS, SIGMA = 5, 50_000, 800, 10, 2e-5


def run(dataset: str = "lending"):
    rows = []
    shards = owner_shards(dataset, [N_PER] * N, seed=4, heterogeneity=0.0)
    cfg = FederationConfig(horizon=T, rho=1.0, sigma=SIGMA)
    prob, base_owners = federate_problem(shards, 1.0, reg=1e-5, theta_max=2.0)
    for eps in (1.0, 5.0):
        owners = with_budgets(base_owners, eps)
        t0 = time.perf_counter()
        tr = Federation(owners, cfg).run(
            jax.random.PRNGKey(0), prob, n_runs=RUNS)
        psi_async = float(jnp.mean(tr.psi[:, -1]))
        trc = Federation(owners, cfg, mechanism="per_owner_rounds").run(
            jax.random.PRNGKey(0), prob, n_runs=RUNS)
        psi_capped = float(jnp.mean(trc.psi[:, -1]))
        trs = Federation(owners, cfg, strategy="sync").run_sync(
            jax.random.PRNGKey(100), prob, lr=0.4, n_runs=RUNS)
        psi_sync = float(jnp.mean(trs.psi[:, -1]))
        us = (time.perf_counter() - t0) * 1e6 / (3 * RUNS * T)
        rows.append((f"async_vs_sync/{dataset}/eps{eps}", us,
                     f"psi_async={psi_async:.4g};psi_sync={psi_sync:.4g};"
                     f"psi_async_capped={psi_capped:.4g}"))
    return rows


if __name__ == "__main__":
    from benchmarks.common import fmt_rows
    print(fmt_rows(run()))
