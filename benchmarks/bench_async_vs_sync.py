"""Related-work comparator ([14]-style synchronous DP): asynchronous
Algorithm 1 vs a synchronous all-owners-per-round DP baseline at equal
total privacy budget, plus the beyond-paper capped-rounds composition —
all three behind the same `Federation` session surface (the sync baseline
is just strategy='sync'). Also times the deep path's two async drivers
head-to-head at 32 owners: the host-authorized per-round `step()` loop vs
the fused `run_rounds` scan (device-resident ledger, K rounds/dispatch) —
the workload and timing harness are bench_fused_rounds', imported."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from benchmarks import bench_fused_rounds
from repro.data import owner_shards
from repro.federation import (Federation, FederationConfig, federate_problem,
                              with_budgets)

N, N_PER, T, RUNS, SIGMA = 5, 50_000, 800, 10, 2e-5


def _deep_driver_row(fast: bool):
    """rounds/sec: fused run_rounds vs the per-round step() loop."""
    k = 128 if fast else 512
    dt_loop, dt_fused = bench_fused_rounds.measure(k)
    return (f"async_vs_sync/deep_fused/owners{bench_fused_rounds.N_OWNERS}",
            dt_fused / k * 1e6,
            bench_fused_rounds.derived_row(dt_loop, dt_fused, k))


def run(dataset: str = "lending", fast: bool = False):
    rows = []
    t = 200 if fast else T
    runs = 3 if fast else RUNS
    shards = owner_shards(dataset, [N_PER] * N, seed=4, heterogeneity=0.0)
    cfg = FederationConfig(horizon=t, rho=1.0, sigma=SIGMA)
    prob, base_owners = federate_problem(shards, 1.0, reg=1e-5, theta_max=2.0)
    for eps in (1.0, 5.0):
        owners = with_budgets(base_owners, eps)
        t0 = time.perf_counter()
        tr = Federation(owners, cfg).run(
            jax.random.PRNGKey(0), prob, n_runs=runs)
        psi_async = float(jnp.mean(tr.psi[:, -1]))
        trc = Federation(owners, cfg, mechanism="per_owner_rounds").run(
            jax.random.PRNGKey(0), prob, n_runs=runs)
        psi_capped = float(jnp.mean(trc.psi[:, -1]))
        trs = Federation(owners, cfg, strategy="sync").run_sync(
            jax.random.PRNGKey(100), prob, lr=0.4, n_runs=runs)
        psi_sync = float(jnp.mean(trs.psi[:, -1]))
        us = (time.perf_counter() - t0) * 1e6 / (3 * runs * t)
        rows.append((f"async_vs_sync/{dataset}/eps{eps}", us,
                     f"psi_async={psi_async:.4g};psi_sync={psi_sync:.4g};"
                     f"psi_async_capped={psi_capped:.4g}"))
    rows.append(_deep_driver_row(fast))
    return rows


if __name__ == "__main__":
    import argparse
    from benchmarks.common import fmt_rows
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="reduced run counts (CI mode)")
    args = ap.parse_args()
    print(fmt_rows(run(fast=args.fast)))
