"""Fused multi-round driver vs the per-round step() loop.

The deep path's wall-clock at small models is dispatch-bound: every
`Federation.step()` is one host round-trip (Python authorize + jitted call)
for microseconds of compute. `run_rounds` scans K rounds per dispatch with
the privacy ledger resident on-device, so the dispatch cost amortizes
K-fold. Reported: us/round for both drivers and the rounds/sec speedup at
each rounds-per-dispatch K.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.federation import (DataOwner, Federation, FederationConfig,
                              PrivatizerConfig)

# Dispatch-bound regime: a model small enough that per-round compute is
# microseconds, so the measured gap is the driver overhead itself.
N_OWNERS, DIM, BATCH = 32, 16, 4


def _setup(horizon):
    key = jax.random.PRNGKey(0)
    params = {"w": jax.random.normal(key, (DIM, DIM)) / DIM,
              "b": jnp.zeros((DIM,))}
    loss_fn = lambda p, b: jnp.mean(
        (b["x"] @ p["w"] + p["b"] - b["y"]) ** 2)
    owners = [DataOwner(n=10_000, epsilon=2.0, xi=1.0)
              for _ in range(N_OWNERS)]
    fed = Federation(owners, FederationConfig(horizon=horizon, sigma=1e-2,
                                              lr_scale=5.0))
    fed.make_step(loss_fn, privatizer=PrivatizerConfig(
        xi=1.0, granularity="microbatch", n_microbatches=1))
    return fed, params


def _batches(k):
    return {"x": jax.random.normal(jax.random.PRNGKey(1), (k, BATCH, DIM)),
            "y": jax.random.normal(jax.random.PRNGKey(2), (k, BATCH, DIM))}


def _time_loop(fed, state, batches, owner_seq, keys):
    k = owner_seq.shape[0]
    t0 = time.perf_counter()
    for i in range(k):
        b = jax.tree_util.tree_map(lambda a: a[i], batches)
        state, _ = fed.step(state, b, int(owner_seq[i]), keys[i])
    jax.block_until_ready(state.theta_L)
    return time.perf_counter() - t0


def _time_fused(fed, state, batches, owner_seq, key):
    t0 = time.perf_counter()
    state, _ = fed.run_rounds(state, batches, owner_seq, key=key)
    jax.block_until_ready(state.theta_L)
    return time.perf_counter() - t0


def measure(k: int):
    """(dt_loop, dt_fused) seconds for K rounds under each driver (after a
    warmup/compile pass each). Shared with bench_async_vs_sync's
    deep-driver row so both suites measure the identical workload."""
    horizon = 4 * k  # nobody exhausts: measure the granted hot path
    batches = _batches(k)
    owner_seq = jax.random.randint(jax.random.PRNGKey(3), (k,), 0, N_OWNERS)
    root = jax.random.PRNGKey(4)
    keys = jax.random.split(root, k)

    fed_l, params = _setup(horizon)
    state_l = fed_l.init_state(params)
    _time_loop(fed_l, state_l, batches, owner_seq, keys)       # warmup
    dt_loop = _time_loop(fed_l, state_l, batches, owner_seq, keys)

    fed_f, _ = _setup(horizon)
    state_f = fed_f.init_state(params)
    _time_fused(fed_f, state_f, batches, owner_seq, root)      # warmup+jit
    dt_fused = _time_fused(fed_f, state_f, batches, owner_seq, root)
    return dt_loop, dt_fused


def derived_row(dt_loop: float, dt_fused: float, k: int) -> str:
    return (f"rounds_per_sec_fused={k / dt_fused:.0f};"
            f"rounds_per_sec_step={k / dt_loop:.0f};"
            f"speedup={dt_loop / dt_fused:.1f}x")


def run(fast: bool = False):
    rows = []
    ks = (64, 256) if fast else (64, 256, 1024)
    for k in ks:
        dt_loop, dt_fused = measure(k)
        rows.append((f"fused_rounds/owners{N_OWNERS}/K{k}",
                     dt_fused / k * 1e6, derived_row(dt_loop, dt_fused, k)))
    return rows


if __name__ == "__main__":
    from benchmarks.common import fmt_rows
    print(fmt_rows(run()))
