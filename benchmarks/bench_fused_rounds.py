"""Fused multi-round driver vs the per-round step() loop, and the
flat-buffer engine vs the pytree path.

Two comparisons, one workload family:

  * fused-vs-step (PR 2): the deep path's wall-clock at small models is
    dispatch-bound — every `Federation.step()` is one host round-trip for
    microseconds of compute. `run_rounds` scans K rounds per dispatch with
    the privacy ledger resident on-device, so the dispatch cost amortizes
    K-fold.
  * flat-vs-tree (ISSUE 3): with dispatch amortized, the round's own
    compute is the bound. The flat engine packs the model into one
    contiguous buffer (bank = one (N, P) matrix, bf16 storage) and runs
    the whole post-gradient round as a single fused pass (`dp_round`),
    measured against the reference pytree path on the same schedule at
    BOTH the dispatch-bound toy config and an MLP-scale model.
  * sharded-vs-replicated bank (ISSUE 4): the flat engine with its state
    laid out over the host device mesh (`make_host_mesh`) against the
    single-device layout, at the MLP-scale config. On a 1-device host
    this measures pure constraint overhead (~0); on a multi-device host
    it is the mesh-sharded engine's row. The row records its mesh
    topology in the derived metrics.
  * grouped-vs-sequential schedule (ISSUE 4): `run_rounds` with
    owner_parallel=True (conflict-free owner groups vmapped per scan
    step, max_group bounds padding waste) against the strictly
    sequential scan at 32 owners. Wins in the compute-bound MLP regime
    (batched member GEMMs); the dispatch-bound toy regime prefers the
    sequential scan — both are recorded.
  * bank-dtype matrix (ISSUE 5): the quantized owner bank (int8/fp8
    codes + per-row scales + error-feedback residual, ~4x below the f32
    resident bytes) against bf16 and f32, with resident-bank-bytes (==
    scan-carry bytes) per round as a derived metric, plus a convergence
    guard pinning the int8+EF trajectory to the f32 one. On the CPU
    oracle backend the codec's own P-sized passes offset most of the
    carry-copy saving (int8 ~parity with bf16, ~1.25x vs f32); the byte
    cut is the durable win, and the compiled-TPU path is where it is
    expected to convert into rounds/sec (ROADMAP: TPU validation).

Timings are interleaved medians (the engines alternate within each
repetition) so machine noise hits both alike.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.federation import (DataOwner, Federation, FederationConfig,
                              PrivatizerConfig)
from repro.launch.mesh import make_host_mesh

# Dispatch-bound regime: a model small enough that per-round compute is
# microseconds, so the measured gap is the driver overhead itself.
N_OWNERS, DIM, BATCH = 32, 16, 4

# MLP-scale regime: ~0.36M params across 14 leaves (6 hidden layers of
# 256) — the smallest config where per-round compute, not dispatch,
# dominates on CPU.
MLP_DIM, MLP_HIDDEN, MLP_LAYERS, MLP_BATCH = 64, 256, 6, 8


def _toy_model():
    key = jax.random.PRNGKey(0)
    params = {"w": jax.random.normal(key, (DIM, DIM)) / DIM,
              "b": jnp.zeros((DIM,))}
    def loss_fn(p, b):
        return jnp.mean((b["x"] @ p["w"] + p["b"] - b["y"]) ** 2)
    return params, loss_fn, DIM, BATCH


def _mlp_model():
    key = jax.random.PRNGKey(0)
    ks = jax.random.split(key, 2 * MLP_LAYERS + 2)
    d_in, layers = MLP_DIM, []
    for i in range(MLP_LAYERS):
        layers.append({"w": jax.random.normal(ks[2 * i], (d_in, MLP_HIDDEN))
                       / np.sqrt(d_in),
                       "b": jnp.zeros((MLP_HIDDEN,))})
        d_in = MLP_HIDDEN
    layers.append({"w": jax.random.normal(ks[-1], (d_in, MLP_DIM))
                   / np.sqrt(d_in),
                   "b": jnp.zeros((MLP_DIM,))})
    params = {"layers": layers}

    def loss_fn(p, b):
        x = b["x"]
        for lay in p["layers"][:-1]:
            x = jax.nn.relu(x @ lay["w"] + lay["b"])
        out = x @ p["layers"][-1]["w"] + p["layers"][-1]["b"]
        return jnp.mean((out - b["y"]) ** 2)

    return params, loss_fn, MLP_DIM, MLP_BATCH


_MODELS = {"toy": _toy_model, "mlp": _mlp_model}


def _make_fed(loss_fn, horizon, *, pack=False, fused=False, bank_dtype=None,
              mesh=None, donate=False, unroll=1):
    owners = [DataOwner(n=10_000, epsilon=2.0, xi=1.0)
              for _ in range(N_OWNERS)]
    fed = Federation(owners, FederationConfig(horizon=horizon, sigma=1e-2,
                                              lr_scale=5.0))
    fed.make_step(loss_fn, privatizer=PrivatizerConfig(
        xi=1.0, granularity="microbatch", n_microbatches=1,
        fused_kernel=fused), pack_params=pack, bank_dtype=bank_dtype,
        mesh=mesh, donate=donate, unroll=unroll)
    return fed


def _setup(horizon):
    params, loss_fn, _, _ = _toy_model()
    return _make_fed(loss_fn, horizon), params


def _batches(k, dim=DIM, batch=BATCH):
    return {"x": jax.random.normal(jax.random.PRNGKey(1), (k, batch, dim)),
            "y": jax.random.normal(jax.random.PRNGKey(2), (k, batch, dim))}


def _time_loop(fed, state, batches, owner_seq, keys):
    k = owner_seq.shape[0]
    seq = np.asarray(owner_seq)   # hoist: no per-iteration host sync
    t0 = time.perf_counter()
    for i in range(k):
        b = jax.tree_util.tree_map(lambda a: a[i], batches)
        state, _ = fed.step(state, b, int(seq[i]), keys[i])
    jax.block_until_ready(state.theta_L)
    return time.perf_counter() - t0


def _time_fused(fed, state, batches, owner_seq, key, **kw):
    t0 = time.perf_counter()
    state, _ = fed.run_rounds(state, batches, owner_seq, key=key, **kw)
    jax.block_until_ready(jax.tree_util.tree_leaves(state.theta_L)[0])
    return time.perf_counter() - t0


def measure(k: int):
    """(dt_loop, dt_fused) seconds for K rounds under each driver (after a
    warmup/compile pass each). Shared with bench_async_vs_sync's
    deep-driver row so both suites measure the identical workload."""
    horizon = 4 * k  # nobody exhausts: measure the granted hot path
    batches = _batches(k)
    owner_seq = jax.random.randint(jax.random.PRNGKey(3), (k,), 0, N_OWNERS)
    root = jax.random.PRNGKey(4)
    keys = jax.random.split(root, k)

    fed_l, params = _setup(horizon)
    state_l = fed_l.init_state(params)
    _time_loop(fed_l, state_l, batches, owner_seq, keys)       # warmup
    # same keys on purpose: warmup and timed pass must be the identical
    # workload (equivalence with the fused driver is asserted elsewhere)
    dt_loop = _time_loop(fed_l, state_l, batches, owner_seq, keys)  # dpcheck: ignore[DPC105]

    fed_f, _ = _setup(horizon)
    state_f = fed_f.init_state(params)
    _time_fused(fed_f, state_f, batches, owner_seq, root)      # warmup+jit
    dt_fused = _time_fused(fed_f, state_f, batches, owner_seq, root)
    return dt_loop, dt_fused


def measure_flat_vs_tree(model: str, k: int, reps: int = 9):
    """Interleaved-median rounds/sec of the flat engine (pack_params +
    dp_round fused pass + bf16 bank — its production configuration)
    against the reference pytree path, same schedule and fused driver."""
    params, loss_fn, dim, batch = _MODELS[model]()
    batches = _batches(k, dim, batch)
    owner_seq = jax.random.randint(jax.random.PRNGKey(3), (k,), 0, N_OWNERS)
    root = jax.random.PRNGKey(4)

    fed_t = _make_fed(loss_fn, 4 * k)
    fed_f = _make_fed(loss_fn, 4 * k, pack=True, fused=True,
                      bank_dtype=jnp.bfloat16)
    runs = [(fed_t, fed_t.init_state(params)),
            (fed_f, fed_f.init_state(params))]
    dt_tree, dt_flat = _interleaved(runs, batches, owner_seq, root, reps)
    return dt_tree, dt_flat


def _interleaved(runs, batches, owner_seq, root, reps, kws=None):
    """Median seconds per engine, engines alternating within each rep.

    A runs entry is (fed, state) or (fed, state_factory): a factory is
    called before every timed dispatch (and blocked on OUTSIDE the
    timer) — required for engines built with donate=True, whose dispatch
    consumes the state it is handed."""
    kws = kws or [{}] * len(runs)

    def _state(st):
        if callable(st):
            s = st()
            jax.block_until_ready(jax.tree_util.tree_leaves(s))
            return s
        return st

    for (fed, st), kw in zip(runs, kws):                       # compile
        _time_fused(fed, _state(st), batches, owner_seq, root, **kw)
    times = [[] for _ in runs]
    for _ in range(reps):
        for i, ((fed, st), kw) in enumerate(zip(runs, kws)):
            times[i].append(
                _time_fused(fed, _state(st), batches, owner_seq, root,
                            **kw))
    return [float(np.median(ts)) for ts in times]


def _mesh_label(mesh) -> str:
    return "x".join(f"{name}{size}" for name, size in
                    zip(mesh.axis_names, mesh.devices.shape))


def measure_sharded_vs_replicated(model: str, k: int, reps: int = 9):
    """Interleaved-median rounds/sec of the mesh-sharded flat engine
    (state laid out by flat_shardings over the host mesh, constraints in
    the scan body) against the single-device flat engine, production
    configuration (dp_round fused pass + bf16 bank) on both sides."""
    params, loss_fn, dim, batch = _MODELS[model]()
    batches = _batches(k, dim, batch)
    owner_seq = jax.random.randint(jax.random.PRNGKey(3), (k,), 0, N_OWNERS)
    root = jax.random.PRNGKey(4)
    mesh = make_host_mesh(model=2 if len(jax.devices()) % 2 == 0 else 1)

    fed_r = _make_fed(loss_fn, 4 * k, pack=True, fused=True,
                      bank_dtype=jnp.bfloat16)
    fed_s = _make_fed(loss_fn, 4 * k, pack=True, fused=True,
                      bank_dtype=jnp.bfloat16, mesh=mesh)
    runs = [(fed_r, fed_r.init_state(params)),
            (fed_s, fed_s.init_state(params))]
    dt_rep, dt_shard = _interleaved(runs, batches, owner_seq, root, reps)
    return dt_rep, dt_shard, _mesh_label(mesh)


def measure_grouped(model: str, k: int, reps: int = 9, max_group: int = 6):
    """Interleaved-median rounds/sec of owner-parallel grouped execution
    (conflict-free owner groups vmapped per scan step) against the
    sequential scan, same schedule/keys, production flat configuration.
    `max_group` bounds group padding waste — unbounded maximal groups pad
    every group to the longest (≈2x wasted member slots at 32 owners)."""
    params, loss_fn, dim, batch = _MODELS[model]()
    batches = _batches(k, dim, batch)
    owner_seq = jax.random.randint(jax.random.PRNGKey(3), (k,), 0, N_OWNERS)
    root = jax.random.PRNGKey(4)

    # one Federation serves both drivers (make_step builds the sequential
    # AND grouped programs; the kwarg picks at dispatch) — a second one
    # would just re-jit identical programs
    fed = _make_fed(loss_fn, 4 * k, pack=True, fused=True,
                    bank_dtype=jnp.bfloat16)
    runs = [(fed, fed.init_state(params)), (fed, fed.init_state(params))]
    kws = [{}, dict(owner_parallel=True, max_group=max_group)]
    dt_seq, dt_grp = _interleaved(runs, batches, owner_seq, root, reps, kws)
    from repro.federation.schedules import partition_conflict_free
    n_groups = len(partition_conflict_free(np.asarray(owner_seq), max_group))
    return dt_seq, dt_grp, n_groups


BANK_DTYPES = {
    # name -> (bank_dtype, extra make_step kwargs). bf16 is the PR 4
    # production configuration (the baseline the quantized rows are
    # judged against); the quantized banks add state donation through
    # the dispatch boundary. unroll stays 1 everywhere: measured on the
    # XLA:CPU oracle backend it REGRESSES this engine (the unrolled body
    # defeats the carry aliasing; 0.5-0.3x at unroll 2-4) — the knob is
    # exposed for the TPU path where the tradeoff differs.
    "f32": (None, {}),
    "bf16": (jnp.bfloat16, {}),
    "int8": ("int8", dict(donate=True)),
    "fp8": ("fp8", dict(donate=True)),
}


def measure_bank_dtypes(model: str, k: int, reps: int = 9):
    """Interleaved-median rounds/sec of the quantized owner banks
    (int8/fp8 codes + f32 scales + error-feedback residual, stochastic
    rounding from the round key) against the bf16 and f32 flat engines:
    same schedule/keys, fused dp_round path everywhere, at the 32-owner
    MLP-scale config. Also returns each bank's RESIDENT bytes — which is
    exactly what one scan round carries, so bytes/round is the derived
    loop-carry metric. Donating engines get a fresh state per rep (init
    excluded from the timer)."""
    params, loss_fn, dim, batch = _MODELS[model]()
    batches = _batches(k, dim, batch)
    owner_seq = jax.random.randint(jax.random.PRNGKey(3), (k,), 0, N_OWNERS)
    root = jax.random.PRNGKey(4)
    runs, names, nbytes = [], [], {}
    for name, (bd, extra) in BANK_DTYPES.items():
        fed = _make_fed(loss_fn, 4 * k, pack=True, fused=True,
                        bank_dtype=bd, **extra)
        bank = fed.init_state(params).bank
        nbytes[name] = int(bank.nbytes)     # QuantBank sums its buffers
        # EVERY engine gets a fresh state per rep — the donating ones
        # must, and mixing protocols is unfair (a reused input state
        # lets the allocator recycle the previous rep's output blocks,
        # which measured up to 1.8x faster than the fresh-state path)
        runs.append((fed, lambda fed=fed: fed.init_state(params)))
        names.append(name)
    dts = _interleaved(runs, batches, owner_seq, root, reps)
    return dict(zip(names, dts)), nbytes


def measure_quant_convergence(model: str, k: int, tol: float = 0.5):
    """Error-feedback validation row against the Theorem 2 noise floor.

    Theorem 2's cost-of-privacy forecast is a function of the DP noise
    alone, so quantized storage may not add error of that order. Three
    runs: f32 under the root key, f32 under a DIFFERENT key (their
    distance IS the DP-noise floor — everything else is identical), and
    int8+EF under the root key (identical Laplace draws to the f32 root
    run — the codec RNG stream is salted away from the privacy stream —
    so quantization is the ONLY difference). The quantization deviation
    must stay under `tol` of one noise-redraw distance (measured ~0.2 at
    this config), in the paper's meaningful-noise regime (small owners,
    eps=1; at n=10k/eps=2 the DP noise is so small that ANY second noise
    source dominates — there the binding metric is the model-relative
    deviation, ~3%, also returned). Raises on violation so the CI
    ERROR-row guard trips."""
    import time as _time
    params, loss_fn, dim, batch = _MODELS[model]()
    batches = _batches(k, dim, batch)
    owner_seq = jax.random.randint(jax.random.PRNGKey(3), (k,), 0, N_OWNERS)
    root = jax.random.PRNGKey(4)
    runs = (("f32", None, root), ("f32_alt", None, jax.random.fold_in(
        root, 1)), ("int8", "int8", root))
    thetas, dt_q = {}, 0.0
    for name, bd, key in runs:
        owners = [DataOwner(n=500, epsilon=1.0, xi=1.0)
                  for _ in range(N_OWNERS)]
        fed = Federation(owners, FederationConfig(horizon=4 * k,
                                                  sigma=1e-2,
                                                  lr_scale=5.0))
        fed.make_step(loss_fn, privatizer=PrivatizerConfig(
            xi=1.0, granularity="microbatch", n_microbatches=1,
            fused_kernel=True), pack_params=True, bank_dtype=bd)
        if name == "int8":
            # compile pass first: this row's us/round lands in the
            # committed trajectory next to the interleaved-median rows,
            # which all exclude trace/compile time
            warm, _ = fed.run_rounds(fed.init_state(params), batches,
                                     owner_seq, key=key)
            jax.block_until_ready(warm.theta_L.buf)
        state = fed.init_state(params)
        t0 = _time.perf_counter()
        state, m = fed.run_rounds(state, batches, owner_seq, key=key)
        jax.block_until_ready(state.theta_L.buf)
        if name == "int8":
            dt_q = _time.perf_counter() - t0
        assert not np.asarray(m["refused"]).any()
        thetas[name] = np.asarray(state.theta_L.buf)
    noise_floor = float(np.linalg.norm(thetas["f32_alt"] - thetas["f32"]))
    dev = float(np.linalg.norm(thetas["int8"] - thetas["f32"]))
    rel_noise = dev / max(noise_floor, 1e-12)
    rel_model = dev / max(float(np.linalg.norm(thetas["f32"])), 1e-12)
    if rel_noise > tol:
        raise RuntimeError(
            f"int8+EF trajectory deviates {rel_noise:.3f} of the DP-noise "
            f"floor (tol {tol}): quantization error would distort the "
            f"Theorem 2 cost-of-privacy fit")
    return dict(dev=dev, noise_floor=noise_floor, rel_noise=rel_noise,
                rel_model=rel_model, tol=tol), dt_q


def bank_dtype_row(dts, nbytes, k: int) -> str:
    parts = [f"rounds_per_sec_{n}={k / dt:.0f}" for n, dt in dts.items()]
    parts += [f"speedup_int8_vs_bf16={dts['bf16'] / dts['int8']:.2f}x",
              f"speedup_int8_vs_f32={dts['f32'] / dts['int8']:.2f}x"]
    parts += [f"bank_bytes_per_round_{n}={b}" for n, b in nbytes.items()]
    parts.append(
        f"bank_bytes_cut_vs_f32={nbytes['f32'] / nbytes['int8']:.2f}x")
    return ";".join(parts)


def derived_row(dt_loop: float, dt_fused: float, k: int) -> str:
    return (f"rounds_per_sec_fused={k / dt_fused:.0f};"
            f"rounds_per_sec_step={k / dt_loop:.0f};"
            f"speedup={dt_loop / dt_fused:.1f}x")


def flat_row(dt_tree: float, dt_flat: float, k: int) -> str:
    return (f"rounds_per_sec_flat={k / dt_flat:.0f};"
            f"rounds_per_sec_tree={k / dt_tree:.0f};"
            f"speedup={dt_tree / dt_flat:.2f}x")


def sharded_row(dt_rep: float, dt_shard: float, k: int, mesh: str) -> str:
    return (f"rounds_per_sec_sharded={k / dt_shard:.0f};"
            f"rounds_per_sec_replicated={k / dt_rep:.0f};"
            f"speedup={dt_rep / dt_shard:.2f}x;mesh={mesh}")


def grouped_row(dt_seq: float, dt_grp: float, k: int, n_groups: int) -> str:
    return (f"rounds_per_sec_grouped={k / dt_grp:.0f};"
            f"rounds_per_sec_sequential={k / dt_seq:.0f};"
            f"speedup={dt_seq / dt_grp:.2f}x;n_groups={n_groups}")


def run(fast: bool = False):
    rows = []
    ks = (64, 256) if fast else (64, 256, 1024)
    for k in ks:
        dt_loop, dt_fused = measure(k)
        rows.append((f"fused_rounds/owners{N_OWNERS}/K{k}",
                     dt_fused / k * 1e6, derived_row(dt_loop, dt_fused, k)))
    flat_cfgs = ((("toy", 128), ("mlp", 24)) if fast
                 else (("toy", 256), ("mlp", 64)))
    reps = 5 if fast else 9
    for model, k in flat_cfgs:
        dt_tree, dt_flat = measure_flat_vs_tree(model, k, reps=reps)
        rows.append((f"fused_rounds/flat_vs_tree/{model}/K{k}",
                     dt_flat / k * 1e6, flat_row(dt_tree, dt_flat, k)))
    k = 24 if fast else 64
    dt_rep, dt_shard, mesh = measure_sharded_vs_replicated("mlp", k,
                                                           reps=reps)
    rows.append((f"fused_rounds/sharded_vs_replicated/mlp/K{k}",
                 dt_shard / k * 1e6, sharded_row(dt_rep, dt_shard, k, mesh)))
    # the grouped win needs enough rounds to amortize the padded groups'
    # compile: K=64 in both modes (K=24 measures ~1.0x, see ISSUE 4)
    kg = 64
    dt_seq, dt_grp, n_groups = measure_grouped("mlp", kg, reps=reps)
    rows.append((f"fused_rounds/grouped_vs_sequential/mlp/K{kg}",
                 dt_grp / kg * 1e6, grouped_row(dt_seq, dt_grp, kg,
                                                n_groups)))
    # quantized owner bank (ISSUE 5): int8/fp8-vs-bf16-vs-f32 at the
    # MLP-scale config + resident-bank-bytes-per-round derived metric,
    # and the error-feedback convergence guard against the f32 trajectory
    kq = 64
    dts, nbytes = measure_bank_dtypes("mlp", kq, reps=reps)
    rows.append((f"fused_rounds/bank_dtype/mlp/K{kq}",
                 dts["int8"] / kq * 1e6, bank_dtype_row(dts, nbytes, kq)))
    qc, dt_q = measure_quant_convergence("mlp", kq)
    rows.append((f"fused_rounds/quant_convergence/mlp/K{kq}",
                 dt_q / kq * 1e6,
                 f"traj_dev={qc['dev']:.4f};"
                 f"noise_floor={qc['noise_floor']:.4f};"
                 f"dev_vs_noise_floor={qc['rel_noise']:.3f};"
                 f"dev_vs_model_norm={qc['rel_model']:.4f};"
                 f"tol={qc['tol']};within_tol=1"))
    return rows


if __name__ == "__main__":
    from benchmarks.common import fmt_rows
    print(fmt_rows(run()))
