"""Kernel-path microbenches (CPU): pure-jnp reference implementations at
small scale + the Pallas kernels in interpret mode for correctness-parity
timing. Real TPU timing is out of scope for this container — the roofline
table (bench_roofline) is the perf deliverable."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import timed
from repro.kernels.flash_attention.ref import attention_ref
from repro.models.attention import blockwise_attention
from repro.models.ssm import ssd_chunked


def run():
    rows = []
    key = jax.random.PRNGKey(0)
    kq, kv, kk, kl, kg = jax.random.split(key, 5)
    B, S, H, hd = 2, 512, 4, 64
    q = jax.random.normal(kq, (B, S, H, hd), jnp.float32)
    pos = jnp.arange(S)

    f_block = jax.jit(lambda q: blockwise_attention(
        q, q, q, q_positions=pos, kv_positions=pos, kv_chunk=128))
    _, us = timed(lambda: jax.block_until_ready(f_block(q)))
    rows.append((f"kernels/blockwise_attention_jnp/B{B}S{S}", us,
                 f"flops={4*B*S*S*H*hd:.3g}"))

    f_ref = jax.jit(lambda q: attention_ref(
        q.transpose(0, 2, 1, 3), q.transpose(0, 2, 1, 3),
        q.transpose(0, 2, 1, 3)))
    _, us_ref = timed(lambda: jax.block_until_ready(f_ref(q)))
    rows.append((f"kernels/attention_materialized/B{B}S{S}", us_ref,
                 "oracle"))

    v = jax.random.normal(kv, (B, S, H, hd))
    k2 = jax.random.normal(kk, (B, S, H, 16))
    ld = -jax.nn.softplus(jax.random.normal(kl, (B, S, H)))
    g = jax.nn.sigmoid(jax.random.normal(kg, (B, S, H)))
    f_ssd = jax.jit(lambda: jax.block_until_ready(
        ssd_chunked(v, ld, k2, k2, g, chunk=128)[0]))
    _, us_ssd = timed(f_ssd)
    rows.append((f"kernels/ssd_chunked_jnp/B{B}S{S}", us_ssd,
                 f"state={H*16*hd}"))
    return rows


if __name__ == "__main__":
    from benchmarks.common import fmt_rows
    print(fmt_rows(run()))
