"""Figs. 2 & 8: percentile statistics of relative fitness psi(theta_L,k)
over 100 runs for three privacy budgets, lending + health datasets — one
vmapped `Federation` session per (dataset, eps) cell.

Beyond-paper: a tree-vs-Laplace cost-of-privacy row at equal (eps, K) on
the paper config — the DP-FTRL tree mechanism's excess final loss over a
noiseless run must come in at or below the paper mechanism's (the O(log K)
vs O(K) cumulative-noise claim, measured end-to-end through the fused
deep engine). The row is guarded by benchmarks/check_regression.py."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.data import owner_shards
from repro.federation import (DataOwner, Federation, FederationConfig,
                              PrivatizerConfig, federate_problem,
                              with_budgets)

N_OWNERS, N_PER, T, RUNS = 3, 10_000, 1000, 100
SIGMA = 2e-5
# Tree sizing for the cost-of-privacy row: round-robin over N=3 owners
# gives ceil(T/N) = 334 leaves per owner, so depth 9 (capacity 2^9-1 =
# 511) runs the whole schedule refusal-free while keeping the per-node
# scale depth * b(511) small enough to beat per-round Laplace at T=1000.
# (The default depth, bit_length(T) = 10, sizes capacity to the full
# horizon an adversarial schedule could demand — and loses the race.)
#
# Regime: the O(log K) advantage is a CUMULATIVE-noise property (the
# DP-FTRL aggregate sums every release), so the row runs the engine where
# the final model reflects the noise SUM — lr_scale small enough that the
# gradient restoring force is weak over the horizon (lr_own*T ~ 0.75).
# At paper-faithful rates the final iterate only remembers the last
# ~1/(lr*w) rounds and per-round scale wins: tree ships d*R/T >= d/N > 1
# times the per-round Laplace scale, so NO depth can win there at equal
# K — measured 12.6x WORSE at lr_scale=1 — which is exactly why DP-FTRL
# is stated for aggregated releases, not last-iterate SGD.
TREE_DEPTH, COP_EPS, COP_LR_SCALE = 9, 3.0, 0.005


def _final_params(n_seeds):
    """Final central model per seed for noiseless / Laplace / tree sessions
    of the SAME toy linear regression: same batches, same per-round keys,
    same round-robin schedule — the mechanism is the only difference, so
    the deviation from the paired noiseless run IS the injected-noise
    response of the dynamics."""
    d, m = 16, 32
    w_true = jax.random.normal(jax.random.PRNGKey(42), (d,)) / jnp.sqrt(d)

    def loss_fn(p, b):
        return jnp.mean((b["x"] @ p["w"] - b["y"]) ** 2)

    priv = PrivatizerConfig(xi=1.0, granularity="microbatch",
                            n_microbatches=4, fused_kernel=True)

    def session(noiseless=False, depth=None):
        owners = [DataOwner(n=N_PER, epsilon=COP_EPS, xi=1.0)
                  for _ in range(N_OWNERS)]
        cfg = FederationConfig(horizon=T, sigma=SIGMA, theta_max=4.0,
                               lr_scale=COP_LR_SCALE, noiseless=noiseless)
        fed = Federation(owners, cfg,
                         mechanism="paper" if depth is None else "tree",
                         **({} if depth is None else {"tree_depth": depth}))
        fed.make_step(loss_fn, privatizer=priv, pack_params=True)
        return fed

    feds = {"noiseless": session(noiseless=True),
            "laplace": session(),
            "tree": session(depth=TREE_DEPTH)}
    owner_seq = jnp.arange(T, dtype=jnp.int32) % N_OWNERS
    params0 = {"w": jnp.zeros((d,), jnp.float32)}
    finals = {name: [] for name in feds}
    for seed in range(n_seeds):
        kb, kr = jax.random.split(jax.random.PRNGKey(100 + seed))
        x = jax.random.normal(kb, (T, m, d))
        y = (x @ w_true
             + 0.1 * jax.random.normal(jax.random.fold_in(kb, 1), (T, m)))
        for j, (name, fed) in enumerate(feds.items()):
            # a distinct stream per session: the noiseless trajectory is
            # key-independent (scale 0), and the laplace/tree deviations
            # are independent variance estimates either way
            ks = jax.random.fold_in(kr, j)
            st, met = fed.run_rounds(fed.init_state(params0),
                                     {"x": x, "y": y}, owner_seq, ks)
            if bool(np.asarray(met["refused"]).any()):
                raise RuntimeError(f"{name} session refused rounds — the "
                                   "CoP comparison needs a full schedule")
            finals[name].append(np.asarray(fed.params_of(st)["w"],
                                           np.float64))
    return finals


def tree_vs_laplace_row(n_seeds):
    # CoP metric: seed-mean squared deviation of the final model from its
    # seed-PAIRED noiseless run. To first order the excess loss equals
    # this deviation (quadratic objective, E[xx^T] = I); measuring the
    # loss difference directly would bury the same quantity under the
    # bias-cross-term's seed variance (resolving it needs ~1e4 seeds —
    # the paired deviation needs a handful).
    t0 = time.perf_counter()
    finals = _final_params(n_seeds)
    dt = (time.perf_counter() - t0) * 1e6 / (n_seeds * len(finals) * T)
    cop_l = float(np.mean([np.sum((w - w0) ** 2) for w, w0
                           in zip(finals["laplace"], finals["noiseless"])]))
    cop_t = float(np.mean([np.sum((w - w0) ** 2) for w, w0
                           in zip(finals["tree"], finals["noiseless"])]))
    ratio = cop_t / cop_l
    if ratio > 1.0:
        # Surfaces as an ERROR row in the harness CSV, which bench-smoke
        # treats as a failure: the tree mechanism must not cost MORE
        # privacy-induced loss than per-round Laplace at equal (eps, K).
        raise RuntimeError(
            f"tree CoP {cop_t:.4g} exceeds Laplace CoP {cop_l:.4g} "
            f"(ratio {ratio:.3f} > 1.0) at eps={COP_EPS}, K={T}, "
            f"depth={TREE_DEPTH}")
    return (f"convergence/tree_vs_laplace/eps{COP_EPS}/k{T}", dt,
            f"cop_laplace={cop_l:.4g};cop_tree={cop_t:.4g};"
            f"cop_ratio_tree_vs_laplace={ratio:.4g}x;depth={TREE_DEPTH}")


def run(n_runs: int = RUNS):
    rows = []
    cfg = FederationConfig(horizon=T, rho=1.0, sigma=SIGMA)
    for dataset in ("lending", "health"):
        shards = owner_shards(dataset, [N_PER] * N_OWNERS, seed=0,
                              heterogeneity=0.0)
        prob, owners = federate_problem(shards, 1.0, reg=1e-5, theta_max=2.0)
        for eps in (3.0, 7.0, 10.0):
            fed = Federation(with_budgets(owners, eps), cfg)
            t0 = time.perf_counter()
            tr = fed.run(jax.random.PRNGKey(0), prob, n_runs=n_runs)
            dt = (time.perf_counter() - t0) * 1e6 / (n_runs * T)
            psi = np.asarray(tr.psi)
            for k in (10, 100, 500, T):
                p25, p50, p75 = np.percentile(psi[:, k - 1], [25, 50, 75])
                rows.append((
                    f"convergence/{dataset}/eps{eps}/k{k}", dt,
                    f"p25={p25:.4g};p50={p50:.4g};p75={p75:.4g}"))
    rows.append(tree_vs_laplace_row(n_seeds=10 if n_runs >= RUNS else 5))
    return rows


if __name__ == "__main__":
    from benchmarks.common import fmt_rows
    print(fmt_rows(run()))
