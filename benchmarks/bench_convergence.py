"""Figs. 2 & 8: percentile statistics of relative fitness psi(theta_L,k)
over 100 runs for three privacy budgets, lending + health datasets — one
vmapped `Federation` session per (dataset, eps) cell."""
from __future__ import annotations

import time

import jax
import numpy as np

from repro.data import owner_shards
from repro.federation import (Federation, FederationConfig, federate_problem,
                              with_budgets)

N_OWNERS, N_PER, T, RUNS = 3, 10_000, 1000, 100
SIGMA = 2e-5


def run(n_runs: int = RUNS):
    rows = []
    cfg = FederationConfig(horizon=T, rho=1.0, sigma=SIGMA)
    for dataset in ("lending", "health"):
        shards = owner_shards(dataset, [N_PER] * N_OWNERS, seed=0,
                              heterogeneity=0.0)
        prob, owners = federate_problem(shards, 1.0, reg=1e-5, theta_max=2.0)
        for eps in (3.0, 7.0, 10.0):
            fed = Federation(with_budgets(owners, eps), cfg)
            t0 = time.perf_counter()
            tr = fed.run(jax.random.PRNGKey(0), prob, n_runs=n_runs)
            dt = (time.perf_counter() - t0) * 1e6 / (n_runs * T)
            psi = np.asarray(tr.psi)
            for k in (10, 100, 500, T):
                p25, p50, p75 = np.percentile(psi[:, k - 1], [25, 50, 75])
                rows.append((
                    f"convergence/{dataset}/eps{eps}/k{k}", dt,
                    f"p25={p25:.4g};p50={p50:.4g};p75={p75:.4g}"))
    return rows


if __name__ == "__main__":
    from benchmarks.common import fmt_rows
    print(fmt_rows(run()))
