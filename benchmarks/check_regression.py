"""CI bench regression guard: fail when the MLP-scale fused rounds/sec
drops more than --max-drop vs the committed BENCH_fused_rounds.json.

    python benchmarks/check_regression.py \
        --baseline /tmp/bench-baseline/BENCH_fused_rounds.json \
        --current BENCH_fused_rounds.json [--max-drop 0.2] [--match mlp]

Compares every ``rounds_per_sec_*`` derived metric of the rows whose name
contains --match (default: the MLP-scale rows — the compute-bound regime
where a real engine regression shows; the toy rows are dispatch-bound
noise). SKIPS (exit 0) when the baseline is missing (first PR with the
guard) or when the environment metadata differs — platform, device kind
or device count — since a laptop-vs-CI or CPU-vs-TPU comparison would
only produce false alarms. Pure stdlib: runs before any jax install.
"""
from __future__ import annotations

import argparse
import json
import os
import sys


def load(path):
    with open(path) as f:
        return json.load(f)


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--baseline", required=True)
    ap.add_argument("--current", required=True)
    ap.add_argument("--max-drop", type=float, default=0.2,
                    help="fail when 1 - current/baseline exceeds this")
    ap.add_argument("--match", default="mlp",
                    help="only guard rows whose name contains this")
    args = ap.parse_args()

    if not os.path.exists(args.baseline):
        print(f"SKIP: no committed baseline at {args.baseline}")
        return 0
    base, cur = load(args.baseline), load(args.current)
    if base.get("env") != cur.get("env"):
        print(f"SKIP: environment differs (baseline {base.get('env')} "
              f"vs current {cur.get('env')}) — cross-machine rounds/sec "
              f"comparisons only produce false alarms. The guard is "
              f"DORMANT until the committed baseline comes from this "
              f"environment: download BENCH_fused_rounds.json from a "
              f"bench-fast-results CI artifact and commit it to arm the "
              f"guard for CI runners.")
        return 0

    base_rows = {r["name"]: r["derived"] for r in base["rows"]}
    failures, checked = [], 0
    for row in cur["rows"]:
        if args.match not in row["name"] or row["name"] not in base_rows:
            continue
        b_derived = base_rows[row["name"]]
        for key, b_val in b_derived.items():
            if not key.startswith("rounds_per_sec"):
                continue
            c_val = row["derived"].get(key)
            if not isinstance(b_val, (int, float)) or not isinstance(
                    c_val, (int, float)) or b_val <= 0:
                continue
            checked += 1
            drop = 1.0 - c_val / b_val
            status = "FAIL" if drop > args.max_drop else "ok"
            print(f"{status}: {row['name']} {key}: {b_val:.0f} -> "
                  f"{c_val:.0f} ({-drop:+.1%})")
            if drop > args.max_drop:
                failures.append((row["name"], key, b_val, c_val))
    if not checked:
        print(f"SKIP: no comparable rounds_per_sec metrics matched "
              f"{args.match!r}")
        return 0
    if failures:
        print(f"\n{len(failures)} metric(s) regressed more than "
              f"{args.max_drop:.0%} vs the committed baseline")
        return 1
    print(f"\nall {checked} guarded metrics within {args.max_drop:.0%} "
          f"of the committed baseline")
    return 0


if __name__ == "__main__":
    sys.exit(main())
