"""CI bench regression guard: perf (rounds/sec) + derived convergence
metrics vs the committed BENCH_*.json baselines.

    python benchmarks/check_regression.py \
        --baseline /tmp/bench-baseline/BENCH_fused_rounds.json \
        --current BENCH_fused_rounds.json [--max-drop 0.2] [--match mlp] \
        [--convergence-baseline-dir /tmp/bench-baseline] \
        [--convergence-current-dir .] [--max-rise 0.5]

Two guards, one exit code:

* perf — every ``rounds_per_sec_*`` derived metric of the rows whose name
  contains --match (default: the MLP-scale rows — the compute-bound
  regime where a real engine regression shows; the toy rows are
  dispatch-bound noise) may not drop more than --max-drop. SKIPS when
  the baseline is missing (first PR with the guard) or when the
  environment metadata differs — a laptop-vs-CI or CPU-vs-TPU rounds/sec
  comparison would only produce false alarms.

* convergence — the derived metrics in CONVERGENCE_GUARDS (quantized-bank
  trajectory deviation, tree-vs-Laplace cost-of-privacy ratio) are
  smaller-is-better and SEED-DETERMINISTIC, so they are compared even
  when the environment differs. A guarded row or metric missing from the
  current run is a FAILURE naming the metric — a silently dropped suite
  row would disarm the guard, which is exactly the failure mode it
  exists to catch.

Pure stdlib: runs before any jax install.
"""
from __future__ import annotations

import argparse
import json
import os
import sys

# (suite json filename, row-name substring, derived metric key).
# Smaller is better for every entry; current may not exceed
# baseline * (1 + --max-rise).
CONVERGENCE_GUARDS = (
    ("BENCH_fused_rounds.json", "quant_convergence", "dev_vs_noise_floor"),
    ("BENCH_convergence.json", "tree_vs_laplace",
     "cop_ratio_tree_vs_laplace"),
    # fault layer (PR 8): the price of carrying the guards on the healthy
    # path is a within-run ratio (machine-independent), and the loss
    # ratio under injected faults is seed-deterministic
    ("BENCH_chaos.json", "guard_overhead", "overhead_ratio"),
    ("BENCH_chaos.json", "degradation_paper_f32", "loss_ratio"),
    # paged owner bank (PR 9): resident device bytes over the analytic
    # dense-bank cost — pure bytes math, machine-independent. A rise
    # means hot-tier state grew or started scaling with N again.
    ("BENCH_paged_bank.json", "paged_trace", "resident_bytes_ratio"),
    # staleness runtime (PR 10): decayed inertia must not lose to
    # decay=1 under a stale latency trace (ratio <= 1 guards the whole
    # point of the knob), and the deadline/retry bookkeeping must stay
    # within the fault layer's healthy-path overhead envelope — both
    # within-run, seed-deterministic ratios
    ("BENCH_chaos.json", "staleness_decay", "loss_ratio_decay"),
    ("BENCH_chaos.json", "retry_overhead", "overhead_ratio"),
)


def load(path):
    with open(path) as f:
        return json.load(f)


def _env_diff(base_env, cur_env) -> str:
    """Human-readable list of the keys that actually differ."""
    base_env, cur_env = base_env or {}, cur_env or {}
    parts = []
    for key in sorted(set(base_env) | set(cur_env)):
        b, c = base_env.get(key), cur_env.get(key)
        if b != c:
            parts.append(f"{key}: baseline={b!r} current={c!r}")
    return "; ".join(parts) or "(no differing keys found)"


def check_perf(args) -> "tuple[list, int]":
    if not os.path.exists(args.baseline):
        print(f"SKIP perf: no committed baseline at {args.baseline}")
        return [], 0
    base, cur = load(args.baseline), load(args.current)
    if base.get("env") != cur.get("env"):
        print(f"SKIP perf: environment differs — "
              f"{_env_diff(base.get('env'), cur.get('env'))} — "
              f"cross-machine rounds/sec comparisons only produce false "
              f"alarms. The guard is DORMANT until the committed baseline "
              f"comes from this environment: download "
              f"BENCH_fused_rounds.json from a bench-fast-results CI "
              f"artifact and commit it to arm the guard for CI runners.")
        return [], 0

    base_rows = {r["name"]: r["derived"] for r in base["rows"]}
    failures, checked = [], 0
    for row in cur["rows"]:
        if args.match not in row["name"] or row["name"] not in base_rows:
            continue
        b_derived = base_rows[row["name"]]
        for key, b_val in b_derived.items():
            if not key.startswith("rounds_per_sec"):
                continue
            c_val = row["derived"].get(key)
            if not isinstance(b_val, (int, float)) or not isinstance(
                    c_val, (int, float)) or b_val <= 0:
                continue
            checked += 1
            drop = 1.0 - c_val / b_val
            status = "FAIL" if drop > args.max_drop else "ok"
            print(f"{status}: {row['name']} {key}: {b_val:.0f} -> "
                  f"{c_val:.0f} ({-drop:+.1%})")
            if drop > args.max_drop:
                failures.append((row["name"], key))
    if not checked:
        print(f"SKIP perf: no comparable rounds_per_sec metrics matched "
              f"{args.match!r}")
    return failures, checked


def check_convergence(args) -> "tuple[list, int]":
    """Guard the derived convergence metrics. Deterministic seeds make
    them machine-independent, so no env gate; a missing guarded row in
    the CURRENT run fails by name instead of silently skipping."""
    failures, checked = [], 0
    for fname, substr, metric in CONVERGENCE_GUARDS:
        label = f"{fname}:{substr}:{metric}"
        base_path = os.path.join(args.convergence_baseline_dir, fname)
        cur_path = os.path.join(args.convergence_current_dir, fname)
        if not os.path.exists(base_path):
            print(f"SKIP convergence: no committed baseline at {base_path} "
                  f"(guard {label} arms on the first commit of that file)")
            continue
        if not os.path.exists(cur_path):
            failures.append(label)
            print(f"FAIL: guarded metric {label} — current run never wrote "
                  f"{cur_path}")
            continue
        base, cur = load(base_path), load(cur_path)
        base_rows = {r["name"]: r["derived"] for r in base["rows"]}
        cur_rows = [r for r in cur["rows"] if substr in r["name"]]
        if not cur_rows:
            failures.append(label)
            print(f"FAIL: guarded metric {label} — no row matching "
                  f"{substr!r} in the current {fname}; a dropped suite row "
                  f"silently disarms the guard")
            continue
        for row in cur_rows:
            c_val = row["derived"].get(metric)
            if not isinstance(c_val, (int, float)):
                failures.append(label)
                print(f"FAIL: guarded metric {label} — row {row['name']} "
                      f"carries no numeric {metric!r} "
                      f"(got {c_val!r})")
                continue
            b_val = (base_rows.get(row["name"]) or {}).get(metric)
            if not isinstance(b_val, (int, float)):
                # first run that emits this row: nothing to diff against
                print(f"ok: {row['name']} {metric}: (new) -> {c_val:.4g}")
                checked += 1
                continue
            checked += 1
            limit = b_val * (1.0 + args.max_rise)
            status = "FAIL" if c_val > limit else "ok"
            print(f"{status}: {row['name']} {metric}: {b_val:.4g} -> "
                  f"{c_val:.4g} (limit {limit:.4g})")
            if c_val > limit:
                failures.append(label)
    return failures, checked


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--baseline", required=True)
    ap.add_argument("--current", required=True)
    ap.add_argument("--max-drop", type=float, default=0.2,
                    help="fail when 1 - current/baseline exceeds this")
    ap.add_argument("--match", default="mlp",
                    help="only guard rows whose name contains this")
    ap.add_argument("--convergence-baseline-dir", default=None,
                    help="dir holding the committed BENCH_*.json for the "
                         "CONVERGENCE_GUARDS table (omit to skip)")
    ap.add_argument("--convergence-current-dir", default=".",
                    help="dir the current run wrote its BENCH_*.json into")
    ap.add_argument("--max-rise", type=float, default=0.5,
                    help="fail when a guarded convergence metric exceeds "
                         "baseline * (1 + this)")
    args = ap.parse_args()

    perf_fail, perf_checked = check_perf(args)
    conv_fail, conv_checked = ([], 0)
    if args.convergence_baseline_dir is not None:
        conv_fail, conv_checked = check_convergence(args)

    failures = perf_fail + conv_fail
    checked = perf_checked + conv_checked
    if failures:
        print(f"\n{len(failures)} guarded metric(s) out of bounds vs the "
              f"committed baseline: "
              + ", ".join(f"{f[0]} {f[1]}" if isinstance(f, tuple) else f
                          for f in failures))
        return 1
    if not checked:
        print("SKIP: nothing compared (baselines missing or dormant)")
        return 0
    print(f"\nall {checked} guarded metrics within bounds of the "
          f"committed baseline")
    return 0


if __name__ == "__main__":
    sys.exit(main())
