"""Paged owner bank (PR 9): resident-memory scaling + paging overhead.

Two question families, both on the fused driver (engine-direct, no
session layer, so the numbers isolate the pager itself):

  * parity — flat bank vs paged bank (n_hot = N, every row permanently
    resident) on the identical workload: rounds/sec of both and their
    ratio. At full residency the paged engine's only extra cost is the
    in-scan page-table lookup (searchsorted over n_hot ids) and the slot
    indirection — the ratio is that price, and the regression guard pins
    the absolute rounds/sec.
  * paged_trace — a LARGE federation (10k owners always; 100k in the
    full run) streamed from an availability trace through a TraceRing,
    hot tier fixed at n_hot rows: rounds/sec with eviction/prefetch
    traffic in the loop, plus `resident_bytes` (measured device bytes of
    the paged row state), `flat_bytes` (what the dense (N, P) bank WOULD
    cost — analytic, never allocated), and their ratio. The two rows
    share one n_hot, so equal resident_bytes across owner scales is the
    working-set claim made measurable; `resident_bytes_ratio` is
    machine-independent and sits in check_regression's convergence-guard
    table.

Timings are interleaved medians (engines alternate within each rep) so
machine noise hits both alike.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.federation.deep import (AsyncDPConfig, init_state_flat,
                                   make_fused_rounds)
from repro.federation.dp_sgd import PrivatizerConfig
from repro.federation.paging import init_paged_state
from repro.federation.schedules import TraceRing

DIM, BATCH = 32, 8


def _model():
    key = jax.random.PRNGKey(0)
    params = {"w": jax.random.normal(key, (DIM, DIM)) / DIM,
              "b": jnp.zeros((DIM,))}

    def loss_fn(p, b):
        return jnp.mean((b["x"] @ p["w"] + p["b"] - b["y"]) ** 2)

    return params, loss_fn


def _batches(k):
    return {"x": jax.random.normal(jax.random.PRNGKey(1), (k, BATCH, DIM)),
            "y": jax.random.normal(jax.random.PRNGKey(2), (k, BATCH, DIM))}


def _cfg(n_owners: int) -> AsyncDPConfig:
    return AsyncDPConfig(
        n_owners=n_owners, horizon=1 << 20,
        epsilons=(2.0,) * n_owners, owner_sizes=(10_000,) * n_owners,
        caps=(64,) * n_owners,
        privatizer=PrivatizerConfig(xi=1.0, granularity="microbatch",
                                    n_microbatches=1))


def _paged_nbytes(state) -> int:
    """Measured device bytes of the PAGED row state (hot rows + page
    table). (N,)-scalar counters are excluded on both sides of the
    ratio — they are identical between flat and paged by design."""
    bank = state.bank
    n = int(np.asarray(bank.hot_ids).nbytes)
    hot = bank.hot
    leaves = jax.tree_util.tree_leaves(hot)
    return n + sum(int(np.prod(x.shape)) * x.dtype.itemsize for x in leaves)


def measure_parity(n_owners: int, n_rounds: int, reps: int = 9):
    """Interleaved-median seconds for K fused rounds: flat bank vs the
    paged bank at FULL residency (n_hot = n_owners) on the same
    schedule, batches, and keys."""
    params, loss_fn = _model()
    cfg = _cfg(n_owners)
    batches = _batches(n_rounds)
    seq = jnp.asarray(
        np.random.default_rng(5).integers(0, n_owners, n_rounds), jnp.int32)
    keys = jax.random.split(jax.random.PRNGKey(6), n_rounds)
    run = jax.jit(make_fused_rounds(loss_fn, cfg))

    s_flat = init_state_flat(params, cfg)
    s_paged, pager = init_paged_state(params, cfg, n_hot=n_owners)
    s_paged = pager.prefetch(s_paged, np.asarray(seq))

    def once(state):
        out, _ = run(state, batches, seq, keys)
        jax.block_until_ready(out.theta_L.buf)
        return out

    once(s_flat), once(s_paged)              # compile both programs
    t_flat, t_paged = [], []
    for _ in range(reps):
        t0 = time.perf_counter()
        once(s_flat)
        t1 = time.perf_counter()
        once(s_paged)
        t2 = time.perf_counter()
        t_flat.append(t1 - t0)
        t_paged.append(t2 - t1)
    return float(np.median(t_flat)), float(np.median(t_paged))


def measure_trace(n_owners: int, n_hot: int, k_total: int, chunk: int,
                  trace_len: int = 4096):
    """Rounds/sec of the paged engine streaming an availability trace
    through a TraceRing at a FIXED hot tier, prefetch/evict traffic
    included. Returns (seconds, resident_bytes, flat_bytes, stats)."""
    params, loss_fn = _model()
    cfg = _cfg(n_owners)
    rng = np.random.default_rng(7)
    # zipf-flavored trace: a heavy head (the working set that stays
    # resident) over a long uniform tail (the eviction traffic)
    head = rng.integers(0, n_hot // 2, trace_len // 2)
    tail = rng.integers(0, n_owners, trace_len - head.size)
    trace = np.empty(trace_len, np.int64)
    trace[0::2], trace[1::2] = head, tail
    run = jax.jit(make_fused_rounds(loss_fn, cfg))
    batches = _batches(chunk)
    keys = jax.random.split(jax.random.PRNGKey(8), chunk)

    state, pager = init_paged_state(params, cfg, n_hot=n_hot)
    flat_bytes = n_owners * int(np.asarray(state.theta_L.buf).size) * 4
    resident = _paged_nbytes(state)

    def stream(state, ring, rounds):
        for _ in range(rounds // chunk):
            window = ring.window(chunk)
            state = pager.prefetch(state, window)
            state, _ = run(state, batches, ring.next(chunk), keys)
        jax.block_until_ready(state.theta_L.buf)
        return state

    state = stream(state, TraceRing(trace, chunk=4 * chunk), chunk)  # warm
    ring = TraceRing(trace, chunk=4 * chunk)
    t0 = time.perf_counter()
    stream(state, ring, k_total)
    dt = time.perf_counter() - t0
    return dt, resident, flat_bytes, dict(pager.stats)


def run(fast: bool = False):
    rows = []
    # fixed row shapes in BOTH modes: CI's --fast rows must carry the
    # same names as the committed full-run baseline or the rounds/sec
    # guard only ever sees "new" rows; fast mode trims reps, not shape
    n_par, k = 64, 192
    reps = 5 if fast else 9
    dt_f, dt_p = measure_parity(n_par, k, reps=reps)
    rows.append((
        f"paged_bank/parity/owners{n_par}/K{k}", dt_p / k * 1e6,
        f"rounds_per_sec_flat={k / dt_f:.1f};"
        f"rounds_per_sec_paged={k / dt_p:.1f};"
        f"paged_vs_flat={dt_p / dt_f:.3f}x"))

    # the 10k row always runs at the SAME shape (its name must match the
    # committed baseline exactly, or the CI ratio guard only ever sees a
    # "new" row); the 100k row is the full run's scaling point — same
    # n_hot, so resident_bytes must not move while flat_bytes grows 10x
    scales = [(10_000, 512)]
    if not fast:
        scales.append((100_000, 512))
    for n_owners, k_total in scales:
        n_hot, chunk = 256, 64
        dt, resident, flat_bytes, stats = measure_trace(
            n_owners, n_hot, k_total, chunk)
        rows.append((
            f"paged_bank/paged_trace/owners{n_owners}/hot{n_hot}/K{k_total}",
            dt / k_total * 1e6,
            f"rounds_per_sec_paged={k_total / dt:.1f};"
            f"resident_bytes={resident};flat_bytes={flat_bytes};"
            f"resident_bytes_ratio={resident / flat_bytes:.6f};"
            f"loads={stats['loads']};evictions={stats['evictions']}"))
    return rows


if __name__ == "__main__":
    from benchmarks.common import fmt_rows
    print(fmt_rows(run()))
